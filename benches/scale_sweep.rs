//! Host-side simulator throughput on the scale-sweep path (plain harness;
//! criterion is unavailable offline). Reports protocol rounds simulated per
//! wall-second — the number that bounds how far the sweep axes (workers ×
//! modes × architectures) can be pushed — plus the relative wall-time cost
//! of enabling the trace layer on the same epochs. Feeds
//! EXPERIMENTS.md §Scale sweep and BENCH_scale_sweep.json.

use std::time::Instant;

use slsgpu::cloud::FrameworkKind;
use slsgpu::coordinator::{strategy_for, ClusterEnv, EnvConfig, SyncMode};
use slsgpu::exp::scale_sweep::{run, SweepConfig};
use slsgpu::trace::TraceConfig;

/// Simulate one epoch of one (framework, W, mode) point and report
/// rounds/second of host wall time. Returns (rounds/s, protocol ops).
fn bench_point(
    fw: FrameworkKind,
    workers: usize,
    mode: SyncMode,
    batches: usize,
    trace: TraceConfig,
) -> (f64, u64) {
    let mut cfg = EnvConfig::virtual_paper(fw, "mobilenet", workers)
        .unwrap()
        .with_sync(mode)
        .with_trace(trace);
    cfg.batches_per_epoch = batches;
    let mut env = ClusterEnv::new(cfg).unwrap();
    let mut strategy = strategy_for(fw);
    let t0 = Instant::now();
    strategy.run_epoch(&mut env).unwrap();
    let secs = t0.elapsed().as_secs_f64();
    (batches as f64 / secs, env.comm.total_ops())
}

fn bench_point_report(fw: FrameworkKind, workers: usize, mode: SyncMode, batches: usize) {
    let (rps, ops) = bench_point(fw, workers, mode, batches, TraceConfig::disabled());
    println!(
        "{:<14} W={:<4} {:<8} {:>6} rounds  {:>10.1} rounds/s  {:>8} ops",
        fw.name(),
        workers,
        mode.label(),
        batches,
        rps,
        ops
    );
}

/// Same epoch with tracing off vs on; the ratio is the observability tax.
/// The vtime/cost results are bit-identical either way (asserted in
/// `rust/tests/determinism.rs`) — only host wall time may move.
fn bench_trace_overhead(fw: FrameworkKind, workers: usize, batches: usize) {
    // Warm-up + best-of-3 per setting to damp allocator/cache noise.
    let best = |trace: TraceConfig| {
        bench_point(fw, workers, SyncMode::Bsp, batches, trace.clone());
        (0..3)
            .map(|_| bench_point(fw, workers, SyncMode::Bsp, batches, trace.clone()).0)
            .fold(0.0_f64, f64::max)
    };
    let off = best(TraceConfig::disabled());
    let on = best(TraceConfig::on());
    println!(
        "{:<14} W={:<4} untraced {:>10.1} rounds/s  traced {:>10.1} rounds/s  overhead {:>5.1}%",
        fw.name(),
        workers,
        off,
        on,
        (off / on - 1.0) * 100.0
    );
}

fn main() {
    println!("-- single points (one epoch each) --");
    for fw in [FrameworkKind::AllReduce, FrameworkKind::ScatterReduce, FrameworkKind::Spirt] {
        for workers in [16, 64, 256] {
            for mode in [SyncMode::Bsp, SyncMode::Async { staleness: 2 }] {
                bench_point_report(fw, workers, mode, 24);
            }
        }
    }

    println!("-- w1024 single points (event-queue core, one BSP epoch, 4 batches) --");
    // The extended-grid anchor: before the discrete-event scheduler core
    // these points were dominated by O(W^2 log W)-ish wait resolution and
    // unbounded busy-interval history; rounds/s here is the before/after
    // number BENCH_scale_sweep.json's `w1024` section records.
    for fw in FrameworkKind::ALL {
        bench_point_report(fw, 1024, SyncMode::Bsp, 4);
    }

    println!("-- trace-layer overhead (BSP, one epoch, best of 3) --");
    for fw in FrameworkKind::ALL {
        for workers in [16, 256] {
            bench_trace_overhead(fw, workers, 24);
        }
    }

    println!("-- threaded sweep (5 architectures x W x 2 modes) --");
    for workers in [vec![4, 16], vec![4, 16, 64]] {
        let cfg = SweepConfig {
            worker_counts: workers.clone(),
            batches_per_epoch: 24,
            threads: 0,
            ..SweepConfig::default()
        };
        let points = cfg.worker_counts.len() * cfg.modes.len() * 5;
        let rounds = points * cfg.batches_per_epoch;
        let t0 = Instant::now();
        run(&cfg).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "sweep W={workers:?}: {points:>3} points  {:>8.1} rounds/s  {secs:.2}s total",
            rounds as f64 / secs
        );
    }
}
