//! Bench: regenerate the paper's Table 2 (time / peak RAM / cost per epoch).
//! Plain harness (criterion is unavailable offline): prints the table and
//! the wall time to produce it.
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let rows = slsgpu::exp::table2::run(4).expect("table2");
    print!("{}", slsgpu::exp::table2::render(&rows));
    println!("regenerated in {:.0} ms", t0.elapsed().as_secs_f64() * 1000.0);
}
