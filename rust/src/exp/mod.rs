//! Experiment drivers: one per table/figure in the paper's evaluation.
//!
//! Each driver runs the relevant protocol(s) through the full substrate
//! stack and renders the paper's rows next to our measured values, so the
//! reproduction status is visible at a glance. See DESIGN.md §2 for the
//! experiment index and EXPERIMENTS.md for recorded outputs.

pub mod fig2;
pub mod fig3;
pub mod scale_sweep;
pub mod spirt_indb;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4_faults;

/// Relative error helper for paper-vs-measured columns.
pub fn rel_err(measured: f64, paper: f64) -> f64 {
    if paper == 0.0 {
        return 0.0;
    }
    (measured - paper).abs() / paper.abs()
}

/// Format a measured-vs-paper cell: `measured (paper, ±err%)`. A zero paper
/// value has no meaningful relative error (and dividing by it would render
/// `inf`/`NaN`), so the percentage is omitted for that cell.
pub fn vs_paper(measured: f64, paper: f64, digits: usize) -> String {
    if paper == 0.0 {
        return format!("{measured:.prec$} (paper {paper:.prec$})", prec = digits);
    }
    format!(
        "{measured:.prec$} (paper {paper:.prec$}, {:+.1}%)",
        (measured - paper) / paper * 100.0,
        prec = digits
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_err_basics() {
        assert!((rel_err(110.0, 100.0) - 0.1).abs() < 1e-12);
        assert_eq!(rel_err(5.0, 0.0), 0.0);
    }

    #[test]
    fn vs_paper_formats() {
        let s = vs_paper(14.0, 14.343, 2);
        assert!(s.starts_with("14.00 (paper 14.34"), "{s}");
    }

    #[test]
    fn vs_paper_zero_paper_value_has_no_inf_or_nan() {
        let s = vs_paper(5.0, 0.0, 1);
        assert_eq!(s, "5.0 (paper 0.0)");
        assert!(!s.contains("inf") && !s.contains("NaN"), "{s}");
    }
}
