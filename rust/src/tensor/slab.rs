//! The `Slab` type: a flat f32 vector, real or size-only.
//!
//! Real slabs are `Arc`-backed: `clone`/[`Slab::share`] hand out a second
//! reference to the same buffer in O(1), and mutating ops copy-on-write
//! (`Arc::make_mut`). This is what lets the protocol layer move gradients
//! through stores, queues and peer databases without deep-copying 16–100 MB
//! payloads on every hop — the scale-sweep hot path at 256 workers.

use std::sync::Arc;

use anyhow::{bail, Result};

/// Block length for the chunked element-wise kernels below.
///
/// The contract (see DESIGN.md "Chunked tensor kernels"):
///
/// * Element-wise ops (`axpy`, `scale`, `sgd`, `mean`, the `axpy_new` /
///   `scale_new` constructors) are **bit-identical** to the unchunked loops
///   they replaced — chunking only re-blocks the iteration, each element
///   still sees exactly the same sequence of operations.
/// * Chunked *reductions* (`l2_norm_sq`) sum per-chunk partials instead of
///   one long serial chain. That is bit-identical for slabs up to one chunk
///   (the unit-test regime) and exact on integer-valued data, but may differ
///   in the last ulp from the serial sum on general data longer than a
///   chunk — callers that need the old bits must not exceed `KERNEL_CHUNK`.
///
/// 4096 f32 lanes = 16 KiB per operand block: two operands stay resident in
/// a 32 KiB L1 slice, and the fixed trip count lets the autovectorizer emit
/// clean SIMD bodies (see the Pallas guide's tiling discussion — same idea,
/// CPU-sized).
pub const KERNEL_CHUNK: usize = 4096;

/// A flat f32 tensor slab.
#[derive(Debug, Clone, PartialEq)]
pub enum Slab {
    /// Backed by shared memory; elementwise math is real and mutation is
    /// copy-on-write.
    Real(Arc<Vec<f32>>),
    /// Size-only stand-in for paper-scale payloads; math is a no-op that
    /// preserves length (time/cost models only need bytes).
    Virtual { len: usize },
}

impl Slab {
    pub fn zeros(len: usize) -> Slab {
        Slab::Real(Arc::new(vec![0.0; len]))
    }

    pub fn virtual_of(len: usize) -> Slab {
        Slab::Virtual { len }
    }

    pub fn from_vec(v: Vec<f32>) -> Slab {
        Slab::Real(Arc::new(v))
    }

    /// A cheap second handle to the same payload (O(1): bumps the refcount
    /// for real slabs, copies a length for virtual ones). Use this instead
    /// of `clone` on protocol hot paths to make the non-copying intent
    /// grep-visible.
    pub fn share(&self) -> Slab {
        self.clone()
    }

    pub fn len(&self) -> usize {
        match self {
            Slab::Real(v) => v.len(),
            Slab::Virtual { len } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_real(&self) -> bool {
        matches!(self, Slab::Real(_))
    }

    /// Payload size on the wire (f32).
    pub fn nbytes(&self) -> u64 {
        self.len() as u64 * 4
    }

    pub fn as_slice(&self) -> Result<&[f32]> {
        match self {
            Slab::Real(v) => Ok(v.as_slice()),
            Slab::Virtual { .. } => bail!("virtual slab has no data"),
        }
    }

    pub fn zeros_like(&self) -> Slab {
        match self {
            Slab::Real(v) => Slab::zeros(v.len()),
            Slab::Virtual { len } => Slab::Virtual { len: *len },
        }
    }

    fn check_len(&self, other: &Slab) -> Result<()> {
        if self.len() != other.len() {
            bail!("slab length mismatch: {} vs {}", self.len(), other.len());
        }
        Ok(())
    }

    /// `self += w * g` — the aggregation primitive (pure-Rust path, used by
    /// the "naive" baselines; the in-database path runs the PJRT kernel).
    /// Chunk-blocked, bit-identical to the plain loop (see [`KERNEL_CHUNK`]).
    pub fn axpy(&mut self, g: &Slab, w: f32) -> Result<()> {
        self.check_len(g)?;
        if let (Slab::Real(a), Slab::Real(b)) = (&mut *self, g) {
            let a = Arc::make_mut(a);
            for (ac, bc) in a.chunks_mut(KERNEL_CHUNK).zip(b.chunks(KERNEL_CHUNK)) {
                for (x, y) in ac.iter_mut().zip(bc.iter()) {
                    *x += w * *y;
                }
            }
        }
        Ok(())
    }

    /// `self *= s`.
    pub fn scale(&mut self, s: f32) {
        if let Slab::Real(v) = self {
            for c in Arc::make_mut(v).chunks_mut(KERNEL_CHUNK) {
                for x in c.iter_mut() {
                    *x *= s;
                }
            }
        }
    }

    /// `self -= lr * g` — SGD apply (pure-Rust path).
    pub fn sgd(&mut self, g: &Slab, lr: f32) -> Result<()> {
        self.axpy(g, -lr)
    }

    /// Sum of squares, accumulated per [`KERNEL_CHUNK`] block. Breaking the
    /// one long serial add chain into per-chunk partials is what lets the
    /// reduction vectorize; the bit-level contract is documented on
    /// [`KERNEL_CHUNK`] (identical ≤ one chunk, exact on integer data).
    pub fn l2_norm_sq(&self) -> f64 {
        match self {
            Slab::Real(v) => v
                .chunks(KERNEL_CHUNK)
                .map(|c| c.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>())
                .sum(),
            Slab::Virtual { .. } => 0.0,
        }
    }

    /// `a + w * b` as a fresh slab, built in one pass. This is the kernel
    /// behind [`crate::tensor::RustMath`]'s `acc`/`sgd`/`avg_update`: the
    /// old `clone` + `axpy` form memcpy'd the source and then re-walked it
    /// read-modify-write; this writes each output element once. Matches
    /// `clone`+`axpy` exactly — same length check, same `*a + w * *b`
    /// element expression, and the result is a shared handle to `a` unless
    /// both operands are real.
    pub fn axpy_new(a: &Slab, b: &Slab, w: f32) -> Result<Slab> {
        a.check_len(b)?;
        if let (Slab::Real(x), Slab::Real(y)) = (a, b) {
            let mut out = Vec::with_capacity(x.len());
            for (xc, yc) in x.chunks(KERNEL_CHUNK).zip(y.chunks(KERNEL_CHUNK)) {
                out.extend(xc.iter().zip(yc.iter()).map(|(p, q)| *p + w * *q));
            }
            Ok(Slab::Real(Arc::new(out)))
        } else {
            Ok(a.share())
        }
    }

    /// `w * src` as a fresh slab, built in one pass (the single-source
    /// counterpart of [`Slab::axpy_new`]; bit-identical to `clone`+`scale`).
    pub fn scale_new(src: &Slab, w: f32) -> Slab {
        match src {
            Slab::Real(v) => {
                let mut out = Vec::with_capacity(v.len());
                for c in v.chunks(KERNEL_CHUNK) {
                    out.extend(c.iter().map(|x| *x * w));
                }
                Slab::Real(Arc::new(out))
            }
            Slab::Virtual { len } => Slab::Virtual { len: *len },
        }
    }

    /// Mean of `k` slabs (all must be same length). Virtual if any input is.
    ///
    /// Single blocked pass: each [`KERNEL_CHUNK`]-sized block of the output
    /// accumulates every input's matching block while it is cache-resident,
    /// instead of the old `k` full-length `axpy` sweeps (k × 100 MB of
    /// traffic per aggregation at paper scale). Per element the adds still
    /// run in slab order with the same `+= w * y` expression, so the result
    /// is bit-identical to the multi-pass form.
    pub fn mean(slabs: &[Slab]) -> Result<Slab> {
        if slabs.is_empty() {
            bail!("mean of zero slabs");
        }
        let len = slabs[0].len();
        if slabs.iter().any(|s| s.len() != len) {
            bail!("slab length mismatch in mean");
        }
        if slabs.iter().any(|s| !s.is_real()) {
            return Ok(Slab::Virtual { len });
        }
        let views: Vec<&[f32]> = slabs.iter().map(|s| s.as_slice()).collect::<Result<_>>()?;
        let w = 1.0 / slabs.len() as f32;
        let mut out = vec![0.0f32; len];
        let mut start = 0;
        while start < len {
            let end = (start + KERNEL_CHUNK).min(len);
            let ob = &mut out[start..end];
            for v in &views {
                for (x, y) in ob.iter_mut().zip(v[start..end].iter()) {
                    *x += w * *y;
                }
            }
            start = end;
        }
        Ok(Slab::Real(Arc::new(out)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_real() {
        let mut a = Slab::from_vec(vec![1.0, 2.0]);
        a.axpy(&Slab::from_vec(vec![10.0, 20.0]), 0.5).unwrap();
        assert_eq!(a.as_slice().unwrap(), &[6.0, 12.0]);
    }

    #[test]
    fn axpy_virtual_is_noop_but_typed() {
        let mut a = Slab::virtual_of(5);
        a.axpy(&Slab::virtual_of(5), 1.0).unwrap();
        assert_eq!(a.len(), 5);
        assert!(!a.is_real());
        assert!(a.axpy(&Slab::virtual_of(4), 1.0).is_err());
    }

    #[test]
    fn sgd_matches_manual() {
        let mut theta = Slab::from_vec(vec![1.0, 1.0, 1.0]);
        theta.sgd(&Slab::from_vec(vec![1.0, 2.0, 3.0]), 0.1).unwrap();
        let got = theta.as_slice().unwrap();
        for (g, w) in got.iter().zip([0.9, 0.8, 0.7]) {
            assert!((g - w).abs() < 1e-6);
        }
    }

    #[test]
    fn mean_of_slabs() {
        let m = Slab::mean(&[
            Slab::from_vec(vec![1.0, 3.0]),
            Slab::from_vec(vec![3.0, 5.0]),
        ])
        .unwrap();
        assert_eq!(m.as_slice().unwrap(), &[2.0, 4.0]);
    }

    #[test]
    fn mean_propagates_virtual() {
        let m = Slab::mean(&[Slab::zeros(3), Slab::virtual_of(3)]).unwrap();
        assert!(!m.is_real());
        assert_eq!(m.len(), 3);
    }

    #[test]
    fn nbytes_is_4x() {
        assert_eq!(Slab::virtual_of(1000).nbytes(), 4000);
    }

    #[test]
    fn norm() {
        assert_eq!(Slab::from_vec(vec![3.0, 4.0]).l2_norm_sq(), 25.0);
    }

    #[test]
    fn mean_empty_errors() {
        assert!(Slab::mean(&[]).is_err());
    }

    #[test]
    fn share_is_aliasing_until_mutation() {
        // share() hands out the same buffer; a mutating op copies-on-write
        // so the sibling handle never observes the change.
        let a = Slab::from_vec(vec![1.0, 2.0]);
        let b = a.share();
        if let (Slab::Real(va), Slab::Real(vb)) = (&a, &b) {
            assert!(Arc::ptr_eq(va, vb), "share must not deep-copy");
        } else {
            panic!("expected real slabs");
        }
        let mut c = b.share();
        c.axpy(&a, 1.0).unwrap();
        assert_eq!(a.as_slice().unwrap(), &[1.0, 2.0], "COW must protect siblings");
        assert_eq!(c.as_slice().unwrap(), &[2.0, 4.0]);
    }

    #[test]
    fn axpy_self_aliased_reads_pre_update_values() {
        let a = Slab::from_vec(vec![1.0, -2.0]);
        let mut b = a.share();
        b.axpy(&a, 1.0).unwrap();
        assert_eq!(b.as_slice().unwrap(), &[2.0, -4.0]);
        assert_eq!(a.as_slice().unwrap(), &[1.0, -2.0]);
    }

    // ---- chunked-kernel bit-equality pins --------------------------------
    // Each test compares a chunked kernel against the plain unchunked loop
    // it replaced, bit for bit, on data spanning several KERNEL_CHUNK blocks
    // plus a ragged tail. These are the regression anchors for the contract
    // documented on KERNEL_CHUNK.

    /// Deterministic quasi-random f32s (LCG), length deliberately not a
    /// multiple of KERNEL_CHUNK so the remainder path is exercised.
    fn noise(seed: u64, len: usize) -> Vec<f32> {
        let mut state = seed;
        (0..len)
            .map(|_| {
                state = state
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                ((state >> 40) as f32) / (1u64 << 24) as f32 - 0.5
            })
            .collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    const PIN_LEN: usize = 3 * KERNEL_CHUNK + 17;

    #[test]
    fn chunked_axpy_is_bit_identical_to_plain_loop() {
        let a0 = noise(1, PIN_LEN);
        let b0 = noise(2, PIN_LEN);
        let mut reference = a0.clone();
        for (x, y) in reference.iter_mut().zip(b0.iter()) {
            *x += 0.37 * *y;
        }
        let mut a = Slab::from_vec(a0);
        a.axpy(&Slab::from_vec(b0), 0.37).unwrap();
        assert_eq!(bits(a.as_slice().unwrap()), bits(&reference));
    }

    #[test]
    fn chunked_scale_is_bit_identical_to_plain_loop() {
        let v0 = noise(3, PIN_LEN);
        let reference: Vec<f32> = v0.iter().map(|x| *x * -1.9).collect();
        let mut s = Slab::from_vec(v0.clone());
        s.scale(-1.9);
        assert_eq!(bits(s.as_slice().unwrap()), bits(&reference));
        // The one-pass constructor agrees with the in-place kernel.
        let fresh = Slab::scale_new(&Slab::from_vec(v0), -1.9);
        assert_eq!(bits(fresh.as_slice().unwrap()), bits(&reference));
    }

    #[test]
    fn axpy_new_is_bit_identical_to_clone_then_axpy() {
        let a = Slab::from_vec(noise(4, PIN_LEN));
        let b = Slab::from_vec(noise(5, PIN_LEN));
        let mut reference = a.share();
        reference.axpy(&b, -0.125).unwrap();
        let fused = Slab::axpy_new(&a, &b, -0.125).unwrap();
        assert_eq!(bits(fused.as_slice().unwrap()), bits(reference.as_slice().unwrap()));
        // Mixed real/virtual operands keep the clone+axpy semantics.
        assert!(Slab::axpy_new(&a, &Slab::virtual_of(PIN_LEN), 1.0).unwrap().is_real());
        assert!(!Slab::axpy_new(&Slab::virtual_of(PIN_LEN), &b, 1.0).unwrap().is_real());
        assert!(Slab::axpy_new(&a, &Slab::virtual_of(7), 1.0).is_err());
    }

    #[test]
    fn single_pass_mean_is_bit_identical_to_axpy_sweeps() {
        let slabs: Vec<Slab> =
            (0..5).map(|i| Slab::from_vec(noise(10 + i, PIN_LEN))).collect();
        // Reference: the old multi-pass form — zeros, then one full-length
        // axpy per slab.
        let mut reference = Slab::zeros(PIN_LEN);
        let w = 1.0 / slabs.len() as f32;
        for s in &slabs {
            reference.axpy(s, w).unwrap();
        }
        let got = Slab::mean(&slabs).unwrap();
        assert_eq!(bits(got.as_slice().unwrap()), bits(reference.as_slice().unwrap()));
    }

    #[test]
    fn l2_norm_sq_keeps_old_bits_within_one_chunk() {
        // ≤ KERNEL_CHUNK elements: one partial == the old serial chain.
        let v = noise(6, KERNEL_CHUNK);
        let serial: f64 = v.iter().map(|x| (*x as f64) * (*x as f64)).sum();
        assert_eq!(Slab::from_vec(v).l2_norm_sq().to_bits(), serial.to_bits());
    }

    #[test]
    fn l2_norm_sq_is_exact_on_integer_data_across_chunks() {
        // Integer-valued f32s: every partial and the final sum are exact, so
        // chunked == serial == the closed form regardless of association.
        let v: Vec<f32> = (0..PIN_LEN).map(|i| ((i % 7) as f32) - 3.0).collect();
        let serial: f64 = v.iter().map(|x| (*x as f64) * (*x as f64)).sum();
        let expected: f64 = v.iter().map(|x| (*x * *x) as f64).sum();
        let got = Slab::from_vec(v).l2_norm_sq();
        assert_eq!(got.to_bits(), serial.to_bits());
        assert_eq!(got, expected);
    }
}
