//! The PJRT execution engine: compile-once, execute-many.
//!
//! One [`Engine`] per process. Artifacts are compiled lazily on first use
//! and cached; every subsequent call is a straight PJRT execute with no
//! recompilation and no Python. The typed wrappers (`init`, `grad`, `eval`,
//! slab ops) own the Literal marshalling of the flat-parameter ABI.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::tensor::{RustMath, Slab, SlabMath};

use super::manifest::Manifest;

/// Output of one grad-artifact execution.
#[derive(Debug, Clone)]
pub struct GradOutput {
    pub loss: f32,
    pub grads: Slab,
    /// Correct top-1 predictions in the batch.
    pub correct: u32,
}

/// PJRT CPU client + compiled-executable cache + manifest.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("platform", &self.client.platform_name())
            .field("compiled", &self.cache.borrow().len())
            .finish()
    }
}

impl Engine {
    /// Create the engine over an artifacts directory (needs manifest.json).
    pub fn load(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Compile (or fetch cached) an artifact by file name.
    fn executable(&self, file: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.artifact_path(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?,
        );
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile every artifact a model config needs (startup warm-up).
    pub fn warm_model(&self, model: &str) -> Result<()> {
        let entry = self.manifest.model(model)?.clone();
        for file in entry.artifacts.values() {
            self.executable(file)?;
        }
        let slab = self.manifest.slab(model)?.clone();
        for file in slab.artifacts.values() {
            self.executable(file)?;
        }
        Ok(())
    }

    fn run(&self, file: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(file)?;
        let result = exe.execute::<xla::Literal>(args)?;
        let tupled = result[0][0].to_literal_sync()?;
        Ok(tupled.to_tuple()?)
    }

    fn artifact_of(&self, model: &str, kind: &str) -> Result<String> {
        let entry = self.manifest.model(model)?;
        entry
            .artifacts
            .get(kind)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("model {model} lacks {kind} artifact"))
    }

    fn slab_artifact_of(&self, slab: &str, kind: &str) -> Result<String> {
        let entry = self.manifest.slab(slab)?;
        entry
            .artifacts
            .get(kind)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("slab {slab} lacks {kind} artifact"))
    }

    // -- typed calls ------------------------------------------------------

    /// He-normal initial parameters for a model config (seeded).
    pub fn init(&self, model: &str, seed: u32) -> Result<Slab> {
        let file = self.artifact_of(model, "init")?;
        let out = self.run(&file, &[xla::Literal::scalar(seed)])?;
        let theta = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow::anyhow!("init returned empty tuple"))?;
        Ok(Slab::from_vec(theta.to_vec::<f32>()?))
    }

    /// Fwd+bwd on one batch: `(loss, grads, correct)`.
    pub fn grad(&self, model: &str, theta: &Slab, x: &[f32], y: &[i32]) -> Result<GradOutput> {
        let entry = self.manifest.model(model)?;
        let (b, n) = (entry.batch, entry.n_params);
        if theta.len() != n {
            bail!("theta has {} params, model {model} needs {n}", theta.len());
        }
        if x.len() != b * 32 * 32 * 3 || y.len() != b {
            bail!("batch shape mismatch: x={} y={} for batch {b}", x.len(), y.len());
        }
        let file = self.artifact_of(model, "grad")?;
        let theta_lit = xla::Literal::vec1(theta.as_slice()?);
        let x_lit = xla::Literal::vec1(x).reshape(&[b as i64, 32, 32, 3])?;
        let y_lit = xla::Literal::vec1(y);
        let out = self.run(&file, &[theta_lit, x_lit, y_lit])?;
        if out.len() != 3 {
            bail!("grad artifact returned {} outputs, expected 3", out.len());
        }
        let mut it = out.into_iter();
        let loss = it.next().unwrap().get_first_element::<f32>()?;
        let grads = Slab::from_vec(it.next().unwrap().to_vec::<f32>()?);
        let correct = it.next().unwrap().get_first_element::<f32>()? as u32;
        Ok(GradOutput { loss, grads, correct })
    }

    /// Forward-only evaluation on one eval batch: `(loss, correct)`.
    pub fn eval(&self, model: &str, theta: &Slab, x: &[f32], y: &[i32]) -> Result<(f32, u32)> {
        let entry = self.manifest.model(model)?;
        let (b, n) = (entry.eval_batch, entry.n_params);
        if theta.len() != n || x.len() != b * 32 * 32 * 3 || y.len() != b {
            bail!("eval shape mismatch");
        }
        let file = self.artifact_of(model, "eval")?;
        let theta_lit = xla::Literal::vec1(theta.as_slice()?);
        let x_lit = xla::Literal::vec1(x).reshape(&[b as i64, 32, 32, 3])?;
        let y_lit = xla::Literal::vec1(y);
        let out = self.run(&file, &[theta_lit, x_lit, y_lit])?;
        if out.len() != 2 {
            bail!("eval artifact returned {} outputs, expected 2", out.len());
        }
        let loss = out[0].get_first_element::<f32>()?;
        let correct = out[1].get_first_element::<f32>()? as u32;
        Ok((loss, correct))
    }

    fn slab_binop(
        &self,
        slab_name: &str,
        kind: &str,
        a: &Slab,
        b: &Slab,
        scalars: &[f32],
    ) -> Result<Slab> {
        let entry = self.manifest.slab(slab_name)?;
        if a.len() != entry.n || b.len() != entry.n {
            bail!(
                "slab op {kind} on {slab_name}: lengths {}/{} vs artifact {}",
                a.len(),
                b.len(),
                entry.n
            );
        }
        let file = self.slab_artifact_of(slab_name, kind)?;
        let mut args = vec![xla::Literal::vec1(a.as_slice()?), xla::Literal::vec1(b.as_slice()?)];
        for s in scalars {
            args.push(xla::Literal::scalar(*s));
        }
        let out = self.run(&file, &args)?;
        Ok(Slab::from_vec(
            out.into_iter()
                .next()
                .ok_or_else(|| anyhow::anyhow!("empty tuple"))?
                .to_vec::<f32>()?,
        ))
    }

    /// Pallas `acc + w*g` at a named slab size.
    pub fn acc(&self, slab_name: &str, acc: &Slab, g: &Slab, w: f32) -> Result<Slab> {
        self.slab_binop(slab_name, "acc", acc, g, &[w])
    }

    /// Pallas `theta - lr*g`.
    pub fn sgd(&self, slab_name: &str, theta: &Slab, g: &Slab, lr: f32) -> Result<Slab> {
        self.slab_binop(slab_name, "sgd", theta, g, &[lr])
    }

    /// Pallas fused `theta - lr*(inv_k*gsum)` (the SPIRT in-DB op).
    pub fn avg_update(
        &self,
        slab_name: &str,
        theta: &Slab,
        gsum: &Slab,
        inv_k: f32,
        lr: f32,
    ) -> Result<Slab> {
        self.slab_binop(slab_name, "avg_update", theta, gsum, &[inv_k, lr])
    }
}

/// [`SlabMath`] backed by the PJRT-executed Pallas kernels — the faithful
/// "RedisAI in-database computation" analog. Virtual slabs (and slab sizes
/// without a compiled artifact) fall back to [`RustMath`] so cost-model
/// experiments run without the runtime.
pub struct PjrtMath {
    engine: Rc<Engine>,
    slab_name: String,
    fallback: RustMath,
}

impl PjrtMath {
    pub fn new(engine: Rc<Engine>, slab_name: impl Into<String>) -> PjrtMath {
        PjrtMath { engine, slab_name: slab_name.into(), fallback: RustMath }
    }

    fn usable(&self, a: &Slab, b: &Slab) -> bool {
        a.is_real()
            && b.is_real()
            && self
                .engine
                .manifest
                .slab(&self.slab_name)
                .map(|s| s.n == a.len())
                .unwrap_or(false)
    }
}

// SAFETY-adjacent note: the engine is not Sync (RefCell cache); the testbed
// is single-threaded by design (deterministic virtual time), so SlabMath's
// Send+Sync bound is satisfied by never actually sharing across threads.
// We keep the trait bound but construct PjrtMath only on the main thread.
unsafe impl Send for PjrtMath {}
unsafe impl Sync for PjrtMath {}

impl SlabMath for PjrtMath {
    fn acc(&self, acc: &Slab, g: &Slab, w: f32) -> Result<Slab> {
        if self.usable(acc, g) {
            self.engine.acc(&self.slab_name, acc, g, w)
        } else {
            self.fallback.acc(acc, g, w)
        }
    }

    fn avg_update(&self, theta: &Slab, gsum: &Slab, inv_k: f32, lr: f32) -> Result<Slab> {
        if self.usable(theta, gsum) {
            self.engine.avg_update(&self.slab_name, theta, gsum, inv_k, lr)
        } else {
            self.fallback.avg_update(theta, gsum, inv_k, lr)
        }
    }

    fn sgd(&self, theta: &Slab, g: &Slab, lr: f32) -> Result<Slab> {
        if self.usable(theta, g) {
            self.engine.sgd(&self.slab_name, theta, g, lr)
        } else {
            self.fallback.sgd(theta, g, lr)
        }
    }

    fn scale(&self, src: &Slab, w: f32) -> Result<Slab> {
        // No dedicated Pallas scale artifact is compiled; the portable loop
        // is memory-bound either way.
        self.fallback.scale(src, w)
    }
}
