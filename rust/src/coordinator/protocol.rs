//! Shared protocol plumbing: per-worker timelines, typed ops, sync policy.
//!
//! Every strategy used to hand-roll the same four-line sequence around each
//! substrate call — read the worker clock, issue the op at that time, charge
//! the elapsed span to a workflow stage, write the completion time back to
//! the clock. That bookkeeping now lives in exactly one place: a
//! [`Timeline`] is a borrowed handle on one worker's clock that executes
//! protocol operations against the [`ClusterEnv`]'s substrates and does the
//! clock-advance / stage-charge / ledger / fault-hook bookkeeping itself.
//!
//! Operations exist in two equivalent forms:
//!
//! * direct methods (`tl.put(..)`, `tl.poll(..)`) — what the strategies
//!   call on their hot paths;
//! * the [`Op`] value type executed via [`Timeline::exec`] — a typed,
//!   inspectable description of the same operations (`Put`, `Get`,
//!   `GetMany`, `Notify`, `Poll`, `RedisOp`, `Barrier`), used where a
//!   protocol step is built up as data (tests, trace tooling).
//!
//! The module also owns the synchronization policy. [`SyncMode::Bsp`] is
//! the paper's bulk-synchronous execution: every round waits for every
//! contribution. [`SyncMode::Async`] relaxes the round barrier to a
//! bounded-staleness quorum: a gather step must incorporate the earliest
//! `participants - staleness` contributions (never fewer than one) and
//! skips the rest, so a straggling or restarting worker delays nobody but
//! itself. Skipped contributions are counted in
//! [`CommStats::stale_skips`](crate::metrics::CommStats) — they are the
//! price async pays in lost signal, and the scale sweep reports them next
//! to the time/cost wins.
//!
//! Network partitions planned by the fault engine are enforced here, at
//! the only layer that knows the acting worker: every communication op
//! first consults `ClusterEnv::partition_gate`, which defers a partitioned
//! worker to its heal time before the op runs. Because the deferral lands
//! *before* the substrate call, a partitioned worker's writes, notifies
//! and uploads become visible only after the heal — the visibility and
//! quorum paths ([`store_quorum`], queue waits) therefore see the
//! reachability mask without any changes of their own, in every strategy.

use anyhow::Result;

use crate::metrics::Stage;
use crate::sim::{EventQueue, VTime};
use crate::tensor::Slab;
use crate::trace::EventKind;

use super::env::ClusterEnv;

/// Trace namespace for object-store keys (dep-edge lookup for `get`).
pub(crate) fn trace_store_key(store: StoreSel, key: &str) -> String {
    match store {
        StoreSel::Shared => format!("s3/{key}"),
        StoreSel::Gpu => format!("s3gpu/{key}"),
    }
}

/// Trace namespace for Redis keys; `own` resolves [`RedisSel::Own`]. Keys
/// on the shared tier carry their owning shard as a coordinate (routing is
/// deterministic, so writer and reader derive the same name).
pub(crate) fn trace_redis_key(
    sel: RedisSel,
    own: usize,
    cluster: &crate::cloud::RedisCluster,
    key: &str,
) -> String {
    match sel {
        RedisSel::Own => format!("redis{own}/{key}"),
        RedisSel::Peer(j) => format!("redis{j}/{key}"),
        RedisSel::Shared => format!("redis-shared/s{}/{key}", cluster.primary_of(key)),
    }
}

/// Round-synchronization policy — how long a worker waits at a sync point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncMode {
    /// Bulk-synchronous parallel: every round incorporates every live
    /// contribution (the paper's execution model).
    Bsp,
    /// Bounded staleness: a gather proceeds once all but `staleness`
    /// contributions are in; the stragglers' updates are skipped for the
    /// round instead of stalling it.
    Async { staleness: usize },
}

impl SyncMode {
    /// How many of `participants` contributions a gather must wait for.
    pub fn quorum(&self, participants: usize) -> usize {
        match self {
            SyncMode::Bsp => participants,
            SyncMode::Async { staleness } => {
                if participants == 0 {
                    0
                } else {
                    participants.saturating_sub(*staleness).max(1)
                }
            }
        }
    }

    pub fn is_async(&self) -> bool {
        matches!(self, SyncMode::Async { .. })
    }

    /// Parse a CLI spec: `bsp`, `async` (staleness 2), or `async:<k>`.
    pub fn parse(spec: &str) -> Result<SyncMode> {
        let spec = spec.trim().to_ascii_lowercase();
        Ok(match spec.as_str() {
            "bsp" | "sync" => SyncMode::Bsp,
            "async" => SyncMode::Async { staleness: 2 },
            other => match other.strip_prefix("async:") {
                Some(k) => SyncMode::Async { staleness: k.parse()? },
                None => anyhow::bail!("unknown sync mode {other:?} (bsp|async[:k])"),
            },
        })
    }

    /// Short label for tables/CSV (`bsp`, `async:2`).
    pub fn label(&self) -> String {
        match self {
            SyncMode::Bsp => "bsp".to_string(),
            SyncMode::Async { staleness } => format!("async:{staleness}"),
        }
    }
}

/// Which object store a `Put`/`Get` targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreSel {
    /// The shared gradient bucket (LambdaML AllReduce/ScatterReduce).
    Shared,
    /// The GPU-side bucket (EC2 bandwidth profile).
    Gpu,
}

/// Which Redis instance a `RedisOp` targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RedisSel {
    /// The timeline worker's own instance (SPIRT P2P database).
    Own,
    /// A peer worker's instance.
    Peer(usize),
    /// The shared instance (MLLess update store, LambdaML model store).
    Shared,
}

/// A Redis operation payload for [`Op::RedisOp`].
#[derive(Debug, Clone)]
pub enum RedisVerb {
    Set { key: String, payload: Slab },
    Get { key: String },
}

/// A typed protocol operation, executable on a [`Timeline`].
#[derive(Debug, Clone)]
pub enum Op {
    /// Upload a payload to an object store.
    Put { store: StoreSel, stage: Stage, key: String, payload: Slab },
    /// Download a payload (blocks on visibility).
    Get { store: StoreSel, stage: Stage, key: String },
    /// Pipelined bulk download over one connection.
    GetMany { store: StoreSel, stage: Stage, keys: Vec<String> },
    /// Publish a message to a queue topic (no stage charge; publishes are
    /// fire-and-forget on the worker's clock).
    Notify { topic: String, body: String },
    /// Block until `count` messages are visible on a topic.
    Poll { topic: String, count: usize },
    /// Network transfer in or out of a Redis instance.
    RedisOp { sel: RedisSel, stage: Stage, verb: RedisVerb },
    /// Align every worker clock to the cluster maximum.
    Barrier,
}

/// Result of executing one [`Op`].
#[derive(Debug, Clone)]
pub enum OpOut {
    /// Completion time (ops that return no payload).
    At(VTime),
    /// A downloaded payload.
    Payload(Slab),
    /// Bulk-downloaded payloads.
    Payloads(Vec<Slab>),
}

impl OpOut {
    pub fn at(&self) -> Option<VTime> {
        match self {
            OpOut::At(t) => Some(*t),
            _ => None,
        }
    }

    pub fn into_payload(self) -> Option<Slab> {
        match self {
            OpOut::Payload(s) => Some(s),
            _ => None,
        }
    }
}

/// Pick the `quorum` earliest-visible contributions.
///
/// Ties (identical visibility times — common in virtual mode, where
/// homogeneous workers finish simultaneously) are broken by index *rotated
/// by `rot`*, so repeated rounds spread the skipped slots across workers
/// instead of starving a fixed suffix. Returns the chosen indices in
/// visibility order — the order an async gather fetches them.
///
/// Implementation: the quorum wait is resolved on a [`EventQueue`] of
/// `(visibility, worker)` events. Candidates are pushed in rotated-index
/// order, so the queue's FIFO tie-break *is* the rotated tie-break, and
/// popping `quorum` events yields exactly the prefix the previous
/// full-sort by `(vis[i], (i + n - r) % n)` produced (pinned bit-for-bit
/// against that reference in the tests below) — without sorting the
/// `n - quorum` contributions the gather is going to skip anyway.
pub fn quorum_subset(vis: &[VTime], quorum: usize, rot: usize) -> Vec<usize> {
    let n = vis.len();
    if n == 0 {
        return Vec::new();
    }
    let r = rot % n;
    let take = quorum.min(n);
    let mut events: EventQueue<usize> = EventQueue::with_capacity(n);
    for j in 0..n {
        let i = (j + r) % n; // push order == rotated index order
        events.push(vis[i], i);
    }
    let mut idx = Vec::with_capacity(take);
    while idx.len() < take {
        let (_, i) = events.pop().expect("take <= n events queued");
        idx.push(i);
    }
    idx
}

/// Async-gather selection over uploaded store keys: indices of the
/// earliest-visible quorum among `keys`, where the quorum target counts
/// `held` contributions the gatherer already has locally (ScatterReduce's
/// kept chunk). Every key must already be uploaded. The BSP arms do not
/// use this — they fetch everything in index order.
pub fn store_quorum(
    env: &ClusterEnv,
    store: StoreSel,
    keys: &[String],
    mode: SyncMode,
    rot: usize,
    held: usize,
) -> Vec<usize> {
    let s = match store {
        StoreSel::Shared => &env.store,
        StoreSel::Gpu => &env.gpu_store,
    };
    let vis: Vec<VTime> =
        keys.iter().map(|k| s.visible_at(k).expect("key not uploaded")).collect();
    let need = mode.quorum(keys.len() + held).saturating_sub(held);
    quorum_subset(&vis, need, rot)
}

/// A per-worker handle on the cluster: executes protocol ops at the
/// worker's current virtual time and owns all the resulting bookkeeping.
pub struct Timeline<'e> {
    env: &'e mut ClusterEnv,
    w: usize,
}

impl ClusterEnv {
    /// Borrow worker `w`'s timeline handle.
    pub fn timeline(&mut self, w: usize) -> Timeline<'_> {
        Timeline { env: self, w }
    }
}

impl Timeline<'_> {
    pub fn worker(&self) -> usize {
        self.w
    }

    /// The worker's current virtual time.
    pub fn now(&self) -> VTime {
        self.env.workers[self.w].clock
    }

    /// Advance the clock by `secs`, charging the span to `stage`.
    pub fn advance(&mut self, stage: Stage, secs: f64) {
        let t0 = self.env.workers[self.w].clock;
        self.env.workers[self.w].clock += secs;
        self.env.stages.add(stage, secs);
        if self.env.trace.enabled() {
            let t1 = self.env.workers[self.w].clock;
            self.env.trace.span(self.w, t0, t1, EventKind::Advance, 0, 0.0, None);
        }
    }

    /// Fault hooks at a synchronization boundary: fire a planned sync-phase
    /// crash (the worker restarts; its clock absorbs the downtime), then
    /// report whether the worker's pending update is dropped in transit.
    pub fn enter_sync(&mut self) -> bool {
        self.env.sync_crash(self.w);
        self.env.update_dropped(self.w)
    }

    /// Upload to an object store; completion time becomes the new clock.
    pub fn put(&mut self, store: StoreSel, stage: Stage, key: &str, payload: Slab) -> VTime {
        self.env.partition_gate(self.w);
        let env = &mut *self.env;
        let t0 = env.workers[self.w].clock;
        let traced = env.trace.enabled();
        let (bytes, cost0) =
            if traced { (payload.nbytes(), env.ledger.total_full()) } else { (0, 0.0) };
        let s = match store {
            StoreSel::Shared => &mut env.store,
            StoreSel::Gpu => &mut env.gpu_store,
        };
        let done = s.put(t0, key, payload, &mut env.ledger, &mut env.comm);
        env.stages.add(stage, done - t0);
        env.workers[self.w].clock = done;
        if traced {
            let cost = env.ledger.total_full() - cost0;
            let idx = env.trace.span(self.w, t0, done, EventKind::Put, bytes, cost, None);
            env.trace.note_write(trace_store_key(store, key), idx);
        }
        done
    }

    /// Download from an object store (blocks on visibility).
    pub fn get(&mut self, store: StoreSel, stage: Stage, key: &str) -> Result<Slab> {
        self.env.partition_gate(self.w);
        let env = &mut *self.env;
        let t0 = env.workers[self.w].clock;
        let traced = env.trace.enabled();
        let cost0 = if traced { env.ledger.total_full() } else { 0.0 };
        let s = match store {
            StoreSel::Shared => &mut env.store,
            StoreSel::Gpu => &mut env.gpu_store,
        };
        let (done, slab) = s.get(t0, key, &mut env.ledger, &mut env.comm)?;
        env.stages.add(stage, done - t0);
        env.workers[self.w].clock = done;
        if traced {
            let cost = env.ledger.total_full() - cost0;
            let dep = env.trace.writer_of(&trace_store_key(store, key));
            env.trace.span(self.w, t0, done, EventKind::Get, slab.nbytes(), cost, dep);
        }
        Ok(slab)
    }

    /// Pipelined bulk download over one connection (the AllReduce master's
    /// reduce fetch).
    pub fn get_many(
        &mut self,
        store: StoreSel,
        stage: Stage,
        keys: &[String],
    ) -> Result<Vec<Slab>> {
        self.env.partition_gate(self.w);
        let env = &mut *self.env;
        let t0 = env.workers[self.w].clock;
        let traced = env.trace.enabled();
        let cost0 = if traced { env.ledger.total_full() } else { 0.0 };
        let s = match store {
            StoreSel::Shared => &mut env.store,
            StoreSel::Gpu => &mut env.gpu_store,
        };
        let (done, slabs) = s.get_many(t0, keys, &mut env.ledger, &mut env.comm)?;
        env.stages.add(stage, done - t0);
        env.workers[self.w].clock = done;
        if traced {
            let cost = env.ledger.total_full() - cost0;
            let bytes = slabs.iter().map(Slab::nbytes).sum();
            // The edge that gated the batch is the last-finishing writer.
            let dep = env.trace.binding_writer(keys.iter().map(|k| trace_store_key(store, k)));
            env.trace.span(self.w, t0, done, EventKind::GetMany, bytes, cost, dep);
        }
        Ok(slabs)
    }

    /// Transfer a payload into a Redis instance.
    pub fn redis_set(&mut self, sel: RedisSel, stage: Stage, key: &str, payload: Slab) -> VTime {
        self.env.partition_gate(self.w);
        let env = &mut *self.env;
        let t0 = env.workers[self.w].clock;
        let traced = env.trace.enabled();
        let bytes = if traced { payload.nbytes() } else { 0 };
        let done = match sel {
            RedisSel::Own => env.worker_redis[self.w].set(t0, key, payload, &mut env.comm),
            RedisSel::Peer(j) => env.worker_redis[j].set(t0, key, payload, &mut env.comm),
            RedisSel::Shared => {
                // A write rerouted around a down primary is a failover the
                // recovery ledger should see (delta over the whole op).
                let fo0 = env.shared_redis.total_failovers();
                let done = env.shared_redis.set(t0, key, payload, &mut env.comm);
                env.recovery.shard_failovers += env.shared_redis.total_failovers() - fo0;
                done
            }
        };
        env.stages.add(stage, done - t0);
        env.workers[self.w].clock = done;
        if traced {
            // Redis transfers bill via instance hours, not per request: no
            // ledger delta to sample here.
            let idx = env.trace.span(self.w, t0, done, EventKind::RedisSet, bytes, 0.0, None);
            env.trace.note_write(trace_redis_key(sel, self.w, &env.shared_redis, key), idx);
        }
        done
    }

    /// Transfer a payload out of a Redis instance (blocks on visibility).
    pub fn redis_get(&mut self, sel: RedisSel, stage: Stage, key: &str) -> Result<Slab> {
        self.env.partition_gate(self.w);
        let env = &mut *self.env;
        let t0 = env.workers[self.w].clock;
        let (done, slab) = match sel {
            RedisSel::Own => env.worker_redis[self.w].get(t0, key, &mut env.comm)?,
            RedisSel::Peer(j) => env.worker_redis[j].get(t0, key, &mut env.comm)?,
            RedisSel::Shared => {
                // Reads served by a replica while the primary restarts are
                // failovers (delta over the whole op).
                let fo0 = env.shared_redis.total_failovers();
                let r = env.shared_redis.get(t0, key, &mut env.comm)?;
                env.recovery.shard_failovers += env.shared_redis.total_failovers() - fo0;
                r
            }
        };
        env.stages.add(stage, done - t0);
        env.workers[self.w].clock = done;
        if env.trace.enabled() {
            let dep = env.trace.writer_of(&trace_redis_key(sel, self.w, &env.shared_redis, key));
            env.trace.span(self.w, t0, done, EventKind::RedisGet, slab.nbytes(), 0.0, dep);
        }
        Ok(slab)
    }

    /// Publish to a queue topic; the clock jumps to the message's
    /// visibility time. Publishes are not charged to a stage (they are
    /// sub-millisecond next to the payload transfers around them).
    pub fn notify(&mut self, topic: &str, body: impl Into<String>) -> VTime {
        self.env.partition_gate(self.w);
        let env = &mut *self.env;
        let t0 = env.workers[self.w].clock;
        let traced = env.trace.enabled();
        let cost0 = if traced { env.ledger.total_full() } else { 0.0 };
        let body = body.into();
        let bytes = body.len() as u64;
        let t = env.queues.publish(t0, topic, body, &mut env.ledger, &mut env.comm);
        env.workers[self.w].clock = t;
        if traced {
            let cost = env.ledger.total_full() - cost0;
            let idx = env.trace.span(self.w, t0, t, EventKind::Notify, bytes, cost, None);
            env.trace.note_notify(topic, idx);
        }
        t
    }

    /// Block until `count` messages are visible on `topic`; the wait is
    /// charged as synchronization time.
    pub fn poll(&mut self, topic: &str, count: usize) -> Result<VTime> {
        self.env.partition_gate(self.w);
        let env = &mut *self.env;
        let t0 = env.workers[self.w].clock;
        let traced = env.trace.enabled();
        let cost0 = if traced { env.ledger.total_full() } else { 0.0 };
        let t = env.queues.wait_for(t0, topic, count, &mut env.ledger, &mut env.comm)?;
        env.stages.add(Stage::Synchronize, t - t0);
        env.workers[self.w].clock = t;
        if traced {
            let cost = env.ledger.total_full() - cost0;
            // The wait was gated on the count-th publish to the topic.
            let dep = env.trace.notify_dep(topic, count);
            env.trace.span(self.w, t0, t, EventKind::Poll, 0, cost, dep);
        }
        Ok(t)
    }

    /// Execute a typed [`Op`].
    pub fn exec(&mut self, op: Op) -> Result<OpOut> {
        Ok(match op {
            Op::Put { store, stage, key, payload } => {
                OpOut::At(self.put(store, stage, &key, payload))
            }
            Op::Get { store, stage, key } => OpOut::Payload(self.get(store, stage, &key)?),
            Op::GetMany { store, stage, keys } => {
                OpOut::Payloads(self.get_many(store, stage, &keys)?)
            }
            Op::Notify { topic, body } => OpOut::At(self.notify(&topic, body)),
            Op::Poll { topic, count } => OpOut::At(self.poll(&topic, count)?),
            Op::RedisOp { sel, stage, verb } => match verb {
                RedisVerb::Set { key, payload } => {
                    OpOut::At(self.redis_set(sel, stage, &key, payload))
                }
                RedisVerb::Get { key } => OpOut::Payload(self.redis_get(sel, stage, &key)?),
            },
            Op::Barrier => {
                let traced = self.env.trace.enabled();
                let (t0, dep) = if traced {
                    // The barrier is bound by the slowest worker: its last
                    // event is the happens-before edge everyone waits on.
                    let slowest = (0..self.env.workers.len())
                        .max_by_key(|&i| (self.env.workers[i].clock, i))
                        .unwrap_or(0);
                    (self.now(), self.env.trace.last_event_of(slowest))
                } else {
                    (VTime::ZERO, None)
                };
                let t = self.env.barrier();
                if traced {
                    self.env.trace.span(self.w, t0, t, EventKind::Barrier, 0, 0.0, dep);
                }
                OpOut::At(t)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::FrameworkKind;
    use crate::coordinator::env::EnvConfig;
    use crate::metrics::CommKind;

    fn env(workers: usize) -> ClusterEnv {
        ClusterEnv::new(
            EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", workers).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn quorum_math() {
        assert_eq!(SyncMode::Bsp.quorum(8), 8);
        assert_eq!(SyncMode::Async { staleness: 2 }.quorum(8), 6);
        assert_eq!(SyncMode::Async { staleness: 10 }.quorum(8), 1);
        assert_eq!(SyncMode::Async { staleness: 0 }.quorum(8), 8);
        assert_eq!(SyncMode::Async { staleness: 3 }.quorum(0), 0);
    }

    #[test]
    fn parse_and_label_roundtrip() {
        assert_eq!(SyncMode::parse("bsp").unwrap(), SyncMode::Bsp);
        assert_eq!(SyncMode::parse("async").unwrap(), SyncMode::Async { staleness: 2 });
        assert_eq!(SyncMode::parse("async:5").unwrap(), SyncMode::Async { staleness: 5 });
        assert!(SyncMode::parse("bulk").is_err());
        assert_eq!(SyncMode::Async { staleness: 5 }.label(), "async:5");
        assert_eq!(SyncMode::Bsp.label(), "bsp");
    }

    #[test]
    fn quorum_subset_orders_by_visibility_then_rotated_index() {
        let vis = vec![
            VTime::from_secs(3.0),
            VTime::from_secs(1.0),
            VTime::from_secs(2.0),
            VTime::from_secs(1.0),
        ];
        // rot 0: ties by plain index -> 1 before 3.
        assert_eq!(quorum_subset(&vis, 3, 0), vec![1, 3, 2]);
        // rot 3 reorders the tie: (i + n - 3) % n maps 3 -> 0, 1 -> 2.
        assert_eq!(quorum_subset(&vis, 3, 3), vec![3, 1, 2]);
        // quorum larger than n is clamped.
        assert_eq!(quorum_subset(&vis, 9, 0).len(), 4);
        assert!(quorum_subset(&[], 3, 0).is_empty());
    }

    #[test]
    fn quorum_subset_matches_the_sort_reference_bit_for_bit() {
        // The event-queue resolution must reproduce the old full-sort
        // selection exactly — same indices, same order — across sizes,
        // rotations and heavy visibility ties.
        let reference = |vis: &[VTime], quorum: usize, rot: usize| -> Vec<usize> {
            let n = vis.len();
            if n == 0 {
                return Vec::new();
            }
            let r = rot % n;
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by_key(|&i| (vis[i], (i + n - r) % n));
            idx.truncate(quorum.min(n));
            idx
        };
        let mut state: u64 = 0xDE5C_0123;
        for n in [1usize, 2, 3, 7, 16, 33] {
            for rot in 0..(2 * n) {
                let vis: Vec<VTime> = (0..n)
                    .map(|_| {
                        state = state
                            .wrapping_mul(6364136223846793005)
                            .wrapping_add(1442695040888963407);
                        VTime::from_secs((state >> 60) as f64) // 0..=15: many ties
                    })
                    .collect();
                for quorum in [1, n / 2, n.saturating_sub(1).max(1), n, n + 3] {
                    assert_eq!(
                        quorum_subset(&vis, quorum, rot),
                        reference(&vis, quorum, rot),
                        "n={n} rot={rot} quorum={quorum} vis={vis:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn store_quorum_selects_earliest_uploads_minus_held() {
        let mut e = env(3);
        let n = e.n_params;
        let keys: Vec<String> = (0..3).map(|w| format!("g{w}")).collect();
        // Stagger visibility: worker 2 uploads much later.
        e.timeline(0).put(StoreSel::Shared, Stage::Synchronize, "g0", Slab::virtual_of(n));
        e.timeline(1).put(StoreSel::Shared, Stage::Synchronize, "g1", Slab::virtual_of(n));
        e.timeline(2).advance(Stage::Synchronize, 100.0);
        e.timeline(2).put(StoreSel::Shared, Stage::Synchronize, "g2", Slab::virtual_of(n));

        let mode = SyncMode::Async { staleness: 1 };
        // quorum(3) = 2: the two early uploads, late one skipped.
        let sel = store_quorum(&e, StoreSel::Shared, &keys, mode, 0, 0);
        assert_eq!(sel.len(), 2);
        assert!(!sel.contains(&2), "the late upload must be skipped: {sel:?}");
        // One contribution already held: quorum(3+1)=3, minus held -> 2.
        let sel = store_quorum(&e, StoreSel::Shared, &keys, mode, 0, 1);
        assert_eq!(sel.len(), 2);
        // BSP-equivalent quorum via staleness 0 takes everything.
        let zero = SyncMode::Async { staleness: 0 };
        assert_eq!(store_quorum(&e, StoreSel::Shared, &keys, zero, 0, 0).len(), 3);
    }

    #[test]
    fn timeline_put_advances_clock_and_charges_stage() {
        let mut e = env(2);
        let n = e.n_params;
        let done = e.timeline(0).put(
            StoreSel::Shared,
            Stage::Synchronize,
            "k",
            Slab::virtual_of(n),
        );
        assert_eq!(e.workers[0].clock, done);
        assert!(done.secs() > 0.0);
        assert_eq!(e.workers[1].clock, VTime::ZERO, "peer untouched");
        assert!(e.stages.get(Stage::Synchronize) > 0.0);
        assert_eq!(e.comm.ops(CommKind::Put), 1);
        assert!(e.ledger.total_paper() > 0.0, "request fee charged");
    }

    #[test]
    fn timeline_get_blocks_on_visibility() {
        let mut e = env(2);
        let n = e.n_params;
        e.timeline(0).put(StoreSel::Shared, Stage::Synchronize, "k", Slab::virtual_of(n));
        let vis = e.store.visible_at("k").unwrap();
        let g = e.timeline(1).get(StoreSel::Shared, Stage::Synchronize, "k").unwrap();
        assert_eq!(g.len(), n);
        assert!(e.workers[1].clock > vis, "reader waits for the writer");
    }

    #[test]
    fn timeline_notify_poll_roundtrip() {
        let mut e = env(2);
        e.timeline(0).notify("t", "w0");
        e.timeline(1).notify("t", "w1");
        let t = e.timeline(0).poll("t", 2).unwrap();
        assert_eq!(e.workers[0].clock, t);
        assert!(e.stages.get(Stage::Synchronize) > 0.0);
    }

    #[test]
    fn exec_matches_direct_methods() {
        // The typed-op façade and the direct methods must produce identical
        // timelines for the same op sequence.
        let mut a = env(2);
        let mut b = env(2);
        let n = a.n_params;

        a.timeline(0).put(StoreSel::Shared, Stage::Synchronize, "k", Slab::virtual_of(n));
        let ga = a.timeline(1).get(StoreSel::Shared, Stage::Synchronize, "k").unwrap();

        let out = b
            .timeline(0)
            .exec(Op::Put {
                store: StoreSel::Shared,
                stage: Stage::Synchronize,
                key: "k".into(),
                payload: Slab::virtual_of(n),
            })
            .unwrap();
        assert!(out.at().is_some());
        let gb = b
            .timeline(1)
            .exec(Op::Get {
                store: StoreSel::Shared,
                stage: Stage::Synchronize,
                key: "k".into(),
            })
            .unwrap()
            .into_payload()
            .unwrap();

        assert_eq!(ga.len(), gb.len());
        for w in 0..2 {
            assert_eq!(
                a.workers[w].clock.secs().to_bits(),
                b.workers[w].clock.secs().to_bits(),
                "worker {w} clock must be bit-identical across the two forms"
            );
        }
    }

    #[test]
    fn exec_barrier_aligns_clocks() {
        let mut e = env(3);
        e.timeline(1).advance(Stage::Synchronize, 5.0);
        let out = e.timeline(0).exec(Op::Barrier).unwrap();
        assert_eq!(out.at().unwrap().secs(), 5.0);
        assert!(e.workers.iter().all(|w| w.clock.secs() == 5.0));
    }

    #[test]
    fn timeline_redis_ops_move_payloads() {
        let mut e = env(2);
        e.timeline(0).redis_set(
            RedisSel::Own,
            Stage::Synchronize,
            "g",
            Slab::from_vec(vec![1.0, 2.0]),
        );
        let g = e.timeline(1).redis_get(RedisSel::Peer(0), Stage::Synchronize, "g").unwrap();
        assert_eq!(g.as_slice().unwrap(), &[1.0, 2.0]);
        assert!(e.workers[1].clock > VTime::ZERO);
    }

    #[test]
    fn traced_timeline_emits_events_with_dep_edges() {
        use crate::trace::TraceConfig;
        let cfg = EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 2)
            .unwrap()
            .with_trace(TraceConfig::on());
        let mut e = ClusterEnv::new(cfg).unwrap();
        let n = e.n_params;
        e.timeline(0).put(StoreSel::Shared, Stage::Synchronize, "k", Slab::virtual_of(n));
        e.timeline(1).get(StoreSel::Shared, Stage::Synchronize, "k").unwrap();
        e.timeline(0).notify("t", "go");
        e.timeline(1).poll("t", 1).unwrap();

        let evs = e.trace.snapshot();
        let kinds: Vec<EventKind> = evs.iter().map(|ev| ev.kind).collect();
        assert_eq!(
            kinds,
            vec![EventKind::Put, EventKind::Get, EventKind::Notify, EventKind::Poll]
        );
        assert_eq!(evs[1].dep, Some(0), "get depends on the put that wrote the key");
        assert_eq!(evs[3].dep, Some(2), "poll depends on the notify it waited for");
        assert_eq!(evs[0].bytes, n as u64 * 4);
        assert!(evs[0].cost > 0.0, "put carries its request fee");
        assert!(evs[1].t0 >= evs[0].t0 && evs[1].t1 >= evs[0].t1);

        // Untraced twin runs the same ops to bit-identical clocks.
        let mut f = ClusterEnv::new(
            EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 2).unwrap(),
        )
        .unwrap();
        f.timeline(0).put(StoreSel::Shared, Stage::Synchronize, "k", Slab::virtual_of(n));
        f.timeline(1).get(StoreSel::Shared, Stage::Synchronize, "k").unwrap();
        f.timeline(0).notify("t", "go");
        f.timeline(1).poll("t", 1).unwrap();
        assert!(f.trace.is_empty());
        for w in 0..2 {
            assert_eq!(
                e.workers[w].clock.secs().to_bits(),
                f.workers[w].clock.secs().to_bits(),
                "worker {w}: traced and untraced clocks must match"
            );
        }
    }

    #[test]
    fn partitioned_worker_ops_defer_to_heal() {
        use crate::faults::FaultPlan;
        let cfg = EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 2)
            .unwrap()
            .with_faults(FaultPlan::none().partition(&[0], 0.0, 40.0));
        let mut e = ClusterEnv::new(cfg).unwrap();
        let n = e.n_params;
        let done =
            e.timeline(0).put(StoreSel::Shared, Stage::Synchronize, "k", Slab::virtual_of(n));
        assert!(done.secs() >= 40.0, "op deferred to heal, got {}", done.secs());
        assert!((e.recovery.partition_secs - 40.0).abs() < 1e-9);
        // Peer visibility follows the deferred write: the reachability mask
        // is what the quorum/visibility paths observe.
        assert!(e.store.visible_at("k").unwrap().secs() > 40.0);
        // After the heal the worker is reachable again: no further gating.
        let healed = e.workers[0].clock;
        e.timeline(0).notify("t", "go");
        assert!(e.workers[0].clock - healed < 1.0);
        assert!((e.recovery.partition_secs - 40.0).abs() < 1e-9);
        // The unpartitioned peer is never gated.
        e.timeline(1).notify("t", "go2");
        assert!(e.workers[1].clock.secs() < 1.0);
    }

    #[test]
    fn enter_sync_consults_fault_hooks() {
        use crate::faults::FaultPlan;
        let cfg = EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 2)
            .unwrap()
            .with_faults(FaultPlan::none().drop_updates(1, 1, 0, Some(1)));
        let mut e = ClusterEnv::new(cfg).unwrap();
        e.begin_epoch();
        e.faults.note_compute(0);
        e.faults.note_compute(1);
        assert!(!e.timeline(0).enter_sync());
        assert!(e.timeline(1).enter_sync(), "planned drop must surface");
    }
}
