//! Consistent-hash ring with virtual nodes — deterministic key→shard routing.
//!
//! Each shard owns `vnodes` points on a 64-bit ring; a key is routed to the
//! shard owning the first point clockwise of the key's hash. Walking further
//! clockwise yields the replica preference list (first `r` *distinct*
//! shards). Virtual nodes smooth the load split, and — the property the
//! cluster tier stands on — adding or removing one shard only remaps the
//! keys whose arcs that shard's points cover, leaving every other key on
//! its old shard.
//!
//! Hashing is FNV-1a over the raw bytes: no `RandomState`, no per-process
//! seeds, so a (key, shard set, vnodes) triple routes identically on every
//! run and every host — the determinism tests compare routes bit-for-bit.

/// FNV-1a 64-bit. Stable across runs/platforms (unlike `std`'s hashers).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A consistent-hash ring over shard ids.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// Ring points sorted by hash: (point hash, shard id).
    points: Vec<(u64, usize)>,
    /// Shard ids currently on the ring (sorted, distinct).
    shards: Vec<usize>,
    vnodes: usize,
}

impl HashRing {
    /// Ring over shards `0..shards`, each holding `vnodes` points.
    pub fn new(shards: usize, vnodes: usize) -> HashRing {
        assert!(shards > 0, "ring needs at least one shard");
        assert!(vnodes > 0, "ring needs at least one vnode per shard");
        let mut ring = HashRing { points: Vec::new(), shards: Vec::new(), vnodes };
        for id in 0..shards {
            ring.add_shard(id);
        }
        ring
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[usize] {
        &self.shards
    }

    /// Hash of one virtual node (`shard`, `vnode` index).
    fn point_hash(shard: usize, vnode: usize) -> u64 {
        let mut buf = [0u8; 16];
        buf[..8].copy_from_slice(&(shard as u64).to_le_bytes());
        buf[8..].copy_from_slice(&(vnode as u64).to_le_bytes());
        fnv1a(&buf)
    }

    /// Add `id`'s virtual nodes to the ring (no-op if already present).
    pub fn add_shard(&mut self, id: usize) {
        if self.shards.contains(&id) {
            return;
        }
        self.shards.push(id);
        self.shards.sort_unstable();
        for v in 0..self.vnodes {
            // Hash collisions between distinct points are theoretically
            // possible; break the tie by shard id so the ring stays a
            // deterministic total order.
            self.points.push((Self::point_hash(id, v), id));
        }
        self.points.sort_unstable();
    }

    /// Remove `id`'s virtual nodes (keys on its arcs move to successors).
    pub fn remove_shard(&mut self, id: usize) {
        self.shards.retain(|&s| s != id);
        self.points.retain(|&(_, s)| s != id);
    }

    /// First ring-point index at or clockwise of `key`'s hash (wrapping).
    fn start_index(&self, key: &str) -> usize {
        let h = fnv1a(key.as_bytes());
        let i = self.points.partition_point(|&(ph, _)| ph < h);
        if i == self.points.len() {
            0
        } else {
            i
        }
    }

    /// The shard owning `key`.
    pub fn primary(&self, key: &str) -> usize {
        self.points[self.start_index(key)].1
    }

    /// Replica preference list: the first `r` distinct shards clockwise of
    /// `key` (primary first). Clamped to the number of shards on the ring.
    pub fn shards_for(&self, key: &str, r: usize) -> Vec<usize> {
        let want = r.clamp(1, self.shards.len());
        let start = self.start_index(key);
        let mut out = Vec::with_capacity(want);
        for k in 0..self.points.len() {
            let shard = self.points[(start + k) % self.points.len()].1;
            if !out.contains(&shard) {
                out.push(shard);
                if out.len() == want {
                    break;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("u/e{}/r{}/w{}", i % 7, i % 24, i)).collect()
    }

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn routing_is_deterministic_and_in_range() {
        let ring = HashRing::new(4, 64);
        for k in keys(200) {
            let p = ring.primary(&k);
            assert!(p < 4);
            assert_eq!(p, HashRing::new(4, 64).primary(&k), "route must be stable");
            assert_eq!(ring.shards_for(&k, 2)[0], p, "preference list starts at primary");
        }
    }

    #[test]
    fn replica_lists_are_distinct_and_clamped() {
        let ring = HashRing::new(3, 32);
        for k in keys(50) {
            let r = ring.shards_for(&k, 2);
            assert_eq!(r.len(), 2);
            assert_ne!(r[0], r[1]);
            // Asking for more replicas than shards clamps to all shards.
            let all = ring.shards_for(&k, 10);
            assert_eq!(all.len(), 3);
        }
        // A 1-shard ring routes everything to shard 0.
        let one = HashRing::new(1, 64);
        for k in keys(20) {
            assert_eq!(one.shards_for(&k, 1), vec![0]);
        }
    }

    #[test]
    fn vnodes_spread_load() {
        // With 64 vnodes the biggest shard should not dwarf the smallest
        // (a single-point ring routinely gives one shard 60%+).
        let ring = HashRing::new(4, 64);
        let mut counts = [0usize; 4];
        for k in keys(4000) {
            counts[ring.primary(&k)] += 1;
        }
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(*min > 0);
        assert!(*max < 3 * *min, "vnode split too lopsided: {counts:?}");
    }

    #[test]
    fn adding_a_shard_only_steals_keys_for_itself() {
        // The consistent-hashing contract: going 4 -> 5 shards, a key either
        // keeps its old primary or moves to the new shard — never between
        // old shards.
        let before = HashRing::new(4, 64);
        let mut after = before.clone();
        after.add_shard(4);
        let mut moved = 0usize;
        let ks = keys(2000);
        for k in &ks {
            let (b, a) = (before.primary(k), after.primary(k));
            if a != b {
                assert_eq!(a, 4, "{k} moved {b} -> {a}, not to the new shard");
                moved += 1;
            }
        }
        // Roughly 1/5 of keys should move (band wide enough to be stable).
        assert!(moved > ks.len() / 10 && moved < ks.len() / 2, "moved {moved}");
    }

    #[test]
    fn removing_a_shard_only_moves_its_own_keys() {
        let before = HashRing::new(4, 64);
        let mut after = before.clone();
        after.remove_shard(2);
        assert_eq!(after.num_shards(), 3);
        for k in keys(2000) {
            let b = before.primary(&k);
            if b != 2 {
                assert_eq!(after.primary(&k), b, "{k} must stay put");
            } else {
                assert_ne!(after.primary(&k), 2);
            }
        }
    }

    #[test]
    fn add_is_idempotent_and_remove_roundtrips() {
        let mut ring = HashRing::new(3, 16);
        let routes: Vec<usize> = keys(100).iter().map(|k| ring.primary(k)).collect();
        ring.add_shard(1); // already present: no-op
        ring.remove_shard(1);
        ring.add_shard(1); // back: identical points, identical routes
        let again: Vec<usize> = keys(100).iter().map(|k| ring.primary(k)).collect();
        assert_eq!(routes, again);
    }
}
