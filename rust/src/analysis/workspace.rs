//! The audited file set: a deterministic, whitelist-driven repo model.
//!
//! A [`Workspace`] maps repo-relative paths (forward slashes, sorted) to
//! file contents. It can be built from disk — collecting exactly the files
//! the rules care about — or assembled in memory for fixture tests. The
//! collection is a whitelist, not a recursive crawl of the repo root, so
//! fixture mini-repos under `rust/tests/fixtures/` are never scanned when
//! auditing the real repo (integration tests are direct children of
//! `rust/tests/`, matching the cargo convention under `autotests=false`).
//!
//! Collected set:
//! - `Cargo.toml`
//! - `rust/src/**/*.rs` (recursive)
//! - `rust/tests/*.rs`, `benches/*.rs`, `examples/*.rs` (direct children)
//! - `docs/**/*.md`, `docs/**/*.json` (recursive)

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

/// Sorted map of repo-relative path -> contents.
#[derive(Debug, Clone, Default)]
pub struct Workspace {
    files: BTreeMap<String, String>,
}

impl Workspace {
    /// Empty workspace, for fixture assembly in tests.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add (or replace) one file.
    pub fn add(&mut self, path: &str, contents: impl Into<String>) -> &mut Self {
        self.files.insert(path.to_string(), contents.into());
        self
    }

    /// Build the audited file set from a repo checkout.
    pub fn from_disk(root: &Path) -> Result<Workspace> {
        let mut ws = Workspace::new();
        let cargo = root.join("Cargo.toml");
        if cargo.is_file() {
            ws.files.insert(
                "Cargo.toml".to_string(),
                fs::read_to_string(&cargo).with_context(|| format!("read {}", cargo.display()))?,
            );
        }
        collect(root, "rust/src", true, &["rs"], &mut ws.files)?;
        collect(root, "rust/tests", false, &["rs"], &mut ws.files)?;
        collect(root, "benches", false, &["rs"], &mut ws.files)?;
        collect(root, "examples", false, &["rs"], &mut ws.files)?;
        collect(root, "docs", true, &["md", "json"], &mut ws.files)?;
        Ok(ws)
    }

    /// All files, sorted by path.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &str)> {
        self.files.iter().map(|(p, c)| (p.as_str(), c.as_str()))
    }

    /// Contents of one file, if collected.
    pub fn get(&self, path: &str) -> Option<&str> {
        self.files.get(path).map(String::as_str)
    }

    /// `rust/src/**/*.rs`, sorted.
    pub fn rust_src(&self) -> impl Iterator<Item = (&str, &str)> {
        self.iter().filter(|(p, _)| p.starts_with("rust/src/") && p.ends_with(".rs"))
    }

    /// Direct `.rs` children of `dir` (e.g. `rust/tests`), sorted.
    pub fn direct_rs(&self, dir: &str) -> Vec<&str> {
        let prefix = format!("{dir}/");
        self.files
            .keys()
            .filter(|p| {
                p.starts_with(&prefix)
                    && p.ends_with(".rs")
                    && !p[prefix.len()..].contains('/')
            })
            .map(String::as_str)
            .collect()
    }

    /// `docs/**` files with the given extension, sorted.
    pub fn docs(&self, ext: &str) -> Vec<&str> {
        let suffix = format!(".{ext}");
        self.files
            .keys()
            .filter(|p| p.starts_with("docs/") && p.ends_with(&suffix))
            .map(String::as_str)
            .collect()
    }
}

/// Collect files under `root/rel` into `out`, keyed by forward-slash
/// relative path. Directory entries are visited in sorted order so the
/// result is reproducible across platforms and filesystems.
fn collect(
    root: &Path,
    rel: &str,
    recursive: bool,
    exts: &[&str],
    out: &mut BTreeMap<String, String>,
) -> Result<()> {
    let dir = root.join(rel);
    if !dir.is_dir() {
        return Ok(());
    }
    let mut entries: Vec<(String, bool)> = Vec::new();
    for entry in fs::read_dir(&dir).with_context(|| format!("read dir {}", dir.display()))? {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        let is_dir = entry.file_type()?.is_dir();
        entries.push((name, is_dir));
    }
    entries.sort();
    for (name, is_dir) in entries {
        let child_rel = format!("{rel}/{name}");
        if is_dir {
            if recursive {
                collect(root, &child_rel, true, exts, out)?;
            }
        } else if exts.iter().any(|e| name.ends_with(&format!(".{e}"))) {
            let path = root.join(&child_rel);
            let contents = fs::read_to_string(&path)
                .with_context(|| format!("read {}", path.display()))?;
            out.insert(child_rel, contents);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_memory_selectors() {
        let mut ws = Workspace::new();
        ws.add("Cargo.toml", "[package]\n");
        ws.add("rust/src/sim/vtime.rs", "fn a() {}\n");
        ws.add("rust/src/cloud/redis.rs", "fn b() {}\n");
        ws.add("rust/tests/integration.rs", "#[test]\nfn t() {}\n");
        ws.add("rust/tests/fixtures/audit/rust/src/sim/x.rs", "nested\n");
        ws.add("benches/micro.rs", "fn main() {}\n");
        ws.add("docs/REPORT.md", "# r\n");
        ws.add("docs/data/table2.json", "{}\n");

        let src: Vec<&str> = ws.rust_src().map(|(p, _)| p).collect();
        assert_eq!(src, vec!["rust/src/cloud/redis.rs", "rust/src/sim/vtime.rs"]);
        // Fixture mini-repos are not direct children of rust/tests.
        assert_eq!(ws.direct_rs("rust/tests"), vec!["rust/tests/integration.rs"]);
        assert_eq!(ws.direct_rs("benches"), vec!["benches/micro.rs"]);
        assert_eq!(ws.docs("md"), vec!["docs/REPORT.md"]);
        assert_eq!(ws.docs("json"), vec!["docs/data/table2.json"]);
    }

    #[test]
    fn from_disk_skips_fixture_trees() {
        // When run under `cargo test` the CWD is the package root.
        let ws = Workspace::from_disk(Path::new(".")).unwrap();
        if ws.get("Cargo.toml").is_none() {
            // Not a repo checkout (e.g. sandboxed harness); nothing to assert.
            return;
        }
        assert!(ws.get("rust/src/lib.rs").is_some());
        assert!(ws.iter().all(|(p, _)| !p.contains("fixtures/")));
    }
}
