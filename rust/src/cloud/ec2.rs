//! EC2 GPU fleet substrate — the paper's distributed baseline.
//!
//! The baseline trains on `n` g4dn.xlarge instances (one NVIDIA T4 each),
//! data-parallel, synchronizing per batch through S3 (§2 "GPU-Based
//! Baseline"). Instances bill by wall-clock hour regardless of utilization —
//! the over-provisioning the paper's serverless argument targets. Compute
//! durations come from the calibrated T4 per-sample model.

use crate::metrics::{CostKind, Ledger};
use crate::sim::VTime;

use super::calibration::ModelProfile;
use super::pricing;

/// A fleet of identical GPU instances.
#[derive(Debug)]
pub struct GpuFleet {
    pub instances: usize,
    /// Boot + CUDA/container init, seconds (paid once per experiment).
    pub provision_secs: f64,
}

impl GpuFleet {
    pub fn new(instances: usize) -> GpuFleet {
        assert!(instances > 0);
        GpuFleet { instances, provision_secs: 60.0 }
    }

    /// Fwd+bwd time for one batch of `batch` samples on one T4.
    pub fn batch_secs(&self, model: &ModelProfile, batch: usize) -> f64 {
        model.gpu_secs_per_sample * batch as f64
    }

    /// Bill the whole fleet for an experiment that ran `duration` seconds of
    /// virtual wall time (instances are on the whole time — that is the
    /// point the paper makes about always-on resources).
    pub fn bill(&self, duration: f64, ledger: &mut Ledger) {
        ledger.charge(CostKind::Ec2Gpu, pricing::gpu_cost(duration, self.instances));
    }

    /// Provisioning completes at `now + provision_secs` (excluded from the
    /// paper's per-epoch accounting, available for ablations).
    pub fn provision(&self, now: VTime) -> VTime {
        now + self.provision_secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::calibration::MOBILENET;

    #[test]
    fn batch_time_scales_with_batch() {
        let fleet = GpuFleet::new(4);
        let b512 = fleet.batch_secs(&MOBILENET, 512);
        let b256 = fleet.batch_secs(&MOBILENET, 256);
        assert!((b512 - 2.0 * b256).abs() < 1e-9);
        assert!(b512 > 2.0 && b512 < 4.0, "T4 MobileNet B512 ≈ 3 s, got {b512}");
    }

    #[test]
    fn billing_matches_paper_formula() {
        let fleet = GpuFleet::new(4);
        let mut ledger = Ledger::new();
        fleet.bill(92.0, &mut ledger);
        assert!((ledger.get(CostKind::Ec2Gpu) - 0.0538).abs() < 5e-4);
    }
}
