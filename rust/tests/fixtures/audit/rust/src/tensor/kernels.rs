//! Fixture: tensor:: owns the chunked-kernel contract, so f32 reductions
//! here are exempt from float-reduction.

pub fn ksum(xs: &[f32]) -> f32 {
    xs.iter().sum::<f32>()
}
