//! Artifact manifest: what aot.py built and where.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// An executed model config (grad/eval/init artifacts exist).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelEntry {
    pub name: String,
    pub arch: String,
    pub width: f64,
    pub n_params: usize,
    pub batch: usize,
    pub eval_batch: usize,
    /// artifact kind ("init"/"grad"/"eval") -> file name.
    pub artifacts: BTreeMap<String, String>,
}

/// A flat-slab size with elementwise artifacts (acc/sgd/avg_update).
#[derive(Debug, Clone, PartialEq)]
pub struct SlabEntry {
    pub name: String,
    pub n: usize,
    pub artifacts: BTreeMap<String, String>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub image_shape: Vec<usize>,
    pub num_classes: usize,
    pub models: BTreeMap<String, ModelEntry>,
    pub slabs: BTreeMap<String, SlabEntry>,
    /// Paper-reported full-model sizes (payload-only experiments).
    pub paper_sizes: BTreeMap<String, usize>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let json = Json::parse(&text).context("parsing manifest.json")?;

        let mut models = BTreeMap::new();
        for (name, entry) in json.get("models")?.as_obj()? {
            let mut artifacts = BTreeMap::new();
            for (kind, file) in entry.get("artifacts")?.as_obj()? {
                artifacts.insert(kind.clone(), file.as_str()?.to_string());
            }
            models.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    arch: entry.get("arch")?.as_str()?.to_string(),
                    width: entry.get("width")?.as_f64()?,
                    n_params: entry.get("n_params")?.as_usize()?,
                    batch: entry.get("batch")?.as_usize()?,
                    eval_batch: entry.get("eval_batch")?.as_usize()?,
                    artifacts,
                },
            );
        }

        let mut slabs = BTreeMap::new();
        for (name, entry) in json.get("slabs")?.as_obj()? {
            let mut artifacts = BTreeMap::new();
            for (kind, file) in entry.get("artifacts")?.as_obj()? {
                artifacts.insert(kind.clone(), file.as_str()?.to_string());
            }
            slabs.insert(
                name.clone(),
                SlabEntry { name: name.clone(), n: entry.get("n")?.as_usize()?, artifacts },
            );
        }

        let mut paper_sizes = BTreeMap::new();
        for (name, n) in json.get("paper_sizes")?.as_obj()? {
            paper_sizes.insert(name.clone(), n.as_usize()?);
        }

        Ok(Manifest {
            dir,
            image_shape: json
                .get("image_shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?,
            num_classes: json.get("num_classes")?.as_usize()?,
            models,
            slabs,
            paper_sizes,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model config {name:?} not in manifest"))
    }

    pub fn slab(&self, name: &str) -> Result<&SlabEntry> {
        self.slabs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("slab {name:?} not in manifest"))
    }

    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Locate the repo's artifacts directory from the test cwd.
    pub fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert!(m.models.contains_key("mobilenet_s"));
        assert!(m.slabs.contains_key("resnet18_full"));
        assert_eq!(m.num_classes, 10);
        assert_eq!(m.image_shape, vec![32, 32, 3]);
        let entry = m.model("mobilenet_s").unwrap();
        assert!(entry.artifacts.contains_key("grad"));
        // every referenced file exists
        for model in m.models.values() {
            for f in model.artifacts.values() {
                assert!(m.artifact_path(f).exists(), "{f} missing");
            }
        }
        // slab sizes cover the paper models
        assert_eq!(m.slabs["mobilenet_full"].n, 4_200_000);
        assert_eq!(m.paper_sizes["resnet50"], 25_600_000);
    }

    #[test]
    fn missing_dir_is_helpful() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
