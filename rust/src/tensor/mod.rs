//! Flat tensor slabs — the wire format of every framework.
//!
//! All five architectures shuttle gradients/parameters as opaque `f32` slabs
//! (the real systems move pickled tensors through Redis/S3; we move
//! [`Slab`]s). A slab is either *real* (backed by memory, used by the
//! end-to-end training runs) or *virtual* (size-only, used by the
//! paper-scale cost/communication experiments where a 25.6M-param gradient
//! would be 100 MB of irrelevant bytes). Every operation preserves length
//! and "virtualness" so the two modes traverse identical protocol code.

pub mod chunk;
pub mod robust;
pub mod significance;
pub mod slab;

pub use chunk::ChunkPlan;
pub use robust::AggregationRule;
pub use significance::SignificanceFilter;
pub use slab::Slab;

use anyhow::Result;

/// Elementwise slab math engine — the compute behind RedisAI's in-database
/// ops. Two implementations exist: [`RustMath`] (portable loops, used by the
/// naive baselines and virtual-slab simulations) and
/// `runtime::PjrtMath` (executes the AOT-compiled Pallas kernels — the
/// faithful RedisAI analog used on the end-to-end path).
pub trait SlabMath: Send + Sync {
    /// `acc + w * g`.
    fn acc(&self, acc: &Slab, g: &Slab, w: f32) -> Result<Slab>;
    /// `theta - lr * (inv_k * gsum)` — the fused average+SGD op.
    fn avg_update(&self, theta: &Slab, gsum: &Slab, inv_k: f32, lr: f32) -> Result<Slab>;
    /// `theta - lr * g`.
    fn sgd(&self, theta: &Slab, g: &Slab, lr: f32) -> Result<Slab>;
    /// `w * src` — a single-source, two-pass op (read src, write out).
    fn scale(&self, src: &Slab, w: f32) -> Result<Slab>;
}

/// Pure-Rust [`SlabMath`] (virtual slabs pass through size-only).
#[derive(Debug, Default, Clone, Copy)]
pub struct RustMath;

impl SlabMath for RustMath {
    fn acc(&self, acc: &Slab, g: &Slab, w: f32) -> Result<Slab> {
        let mut out = acc.clone();
        out.axpy(g, w)?;
        Ok(out)
    }

    fn avg_update(&self, theta: &Slab, gsum: &Slab, inv_k: f32, lr: f32) -> Result<Slab> {
        let mut out = theta.clone();
        out.axpy(gsum, -lr * inv_k)?;
        Ok(out)
    }

    fn sgd(&self, theta: &Slab, g: &Slab, lr: f32) -> Result<Slab> {
        let mut out = theta.clone();
        out.axpy(g, -lr)?;
        Ok(out)
    }

    fn scale(&self, src: &Slab, w: f32) -> Result<Slab> {
        let mut out = src.clone();
        out.scale(w);
        Ok(out)
    }
}

#[cfg(test)]
mod math_tests {
    use super::*;

    #[test]
    fn rust_math_matches_manual() {
        let m = RustMath;
        let acc = m.acc(&Slab::from_vec(vec![1.0]), &Slab::from_vec(vec![2.0]), 0.5).unwrap();
        assert_eq!(acc.as_slice().unwrap(), &[2.0]);
        let upd = m
            .avg_update(&Slab::from_vec(vec![1.0]), &Slab::from_vec(vec![4.0]), 0.25, 0.1)
            .unwrap();
        assert!((upd.as_slice().unwrap()[0] - 0.9).abs() < 1e-6);
        let sgd = m.sgd(&Slab::from_vec(vec![1.0]), &Slab::from_vec(vec![1.0]), 0.3).unwrap();
        assert!((sgd.as_slice().unwrap()[0] - 0.7).abs() < 1e-6);
        let scaled = m.scale(&Slab::from_vec(vec![2.0, -4.0]), 0.5).unwrap();
        assert_eq!(scaled.as_slice().unwrap(), &[1.0, -2.0]);
    }

    #[test]
    fn scale_equals_acc_into_zeros() {
        // The old scale_in_db detour: acc(zeros, src, w) == w * src.
        let m = RustMath;
        let src = Slab::from_vec(vec![1.5, -3.0, 0.25]);
        let via_acc = m.acc(&src.zeros_like(), &src, 0.5).unwrap();
        let direct = m.scale(&src, 0.5).unwrap();
        assert_eq!(via_acc.as_slice().unwrap(), direct.as_slice().unwrap());
    }

    #[test]
    fn rust_math_passes_virtual_through() {
        let m = RustMath;
        let out = m.acc(&Slab::virtual_of(8), &Slab::virtual_of(8), 1.0).unwrap();
        assert_eq!(out.len(), 8);
        assert!(!out.is_real());
    }
}
