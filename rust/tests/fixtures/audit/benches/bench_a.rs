fn main() {}
