//! LambdaML AllReduce: master-aggregated synchronization (§2, Table 1).
//!
//! Per batch round every worker pushes its gradient to shared storage; a
//! designated master (worker 0) fetches all of them, aggregates, and pushes
//! the result; everyone fetches the aggregate and updates locally. Simple,
//! but the master serializes `W` gradient transfers per round — the
//! scalability bottleneck the paper measures in Fig. 2 (21.88 s at 16
//! workers on ResNet-50).
//!
//! Under [`SyncMode::Async`] the master reduces over the earliest-visible
//! quorum instead of waiting for every upload, so a straggler or a
//! restarting worker no longer stalls the round — it only loses its
//! contribution for that round (counted in `CommStats::stale_skips`).

use crate::cloud::FrameworkKind;
use crate::metrics::Stage;
use crate::tensor::Slab;
use crate::Result;

use super::env::{ClusterEnv, Device};
use super::protocol::{store_quorum, StoreSel, SyncMode};
use super::{EpochStats, Strategy};

#[derive(Debug, Default)]
pub struct AllReduce {
    pub master: usize,
}

impl AllReduce {
    pub fn new() -> AllReduce {
        AllReduce { master: 0 }
    }

    /// One synchronization round after gradients are computed: workers put,
    /// master aggregates, workers fetch + update. Factored out so Fig. 2 can
    /// measure a single round's communication time. `round` seeds the async
    /// quorum's tie-rotation only; BSP ignores it.
    ///
    /// Fault semantics: a sync-phase crash delays the crashed worker's
    /// upload until its restart — and because the master waits for every
    /// gradient before it can aggregate, the *whole round* stalls behind
    /// the restart (the master-topology weakness the SPIRT paper targets).
    /// A master crash delays the fetch+aggregate+re-publish chain itself.
    /// Dropped updates are simply absent from the aggregate. In async mode
    /// a late upload falls out of the quorum instead of stalling the round.
    pub fn sync_round(
        &self,
        env: &mut ClusterEnv,
        round: usize,
        round_tag: &str,
        grads: Vec<Slab>,
    ) -> Result<()> {
        let w_count = env.num_workers();
        let mode = env.sync;

        // Every worker uploads its gradient (late if it just restarted,
        // never if the update is dropped in transit).
        let mut keys: Vec<String> = Vec::with_capacity(w_count);
        for (w, grad) in grads.into_iter().enumerate() {
            let mut tl = env.timeline(w);
            if tl.enter_sync() {
                continue;
            }
            let key = format!("{round_tag}/g{w}");
            tl.put(StoreSel::Shared, Stage::Synchronize, &key, grad);
            keys.push(key);
        }
        if keys.is_empty() {
            // Every update was lost: nothing to aggregate this round.
            return Ok(());
        }

        // Master bulk-fetches the round's gradients (pipelined over one
        // connection, still serialized on its clock — the Fig. 2
        // bottleneck), averages. BSP waits for all of them; async takes the
        // earliest-visible quorum and skips the rest.
        let subset: Vec<usize> = match mode {
            SyncMode::Bsp => (0..keys.len()).collect(),
            SyncMode::Async { .. } => store_quorum(env, StoreSel::Shared, &keys, mode, round, 0),
        };
        env.comm.stale_skips += (keys.len() - subset.len()) as u64;
        let fetch_keys: Vec<String> = subset.iter().map(|&i| keys[i].clone()).collect();

        let m = self.master;
        let fetched = env.timeline(m).get_many(StoreSel::Shared, Stage::Synchronize, &fetch_keys)?;
        let agg_secs = env.local_agg_secs(fetched.len());
        env.timeline(m).advance(Stage::Synchronize, agg_secs);
        let mean = env.aggregate(m, &fetched)?;
        let agg_key = format!("{round_tag}/agg");
        env.timeline(m).put(StoreSel::Shared, Stage::Synchronize, &agg_key, mean);

        // Everyone fetches the aggregate and applies it.
        for w in 0..w_count {
            let agg = env.timeline(w).get(StoreSel::Shared, Stage::Synchronize, &agg_key)?;
            // Gradients were already averaged by the master: inv_k = 1.
            env.apply_update(w, &agg, 1.0)?;
        }

        // The round's payloads are consumed; free them (timeline-neutral).
        for key in &keys {
            env.store.delete(key);
        }
        env.store.delete(&agg_key);
        Ok(())
    }
}

impl Strategy for AllReduce {
    fn kind(&self) -> FrameworkKind {
        FrameworkKind::AllReduce
    }

    fn run_epoch(&mut self, env: &mut ClusterEnv) -> Result<EpochStats> {
        env.begin_epoch();
        let w_count = env.num_workers();
        let start = env.max_clock();
        let alloc_mb = env.allocated_mb();
        let mut loss_sum = 0.0;
        let mut loss_n = 0usize;

        for round in 0..env.batches_per_epoch {
            env.trace.set_round(round);
            let tag = format!("e{}/r{}", env.epoch, round);

            // Each batch is one stateless invocation per worker.
            let mut invs = Vec::with_capacity(w_count);
            let mut grads = Vec::with_capacity(w_count);
            for w in 0..w_count {
                let inv = env.lambda.begin_invocation(env.workers[w].clock, w);
                env.workers[w].clock = inv.body_start;
                invs.push(inv);
                env.state_load(w);
                let mut g = env.compute_grad(w, Device::LambdaCpu)?;
                if env.crash_in_compute(w) {
                    g = env.recover_invocation(w, Device::LambdaCpu)?;
                }
                if let Some(l) = g.loss {
                    loss_sum += l;
                    loss_n += 1;
                }
                grads.push(g.grad);
            }

            self.sync_round(env, round, &tag, grads)?;

            // Residual orchestration overhead (calibration), then billing.
            let overhead = self.kind().batch_overhead();
            for w in 0..w_count {
                env.charge_sync(w, overhead);
                let end = env.workers[w].clock;
                env.lambda.finish_invocation(invs[w], end, alloc_mb, &mut env.ledger);
            }
        }

        let epoch_secs = env.max_clock() - start;
        Ok(EpochStats {
            mean_loss: (loss_n > 0).then(|| loss_sum / loss_n as f64),
            batches: env.batches_per_epoch * w_count,
            epoch_secs,
            mean_fn_secs: env.lambda.mean_duration(),
        })
    }

    fn stage_table(&self) -> Vec<(Stage, &'static str)> {
        vec![
            (Stage::FetchDataset, "Each worker fetches a minibatch."),
            (
                Stage::ComputeGradients,
                "Gradients are computed for the minibatch and stored in a shared database.",
            ),
            (
                Stage::Synchronize,
                "A designated master worker retrieves all gradients, aggregates, stores the \
                 result; other workers fetch the aggregated gradient.",
            ),
            (Stage::ModelUpdate, "Workers apply the aggregated gradient to update the model."),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::FrameworkKind;
    use crate::coordinator::env::EnvConfig;

    fn env(workers: usize) -> ClusterEnv {
        ClusterEnv::new(
            EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", workers).unwrap(),
        )
        .unwrap()
    }

    fn async_env(workers: usize, staleness: usize) -> ClusterEnv {
        ClusterEnv::new(
            EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", workers)
                .unwrap()
                .with_sync(SyncMode::Async { staleness }),
        )
        .unwrap()
    }

    #[test]
    fn epoch_runs_and_bills_all_invocations() {
        let mut e = env(4);
        let stats = AllReduce::new().run_epoch(&mut e).unwrap();
        assert_eq!(stats.batches, 4 * 24);
        assert_eq!(e.lambda.invocations, 4 * 24);
        assert!(stats.epoch_secs > 0.0);
        assert!(e.ledger.total_paper() > 0.0);
        // per-batch duration should land in the paper's ballpark (14.38 s)
        assert!(
            (stats.mean_fn_secs - 14.382).abs() / 14.382 < 0.15,
            "mean fn duration {:.2}s vs paper 14.382s",
            stats.mean_fn_secs
        );
    }

    #[test]
    fn master_is_slowest_clock() {
        let mut e = env(4);
        AllReduce::new().run_epoch(&mut e).unwrap();
        // Master (w0) fetched W grads per round; its clock must lead or tie.
        let m = e.workers[0].clock;
        assert!(e.workers.iter().all(|w| w.clock <= m));
    }

    #[test]
    fn mid_epoch_crash_stalls_the_whole_round() {
        use crate::faults::FaultPlan;
        let mut clean = env(4);
        let c = AllReduce::new().run_epoch(&mut clean).unwrap();

        let cfg = EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 4)
            .unwrap()
            .with_faults(FaultPlan::none().crash(2, 1, 12));
        let mut faulty = ClusterEnv::new(cfg).unwrap();
        let f = AllReduce::new().run_epoch(&mut faulty).unwrap();

        // The master waits for every gradient, so the epoch degrades by at
        // least the crashed worker's full restart (cold start + reload +
        // recompute), not just its own delay.
        let restart_stall = crate::cloud::calibration::LAMBDA_COLD_START;
        assert!(
            f.epoch_secs > c.epoch_secs + restart_stall,
            "faulty {:.1}s vs clean {:.1}s",
            f.epoch_secs,
            c.epoch_secs
        );
        // The stall propagates: the *master* (worker 0, which did not
        // crash) is also delayed by more than the restart, because its
        // round fetch blocks on the crashed worker's late upload.
        assert!(
            faulty.workers[0].clock.secs() > clean.workers[0].clock.secs() + restart_stall,
            "master must stall behind the restart: {:.1}s vs {:.1}s",
            faulty.workers[0].clock.secs(),
            clean.workers[0].clock.secs()
        );
        assert_eq!(faulty.recovery.invocation_retries, 1);
        assert!(faulty.recovery.cost_usd > 0.0);
        assert!(faulty.ledger.total_paper() > clean.ledger.total_paper());
    }

    #[test]
    fn dropped_update_falls_out_of_the_aggregate() {
        use crate::faults::FaultPlan;
        let cfg = EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 4)
            .unwrap()
            .with_faults(FaultPlan::none().drop_updates(3, 1, 0, Some(24)));
        let mut e = ClusterEnv::new(cfg).unwrap();
        AllReduce::new().run_epoch(&mut e).unwrap();
        assert_eq!(e.recovery.dropped_updates, 24);
        // Fewer uploads crossed the wire than the clean 24 × 4 per epoch.
        let mut clean = env(4);
        AllReduce::new().run_epoch(&mut clean).unwrap();
        use crate::metrics::CommKind;
        assert!(e.comm.ops(CommKind::Put) < clean.comm.ops(CommKind::Put));
    }

    #[test]
    fn comm_scales_with_workers() {
        let mut small = env(4);
        AllReduce::new().run_epoch(&mut small).unwrap();
        let mut big = env(8);
        AllReduce::new().run_epoch(&mut big).unwrap();
        assert!(big.comm.wire_bytes() > small.comm.wire_bytes() * 3 / 2);
    }

    #[test]
    fn async_quorum_shrinks_master_round_and_counts_skips() {
        let mut bsp = env(8);
        let b = AllReduce::new().run_epoch(&mut bsp).unwrap();
        let mut asy = async_env(8, 2);
        let a = AllReduce::new().run_epoch(&mut asy).unwrap();

        // The master reduces over 6 of 8 gradients per round: strictly less
        // fetch + aggregate time on the critical path.
        assert!(
            a.epoch_secs < b.epoch_secs,
            "async {:.1}s must beat BSP {:.1}s",
            a.epoch_secs,
            b.epoch_secs
        );
        // 2 skips per round, every round.
        assert_eq!(asy.comm.stale_skips, 2 * 24);
        assert_eq!(bsp.comm.stale_skips, 0);
        // Fewer gradients cross the master: fewer GETs on the wire.
        use crate::metrics::CommKind;
        assert!(asy.comm.ops(CommKind::Get) < bsp.comm.ops(CommKind::Get));
    }

    #[test]
    fn async_absorbs_a_straggler_cheaply() {
        use crate::faults::FaultPlan;
        let plan = FaultPlan::none().straggler(3, 1, 0, 4.0, None);

        let cfg = EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 4)
            .unwrap()
            .with_faults(plan.clone());
        let mut bsp = ClusterEnv::new(cfg).unwrap();
        AllReduce::new().run_epoch(&mut bsp).unwrap();

        let cfg = EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 4)
            .unwrap()
            .with_faults(plan)
            .with_sync(SyncMode::Async { staleness: 1 });
        let mut asy = ClusterEnv::new(cfg).unwrap();
        AllReduce::new().run_epoch(&mut asy).unwrap();

        // The straggler's own clock dominates the epoch either way, but the
        // healthy workers no longer wait for its uploads: the fleet bills
        // fewer Lambda-seconds and the fast workers finish far earlier.
        assert!(
            asy.lambda.billed_secs < bsp.lambda.billed_secs,
            "async billed {:.1}s vs BSP {:.1}s",
            asy.lambda.billed_secs,
            bsp.lambda.billed_secs
        );
        let fast_async = asy.workers[0].clock.secs();
        let fast_bsp = bsp.workers[0].clock.secs();
        assert!(
            fast_async < fast_bsp,
            "healthy worker decoupled: {fast_async:.1}s vs {fast_bsp:.1}s"
        );
        assert!(asy.comm.stale_skips > 0);
    }
}
