//! Bench: regenerate Fig. 3 (MLLess communication reduction via
//! significance filtering) — publish-rate sweep at paper scale.
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let points = slsgpu::exp::fig3::run_sim(&[1.0, 0.75, 0.5, 0.25, 0.1, 0.05, 0.02])
        .expect("fig3");
    print!("{}", slsgpu::exp::fig3::render_sim(&points));
    let first = &points[0];
    let last = &points[points.len() - 1];
    println!(
        "epoch-time reduction {:.1}x (paper convergence-time headline: {:.1}x)",
        first.epoch_secs / last.epoch_secs,
        slsgpu::exp::fig3::PAPER_UNFILTERED_SECS / slsgpu::exp::fig3::PAPER_FILTERED_SECS
    );
    println!("regenerated in {:.0} ms", t0.elapsed().as_secs_f64() * 1000.0);
}
