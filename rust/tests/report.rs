//! Report-layer acceptance tests.
//!
//! * Golden files: rendering a fixed `Report` must be byte-stable — the
//!   text/Markdown/JSON renderers are compared against checked-in goldens
//!   under `rust/tests/golden/`.
//! * Determinism: two runs of the (reduced) virtual-mode suite must
//!   produce identical reports, and `write_docs` must write bit-identical
//!   `docs/` trees — the property the CI freshness gate relies on.
//! * Verdicts: anchor PASS/WARN boundaries must match `exp::rel_err`.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use slsgpu::exp;
use slsgpu::report::suite::{self, Outcome, SuiteConfig};
use slsgpu::report::{Align, Anchor, Cell, Report, Section, Table, Verdict};

// ---------------------------------------------------------------------------
// Golden rendering

fn fixture() -> Report {
    let mut t = Table::new(
        "timing",
        &[
            ("Framework", Align::Left),
            ("Per-batch (s)", Align::Right),
            ("Verdict basis", Align::Left),
        ],
    )
    .title("Fixture — paper-anchored timings");
    t.push_row(vec![
        Cell::text("SPIRT"),
        Cell::vs_paper(14.0, 14.343, 2, 0.15),
        Cell::text("within 15%"),
    ]);
    t.rule();
    t.push_row(vec![
        Cell::text("MLLess"),
        Cell::vs_paper(99.0, 69.425, 2, 0.15),
        Cell::text("out of 15%"),
    ]);
    let mut plain = Table::new("counts", &[("kind", Align::Left), ("n", Align::Right)]);
    plain.push_row(vec![Cell::text("ops"), Cell::count(42)]);
    Report::new("fixture", "Fixture report", "slsgpu fixture")
        .with_intro(
            "Fixed input for the golden-file tests: byte-stable across runs and platforms.",
        )
        .with_section(
            Section::new()
                .heading("Timings")
                .paragraph("One PASS row and one WARN row.")
                .table(t)
                .note("note: trailing footer line"),
        )
        .with_section(Section::new().table(plain))
}

#[test]
fn golden_text_rendering_is_byte_stable() {
    assert_eq!(fixture().to_text(), include_str!("golden/report_fixture.txt"));
}

#[test]
fn golden_markdown_rendering_is_byte_stable() {
    assert_eq!(fixture().to_markdown(), include_str!("golden/report_fixture.md"));
}

#[test]
fn golden_json_rendering_is_byte_stable() {
    assert_eq!(
        format!("{}\n", fixture().to_json()),
        include_str!("golden/report_fixture.json")
    );
}

// ---------------------------------------------------------------------------
// Verdict boundaries

#[test]
fn anchor_verdicts_match_rel_err_boundaries() {
    let anchor = Anchor::new(100.0, 0.10);
    for measured in [85.0, 90.0, 95.0, 100.0, 105.0, 110.0, 110.0001, 123.456, 250.0] {
        let expected = if exp::rel_err(measured, 100.0) <= 0.10 {
            Verdict::Pass
        } else {
            Verdict::Warn
        };
        assert_eq!(anchor.verdict(measured), expected, "measured {measured}");
    }
    // The boundary is inclusive: rel_err == tol is a PASS, just beyond is
    // a WARN — exactly where the `< tol` experiment tests sit.
    assert_eq!(anchor.verdict(110.0), Verdict::Pass);
    assert_eq!(anchor.verdict(110.0001), Verdict::Warn);
    assert_eq!(anchor.verdict(90.0), Verdict::Pass);
    assert_eq!(anchor.verdict(89.999), Verdict::Warn);
    // Zero paper values have rel_err defined as 0 (no meaningful relative
    // error), so they can never WARN — mirroring `exp::vs_paper`'s output.
    assert_eq!(Anchor::new(0.0, 0.0).verdict(5.0), Verdict::Pass);
}

// ---------------------------------------------------------------------------
// Suite determinism

/// Reduced suite: same code paths as the canonical `docs/` run, small
/// enough for CI (single sweep point, 1 fault epoch, short sweeps).
fn tiny_suite() -> SuiteConfig {
    SuiteConfig {
        fig2_workers: vec![4],
        fig3_rates: vec![1.0, 0.1],
        indb_minibatches: 6,
        fault: exp::table4_faults::FaultConfig { epochs: 1, ..Default::default() },
        sweep: exp::scale_sweep::SweepConfig {
            worker_counts: vec![4],
            batches_per_epoch: 4,
            threads: 2,
            ..Default::default()
        },
        shard_sweep: exp::shard_sweep::ShardSweepConfig {
            shard_counts: vec![1, 2],
            replications: vec![1, 2],
            worker_counts: vec![4],
            batches_per_epoch: 4,
            threads: 2,
            ..Default::default()
        },
        ..SuiteConfig::default()
    }
}

fn tree_files(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    let mut out = BTreeMap::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        for dirent in fs::read_dir(&d).unwrap() {
            let path = dirent.unwrap().path();
            if path.is_dir() {
                stack.push(path);
            } else {
                let rel = path.strip_prefix(dir).unwrap().to_string_lossy().into_owned();
                out.insert(rel, fs::read(&path).unwrap());
            }
        }
    }
    out
}

#[test]
fn suite_reruns_and_docs_trees_are_bit_identical() {
    let entries_a = suite::run(&tiny_suite()).unwrap();
    let entries_b = suite::run(&tiny_suite()).unwrap();
    assert_eq!(entries_a.len(), suite::EXPERIMENT_IDS.len());
    for (a, b) in entries_a.iter().zip(&entries_b) {
        assert_eq!(a.id, b.id);
        match (&a.outcome, &b.outcome) {
            (Outcome::Ran(ra), Outcome::Ran(rb)) => {
                assert_eq!(
                    ra.to_json().to_string(),
                    rb.to_json().to_string(),
                    "{}: JSON must be bit-identical across runs",
                    a.id
                );
                assert_eq!(ra.to_markdown(), rb.to_markdown(), "{}", a.id);
                // Drivers and the suite's skip path must agree on titles,
                // or a skipped run renders a different summary row.
                assert_eq!(
                    ra.title,
                    suite::canonical_title(&a.id),
                    "{}: driver title desynced from suite::canonical_title",
                    a.id
                );
            }
            (Outcome::Skipped(_), Outcome::Skipped(_)) => {}
            _ => panic!("{}: ran/skipped mismatch across identical configs", a.id),
        }
    }

    let base = std::env::temp_dir().join(format!("slsgpu-report-test-{}", std::process::id()));
    let dir_a = base.join("a");
    let dir_b = base.join("b");
    suite::write_docs(&entries_a, &dir_a).unwrap();
    suite::write_docs(&entries_b, &dir_b).unwrap();
    let tree_a = tree_files(&dir_a);
    let tree_b = tree_files(&dir_b);
    assert_eq!(
        tree_a.keys().collect::<Vec<_>>(),
        tree_b.keys().collect::<Vec<_>>(),
        "docs trees must contain the same files"
    );
    for (name, bytes) in &tree_a {
        assert_eq!(bytes, &tree_b[name], "{name} must be bit-identical");
    }
    assert!(tree_a.contains_key("REPORT.md"));
    assert!(tree_a.contains_key("table2.md"));
    assert!(tree_a.contains_key("data/table2.json"));
    // Skipped table3 still gets a stub page so REPORT.md links resolve,
    // but no data file.
    assert!(tree_a.contains_key("table3.md"));
    assert!(!tree_a.contains_key("data/table3.json"));
    fs::remove_dir_all(&base).unwrap();
}

#[test]
fn write_docs_owns_the_tree_and_clears_stale_files() {
    let mut cfg = tiny_suite();
    cfg.skip = suite::EXPERIMENT_IDS
        .iter()
        .copied()
        .filter(|id| *id != "table1" && *id != "spirt_indb")
        .map(|s| s.to_string())
        .collect();
    let entries = suite::run(&cfg).unwrap();
    let dir = std::env::temp_dir().join(format!("slsgpu-report-stale-{}", std::process::id()));
    suite::write_docs(&entries, &dir).unwrap();
    // Plant a stale *generated* page/data file (carrying the generated-file
    // markers) and a hand-written file without them, then regenerate.
    fs::write(dir.join("zzz_stale.md"), "> Generated by `slsgpu report` — old page\n").unwrap();
    fs::write(
        dir.join("data").join("zzz_stale.json"),
        "{\"command\":\"slsgpu exp gone\"}\n",
    )
    .unwrap();
    fs::write(dir.join("zzz_handwritten.md"), "my notes, not generated\n").unwrap();
    suite::write_docs(&entries, &dir).unwrap();
    let tree = tree_files(&dir);
    assert!(!tree.contains_key("zzz_stale.md"), "stale generated pages must be cleared");
    assert!(!tree.contains_key("data/zzz_stale.json"), "stale generated data must be cleared");
    assert!(
        tree.contains_key("zzz_handwritten.md"),
        "files without the generated marker must be left untouched"
    );
    assert!(tree.contains_key("table1.md"));
    let summary = String::from_utf8(tree["REPORT.md"].clone()).unwrap();
    assert!(summary.contains("| skipped |"), "{summary}");
    fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// Report statuses surface in the summary

#[test]
fn summary_reflects_anchor_statuses() {
    let outcome = exp::spirt_indb::run(None, 24).unwrap();
    let report = exp::spirt_indb::report(&outcome);
    assert_eq!(report.status(), Some(Verdict::Pass));
    let entries = vec![suite_entry(report)];
    let md = suite::summary_markdown(&entries);
    assert!(md.contains("| PASS | 4/0 |"), "{md}");
}

fn suite_entry(report: Report) -> suite::Entry {
    suite::Entry {
        id: report.id.clone(),
        title: report.title.clone(),
        outcome: Outcome::Ran(report),
    }
}
