"""Tiled Pallas matmul kernel — the MXU hot-spot of every pointwise conv.

The paper's compute hot path (MobileNet / ResNet on CIFAR-10) is dominated by
1x1 "pointwise" convolutions and the classifier head, both of which are plain
GEMMs after an im2col reshape. This kernel implements that GEMM with an
explicit HBM->VMEM schedule expressed through ``BlockSpec``:

  grid = (M/bm, N/bn, K/bk)      # K innermost: output block stays resident
  x block: (bm, bk) indexed (i, k)
  y block: (bk, bn) indexed (k, j)
  o block: (bm, bn) indexed (i, j), accumulated across the K steps

On a real TPU the (128, 128) output tile matches the MXU systolic array and
the three resident blocks fit comfortably in VMEM (see EXPERIMENTS.md §Perf
for the footprint arithmetic). Under ``interpret=True`` the same schedule
lowers to a fori-loop of (bm,bk)@(bk,bn) dots, which XLA:CPU fuses well.

Autodiff: ``pallas_call`` has no built-in VJP, so ``matmul`` carries a
``jax.custom_vjp`` whose backward pass reuses the same kernel
(dx = g @ y^T, dy = x^T @ g) — the backward GEMMs run on the identical
VMEM schedule as the forward one.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default tiles. bm=bn=128 matches the 128x128 systolic array;
# bk=128 keeps the K-panel bf16/f32-friendly. Shapes that do not divide the
# tile are zero-padded by the wrapper (padding contributes zeros to the
# accumulator, so results are exact).
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] (+)= x[i,k] @ y[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # f32 accumulate; on TPU this is the MXU contraction, under interpret it
    # is a plain dot that XLA lowers to an optimized CPU GEMM per block.
    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(value: int, mult: int) -> int:
    return ((value + mult - 1) // mult) * mult


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def _matmul_padded(x, y, bm, bn, bk):
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"

    # Clamp tiles to the (padded) problem so tiny layers don't pay for a
    # full 128^3 tile, then zero-pad every dim to a tile multiple.
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def matmul(x, y, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """``x @ y`` through the tiled Pallas kernel (f32, any 2-D shapes)."""
    return _matmul_padded(x, y, bm, bn, bk)


def _matmul_fwd(x, y, bm, bn, bk):
    return _matmul_padded(x, y, bm, bn, bk), (x, y)


def _matmul_bwd(bm, bn, bk, res, g):
    x, y = res
    # Both backward GEMMs run through the same Pallas schedule.
    dx = _matmul_padded(g, y.T, bm, bn, bk)
    dy = _matmul_padded(x.T, g, bm, bn, bk)
    return dx, dy


matmul.defvjp(_matmul_fwd, _matmul_bwd)
