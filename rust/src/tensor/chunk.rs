//! Gradient chunking — the heart of ScatterReduce.
//!
//! LambdaML's ScatterReduce splits each gradient into `W` chunks; worker `i`
//! owns chunk `i`, aggregates everyone's copy of it, and the full gradient is
//! reassembled from the `W` aggregated chunks. `ChunkPlan` fixes the split
//! deterministically (first `n % W` chunks are one element longer) so every
//! worker derives identical boundaries without coordination.

use anyhow::{bail, Result};

use super::slab::Slab;

/// A deterministic split of a length-`n` slab into `k` contiguous chunks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkPlan {
    n: usize,
    k: usize,
}

impl ChunkPlan {
    pub fn new(n: usize, k: usize) -> Result<ChunkPlan> {
        if k == 0 {
            bail!("chunk count must be positive");
        }
        if n < k {
            bail!("cannot split {n} elements into {k} non-empty chunks");
        }
        Ok(ChunkPlan { n, k })
    }

    pub fn num_chunks(&self) -> usize {
        self.k
    }

    pub fn total_len(&self) -> usize {
        self.n
    }

    /// Half-open element range `[start, end)` of chunk `i`.
    pub fn range(&self, i: usize) -> (usize, usize) {
        assert!(i < self.k, "chunk index out of range");
        let base = self.n / self.k;
        let extra = self.n % self.k;
        let start = i * base + i.min(extra);
        let len = base + usize::from(i < extra);
        (start, start + len)
    }

    pub fn chunk_len(&self, i: usize) -> usize {
        let (s, e) = self.range(i);
        e - s
    }

    /// Split a slab according to the plan (virtualness preserved).
    pub fn split(&self, slab: &Slab) -> Result<Vec<Slab>> {
        if slab.len() != self.n {
            bail!("slab length {} does not match plan {}", slab.len(), self.n);
        }
        let mut out = Vec::with_capacity(self.k);
        for i in 0..self.k {
            let (s, e) = self.range(i);
            out.push(match slab {
                Slab::Real(v) => Slab::from_vec(v[s..e].to_vec()),
                Slab::Virtual { .. } => Slab::virtual_of(e - s),
            });
        }
        Ok(out)
    }

    /// Reassemble chunks back into a full slab (inverse of `split`).
    pub fn concat(&self, chunks: &[Slab]) -> Result<Slab> {
        if chunks.len() != self.k {
            bail!("expected {} chunks, got {}", self.k, chunks.len());
        }
        for (i, c) in chunks.iter().enumerate() {
            if c.len() != self.chunk_len(i) {
                bail!("chunk {i} has length {}, expected {}", c.len(), self.chunk_len(i));
            }
        }
        if chunks.iter().any(|c| !c.is_real()) {
            return Ok(Slab::virtual_of(self.n));
        }
        let mut out = Vec::with_capacity(self.n);
        for c in chunks {
            out.extend_from_slice(c.as_slice()?);
        }
        Ok(Slab::from_vec(out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_partition_exactly() {
        let plan = ChunkPlan::new(10, 3).unwrap();
        assert_eq!(plan.range(0), (0, 4)); // 10 % 3 = 1 extra -> first chunk longer
        assert_eq!(plan.range(1), (4, 7));
        assert_eq!(plan.range(2), (7, 10));
    }

    #[test]
    fn split_concat_roundtrip() {
        let v: Vec<f32> = (0..23).map(|i| i as f32).collect();
        let slab = Slab::from_vec(v.clone());
        let plan = ChunkPlan::new(23, 4).unwrap();
        let chunks = plan.split(&slab).unwrap();
        assert_eq!(chunks.len(), 4);
        let back = plan.concat(&chunks).unwrap();
        assert_eq!(back.as_slice().unwrap(), v.as_slice());
    }

    #[test]
    fn virtual_split_preserves_sizes() {
        let plan = ChunkPlan::new(100, 7).unwrap();
        let chunks = plan.split(&Slab::virtual_of(100)).unwrap();
        let total: usize = chunks.iter().map(|c| c.len()).sum();
        assert_eq!(total, 100);
        assert!(chunks.iter().all(|c| !c.is_real()));
        assert!(!plan.concat(&chunks).unwrap().is_real());
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(ChunkPlan::new(3, 0).is_err());
        assert!(ChunkPlan::new(2, 3).is_err());
        let plan = ChunkPlan::new(10, 2).unwrap();
        assert!(plan.split(&Slab::zeros(9)).is_err());
        assert!(plan.concat(&[Slab::zeros(5)]).is_err());
        assert!(plan.concat(&[Slab::zeros(4), Slab::zeros(6)]).is_err());
    }
}
