//! LambdaML AllReduce: master-aggregated synchronization (§2, Table 1).
//!
//! Per batch round every worker pushes its gradient to shared storage; a
//! designated master (worker 0) fetches all of them, aggregates, and pushes
//! the result; everyone fetches the aggregate and updates locally. Simple,
//! but the master serializes `W` gradient transfers per round — the
//! scalability bottleneck the paper measures in Fig. 2 (21.88 s at 16
//! workers on ResNet-50).

use crate::cloud::FrameworkKind;
use crate::metrics::Stage;
use crate::tensor::Slab;
use crate::Result;

use super::env::{ClusterEnv, Device};
use super::{EpochStats, Strategy};

#[derive(Debug, Default)]
pub struct AllReduce {
    pub master: usize,
}

impl AllReduce {
    pub fn new() -> AllReduce {
        AllReduce { master: 0 }
    }

    /// One synchronization round after gradients are computed: workers put,
    /// master aggregates, workers fetch + update. Factored out so Fig. 2 can
    /// measure a single round's communication time.
    ///
    /// Fault semantics: a sync-phase crash delays the crashed worker's
    /// upload until its restart — and because the master waits for every
    /// gradient before it can aggregate, the *whole round* stalls behind
    /// the restart (the master-topology weakness the SPIRT paper targets).
    /// A master crash delays the fetch+aggregate+re-publish chain itself.
    /// Dropped updates are simply absent from the aggregate.
    pub fn sync_round(
        &self,
        env: &mut ClusterEnv,
        round_tag: &str,
        grads: Vec<Slab>,
    ) -> Result<()> {
        let w_count = env.num_workers();

        // Every worker uploads its gradient (late if it just restarted,
        // never if the update is dropped in transit).
        let mut keys: Vec<String> = Vec::with_capacity(w_count);
        for w in 0..w_count {
            env.sync_crash(w);
            if env.update_dropped(w) {
                continue;
            }
            let key = format!("{round_tag}/g{w}");
            let t0 = env.workers[w].clock;
            let done = env.store.put(t0, &key, grads[w].clone(), &mut env.ledger, &mut env.comm);
            let dt = done - t0;
            env.workers[w].clock = done;
            env.stages.add(Stage::Synchronize, dt);
            keys.push(key);
        }
        if keys.is_empty() {
            // Every update was lost: nothing to aggregate this round.
            return Ok(());
        }

        // Master bulk-fetches all gradients (pipelined over one connection,
        // still serialized on its clock — the Fig. 2 bottleneck), averages.
        let m = self.master;
        let t0 = env.workers[m].clock;
        let (done, fetched) = env.store.get_many(t0, &keys, &mut env.ledger, &mut env.comm)?;
        env.stages.add(Stage::Synchronize, done - t0);
        env.workers[m].clock = done;
        let agg_secs = env.local_agg_secs(keys.len());
        env.workers[m].clock += agg_secs;
        env.stages.add(Stage::Synchronize, agg_secs);
        let mean = env.aggregate(m, &fetched)?;
        let t0 = env.workers[m].clock;
        let done =
            env.store.put(t0, &format!("{round_tag}/agg"), mean, &mut env.ledger, &mut env.comm);
        env.stages.add(Stage::Synchronize, done - t0);
        env.workers[m].clock = done;

        // Everyone fetches the aggregate and applies it.
        for w in 0..w_count {
            let t0 = env.workers[w].clock;
            let (done, agg) =
                env.store.get(t0, &format!("{round_tag}/agg"), &mut env.ledger, &mut env.comm)?;
            env.stages.add(Stage::Synchronize, done - t0);
            env.workers[w].clock = done;
            // Gradients were already averaged by the master: inv_k = 1.
            env.apply_update(w, &agg, 1.0)?;
        }
        Ok(())
    }
}

impl Strategy for AllReduce {
    fn kind(&self) -> FrameworkKind {
        FrameworkKind::AllReduce
    }

    fn run_epoch(&mut self, env: &mut ClusterEnv) -> Result<EpochStats> {
        env.begin_epoch();
        let w_count = env.num_workers();
        let start = env.max_clock();
        let alloc_mb = env.allocated_mb();
        let mut loss_sum = 0.0;
        let mut loss_n = 0usize;

        for round in 0..env.batches_per_epoch {
            let tag = format!("e{}/r{}", env.epoch, round);

            // Each batch is one stateless invocation per worker.
            let mut invs = Vec::with_capacity(w_count);
            let mut grads = Vec::with_capacity(w_count);
            for w in 0..w_count {
                let inv = env.lambda.begin_invocation(env.workers[w].clock, w);
                env.workers[w].clock = inv.body_start;
                invs.push(inv);
                env.state_load(w);
                let mut g = env.compute_grad(w, Device::LambdaCpu)?;
                if env.crash_in_compute(w) {
                    g = env.recover_invocation(w, Device::LambdaCpu)?;
                }
                if let Some(l) = g.loss {
                    loss_sum += l;
                    loss_n += 1;
                }
                grads.push(g.grad);
            }

            self.sync_round(env, &tag, grads)?;

            // Residual orchestration overhead (calibration), then billing.
            let overhead = self.kind().batch_overhead();
            for w in 0..w_count {
                env.charge_sync(w, overhead);
                let end = env.workers[w].clock;
                env.lambda.finish_invocation(invs[w], end, alloc_mb, &mut env.ledger);
            }
        }

        let epoch_secs = env.max_clock() - start;
        Ok(EpochStats {
            mean_loss: (loss_n > 0).then(|| loss_sum / loss_n as f64),
            batches: env.batches_per_epoch * w_count,
            epoch_secs,
            mean_fn_secs: env.lambda.mean_duration(),
        })
    }

    fn stage_table(&self) -> Vec<(Stage, &'static str)> {
        vec![
            (Stage::FetchDataset, "Each worker fetches a minibatch."),
            (
                Stage::ComputeGradients,
                "Gradients are computed for the minibatch and stored in a shared database.",
            ),
            (
                Stage::Synchronize,
                "A designated master worker retrieves all gradients, aggregates, stores the \
                 result; other workers fetch the aggregated gradient.",
            ),
            (Stage::ModelUpdate, "Workers apply the aggregated gradient to update the model."),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::FrameworkKind;
    use crate::coordinator::env::EnvConfig;

    fn env(workers: usize) -> ClusterEnv {
        ClusterEnv::new(
            EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", workers).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn epoch_runs_and_bills_all_invocations() {
        let mut e = env(4);
        let stats = AllReduce::new().run_epoch(&mut e).unwrap();
        assert_eq!(stats.batches, 4 * 24);
        assert_eq!(e.lambda.invocations, 4 * 24);
        assert!(stats.epoch_secs > 0.0);
        assert!(e.ledger.total_paper() > 0.0);
        // per-batch duration should land in the paper's ballpark (14.38 s)
        assert!(
            (stats.mean_fn_secs - 14.382).abs() / 14.382 < 0.15,
            "mean fn duration {:.2}s vs paper 14.382s",
            stats.mean_fn_secs
        );
    }

    #[test]
    fn master_is_slowest_clock() {
        let mut e = env(4);
        AllReduce::new().run_epoch(&mut e).unwrap();
        // Master (w0) fetched W grads per round; its clock must lead or tie.
        let m = e.workers[0].clock;
        assert!(e.workers.iter().all(|w| w.clock <= m));
    }

    #[test]
    fn mid_epoch_crash_stalls_the_whole_round() {
        use crate::faults::FaultPlan;
        let mut clean = env(4);
        let c = AllReduce::new().run_epoch(&mut clean).unwrap();

        let cfg = EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 4)
            .unwrap()
            .with_faults(FaultPlan::none().crash(2, 1, 12));
        let mut faulty = ClusterEnv::new(cfg).unwrap();
        let f = AllReduce::new().run_epoch(&mut faulty).unwrap();

        // The master waits for every gradient, so the epoch degrades by at
        // least the crashed worker's full restart (cold start + reload +
        // recompute), not just its own delay.
        let restart_stall = crate::cloud::calibration::LAMBDA_COLD_START;
        assert!(
            f.epoch_secs > c.epoch_secs + restart_stall,
            "faulty {:.1}s vs clean {:.1}s",
            f.epoch_secs,
            c.epoch_secs
        );
        // The stall propagates: the *master* (worker 0, which did not
        // crash) is also delayed by more than the restart, because its
        // round fetch blocks on the crashed worker's late upload.
        assert!(
            faulty.workers[0].clock.secs() > clean.workers[0].clock.secs() + restart_stall,
            "master must stall behind the restart: {:.1}s vs {:.1}s",
            faulty.workers[0].clock.secs(),
            clean.workers[0].clock.secs()
        );
        assert_eq!(faulty.recovery.invocation_retries, 1);
        assert!(faulty.recovery.cost_usd > 0.0);
        assert!(faulty.ledger.total_paper() > clean.ledger.total_paper());
    }

    #[test]
    fn dropped_update_falls_out_of_the_aggregate() {
        use crate::faults::FaultPlan;
        let cfg = EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 4)
            .unwrap()
            .with_faults(FaultPlan::none().drop_updates(3, 1, 0, Some(24)));
        let mut e = ClusterEnv::new(cfg).unwrap();
        AllReduce::new().run_epoch(&mut e).unwrap();
        assert_eq!(e.recovery.dropped_updates, 24);
        // Fewer uploads crossed the wire than the clean 24 × 4 per epoch.
        let mut clean = env(4);
        AllReduce::new().run_epoch(&mut clean).unwrap();
        use crate::metrics::CommKind;
        assert!(e.comm.ops(CommKind::Put) < clean.comm.ops(CommKind::Put));
    }

    #[test]
    fn comm_scales_with_workers() {
        let mut small = env(4);
        AllReduce::new().run_epoch(&mut small).unwrap();
        let mut big = env(8);
        AllReduce::new().run_epoch(&mut big).unwrap();
        assert!(big.comm.wire_bytes() > small.comm.wire_bytes() * 3 / 2);
    }
}
