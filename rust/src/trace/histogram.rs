//! Per-op-kind latency/cost summaries over a trace, built on
//! [`metrics::Histogram`](crate::metrics::Histogram) (nearest-rank
//! percentiles).

use std::collections::BTreeMap;

use crate::metrics::Histogram;

use super::event::{EventKind, TraceEvent};

/// Latency percentiles and totals for one op kind.
#[derive(Debug, Clone, PartialEq)]
pub struct KindStats {
    pub kind: EventKind,
    pub count: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    pub total_secs: f64,
    pub total_cost: f64,
}

/// Summarize span latency/cost per kind, in [`EventKind`] display order.
/// Instant markers (zero-duration fault flags) are excluded.
pub fn kind_stats<'a>(events: impl IntoIterator<Item = &'a TraceEvent>) -> Vec<KindStats> {
    let mut lat: BTreeMap<EventKind, Histogram> = BTreeMap::new();
    let mut cost: BTreeMap<EventKind, f64> = BTreeMap::new();
    for e in events {
        if e.kind.is_instant() {
            continue;
        }
        lat.entry(e.kind).or_default().add(e.secs() * 1e3);
        *cost.entry(e.kind).or_insert(0.0) += e.cost;
    }
    lat.into_iter()
        .map(|(kind, h)| KindStats {
            kind,
            count: h.len() as u64,
            p50_ms: h.percentile(50.0),
            p95_ms: h.percentile(95.0),
            p99_ms: h.percentile(99.0),
            max_ms: h.max(),
            total_secs: h.total() / 1e3,
            total_cost: cost[&kind],
        })
        .collect()
}

/// p99 latency (ms) over communication/coordination ops only — the number
/// the scale sweep records per point when tracing is opted in.
pub fn p99_comm_ms<'a>(events: impl IntoIterator<Item = &'a TraceEvent>) -> Option<f64> {
    let mut h = Histogram::new();
    for e in events {
        if e.kind.is_comm() {
            h.add(e.secs() * 1e3);
        }
    }
    if h.is_empty() {
        None
    } else {
        Some(h.percentile(99.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::VTime;
    use crate::trace::{TraceCollector, TraceConfig};

    fn collector_with_puts(n: usize) -> TraceCollector {
        let mut c = TraceCollector::new(&TraceConfig::on());
        for i in 1..=n {
            // Latencies 1ms, 2ms, …, n ms.
            let t0 = VTime::from_secs(i as f64);
            c.span(0, t0, t0 + i as f64 * 1e-3, EventKind::Put, 8, 0.01, None);
        }
        c
    }

    #[test]
    fn nearest_rank_percentiles_per_kind() {
        let c = collector_with_puts(100);
        let stats = kind_stats(c.snapshot().iter());
        assert_eq!(stats.len(), 1);
        let s = &stats[0];
        assert_eq!(s.kind, EventKind::Put);
        assert_eq!(s.count, 100);
        assert!((s.p50_ms - 50.0).abs() < 1e-9);
        assert!((s.p95_ms - 95.0).abs() < 1e-9);
        assert!((s.p99_ms - 99.0).abs() < 1e-9);
        assert!((s.max_ms - 100.0).abs() < 1e-9);
        assert!((s.total_secs - 5.050).abs() < 1e-9);
        assert!((s.total_cost - 1.0).abs() < 1e-9);
    }

    #[test]
    fn comm_p99_ignores_compute_and_instants() {
        let mut c = collector_with_puts(10);
        c.span(1, VTime::ZERO, VTime::from_secs(100.0), EventKind::Compute, 0, 0.0, None);
        c.instant(1, VTime::from_secs(1.0), EventKind::Poison);
        assert!((p99_comm_ms(c.snapshot().iter()).unwrap() - 10.0).abs() < 1e-9);
        let empty = TraceCollector::new(&TraceConfig::on());
        assert_eq!(p99_comm_ms(empty.snapshot().iter()), None);
    }
}
