//! # slsgpu — Serverless-vs-GPU distributed training testbed
//!
//! Reproduction of *"Cost-Performance Analysis: A Comparative Study of
//! CPU-Based Serverless and GPU-Based Training Architectures"* (Barrak,
//! Petrillo, Jaafar — PDCAT 2025).
//!
//! The crate is the paper's testbed rebuilt as a library:
//!
//! * [`cloud`] — simulated AWS substrates (Lambda, RedisAI, S3, queues,
//!   Step Functions, EC2/GPU) with virtual-time latency + billing models;
//!   `cloud::cluster` shards the shared store over a consistent-hash ring
//!   with replication, failover and deterministic LRU eviction.
//! * [`coordinator`] — the five training architectures under comparison:
//!   SPIRT, MLLess, LambdaML AllReduce / ScatterReduce, and the distributed
//!   GPU baseline. Their shared protocol plumbing (per-worker `Timeline`
//!   handles, typed ops, the BSP/bounded-staleness `SyncMode` policy)
//!   lives in `coordinator::protocol`.
//! * [`runtime`] — the PJRT bridge: loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them from Rust. Python
//!   never runs at request time.
//! * [`sim`] — the virtual-time core: worker clocks, queueing resources,
//!   the calibrated compute-duration model.
//! * [`faults`] — deterministic fault injection (crashes with cold-start
//!   restarts, stragglers, update drops, gradient poisoning) consulted by
//!   the coordinator at every workflow-stage boundary, plus the
//!   poisoning/robust-aggregation demo. Adversarial regimes compose the
//!   primitives: Byzantine coalitions, healing network partitions,
//!   heavy-tailed Pareto straggler factors, and spot-preemption storms
//!   (DESIGN.md §8).
//! * [`train`] — the epoch/step driver that wires data, strategy, substrates
//!   and runtime into a training session.
//! * [`exp`] — drivers that regenerate every table and figure of the paper,
//!   plus the fault-resilience table (`exp::table4_faults`), the
//!   4→256-worker scalability sweep (`exp::scale_sweep`, parallelized over
//!   std threads), the store-tier provisioning frontier
//!   (`exp::shard_sweep`) and the robustness tournament crossing
//!   aggregation rules × adversarial regimes × architectures
//!   (`exp::tournament`). Every driver returns a typed [`report::Report`].
//! * [`report`] — the documentation pipeline: the typed report model
//!   (tables, rows, cells with paper anchors and PASS/WARN verdicts) with
//!   text/Markdown/CSV/JSON renderers, and the suite runner behind
//!   `slsgpu report` that regenerates the `docs/` tree deterministically.
//! * [`trace`] — protocol-level observability: a deterministic structured
//!   event log over every protocol op/stage/fault (zero-cost when disabled),
//!   with Chrome trace-event export, critical-path analysis and per-op-kind
//!   latency percentiles behind `slsgpu trace`.
//! * [`analysis`] — the repo-native invariant auditor: a static-analysis
//!   pass over this repository's own sources that enforces the
//!   determinism, accounting and registration contracts (unordered
//!   iteration, vtime purity, float-reduction discipline, target
//!   registration, trace-emit confinement, generated-docs markers) behind
//!   `slsgpu audit`, with audited `audit:allow` suppressions.
//!
//! Time in experiment outputs is *virtual* (the paper's AWS time axis,
//! calibrated from the paper's own measurements — see
//! [`cloud::calibration`]); bytes, gradients and accuracies are real.

pub mod analysis;
pub mod cloud;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod faults;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod trace;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
