//! Quickstart: train a small MobileNet with the AllReduce architecture,
//! end to end through the full stack — PJRT-compiled JAX/Pallas gradients,
//! simulated AWS substrates, virtual-time cost accounting.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::rc::Rc;

use slsgpu::cloud::FrameworkKind;
use slsgpu::coordinator::{strategy_for, ClusterEnv, EnvConfig};
use slsgpu::runtime::Engine;
use slsgpu::train::{run_session, SessionConfig};

fn main() -> anyhow::Result<()> {
    // 1. Load the AOT artifacts (built once by `make artifacts`).
    let engine = Rc::new(Engine::load("artifacts")?);

    // 2. Build a 4-worker cluster over the executed MobileNet config.
    let cfg = EnvConfig::real(
        FrameworkKind::AllReduce,
        engine,
        "mobilenet_s",
        4,    // workers
        512,  // training samples (synthetic CIFAR)
        42,   // seed
    )?;
    let mut env = ClusterEnv::new(cfg)?;

    // 3. Train for two epochs with the framework's full protocol.
    let mut strategy = strategy_for(FrameworkKind::AllReduce);
    let session = SessionConfig { max_epochs: 2, target_acc: 0.99, patience: 10, evaluate: true };
    let report = run_session(&mut env, strategy.as_mut(), &session)?;

    for e in &report.reports {
        println!(
            "epoch {}: loss {:.4}, test acc {:.1}%, virtual time {:.1}s, cost ${:.4}",
            e.epoch,
            e.mean_loss.unwrap_or(f64::NAN),
            e.test_acc.unwrap_or(0.0) * 100.0,
            e.vtime_secs,
            e.cost_usd
        );
    }
    println!(
        "gradient bytes on the wire: {}",
        slsgpu::util::fmt_bytes(env.comm.wire_bytes())
    );
    Ok(())
}
