//! Micro-benchmarks for the L3 hot paths (plain harness; criterion is
//! unavailable offline). Each case reports ns/op or GB/s over enough
//! iterations to stabilize — the numbers feed EXPERIMENTS.md §Perf.

use std::time::Instant;

use slsgpu::metrics::{CommStats, Ledger};
use slsgpu::sim::{Resource, VTime};
use slsgpu::tensor::{ChunkPlan, Slab};

fn time<F: FnMut()>(name: &str, iters: usize, bytes_per_iter: Option<u64>, mut f: F) {
    // Warmup.
    for _ in 0..iters.div_ceil(10) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let secs = t0.elapsed().as_secs_f64();
    let per_op = secs / iters as f64;
    match bytes_per_iter {
        Some(b) => println!(
            "{name:<40} {:>10.2} us/op  {:>8.2} GB/s",
            per_op * 1e6,
            b as f64 * iters as f64 / secs / 1e9
        ),
        None => println!("{name:<40} {:>10.2} us/op", per_op * 1e6),
    }
}

fn main() {
    let n = 4_200_000; // MobileNet-sized slab

    // Slab axpy — the pure-Rust aggregation hot loop.
    let mut acc = Slab::zeros(n);
    let g = Slab::from_vec(vec![0.5; n]);
    time("slab axpy (4.2M f32)", 50, Some(4 * n as u64), || {
        acc.axpy(&g, 0.25).unwrap();
    });

    // Slab mean of 4 (AllReduce master aggregation).
    let grads: Vec<Slab> = (0..4).map(|_| Slab::from_vec(vec![1.0; n])).collect();
    time("slab mean of 4 (4.2M f32)", 20, Some(16 * n as u64), || {
        let _ = Slab::mean(&grads).unwrap();
    });

    // Chunk split + concat (ScatterReduce path).
    let plan = ChunkPlan::new(n, 16).unwrap();
    let slab = Slab::from_vec(vec![2.0; n]);
    time("chunk split 16-way (4.2M f32)", 50, Some(4 * n as u64), || {
        let _ = plan.split(&slab).unwrap();
    });
    let chunks = plan.split(&slab).unwrap();
    time("chunk concat 16-way (4.2M f32)", 50, Some(4 * n as u64), || {
        let _ = plan.concat(&chunks).unwrap();
    });

    // L2 norm (significance filter).
    time("slab l2_norm_sq (4.2M f32)", 50, Some(4 * n as u64), || {
        let _ = slab.l2_norm_sq();
    });

    // Virtual-time resource scheduling (the simulation engine itself).
    let mut r = Resource::new("bench", 4);
    let mut i = 0u64;
    time("resource serve (backfill scheduler)", 200_000, None, || {
        i += 1;
        if i % 10_000 == 0 {
            r.reset(); // keep interval lists bounded like real epochs do
        }
        let _ = r.serve(VTime::from_secs((i % 100) as f64), 0.01);
    });

    // Virtual redis set/get with real slab movement (1 MB payloads).
    let mut redis = slsgpu::cloud::Redis::new("bench");
    let mut comm = CommStats::new();
    let payload = Slab::from_vec(vec![1.0; 262_144]);
    let mut t = VTime::ZERO;
    time("redis set+get (1 MiB real slab)", 2_000, Some(2 * 1_048_576), || {
        t = redis.set(t, "k", payload.clone(), &mut comm);
        let (t2, _) = redis.get(t, "k", &mut comm).unwrap();
        t = t2;
    });

    // Queue publish/poll.
    let mut q = slsgpu::cloud::MessageQueue::new();
    let mut ledger = Ledger::new();
    let mut tq = VTime::ZERO;
    let mut k = 0u64;
    time("queue publish+wait", 20_000, None, || {
        k += 1;
        let topic = format!("t{}", k % 64);
        tq = q.publish(tq, &topic, "m", &mut ledger, &mut comm);
        let _ = q.wait_for(tq, &topic, 1, &mut ledger, &mut comm).unwrap();
        if k % 1000 == 0 {
            q.clear();
        }
    });

    // One full virtual Table-2 epoch (whole-simulator throughput).
    time("virtual epoch: AllReduce/mobilenet x4", 5, None, || {
        let mut env = slsgpu::coordinator::ClusterEnv::new(
            slsgpu::coordinator::EnvConfig::virtual_paper(
                slsgpu::cloud::FrameworkKind::AllReduce,
                "mobilenet",
                4,
            )
            .unwrap(),
        )
        .unwrap();
        slsgpu::coordinator::strategy_for(slsgpu::cloud::FrameworkKind::AllReduce)
            .run_epoch(&mut env)
            .unwrap();
    });
}
