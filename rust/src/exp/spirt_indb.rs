//! §4.2: SPIRT in-database computation vs the naive fetch-update-store
//! baseline — gradient averaging and model update on ResNet-18-sized slabs.
//!
//! Two modes: virtual (paper-scale payload, latency model only) and real
//! (actual 46.8 MB slabs, the math executed by the PJRT-compiled Pallas
//! kernels inside the Redis substrate — the faithful RedisAI analog; also
//! reports host wall-clock for EXPERIMENTS.md §Perf).

use std::rc::Rc;
use std::sync::Arc;
// audit:allow(vtime-purity, measures host wall time of the real PJRT path - never enters vtime)
use std::time::Instant;

use crate::cloud::Redis;
use crate::metrics::CommStats;
use crate::report::{Align, Cell, Report, Table};
use crate::runtime::{Engine, PjrtMath};
use crate::sim::VTime;
use crate::tensor::Slab;
use crate::Result;

/// Anchor tolerances: the averaging loop reproduces within 10%, the update
/// path within 15% (the bands `virtual_mode_reproduces_paper_within_10pct`
/// asserts).
pub const AVG_TOL: f64 = 0.10;
pub const UPDATE_TOL: f64 = 0.15;

/// Paper §4.2 values (seconds).
pub const PAPER: PaperValues = PaperValues {
    naive_avg: 67.32,
    indb_avg: 37.41,
    naive_update: 27.5,
    indb_update: 4.8,
};

#[derive(Debug, Clone, Copy)]
pub struct PaperValues {
    pub naive_avg: f64,
    pub indb_avg: f64,
    pub naive_update: f64,
    pub indb_update: f64,
}

#[derive(Debug, Clone)]
pub struct Outcome {
    pub n_params: usize,
    pub minibatches: usize,
    pub naive_avg_secs: f64,
    pub indb_avg_secs: f64,
    pub naive_update_secs: f64,
    pub indb_update_secs: f64,
    /// Host wall-clock of the real in-DB ops (ms), when run with the engine.
    pub real_wall_ms: Option<f64>,
}

fn make_slab(n: usize, real: bool, seed: u64) -> Slab {
    if !real {
        return Slab::virtual_of(n);
    }
    let mut rng = crate::util::rng::Rng::new(seed);
    Slab::from_vec((0..n).map(|_| rng.normal_f32(0.0, 0.01)).collect())
}

/// Run the benchmark. `engine: Some(..)` uses real slabs + PJRT in-DB math
/// at the named slab size; `None` runs the latency model at paper scale.
pub fn run(engine: Option<(Rc<Engine>, &str)>, minibatches: usize) -> Result<Outcome> {
    let (n, mut redis, real) = match &engine {
        Some((eng, slab_name)) => {
            let n = eng.manifest.slab(slab_name)?.n;
            let math = Arc::new(PjrtMath::new(eng.clone(), slab_name.to_string()));
            (n, Redis::with_math("indb-bench", math), true)
        }
        None => (11_700_000, Redis::new("indb-bench"), false),
    };
    let mut comm = CommStats::new();
    // audit:allow(vtime-purity, real_wall_ms is host-side reporting for EXPERIMENTS.md - not vtime)
    let wall_start = Instant::now();

    // ---- Averaging: naive fetch-update-store ----------------------------
    let mut naive = Redis::new("naive-bench");
    naive.set(VTime::ZERO, "acc", make_slab(n, real, 1), &mut comm);
    naive.set(VTime::ZERO, "g", make_slab(n, real, 2), &mut comm);
    let start = VTime::from_secs(0.0);
    let mut t = start;
    for _ in 0..minibatches {
        // Stateless function: fetch acc + fetch gradient, store new acc.
        let (t1, mut acc) = naive.get_tensor_client(t, "acc", &mut comm)?;
        let (t2, g) = naive.get_tensor_client(t1, "g", &mut comm)?;
        acc.axpy(&g, 1.0)?;
        t = naive.set_tensor_client(t2, "acc", acc, &mut comm);
    }
    let naive_avg_secs = t - start;

    // ---- Averaging: in-database accumulation ----------------------------
    redis.set(VTime::ZERO, "g", make_slab(n, real, 3), &mut comm);
    let mut t = start;
    for i in 0..minibatches {
        t = if i == 0 {
            redis.scale_in_db(t, "gsum", "g", 1.0, &mut comm)?
        } else {
            redis.acc_in_db(t, "gsum", "gsum", "g", 1.0, &mut comm)?
        };
    }
    let indb_avg_secs = t - start;

    // ---- Update: naive (fetch, rebuild state_dict, apply, store) --------
    // Measured on an idle timeline (well past the averaging phase).
    let nbytes = 4 * n as u64;
    let u0 = VTime::from_secs(1_000.0);
    let (t1, mut theta) = naive.get_tensor_client(u0, "acc", &mut comm)?;
    let (t2, g) = naive.get_tensor_client(t1, "g", &mut comm)?;
    theta.sgd(&g, 0.01)?;
    let t3 = t2 + Redis::rebuild_secs(nbytes);
    let naive_update_secs = naive.set_tensor_client(t3, "theta", theta, &mut comm) - u0;

    // ---- Update: in-database fused Pallas kernel -------------------------
    redis.set(VTime::ZERO, "theta", make_slab(n, real, 4), &mut comm);
    let t_up0 = VTime::from_secs(1_000.0);
    let indb_update_secs =
        redis.avg_update_in_db(t_up0, "theta", "gsum", 1.0 / minibatches as f32, 0.01, &mut comm)?
            - t_up0;

    Ok(Outcome {
        n_params: n,
        minibatches,
        naive_avg_secs,
        indb_avg_secs,
        naive_update_secs,
        indb_update_secs,
        real_wall_ms: real.then(|| wall_start.elapsed().as_secs_f64() * 1000.0),
    })
}

/// Build the §4.2 report (all four paper values anchored).
pub fn report(o: &Outcome) -> Report {
    let mut t = Table::new(
        "spirt_indb",
        &[
            ("Operation", Align::Left),
            ("Naive (s)", Align::Right),
            ("In-DB (s)", Align::Right),
            ("Speedup", Align::Right),
            ("Paper (naive->in-DB)", Align::Right),
        ],
    )
    .title(format!(
        "SPIRT in-database ops vs naive fetch-update-store ({} params, {} minibatches)",
        o.n_params, o.minibatches
    ));
    let anchored = |measured: f64, paper: f64, tol: f64| {
        Cell::anchored(format!("{measured:.2}"), measured, paper, tol)
    };
    t.push_row(vec![
        Cell::text("Gradient averaging"),
        anchored(o.naive_avg_secs, PAPER.naive_avg, AVG_TOL),
        anchored(o.indb_avg_secs, PAPER.indb_avg, AVG_TOL),
        Cell::text(format!("{:.2}x", o.naive_avg_secs / o.indb_avg_secs))
            .with_value(o.naive_avg_secs / o.indb_avg_secs),
        Cell::text(format!("{:.2} -> {:.2}", PAPER.naive_avg, PAPER.indb_avg)),
    ]);
    t.push_row(vec![
        Cell::text("Model update"),
        anchored(o.naive_update_secs, PAPER.naive_update, UPDATE_TOL),
        anchored(o.indb_update_secs, PAPER.indb_update, UPDATE_TOL),
        Cell::text(format!("{:.2}x", o.naive_update_secs / o.indb_update_secs))
            .with_value(o.naive_update_secs / o.indb_update_secs),
        Cell::text(format!("{:.2} -> {:.2}", PAPER.naive_update, PAPER.indb_update)),
    ]);
    if let Some(ms) = o.real_wall_ms {
        t.rule();
        t.push_row(vec![
            Cell::text("Host wall (real PJRT ops)"),
            Cell::text("-"),
            Cell::text(format!("{ms:.0} ms")),
            Cell::text("-"),
            Cell::text("-"),
        ]);
    }
    Report::new(
        "spirt_indb",
        "SPIRT in-database ops vs naive fetch-update-store",
        format!("slsgpu exp spirt-indb --minibatches {}", o.minibatches),
    )
    .with_intro(
        "§4.2: gradient averaging and model update on ResNet-18-sized slabs, the naive \
         fetch-update-store loop vs SPIRT's in-database computation. Virtual mode runs \
         the calibrated Redis latency model at paper scale; with `--real` the same \
         benchmark moves actual 46.8 MB slabs and executes the PJRT-compiled Pallas \
         kernels inside the Redis substrate (the RedisAI analog).",
    )
    .with_table(t)
}

/// Legacy CLI view of [`report`].
pub fn render(o: &Outcome) -> String {
    report(o).to_text()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::rel_err;

    #[test]
    fn virtual_mode_reproduces_paper_within_10pct() {
        let o = run(None, 24).unwrap();
        assert!(rel_err(o.naive_avg_secs, PAPER.naive_avg) < 0.10, "{:.1}", o.naive_avg_secs);
        assert!(rel_err(o.indb_avg_secs, PAPER.indb_avg) < 0.10, "{:.1}", o.indb_avg_secs);
        assert!(
            rel_err(o.naive_update_secs, PAPER.naive_update) < 0.15,
            "{:.1}",
            o.naive_update_secs
        );
        assert!(rel_err(o.indb_update_secs, PAPER.indb_update) < 0.15, "{:.2}", o.indb_update_secs);
    }

    #[test]
    fn report_status_is_pass_in_virtual_mode() {
        let o = run(None, 24).unwrap();
        let r = report(&o);
        assert_eq!(r.verdicts(), (4, 0), "all four paper anchors within tolerance");
        assert_eq!(r.status(), Some(crate::report::Verdict::Pass));
    }

    #[test]
    fn indb_wins_both_operations() {
        let o = run(None, 24).unwrap();
        assert!(o.indb_avg_secs < o.naive_avg_secs);
        assert!(o.indb_update_secs < o.naive_update_secs);
        // Update benefits much more than averaging (paper: 5.7x vs 1.8x).
        let avg_speedup = o.naive_avg_secs / o.indb_avg_secs;
        let upd_speedup = o.naive_update_secs / o.indb_update_secs;
        assert!(upd_speedup > 2.0 * avg_speedup, "avg {avg_speedup:.1}x upd {upd_speedup:.1}x");
    }
}

