//! Cluster environment: workers + substrates + measurement plane.
//!
//! One `ClusterEnv` is one experiment: it owns the worker states (virtual
//! clock + model replica + data shard), every cloud substrate instance, the
//! gradient source (real PJRT artifacts or size-only), and the cost/comm/
//! stage accumulators. Strategies mutate it; the experiment drivers read the
//! results out of it.

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::cloud::calibration::{self, FrameworkKind, ModelProfile};
use crate::cloud::cluster::SHARD_RESTART_SECS;
use crate::cloud::{
    GpuFleet, LambdaRuntime, MessageQueue, ObjectStore, recovery, Redis, RedisCluster,
    StepFunctions, StoreTierConfig,
};
use crate::data::{Dataset, SyntheticCifar, IMG_ELEMS};
use crate::faults::{FaultPlan, FaultSchedule};
use crate::metrics::{CommStats, Ledger, RecoveryStats, Stage, StageTimer};
use crate::runtime::{Engine, PjrtMath};
use crate::sim::VTime;
use crate::tensor::{AggregationRule, Slab};
use crate::trace::{EventKind, TraceCollector, TraceConfig};
use crate::util::rng::Rng;

use super::protocol::SyncMode;

/// Local (in-function) aggregation memory bandwidth, bytes/sec — the speed
/// of summing gradient slabs inside a worker (NumPy-level memory-bound op).
pub const LOCAL_AGG_BW: f64 = 2.0e9;

/// Whether gradients come from the PJRT runtime or are size-only.
pub enum GradMode {
    /// Size-only gradients; losses are not tracked. Used by the paper-scale
    /// cost/communication experiments (Table 2, Fig. 2, Fig. 3-sim).
    Virtual,
    /// Real gradients through the AOT grad artifact; the full e2e path.
    Real {
        engine: Rc<Engine>,
        /// Executed model config name (e.g. "mobilenet_s").
        model: String,
        train: Dataset,
        test: Dataset,
    },
}

/// One worker replica.
#[derive(Debug)]
pub struct WorkerState {
    pub id: usize,
    pub clock: VTime,
    pub theta: Slab,
    /// Sample indices this worker owns (reshuffled every epoch).
    pub shard: Vec<usize>,
    cursor: usize,
}

/// Experiment parameters for building a [`ClusterEnv`].
pub struct EnvConfig {
    pub framework: FrameworkKind,
    pub workers: usize,
    /// Gradient batches per worker per epoch (paper: 24).
    pub batches_per_epoch: usize,
    /// Samples per gradient batch (paper: 512; executed configs: 32/64).
    pub batch_size: usize,
    pub lr: f32,
    /// Full-architecture profile for the virtual-time compute model.
    pub profile: ModelProfile,
    pub grad_mode: GradMode,
    pub seed: u64,
    /// Planned fault injection (empty = fault-free run).
    pub fault_plan: FaultPlan,
    /// How worker updates are combined (robust rules defend poisoning).
    pub agg: AggregationRule,
    /// Round-synchronization policy (BSP barriers or bounded staleness).
    pub sync: SyncMode,
    /// Protocol-event tracing (disabled by default; purely observational).
    pub trace: TraceConfig,
    /// Shared store tier provisioning (shards/replication/eviction). The
    /// default single-shard tier reproduces the pre-cluster store exactly.
    pub store: StoreTierConfig,
}

impl EnvConfig {
    /// Paper-scale, size-only config (cost/communication experiments).
    pub fn virtual_paper(
        framework: FrameworkKind,
        arch: &str,
        workers: usize,
    ) -> Result<EnvConfig> {
        let profile = calibration::profile(arch)
            .ok_or_else(|| anyhow::anyhow!("unknown architecture {arch}"))?;
        Ok(EnvConfig {
            framework,
            workers,
            batches_per_epoch: 24,
            batch_size: 512,
            lr: 0.05,
            profile,
            grad_mode: GradMode::Virtual,
            seed: 0x5157,
            fault_plan: FaultPlan::none(),
            agg: AggregationRule::Mean,
            sync: SyncMode::Bsp,
            trace: TraceConfig::disabled(),
            store: StoreTierConfig::single(),
        })
    }

    /// Install a fault plan (builder style).
    pub fn with_faults(mut self, plan: FaultPlan) -> EnvConfig {
        self.fault_plan = plan;
        self
    }

    /// Enable protocol-event tracing (builder style).
    pub fn with_trace(mut self, trace: TraceConfig) -> EnvConfig {
        self.trace = trace;
        self
    }

    /// Select the round-synchronization policy (builder style).
    pub fn with_sync(mut self, sync: SyncMode) -> EnvConfig {
        self.sync = sync;
        self
    }

    /// Select the update-aggregation rule (builder style).
    pub fn with_aggregation(mut self, agg: AggregationRule) -> EnvConfig {
        self.agg = agg;
        self
    }

    /// Provision the shared store tier (builder style).
    pub fn with_store(mut self, store: StoreTierConfig) -> EnvConfig {
        self.store = store;
        self
    }

    /// End-to-end config over an executed model (real gradients). The
    /// virtual-time compute model is the full architecture's, scaled to the
    /// reduced parameter count.
    pub fn real(
        framework: FrameworkKind,
        engine: Rc<Engine>,
        model: &str,
        workers: usize,
        train_samples: usize,
        seed: u64,
    ) -> Result<EnvConfig> {
        let entry = engine.manifest.model(model)?.clone();
        let base = calibration::profile(&entry.arch)
            .ok_or_else(|| anyhow::anyhow!("no profile for arch {}", entry.arch))?;
        let profile = calibration::scaled_profile(base, entry.n_params as u64);
        let gen = SyntheticCifar::with_defaults(seed);
        let train = gen.generate(train_samples, 0);
        let test = gen.generate(entry.eval_batch * 4, 1);
        let batch = entry.batch;
        let batches_per_epoch = (train_samples / workers / batch).max(1);
        Ok(EnvConfig {
            framework,
            workers,
            batches_per_epoch,
            batch_size: batch,
            lr: 0.1,
            profile,
            grad_mode: GradMode::Real { engine, model: model.to_string(), train, test },
            seed,
            fault_plan: FaultPlan::none(),
            agg: AggregationRule::Mean,
            sync: SyncMode::Bsp,
            trace: TraceConfig::disabled(),
            store: StoreTierConfig::single(),
        })
    }
}

/// Result of one gradient computation.
#[derive(Debug)]
pub struct GradResult {
    pub grad: Slab,
    pub loss: Option<f64>,
    pub correct: u32,
    /// Virtual seconds the computation took on the configured device.
    pub secs: f64,
}

/// Which device executes gradient compute (drives the duration model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Device {
    LambdaCpu,
    GpuT4,
}

/// The experiment world.
pub struct ClusterEnv {
    pub framework: FrameworkKind,
    pub workers: Vec<WorkerState>,
    pub profile: ModelProfile,
    pub batch_size: usize,
    pub batches_per_epoch: usize,
    pub lr: f32,
    pub n_params: usize,
    pub epoch: usize,

    // Substrates.
    pub lambda: LambdaRuntime,
    /// Shared object store (LambdaML gradient bucket, Lambda data loads).
    pub store: ObjectStore,
    /// GPU-side object store (EC2 bandwidth profile).
    pub gpu_store: ObjectStore,
    pub queues: MessageQueue,
    pub stepfn: StepFunctions,
    /// Per-worker Redis instances (SPIRT's P2P databases).
    pub worker_redis: Vec<Redis>,
    /// Shared store tier (MLLess update store, LambdaML model store): a
    /// consistent-hash cluster of Redis shards. `StoreTierConfig::single()`
    /// makes it behave exactly like the one shared instance it replaced.
    pub shared_redis: RedisCluster,
    pub fleet: GpuFleet,

    // Measurement plane.
    pub ledger: Ledger,
    pub comm: CommStats,
    pub stages: StageTimer,
    pub recovery: RecoveryStats,
    /// Protocol-event log (no-op unless enabled via `EnvConfig::trace`).
    pub trace: TraceCollector,

    // Fault engine + aggregation policy (consulted at the fetch/compute/
    // sync/update boundaries; see the `faults` module).
    pub faults: FaultSchedule,
    pub agg: AggregationRule,
    /// Round-synchronization policy the strategies consult at sync points.
    pub sync: SyncMode,

    grad_mode: GradMode,
    pub rng: Rng,
}

impl ClusterEnv {
    pub fn new(cfg: EnvConfig) -> Result<ClusterEnv> {
        if cfg.workers == 0 {
            bail!("need at least one worker");
        }
        let n_params = match &cfg.grad_mode {
            GradMode::Virtual => cfg.profile.params as usize,
            GradMode::Real { engine, model, .. } => engine.manifest.model(model)?.n_params,
        };

        let rng = Rng::new(cfg.seed);
        let mut workers = Vec::with_capacity(cfg.workers);
        let theta0 = match &cfg.grad_mode {
            GradMode::Virtual => Slab::virtual_of(n_params),
            GradMode::Real { engine, model, .. } => engine.init(model, cfg.seed as u32)?,
        };
        let shards = match &cfg.grad_mode {
            GradMode::Virtual => vec![Vec::new(); cfg.workers],
            GradMode::Real { train, .. } => train.shard_indices(cfg.workers),
        };
        for (id, shard) in shards.into_iter().enumerate() {
            workers.push(WorkerState {
                id,
                clock: VTime::ZERO,
                theta: theta0.clone(),
                shard,
                cursor: 0,
            });
        }

        // SPIRT's per-worker Redis instances get the PJRT in-database math
        // engine in real mode (the RedisAI analog).
        let worker_redis: Vec<Redis> = (0..cfg.workers)
            .map(|i| match &cfg.grad_mode {
                GradMode::Real { engine, model, .. } => Redis::with_math(
                    format!("spirt-w{i}"),
                    std::sync::Arc::new(PjrtMath::new(engine.clone(), model.clone())),
                ),
                GradMode::Virtual => Redis::new(format!("spirt-w{i}")),
            })
            .collect();

        let shared_redis = RedisCluster::new("shared", &cfg.store)?;
        if let Some(max) = cfg.fault_plan.events.iter().filter_map(|ev| {
            matches!(ev.kind, crate::faults::FaultKind::ShardCrash).then_some(ev.worker)
        }).max() {
            if max >= shared_redis.num_shards() {
                bail!(
                    "fault plan crashes shard {max} but the store tier has {} shards",
                    shared_redis.num_shards()
                );
            }
        }

        Ok(ClusterEnv {
            framework: cfg.framework,
            workers,
            profile: cfg.profile,
            batch_size: cfg.batch_size,
            batches_per_epoch: cfg.batches_per_epoch,
            lr: cfg.lr,
            n_params,
            epoch: 0,
            lambda: LambdaRuntime::new(),
            store: ObjectStore::new(),
            gpu_store: ObjectStore::with_profile(
                calibration::GPU_S3_LATENCY,
                calibration::GPU_S3_BW,
                64,
            ),
            queues: MessageQueue::new(),
            stepfn: StepFunctions::new(),
            worker_redis,
            shared_redis,
            fleet: GpuFleet::new(cfg.workers),
            ledger: Ledger::new(),
            comm: CommStats::new(),
            stages: StageTimer::new(),
            recovery: RecoveryStats::new(),
            trace: TraceCollector::new(&cfg.trace),
            faults: FaultSchedule::new(cfg.fault_plan, cfg.workers)?,
            agg: cfg.agg,
            sync: cfg.sync,
            grad_mode: cfg.grad_mode,
            rng: Rng::fork(&rng, 1),
        })
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn is_real(&self) -> bool {
        matches!(self.grad_mode, GradMode::Real { .. })
    }

    /// Gradient payload bytes (f32 × params).
    pub fn grad_bytes(&self) -> u64 {
        self.n_params as u64 * 4
    }

    /// Begin a new epoch: reshuffle shards, bump counter, re-arm the fault
    /// engine's round counters, and fire any store-shard crashes planned
    /// for this epoch (the shard goes down at the cluster-wide clock, loses
    /// its contents, and restarts [`SHARD_RESTART_SECS`] later).
    pub fn begin_epoch(&mut self) {
        self.epoch += 1;
        self.trace.begin_epoch(self.epoch);
        self.faults.begin_epoch(self.epoch);
        // Release substrate busy history the new epoch can no longer
        // touch: every future request arrives at or after the slowest
        // worker's current clock, and `sim::Resource::release` is
        // placement-preserving for such arrivals. Keeps the interval maps
        // (the sweep's dominant allocation at W >= 1024) bounded per
        // epoch instead of growing for the whole run.
        let watermark = self.min_clock();
        self.store.prune_history(watermark);
        self.gpu_store.prune_history(watermark);
        self.shared_redis.prune_history(watermark);
        for r in &mut self.worker_redis {
            r.prune_history(watermark);
        }
        let now = self.max_clock();
        while let Some(shard) = self.faults.crash_shard(now) {
            // Invalid shard ids are rejected at construction; ignore
            // defensively rather than panic mid-run.
            if self.shared_redis.crash_shard(shard, now).is_ok() {
                self.recovery.shard_restarts += 1;
                self.recovery.downtime_secs += SHARD_RESTART_SECS;
                if self.trace.enabled() {
                    use crate::faults::SUPERVISOR;
                    self.trace.span(
                        SUPERVISOR,
                        now,
                        now + SHARD_RESTART_SECS,
                        EventKind::ShardCrash,
                        0,
                        0.0,
                        None,
                    );
                }
            }
        }
        let mut rng = self.rng.fork(0xE70C ^ self.epoch as u64);
        for w in &mut self.workers {
            rng.shuffle(&mut w.shard);
            w.cursor = 0;
        }
    }

    /// Serverless statelessness: re-load model + batch data on invocation.
    /// Advances the worker clock; charges FetchDataset stage time.
    pub fn state_load(&mut self, w: usize) {
        let t0 = self.workers[w].clock;
        let model_load = self.grad_bytes() as f64 / calibration::REDIS_BW
            + calibration::REDIS_LATENCY;
        let data_bytes = (self.batch_size * IMG_ELEMS * 4) as u64;
        let data_load = data_bytes as f64 / calibration::S3_BW + calibration::S3_LATENCY;
        let secs = model_load + data_load;
        self.workers[w].clock += secs;
        self.stages.add(Stage::FetchDataset, secs);
        if self.trace.enabled() {
            let bytes = self.grad_bytes() + data_bytes;
            let t1 = self.workers[w].clock;
            self.trace.span(w, t0, t1, EventKind::StateLoad, bytes, 0.0, None);
        }
    }

    /// Compute one gradient batch for worker `w` on `device`. Advances the
    /// worker clock by the modeled duration; returns the (real or virtual)
    /// gradient. Fault hooks: an active straggler event inflates the
    /// duration; an active poison event corrupts the returned gradient.
    pub fn compute_grad(&mut self, w: usize, device: Device) -> Result<GradResult> {
        let per_sample = match device {
            Device::LambdaCpu => self.profile.lambda_secs_per_sample,
            Device::GpuT4 => self.profile.gpu_secs_per_sample,
        };
        let round = self.faults.note_compute(w);
        let factor = self.faults.compute_factor(w, round, self.workers[w].clock);
        let secs = per_sample * self.batch_size as f64 * factor;
        if factor > 1.0 {
            self.recovery.straggler_secs += secs * (1.0 - 1.0 / factor);
        }

        let mut out = match &self.grad_mode {
            GradMode::Virtual => GradResult {
                grad: Slab::virtual_of(self.n_params),
                loss: None,
                correct: 0,
                secs,
            },
            GradMode::Real { engine, model, train, .. } => {
                let worker = &mut self.workers[w];
                let b = self.batch_size;
                if worker.shard.len() < b {
                    bail!("worker {w} shard smaller than one batch");
                }
                // Wrap the cursor (epoch boundaries are driven by the
                // strategy's batches_per_epoch, not shard exhaustion).
                if worker.cursor + b > worker.shard.len() {
                    worker.cursor = 0;
                }
                let idx = &worker.shard[worker.cursor..worker.cursor + b];
                worker.cursor += b;
                let (x, y) = train.batch(idx);
                let g = engine.grad(model, &worker.theta, &x, &y)?;
                GradResult {
                    grad: g.grads,
                    loss: Some(g.loss as f64),
                    correct: g.correct,
                    secs,
                }
            }
        };
        let mut poisoned = false;
        if let Some(mode) = self.faults.poison(w, round, self.workers[w].clock) {
            mode.apply(&mut out.grad);
            self.recovery.poisoned_grads += 1;
            poisoned = true;
        }
        let t0 = self.workers[w].clock;
        self.workers[w].clock += secs;
        self.stages.add(Stage::ComputeGradients, secs);
        if self.trace.enabled() {
            let t1 = self.workers[w].clock;
            self.trace.span(w, t0, t1, EventKind::Compute, self.grad_bytes(), 0.0, None);
            if factor > 1.0 {
                self.trace.instant(w, t1, EventKind::Straggler);
            }
            if poisoned {
                self.trace.instant(w, t1, EventKind::Poison);
            }
        }
        Ok(out)
    }

    /// Did the fault plan crash `w`'s in-flight invocation (the one whose
    /// gradient was just computed)? Consumes the event when it fires.
    /// Spot preemptions fire through the same gate: the recovery mechanics
    /// (cold start + reload + recompute, billed again) are identical, but a
    /// preemption is counted separately and marked on the supervisor track
    /// so a storm stays legible as one in the event log.
    pub fn crash_in_compute(&mut self, w: usize) -> bool {
        let round = self.faults.current_round(w);
        let now = self.workers[w].clock;
        if self.faults.crash_compute(w, round, now) {
            return true;
        }
        if self.faults.preempted(w, round, now) {
            self.recovery.preemptions += 1;
            if self.trace.enabled() {
                use crate::faults::SUPERVISOR;
                self.trace.instant(SUPERVISOR, now, EventKind::Preemption);
            }
            return true;
        }
        false
    }

    /// Partition reachability gate: if the fault plan has `w` cut off at
    /// its current clock, defer it to the heal time (charged as
    /// synchronization wait) before the protocol op proceeds. Every
    /// `Timeline` communication op consults this first — which is exactly
    /// what makes a partitioned worker's writes, notifies and polls
    /// invisible to its peers until the partition heals, and so what the
    /// visibility/quorum paths observe.
    pub fn partition_gate(&mut self, w: usize) {
        let now = self.workers[w].clock;
        let Some(hit) = self.faults.partition_until(w, now) else {
            return;
        };
        if self.trace.enabled() {
            use crate::faults::SUPERVISOR;
            for (start, heal) in &hit.newly {
                let (s, h) = (VTime::from_secs(*start), VTime::from_secs(*heal));
                self.trace.span(SUPERVISOR, s, h, EventKind::Partition, 0, 0.0, None);
                self.trace.instant(SUPERVISOR, h, EventKind::PartitionHeal);
            }
        }
        let wait = hit.until - now.secs();
        if wait > 0.0 {
            self.recovery.partition_secs += wait;
            self.charge_sync(w, wait);
        }
    }

    /// Platform retry after a compute-phase crash: the worker pays a cold
    /// start (Lambda) or instance reboot (GPU), re-loads state and
    /// recomputes the same round's gradient. The retry is billed as a fresh
    /// invocation; the wasted first attempt stays on the clock (it was
    /// in-flight when it died).
    pub fn recover_invocation(&mut self, w: usize, device: Device) -> Result<GradResult> {
        let t0 = self.workers[w].clock;
        let down = match device {
            Device::LambdaCpu => calibration::LAMBDA_COLD_START,
            Device::GpuT4 => self.fleet.provision_secs,
        };
        self.workers[w].clock += down;
        self.recovery.cold_restarts += 1;
        self.recovery.downtime_secs += down;
        // Emit the downtime span before the retry's own events so the
        // program-order chain runs crash -> reload -> recompute. The retry
        // billing lands after the recompute and stays unattributed here.
        if self.trace.enabled() {
            self.trace.span(w, t0, t0 + down, EventKind::CrashCompute, 0, 0.0, None);
        }
        // The wasted attempt's gradient is discarded; if a poison window is
        // active on this round, the recompute will count it again — undo the
        // discarded attempt's tally so stats reflect delivered gradients.
        let wasted_round = self.faults.current_round(w);
        if self.faults.poison(w, wasted_round, self.workers[w].clock).is_some() {
            self.recovery.poisoned_grads = self.recovery.poisoned_grads.saturating_sub(1);
        }
        // The retry re-runs the same protocol round (and, in real mode, the
        // same batch slice).
        self.faults.redo_round(w);
        if self.is_real() {
            let b = self.batch_size;
            let cursor = &mut self.workers[w].cursor;
            *cursor = cursor.saturating_sub(b);
        }
        if device == Device::LambdaCpu {
            // Stateless function: the retry re-loads model + batch. The GPU
            // baseline's data is already resident on instance disk — its
            // reboot cost is the provisioning time alone.
            self.state_load(w);
        }
        let g = self.compute_grad(w, device)?;
        let retry_secs = self.workers[w].clock - t0;
        self.recovery.invocation_retries += 1;
        if device == Device::LambdaCpu {
            let mb = self.allocated_mb();
            recovery::lambda_retry(retry_secs, mb, &mut self.ledger, &mut self.recovery);
        }
        // GPU: instance time is already billed by epoch wall time; the
        // reboot shows up as a longer (and costlier) epoch.
        Ok(g)
    }

    /// Sync-phase crash hook: if planned for `w` this epoch, the worker
    /// goes down entering synchronization and restarts after a cold start
    /// plus a model snapshot restore (GPU: an instance reboot). In the
    /// barriered storage topologies (AllReduce, ScatterReduce, GPU) the
    /// peers re-poll shared storage while it is away and those requests are
    /// billed; SPIRT peers reroute and MLLess workers wait only on the
    /// supervisor, so neither pays repolls. Returns the downtime added to
    /// `w`'s clock.
    pub fn sync_crash(&mut self, w: usize) -> Option<f64> {
        let now = self.workers[w].clock;
        if !self.faults.crash_sync(w, now) {
            return None;
        }
        let cost0 = if self.trace.enabled() { self.ledger.total_full() } else { 0.0 };
        let waiters = self.num_workers().saturating_sub(1);
        let down = if self.framework == FrameworkKind::GpuBaseline {
            let down = self.fleet.provision_secs;
            recovery::storage_repolls(down, waiters, &mut self.ledger, &mut self.recovery);
            down
        } else {
            let restore = recovery::redis_snapshot_restore(
                self.grad_bytes(),
                &mut self.ledger,
                &mut self.recovery,
            );
            let down = calibration::LAMBDA_COLD_START + restore;
            // The restarted worker function is a fresh billed invocation.
            let mb = self.allocated_mb();
            if self.framework == FrameworkKind::Spirt {
                // SPIRT's sync stage runs after its minibatch invocations
                // were finished/billed: no open span carries the restart,
                // so its duration is billed here in full.
                recovery::lambda_restart_billed(down, mb, &mut self.ledger, &mut self.recovery);
            } else {
                recovery::lambda_retry(down, mb, &mut self.ledger, &mut self.recovery);
            }
            match self.framework {
                // SPIRT reroutes around the dead peer; MLLess peers wait on
                // the supervisor, not on each other: no one polls for `w`.
                FrameworkKind::Spirt | FrameworkKind::MlLess => {}
                _ => recovery::storage_repolls(down, waiters, &mut self.ledger, &mut self.recovery),
            }
            down
        };
        self.workers[w].clock += down;
        self.recovery.cold_restarts += 1;
        self.recovery.downtime_secs += down;
        self.stages.add(Stage::Synchronize, down);
        if self.trace.enabled() {
            let cost = self.ledger.total_full() - cost0;
            let t1 = self.workers[w].clock;
            self.trace.span(w, now, t1, EventKind::CrashSync, 0, cost, None);
        }
        Some(down)
    }

    /// MLLess supervisor crash hook at (current epoch, `round`): returns
    /// the supervisor restart delay (cold start + re-poll of the round's
    /// worker reports), billed as a fresh supervisor invocation.
    pub fn supervisor_crash(&mut self, round: usize, now: VTime) -> Option<f64> {
        if !self.faults.crash_supervisor(round, now) {
            return None;
        }
        let cost0 = if self.trace.enabled() { self.ledger.total_full() } else { 0.0 };
        let down = calibration::LAMBDA_COLD_START;
        let mb = self.allocated_mb();
        recovery::lambda_retry(down, mb, &mut self.ledger, &mut self.recovery);
        recovery::queue_repolls(down, self.num_workers(), &mut self.ledger, &mut self.recovery);
        self.recovery.supervisor_restarts += 1;
        self.recovery.downtime_secs += down;
        if self.trace.enabled() {
            let cost = self.ledger.total_full() - cost0;
            use crate::faults::SUPERVISOR;
            self.trace.span(SUPERVISOR, now, now + down, EventKind::CrashSupervisor, 0, cost, None);
        }
        Some(down)
    }

    /// Is `w`'s most recently computed update dropped by the fault plan?
    pub fn update_dropped(&mut self, w: usize) -> bool {
        let round = self.faults.current_round(w);
        let now = self.workers[w].clock;
        if self.faults.drop_update(w, round, now) {
            self.recovery.dropped_updates += 1;
            self.trace.instant(w, now, EventKind::DropUpdate);
            true
        } else {
            false
        }
    }

    /// Combine worker updates under the configured aggregation rule. The
    /// strategies already charge the plain-mean aggregation time; robust
    /// rules charge their extra slab passes here on `w`'s clock, sized by
    /// the actual payloads (ScatterReduce aggregates chunk-sized slabs).
    pub fn aggregate(&mut self, w: usize, slabs: &[Slab]) -> Result<Slab> {
        let bytes: u64 = slabs.iter().map(|s| s.nbytes()).sum();
        let extra = (self.agg.cost_multiplier() - 1.0) * bytes as f64 / LOCAL_AGG_BW;
        if extra > 0.0 {
            self.charge_sync(w, extra);
        }
        self.agg.apply(slabs)
    }

    /// Apply `theta -= lr * inv_k * gsum` on worker `w`'s replica. In real
    /// mode this runs the fused Pallas `avg_update` artifact; virtual mode
    /// charges the modeled duration only.
    pub fn apply_update(&mut self, w: usize, gsum: &Slab, inv_k: f32) -> Result<()> {
        let secs = 3.0 * gsum.nbytes() as f64 / LOCAL_AGG_BW;
        match &self.grad_mode {
            GradMode::Virtual => {}
            GradMode::Real { engine, model, .. } => {
                let theta = &self.workers[w].theta;
                self.workers[w].theta =
                    engine.avg_update(model, theta, gsum, inv_k, self.lr)?;
            }
        }
        let t0 = self.workers[w].clock;
        self.workers[w].clock += secs;
        self.stages.add(Stage::ModelUpdate, secs);
        if self.trace.enabled() {
            let t1 = self.workers[w].clock;
            self.trace.span(w, t0, t1, EventKind::ApplyUpdate, gsum.nbytes(), 0.0, None);
        }
        Ok(())
    }

    /// Local in-function aggregation duration for summing `k` slabs.
    pub fn local_agg_secs(&self, k: usize) -> f64 {
        k as f64 * self.grad_bytes() as f64 / LOCAL_AGG_BW
    }

    /// Charge `secs` of synchronization wait to worker `w`.
    pub fn charge_sync(&mut self, w: usize, secs: f64) {
        let t0 = self.workers[w].clock;
        self.workers[w].clock += secs;
        self.stages.add(Stage::Synchronize, secs);
        if self.trace.enabled() {
            let t1 = self.workers[w].clock;
            self.trace.span(w, t0, t1, EventKind::SyncWait, 0, 0.0, None);
        }
    }

    /// Virtual barrier across all workers (clocks jump to the max).
    pub fn barrier(&mut self) -> VTime {
        let t = self
            .workers
            .iter()
            .map(|w| w.clock)
            .fold(VTime::ZERO, VTime::max);
        for w in &mut self.workers {
            w.clock = t;
        }
        t
    }

    /// Max worker clock (epoch end time).
    pub fn max_clock(&self) -> VTime {
        self.workers.iter().map(|w| w.clock).fold(VTime::ZERO, VTime::max)
    }

    /// Min worker clock: no future substrate request can arrive before it
    /// (clocks never rewind past an epoch boundary — SPIRT's per-minibatch
    /// clock resets go back only to the current epoch's base). This is the
    /// watermark `begin_epoch` prunes substrate busy history with.
    pub fn min_clock(&self) -> VTime {
        self.workers.iter().map(|w| w.clock).fold(self.max_clock(), VTime::min)
    }

    /// Evaluate test accuracy of worker 0's replica (real mode only).
    pub fn eval_accuracy(&self) -> Result<Option<f64>> {
        let GradMode::Real { engine, model, test, .. } = &self.grad_mode else {
            return Ok(None);
        };
        let entry = engine.manifest.model(model)?;
        let b = entry.eval_batch;
        let theta = &self.workers[0].theta;
        let mut correct = 0u64;
        let mut total = 0u64;
        let batches = test.len() / b;
        for i in 0..batches {
            let idx: Vec<usize> = (i * b..(i + 1) * b).collect();
            let (x, y) = test.batch(&idx);
            let (_, c) = engine.eval(model, theta, &x, &y)?;
            correct += c as u64;
            total += b as u64;
        }
        Ok(Some(correct as f64 / total.max(1) as f64))
    }

    /// Allocated Lambda memory for this framework/model (billing input).
    pub fn allocated_mb(&self) -> f64 {
        calibration::peak_ram_mb(self.framework, &self.profile, self.batch_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn virt_env(workers: usize) -> ClusterEnv {
        ClusterEnv::new(
            EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", workers).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn virtual_env_has_paper_shapes() {
        let env = virt_env(4);
        assert_eq!(env.num_workers(), 4);
        assert_eq!(env.n_params, 4_200_000);
        assert_eq!(env.grad_bytes(), 16_800_000);
        assert_eq!(env.batches_per_epoch, 24);
        assert!(!env.is_real());
    }

    #[test]
    fn compute_grad_charges_device_time() {
        let mut env = virt_env(2);
        let r = env.compute_grad(0, Device::LambdaCpu).unwrap();
        assert!((r.secs - 512.0 * env.profile.lambda_secs_per_sample).abs() < 1e-9);
        assert_eq!(env.workers[0].clock.secs(), r.secs);
        assert_eq!(env.workers[1].clock.secs(), 0.0);
        let g = env.compute_grad(1, Device::GpuT4).unwrap();
        assert!(g.secs < r.secs, "T4 must be faster than Lambda CPU");
        assert_eq!(r.grad.len(), env.n_params);
    }

    #[test]
    fn barrier_aligns_clocks() {
        let mut env = virt_env(3);
        env.charge_sync(1, 5.0);
        let t = env.barrier();
        assert_eq!(t.secs(), 5.0);
        assert!(env.workers.iter().all(|w| w.clock == t));
    }

    #[test]
    fn state_load_charges_fetch_stage() {
        let mut env = virt_env(1);
        env.state_load(0);
        assert!(env.stages.get(Stage::FetchDataset) > 0.05);
        assert!(env.workers[0].clock.secs() > 0.0);
    }

    #[test]
    fn apply_update_virtual_charges_update_stage() {
        let mut env = virt_env(1);
        let g = Slab::virtual_of(env.n_params);
        env.apply_update(0, &g, 0.25).unwrap();
        assert!(env.stages.get(Stage::ModelUpdate) > 0.0);
    }

    #[test]
    fn begin_epoch_reshuffles_deterministically() {
        let mut a = virt_env(2);
        let mut b = virt_env(2);
        a.begin_epoch();
        b.begin_epoch();
        assert_eq!(a.epoch, 1);
        assert_eq!(a.workers[0].shard, b.workers[0].shard);
    }

    #[test]
    fn straggler_inflates_compute_time() {
        let mut plain = virt_env(2);
        let base = plain.compute_grad(0, Device::LambdaCpu).unwrap().secs;

        let cfg = EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 2)
            .unwrap()
            .with_faults(crate::faults::FaultPlan::none().straggler(0, 1, 0, 4.0, Some(1)));
        let mut env = ClusterEnv::new(cfg).unwrap();
        env.begin_epoch();
        let slow = env.compute_grad(0, Device::LambdaCpu).unwrap().secs;
        assert!((slow - 4.0 * base).abs() < 1e-9, "{slow} vs 4x{base}");
        assert!(env.recovery.straggler_secs > 0.0);
        // Window over: next round is back to normal.
        let next = env.compute_grad(0, Device::LambdaCpu).unwrap().secs;
        assert!((next - base).abs() < 1e-9);
    }

    #[test]
    fn compute_crash_fires_and_recovery_bills_retry() {
        let cfg = EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 2)
            .unwrap()
            .with_faults(crate::faults::FaultPlan::none().crash(1, 1, 0));
        let mut env = ClusterEnv::new(cfg).unwrap();
        env.begin_epoch();
        env.compute_grad(1, Device::LambdaCpu).unwrap();
        assert!(env.crash_in_compute(1));
        assert!(!env.crash_in_compute(1), "one-shot");
        let before = env.workers[1].clock;
        env.recover_invocation(1, Device::LambdaCpu).unwrap();
        let stall = env.workers[1].clock - before;
        assert!(
            stall > crate::cloud::calibration::LAMBDA_COLD_START,
            "retry pays cold start + reload + recompute, got {stall}"
        );
        assert_eq!(env.recovery.invocation_retries, 1);
        assert!(env.recovery.cost_usd > 0.0);
        // Worker 0 is untouched.
        assert_eq!(env.workers[0].clock.secs(), 0.0);
    }

    #[test]
    fn drop_and_poison_hooks_count() {
        use crate::faults::{FaultPlan, PoisonMode};
        let cfg = EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 2)
            .unwrap()
            .with_faults(
                FaultPlan::none()
                    .drop_updates(0, 1, 0, Some(1))
                    .poison(1, 1, PoisonMode::SignFlip),
            );
        let mut env = ClusterEnv::new(cfg).unwrap();
        env.begin_epoch();
        env.compute_grad(0, Device::LambdaCpu).unwrap();
        env.compute_grad(1, Device::LambdaCpu).unwrap();
        assert!(env.update_dropped(0));
        assert!(!env.update_dropped(1));
        assert_eq!(env.recovery.dropped_updates, 1);
        assert_eq!(env.recovery.poisoned_grads, 1);
    }

    #[test]
    fn robust_aggregation_charges_extra_time() {
        let cfg = EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 2)
            .unwrap()
            .with_aggregation(crate::tensor::AggregationRule::CoordMedian);
        let mut env = ClusterEnv::new(cfg).unwrap();
        let slabs = vec![Slab::virtual_of(env.n_params), Slab::virtual_of(env.n_params)];
        let before = env.workers[0].clock;
        let out = env.aggregate(0, &slabs).unwrap();
        assert_eq!(out.len(), env.n_params);
        assert!(env.workers[0].clock > before, "median pays extra slab passes");
    }

    #[test]
    fn tracing_is_opt_in_and_observational() {
        let mut plain = virt_env(2);
        let cfg = EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 2)
            .unwrap()
            .with_trace(TraceConfig::on());
        let mut traced = ClusterEnv::new(cfg).unwrap();
        for env in [&mut plain, &mut traced] {
            env.begin_epoch();
            env.state_load(0);
            env.compute_grad(0, Device::LambdaCpu).unwrap();
            let g = Slab::virtual_of(env.n_params);
            env.apply_update(0, &g, 0.5).unwrap();
            env.charge_sync(0, 1.0);
        }
        assert_eq!(
            plain.workers[0].clock.secs().to_bits(),
            traced.workers[0].clock.secs().to_bits(),
            "collector must not perturb the timeline"
        );
        assert!(plain.trace.is_empty(), "tracing stays off by default");
        let kinds: Vec<EventKind> = traced.trace.events().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::StateLoad,
                EventKind::Compute,
                EventKind::ApplyUpdate,
                EventKind::SyncWait
            ]
        );
        assert!(traced.trace.events().all(|e| e.epoch == 1 && e.worker == 0));
    }

    #[test]
    fn shard_crash_fires_at_epoch_top_and_counts() {
        let cfg = EnvConfig::virtual_paper(FrameworkKind::MlLess, "mobilenet", 2)
            .unwrap()
            .with_store(StoreTierConfig::sharded(2, 2))
            .with_faults(crate::faults::FaultPlan::none().shard_crash(1, 1));
        let mut env = ClusterEnv::new(cfg).unwrap();
        assert_eq!(env.shared_redis.num_shards(), 2);
        env.begin_epoch();
        assert_eq!(env.recovery.shard_restarts, 1);
        assert!((env.recovery.downtime_secs - SHARD_RESTART_SECS).abs() < 1e-12);
        env.begin_epoch();
        assert_eq!(env.recovery.shard_restarts, 1, "one-shot");

        // A plan crashing a shard the tier doesn't have is rejected up front.
        let bad = EnvConfig::virtual_paper(FrameworkKind::MlLess, "mobilenet", 2)
            .unwrap()
            .with_faults(crate::faults::FaultPlan::none().shard_crash(3, 1));
        assert!(ClusterEnv::new(bad).is_err());
    }

    #[test]
    fn allocated_memory_uses_framework_model() {
        let env = virt_env(4);
        let mb = env.allocated_mb();
        assert!((mb - 2070.7).abs() < 50.0, "AllReduce/MobileNet ≈ 2048–2090, got {mb}");
    }
}
