//! Tiny CLI argument parser: `prog <subcommand> [--key value] [--flag]`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line: a subcommand plus `--key value` options and flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let value = iter.next().unwrap();
                    args.options.insert(name.to_string(), value);
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(arg);
            } else {
                args.positional.push(arg);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(parts: &[&str]) -> Args {
        Args::parse(parts.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        let a = parse(&["exp", "--model", "mobilenet", "--verbose", "--workers=8", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.get("model"), Some("mobilenet"));
        assert_eq!(a.get("workers"), Some("8"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["x", "--n", "4", "--lr", "0.5"]);
        assert_eq!(a.get_usize("n", 1).unwrap(), 4);
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(parse(&["x", "--n", "nope"]).get_usize("n", 1).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["x", "--quiet"]);
        assert!(a.has_flag("quiet"));
        assert!(a.get("quiet").is_none());
    }
}
