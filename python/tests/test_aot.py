"""AOT artifact integrity: manifest consistency + HLO text executability.

Executes a lowered artifact back through the local PJRT CPU client (the same
xla_client the Rust runtime wraps) to prove the HLO text round-trips.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_manifest_lists_every_file():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    for model in manifest["models"].values():
        for fname in model["artifacts"].values():
            assert os.path.exists(os.path.join(ART, fname)), fname
    for slab in manifest["slabs"].values():
        for fname in slab["artifacts"].values():
            assert os.path.exists(os.path.join(ART, fname)), fname


@needs_artifacts
def test_manifest_sizes_match_models():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    for name, entry in manifest["models"].items():
        _, _, spec = M.build_model(name)
        assert entry["n_params"] == spec["total"]
        assert entry["n_params"] == manifest["slabs"][name]["n"]
    for arch, n in M.PAPER_SIZES.items():
        assert manifest["slabs"][f"{arch}_full"]["n"] == n


def test_hlo_text_well_formed_and_mlir_executes():
    """HLO text is well-formed; the same lowering executes correctly via the
    local PJRT client. (The text->proto->execute round trip itself is covered
    by the Rust runtime integration tests, which load these artifacts.)"""

    def fn(a, b):
        return (a @ b + 1.0,)

    spec = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    assert "f32[8,8]" in text
    # return_tuple=True: the root must be a tuple.
    assert "ROOT tuple" in text

    backend = jax.devices("cpu")[0].client
    exe = backend.compile_and_load(
        str(lowered.compiler_ir("stablehlo")), backend.devices()
    )
    rng = np.random.default_rng(0)
    a = np.asarray(rng.normal(size=(8, 8)), np.float32)
    b = np.asarray(rng.normal(size=(8, 8)), np.float32)
    out = exe.execute_sharded([jax.device_put(a), jax.device_put(b)])
    got = np.asarray(out.disassemble_into_single_device_arrays()[0][0])
    np.testing.assert_allclose(got, a @ b + 1.0, atol=1e-5)


@needs_artifacts
def test_grad_artifact_hlo_mentions_expected_shapes():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    entry = manifest["models"]["mobilenet_s"]
    path = os.path.join(ART, entry["artifacts"]["grad"])
    text = open(path).read()
    n = entry["n_params"]
    b = entry["batch"]
    assert f"f32[{n}]" in text, "flat theta/grad shape missing from HLO"
    assert f"f32[{b},32,32,3]" in text, "batch input shape missing from HLO"
