//! LambdaML ScatterReduce: chunked distributed aggregation (§2, Table 1).
//!
//! Each worker splits its gradient into `W` chunks, keeps chunk `w` and
//! uploads the rest; worker `i` aggregates everyone's chunk `i`, re-uploads
//! the partial aggregate; everyone downloads the `W` partials and
//! reassembles the full mean gradient. Aggregation work is balanced, but
//! the request count grows as `O(W)` per worker per round — which is why
//! AllReduce overtakes it for small models at high worker counts while
//! ScatterReduce wins on large models (Fig. 2).
//!
//! Under [`SyncMode::Async`] each chunk owner reduces over the
//! earliest-visible quorum of incoming chunks instead of all of them. The
//! all-gather still needs every partial (each covers a distinct parameter
//! range), so the chunk *owner's* lateness survives async — a structural
//! property of the topology the scale sweep makes visible.

use crate::cloud::FrameworkKind;
use crate::metrics::Stage;
use crate::tensor::{ChunkPlan, Slab};
use crate::Result;

use super::env::{ClusterEnv, Device};
use super::protocol::{store_quorum, StoreSel, SyncMode};
use super::{EpochStats, Strategy};

#[derive(Debug, Default)]
pub struct ScatterReduce;

impl ScatterReduce {
    pub fn new() -> ScatterReduce {
        ScatterReduce
    }

    /// One chunked synchronization round (factored out for Fig. 2). `round`
    /// seeds the async quorum's tie-rotation only; BSP ignores it.
    ///
    /// Fault semantics: a sync-phase crash makes the crashed worker a late
    /// *chunk owner* — every peer needs its partial aggregate, so all of
    /// them stall behind its restart. A dropped update removes that
    /// worker's gradient (its outgoing chunks and its own kept chunk) from
    /// the round's aggregate. In async mode late *incoming* chunks fall out
    /// of the owner's quorum, but a late owner still stalls the all-gather.
    pub fn sync_round(
        &self,
        env: &mut ClusterEnv,
        round: usize,
        round_tag: &str,
        grads: Vec<Slab>,
    ) -> Result<()> {
        let w_count = env.num_workers();
        let mode = env.sync;
        let plan = ChunkPlan::new(env.n_params, w_count)?;

        // Scatter: worker w uploads chunk j (j != w) for peer j; keeps own.
        let mut own_chunks: Vec<Option<Slab>> = vec![None; w_count];
        let mut dropped = vec![false; w_count];
        for (w, grad) in grads.into_iter().enumerate() {
            let mut tl = env.timeline(w);
            if tl.enter_sync() {
                dropped[w] = true;
                continue;
            }
            let chunks = plan.split(&grad)?;
            for (j, chunk) in chunks.into_iter().enumerate() {
                if j == w {
                    own_chunks[w] = Some(chunk);
                } else {
                    let key = format!("{round_tag}/c{w}to{j}");
                    tl.put(StoreSel::Shared, Stage::Synchronize, &key, chunk);
                }
            }
        }

        // Partial-aggregate keys are shared by the reduce upload, the
        // all-gather and the cleanup below; formatting them once per round
        // keeps the all-gather at O(W) string builds instead of O(W^2).
        let agg_keys: Vec<String> =
            (0..w_count).map(|j| format!("{round_tag}/agg{j}")).collect();

        // Reduce: worker w aggregates everyone's chunk w, uploads partial.
        for w in 0..w_count {
            let mut parts: Vec<Slab> = own_chunks[w].take().into_iter().collect();
            let contrib: Vec<String> = (0..w_count)
                .filter(|&j| j != w && !dropped[j])
                .map(|j| format!("{round_tag}/c{j}to{w}"))
                .collect();
            let picked: Vec<usize> = match mode {
                SyncMode::Bsp => (0..contrib.len()).collect(),
                // The quorum counts the owner's kept chunk too.
                SyncMode::Async { .. } => {
                    store_quorum(env, StoreSel::Shared, &contrib, mode, round + w, parts.len())
                }
            };
            env.comm.stale_skips += (contrib.len() - picked.len()) as u64;
            {
                let mut tl = env.timeline(w);
                for &i in &picked {
                    parts.push(tl.get(StoreSel::Shared, Stage::Synchronize, &contrib[i])?);
                }
            }
            let agg_secs =
                w_count as f64 * (plan.chunk_len(w) as f64 * 4.0) / super::env::LOCAL_AGG_BW;
            env.timeline(w).advance(Stage::Synchronize, agg_secs);
            let partial = if parts.is_empty() {
                // Every contribution to this chunk was dropped: zero update.
                if env.is_real() {
                    Slab::zeros(plan.chunk_len(w))
                } else {
                    Slab::virtual_of(plan.chunk_len(w))
                }
            } else {
                env.aggregate(w, &parts)?
            };
            env.timeline(w).put(StoreSel::Shared, Stage::Synchronize, &agg_keys[w], partial);
        }

        // All-gather: everyone downloads the other partials, reassembles,
        // and applies the full mean gradient. Every partial covers a
        // distinct parameter range, so all W are required in both modes.
        for w in 0..w_count {
            let mut parts: Vec<Slab> = Vec::with_capacity(w_count);
            {
                let mut tl = env.timeline(w);
                for key in &agg_keys {
                    parts.push(tl.get(StoreSel::Shared, Stage::Synchronize, key)?);
                }
            }
            let full = plan.concat(&parts)?;
            env.apply_update(w, &full, 1.0)?;
        }

        // The round's chunks and partials are consumed; free them
        // (timeline-neutral).
        for w in 0..w_count {
            for j in 0..w_count {
                if j != w {
                    env.store.delete(&format!("{round_tag}/c{w}to{j}"));
                }
            }
            env.store.delete(&agg_keys[w]);
        }
        Ok(())
    }
}

impl Strategy for ScatterReduce {
    fn kind(&self) -> FrameworkKind {
        FrameworkKind::ScatterReduce
    }

    fn run_epoch(&mut self, env: &mut ClusterEnv) -> Result<EpochStats> {
        env.begin_epoch();
        let w_count = env.num_workers();
        let start = env.max_clock();
        let alloc_mb = env.allocated_mb();
        let mut loss_sum = 0.0;
        let mut loss_n = 0usize;

        for round in 0..env.batches_per_epoch {
            env.trace.set_round(round);
            let tag = format!("e{}/r{}", env.epoch, round);
            let mut invs = Vec::with_capacity(w_count);
            let mut grads = Vec::with_capacity(w_count);
            for w in 0..w_count {
                let inv = env.lambda.begin_invocation(env.workers[w].clock, w);
                env.workers[w].clock = inv.body_start;
                invs.push(inv);
                env.state_load(w);
                let mut g = env.compute_grad(w, Device::LambdaCpu)?;
                if env.crash_in_compute(w) {
                    g = env.recover_invocation(w, Device::LambdaCpu)?;
                }
                if let Some(l) = g.loss {
                    loss_sum += l;
                    loss_n += 1;
                }
                grads.push(g.grad);
            }

            self.sync_round(env, round, &tag, grads)?;

            let overhead = self.kind().batch_overhead();
            for w in 0..w_count {
                env.charge_sync(w, overhead);
                let end = env.workers[w].clock;
                env.lambda.finish_invocation(invs[w], end, alloc_mb, &mut env.ledger);
            }
        }

        let epoch_secs = env.max_clock() - start;
        Ok(EpochStats {
            mean_loss: (loss_n > 0).then(|| loss_sum / loss_n as f64),
            batches: env.batches_per_epoch * w_count,
            epoch_secs,
            mean_fn_secs: env.lambda.mean_duration(),
        })
    }

    fn stage_table(&self) -> Vec<(Stage, &'static str)> {
        vec![
            (Stage::FetchDataset, "Each worker fetches a minibatch to process."),
            (
                Stage::ComputeGradients,
                "Gradients are computed and divided into chunks, one per peer; workers retain \
                 one chunk and send the rest to the database.",
            ),
            (
                Stage::Synchronize,
                "Workers fetch chunks assigned to them, aggregate, send the result back, then \
                 retrieve and concatenate all aggregated chunks to form the full gradient.",
            ),
            (Stage::ModelUpdate, "The full aggregated gradient is used to update the model."),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::env::EnvConfig;

    fn env(workers: usize, arch: &str) -> ClusterEnv {
        ClusterEnv::new(
            EnvConfig::virtual_paper(FrameworkKind::ScatterReduce, arch, workers).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn epoch_matches_paper_batch_duration() {
        let mut e = env(4, "mobilenet");
        let stats = ScatterReduce::new().run_epoch(&mut e).unwrap();
        assert!(
            (stats.mean_fn_secs - 14.343).abs() / 14.343 < 0.15,
            "mean fn {:.2}s vs paper 14.343s",
            stats.mean_fn_secs
        );
    }

    #[test]
    fn chunk_traffic_is_balanced() {
        // Unlike AllReduce there is no single hot worker: clocks end close.
        let mut e = env(4, "resnet18");
        ScatterReduce::new().run_epoch(&mut e).unwrap();
        let clocks: Vec<f64> = e.workers.iter().map(|w| w.clock.secs()).collect();
        let max = clocks.iter().cloned().fold(0.0, f64::max);
        let min = clocks.iter().cloned().fold(f64::MAX, f64::min);
        assert!((max - min) / max < 0.05, "imbalance: {clocks:?}");
    }

    #[test]
    fn request_count_grows_with_workers() {
        let mut a = env(4, "mobilenet");
        ScatterReduce::new().run_epoch(&mut a).unwrap();
        let mut b = env(8, "mobilenet");
        ScatterReduce::new().run_epoch(&mut b).unwrap();
        // ops per worker per round ~ 3(W-1)+1: grows superlinearly in total
        assert!(b.comm.total_ops() > 2 * a.comm.total_ops());
    }

    #[test]
    fn async_thins_the_chunk_barrier() {
        let mut bsp = env(8, "mobilenet");
        let b = ScatterReduce::new().run_epoch(&mut bsp).unwrap();
        let cfg = EnvConfig::virtual_paper(FrameworkKind::ScatterReduce, "mobilenet", 8)
            .unwrap()
            .with_sync(SyncMode::Async { staleness: 2 });
        let mut asy = ClusterEnv::new(cfg).unwrap();
        let a = ScatterReduce::new().run_epoch(&mut asy).unwrap();

        // Each chunk owner reduces over 6 of 8 contributions: fewer GETs
        // and 2 skips per owner per round.
        assert_eq!(asy.comm.stale_skips, 2 * 8 * 24);
        use crate::metrics::CommKind;
        assert!(asy.comm.ops(CommKind::Get) < bsp.comm.ops(CommKind::Get));
        // The all-gather still serializes on partials, so async helps less
        // than in AllReduce — but it must not be slower.
        assert!(a.epoch_secs <= b.epoch_secs, "async {} vs bsp {}", a.epoch_secs, b.epoch_secs);
    }
}
