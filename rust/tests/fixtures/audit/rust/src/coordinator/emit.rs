//! Fixture: trace-emit confinement — one rogue construction, one
//! multi-line emit covered by a single statement-scoped allow.

pub fn rogue() {
    let _ = EventKind::Poll;
}

pub fn sanctioned_multiline() {
    // audit:allow(trace-emit, fixture - multi-line span covered by one annotation)
    let _idx = trace.span(
        SUPERVISOR,
        t0,
        t,
        EventKind::Notify,
        0,
    );
}
