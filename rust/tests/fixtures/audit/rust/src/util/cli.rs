//! Fixture: util::cli is the one sanctioned ambient-state reader.

pub fn argv() -> Vec<String> {
    std::env::args().collect()
}
