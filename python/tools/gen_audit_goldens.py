#!/usr/bin/env python3
"""Regenerate the audit golden files from the fixture mini-repo.

The goldens under ``rust/tests/golden/`` are what `rust/tests/audit.rs`
compares the Rust auditor's output against; producing them with
``audit.py`` makes the byte-identity of the two implementations part of
the test suite rather than a CI-only property.

Usage: ``python3 python/tools/gen_audit_goldens.py``
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import audit  # noqa: E402
from report_replica import report_json, report_text  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
FIXTURE = os.path.join(REPO, "rust", "tests", "fixtures", "audit")
GOLDEN = os.path.join(REPO, "rust", "tests", "golden")


def main():
    ws = audit.workspace_from_disk(FIXTURE)
    result = audit.run(ws)
    r = audit.render(result)
    for name, contents in [
        ("audit_fixture.txt", report_text(r)),
        ("audit_fixture.json", report_json(r)),
    ]:
        path = os.path.join(GOLDEN, name)
        with open(path, "w", encoding="utf-8", newline="") as f:
            f.write(contents)
        print(f"wrote {os.path.relpath(path, REPO)} ({len(contents)} bytes)")


if __name__ == "__main__":
    main()
