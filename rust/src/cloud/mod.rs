//! Simulated AWS substrates.
//!
//! The paper ran on AWS Lambda + RedisAI-on-EC2 + S3 + RabbitMQ + Step
//! Functions + g4dn GPU instances. None of that is available here, so each
//! managed service is rebuilt as an in-process substrate: real data
//! structures hold real bytes (gradients actually move, in-database ops
//! actually compute), while *time* is charged to virtual clocks from
//! calibrated latency/bandwidth models and *money* into the [`crate::metrics::Ledger`]
//! from the public AWS rate card. See DESIGN.md §2 for the substitution
//! table and why each one preserves the paper's behaviour.

pub mod calibration;
pub mod cluster;
pub mod ec2;
pub mod lambda;
pub mod object_store;
pub mod pricing;
pub mod queue;
pub mod recovery;
pub mod redis;
pub mod step_functions;

pub use calibration::{FrameworkKind, ModelProfile};
pub use cluster::{RedisCluster, ShardReport, ShardStats, StoreTierConfig};
pub use ec2::GpuFleet;
pub use lambda::LambdaRuntime;
pub use object_store::ObjectStore;
pub use queue::MessageQueue;
pub use redis::Redis;
pub use step_functions::StepFunctions;
