//! Redis/RedisAI substrate: KV tensor store + in-database computation.
//!
//! SPIRT hosts one RedisAI instance per worker and pushes the gradient math
//! *into* the database (AI.TENSORSET + scripted averaging/SGD), so slabs
//! never cross the network during aggregation — the paper measures this as
//! 67.32→37.41 s averaging and 27.5→4.8 s updates vs a naive
//! fetch-update-store loop (§4.2). This substrate reproduces both paths:
//!
//! * network ops (`set`/`get`) charge latency + bytes/bandwidth and move
//!   real slabs in and out;
//! * in-DB ops (`acc_in_db`, `avg_update_in_db`) run a [`SlabMath`] engine
//!   *inside* the store — on the end-to-end path that engine is the PJRT
//!   executable of the fused Pallas kernel (`runtime::PjrtMath`), the
//!   faithful RedisAI analog — and charge only the in-instance throughput.
//!
//! Redis command processing is single-threaded: one queueing server, so
//! concurrent clients serialize exactly like a real instance.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::metrics::{CommKind, CommStats, Ledger};
use crate::sim::{Resource, VTime};
use crate::tensor::{RustMath, Slab, SlabMath};

use super::calibration::{
    CLIENT_TENSOR_BW, INDB_UPDATE_BW, REDIS_BW, REDIS_INDB_BW, REDIS_LATENCY, TORCH_REBUILD_BW,
};

/// One Redis/RedisAI instance.
pub struct Redis {
    name: String,
    store: HashMap<String, (Slab, VTime)>,
    cmd: Resource, // single-threaded command loop (network transfers)
    /// RedisAI executes scripted tensor ops on a background worker thread
    /// (AI.SCRIPTEXEC threadpool) — the command loop stays responsive while
    /// accumulation chains run, matching RedisAI's actual architecture.
    script_engine: Resource,
    math: Arc<dyn SlabMath>,
    latency: f64,
    net_bw: f64,
    indb_bw: f64,
}

impl std::fmt::Debug for Redis {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Redis")
            .field("name", &self.name)
            .field("keys", &self.store.len())
            .finish()
    }
}

impl Redis {
    pub fn new(name: impl Into<String>) -> Redis {
        Redis::with_math(name, Arc::new(RustMath))
    }

    /// Install the in-database math engine (PJRT-backed on the e2e path).
    pub fn with_math(name: impl Into<String>, math: Arc<dyn SlabMath>) -> Redis {
        Redis {
            name: name.into(),
            store: HashMap::new(),
            cmd: Resource::new("redis-cmd", 1),
            script_engine: Resource::new("redisai-scripts", 1),
            math,
            latency: REDIS_LATENCY,
            net_bw: REDIS_BW,
            indb_bw: REDIS_INDB_BW,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// SET: transfer the slab over the network into the store. Per-op
    /// latency is client-side RTT; only the transfer occupies the command
    /// loop.
    pub fn set(&mut self, now: VTime, key: &str, slab: Slab, comm: &mut CommStats) -> VTime {
        let bytes = slab.nbytes();
        let done = self.cmd.serve(now + self.latency, bytes as f64 / self.net_bw).end;
        self.store.insert(key.to_string(), (slab, done));
        comm.record(CommKind::Put, bytes);
        comm.comm_time += done - now;
        done
    }

    /// GET: transfer the slab out (waits for visibility).
    pub fn get(&mut self, now: VTime, key: &str, comm: &mut CommStats) -> Result<(VTime, Slab)> {
        let (slab, visible) = self
            .store
            .get(key)
            .ok_or_else(|| anyhow!("redis[{}]: missing key {key}", self.name))?
            .clone();
        let start = now.max(visible) + self.latency;
        let done = self.cmd.serve(start, slab.nbytes() as f64 / self.net_bw).end;
        comm.record(CommKind::Get, slab.nbytes());
        comm.comm_time += done - now;
        Ok((done, slab))
    }

    /// Client-side tensor GET (tensorget → numpy conversion in a Python
    /// function — the naive fetch-update-store path of §4.2).
    pub fn get_tensor_client(
        &mut self,
        now: VTime,
        key: &str,
        comm: &mut CommStats,
    ) -> Result<(VTime, Slab)> {
        let (slab, visible) = self.peek(key)?;
        let start = now.max(visible) + self.latency;
        let done = self.cmd.serve(start, slab.nbytes() as f64 / CLIENT_TENSOR_BW).end;
        comm.record(CommKind::Get, slab.nbytes());
        comm.comm_time += done - now;
        Ok((done, slab))
    }

    /// Client-side tensor SET (numpy → tensorset from a Python function).
    pub fn set_tensor_client(
        &mut self,
        now: VTime,
        key: &str,
        slab: Slab,
        comm: &mut CommStats,
    ) -> VTime {
        let bytes = slab.nbytes();
        let done = self.cmd.serve(now + self.latency, bytes as f64 / CLIENT_TENSOR_BW).end;
        self.store.insert(key.to_string(), (slab, done));
        comm.record(CommKind::Put, bytes);
        comm.comm_time += done - now;
        done
    }

    /// Client-side model rebuild: torch.load + state_dict copy after a
    /// fetch. Pure client time (no Redis server involvement).
    pub fn rebuild_secs(bytes: u64) -> f64 {
        bytes as f64 / TORCH_REBUILD_BW
    }

    /// Earliest time `key` is visible.
    pub fn visible_at(&self, key: &str) -> Option<VTime> {
        self.store.get(key).map(|(_, t)| *t)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.store.contains_key(key)
    }

    /// In-DB `dst = src_acc + w * src_g` (AI script). Bytes never leave the
    /// instance; duration uses in-instance throughput over 3 slab passes.
    pub fn acc_in_db(
        &mut self,
        now: VTime,
        dst: &str,
        src_acc: &str,
        src_g: &str,
        w: f32,
        comm: &mut CommStats,
    ) -> Result<VTime> {
        let (acc, v1) = self.peek(src_acc)?;
        let (g, v2) = self.peek(src_g)?;
        let out = self.math.acc(&acc, &g, w)?;
        let bytes = 3 * out.nbytes();
        let start = now.max(v1).max(v2) + self.latency;
        let done = self.script_engine.serve(start, bytes as f64 / self.indb_bw).end;
        self.store.insert(dst.to_string(), (out, done));
        comm.record(CommKind::InDb, bytes);
        Ok(done)
    }

    /// In-DB `dst = w * src` (scripted scaling — SPIRT's in-database
    /// gradient averaging: `avg = gsum / k` without leaving the instance).
    pub fn scale_in_db(
        &mut self,
        now: VTime,
        dst: &str,
        src: &str,
        w: f32,
        comm: &mut CommStats,
    ) -> Result<VTime> {
        let (src_slab, visible) = self.peek(src)?;
        let out = self.math.acc(&src_slab.zeros_like(), &src_slab, w)?;
        let bytes = 2 * out.nbytes();
        let start = now.max(visible) + self.latency;
        let done = self.script_engine.serve(start, bytes as f64 / self.indb_bw).end;
        self.store.insert(dst.to_string(), (out, done));
        comm.record(CommKind::InDb, bytes);
        Ok(done)
    }

    /// In-DB fused `theta = theta - lr * inv_k * gsum` (SPIRT model update).
    pub fn avg_update_in_db(
        &mut self,
        now: VTime,
        theta_key: &str,
        gsum_key: &str,
        inv_k: f32,
        lr: f32,
        comm: &mut CommStats,
    ) -> Result<VTime> {
        let (theta, v1) = self.peek(theta_key)?;
        let (gsum, v2) = self.peek(gsum_key)?;
        let out = self.math.avg_update(&theta, &gsum, inv_k, lr)?;
        let bytes = 3 * out.nbytes();
        let start = now.max(v1).max(v2);
        // TorchScript SGD is slower than a scripted buffer add (§4.2: 4.8 s
        // for a 46.8 MB model).
        let done = self
            .script_engine
            .serve(start + self.latency, bytes as f64 / INDB_UPDATE_BW)
            .end;
        self.store.insert(theta_key.to_string(), (out, done));
        comm.record(CommKind::InDb, bytes);
        Ok(done)
    }

    /// Value + visibility without timeline effects (internal).
    fn peek(&self, key: &str) -> Result<(Slab, VTime)> {
        self.store
            .get(key)
            .cloned()
            .ok_or_else(|| anyhow!("redis[{}]: missing key {key}", self.name))
    }

    /// Read a stored slab without modeling a transfer (test/assert helper).
    pub fn peek_slab(&self, key: &str) -> Result<Slab> {
        Ok(self.peek(key)?.0)
    }

    pub fn delete(&mut self, key: &str) {
        self.store.remove(key);
    }

    pub fn clear(&mut self) {
        self.store.clear();
        self.cmd.reset();
        self.script_engine.reset();
    }

    /// Bill the hosting EC2 instance for the experiment duration (the paper
    /// excludes this; we track it under `CostKind::Ec2Redis`).
    pub fn bill_hosting(&self, duration: f64, ledger: &mut Ledger) {
        ledger.charge(
            crate::metrics::CostKind::Ec2Redis,
            super::pricing::redis_host_cost(duration, 1),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut r = Redis::new("w0");
        let mut c = CommStats::new();
        let t1 = r.set(VTime::ZERO, "g", Slab::from_vec(vec![1.0, 2.0]), &mut c);
        let (t2, s) = r.get(t1, "g", &mut c).unwrap();
        assert!(t2 > t1);
        assert_eq!(s.as_slice().unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn indb_acc_computes_real_math() {
        let mut r = Redis::new("w0");
        let mut c = CommStats::new();
        r.set(VTime::ZERO, "acc", Slab::from_vec(vec![1.0, 1.0]), &mut c);
        r.set(VTime::ZERO, "g", Slab::from_vec(vec![2.0, 4.0]), &mut c);
        r.acc_in_db(VTime::from_secs(1.0), "acc", "acc", "g", 0.5, &mut c).unwrap();
        let out = r.peek_slab("acc").unwrap();
        assert_eq!(out.as_slice().unwrap(), &[2.0, 3.0]);
        assert!(c.bytes(CommKind::InDb) > 0);
    }

    #[test]
    fn indb_avg_update_applies_fused_step() {
        let mut r = Redis::new("w0");
        let mut c = CommStats::new();
        r.set(VTime::ZERO, "theta", Slab::from_vec(vec![1.0]), &mut c);
        r.set(VTime::ZERO, "gsum", Slab::from_vec(vec![4.0]), &mut c);
        r.avg_update_in_db(VTime::from_secs(1.0), "theta", "gsum", 0.25, 0.1, &mut c)
            .unwrap();
        let theta = r.peek_slab("theta").unwrap();
        assert!((theta.as_slice().unwrap()[0] - 0.9).abs() < 1e-6);
    }

    #[test]
    fn indb_is_faster_than_fetch_update_store() {
        // The §4.2 contrast: the naive path round-trips tensors through a
        // Python client (tensorget → numpy → tensorset); the in-DB path
        // runs one scripted op on identically sized slabs.
        let n = 2_000_000; // 8 MB
        let mut c = CommStats::new();

        let mut naive = Redis::new("naive");
        naive.set(VTime::ZERO, "acc", Slab::virtual_of(n), &mut c);
        naive.set(VTime::ZERO, "g", Slab::virtual_of(n), &mut c);
        let t0 = VTime::from_secs(1.0);
        let (t1, _) = naive.get_tensor_client(t0, "acc", &mut c).unwrap();
        let (t2, _) = naive.get_tensor_client(t1, "g", &mut c).unwrap();
        let t_naive = naive.set_tensor_client(t2, "acc", Slab::virtual_of(n), &mut c) - t0;

        // In-DB: one scripted op.
        let mut indb = Redis::new("indb");
        indb.set(VTime::ZERO, "acc", Slab::virtual_of(n), &mut c);
        indb.set(VTime::ZERO, "g", Slab::virtual_of(n), &mut c);
        let t_indb =
            indb.acc_in_db(t0, "acc", "acc", "g", 1.0, &mut c).unwrap() - t0;

        assert!(
            t_indb < t_naive * 0.75,
            "in-DB {t_indb:.3}s should beat naive {t_naive:.3}s"
        );
    }

    #[test]
    fn paper_4_2_averaging_times_reproduce() {
        // ResNet-18 (46.8 MB), 24 minibatch accumulations per epoch.
        let n = 11_700_000;
        let mut c = CommStats::new();

        // Naive: each stateless function fetches acc + grad, stores acc.
        let mut naive = Redis::new("naive");
        naive.set(VTime::ZERO, "acc", Slab::virtual_of(n), &mut c);
        naive.set(VTime::ZERO, "g", Slab::virtual_of(n), &mut c);
        let mut t = VTime::from_secs(0.0);
        let start = t;
        for _ in 0..24 {
            let (t1, _) = naive.get_tensor_client(t, "acc", &mut c).unwrap();
            let (t2, _) = naive.get_tensor_client(t1, "g", &mut c).unwrap();
            t = naive.set_tensor_client(t2, "acc", Slab::virtual_of(n), &mut c);
        }
        let naive_secs = t - start;
        assert!((naive_secs - 67.32).abs() / 67.32 < 0.05, "naive {naive_secs:.1}s vs 67.32");

        // In-DB: 24 scripted accumulations.
        let mut indb = Redis::new("indb");
        indb.set(VTime::ZERO, "gsum", Slab::virtual_of(n), &mut c);
        indb.set(VTime::ZERO, "g", Slab::virtual_of(n), &mut c);
        let mut t = VTime::from_secs(0.0);
        let start = t;
        for _ in 0..24 {
            t = indb.acc_in_db(t, "gsum", "gsum", "g", 1.0, &mut c).unwrap();
        }
        let indb_secs = t - start;
        assert!((indb_secs - 37.41).abs() / 37.41 < 0.05, "in-DB {indb_secs:.1}s vs 37.41");
    }

    #[test]
    fn paper_4_2_update_times_reproduce() {
        // ResNet-18 model update: naive (fetch theta+gsum, rebuild
        // state_dict, store) vs in-DB fused TorchScript SGD.
        let n = 11_700_000;
        let bytes = 4 * n as u64;
        let mut c = CommStats::new();

        let mut r = Redis::new("upd");
        r.set(VTime::ZERO, "theta", Slab::virtual_of(n), &mut c);
        r.set(VTime::ZERO, "gsum", Slab::virtual_of(n), &mut c);

        let t0 = VTime::from_secs(0.0);
        let (t1, _) = r.get_tensor_client(t0, "theta", &mut c).unwrap();
        let (t2, _) = r.get_tensor_client(t1, "gsum", &mut c).unwrap();
        let t3 = t2 + Redis::rebuild_secs(bytes);
        let t_naive = r.set_tensor_client(t3, "theta", Slab::virtual_of(n), &mut c) - t0;
        assert!((t_naive - 27.5).abs() / 27.5 < 0.10, "naive update {t_naive:.1}s vs 27.5");

        let t_indb = r
            .avg_update_in_db(VTime::from_secs(100.0), "theta", "gsum", 1.0, 0.1, &mut c)
            .unwrap()
            - VTime::from_secs(100.0);
        assert!((t_indb - 4.8).abs() / 4.8 < 0.10, "in-DB update {t_indb:.2}s vs 4.8");
    }

    #[test]
    fn single_threaded_commands_serialize() {
        let mut r = Redis::new("w0");
        let mut c = CommStats::new();
        let big = Slab::virtual_of(30_000_000); // 120 MB -> 0.4 s at 300 MB/s
        let t_a = r.set(VTime::ZERO, "a", big.clone(), &mut c);
        let t_b = r.set(VTime::ZERO, "b", big, &mut c);
        assert!(t_b.secs() > t_a.secs() + 0.3, "second client must queue");
    }

    #[test]
    fn missing_keys_error() {
        let mut r = Redis::new("w0");
        let mut c = CommStats::new();
        assert!(r.get(VTime::ZERO, "x", &mut c).is_err());
        assert!(r.acc_in_db(VTime::ZERO, "d", "a", "b", 1.0, &mut c).is_err());
    }
}
