"""Model zoo correctness: shapes, flat ABI round-trip, gradient sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import params as P
from compile.models import ARCHS


def _data(batch, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(batch, 32, 32, 3)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, batch), jnp.int32)
    return x, y


@pytest.mark.parametrize("name", list(M.MODEL_CONFIGS))
def test_apply_shapes(name):
    init, apply, spec = M.build_model(name)
    params = init(jax.random.PRNGKey(0))
    x, _ = _data(4)
    logits = apply(params, x)
    assert logits.shape == (4, M.NUM_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", list(M.MODEL_CONFIGS))
def test_flat_roundtrip(name):
    init, _, spec = M.build_model(name)
    params = init(jax.random.PRNGKey(1))
    vec = P.tree_to_vec(params)
    assert vec.shape == (spec["total"],)
    back = P.vec_to_tree(vec, spec)
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", list(M.MODEL_CONFIGS))
def test_grad_fn_signature_and_descent(name):
    """One SGD step on the flat ABI must reduce loss on the same batch."""
    grad_fn = jax.jit(M.make_grad_fn(name))
    theta = M.make_init_fn(name)(jnp.uint32(0))[0]
    x, y = _data(M.MODEL_CONFIGS[name]["batch"])
    loss0, g, correct = grad_fn(theta, x, y)
    assert g.shape == theta.shape
    assert 0.0 <= float(correct) <= x.shape[0]
    assert np.isfinite(float(loss0))
    # Step size normalized by the gradient norm so the descent check is
    # robust across architectures (resnet grads are ~2x larger).
    step = 0.1 / max(1.0, float(jnp.linalg.norm(g)))
    loss1, _, _ = grad_fn(theta - step * g, x, y)
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("name", list(M.MODEL_CONFIGS))
def test_eval_matches_grad_forward(name):
    """eval(theta) loss must equal grad(theta) loss (same fwd graph)."""
    grad_fn = jax.jit(M.make_grad_fn(name))
    eval_fn = jax.jit(M.make_eval_fn(name))
    theta = M.make_init_fn(name)(jnp.uint32(3))[0]
    x, y = _data(M.MODEL_CONFIGS[name]["batch"], seed=5)
    loss_g, _, corr_g = grad_fn(theta, x, y)
    loss_e, corr_e = eval_fn(theta, x, y)
    np.testing.assert_allclose(float(loss_g), float(loss_e), rtol=1e-5)
    assert float(corr_g) == float(corr_e)


def test_init_is_deterministic_per_seed():
    f = M.make_init_fn("mobilenet_s")
    a = np.asarray(f(jnp.uint32(7))[0])
    b = np.asarray(f(jnp.uint32(7))[0])
    c = np.asarray(f(jnp.uint32(8))[0])
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_resnet50_instantiates_small():
    """Fig. 2's large model must at least build + run at reduced width."""
    init, apply = ARCHS["resnet50"](width=0.125, num_classes=10)
    params = init(jax.random.PRNGKey(0))
    x, _ = _data(2)
    logits = apply(params, x)
    assert logits.shape == (2, 10)


def test_paper_sizes_ordering():
    """Gradient payloads must order mobilenet < resnet18 < resnet50."""
    s = M.PAPER_SIZES
    assert s["mobilenet"] < s["resnet18"] < s["resnet50"]


@pytest.mark.parametrize("arch,width,lo,hi", [
    ("mobilenet", 1.0, 3_000_000, 5_000_000),
    ("resnet18", 1.0, 10_000_000, 13_000_000),
])
def test_fullwidth_param_counts_near_paper(arch, width, lo, hi):
    """Full-width zoo models land near the paper's reported sizes."""
    init, _ = ARCHS[arch](width=width, num_classes=10)
    n = P.param_count(init)
    assert lo <= n <= hi, f"{arch}: {n}"
