//! Synthetic CIFAR-10 stand-in (see DESIGN.md substitution table).
//!
//! The real CIFAR-10 pixels are unavailable offline; the convergence
//! experiments need a *learnable 10-class 32×32×3 image task*, not those
//! exact pixels. Each class gets a smooth random template (low-frequency
//! noise upsampled 8×8 → 32×32); a sample is its class template plus
//! per-sample Gaussian noise and a random circular shift. CNNs learn this
//! task the way they learn CIFAR — conv features pick up the class
//! textures — and accuracy-vs-time curves keep the paper's shape
//! (EXPERIMENTS.md reports this substitution with every result).

use crate::util::rng::Rng;

pub const IMG_H: usize = 32;
pub const IMG_W: usize = 32;
pub const IMG_C: usize = 3;
pub const IMG_ELEMS: usize = IMG_H * IMG_W * IMG_C;
pub const NUM_CLASSES: usize = 10;

/// An owned dataset: sample-major contiguous images + labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    images: Vec<f32>, // n * IMG_ELEMS, NHWC
    labels: Vec<i32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * IMG_ELEMS..(i + 1) * IMG_ELEMS]
    }

    pub fn label(&self, i: usize) -> i32 {
        self.labels[i]
    }

    /// Materialize a batch from sample indices (contiguous NHWC + labels).
    pub fn batch(&self, indices: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(indices.len() * IMG_ELEMS);
        let mut y = Vec::with_capacity(indices.len());
        for &i in indices {
            x.extend_from_slice(self.image(i));
            y.push(self.label(i));
        }
        (x, y)
    }

    /// Contiguous index ranges per worker (even split, remainder forward).
    pub fn shard_indices(&self, workers: usize) -> Vec<Vec<usize>> {
        assert!(workers > 0);
        let n = self.len();
        let base = n / workers;
        let extra = n % workers;
        let mut out = Vec::with_capacity(workers);
        let mut start = 0;
        for w in 0..workers {
            let len = base + usize::from(w < extra);
            out.push((start..start + len).collect());
            start += len;
        }
        out
    }
}

/// Generator parameters for the synthetic task.
#[derive(Debug, Clone, Copy)]
pub struct TaskSpec {
    /// Template amplitude (signal strength).
    pub signal: f32,
    /// Per-sample Gaussian noise σ.
    pub noise: f32,
    /// Max circular shift in pixels (augmentation-like variation).
    pub max_shift: usize,
}

impl Default for TaskSpec {
    fn default() -> Self {
        // Signal-to-noise chosen so a small CNN reaches >80% within a few
        // hundred optimizer steps but does not solve the task instantly
        // (the testbed has a single CPU core; CIFAR-scale epoch counts are
        // out of budget — DESIGN.md documents the substitution).
        TaskSpec { signal: 1.0, noise: 0.45, max_shift: 2 }
    }
}

/// Deterministic synthetic CIFAR generator.
#[derive(Debug)]
pub struct SyntheticCifar {
    templates: Vec<Vec<f32>>, // NUM_CLASSES × IMG_ELEMS
    spec: TaskSpec,
    seed: u64,
}

impl SyntheticCifar {
    pub fn new(seed: u64, spec: TaskSpec) -> SyntheticCifar {
        let mut templates = Vec::with_capacity(NUM_CLASSES);
        for class in 0..NUM_CLASSES {
            templates.push(make_template(seed, class, spec.signal));
        }
        SyntheticCifar { templates, spec, seed }
    }

    pub fn with_defaults(seed: u64) -> SyntheticCifar {
        SyntheticCifar::new(seed, TaskSpec::default())
    }

    /// Generate `n` samples under a stream label (train/test get different
    /// streams from the same generator seed).
    pub fn generate(&self, n: usize, stream: u64) -> Dataset {
        let mut rng = Rng::new(self.seed).fork(0x5EED ^ stream);
        let mut images = Vec::with_capacity(n * IMG_ELEMS);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.below(NUM_CLASSES as u64) as usize;
            let dy = rng.below(2 * self.spec.max_shift as u64 + 1) as isize
                - self.spec.max_shift as isize;
            let dx = rng.below(2 * self.spec.max_shift as u64 + 1) as isize
                - self.spec.max_shift as isize;
            let template = &self.templates[class];
            for h in 0..IMG_H {
                for w in 0..IMG_W {
                    let sh = (h as isize + dy).rem_euclid(IMG_H as isize) as usize;
                    let sw = (w as isize + dx).rem_euclid(IMG_W as isize) as usize;
                    for c in 0..IMG_C {
                        let v = template[(sh * IMG_W + sw) * IMG_C + c]
                            + rng.normal_f32(0.0, self.spec.noise);
                        images.push(v);
                    }
                }
            }
            labels.push(class as i32);
        }
        Dataset { images, labels }
    }
}

/// Smooth class template: 8×8 Gaussian field bilinearly upsampled to 32×32.
fn make_template(seed: u64, class: usize, signal: f32) -> Vec<f32> {
    const G: usize = 8;
    let mut rng = Rng::new(seed).fork(0x7E3Au64 ^ class as u64);
    let mut coarse = [[0f32; 3]; G * G];
    for cell in coarse.iter_mut() {
        for ch in cell.iter_mut() {
            *ch = rng.normal_f32(0.0, signal);
        }
    }
    let mut out = vec![0f32; IMG_ELEMS];
    let scale = G as f32 / IMG_H as f32;
    for h in 0..IMG_H {
        for w in 0..IMG_W {
            let fy = (h as f32 + 0.5) * scale - 0.5;
            let fx = (w as f32 + 0.5) * scale - 0.5;
            let y0 = fy.floor().clamp(0.0, (G - 1) as f32) as usize;
            let x0 = fx.floor().clamp(0.0, (G - 1) as f32) as usize;
            let y1 = (y0 + 1).min(G - 1);
            let x1 = (x0 + 1).min(G - 1);
            let ty = (fy - y0 as f32).clamp(0.0, 1.0);
            let tx = (fx - x0 as f32).clamp(0.0, 1.0);
            for c in 0..IMG_C {
                let v00 = coarse[y0 * G + x0][c];
                let v01 = coarse[y0 * G + x1][c];
                let v10 = coarse[y1 * G + x0][c];
                let v11 = coarse[y1 * G + x1][c];
                let v0 = v00 * (1.0 - tx) + v01 * tx;
                let v1 = v10 * (1.0 - tx) + v11 * tx;
                out[(h * IMG_W + w) * IMG_C + c] = v0 * (1.0 - ty) + v1 * ty;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let gen = SyntheticCifar::with_defaults(42);
        let a = gen.generate(16, 0);
        let b = gen.generate(16, 0);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let c = gen.generate(16, 1);
        assert_ne!(a.images, c.images, "streams must differ");
    }

    #[test]
    fn labels_cover_classes() {
        let gen = SyntheticCifar::with_defaults(7);
        let d = gen.generate(500, 0);
        let mut seen = [0usize; NUM_CLASSES];
        for i in 0..d.len() {
            seen[d.label(i) as usize] += 1;
        }
        assert!(seen.iter().all(|&c| c > 20), "class balance: {seen:?}");
    }

    #[test]
    fn same_class_is_more_similar_than_cross_class() {
        // The task must be learnable: within-class distance << cross-class.
        let gen = SyntheticCifar::new(3, TaskSpec { signal: 1.0, noise: 0.3, max_shift: 0 });
        let d = gen.generate(200, 0);
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum()
        };
        let mut within = (0.0, 0);
        let mut cross = (0.0, 0);
        for i in 0..50 {
            for j in (i + 1)..50 {
                let dd = dist(d.image(i), d.image(j));
                if d.label(i) == d.label(j) {
                    within = (within.0 + dd, within.1 + 1);
                } else {
                    cross = (cross.0 + dd, cross.1 + 1);
                }
            }
        }
        let within_mean = within.0 / within.1.max(1) as f32;
        let cross_mean = cross.0 / cross.1.max(1) as f32;
        assert!(
            within_mean * 1.5 < cross_mean,
            "within {within_mean} should be well below cross {cross_mean}"
        );
    }

    #[test]
    fn batch_materialization() {
        let gen = SyntheticCifar::with_defaults(1);
        let d = gen.generate(10, 0);
        let (x, y) = d.batch(&[3, 7]);
        assert_eq!(x.len(), 2 * IMG_ELEMS);
        assert_eq!(y.len(), 2);
        assert_eq!(&x[..IMG_ELEMS], d.image(3));
        assert_eq!(y[1], d.label(7));
    }

    #[test]
    fn sharding_partitions_everything() {
        let gen = SyntheticCifar::with_defaults(1);
        let d = gen.generate(103, 0);
        let shards = d.shard_indices(4);
        assert_eq!(shards.len(), 4);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 103);
        assert_eq!(shards[0].len(), 26); // remainder goes forward
        assert_eq!(shards[3].len(), 25);
        let mut all: Vec<usize> = shards.concat();
        all.sort();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
    }

    #[test]
    fn images_are_finite_and_nontrivial() {
        let gen = SyntheticCifar::with_defaults(5);
        let d = gen.generate(4, 0);
        let img = d.image(0);
        assert!(img.iter().all(|v| v.is_finite()));
        let var: f32 = {
            // audit:allow(float-reduction, test-local image statistic - fixed order, not a kernel path)
            let mean: f32 = img.iter().sum::<f32>() / img.len() as f32;
            // audit:allow(float-reduction, test-local image statistic - fixed order, not a kernel path)
            img.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / img.len() as f32
        };
        assert!(var > 0.1, "image variance too small: {var}");
    }
}
