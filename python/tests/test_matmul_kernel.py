"""Pallas matmul kernel vs pure-jnp oracle: shapes, values, autodiff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul
from compile.kernels import ref

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


dims = st.integers(min_value=1, max_value=200)


@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_random_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, m, k)
    y = _rand(rng, k, n)
    got = matmul(x, y)
    want = ref.matmul(x, y)
    assert got.shape == (m, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-4)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),  # degenerate
        (128, 128, 128),  # exactly one tile
        (129, 127, 130),  # one past / short of a tile edge
        (256, 384, 128),  # multi-tile in every dim
        (7, 512, 3),  # skinny output
    ],
)
def test_matmul_tile_edges(m, k, n):
    rng = np.random.default_rng(42)
    x = _rand(rng, m, k)
    y = _rand(rng, k, n)
    np.testing.assert_allclose(
        np.asarray(matmul(x, y)), np.asarray(ref.matmul(x, y)), atol=1e-3, rtol=1e-4
    )


def test_matmul_zero_inputs():
    x = jnp.zeros((33, 65), jnp.float32)
    y = jnp.zeros((65, 17), jnp.float32)
    assert float(jnp.abs(matmul(x, y)).max()) == 0.0


def test_matmul_identity():
    rng = np.random.default_rng(7)
    x = _rand(rng, 50, 50)
    eye = jnp.eye(50, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(matmul(x, eye)), np.asarray(x), atol=1e-5)


@given(m=st.integers(1, 64), k=st.integers(1, 64), n=st.integers(1, 64),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_vjp_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, m, k)
    y = _rand(rng, k, n)

    def f_kernel(a, b):
        return jnp.sum(jnp.sin(matmul(a, b)))

    def f_ref(a, b):
        return jnp.sum(jnp.sin(ref.matmul(a, b)))

    gx, gy = jax.grad(f_kernel, argnums=(0, 1))(x, y)
    rx, ry = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(gy), np.asarray(ry), atol=1e-3, rtol=1e-3)


def test_matmul_jit_and_lowerable():
    """The kernel must survive jit + stablehlo lowering (the AOT path)."""
    spec = jax.ShapeDtypeStruct((96, 80), jnp.float32)
    spec2 = jax.ShapeDtypeStruct((80, 48), jnp.float32)
    lowered = jax.jit(lambda a, b: matmul(a, b)).lower(spec, spec2)
    text = lowered.compiler_ir("stablehlo")
    assert "stablehlo" in str(text)
