//! Scale sweep: all five architectures from paper scale to 256 workers,
//! under both synchronization policies.
//!
//! The paper evaluates 4–16 workers, but its central claims are about
//! *scalability*: the AllReduce master bottleneck, ScatterReduce's
//! request-count blowup, SPIRT's P2P fan-out. This driver extends the
//! testbed along the two axes the paper leaves open — worker count
//! (default 4 → 256) and synchronization policy (BSP vs bounded-staleness
//! async, see `coordinator::protocol::SyncMode`) — and reports per-epoch
//! time, cost, wire traffic, request count and quorum skips for every
//! (architecture × W × mode) point.
//!
//! Each point is an independent deterministic simulation (its own
//! `ClusterEnv`, fixed seed), so points run in parallel on std threads:
//! the sweep's wall time is the slowest point, not the sum. Results are
//! identical for any thread count.
//!
//! The grid is open-ended: `--workers 1024` and `--workers 4096` are
//! supported points (the event-queue scheduler core and epoch-boundary
//! history pruning keep those affordable — see DESIGN.md); the default
//! grid stays 4 → 256 so `docs/` output and goldens are unchanged.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::cloud::{FrameworkKind, StoreTierConfig};
use crate::coordinator::{strategy_for, ClusterEnv, EnvConfig, SyncMode};
use crate::report::{Align, Cell, Report, Table};
use crate::util::{fmt_bytes, fmt_duration};
use crate::Result;

/// Sweep parameters.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Calibrated architecture profile (`mobilenet`, `resnet18`, ...).
    pub arch: String,
    /// Worker counts to sweep.
    pub worker_counts: Vec<usize>,
    /// Synchronization policies to sweep.
    pub modes: Vec<SyncMode>,
    /// Gradient batches per worker per epoch (paper: 24).
    pub batches_per_epoch: usize,
    /// Epochs simulated per point (metrics are per-epoch averages).
    pub epochs: usize,
    /// Simulation threads (0 = one per available core).
    pub threads: usize,
    /// Record protocol traces and report per-point p99 op latency
    /// (opt-in: tracing buffers every protocol event).
    pub trace: bool,
    /// Shared store tier provisioning for every point (the shard-sweep
    /// driver varies this axis; here it is held fixed across the sweep).
    pub store: StoreTierConfig,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            arch: "mobilenet".to_string(),
            worker_counts: vec![4, 16, 64, 256],
            modes: vec![SyncMode::Bsp, SyncMode::Async { staleness: 2 }],
            batches_per_epoch: 24,
            epochs: 1,
            threads: 0,
            trace: false,
            store: StoreTierConfig::single(),
        }
    }
}

/// One (architecture × worker count × sync mode) measurement. Every
/// quantity is a per-epoch mean over the simulated epochs, so rows from
/// runs with different `--epochs` stay comparable.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub framework: FrameworkKind,
    pub workers: usize,
    pub mode: SyncMode,
    /// Mean epoch wall time on the virtual timeline (seconds).
    pub epoch_secs: f64,
    /// Mean cost per epoch under the paper's model (USD).
    pub cost_usd: f64,
    /// Mean bytes per epoch that crossed the network.
    pub wire_bytes: u64,
    /// Mean substrate operations issued per epoch.
    pub total_ops: u64,
    /// Mean Lambda function duration over the run (0 for the GPU baseline).
    pub mean_fn_secs: f64,
    /// Mean contributions per epoch skipped by the staleness policy.
    pub stale_skips: u64,
    /// p99 latency of communication ops (ms), when the sweep traced.
    pub p99_op_ms: Option<f64>,
}

fn run_point(
    cfg: &SweepConfig,
    fw: FrameworkKind,
    workers: usize,
    mode: SyncMode,
) -> Result<SweepPoint> {
    let mut ec = EnvConfig::virtual_paper(fw, &cfg.arch, workers)?
        .with_sync(mode)
        .with_store(cfg.store.clone());
    if cfg.trace {
        ec = ec.with_trace(crate::trace::TraceConfig::on());
    }
    ec.batches_per_epoch = cfg.batches_per_epoch;
    let mut env = ClusterEnv::new(ec)?;
    let mut strategy = strategy_for(fw);
    let epochs = cfg.epochs.max(1);
    let mut epoch_secs = 0.0;
    let mut mean_fn_secs = 0.0;
    for _ in 0..epochs {
        let stats = strategy.run_epoch(&mut env)?;
        epoch_secs += stats.epoch_secs;
        // `mean_duration` is cumulative over the whole run already.
        mean_fn_secs = stats.mean_fn_secs;
    }
    Ok(SweepPoint {
        framework: fw,
        workers,
        mode,
        epoch_secs: epoch_secs / epochs as f64,
        cost_usd: env.ledger.total_paper() / epochs as f64,
        wire_bytes: env.comm.wire_bytes() / epochs as u64,
        total_ops: env.comm.total_ops() / epochs as u64,
        mean_fn_secs,
        stale_skips: env.comm.stale_skips / epochs as u64,
        p99_op_ms: if cfg.trace {
            crate::trace::histogram::p99_comm_ms(env.trace.events())
        } else {
            None
        },
    })
}

/// Run the sweep. Points are scheduled over a work-stealing cursor onto
/// `cfg.threads` std threads; output order is deterministic (framework ×
/// worker count × mode, as configured) regardless of thread count.
pub fn run(cfg: &SweepConfig) -> Result<Vec<SweepPoint>> {
    let tasks: Vec<(FrameworkKind, usize, SyncMode)> = FrameworkKind::ALL
        .iter()
        .flat_map(|&fw| {
            cfg.worker_counts.iter().flat_map(move |&w| {
                cfg.modes.iter().map(move |&m| (fw, w, m))
            })
        })
        .collect();
    if tasks.is_empty() {
        return Ok(Vec::new());
    }
    let threads = if cfg.threads > 0 {
        cfg.threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
    .clamp(1, tasks.len());

    let cursor = AtomicUsize::new(0);
    let outputs: Vec<Vec<(usize, Result<SweepPoint>)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut out = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= tasks.len() {
                            break;
                        }
                        let (fw, w, mode) = tasks[i];
                        out.push((i, run_point(cfg, fw, w, mode)));
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("sweep thread panicked")).collect()
    });

    let mut indexed: Vec<(usize, SweepPoint)> = Vec::with_capacity(tasks.len());
    for (i, res) in outputs.into_iter().flatten() {
        indexed.push((i, res?));
    }
    indexed.sort_by_key(|(i, _)| *i);
    Ok(indexed.into_iter().map(|(_, p)| p).collect())
}

/// Build the sweep report. No paper anchors: the sweep extends the paper's
/// 4–16-worker range to 256 on purpose, so every row is a measurement with
/// nothing to compare against.
pub fn report(points: &[SweepPoint], cfg: &SweepConfig) -> Report {
    let mut t = Table::new(
        "scale_sweep",
        &[
            ("Framework", Align::Left),
            ("W", Align::Right),
            ("Mode", Align::Left),
            ("Epoch", Align::Right),
            ("Cost ($)", Align::Right),
            ("Wire", Align::Right),
            ("Ops", Align::Right),
            ("Fn (s)", Align::Right),
            ("Skips", Align::Right),
            ("p99 op (ms)", Align::Right),
        ],
    )
    .title(format!(
        "Scale sweep — {} profile, {} batches/epoch (virtual gradients)",
        cfg.arch, cfg.batches_per_epoch
    ));
    let mut last_fw: Option<FrameworkKind> = None;
    for p in points {
        if last_fw.is_some() && last_fw != Some(p.framework) {
            t.rule();
        }
        last_fw = Some(p.framework);
        t.push_row(vec![
            Cell::text(p.framework.name()),
            Cell::count(p.workers as u64),
            Cell::text(p.mode.label()),
            Cell::text(fmt_duration(p.epoch_secs)).with_value(p.epoch_secs),
            Cell::num(p.cost_usd, 4),
            Cell::text(fmt_bytes(p.wire_bytes)).with_value(p.wire_bytes as f64),
            Cell::count(p.total_ops),
            Cell::num(p.mean_fn_secs, 2),
            Cell::count(p.stale_skips),
            match p.p99_op_ms {
                Some(ms) => Cell::num(ms, 1),
                None => Cell::text("—"),
            },
        ]);
    }
    let mode_labels: Vec<String> = cfg.modes.iter().map(|m| m.label()).collect();
    let worker_labels: Vec<String> = cfg.worker_counts.iter().map(|w| w.to_string()).collect();
    Report::new(
        "scale_sweep",
        "Scale sweep — 4 → 256 workers × sync modes",
        format!(
            "slsgpu scale-sweep --arch {} --workers {} --modes {} --batches {}",
            cfg.arch,
            worker_labels.join(","),
            mode_labels.join(","),
            cfg.batches_per_epoch
        ),
    )
    .with_intro(
        "Extension along the two axes the paper leaves open: worker count (its central \
         scalability claims — the AllReduce master bottleneck, ScatterReduce's \
         request-count blowup, SPIRT's once-per-epoch P2P fan-out) and synchronization \
         policy (BSP vs bounded-staleness async). Every (architecture × W × mode) \
         point is one independent seeded simulation of a full epoch through the same \
         substrate stack as Table 2; `Skips` counts contributions the staleness quorum \
         proceeded without (always 0 under BSP). The counter's granularity differs by \
         topology, so compare it across modes or worker counts within one framework.",
    )
    .with_table(t)
}

/// Legacy CLI view of [`report`].
pub fn render(points: &[SweepPoint], cfg: &SweepConfig) -> String {
    report(points, cfg).to_text()
}

/// CSV export (one row per point).
pub fn render_csv(points: &[SweepPoint]) -> String {
    let mut out = String::from(
        "framework,workers,mode,epoch_secs,cost_usd,wire_bytes,total_ops,mean_fn_secs,\
         stale_skips,p99_op_ms\n",
    );
    for p in points {
        out.push_str(&format!(
            "{},{},{},{:.6},{:.6},{},{},{:.6},{},{}\n",
            p.framework.name(),
            p.workers,
            p.mode.label(),
            p.epoch_secs,
            p.cost_usd,
            p.wire_bytes,
            p.total_ops,
            p.mean_fn_secs,
            p.stale_skips,
            p.p99_op_ms.map(|ms| format!("{ms:.3}")).unwrap_or_default()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> SweepConfig {
        SweepConfig {
            arch: "mobilenet".to_string(),
            worker_counts: vec![4, 8],
            modes: vec![SyncMode::Bsp, SyncMode::Async { staleness: 2 }],
            batches_per_epoch: 4,
            epochs: 1,
            threads: 2,
            trace: false,
            store: StoreTierConfig::single(),
        }
    }

    #[test]
    fn sweep_covers_every_architecture_and_mode() {
        let cfg = small_cfg();
        let points = run(&cfg).unwrap();
        assert_eq!(points.len(), 5 * 2 * 2);
        for p in &points {
            assert!(p.epoch_secs > 0.0, "{:?}", p);
            assert!(p.cost_usd > 0.0, "{:?}", p);
            assert!(p.total_ops > 0, "{:?}", p);
        }
        // Output order is (framework, W, mode) as configured.
        assert_eq!(points[0].framework, FrameworkKind::Spirt);
        assert_eq!(points[0].workers, 4);
        assert_eq!(points[0].mode, SyncMode::Bsp);
        assert_eq!(points[1].mode, SyncMode::Async { staleness: 2 });
        // BSP points never skip; async points on the barriered topologies do.
        assert!(points.iter().filter(|p| p.mode == SyncMode::Bsp).all(|p| p.stale_skips == 0));
        let table = render(&points, &cfg);
        assert!(table.contains("AllReduce") && table.contains("async:2"), "{table}");
        let csv = render_csv(&points);
        assert_eq!(csv.lines().count(), 1 + points.len());
    }

    #[test]
    fn sweep_is_deterministic_across_thread_counts() {
        let mut serial = small_cfg();
        serial.threads = 1;
        let mut parallel = small_cfg();
        parallel.threads = 4;
        let a = run(&serial).unwrap();
        let b = run(&parallel).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.framework, y.framework);
            assert_eq!(x.workers, y.workers);
            assert_eq!(x.mode, y.mode);
            assert_eq!(
                x.epoch_secs.to_bits(),
                y.epoch_secs.to_bits(),
                "{:?} W={} {}: vtime must not depend on thread count",
                x.framework,
                x.workers,
                x.mode.label()
            );
            assert_eq!(x.cost_usd.to_bits(), y.cost_usd.to_bits());
            assert_eq!(x.total_ops, y.total_ops);
        }
    }

    #[test]
    fn traced_sweep_adds_p99_without_perturbing_the_timeline() {
        let plain = run(&small_cfg()).unwrap();
        let mut tcfg = small_cfg();
        tcfg.trace = true;
        let traced = run(&tcfg).unwrap();
        assert_eq!(plain.len(), traced.len());
        for (x, y) in plain.iter().zip(&traced) {
            assert_eq!(
                x.epoch_secs.to_bits(),
                y.epoch_secs.to_bits(),
                "{:?} W={}: tracing must not move the timeline",
                x.framework,
                x.workers
            );
            assert_eq!(x.cost_usd.to_bits(), y.cost_usd.to_bits());
            assert!(x.p99_op_ms.is_none());
            assert!(y.p99_op_ms.unwrap() > 0.0, "{y:?}");
        }
        let csv = render_csv(&traced);
        assert_eq!(csv.lines().nth(1).unwrap().split(',').count(), 10);
    }

    #[test]
    fn master_bottleneck_emerges_with_scale() {
        // AllReduce's per-epoch time must grow faster than SPIRT's as W
        // scales: the master serializes W transfers on every round's
        // critical path, while SPIRT pays its O(W) P2P exchange once per
        // epoch.
        let cfg = SweepConfig {
            worker_counts: vec![4, 64],
            modes: vec![SyncMode::Bsp],
            batches_per_epoch: 4,
            threads: 0,
            ..SweepConfig::default()
        };
        let points = run(&cfg).unwrap();
        let get = |fw: FrameworkKind, w: usize| {
            points
                .iter()
                .find(|p| p.framework == fw && p.workers == w)
                .unwrap()
                .epoch_secs
        };
        let ar_growth = get(FrameworkKind::AllReduce, 64) / get(FrameworkKind::AllReduce, 4);
        let sp_growth = get(FrameworkKind::Spirt, 64) / get(FrameworkKind::Spirt, 4);
        assert!(
            ar_growth > sp_growth,
            "AllReduce must degrade faster: {ar_growth:.2}x vs SPIRT {sp_growth:.2}x"
        );
    }

    #[test]
    #[ignore = "full paper-scale run (~minutes); exercised by `slsgpu scale-sweep`"]
    fn full_sweep_completes_at_256_workers() {
        let cfg = SweepConfig::default();
        let points = run(&cfg).unwrap();
        assert_eq!(points.len(), 5 * 4 * 2);
        assert!(points.iter().all(|p| p.epoch_secs > 0.0));
    }

    #[test]
    #[ignore = "sweep-scale run; CI's release-build W=1024 smoke exercises the same point"]
    fn sweep_completes_at_1024_workers() {
        let cfg = SweepConfig {
            worker_counts: vec![1024],
            modes: vec![SyncMode::Bsp],
            batches_per_epoch: 2,
            ..SweepConfig::default()
        };
        let points = run(&cfg).unwrap();
        assert_eq!(points.len(), 5);
        assert!(points.iter().all(|p| p.epoch_secs > 0.0 && p.total_ops > 0));
        // The barriered topologies must still be strictly costlier per
        // epoch than SPIRT's once-per-epoch sync at this scale.
        let get = |fw: FrameworkKind| {
            points.iter().find(|p| p.framework == fw).unwrap().epoch_secs
        };
        assert!(get(FrameworkKind::AllReduce) > get(FrameworkKind::Spirt));
    }

    #[test]
    #[ignore = "largest supported point (ScatterReduce is ~W^2 store ops per round); run explicitly"]
    fn sweep_completes_at_4096_workers() {
        // One batch per epoch: the point's job is to prove the grid's upper
        // end completes within bounded memory (epoch-boundary history
        // pruning) — scaling rounds adds wall time, not new behaviour.
        let cfg = SweepConfig {
            worker_counts: vec![4096],
            modes: vec![SyncMode::Bsp],
            batches_per_epoch: 1,
            ..SweepConfig::default()
        };
        let points = run(&cfg).unwrap();
        assert_eq!(points.len(), 5);
        assert!(points.iter().all(|p| p.epoch_secs > 0.0 && p.total_ops > 0));
    }
}
