//! Bench: regenerate §4.2 (SPIRT in-database ops vs naive
//! fetch-update-store). Runs the virtual paper-scale benchmark always, and
//! the real-slab PJRT-backed variant when artifacts are present.
use std::rc::Rc;
use std::time::Instant;

use slsgpu::runtime::Engine;

fn main() {
    let t0 = Instant::now();
    let virt = slsgpu::exp::spirt_indb::run(None, 24).expect("spirt-indb");
    print!("{}", slsgpu::exp::spirt_indb::render(&virt));

    match Engine::load("artifacts") {
        Ok(engine) => {
            // Real 46.8 MB slabs through the PJRT-compiled Pallas kernels.
            let real = slsgpu::exp::spirt_indb::run(Some((Rc::new(engine), "resnet18_full")), 24)
                .expect("spirt-indb real");
            print!("{}", slsgpu::exp::spirt_indb::render(&real));
        }
        Err(_) => println!("(real-slab variant skipped: run `make artifacts`)"),
    }
    println!("regenerated in {:.0} ms", t0.elapsed().as_secs_f64() * 1000.0);
}
