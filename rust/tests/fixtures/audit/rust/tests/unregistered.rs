#[test]
fn never_runs() {}
