//! Table 3 / Fig. 4: convergence time and final accuracy — all five
//! frameworks training the executed model end-to-end (real gradients
//! through the PJRT artifacts, virtual time on the paper's axis).
//!
//! The paper's shape: GPU converges fastest; SPIRT is the best serverless
//! trade-off (gradient accumulation → one sync per epoch); MLLess is slower
//! (delayed updates); AllReduce/ScatterReduce are an order of magnitude
//! slower (per-batch synchronization at serverless latencies) with
//! AllReduce eventually the most accurate.

use std::rc::Rc;

use crate::cloud::FrameworkKind;
use crate::coordinator::{strategy_for, ClusterEnv, EnvConfig};
use crate::report::{Align, Cell, Report, Table};
use crate::runtime::Engine;
use crate::train::{run_session, SessionConfig, SessionReport};
use crate::Result;

/// Paper Table 3 (minutes to 80%, final accuracy %).
pub fn paper_row(fw: FrameworkKind) -> (f64, f64) {
    match fw {
        FrameworkKind::Spirt => (84.96, 83.2),
        FrameworkKind::MlLess => (189.68, 83.48),
        FrameworkKind::ScatterReduce => (1652.49, 82.1),
        FrameworkKind::AllReduce => (1367.01, 85.05),
        FrameworkKind::GpuBaseline => (70.33, 84.5),
    }
}

#[derive(Debug, Clone)]
pub struct Table3Config {
    pub model: String,
    pub workers: usize,
    pub train_samples: usize,
    pub max_epochs: usize,
    pub target_acc: f64,
    pub seed: u64,
}

impl Default for Table3Config {
    fn default() -> Self {
        Table3Config {
            model: "mobilenet_s".into(),
            workers: 4,
            train_samples: 6144,
            max_epochs: 20,
            target_acc: 0.80,
            seed: 42,
        }
    }
}

/// One framework's full Table 3 outcome.
#[derive(Debug, Clone)]
pub struct Row {
    pub framework: FrameworkKind,
    pub session: SessionReport,
    /// First epoch at which the target accuracy was reached.
    pub epochs_to_target: Option<usize>,
    /// Paper-scale virtual epoch duration used as the time axis (seconds).
    pub paper_epoch_secs: f64,
    /// Time to target on the paper-scale axis (minutes).
    pub time_to_target_min: Option<f64>,
    /// MLLess only: measured fraction of updates that passed the filter.
    pub publish_rate: Option<f64>,
}

/// Run one framework's convergence session.
pub fn run_framework(engine: Rc<Engine>, fw: FrameworkKind, cfg: &Table3Config) -> Result<Row> {
    let env_cfg =
        EnvConfig::real(fw, engine, &cfg.model, cfg.workers, cfg.train_samples, cfg.seed)?;
    let mut env = ClusterEnv::new(env_cfg)?;
    let session_cfg = SessionConfig {
        max_epochs: cfg.max_epochs,
        target_acc: cfg.target_acc,
        patience: 6,
        evaluate: true,
    };

    // MLLess is constructed directly so its measured publish rate can feed
    // the paper-scale epoch pricing below.
    let (session, publish_rate) = if fw == FrameworkKind::MlLess {
        let mut s = crate::coordinator::mlless::MlLess::new(
            crate::coordinator::mlless::DEFAULT_THRESHOLD,
        );
        let report = run_session(&mut env, &mut s, &session_cfg)?;
        (report, Some(s.publish_rate()))
    } else {
        let mut strategy = strategy_for(fw);
        (run_session(&mut env, strategy.as_mut(), &session_cfg)?, None)
    };

    let epochs_to_target = session
        .reports
        .iter()
        .find(|r| r.test_acc.map(|a| a >= cfg.target_acc).unwrap_or(false))
        .map(|r| r.epoch);
    let epoch_secs = paper_epoch_secs(fw, publish_rate.unwrap_or(1.0))?;
    Ok(Row {
        framework: fw,
        epochs_to_target,
        paper_epoch_secs: epoch_secs,
        time_to_target_min: epochs_to_target.map(|e| e as f64 * epoch_secs / 60.0),
        publish_rate,
        session,
    })
}

/// Run the full Table 3 comparison.
pub fn run(engine: Rc<Engine>, cfg: &Table3Config) -> Result<Vec<Row>> {
    FrameworkKind::ALL
        .iter()
        .map(|fw| run_framework(engine.clone(), *fw, cfg))
        .collect()
}

/// Paper-scale epoch duration (seconds) for the Table 3 time axis.
///
/// Methodology: convergence behaviour (epochs to target, accuracy) is
/// measured *end-to-end* on the executed model; the time axis prices each
/// epoch at the paper-scale virtual cost of Table 2 (MobileNet, B=512, 24
/// batches/worker) — exactly as the paper's own time axis reflects its AWS
/// infrastructure, not its model math. MLLess's epoch cost depends on how
/// many updates pass the filter, so it is evaluated at the real run's
/// measured publish rate.
pub fn paper_epoch_secs(fw: FrameworkKind, publish_rate: f64) -> Result<f64> {
    use crate::coordinator::mlless::MlLess;
    use crate::coordinator::Strategy;
    let mut env = ClusterEnv::new(EnvConfig::virtual_paper(fw, "mobilenet", 4)?)?;
    let stats = match fw {
        FrameworkKind::MlLess => {
            MlLess::new(0.0).with_virtual_publish_rate(publish_rate).run_epoch(&mut env)?
        }
        _ => {
            let mut s = strategy_for(fw);
            s.run_epoch(&mut env)?
        }
    };
    Ok(stats.epoch_secs)
}

/// Build the Table 3 report. Convergence of the executed model is measured,
/// not anchored: the synthetic-CIFAR substitution changes the absolute
/// numbers by design, so the paper's values render as a comparison column
/// and the *shape* assertions live in the integration tests.
pub fn report(rows: &[Row], cfg: &Table3Config) -> Report {
    let mut t = Table::new(
        "table3",
        &[
            ("Framework", Align::Left),
            ("Time to target (min)", Align::Right),
            ("Final acc (%)", Align::Right),
            ("Epochs", Align::Right),
            ("Epoch cost (s)", Align::Right),
            ("Paper (min, %)", Align::Right),
        ],
    )
    .title(format!(
        "Table 3 — Convergence ({} on synthetic CIFAR, target {:.0}%, paper-scale time axis)",
        cfg.model,
        cfg.target_acc * 100.0
    ));

    for row in rows {
        let (paper_min, paper_acc) = paper_row(row.framework);
        let time_cell = match row.time_to_target_min {
            Some(m) => Cell::num(m, 1),
            None => Cell::text(format!(
                ">{:.1}",
                row.session.reports.len() as f64 * row.paper_epoch_secs / 60.0
            )),
        };
        let acc_cell = match row.session.final_acc {
            Some(a) => Cell::text(format!("{:.1}", a * 100.0)).with_value(a * 100.0),
            None => Cell::text("-"),
        };
        t.push_row(vec![
            Cell::text(row.framework.name()),
            time_cell,
            acc_cell,
            Cell::count(row.session.reports.len() as u64),
            Cell::num(row.paper_epoch_secs, 1),
            Cell::text(format!("{paper_min:.0}, {paper_acc:.1}")),
        ]);
    }
    Report::new(
        "table3",
        "Table 3 / Fig. 4 — convergence on the executed model",
        format!("slsgpu exp table3 --model {} --epochs {}", cfg.model, cfg.max_epochs),
    )
    .with_intro(
        "All five frameworks training the executed model end to end: real gradients \
         through the PJRT artifacts, accuracy on the synthetic-CIFAR task, each epoch \
         priced at the paper-scale virtual cost of Table 2 (MLLess at its measured \
         publish rate). Expect the paper's shape: GPU fastest to target, SPIRT the \
         best serverless trade-off, MLLess slower, AllReduce/ScatterReduce an order \
         of magnitude slower with AllReduce eventually most accurate.",
    )
    .with_table(t)
}

/// Legacy CLI view of [`report`].
pub fn render(rows: &[Row], cfg: &Table3Config) -> String {
    report(rows, cfg).to_text()
}

/// Render the Fig. 4 accuracy-vs-time series as CSV (for plotting).
pub fn render_csv(rows: &[Row]) -> String {
    let mut out = String::from("framework,epoch,paper_time_min,loss,accuracy\n");
    for row in rows {
        for e in &row.session.reports {
            out.push_str(&format!(
                "{},{},{:.3},{},{}\n",
                row.session.framework,
                e.epoch,
                e.epoch as f64 * row.paper_epoch_secs / 60.0,
                e.mean_loss.map(|l| format!("{l:.4}")).unwrap_or_default(),
                e.test_acc.map(|a| format!("{a:.4}")).unwrap_or_default(),
            ));
        }
    }
    out
}
