//! Stub PJRT engine — compiled when the `pjrt` feature is off.
//!
//! The real engine (`engine.rs`) binds the vendored `xla` crate, which is
//! not available in every build environment. This stub keeps the public
//! surface identical so the rest of the crate (coordinator, experiments,
//! CLI) compiles unchanged: [`Engine::load`] returns a descriptive error,
//! so no `Engine` value ever exists and the remaining methods are
//! unreachable in practice (they error defensively anyway). Everything that
//! runs in virtual (size-only) gradient mode is unaffected.

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::tensor::{RustMath, Slab, SlabMath};

use super::manifest::Manifest;

/// Output of one grad-artifact execution (mirror of the real engine's).
#[derive(Debug, Clone)]
pub struct GradOutput {
    pub loss: f32,
    pub grads: Slab,
    /// Correct top-1 predictions in the batch.
    pub correct: u32,
}

const NO_PJRT: &str = "slsgpu was built without the `pjrt` feature: the PJRT runtime \
     (vendored `xla` crate) is unavailable, so end-to-end gradient execution is \
     disabled. Rebuild with `--features pjrt` in an environment that vendors xla; \
     all cost-model experiments (table1/table2/fig2/fig3/fault-tolerance) run \
     without it.";

/// Stub engine: same shape as the PJRT engine, but cannot load artifacts.
#[derive(Debug)]
pub struct Engine {
    pub manifest: Manifest,
}

impl Engine {
    /// Always errors: artifact execution requires the `pjrt` feature.
    pub fn load(_artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        bail!(NO_PJRT)
    }

    pub fn warm_model(&self, _model: &str) -> Result<()> {
        bail!(NO_PJRT)
    }

    pub fn init(&self, _model: &str, _seed: u32) -> Result<Slab> {
        bail!(NO_PJRT)
    }

    pub fn grad(&self, _model: &str, _theta: &Slab, _x: &[f32], _y: &[i32]) -> Result<GradOutput> {
        bail!(NO_PJRT)
    }

    pub fn eval(&self, _model: &str, _theta: &Slab, _x: &[f32], _y: &[i32]) -> Result<(f32, u32)> {
        bail!(NO_PJRT)
    }

    pub fn acc(&self, _slab_name: &str, _acc: &Slab, _g: &Slab, _w: f32) -> Result<Slab> {
        bail!(NO_PJRT)
    }

    pub fn sgd(&self, _slab_name: &str, _theta: &Slab, _g: &Slab, _lr: f32) -> Result<Slab> {
        bail!(NO_PJRT)
    }

    pub fn avg_update(
        &self,
        _slab_name: &str,
        _theta: &Slab,
        _gsum: &Slab,
        _inv_k: f32,
        _lr: f32,
    ) -> Result<Slab> {
        bail!(NO_PJRT)
    }
}

/// Stub [`SlabMath`]: falls back to the portable Rust implementation, which
/// is exactly what the real `PjrtMath` does for slabs it cannot execute.
pub struct PjrtMath {
    fallback: RustMath,
}

impl PjrtMath {
    pub fn new(_engine: Rc<Engine>, _slab_name: impl Into<String>) -> PjrtMath {
        PjrtMath { fallback: RustMath }
    }
}

impl SlabMath for PjrtMath {
    fn acc(&self, acc: &Slab, g: &Slab, w: f32) -> Result<Slab> {
        self.fallback.acc(acc, g, w)
    }

    fn avg_update(&self, theta: &Slab, gsum: &Slab, inv_k: f32, lr: f32) -> Result<Slab> {
        self.fallback.avg_update(theta, gsum, inv_k, lr)
    }

    fn sgd(&self, theta: &Slab, g: &Slab, lr: f32) -> Result<Slab> {
        self.fallback.sgd(theta, g, lr)
    }

    fn scale(&self, src: &Slab, w: f32) -> Result<Slab> {
        self.fallback.scale(src, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_errors_with_guidance() {
        let err = Engine::load("/nonexistent").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn stub_math_matches_rust_math() {
        // No Engine value can exist, so PjrtMath is only constructible in
        // this test via transmute-free fallback behaviour checks.
        let m = RustMath;
        let out = m.acc(&Slab::from_vec(vec![1.0]), &Slab::from_vec(vec![2.0]), 2.0).unwrap();
        assert_eq!(out.as_slice().unwrap(), &[5.0]);
    }
}
