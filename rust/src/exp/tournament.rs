//! Robustness tournament: aggregation rule × adversarial regime ×
//! architecture, with a cost/accuracy Pareto verdict per cell family.
//!
//! Table 4 measures one fault at a time under the default (mean)
//! aggregation. The tournament asks the composite question the SPIRT
//! robustness claims actually hinge on: *which aggregation rule should each
//! architecture run when the environment is adversarial, and what does that
//! choice cost?* Every cell is one deterministic session of a paper-scale
//! workload under one of the four adversarial regimes from `faults::`
//! (colluding Byzantine coalition, healing network partition, heavy-tailed
//! Pareto stragglers, correlated spot-preemption storm), with one of five
//! aggregation rules (`mean`, `clipped:1`, `coord-median`, `krum:2`,
//! `trimmed:2`) driving `ClusterEnv::aggregate` — so the rule's extra
//! compute is billed on the virtual clock and in the ledger.
//!
//! The accuracy axis cannot come from size-only slabs, so it comes from the
//! same real-gradient logistic task as the poisoning demo
//! ([`crate::faults::poison_demo::coalition_accuracy`]): under the
//! coalition regime the demo's coalition (workers 1 and 2 of 8, `Scale(-8)`)
//! poisons its shards; under the other regimes the adversary corrupts
//! timing/availability but not gradient values, so accuracy is the rule's
//! clean-run accuracy (robust estimators pay a small bias even with no
//! adversary — that is exactly the cost the Pareto column weighs).
//!
//! Per (attack × architecture) family the five rule-cells are scored on
//! (cost, accuracy): a rule is Pareto-optimal when no other rule is at
//! least as cheap *and* at least as accurate with one strict improvement.
//! Cells run in parallel on std threads (work-stealing cursor, like the
//! scale sweep); results are bit-identical for any thread count.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::cloud::FrameworkKind;
use crate::coordinator::{strategy_for, ClusterEnv, EnvConfig};
use crate::faults::{poison_demo, FaultPlan, PoisonMode};
use crate::metrics::RecoveryStats;
use crate::report::{Align, Cell as RCell, Report, Section, Table};
use crate::tensor::AggregationRule;
use crate::train::{run_session, SessionConfig};
use crate::Result;

/// The four adversarial regimes (column families of the grid).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Attack {
    /// Workers 1 and 2 collude: both submit `Scale(-8)`-poisoned updates on
    /// the same rounds from the middle epoch onward.
    Coalition,
    /// Worker 1 is partitioned from the network from the start of the run;
    /// the partition heals at a planned virtual time (45 s).
    Partition,
    /// Workers 1–3 draw heavy-tailed (Pareto, alpha 1.5) compute slowdowns
    /// every round from the middle epoch onward.
    StragglerTail,
    /// Workers 1–3 are spot-preempted in one correlated burst mid-epoch and
    /// pay cold-start restarts.
    PreemptionStorm,
}

impl Attack {
    pub const ALL: [Attack; 4] = [
        Attack::Coalition,
        Attack::Partition,
        Attack::StragglerTail,
        Attack::PreemptionStorm,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Attack::Coalition => "coalition",
            Attack::Partition => "partition",
            Attack::StragglerTail => "straggler-tail",
            Attack::PreemptionStorm => "preemption-storm",
        }
    }

    /// Parse a CLI spec (`coalition|partition|straggler-tail|preemption-storm`).
    pub fn parse(spec: &str) -> Result<Attack> {
        let spec = spec.trim().to_ascii_lowercase();
        for a in Attack::ALL {
            if spec == a.name() {
                return Ok(a);
            }
        }
        anyhow::bail!(
            "unknown attack {spec:?} (coalition|partition|straggler-tail|preemption-storm)"
        )
    }
}

/// The rule roster every (attack × architecture) family competes over.
pub fn rules() -> [AggregationRule; 5] {
    [
        AggregationRule::Mean,
        AggregationRule::ClippedMean { ratio: 1.0 },
        AggregationRule::CoordMedian,
        AggregationRule::Krum { f: 2 },
        AggregationRule::TrimmedMean { k: 2 },
    ]
}

/// The coalition: 2 of 8 workers, below every roster rule's breakdown
/// point (krum:2 needs `n >= f + 3 = 5`, trimmed:2 needs `n > 2k = 4`).
pub const COALITION: [usize; 2] = [1, 2];
/// The coalition's poison: large negative scaling, the regime where the
/// plain mean demonstrably diverges (asserted in the tests below).
pub const COALITION_MODE: PoisonMode = PoisonMode::Scale(-8.0);
/// Victims of the straggler-tail and preemption-storm regimes.
pub const STORM_VICTIMS: [usize; 3] = [1, 2, 3];

/// Tournament knobs.
#[derive(Debug, Clone)]
pub struct TournamentConfig {
    /// Calibrated model profile for the sessions (`mobilenet`, ...).
    pub model: String,
    /// Architectures to run (default: all five).
    pub frameworks: Vec<FrameworkKind>,
    /// Adversarial regimes to run (default: all four).
    pub attacks: Vec<Attack>,
    /// Session workers. Must be >= 5 so `krum:2` has `n >= f + 3`
    /// contributions to score (the accuracy axis always uses the demo's
    /// 8-worker task so its columns stay comparable across configs).
    pub workers: usize,
    pub epochs: usize,
    pub seed: u64,
    /// Simulation threads (0 = one per available core).
    pub threads: usize,
}

impl Default for TournamentConfig {
    fn default() -> Self {
        TournamentConfig {
            model: "mobilenet".to_string(),
            frameworks: FrameworkKind::ALL.to_vec(),
            attacks: Attack::ALL.to_vec(),
            workers: 8,
            epochs: 2,
            seed: 42,
            threads: 0,
        }
    }
}

/// Deterministic fault plan for one regime. The adversarial epoch is the
/// middle of the run, mirroring `table4_faults::plan_for`.
pub fn plan_for(attack: Attack, cfg: &TournamentConfig) -> FaultPlan {
    let epoch = (cfg.epochs / 2 + 1).min(cfg.epochs);
    match attack {
        Attack::Coalition => FaultPlan::none().coalition(&COALITION, epoch, 0, None, COALITION_MODE),
        // Start at vtime 0 so the victim's *first* communication op is the
        // one that defers (every architecture's first sync lands well
        // before the 45 s heal), making the regime observable for all five
        // topologies regardless of their round cadence.
        Attack::Partition => FaultPlan::none().partition(&[1], 0.0, 45.0),
        Attack::StragglerTail => {
            FaultPlan::none().pareto_stragglers(&STORM_VICTIMS, epoch, 0, 1.5, 1.0, cfg.seed, None)
        }
        Attack::PreemptionStorm => FaultPlan::none().preemption_storm(&STORM_VICTIMS, epoch, 12),
    }
}

/// One (architecture × attack × rule) measurement.
#[derive(Debug, Clone)]
pub struct TournamentCell {
    pub framework: FrameworkKind,
    pub attack: Attack,
    pub rule: AggregationRule,
    /// Session wall time on the virtual timeline (seconds).
    pub vtime_secs: f64,
    /// Session cost under the paper's model (USD).
    pub cost_usd: f64,
    /// Final accuracy of the real-gradient logistic task under this
    /// (attack, rule) — shared across architectures by construction.
    pub accuracy: f64,
    pub recovery: RecoveryStats,
    /// Pareto-optimal on (cost, accuracy) within its (attack × architecture)
    /// family of rule-cells.
    pub pareto: bool,
}

/// The full grid plus the clean-run headline the accuracy deltas read
/// against.
#[derive(Debug, Clone)]
pub struct Tournament {
    pub cells: Vec<TournamentCell>,
    /// Fault-free accuracy of the plain mean on the demo task.
    pub clean_acc: f64,
}

/// Accuracy axis, precomputed per (poisoned?, rule): the logistic-task
/// runs are independent of the session grid, so each unique pair trains
/// once.
struct AccTable {
    clean: Vec<f64>,
    coalition: Vec<f64>,
}

fn accuracy_axis(seed: u64) -> Result<AccTable> {
    let mut clean = Vec::new();
    let mut coalition = Vec::new();
    for rule in rules() {
        clean.push(poison_demo::coalition_accuracy(
            seed,
            poison_demo::DEMO_WORKERS,
            &[],
            COALITION_MODE,
            rule,
        )?);
        coalition.push(poison_demo::coalition_accuracy(
            seed,
            poison_demo::DEMO_WORKERS,
            &COALITION,
            COALITION_MODE,
            rule,
        )?);
    }
    Ok(AccTable { clean, coalition })
}

fn run_cell(
    cfg: &TournamentConfig,
    fw: FrameworkKind,
    attack: Attack,
    rule: AggregationRule,
) -> Result<(f64, f64, RecoveryStats)> {
    let mut env_cfg = EnvConfig::virtual_paper(fw, &cfg.model, cfg.workers)?
        .with_faults(plan_for(attack, cfg))
        .with_aggregation(rule);
    env_cfg.seed = cfg.seed;
    let mut env = ClusterEnv::new(env_cfg)?;
    let mut strategy = strategy_for(fw);
    let session = SessionConfig {
        max_epochs: cfg.epochs,
        target_acc: 2.0, // unreachable: run the full epoch budget
        patience: cfg.epochs + 1,
        evaluate: false,
    };
    let report = run_session(&mut env, strategy.as_mut(), &session)?;
    Ok((report.total_vtime_secs, report.total_cost_usd, env.recovery.clone()))
}

/// Mark the Pareto-optimal cells of one (attack × architecture) family on
/// (cost down, accuracy up). Equal-on-both cells dominate nobody, so ties
/// all stay on the frontier; the scan is index-ordered and deterministic.
fn mark_pareto(family: &mut [TournamentCell]) {
    let scores: Vec<(f64, f64)> = family.iter().map(|c| (c.cost_usd, c.accuracy)).collect();
    for (i, cell) in family.iter_mut().enumerate() {
        let (ci, ai) = scores[i];
        cell.pareto = !scores.iter().enumerate().any(|(j, &(cj, aj))| {
            j != i && cj <= ci && aj >= ai && (cj < ci || aj > ai)
        });
    }
}

/// Run the tournament grid. Cells are scheduled over a work-stealing
/// cursor onto `cfg.threads` std threads; output order is deterministic
/// (framework × attack × rule, as configured) regardless of thread count.
pub fn run(cfg: &TournamentConfig) -> Result<Tournament> {
    anyhow::ensure!(
        cfg.workers >= 5,
        "tournament needs >= 5 workers so krum:2 has n >= f + 3 contributions"
    );
    anyhow::ensure!(
        !cfg.frameworks.is_empty() && !cfg.attacks.is_empty(),
        "empty tournament grid"
    );
    let acc = accuracy_axis(cfg.seed)?;
    let roster = rules();

    let tasks: Vec<(FrameworkKind, Attack, usize)> = cfg
        .frameworks
        .iter()
        .flat_map(|&fw| {
            cfg.attacks.iter().flat_map(move |&a| (0..roster.len()).map(move |r| (fw, a, r)))
        })
        .collect();
    let threads = if cfg.threads > 0 {
        cfg.threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
    .clamp(1, tasks.len());

    let cursor = AtomicUsize::new(0);
    type CellOut = (f64, f64, RecoveryStats);
    let outputs: Vec<Vec<(usize, Result<CellOut>)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut out = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= tasks.len() {
                            break;
                        }
                        let (fw, attack, r) = tasks[i];
                        out.push((i, run_cell(cfg, fw, attack, roster[r])));
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("tournament thread panicked")).collect()
    });

    let mut indexed: Vec<(usize, CellOut)> = Vec::with_capacity(tasks.len());
    for (i, res) in outputs.into_iter().flatten() {
        indexed.push((i, res?));
    }
    indexed.sort_by_key(|(i, _)| *i);

    let mut cells: Vec<TournamentCell> = indexed
        .into_iter()
        .map(|(i, (vtime_secs, cost_usd, recovery))| {
            let (fw, attack, r) = tasks[i];
            let accuracy = match attack {
                Attack::Coalition => acc.coalition[r],
                _ => acc.clean[r],
            };
            TournamentCell {
                framework: fw,
                attack,
                rule: roster[r],
                vtime_secs,
                cost_usd,
                accuracy,
                recovery,
                pareto: false,
            }
        })
        .collect();

    // Families are contiguous runs of `roster.len()` cells by construction.
    for family in cells.chunks_mut(roster.len()) {
        mark_pareto(family);
    }
    Ok(Tournament { cells, clean_acc: acc.clean[0] })
}

fn attack_blurb(attack: Attack) -> &'static str {
    match attack {
        Attack::Coalition => {
            "Workers 1 and 2 collude, both submitting Scale(-8)-poisoned updates on the \
             same rounds from the middle epoch onward. The accuracy column is the \
             real-gradient demo task under the same 2-of-8 coalition: the plain mean \
             diverges, the robust rules hold."
        }
        Attack::Partition => {
            "Worker 1 is partitioned from the network over virtual seconds [0, 45): every \
             communication op it attempts defers to the heal, so its writes surface to \
             the quorum/visibility paths only afterwards. Gradients are never corrupted, \
             so accuracy is each rule's clean-run accuracy."
        }
        Attack::StragglerTail => {
            "Workers 1-3 draw deterministic Pareto(alpha=1.5) compute slowdowns every \
             round from the middle epoch onward — the occasional 10x+ tail event is the \
             point. Accuracy is each rule's clean-run accuracy."
        }
        Attack::PreemptionStorm => {
            "Workers 1-3 are spot-preempted in one correlated burst mid-epoch; each pays \
             a cold-start restart, billed like any invocation retry. Accuracy is each \
             rule's clean-run accuracy."
        }
    }
}

/// Build the tournament report: one section per attack, each a
/// (framework × rule) table with the Pareto verdict. No paper anchors —
/// the grid extends beyond the paper; its hard bounds live in the tests.
pub fn report(t: &Tournament, cfg: &TournamentConfig) -> Report {
    let fw_names: Vec<&str> = cfg.frameworks.iter().map(|f| f.name()).collect();
    let attack_names: Vec<&str> = cfg.attacks.iter().map(|a| a.name()).collect();
    let mut rep = Report::new(
        "tournament",
        "Robustness tournament — aggregation rule × attack × architecture",
        format!(
            "slsgpu robustness-tournament --model {} --workers {} --epochs {} --seed {}",
            cfg.model, cfg.workers, cfg.epochs, cfg.seed
        ),
    )
    .with_intro(format!(
        "Every cell is one deterministic session of the {} workload ({} workers, {} \
         epochs) under one adversarial regime, with the named aggregation rule driving \
         every aggregation in the protocol (its extra compute is billed on the virtual \
         clock and in the ledger). Accuracy comes from the real-gradient logistic demo \
         task under the same regime; the fault-free mean reaches {:.1}%. Within each \
         (attack, architecture) family a rule is Pareto-optimal (*) when no other rule \
         is at least as cheap and at least as accurate with one strict improvement. \
         Architectures: {}. Attacks: {}.",
        cfg.model,
        cfg.workers,
        cfg.epochs,
        t.clean_acc * 100.0,
        fw_names.join(", "),
        attack_names.join(", "),
    ));

    for &attack in &cfg.attacks {
        let mut table = Table::new(
            format!("tournament_{}", attack.name().replace('-', "_")),
            &[
                ("Framework", Align::Left),
                ("Rule", Align::Left),
                ("Time (s)", Align::Right),
                ("Cost ($)", Align::Right),
                ("Acc (%)", Align::Right),
                ("dAcc (pts)", Align::Right),
                ("Pareto", Align::Left),
                ("Recovery", Align::Left),
            ],
        )
        .title(format!("Attack: {}", attack.name()));
        let mut last_fw: Option<FrameworkKind> = None;
        for cell in t.cells.iter().filter(|c| c.attack == attack) {
            if last_fw.is_some() && last_fw != Some(cell.framework) {
                table.rule();
            }
            last_fw = Some(cell.framework);
            let dacc = (cell.accuracy - t.clean_acc) * 100.0;
            table.push_row(vec![
                RCell::text(cell.framework.name()),
                RCell::text(cell.rule.name()),
                RCell::num(cell.vtime_secs, 1),
                RCell::num(cell.cost_usd, 4),
                RCell::num(cell.accuracy * 100.0, 1),
                RCell::text(format!("{dacc:+.1}")).with_value(dacc),
                RCell::text(if cell.pareto { "*" } else { "-" }),
                RCell::text(cell.recovery.summary()),
            ]);
        }
        rep = rep.with_section(
            Section::new()
                .heading(format!("Attack: {}", attack.name()))
                .paragraph(attack_blurb(attack))
                .table(table),
        );
    }
    rep.with_note(
        "Bit-identical across reruns and thread counts: every cell is an independent \
         seeded simulation, the accuracy axis is a seeded real-gradient run, and the \
         Pareto scan is index-ordered (asserted in the tests and in \
         rust/tests/determinism.rs).",
    )
}

/// CLI view of [`report`].
pub fn render(t: &Tournament, cfg: &TournamentConfig) -> String {
    report(t, cfg).to_text()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> TournamentConfig {
        TournamentConfig {
            frameworks: vec![FrameworkKind::Spirt, FrameworkKind::AllReduce],
            epochs: 1,
            threads: 2,
            ..TournamentConfig::default()
        }
    }

    #[test]
    fn grid_covers_every_cell_and_marks_a_frontier() {
        let cfg = small();
        let t = run(&cfg).unwrap();
        assert_eq!(t.cells.len(), 2 * Attack::ALL.len() * rules().len());
        for cell in &t.cells {
            assert!(cell.vtime_secs > 0.0, "{cell:?}");
            assert!(cell.cost_usd > 0.0, "{cell:?}");
            assert!(cell.accuracy > 0.0 && cell.accuracy <= 1.0, "{cell:?}");
        }
        // Every (attack, framework) family keeps at least one cell on the
        // Pareto frontier (a non-empty finite set always has a maximum).
        for fw in &cfg.frameworks {
            for attack in Attack::ALL {
                assert!(
                    t.cells
                        .iter()
                        .any(|c| c.framework == *fw && c.attack == attack && c.pareto),
                    "{fw:?}/{attack:?} family has an empty frontier"
                );
            }
        }
        // The regimes actually fired, and only in their own columns.
        for cell in &t.cells {
            match cell.attack {
                Attack::Coalition => assert!(cell.recovery.poisoned_grads > 0, "{cell:?}"),
                Attack::Partition => assert!(cell.recovery.partition_secs > 0.0, "{cell:?}"),
                Attack::StragglerTail => assert!(cell.recovery.straggler_secs > 0.0, "{cell:?}"),
                Attack::PreemptionStorm => {
                    assert_eq!(cell.recovery.preemptions, STORM_VICTIMS.len() as u64, "{cell:?}")
                }
            }
        }
        let text = render(&t, &cfg);
        assert!(text.contains("Attack: coalition"), "{text}");
        assert!(text.contains("krum"), "{text}");
        assert!(text.contains('*'), "{text}");
    }

    #[test]
    fn deterministic_across_reruns_and_thread_counts() {
        let mut serial = small();
        serial.threads = 1;
        let mut parallel = small();
        parallel.threads = 4;
        let a = run(&serial).unwrap();
        let b = run(&parallel).unwrap();
        let c = run(&parallel).unwrap();
        assert_eq!(a.cells.len(), b.cells.len());
        for ((x, y), z) in a.cells.iter().zip(&b.cells).zip(&c.cells) {
            assert_eq!(x.framework, y.framework);
            assert_eq!(x.attack, y.attack);
            assert_eq!(x.rule, y.rule);
            for (p, q) in [(x, y), (y, z)] {
                assert_eq!(
                    p.vtime_secs.to_bits(),
                    q.vtime_secs.to_bits(),
                    "{:?}/{:?}/{}",
                    p.framework,
                    p.attack,
                    p.rule.name()
                );
                assert_eq!(p.cost_usd.to_bits(), q.cost_usd.to_bits());
                assert_eq!(p.accuracy.to_bits(), q.accuracy.to_bits());
                assert_eq!(p.pareto, q.pareto);
            }
        }
        assert_eq!(render(&a, &serial), render(&b, &parallel));
    }

    /// The acceptance headline: under the 2-of-8 coalition the plain mean
    /// demonstrably diverges while krum:2 and trimmed:2 recover to within
    /// tolerance of the fault-free accuracy.
    #[test]
    fn coalition_mean_diverges_robust_rules_recover() {
        let cfg = TournamentConfig {
            frameworks: vec![FrameworkKind::Spirt],
            attacks: vec![Attack::Coalition],
            epochs: 1,
            threads: 2,
            ..TournamentConfig::default()
        };
        let t = run(&cfg).unwrap();
        assert!(t.clean_acc > 0.85, "baseline learns the task, got {:.3}", t.clean_acc);
        let acc = |rule: AggregationRule| {
            t.cells.iter().find(|c| c.rule == rule).map(|c| c.accuracy).unwrap()
        };
        assert!(
            acc(AggregationRule::Mean) < t.clean_acc - 0.05,
            "mean must diverge under the coalition: {:.3} vs clean {:.3}",
            acc(AggregationRule::Mean),
            t.clean_acc
        );
        // Trimmed mean still averages n-2k honest shards, so it sits close
        // to the clean mean; Krum selects a *single* honest shard gradient
        // per round (1/8 of the data), so it pays a visible but bounded
        // selection-noise penalty — its tolerance is looser on purpose.
        assert!(
            acc(AggregationRule::TrimmedMean { k: 2 }) >= t.clean_acc - 0.04,
            "trimmed-mean must recover within 4 points: {:.3} vs clean {:.3}",
            acc(AggregationRule::TrimmedMean { k: 2 }),
            t.clean_acc
        );
        assert!(
            acc(AggregationRule::Krum { f: 2 }) >= t.clean_acc - 0.07,
            "krum must recover within 7 points: {:.3} vs clean {:.3}",
            acc(AggregationRule::Krum { f: 2 }),
            t.clean_acc
        );
        // Krum's extra passes are billed: its sessions cost more than mean's.
        let cost = |rule: AggregationRule| {
            t.cells.iter().find(|c| c.rule == rule).map(|c| c.cost_usd).unwrap()
        };
        assert!(cost(AggregationRule::Krum { f: 2 }) > cost(AggregationRule::Mean));
    }

    #[test]
    fn attack_specs_round_trip() {
        for a in Attack::ALL {
            assert_eq!(Attack::parse(a.name()).unwrap(), a);
        }
        assert!(Attack::parse("sybil").is_err());
    }
}
