"""Shared layer primitives for the model zoo.

Every layer is a pair of pure functions:

  init_<layer>(key, ...) -> params (a dict of arrays)
  <layer>(params, x, ...) -> y

Conventions: NHWC activations, HWIO conv kernels, f32 everywhere. Pointwise
(1x1) convs and dense layers route through the Pallas matmul kernel so the
model's GEMM hot path exercises the Layer-1 schedule end to end.
"""

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import matmul

_DN = ("NHWC", "HWIO", "NHWC")


def he_normal(key, shape, fan_in):
    """He-normal initialization (ReLU-family gain)."""
    std = jnp.sqrt(2.0 / fan_in)
    return std * jax.random.normal(key, shape, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Convolutions


def init_conv(key, kh, kw, cin, cout):
    return {"w": he_normal(key, (kh, kw, cin, cout), kh * kw * cin)}


def conv(params, x, stride=1):
    """Spatial conv, SAME padding (XLA-lowered)."""
    return lax.conv_general_dilated(
        x,
        params["w"],
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=_DN,
    )


def init_depthwise(key, kh, kw, c):
    # HWIO with feature_group_count=c: (kh, kw, 1, c)
    return {"w": he_normal(key, (kh, kw, 1, c), kh * kw)}


def depthwise(params, x, stride=1):
    """3x3 depthwise conv, SAME padding (one filter per channel)."""
    c = x.shape[-1]
    return lax.conv_general_dilated(
        x,
        params["w"],
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=_DN,
        feature_group_count=c,
    )


def init_pointwise(key, cin, cout):
    return {"w": he_normal(key, (cin, cout), cin)}


def pointwise(params, x):
    """1x1 conv as a GEMM through the Pallas matmul kernel."""
    n, h, w, cin = x.shape
    flat = x.reshape(n * h * w, cin)
    out = matmul(flat, params["w"])
    return out.reshape(n, h, w, -1)


# ---------------------------------------------------------------------------
# Normalization / activations


def init_groupnorm(c):
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32)}


def groupnorm(params, x, groups=8, eps=1e-5):
    """GroupNorm over NHWC (stateless BatchNorm substitute)."""
    n, h, w, c = x.shape
    g = min(groups, c)
    while c % g != 0:  # channels are powers of two here, but stay safe
        g -= 1
    xg = x.reshape(n, h, w, g, c // g)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * lax.rsqrt(var + eps)
    x = xg.reshape(n, h, w, c)
    return x * params["scale"] + params["bias"]


def relu(x):
    return jnp.maximum(x, 0.0)


def relu6(x):
    return jnp.clip(x, 0.0, 6.0)


# ---------------------------------------------------------------------------
# Head


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


def init_dense(key, cin, cout):
    kw, kb = jax.random.split(key)
    return {
        "w": he_normal(kw, (cin, cout), cin),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def dense(params, x):
    """Classifier head GEMM through the Pallas matmul kernel."""
    return matmul(x, params["w"]) + params["b"]


# ---------------------------------------------------------------------------
# Loss / metrics


def softmax_cross_entropy(logits, labels):
    """Mean CE over the batch; labels are int32 class ids."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logz, labels[:, None], axis=-1)[:, 0]
    return -jnp.mean(ll)


def correct_count(logits, labels):
    """Number of correct top-1 predictions (f32 scalar)."""
    pred = jnp.argmax(logits, axis=-1).astype(labels.dtype)
    return jnp.sum((pred == labels).astype(jnp.float32))
