//! The per-run event collector: a ring buffer of [`TraceEvent`]s plus the
//! side tables (key → writer, topic → notifies, worker → last event) used to
//! attach happens-before edges at record time.
//!
//! The collector is strictly observational: it never advances a clock,
//! charges a ledger or draws from the RNG, so enabling it cannot perturb the
//! simulated timelines (asserted bit-exactly in `rust/tests/determinism.rs`).
//! When disabled every entry point returns after one boolean test and no
//! allocation happens at all.

use std::collections::{BTreeMap, VecDeque};

use crate::sim::VTime;

use super::event::{EventKind, TraceEvent};

/// Default ring capacity: enough for every experiment in the suite (a
/// 256-worker sweep epoch is ~50k events) while bounding a runaway session.
pub const DEFAULT_CAPACITY: usize = 1 << 20;

/// Tracing knob carried by `EnvConfig`. The default — and the only value any
/// exp driver may use without an explicit opt-in flag — is
/// [`TraceConfig::disabled`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceConfig {
    pub enabled: bool,
    /// Ring capacity in events; oldest events are evicted past this.
    pub capacity: usize,
}

impl TraceConfig {
    /// Tracing off: the zero-cost default everywhere.
    pub fn disabled() -> TraceConfig {
        TraceConfig { enabled: false, capacity: 0 }
    }

    /// Tracing on with the default ring capacity.
    pub fn on() -> TraceConfig {
        TraceConfig { enabled: true, capacity: DEFAULT_CAPACITY }
    }

    pub fn with_capacity(mut self, capacity: usize) -> TraceConfig {
        self.capacity = capacity.max(1);
        self
    }
}

impl Default for TraceConfig {
    fn default() -> TraceConfig {
        TraceConfig::disabled()
    }
}

/// Deterministic per-run event log. Owned by `ClusterEnv`; strategies and
/// `Timeline` methods feed it through the emit API below.
#[derive(Debug, Clone, Default)]
pub struct TraceCollector {
    on: bool,
    capacity: usize,
    /// Live window of the log; `events[0]` has index `first`.
    events: VecDeque<TraceEvent>,
    first: u64,
    dropped: u64,
    epoch: u32,
    round: u32,
    /// Namespaced key (`s3/...`, `s3gpu/...`, `redis<j>/...`) → index of the
    /// event that last wrote it; read ops look their `dep` edge up here.
    writers: BTreeMap<String, u64>,
    /// Queue topic → notify event indices in publish order; `poll(topic, n)`
    /// depends on the n-th publish it waited for.
    notifies: BTreeMap<String, Vec<u64>>,
    last_by_worker: BTreeMap<usize, u64>,
}

impl TraceCollector {
    pub fn new(cfg: &TraceConfig) -> TraceCollector {
        TraceCollector {
            on: cfg.enabled,
            capacity: if cfg.enabled { cfg.capacity.max(1) } else { 0 },
            ..TraceCollector::default()
        }
    }

    pub fn enabled(&self) -> bool {
        self.on
    }

    /// Stamp subsequent events with this 1-based epoch and reset the round.
    pub fn begin_epoch(&mut self, epoch: usize) {
        if self.on {
            self.epoch = epoch as u32;
            self.round = 0;
        }
    }

    /// Stamp subsequent events with this round (minibatch for SPIRT).
    pub fn set_round(&mut self, round: usize) {
        if self.on {
            self.round = round as u32;
        }
    }

    /// Record a span. Returns its index, or `None` when tracing is off.
    pub fn span(
        &mut self,
        worker: usize,
        t0: VTime,
        t1: VTime,
        kind: EventKind,
        bytes: u64,
        cost: f64,
        dep: Option<u64>,
    ) -> Option<u64> {
        if !self.on {
            return None;
        }
        let idx = self.first + self.events.len() as u64;
        let prev = self.last_by_worker.insert(worker, idx);
        self.events.push_back(TraceEvent {
            worker,
            t0,
            t1,
            kind,
            bytes,
            cost,
            round: self.round,
            epoch: self.epoch,
            dep,
            prev,
        });
        if self.events.len() > self.capacity {
            self.events.pop_front();
            self.first += 1;
            self.dropped += 1;
        }
        Some(idx)
    }

    /// Record a zero-duration marker (fault instants).
    pub fn instant(&mut self, worker: usize, t: VTime, kind: EventKind) -> Option<u64> {
        self.span(worker, t, t, kind, 0, 0.0, None)
    }

    /// Register `idx` as the current writer of `key` (namespaced).
    pub fn note_write(&mut self, key: String, idx: Option<u64>) {
        if let Some(i) = idx {
            self.writers.insert(key, i);
        }
    }

    /// The event that last wrote `key`, if traced and still resident.
    pub fn writer_of(&self, key: &str) -> Option<u64> {
        self.writers.get(key).copied()
    }

    /// Among `keys`, the writer that finished last — the edge that actually
    /// gates a batched `get_many`. Ties break on event index, so the result
    /// is deterministic.
    pub fn binding_writer(&self, keys: impl IntoIterator<Item = String>) -> Option<u64> {
        keys.into_iter()
            .filter_map(|k| self.writer_of(&k))
            .filter_map(|i| self.get(i).map(|e| (e.t1, i)))
            .max()
            .map(|(_, i)| i)
    }

    /// Register a queue publish so later polls can find their edge.
    pub fn note_notify(&mut self, topic: &str, idx: Option<u64>) {
        if let Some(i) = idx {
            self.notifies.entry(topic.to_string()).or_default().push(i);
        }
    }

    /// The publish a `poll(topic, count)` was gated on: the `count`-th
    /// notify on that topic (queues deliver in publish order).
    pub fn notify_dep(&self, topic: &str, count: usize) -> Option<u64> {
        self.notifies.get(topic)?.get(count.checked_sub(1)?).copied()
    }

    /// Index of the most recent event on `worker`'s track.
    pub fn last_event_of(&self, worker: usize) -> Option<u64> {
        self.last_by_worker.get(&worker).copied()
    }

    /// Resolve an event index; `None` once evicted from the ring.
    pub fn get(&self, idx: u64) -> Option<&TraceEvent> {
        if idx < self.first {
            return None;
        }
        self.events.get((idx - self.first) as usize)
    }

    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        self.events.iter()
    }

    /// `(index, event)` pairs for the resident window.
    pub fn iter_indexed(&self) -> impl Iterator<Item = (u64, &TraceEvent)> {
        (self.first..).zip(self.events.iter())
    }

    /// Copy the resident window out for export/analysis.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.events.iter().copied().collect()
    }

    /// Index of the oldest resident event.
    pub fn first_index(&self) -> u64 {
        self.first
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events evicted by the ring so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> VTime {
        VTime::from_secs(s)
    }

    #[test]
    fn disabled_collector_records_nothing() {
        let mut c = TraceCollector::new(&TraceConfig::disabled());
        assert!(!c.enabled());
        assert_eq!(c.span(0, t(0.0), t(1.0), EventKind::Put, 8, 0.1, None), None);
        assert_eq!(c.instant(0, t(1.0), EventKind::Poison), None);
        c.note_write("s3/k".into(), None);
        c.note_notify("topic", None);
        assert!(c.is_empty());
        assert_eq!(c.writer_of("s3/k"), None);
        assert_eq!(c.notify_dep("topic", 1), None);
    }

    #[test]
    fn indices_prev_and_dep_lookups() {
        let mut c = TraceCollector::new(&TraceConfig::on());
        c.begin_epoch(1);
        c.set_round(3);
        let a = c.span(0, t(0.0), t(1.0), EventKind::Put, 8, 0.0, None);
        c.note_write("s3/k".into(), a);
        let b = c.span(1, t(0.5), t(2.0), EventKind::Put, 8, 0.0, None);
        c.note_write("s3/j".into(), b);
        let g = c.span(0, t(1.0), t(2.5), EventKind::Get, 8, 0.0, c.writer_of("s3/j"));
        assert_eq!(a, Some(0));
        assert_eq!(b, Some(1));
        assert_eq!(g, Some(2));
        let ev = c.get(2).unwrap();
        assert_eq!(ev.dep, Some(1));
        assert_eq!(ev.prev, Some(0), "same-worker predecessor");
        assert_eq!(ev.epoch, 1);
        assert_eq!(ev.round, 3);
        // Latest-finishing writer wins the batched edge.
        assert_eq!(c.binding_writer(["s3/k".to_string(), "s3/j".to_string()]), Some(1));
        assert_eq!(c.last_event_of(0), Some(2));
    }

    #[test]
    fn notify_order_indexes_poll_deps() {
        let mut c = TraceCollector::new(&TraceConfig::on());
        let n1 = c.span(0, t(0.0), t(0.1), EventKind::Notify, 4, 0.0, None);
        c.note_notify("sync/e1", n1);
        let n2 = c.span(1, t(0.0), t(0.2), EventKind::Notify, 4, 0.0, None);
        c.note_notify("sync/e1", n2);
        assert_eq!(c.notify_dep("sync/e1", 1), n1);
        assert_eq!(c.notify_dep("sync/e1", 2), n2);
        assert_eq!(c.notify_dep("sync/e1", 3), None);
        assert_eq!(c.notify_dep("sync/e1", 0), None);
    }

    #[test]
    fn ring_evicts_oldest_but_never_renumbers() {
        let mut c = TraceCollector::new(&TraceConfig::on().with_capacity(2));
        for i in 0..5 {
            let idx = c.span(0, t(i as f64), t(i as f64 + 0.5), EventKind::Advance, 0, 0.0, None);
            assert_eq!(idx, Some(i as u64));
        }
        assert_eq!(c.len(), 2);
        assert_eq!(c.dropped(), 3);
        assert_eq!(c.first_index(), 3);
        assert!(c.get(2).is_none(), "evicted indices resolve to None");
        assert_eq!(c.get(3).unwrap().t0, t(3.0));
        assert_eq!(c.get(4).unwrap().prev, Some(3));
        let idx: Vec<u64> = c.iter_indexed().map(|(i, _)| i).collect();
        assert_eq!(idx, vec![3, 4]);
    }
}
