//! Integration tests over the full stack: PJRT runtime + coordinator +
//! substrates. All tests that execute artifacts skip gracefully when
//! `make artifacts` has not been run.

use std::path::PathBuf;
use std::rc::Rc;

use slsgpu::cloud::FrameworkKind;
use slsgpu::coordinator::{strategy_for, ClusterEnv, EnvConfig};
use slsgpu::runtime::Engine;
use slsgpu::tensor::{RustMath, Slab, SlabMath};
use slsgpu::train::{run_session, SessionConfig};
use slsgpu::util::rng::Rng;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine() -> Option<Rc<Engine>> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Rc::new(Engine::load(artifacts_dir()).expect("engine load")))
}

#[test]
fn runtime_grad_artifact_descends_loss() {
    let Some(engine) = engine() else { return };
    let model = "mobilenet_s";
    let entry = engine.manifest.model(model).unwrap().clone();
    let theta = engine.init(model, 7).unwrap();
    assert_eq!(theta.len(), entry.n_params);

    let mut rng = Rng::new(3);
    let b = entry.batch;
    let x: Vec<f32> = (0..b * 32 * 32 * 3).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let y: Vec<i32> = (0..b).map(|_| rng.below(10) as i32).collect();

    let out = engine.grad(model, &theta, &x, &y).unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0);
    assert!(out.correct <= b as u32);
    assert_eq!(out.grads.len(), entry.n_params);

    // SGD step through the Pallas artifact reduces the loss on this batch.
    let gnorm = out.grads.l2_norm_sq().sqrt() as f32;
    let theta2 = engine.sgd(model, &theta, &out.grads, 0.1 / gnorm.max(1.0)).unwrap();
    let out2 = engine.grad(model, &theta2, &x, &y).unwrap();
    assert!(
        out2.loss < out.loss,
        "loss must descend: {} -> {}",
        out.loss,
        out2.loss
    );
}

#[test]
fn pjrt_slab_math_matches_rust_math() {
    // The RedisAI analog (PJRT-executed Pallas kernels) must agree with the
    // portable Rust implementation bit-for-bit-ish.
    let Some(engine) = engine() else { return };
    let model = "mobilenet_s";
    let n = engine.manifest.slab(model).unwrap().n;
    let mut rng = Rng::new(11);
    let a = Slab::from_vec((0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect());
    let b = Slab::from_vec((0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect());

    let rust = RustMath;
    let cases: Vec<(Slab, Slab)> = vec![
        (engine.acc(model, &a, &b, 0.25).unwrap(), rust.acc(&a, &b, 0.25).unwrap()),
        (
            engine.avg_update(model, &a, &b, 0.125, 0.05).unwrap(),
            rust.avg_update(&a, &b, 0.125, 0.05).unwrap(),
        ),
        (engine.sgd(model, &a, &b, 0.1).unwrap(), rust.sgd(&a, &b, 0.1).unwrap()),
    ];
    for (i, (pjrt, ref_out)) in cases.iter().enumerate() {
        let p = pjrt.as_slice().unwrap();
        let r = ref_out.as_slice().unwrap();
        let max_err = p
            .iter()
            .zip(r)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-5, "case {i}: max err {max_err}");
    }
}

#[test]
fn eval_artifact_agrees_with_grad_forward() {
    let Some(engine) = engine() else { return };
    let model = "mobilenet_s";
    let entry = engine.manifest.model(model).unwrap().clone();
    let theta = engine.init(model, 5).unwrap();
    let mut rng = Rng::new(9);
    let be = entry.eval_batch;
    let xe: Vec<f32> = (0..be * 32 * 32 * 3).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let ye: Vec<i32> = (0..be).map(|_| rng.below(10) as i32).collect();
    let (loss, correct) = engine.eval(model, &theta, &xe, &ye).unwrap();
    assert!(loss.is_finite());
    assert!(correct <= be as u32);
}

#[test]
fn every_framework_trains_one_epoch_end_to_end() {
    let Some(engine) = engine() else { return };
    for fw in FrameworkKind::ALL {
        let cfg =
            EnvConfig::real(fw, engine.clone(), "mobilenet_s", 2, 256, 42).expect("env cfg");
        let mut env = ClusterEnv::new(cfg).expect("env");
        let mut strategy = strategy_for(fw);
        let stats = strategy.run_epoch(&mut env).unwrap_or_else(|e| panic!("{fw:?}: {e:#}"));
        assert!(stats.mean_loss.unwrap() > 0.0, "{fw:?}");
        assert!(stats.epoch_secs > 0.0, "{fw:?}");
        assert!(env.ledger.total_paper() > 0.0, "{fw:?}");
        // All replicas hold finite parameters after the epoch.
        for w in &env.workers {
            assert!(w.theta.is_real(), "{fw:?}");
            assert!(w.theta.l2_norm_sq().is_finite(), "{fw:?}");
        }
    }
}

#[test]
fn synchronous_frameworks_keep_replicas_consistent() {
    // AllReduce / ScatterReduce / GPU apply identical global updates: every
    // worker's replica must stay bitwise identical across an epoch.
    let Some(engine) = engine() else { return };
    for fw in [FrameworkKind::AllReduce, FrameworkKind::ScatterReduce, FrameworkKind::GpuBaseline]
    {
        let cfg = EnvConfig::real(fw, engine.clone(), "mobilenet_s", 2, 256, 1).unwrap();
        let mut env = ClusterEnv::new(cfg).unwrap();
        let mut strategy = strategy_for(fw);
        strategy.run_epoch(&mut env).unwrap();
        let w0 = env.workers[0].theta.as_slice().unwrap().to_vec();
        for w in &env.workers[1..] {
            let max_err = w
                .theta
                .as_slice()
                .unwrap()
                .iter()
                .zip(&w0)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(max_err < 2e-5, "{fw:?}: replicas diverged by {max_err}");
        }
    }
}

#[test]
fn spirt_theta_lives_in_the_database() {
    let Some(engine) = engine() else { return };
    let cfg =
        EnvConfig::real(FrameworkKind::Spirt, engine, "mobilenet_s", 2, 256, 2).unwrap();
    let mut env = ClusterEnv::new(cfg).unwrap();
    let mut strategy = strategy_for(FrameworkKind::Spirt);
    strategy.run_epoch(&mut env).unwrap();
    // Replica mirror equals the in-database model.
    for (w, redis) in env.workers.iter().zip(&env.worker_redis) {
        let db = redis.peek_slab("theta").unwrap();
        assert_eq!(db.as_slice().unwrap(), w.theta.as_slice().unwrap());
    }
    // SPIRT synchronized once (per epoch), not per batch.
    assert_eq!(env.queues.total_published(), 2);
}

#[test]
fn short_session_improves_accuracy() {
    // Three epochs of the GPU baseline on the easy synthetic task must lift
    // accuracy well above chance — the whole stack learns.
    let Some(engine) = engine() else { return };
    let cfg =
        EnvConfig::real(FrameworkKind::GpuBaseline, engine, "mobilenet_s", 4, 1024, 42).unwrap();
    let mut env = ClusterEnv::new(cfg).unwrap();
    let mut strategy = strategy_for(FrameworkKind::GpuBaseline);
    let session = SessionConfig { max_epochs: 3, target_acc: 0.99, patience: 10, evaluate: true };
    let report = run_session(&mut env, strategy.as_mut(), &session).unwrap();
    let first = report.reports.first().unwrap().test_acc.unwrap();
    let last = report.final_acc.unwrap();
    assert!(last > 0.15, "accuracy after 3 epochs: {last}");
    assert!(last > first - 0.02, "accuracy should not regress: {first} -> {last}");
}
