//! S3-like object store: put/get with latency + bandwidth + request fees.
//!
//! The store frontend is modeled as a wide queueing resource (S3 scales
//! horizontally; per-stream bandwidth and per-request latency are what a
//! client observes). The GPU baseline synchronizes gradients through this
//! substrate, LambdaML (AllReduce/ScatterReduce) uses it as the shared
//! gradient bucket, and Lambda state loads read batches from it.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::metrics::{CommKind, CommStats, CostKind, Ledger};
use crate::sim::{Resource, VTime};
use crate::tensor::Slab;

use super::calibration::{S3_BW, S3_LATENCY};
use super::pricing;

/// In-process S3: objects are real slabs, time is virtual.
#[derive(Debug)]
pub struct ObjectStore {
    // Key -> (value, time it became visible). Ordered map: only keyed
    // lookups touch it (unordered-iteration audit invariant).
    objects: BTreeMap<String, (Slab, VTime)>,
    frontend: Resource,
    latency: f64,
    bandwidth: f64,
}

impl Default for ObjectStore {
    fn default() -> Self {
        Self::new()
    }
}

impl ObjectStore {
    pub fn new() -> ObjectStore {
        ObjectStore::with_profile(S3_LATENCY, S3_BW, 64)
    }

    /// Custom latency/bandwidth/parallelism (used by ablation benches).
    pub fn with_profile(latency: f64, bandwidth: f64, servers: usize) -> ObjectStore {
        ObjectStore {
            objects: BTreeMap::new(),
            frontend: Resource::new("s3", servers),
            latency,
            bandwidth,
        }
    }

    /// PUT: object becomes visible when the transfer completes. The
    /// per-request latency is client-side RTT (it does not consume server
    /// capacity); only the byte transfer occupies a frontend server.
    pub fn put(
        &mut self,
        now: VTime,
        key: &str,
        slab: Slab,
        ledger: &mut Ledger,
        comm: &mut CommStats,
    ) -> VTime {
        let bytes = slab.nbytes();
        let served = self.frontend.serve(now + self.latency, bytes as f64 / self.bandwidth);
        let done = served.end;
        self.objects.insert(key.to_string(), (slab, done));
        ledger.charge(CostKind::S3Requests, pricing::s3_put_cost(1));
        comm.record(CommKind::Put, bytes);
        comm.comm_time += done - now;
        done
    }

    /// GET: blocks (in virtual time) until the object is visible, then
    /// transfers it. Returns the completion time and a copy of the slab.
    pub fn get(
        &mut self,
        now: VTime,
        key: &str,
        ledger: &mut Ledger,
        comm: &mut CommStats,
    ) -> Result<(VTime, Slab)> {
        let (slab, visible) = self
            .objects
            .get(key)
            .ok_or_else(|| anyhow!("object not found: {key}"))?
            .clone();
        let start = now.max(visible) + self.latency;
        let done = self.frontend.serve(start, slab.nbytes() as f64 / self.bandwidth).end;
        ledger.charge(CostKind::S3Requests, pricing::s3_get_cost(1));
        comm.record(CommKind::Get, slab.nbytes());
        comm.comm_time += done - now;
        Ok((done, slab))
    }

    /// Pipelined bulk GET over one connection: a single request latency,
    /// then sequential transfers (the LambdaML master's reduce loop fetches
    /// all worker gradients with connection reuse).
    pub fn get_many(
        &mut self,
        now: VTime,
        keys: &[String],
        ledger: &mut Ledger,
        comm: &mut CommStats,
    ) -> Result<(VTime, Vec<Slab>)> {
        let mut t = now + self.latency;
        let mut out = Vec::with_capacity(keys.len());
        for key in keys {
            let (slab, visible) = self
                .objects
                .get(key)
                .ok_or_else(|| anyhow!("object not found: {key}"))?
                .clone();
            let start = t.max(visible);
            t = self.frontend.serve(start, slab.nbytes() as f64 / self.bandwidth).end;
            ledger.charge(CostKind::S3Requests, pricing::s3_get_cost(1));
            comm.record(CommKind::Get, slab.nbytes());
            out.push(slab);
        }
        comm.comm_time += t - now;
        Ok((t, out))
    }

    /// Earliest virtual time at which `key` is readable (None if absent).
    pub fn visible_at(&self, key: &str) -> Option<VTime> {
        self.objects.get(key).map(|(_, t)| *t)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.objects.contains_key(key)
    }

    pub fn delete(&mut self, key: &str) {
        self.objects.remove(key);
    }

    pub fn len(&self) -> usize {
        self.objects.len()
    }

    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }

    /// Drop frontend busy history that ended at or before `before` (see
    /// `sim::Resource::release`). Placement of every request arriving at
    /// or after the watermark is unchanged; only the interval history's
    /// memory is bounded. At W=4096 a single ScatterReduce epoch issues
    /// tens of millions of frontend requests — without this the sweep's
    /// busy-interval maps are the dominant allocation.
    pub fn prune_history(&mut self, before: VTime) {
        self.frontend.release(before);
    }

    /// Reset timeline + contents (new experiment).
    pub fn clear(&mut self) {
        self.objects.clear();
        self.frontend.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> (Ledger, CommStats) {
        (Ledger::new(), CommStats::new())
    }

    #[test]
    fn put_get_times_match_closed_form() {
        // Pin put/get completion times to the closed-form latency model.
        // The store container holds (slab, visibility) per key and is only
        // ever consulted by keyed lookup, so these exact f64 equalities are
        // invariant under the HashMap->BTreeMap swap (and would catch any
        // future change that lets container state leak into the timeline).
        let mut s3 = ObjectStore::new();
        let (mut l, mut c) = env();
        let slab = Slab::from_vec(vec![0.5f32; 1024]);
        let bytes = slab.nbytes() as f64;
        let t_put = s3.put(VTime::ZERO, "k", slab, &mut l, &mut c);
        assert_eq!(t_put.secs(), S3_LATENCY + bytes / S3_BW);
        let (t_get, _) = s3.get(t_put, "k", &mut l, &mut c).unwrap();
        assert_eq!(t_get.secs(), t_put.secs() + S3_LATENCY + bytes / S3_BW);
    }

    #[test]
    fn put_get_roundtrip_preserves_data() {
        let mut s3 = ObjectStore::new();
        let (mut l, mut c) = env();
        let t1 = s3.put(VTime::ZERO, "g/0", Slab::from_vec(vec![1.0, 2.0]), &mut l, &mut c);
        let (t2, slab) = s3.get(t1, "g/0", &mut l, &mut c).unwrap();
        assert!(t2 > t1);
        assert_eq!(slab.as_slice().unwrap(), &[1.0, 2.0]);
        assert_eq!(c.ops(CommKind::Put), 1);
        assert_eq!(c.ops(CommKind::Get), 1);
        assert!(l.get(CostKind::S3Requests) > 0.0);
    }

    #[test]
    fn get_waits_for_visibility() {
        let mut s3 = ObjectStore::new();
        let (mut l, mut c) = env();
        // Writer finishes at ~t=0.5 (100 MB at 100 MB/s handled below).
        let big = Slab::virtual_of(10_000_000); // 40 MB -> 0.4 s + latency
        let vis = s3.put(VTime::ZERO, "k", big, &mut l, &mut c);
        // Reader arrives earlier than visibility.
        let (done, _) = s3.get(VTime::ZERO, "k", &mut l, &mut c).unwrap();
        assert!(done > vis, "reader must wait for the writer");
    }

    #[test]
    fn transfer_time_scales_with_bytes() {
        let mut s3 = ObjectStore::new();
        let (mut l, mut c) = env();
        let t_small = s3.put(VTime::ZERO, "a", Slab::virtual_of(1000), &mut l, &mut c);
        let mut s3b = ObjectStore::new();
        let t_big = s3b.put(VTime::ZERO, "b", Slab::virtual_of(25_000_000), &mut l, &mut c);
        assert!(t_big.secs() > t_small.secs() + 0.5);
    }

    #[test]
    fn missing_key_errors() {
        let mut s3 = ObjectStore::new();
        let (mut l, mut c) = env();
        assert!(s3.get(VTime::ZERO, "nope", &mut l, &mut c).is_err());
    }

    #[test]
    fn comm_time_accumulates() {
        let mut s3 = ObjectStore::new();
        let (mut l, mut c) = env();
        s3.put(VTime::ZERO, "a", Slab::virtual_of(100), &mut l, &mut c);
        assert!(c.comm_time >= S3_LATENCY);
    }
}
