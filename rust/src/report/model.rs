//! The typed report model: [`Report`] → [`Section`] → [`Table`] → [`Row`]
//! → [`Cell`], with optional paper [`Anchor`]s and tolerance [`Verdict`]s.
//!
//! Every experiment driver builds one of these instead of printing; the
//! renderers in [`super::render`] turn the same value into the CLI text
//! view, a `docs/` Markdown page, CSV, or machine-readable JSON — so the
//! documented reproduction status can never drift from what the simulator
//! measured. The `rel_err`/`vs_paper` helpers that used to live in
//! `exp::mod` are generalized here (and re-exported from `exp` for
//! backward compatibility): an anchored cell carries the measured value,
//! the paper value and the tolerance, and derives its PASS/WARN verdict
//! from exactly the relative error the experiment tests assert.

use crate::util::table::Align;

/// Relative error of a measured value against a paper anchor. A zero paper
/// value has no meaningful relative error, so it reports 0 (see
/// `exp::vs_paper` for the rendering consequence).
pub fn rel_err(measured: f64, paper: f64) -> f64 {
    if paper == 0.0 {
        return 0.0;
    }
    (measured - paper).abs() / paper.abs()
}

/// Format a measured-vs-paper cell: `measured (paper, ±err%)`. A zero paper
/// value has no meaningful relative error (and dividing by it would render
/// `inf`/`NaN`), so the percentage is omitted for that cell.
pub fn vs_paper(measured: f64, paper: f64, digits: usize) -> String {
    if paper == 0.0 {
        return format!("{measured:.prec$} (paper {paper:.prec$})", prec = digits);
    }
    format!(
        "{measured:.prec$} (paper {paper:.prec$}, {:+.1}%)",
        (measured - paper) / paper * 100.0,
        prec = digits
    )
}

/// Outcome of checking a measured value against a paper anchor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Relative error within the anchor's tolerance.
    Pass,
    /// Relative error beyond the anchor's tolerance — the cell is flagged in
    /// rendered docs, but nothing fails: WARN is a documentation state, the
    /// hard bounds live in the experiment tests.
    Warn,
}

impl Verdict {
    pub fn name(&self) -> &'static str {
        match self {
            Verdict::Pass => "PASS",
            Verdict::Warn => "WARN",
        }
    }
}

/// A paper-value anchor with a relative-error tolerance.
///
/// The tolerance is the same band the experiment's unit tests assert (e.g.
/// Table 2 per-batch durations: 15%), so a WARN in the rendered docs and a
/// failing tolerance test fire on the same boundary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Anchor {
    /// The paper's reported value.
    pub paper: f64,
    /// Maximum relative error considered PASS (inclusive).
    pub tol: f64,
}

impl Anchor {
    pub fn new(paper: f64, tol: f64) -> Anchor {
        Anchor { paper, tol }
    }

    /// PASS iff `rel_err(measured, paper) <= tol` — byte-for-byte the
    /// `exp::rel_err` definition (asserted in `rust/tests/report.rs`).
    pub fn verdict(&self, measured: f64) -> Verdict {
        if rel_err(measured, self.paper) <= self.tol {
            Verdict::Pass
        } else {
            Verdict::Warn
        }
    }
}

/// One table cell: rendered text plus optional machine-readable value and
/// paper anchor.
#[derive(Debug, Clone)]
pub struct Cell {
    /// The text exactly as the CLI table renders it.
    pub text: String,
    /// The raw measured value (exported to JSON/CSV; drives the verdict).
    pub value: Option<f64>,
    /// Paper anchor + tolerance, when the paper reports this quantity.
    pub anchor: Option<Anchor>,
}

impl Cell {
    /// A plain text cell (labels, qualitative content, em-dashes).
    pub fn text(text: impl Into<String>) -> Cell {
        Cell { text: text.into(), value: None, anchor: None }
    }

    /// A numeric cell rendered with fixed decimals.
    pub fn num(value: f64, digits: usize) -> Cell {
        debug_assert!(value.is_finite(), "non-finite cell value {value}");
        Cell { text: format!("{value:.digits$}"), value: Some(value), anchor: None }
    }

    /// An integer count cell.
    pub fn count(value: u64) -> Cell {
        Cell { text: value.to_string(), value: Some(value as f64), anchor: None }
    }

    /// An anchored numeric cell with custom text (legacy CLI formats).
    pub fn anchored(text: impl Into<String>, measured: f64, paper: f64, tol: f64) -> Cell {
        debug_assert!(measured.is_finite(), "non-finite cell value {measured}");
        Cell { text: text.into(), value: Some(measured), anchor: Some(Anchor::new(paper, tol)) }
    }

    /// An anchored cell in the canonical `measured (paper X, ±err%)` format.
    pub fn vs_paper(measured: f64, paper: f64, digits: usize, tol: f64) -> Cell {
        Cell::anchored(vs_paper(measured, paper, digits), measured, paper, tol)
    }

    /// Attach a raw value to a text cell (keeps the custom rendering).
    pub fn with_value(mut self, value: f64) -> Cell {
        self.value = Some(value);
        self
    }

    /// PASS/WARN for anchored cells with a value; `None` otherwise.
    pub fn verdict(&self) -> Option<Verdict> {
        match (self.value, self.anchor) {
            (Some(v), Some(a)) => Some(a.verdict(v)),
            _ => None,
        }
    }
}

/// A table column: header name + alignment (shared with the ASCII renderer).
#[derive(Debug, Clone)]
pub struct Column {
    pub name: String,
    pub align: Align,
}

/// One table row.
#[derive(Debug, Clone)]
pub struct Row {
    pub cells: Vec<Cell>,
}

/// A typed table: the unit the renderers align, link and export.
#[derive(Debug, Clone)]
pub struct Table {
    /// Stable identifier (used for CSV/JSON export labels).
    pub id: String,
    /// Title line above the table (the legacy CLI table title).
    pub title: Option<String>,
    pub columns: Vec<Column>,
    pub rows: Vec<Row>,
    /// Row counts after which a horizontal rule is drawn (section breaks).
    pub rules: Vec<usize>,
}

impl Table {
    pub fn new(id: impl Into<String>, columns: &[(&str, Align)]) -> Table {
        Table {
            id: id.into(),
            title: None,
            columns: columns
                .iter()
                .map(|(name, align)| Column { name: name.to_string(), align: *align })
                .collect(),
            rows: Vec::new(),
            rules: Vec::new(),
        }
    }

    pub fn title(mut self, title: impl Into<String>) -> Table {
        self.title = Some(title.into());
        self
    }

    pub fn push_row(&mut self, cells: Vec<Cell>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch in table {}", self.id);
        self.rows.push(Row { cells });
    }

    /// Draw a horizontal rule after the last added row (section break).
    pub fn rule(&mut self) {
        self.rules.push(self.rows.len());
    }

    /// (PASS count, WARN count) over all anchored cells.
    pub fn verdicts(&self) -> (usize, usize) {
        let mut pass = 0;
        let mut warn = 0;
        for row in &self.rows {
            for cell in &row.cells {
                match cell.verdict() {
                    Some(Verdict::Pass) => pass += 1,
                    Some(Verdict::Warn) => warn += 1,
                    None => {}
                }
            }
        }
        (pass, warn)
    }
}

/// A report section: optional heading, leading paragraphs, tables, and
/// trailing notes (rendered after the tables, like the legacy CLI footers).
#[derive(Debug, Clone, Default)]
pub struct Section {
    pub heading: Option<String>,
    pub paragraphs: Vec<String>,
    pub tables: Vec<Table>,
    pub notes: Vec<String>,
}

impl Section {
    pub fn new() -> Section {
        Section::default()
    }

    pub fn heading(mut self, h: impl Into<String>) -> Section {
        self.heading = Some(h.into());
        self
    }

    pub fn paragraph(mut self, p: impl Into<String>) -> Section {
        self.paragraphs.push(p.into());
        self
    }

    pub fn table(mut self, t: Table) -> Section {
        self.tables.push(t);
        self
    }

    pub fn note(mut self, n: impl Into<String>) -> Section {
        self.notes.push(n.into());
        self
    }
}

/// A complete experiment report.
///
/// `to_text` reproduces the legacy CLI output (sections only — the title
/// and intro are page front-matter); `to_markdown` renders the `docs/`
/// page; `to_json` the machine-readable export under `docs/data/`.
#[derive(Debug, Clone)]
pub struct Report {
    /// Stable identifier: the docs page / data file name (`table2`, ...).
    pub id: String,
    /// Page title (`Table 2 — Training time, ...`).
    pub title: String,
    /// The CLI command that regenerates this report.
    pub command: String,
    /// Page-level context paragraphs (methodology; Markdown/JSON only).
    pub intro: Vec<String>,
    pub sections: Vec<Section>,
}

impl Report {
    pub fn new(
        id: impl Into<String>,
        title: impl Into<String>,
        command: impl Into<String>,
    ) -> Report {
        Report {
            id: id.into(),
            title: title.into(),
            command: command.into(),
            intro: Vec::new(),
            sections: Vec::new(),
        }
    }

    pub fn with_intro(mut self, p: impl Into<String>) -> Report {
        self.intro.push(p.into());
        self
    }

    pub fn with_section(mut self, s: Section) -> Report {
        self.sections.push(s);
        self
    }

    /// Append a table to the last section (creating one if none exists).
    pub fn with_table(mut self, t: Table) -> Report {
        if self.sections.is_empty() {
            self.sections.push(Section::new());
        }
        self.sections.last_mut().unwrap().tables.push(t);
        self
    }

    /// Append a trailing note to the last section (creating one if needed).
    pub fn with_note(mut self, n: impl Into<String>) -> Report {
        if self.sections.is_empty() {
            self.sections.push(Section::new());
        }
        self.sections.last_mut().unwrap().notes.push(n.into());
        self
    }

    /// All tables across all sections, in order.
    pub fn tables(&self) -> impl Iterator<Item = &Table> {
        self.sections.iter().flat_map(|s| s.tables.iter())
    }

    /// (PASS count, WARN count) over every anchored cell in the report.
    pub fn verdicts(&self) -> (usize, usize) {
        self.tables().fold((0, 0), |(p, w), t| {
            let (tp, tw) = t.verdicts();
            (p + tp, w + tw)
        })
    }

    /// Overall status: `None` if the report has no anchored cells, else
    /// WARN if any anchored cell is out of tolerance, else PASS.
    pub fn status(&self) -> Option<Verdict> {
        match self.verdicts() {
            (0, 0) => None,
            (_, 0) => Some(Verdict::Pass),
            _ => Some(Verdict::Warn),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchored_cell_verdicts() {
        let pass = Cell::vs_paper(14.0, 14.343, 2, 0.15);
        assert_eq!(pass.verdict(), Some(Verdict::Pass));
        assert!(pass.text.starts_with("14.00 (paper 14.34"), "{}", pass.text);
        let warn = Cell::vs_paper(99.0, 69.425, 2, 0.15);
        assert_eq!(warn.verdict(), Some(Verdict::Warn));
        assert_eq!(Cell::text("label").verdict(), None);
    }

    #[test]
    fn table_counts_verdicts_and_checks_arity() {
        let mut t = Table::new("t", &[("a", Align::Left), ("b", Align::Right)]);
        t.push_row(vec![Cell::text("x"), Cell::vs_paper(1.0, 1.0, 1, 0.1)]);
        t.push_row(vec![Cell::text("y"), Cell::vs_paper(2.0, 1.0, 1, 0.1)]);
        assert_eq!(t.verdicts(), (1, 1));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_mismatch_panics() {
        let mut t = Table::new("t", &[("a", Align::Left), ("b", Align::Right)]);
        t.push_row(vec![Cell::text("only one")]);
    }

    #[test]
    fn report_status_aggregates() {
        let mut t = Table::new("t", &[("v", Align::Right)]);
        t.push_row(vec![Cell::vs_paper(1.0, 1.0, 1, 0.1)]);
        let r = Report::new("r", "R", "cmd").with_table(t);
        assert_eq!(r.status(), Some(Verdict::Pass));
        let empty = Report::new("r", "R", "cmd");
        assert_eq!(empty.status(), None);
    }
}
