//! Recovery billing: what failure handling costs on AWS.
//!
//! Crashes are not free even on "pay per use" substrates — a retried Lambda
//! invocation is a second billed invocation, a model restored from a Redis
//! snapshot occupies the instance and the network, and peers that poll
//! shared storage for an object that is late keep paying per-request fees
//! while they wait. Restore/repoll helpers charge the normal AWS line item
//! in the [`Ledger`] and tally the amount into
//! [`RecoveryStats`]`::cost_usd`. Retried invocations are the exception:
//! the strategies bill each logical invocation's *extended* span (which
//! already contains the wasted attempt and the retry window) through
//! `LambdaRuntime::finish_invocation`, so [`lambda_retry`] charges the
//! ledger only the retry's extra request fee and *attributes* the window's
//! duration cost to `cost_usd` — the fault table reports recovery cost
//! without double-charging the ledger.

use crate::metrics::{CostKind, Ledger, RecoveryStats};

use super::calibration::{REDIS_BW, REDIS_LATENCY};
use super::pricing;

/// Poll interval peers use while re-polling a late object/message (seconds).
/// Matches the 1 s backoff LambdaML-style storage synchronization uses.
pub const REPOLL_INTERVAL: f64 = 1.0;

/// Account a retried/restarted Lambda invocation of `duration_secs` at
/// `allocated_mb`: the ledger gets the extra request fee (the duration is
/// billed by the strategy's extended invocation span — see module docs);
/// the full window cost is attributed to recovery.
pub fn lambda_retry(
    duration_secs: f64,
    allocated_mb: f64,
    ledger: &mut Ledger,
    recovery: &mut RecoveryStats,
) {
    ledger.charge(CostKind::LambdaCompute, pricing::LAMBDA_USD_PER_REQUEST);
    recovery.cost_usd += pricing::lambda_cost(duration_secs, allocated_mb);
}

/// Like [`lambda_retry`], but for a restart that happens *outside* any open
/// invocation span (SPIRT's sync stage runs after its minibatch functions
/// finished): the full duration is billed to the ledger here, since no
/// extended span will carry it.
pub fn lambda_restart_billed(
    duration_secs: f64,
    allocated_mb: f64,
    ledger: &mut Ledger,
    recovery: &mut RecoveryStats,
) {
    let usd = pricing::lambda_cost(duration_secs, allocated_mb);
    ledger.charge(CostKind::LambdaCompute, usd);
    recovery.cost_usd += usd;
}

/// Restore `bytes` of model state from a Redis snapshot after a restart.
/// Returns the restore duration; the hosting instance time is billed under
/// `Ec2Redis` (excluded from the paper total, reported off to the side —
/// same treatment as regular Redis hosting).
pub fn redis_snapshot_restore(
    bytes: u64,
    ledger: &mut Ledger,
    recovery: &mut RecoveryStats,
) -> f64 {
    let secs = bytes as f64 / REDIS_BW + REDIS_LATENCY;
    let usd = pricing::redis_host_cost(secs, 1);
    ledger.charge(CostKind::Ec2Redis, usd);
    recovery.snapshot_restores += 1;
    recovery.restore_bytes += bytes;
    recovery.cost_usd += usd;
    secs
}

/// Bill the storage GETs `waiters` peers issue while re-polling for
/// `down_secs` of downtime (one request per peer per poll interval).
pub fn storage_repolls(
    down_secs: f64,
    waiters: usize,
    ledger: &mut Ledger,
    recovery: &mut RecoveryStats,
) {
    let polls = (down_secs / REPOLL_INTERVAL).ceil().max(1.0) as u64 * waiters as u64;
    let usd = pricing::s3_get_cost(polls);
    ledger.charge(CostKind::S3Requests, usd);
    recovery.storage_repolls += polls;
    recovery.cost_usd += usd;
}

/// Bill the queue polls `waiters` peers issue while re-polling for
/// `down_secs` of downtime.
pub fn queue_repolls(
    down_secs: f64,
    waiters: usize,
    ledger: &mut Ledger,
    recovery: &mut RecoveryStats,
) {
    let polls = (down_secs / REPOLL_INTERVAL).ceil().max(1.0) as u64 * waiters as u64;
    let usd = pricing::queue_cost(polls);
    ledger.charge(CostKind::QueueMessages, usd);
    recovery.queue_repolls += polls;
    recovery.cost_usd += usd;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_bills_request_fee_and_attributes_duration() {
        let mut l = Ledger::new();
        let mut r = RecoveryStats::new();
        lambda_retry(10.0, 2048.0, &mut l, &mut r);
        // Ledger: only the extra request fee (duration rides on the
        // strategy's extended invocation span).
        let fee = pricing::LAMBDA_USD_PER_REQUEST;
        assert!((l.get(CostKind::LambdaCompute) - fee).abs() < 1e-15);
        // Attribution: the full retry-window cost.
        let full = pricing::lambda_cost(10.0, 2048.0);
        assert!((r.cost_usd - full).abs() < 1e-15);
        assert!(r.cost_usd > fee);
    }

    #[test]
    fn uncovered_restart_bills_full_duration() {
        let mut l = Ledger::new();
        let mut r = RecoveryStats::new();
        lambda_restart_billed(3.0, 2048.0, &mut l, &mut r);
        let full = pricing::lambda_cost(3.0, 2048.0);
        assert!((l.get(CostKind::LambdaCompute) - full).abs() < 1e-15);
        assert!((r.cost_usd - full).abs() < 1e-15);
    }

    #[test]
    fn snapshot_restore_takes_transfer_time() {
        let mut l = Ledger::new();
        let mut r = RecoveryStats::new();
        // 46.8 MB ResNet-18 state at 300 MB/s ≈ 0.156 s.
        let secs = redis_snapshot_restore(46_800_000, &mut l, &mut r);
        assert!((secs - 0.1575).abs() < 0.01, "{secs}");
        assert_eq!(r.restore_bytes, 46_800_000);
        assert!(l.get(CostKind::Ec2Redis) > 0.0);
        // Paper's cost model excludes Redis hosting; total_paper unchanged.
        assert_eq!(l.total_paper(), 0.0);
    }

    #[test]
    fn repolls_scale_with_downtime_and_waiters() {
        let mut l = Ledger::new();
        let mut r = RecoveryStats::new();
        storage_repolls(3.2, 3, &mut l, &mut r);
        assert_eq!(r.storage_repolls, 4 * 3);
        queue_repolls(0.1, 2, &mut l, &mut r);
        assert_eq!(r.queue_repolls, 2);
        assert!(l.get(CostKind::S3Requests) > 0.0);
        assert!(l.get(CostKind::QueueMessages) > 0.0);
    }
}
