//! Fig. 2: AllReduce vs ScatterReduce communication time as the worker
//! count scales, for MobileNet and ResNet-50 payloads.
//!
//! Measures one synchronization round (gradients already computed) — the
//! paper's communication-time metric. The crossover the paper reports must
//! emerge: ScatterReduce wins on the large model (master bandwidth bound),
//! AllReduce wins on the small model at high worker counts (request-count
//! bound). The paper only anchors 4–16 workers; sweeps beyond that (the
//! scale-sweep regime) render an em-dash in the paper column.

use crate::cloud::FrameworkKind;
use crate::coordinator::allreduce::AllReduce;
use crate::coordinator::scatter_reduce::ScatterReduce;
use crate::coordinator::{ClusterEnv, EnvConfig};
use crate::report::{Align, Cell, Report, Table};
use crate::tensor::Slab;
use crate::Result;

/// Anchor tolerance for the 16-worker extremes. The tests assert the
/// asymmetric 2× band `(paper/2, paper×2)`; a symmetric rel-err tolerance
/// of 0.5 gives `[paper/2, paper×1.5]` — a subset, so a PASS in the docs
/// always implies the test band holds (the docs may WARN in the
/// `(1.5×, 2×)` stretch the test still tolerates, erring toward WARN).
pub const ANCHOR_TOL: f64 = 0.5;

#[derive(Debug, Clone)]
pub struct Point {
    pub arch: String,
    pub workers: usize,
    pub allreduce_secs: f64,
    pub scatter_secs: f64,
}

/// Paper's Fig. 2 anchor values (communication seconds). Worker counts the
/// paper never measured (anything beyond 4–16) have no anchor.
pub fn paper_anchor(arch: &str, workers: usize) -> Option<(f64, f64)> {
    // (allreduce, scatter) — §4.2 text gives the 16-worker extremes.
    match (arch, workers) {
        ("resnet50", 16) => Some((21.88, 8.36)),
        ("mobilenet", 16) => Some((4.77, 6.47)),
        _ => None,
    }
}

fn comm_round(fw: FrameworkKind, arch: &str, workers: usize) -> Result<f64> {
    let mut env = ClusterEnv::new(EnvConfig::virtual_paper(fw, arch, workers)?)?;
    let grads: Vec<Slab> = (0..workers).map(|_| Slab::virtual_of(env.n_params)).collect();
    match fw {
        FrameworkKind::AllReduce => {
            AllReduce::new().sync_round(&mut env, 0, "fig2", grads)?;
        }
        FrameworkKind::ScatterReduce => {
            ScatterReduce::new().sync_round(&mut env, 0, "fig2", grads)?;
        }
        _ => anyhow::bail!("fig2 compares the LambdaML strategies"),
    }
    // Round completion: the slowest worker's clock.
    Ok(env.max_clock().secs())
}

/// Sweep worker counts for both models.
pub fn run(worker_counts: &[usize]) -> Result<Vec<Point>> {
    let mut out = Vec::new();
    for arch in ["mobilenet", "resnet50"] {
        for &w in worker_counts {
            out.push(Point {
                arch: arch.to_string(),
                workers: w,
                allreduce_secs: comm_round(FrameworkKind::AllReduce, arch, w)?,
                scatter_secs: comm_round(FrameworkKind::ScatterReduce, arch, w)?,
            });
        }
    }
    Ok(out)
}

/// Build the Fig. 2 report; worker counts the paper measured carry anchors,
/// everything beyond renders an em-dash paper cell.
pub fn report(points: &[Point]) -> Report {
    let mut t = Table::new(
        "fig2",
        &[
            ("Model", Align::Left),
            ("Workers", Align::Right),
            ("AllReduce (s)", Align::Right),
            ("ScatterReduce (s)", Align::Right),
            ("Winner", Align::Left),
            ("Paper (AR/SR)", Align::Right),
        ],
    )
    .title("Fig. 2 — Communication time per synchronization round");
    let mut last_arch = String::new();
    for p in points {
        if p.arch != last_arch {
            if !last_arch.is_empty() {
                t.rule();
            }
            last_arch = p.arch.clone();
        }
        let winner = if p.allreduce_secs < p.scatter_secs { "AllReduce" } else { "ScatterReduce" };
        let anchor = paper_anchor(&p.arch, p.workers);
        let numeric = |measured: f64, paper: Option<f64>| match paper {
            Some(paper) => Cell::anchored(format!("{measured:.2}"), measured, paper, ANCHOR_TOL),
            None => Cell::num(measured, 2),
        };
        t.push_row(vec![
            Cell::text(p.arch.clone()),
            Cell::count(p.workers as u64),
            numeric(p.allreduce_secs, anchor.map(|(a, _)| a)),
            numeric(p.scatter_secs, anchor.map(|(_, s)| s)),
            Cell::text(winner),
            Cell::text(
                anchor.map(|(a, s)| format!("{a:.2}/{s:.2}")).unwrap_or_else(|| "—".into()),
            ),
        ]);
    }
    // Reproduce command derived from the points themselves, so the page
    // can never cite a different sweep than it shows.
    let mut counts: Vec<usize> = Vec::new();
    for p in points {
        if !counts.contains(&p.workers) {
            counts.push(p.workers);
        }
    }
    let counts: Vec<String> = counts.iter().map(|w| w.to_string()).collect();
    Report::new(
        "fig2",
        "Fig. 2 — Communication time per synchronization round",
        format!("slsgpu exp fig2 --workers {}", counts.join(",")),
    )
    .with_intro(
        "One synchronization round (gradients already computed) for the two LambdaML \
         strategies as the worker count scales, MobileNet and ResNet-50 payloads. The \
         paper's crossover must emerge: ScatterReduce wins the large model (master \
         bandwidth bound), AllReduce wins the small model at high worker counts \
         (request-count bound). Only the 16-worker extremes are anchored (§4.2 text); \
         anchorless worker counts render an em-dash.",
    )
    .with_table(t)
}

/// Legacy CLI view of [`report`].
pub fn render(points: &[Point]) -> String {
    report(points).to_text()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossover_shapes_match_paper() {
        let points = run(&[4, 16]).unwrap();
        let find = |arch: &str, w: usize| {
            points.iter().find(|p| p.arch == arch && p.workers == w).unwrap()
        };
        // Large model at 16 workers: ScatterReduce must win decisively.
        let big = find("resnet50", 16);
        assert!(
            big.scatter_secs * 1.5 < big.allreduce_secs,
            "resnet50@16: SR {:.2}s vs AR {:.2}s",
            big.scatter_secs,
            big.allreduce_secs
        );
        // Small model at 16 workers: AllReduce must win.
        let small = find("mobilenet", 16);
        assert!(
            small.allreduce_secs < small.scatter_secs,
            "mobilenet@16: AR {:.2}s vs SR {:.2}s",
            small.allreduce_secs,
            small.scatter_secs
        );
    }

    #[test]
    fn comm_time_grows_with_workers() {
        let points = run(&[4, 8, 16]).unwrap();
        let series: Vec<f64> = points
            .iter()
            .filter(|p| p.arch == "resnet50")
            .map(|p| p.allreduce_secs)
            .collect();
        assert!(series.windows(2).all(|w| w[1] > w[0]), "{series:?}");
    }

    #[test]
    fn anchorless_worker_counts_render_an_em_dash_row() {
        // Scale-sweep worker counts have no paper anchors; the figure must
        // still run and render instead of relying on the 4–16 table.
        let points = run(&[64]).unwrap();
        assert_eq!(points.len(), 2);
        for p in &points {
            assert!(paper_anchor(&p.arch, p.workers).is_none());
            assert!(p.allreduce_secs > 0.0 && p.scatter_secs > 0.0);
        }
        let table = render(&points);
        assert!(table.contains('—'), "missing-anchor rows must render an em dash:\n{table}");
    }

    #[test]
    fn report_anchors_only_paper_measured_points() {
        let points = run(&[4, 16]).unwrap();
        let (pass, warn) = report(&points).verdicts();
        // AR + SR anchored for both models at W=16 only.
        assert_eq!(pass + warn, 4, "pass={pass} warn={warn}");
    }

    #[test]
    fn sixteen_worker_extremes_near_paper() {
        let points = run(&[16]).unwrap();
        for p in &points {
            let (ar, sr) = paper_anchor(&p.arch, 16).unwrap();
            // The shapes must hold within a loose factor (our substrate is a
            // model, not their testbed): 2x band on absolute values.
            assert!(
                p.allreduce_secs > ar / 2.0 && p.allreduce_secs < ar * 2.0,
                "{}: AR {:.2} vs paper {ar}",
                p.arch,
                p.allreduce_secs
            );
            assert!(
                p.scatter_secs > sr / 2.0 && p.scatter_secs < sr * 2.0,
                "{}: SR {:.2} vs paper {sr}",
                p.arch,
                p.scatter_secs
            );
        }
    }
}

