//! MLLess significance filter (Rust-side decision logic).
//!
//! MLLess publishes an update only when its relative magnitude
//! `||g|| / max(||theta||, eps)` exceeds a threshold; insignificant updates
//! are accumulated locally and folded into the next significant one, so no
//! gradient signal is lost — only its propagation is delayed. This mirrors
//! the paper's description (§2, Fig. 3). The same predicate exists as a
//! Pallas kernel (`kernels/significance.py`) for the in-runtime path; this
//! Rust implementation drives the decision in the coordinator and is tested
//! against hand-computed values.

use super::slab::Slab;

/// Stateful per-worker significance filter with local accumulation.
#[derive(Debug, Clone)]
pub struct SignificanceFilter {
    threshold: f64,
    /// Locally accumulated (not yet propagated) gradient.
    pending: Option<Slab>,
    /// Stats for Fig. 3-style reporting.
    pub proposed: u64,
    pub published: u64,
}

impl SignificanceFilter {
    pub fn new(threshold: f64) -> SignificanceFilter {
        assert!(threshold >= 0.0);
        SignificanceFilter { threshold, pending: None, proposed: 0, published: 0 }
    }

    pub fn threshold(&self) -> f64 {
        self.threshold
    }

    /// Relative-magnitude significance predicate.
    pub fn is_significant(&self, g: &Slab, theta: &Slab) -> bool {
        if self.threshold == 0.0 {
            return true; // filtering disabled
        }
        let gn = g.l2_norm_sq();
        let tn = theta.l2_norm_sq().max(1e-12);
        gn > self.threshold * self.threshold * tn
    }

    /// Offer a gradient. Returns `Some(update)` when the accumulated update
    /// should be published (the pending accumulation is drained into it);
    /// `None` when it stays local.
    pub fn offer(&mut self, g: Slab, theta: &Slab) -> Option<Slab> {
        self.proposed += 1;
        let merged = match self.pending.take() {
            Some(mut acc) => {
                acc.axpy(&g, 1.0).expect("filter slab lengths must match");
                acc
            }
            None => g,
        };
        if self.is_significant(&merged, theta) {
            self.published += 1;
            Some(merged)
        } else {
            self.pending = Some(merged);
            None
        }
    }

    /// Fraction of offers that were published (1.0 when disabled).
    pub fn publish_rate(&self) -> f64 {
        if self.proposed == 0 {
            1.0
        } else {
            self.published as f64 / self.proposed as f64
        }
    }

    /// Any still-unpublished accumulation (flushed at epoch end).
    pub fn drain_pending(&mut self) -> Option<Slab> {
        self.pending.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slab(v: &[f32]) -> Slab {
        Slab::from_vec(v.to_vec())
    }

    #[test]
    fn zero_threshold_publishes_everything() {
        let mut f = SignificanceFilter::new(0.0);
        let theta = slab(&[100.0; 4]);
        assert!(f.offer(slab(&[1e-9; 4]), &theta).is_some());
        assert_eq!(f.publish_rate(), 1.0);
    }

    #[test]
    fn small_updates_held_then_merged() {
        // theta norm = 10; threshold 0.5 -> publish when ||g|| > 5.
        let mut f = SignificanceFilter::new(0.5);
        let theta = slab(&[10.0]);
        assert!(f.offer(slab(&[3.0]), &theta).is_none()); // 3 < 5, held
        // 3 + 3 = 6 > 5 -> published, including the held part.
        let out = f.offer(slab(&[3.0]), &theta).unwrap();
        assert_eq!(out.as_slice().unwrap(), &[6.0]);
        assert_eq!(f.proposed, 2);
        assert_eq!(f.published, 1);
    }

    #[test]
    fn pending_flush() {
        let mut f = SignificanceFilter::new(10.0);
        let theta = slab(&[1.0]);
        assert!(f.offer(slab(&[0.5]), &theta).is_none());
        let flushed = f.drain_pending().unwrap();
        assert_eq!(flushed.as_slice().unwrap(), &[0.5]);
        assert!(f.drain_pending().is_none());
    }

    #[test]
    fn significance_uses_relative_norm() {
        let f = SignificanceFilter::new(0.5);
        assert!(f.is_significant(&slab(&[6.0]), &slab(&[10.0]))); // 6 > 5
        assert!(!f.is_significant(&slab(&[4.0]), &slab(&[10.0]))); // 4 < 5
        // Zero theta: everything significant (eps guard).
        assert!(f.is_significant(&slab(&[1e-3]), &slab(&[0.0])));
    }
}
