import os
import sys

# Tests run from python/ (see Makefile) but also work from the repo root.
_HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)
