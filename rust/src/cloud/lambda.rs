//! AWS Lambda runtime substrate: invocation lifecycle + GB-second billing.
//!
//! Serverless training is *stateless*: every batch is a fresh invocation
//! that must re-load the model (and often the data shard) before computing
//! (§3.1 "Communication Overhead"). `LambdaRuntime` models exactly that
//! lifecycle on the virtual timeline:
//!
//! ```text
//! invoke = [cold-start?] + warm-init + state-load + body + finalize
//! cost   = duration × allocated-GB × rate + request fee
//! ```
//!
//! The *body* (gradient compute + protocol communication) is charged by the
//! strategy code between `begin_invocation` and `finish_invocation`; this
//! module owns the init/billing bookkeeping and the warm-pool state.

use std::collections::BTreeSet;

use crate::metrics::{CostKind, Ledger};
use crate::sim::VTime;

use super::calibration::{LAMBDA_COLD_START, LAMBDA_WARM_INIT};
use super::pricing;

/// An in-flight invocation handle (returned by `begin_invocation`).
#[derive(Debug, Clone, Copy)]
pub struct Invocation {
    pub worker: usize,
    /// When the invocation was requested.
    pub requested: VTime,
    /// When user code starts (after cold/warm init).
    pub body_start: VTime,
    /// Whether this invocation paid a cold start.
    pub cold: bool,
}

/// Per-experiment Lambda runtime: warm pool + billing statistics.
#[derive(Debug, Default)]
pub struct LambdaRuntime {
    /// Workers with a warm sandbox. Ordered set: membership is all the
    /// warm-pool logic needs, and keeping sim-path containers ordered is
    /// the `unordered-iteration` audit invariant.
    warm: BTreeSet<usize>,
    pub invocations: u64,
    pub cold_starts: u64,
    pub billed_secs: f64,
    pub billed_gb_secs: f64,
    /// Max duration across invocations (timeout-budget check).
    pub max_duration: f64,
}

impl LambdaRuntime {
    pub fn new() -> LambdaRuntime {
        LambdaRuntime::default()
    }

    /// Start an invocation for `worker` at `now`. The first invocation of
    /// each worker's function pays the cold start (sandbox + import of the
    /// PyTorch-sized deployment package).
    pub fn begin_invocation(&mut self, now: VTime, worker: usize) -> Invocation {
        let cold = !self.warm.contains(&worker);
        if cold {
            self.warm.insert(worker);
            self.cold_starts += 1;
        }
        self.invocations += 1;
        let init = if cold { LAMBDA_COLD_START } else { 0.0 } + LAMBDA_WARM_INIT;
        Invocation { worker, requested: now, body_start: now + init, cold }
    }

    /// Finish an invocation whose body completed at `body_end`; bills
    /// duration × allocated memory. Returns the function's total duration.
    pub fn finish_invocation(
        &mut self,
        inv: Invocation,
        body_end: VTime,
        allocated_mb: f64,
        ledger: &mut Ledger,
    ) -> f64 {
        assert!(body_end >= inv.body_start, "invocation ended before it started");
        let duration = body_end - inv.requested;
        self.billed_secs += duration;
        self.billed_gb_secs += duration * allocated_mb / 1024.0;
        self.max_duration = self.max_duration.max(duration);
        ledger.charge(CostKind::LambdaCompute, pricing::lambda_cost(duration, allocated_mb));
        duration
    }

    /// Forget warm state (e.g. between epochs with long gaps).
    pub fn evict_all(&mut self) {
        self.warm.clear();
    }

    pub fn mean_duration(&self) -> f64 {
        if self.invocations == 0 {
            0.0
        } else {
            self.billed_secs / self.invocations as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_invocation_is_cold_then_warm() {
        let mut rt = LambdaRuntime::new();
        let a = rt.begin_invocation(VTime::ZERO, 0);
        assert!(a.cold);
        let b = rt.begin_invocation(VTime::from_secs(10.0), 0);
        assert!(!b.cold);
        let c = rt.begin_invocation(VTime::ZERO, 1);
        assert!(c.cold);
        assert_eq!(rt.cold_starts, 2);
        assert!(a.body_start.secs() > LAMBDA_COLD_START);
        assert!((b.body_start.secs() - (10.0 + LAMBDA_WARM_INIT)).abs() < 1e-9);
    }

    #[test]
    fn billing_follows_duration_times_memory() {
        let mut rt = LambdaRuntime::new();
        let mut ledger = Ledger::new();
        let inv = rt.begin_invocation(VTime::ZERO, 0);
        let end = inv.body_start + 10.0;
        let dur = rt.finish_invocation(inv, end, 2048.0, &mut ledger);
        let expected = pricing::lambda_cost(dur, 2048.0);
        assert!((ledger.get(CostKind::LambdaCompute) - expected).abs() < 1e-12);
        assert!(dur > 10.0); // init included in billed duration
    }

    #[test]
    fn eviction_restores_cold_start() {
        let mut rt = LambdaRuntime::new();
        rt.begin_invocation(VTime::ZERO, 0);
        rt.evict_all();
        assert!(rt.begin_invocation(VTime::from_secs(1.0), 0).cold);
    }

    #[test]
    fn stats_accumulate() {
        let mut rt = LambdaRuntime::new();
        let mut ledger = Ledger::new();
        for i in 0..3 {
            let inv = rt.begin_invocation(VTime::ZERO, i);
            rt.finish_invocation(inv, inv.body_start + 5.0, 1024.0, &mut ledger);
        }
        assert_eq!(rt.invocations, 3);
        assert!(rt.mean_duration() > 5.0);
        assert!(rt.max_duration >= rt.mean_duration());
    }

    #[test]
    #[should_panic(expected = "ended before it started")]
    fn rejects_time_travel() {
        let mut rt = LambdaRuntime::new();
        let mut ledger = Ledger::new();
        let inv = rt.begin_invocation(VTime::from_secs(5.0), 0);
        rt.finish_invocation(inv, VTime::ZERO, 1024.0, &mut ledger);
    }
}
