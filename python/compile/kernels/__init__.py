"""Layer-1 Pallas kernels for the slsgpu testbed.

Every kernel is lowered with ``interpret=True``: the CPU PJRT plugin that the
Rust runtime embeds cannot execute Mosaic custom-calls, and interpret mode
lowers the kernel into plain HLO (a fori-loop over the grid) that runs on any
backend. Block shapes are still chosen as if targeting a TPU core — 128x128
MXU-shaped tiles for the matmul, 64K-element VMEM-resident slabs for the
elementwise aggregation kernels — so the VMEM/MXU estimates recorded in
EXPERIMENTS.md §Perf reflect the real schedule.
"""

from .matmul import matmul
from .aggregate import accumulate, fused_avg_update, sgd_update
from .significance import l2_norm_sq

__all__ = [
    "matmul",
    "accumulate",
    "fused_avg_update",
    "sgd_update",
    "l2_norm_sq",
]
