"""MobileNet-v1 adapted to CIFAR-scale 32x32 inputs.

Standard v1 stack (Howard et al. 2017) with the ImageNet stem stride removed
(32x32 inputs downsample 4x across the depthwise blocks, ending at 2x2).
Every pointwise conv is a Pallas-matmul GEMM — MobileNet is ~95% pointwise
FLOPs, so this is the architecture where the Layer-1 kernel carries the
model. Width multiplier scales all channel counts (paper uses 1.0; the
executed testbed config uses 0.25).
"""

import jax

from . import layers as L

# (stride of the depthwise conv, output channels at width=1.0)
_BLOCKS = [
    (1, 64),
    (2, 128),
    (1, 128),
    (2, 256),
    (1, 256),
    (2, 512),
    (1, 512),
    (1, 512),
    (1, 512),
    (1, 512),
    (1, 512),
    (2, 1024),
    (1, 1024),
]

_STEM_CH = 32


def _scaled(c, width):
    return max(8, int(c * width))


def mobilenet(width=1.0, num_classes=10):
    """Returns (init, apply) for MobileNet-v1 at the given width."""

    stem_ch = _scaled(_STEM_CH, width)
    chans = [_scaled(c, width) for _, c in _BLOCKS]
    strides = [s for s, _ in _BLOCKS]

    def init(key):
        keys = jax.random.split(key, 2 * len(_BLOCKS) + 2)
        params = {
            "stem": {
                "conv": L.init_conv(keys[0], 3, 3, 3, stem_ch),
                "gn": L.init_groupnorm(stem_ch),
            },
            "blocks": [],
            "head": L.init_dense(keys[1], chans[-1], num_classes),
        }
        cin = stem_ch
        for i, cout in enumerate(chans):
            params["blocks"].append(
                {
                    "dw": L.init_depthwise(keys[2 + 2 * i], 3, 3, cin),
                    "dw_gn": L.init_groupnorm(cin),
                    "pw": L.init_pointwise(keys[3 + 2 * i], cin, cout),
                    "pw_gn": L.init_groupnorm(cout),
                }
            )
            cin = cout
        return params

    def apply(params, x):
        x = L.relu6(L.groupnorm(params["stem"]["gn"], L.conv(params["stem"]["conv"], x)))
        for blk, stride in zip(params["blocks"], strides):
            x = L.relu6(L.groupnorm(blk["dw_gn"], L.depthwise(blk["dw"], x, stride)))
            x = L.relu6(L.groupnorm(blk["pw_gn"], L.pointwise(blk["pw"], x)))
        x = L.global_avg_pool(x)
        return L.dense(params["head"], x)

    return init, apply
