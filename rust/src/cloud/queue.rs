//! Message queue substrate (RabbitMQ / SQS analog).
//!
//! Used for worker synchronization: SPIRT's sync queue (workers notify
//! completion and poll until all peers report), MLLess's per-worker update
//! queues and supervisor channel. Messages become *visible* at the virtual
//! time their publish completes; a waiter's clock jumps to the visibility
//! of the k-th message plus a poll latency — exactly the notify/poll
//! semantics the paper describes, on the virtual timeline.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::metrics::{CommKind, CommStats, CostKind, Ledger};
use crate::sim::{OrderLog, VTime};

use super::calibration::QUEUE_LATENCY;
use super::pricing;

/// One message: payload + visibility time.
#[derive(Debug, Clone, PartialEq)]
pub struct Msg {
    pub body: String,
    pub visible: VTime,
}

/// One topic: the message list (drain order) plus an incrementally sorted
/// log of visibility times, so `kth_visible` — the MLLess supervisor wait
/// and every SPIRT sync poll, called once per waiter per round — is an
/// O(1) rank lookup instead of re-sorting W visibilities per call (which
/// made a 1024-worker round cost O(W² log W) host work).
#[derive(Debug, Default)]
struct Topic {
    msgs: Vec<Msg>,
    visibility: OrderLog,
}

/// A named-topic message broker.
#[derive(Debug, Default)]
pub struct MessageQueue {
    topics: BTreeMap<String, Topic>,
    latency: f64,
    published: u64,
}

impl MessageQueue {
    pub fn new() -> MessageQueue {
        MessageQueue { topics: BTreeMap::new(), latency: QUEUE_LATENCY, published: 0 }
    }

    /// Publish `body` to `topic`; visible after the publish latency.
    pub fn publish(
        &mut self,
        now: VTime,
        topic: &str,
        body: impl Into<String>,
        ledger: &mut Ledger,
        comm: &mut CommStats,
    ) -> VTime {
        let visible = now + self.latency;
        let body = body.into();
        let bytes = body.len() as u64 + 64; // envelope overhead
        let t = self.topics.entry(topic.to_string()).or_default();
        t.msgs.push(Msg { body, visible });
        t.visibility.insert(visible);
        self.published += 1;
        ledger.charge(CostKind::QueueMessages, pricing::queue_cost(1));
        comm.record(CommKind::Publish, bytes);
        visible
    }

    /// Virtual time at which the `k`-th message (1-based) on `topic` is
    /// visible, or None if fewer than `k` messages were ever published.
    pub fn kth_visible(&self, topic: &str, k: usize) -> Option<VTime> {
        // The per-topic OrderLog is the sorted visibility vector the old
        // sort-per-call code rebuilt here; the k-th rank is bit-identical.
        self.topics.get(topic)?.visibility.kth(k)
    }

    /// Block (in virtual time) until `count` messages are visible on
    /// `topic`, charging one poll. Returns the waiter's new clock.
    pub fn wait_for(
        &mut self,
        now: VTime,
        topic: &str,
        count: usize,
        ledger: &mut Ledger,
        comm: &mut CommStats,
    ) -> Result<VTime> {
        let Some(t) = self.kth_visible(topic, count) else {
            bail!(
                "queue[{topic}]: only {} messages, waiting for {count}",
                self.topics.get(topic).map(|t| t.msgs.len()).unwrap_or(0)
            );
        };
        let done = now.max(t) + self.latency;
        ledger.charge(CostKind::QueueMessages, pricing::queue_cost(1));
        comm.record(CommKind::Poll, 64);
        comm.comm_time += done - now;
        Ok(done)
    }

    /// Consume every message visible by `now` on `topic` (drains them).
    pub fn drain_visible(
        &mut self,
        now: VTime,
        topic: &str,
        ledger: &mut Ledger,
        comm: &mut CommStats,
    ) -> (VTime, Vec<String>) {
        let done = now + self.latency;
        let mut out = Vec::new();
        if let Some(t) = self.topics.get_mut(topic) {
            let mut rest = Vec::new();
            for m in t.msgs.drain(..) {
                if m.visible <= now {
                    out.push(m.body);
                } else {
                    rest.push(m);
                }
            }
            t.msgs = rest;
            // Draining removes an arbitrary subset; rebuild the rank log
            // from the survivors.
            t.visibility.rebuild(t.msgs.iter().map(|m| m.visible));
        }
        ledger.charge(CostKind::QueueMessages, pricing::queue_cost(1));
        comm.record(CommKind::Poll, 64 * (out.len() as u64 + 1));
        comm.comm_time += self.latency;
        (done, out)
    }

    /// Messages currently enqueued on a topic (any visibility).
    pub fn depth(&self, topic: &str) -> usize {
        self.topics.get(topic).map(|t| t.msgs.len()).unwrap_or(0)
    }

    pub fn total_published(&self) -> u64 {
        self.published
    }

    /// Discard a fully consumed topic (bookkeeping only: no charges, no
    /// clock movement, `total_published` keeps counting). Strategies name
    /// sync topics per round/epoch, so without this the broker retains
    /// every round's W messages for the whole sweep — at W=4096 that is
    /// the difference between bounded and unbounded memory.
    pub fn drop_topic(&mut self, topic: &str) {
        self.topics.remove(topic);
    }

    pub fn clear(&mut self) {
        self.topics.clear();
        self.published = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> (Ledger, CommStats) {
        (Ledger::new(), CommStats::new())
    }

    #[test]
    fn publish_then_wait() {
        let mut q = MessageQueue::new();
        let (mut l, mut c) = env();
        q.publish(VTime::from_secs(1.0), "sync", "w0", &mut l, &mut c);
        q.publish(VTime::from_secs(3.0), "sync", "w1", &mut l, &mut c);
        // Waiter arrives early; must wait for the 2nd message (3.0 + lat).
        let t = q.wait_for(VTime::ZERO, "sync", 2, &mut l, &mut c).unwrap();
        assert!(t.secs() >= 3.0 + QUEUE_LATENCY);
        // Waiter arriving late pays only the poll.
        let t2 = q.wait_for(VTime::from_secs(10.0), "sync", 2, &mut l, &mut c).unwrap();
        assert!((t2.secs() - (10.0 + QUEUE_LATENCY)).abs() < 1e-9);
    }

    #[test]
    fn wait_for_unpublished_fails() {
        let mut q = MessageQueue::new();
        let (mut l, mut c) = env();
        assert!(q.wait_for(VTime::ZERO, "sync", 1, &mut l, &mut c).is_err());
    }

    #[test]
    fn kth_visible_is_order_statistic() {
        let mut q = MessageQueue::new();
        let (mut l, mut c) = env();
        q.publish(VTime::from_secs(5.0), "t", "late", &mut l, &mut c);
        q.publish(VTime::from_secs(1.0), "t", "early", &mut l, &mut c);
        assert!(q.kth_visible("t", 1).unwrap().secs() < 2.0);
        assert!(q.kth_visible("t", 2).unwrap().secs() > 4.0);
        assert!(q.kth_visible("t", 3).is_none());
    }

    #[test]
    fn drain_visible_respects_time() {
        let mut q = MessageQueue::new();
        let (mut l, mut c) = env();
        q.publish(VTime::ZERO, "t", "a", &mut l, &mut c);
        q.publish(VTime::from_secs(100.0), "t", "b", &mut l, &mut c);
        let (_, got) = q.drain_visible(VTime::from_secs(1.0), "t", &mut l, &mut c);
        assert_eq!(got, vec!["a"]);
        assert_eq!(q.depth("t"), 1); // "b" still pending
    }

    #[test]
    fn kth_visible_matches_sort_reference_across_drains() {
        // The incremental OrderLog must agree bit-for-bit with the old
        // sort-per-call resolution, including after drains remove an
        // arbitrary visible prefix.
        let mut q = MessageQueue::new();
        let (mut l, mut c) = env();
        let times = [5.0, 1.0, 3.0, 3.0, 9.0, 0.5, 3.0, 7.0];
        for (i, &t) in times.iter().enumerate() {
            q.publish(VTime::from_secs(t), "t", format!("m{i}"), &mut l, &mut c);
        }
        // Ranks are sorted and complete.
        let ranks: Vec<VTime> = (1..=times.len()).map(|k| q.kth_visible("t", k).unwrap()).collect();
        let mut sorted: Vec<VTime> = times.iter().map(|&t| VTime::from_secs(t) + QUEUE_LATENCY).collect();
        sorted.sort();
        for (a, b) in ranks.iter().zip(&sorted) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(q.kth_visible("t", times.len() + 1).is_none());
        // Drain the messages visible by t=4, then re-check every rank.
        let (_, got) = q.drain_visible(VTime::from_secs(4.0), "t", &mut l, &mut c);
        assert_eq!(got.len(), sorted.iter().filter(|t| t.secs() <= 4.0).count());
        let remaining: Vec<VTime> = sorted.into_iter().filter(|t| t.secs() > 4.0).collect();
        for (k, want) in remaining.iter().enumerate() {
            assert_eq!(q.kth_visible("t", k + 1).unwrap().to_bits(), want.to_bits());
        }
        assert!(q.kth_visible("t", remaining.len() + 1).is_none());
    }

    #[test]
    fn drop_topic_is_bookkeeping_only() {
        let mut q = MessageQueue::new();
        let (mut l, mut c) = env();
        q.publish(VTime::ZERO, "round0", "x", &mut l, &mut c);
        let published = q.total_published();
        let cost = l.get(CostKind::QueueMessages);
        q.drop_topic("round0");
        assert_eq!(q.depth("round0"), 0);
        assert!(q.kth_visible("round0", 1).is_none());
        assert_eq!(q.total_published(), published, "publish count survives");
        assert_eq!(l.get(CostKind::QueueMessages), cost, "no charge for dropping");
    }

    #[test]
    fn message_costs_charged() {
        let mut q = MessageQueue::new();
        let (mut l, mut c) = env();
        q.publish(VTime::ZERO, "t", "x", &mut l, &mut c);
        assert!(l.get(CostKind::QueueMessages) > 0.0);
        assert_eq!(c.ops(CommKind::Publish), 1);
    }
}
