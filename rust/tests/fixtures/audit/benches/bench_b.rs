fn main() {}
