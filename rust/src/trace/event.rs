//! The structured trace event: one record per protocol op, stage span or
//! fault, stamped with virtual time, payload size and attributed cost.

use crate::sim::VTime;

/// What a [`TraceEvent`] describes. Ordered so per-kind tables iterate in a
/// stable, meaningful order (stage work first, protocol ops, then faults).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// Model + minibatch fetch at invocation start (`ClusterEnv::state_load`).
    StateLoad,
    /// Forward/backward pass (`ClusterEnv::compute_grad`).
    Compute,
    /// Local aggregation math applied to the model (`ClusterEnv::apply_update`).
    ApplyUpdate,
    /// Synchronization overhead charged outside a substrate call
    /// (`ClusterEnv::charge_sync`: aggregation CPU, per-round constants).
    SyncWait,
    /// Explicit stage advance on a worker clock (`Timeline::advance`).
    Advance,
    /// Object-store upload (`Timeline::put`).
    Put,
    /// Object-store download (`Timeline::get`).
    Get,
    /// Batched object-store download (`Timeline::get_many`).
    GetMany,
    /// Redis write (`Timeline::redis_set`, or SPIRT's direct per-worker set).
    RedisSet,
    /// Redis read (`Timeline::redis_get`).
    RedisGet,
    /// In-database math executed inside a Redis instance (SPIRT's
    /// `acc_in_db`/`scale_in_db`/`avg_update_in_db`).
    InDb,
    /// Queue publish (`Timeline::notify`, MLLess supervisor proceed).
    Notify,
    /// Queue wait (`Timeline::poll`, MLLess supervisor round wait).
    Poll,
    /// Full-cluster barrier (`Op::Barrier`).
    Barrier,
    /// Invocation crash + cold-start retry downtime (`recover_invocation`).
    CrashCompute,
    /// Crash at the synchronization point (`ClusterEnv::sync_crash`).
    CrashSync,
    /// MLLess supervisor crash + restart (`ClusterEnv::supervisor_crash`).
    CrashSupervisor,
    /// A store-tier shard crash + restart window (`ClusterEnv::begin_epoch`
    /// firing `FaultKind::ShardCrash`; span on the supervisor track).
    ShardCrash,
    /// An update silently dropped by the fault plan (instant).
    DropUpdate,
    /// A poisoned gradient injected by the fault plan (instant).
    Poison,
    /// A straggler slowdown applied to this compute span (instant marker).
    Straggler,
    /// A network partition window `[start, heal)` on the supervisor track
    /// (span; emitted once per planned window, at first enforcement).
    Partition,
    /// The instant a partition heals and deferred ops proceed (supervisor
    /// track).
    PartitionHeal,
    /// A spot-instance preemption reclaiming an in-flight invocation
    /// (instant on the supervisor track; the victim's restart downtime
    /// stays a `CrashCompute` span on its own track).
    Preemption,
}

impl EventKind {
    /// Every kind, in display order.
    pub const ALL: [EventKind; 24] = [
        EventKind::StateLoad,
        EventKind::Compute,
        EventKind::ApplyUpdate,
        EventKind::SyncWait,
        EventKind::Advance,
        EventKind::Put,
        EventKind::Get,
        EventKind::GetMany,
        EventKind::RedisSet,
        EventKind::RedisGet,
        EventKind::InDb,
        EventKind::Notify,
        EventKind::Poll,
        EventKind::Barrier,
        EventKind::CrashCompute,
        EventKind::CrashSync,
        EventKind::CrashSupervisor,
        EventKind::ShardCrash,
        EventKind::DropUpdate,
        EventKind::Poison,
        EventKind::Straggler,
        EventKind::Partition,
        EventKind::PartitionHeal,
        EventKind::Preemption,
    ];

    pub fn name(self) -> &'static str {
        match self {
            EventKind::StateLoad => "state-load",
            EventKind::Compute => "compute",
            EventKind::ApplyUpdate => "apply-update",
            EventKind::SyncWait => "sync-wait",
            EventKind::Advance => "advance",
            EventKind::Put => "put",
            EventKind::Get => "get",
            EventKind::GetMany => "get-many",
            EventKind::RedisSet => "redis-set",
            EventKind::RedisGet => "redis-get",
            EventKind::InDb => "in-db",
            EventKind::Notify => "notify",
            EventKind::Poll => "poll",
            EventKind::Barrier => "barrier",
            EventKind::CrashCompute => "crash-compute",
            EventKind::CrashSync => "crash-sync",
            EventKind::CrashSupervisor => "crash-supervisor",
            EventKind::ShardCrash => "shard-crash",
            EventKind::DropUpdate => "drop-update",
            EventKind::Poison => "poison",
            EventKind::Straggler => "straggler",
            EventKind::Partition => "partition",
            EventKind::PartitionHeal => "partition-heal",
            EventKind::Preemption => "preemption",
        }
    }

    /// Chrome trace-event category (one lane colour per group in Perfetto).
    pub fn category(self) -> &'static str {
        match self {
            EventKind::StateLoad
            | EventKind::Compute
            | EventKind::ApplyUpdate
            | EventKind::SyncWait
            | EventKind::Advance => "stage",
            EventKind::Put
            | EventKind::Get
            | EventKind::GetMany
            | EventKind::RedisSet
            | EventKind::RedisGet
            | EventKind::InDb
            | EventKind::Notify
            | EventKind::Poll
            | EventKind::Barrier => "proto",
            EventKind::CrashCompute
            | EventKind::CrashSync
            | EventKind::CrashSupervisor
            | EventKind::ShardCrash
            | EventKind::DropUpdate
            | EventKind::Poison
            | EventKind::Straggler
            | EventKind::Partition
            | EventKind::PartitionHeal
            | EventKind::Preemption => "fault",
        }
    }

    /// Zero-duration markers rendered as Chrome instant events (`ph:"i"`).
    pub fn is_instant(self) -> bool {
        matches!(
            self,
            EventKind::DropUpdate
                | EventKind::Poison
                | EventKind::Straggler
                | EventKind::PartitionHeal
                | EventKind::Preemption
        )
    }

    /// Communication / coordination ops — the population for the sweep's
    /// p99 op-latency column (excludes local compute and fault downtime).
    pub fn is_comm(self) -> bool {
        matches!(
            self,
            EventKind::Put
                | EventKind::Get
                | EventKind::GetMany
                | EventKind::RedisSet
                | EventKind::RedisGet
                | EventKind::InDb
                | EventKind::Notify
                | EventKind::Poll
                | EventKind::Barrier
        )
    }
}

/// One traced span (or instant, when `t0 == t1`) on a worker's track.
///
/// `dep` is an explicit cross-worker happens-before edge (the event index of
/// the write/notify this op observed); `prev` is the same-worker
/// program-order predecessor. Both are collector event indices, stable for
/// the life of the run (ring-buffer eviction only makes old indices
/// unresolvable, it never renumbers).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// Worker track; `faults::SUPERVISOR` (`usize::MAX`) for the MLLess
    /// supervisor's own timeline.
    pub worker: usize,
    pub t0: VTime,
    pub t1: VTime,
    pub kind: EventKind,
    /// Payload bytes moved by this op (0 for waits and instants).
    pub bytes: u64,
    /// Ledger dollars attributed to this op (sampled around the substrate
    /// call; 0 for ops that bill elsewhere — see DESIGN.md on residual cost).
    pub cost: f64,
    /// Protocol round (minibatch for SPIRT's compute phase) within the epoch.
    pub round: u32,
    /// 1-based epoch stamp (0 = before the first `begin_epoch`).
    pub epoch: u32,
    /// Cross-worker happens-before edge: index of the event this op observed.
    pub dep: Option<u64>,
    /// Same-worker program-order predecessor index.
    pub prev: Option<u64>,
}

impl TraceEvent {
    /// Span duration in seconds.
    pub fn secs(&self) -> f64 {
        self.t1 - self.t0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_unique_and_kebab() {
        let mut seen = std::collections::BTreeSet::new();
        for k in EventKind::ALL {
            assert!(seen.insert(k.name()), "duplicate name {}", k.name());
            assert!(
                k.name().chars().all(|c| c.is_ascii_lowercase() || c == '-'),
                "non-kebab name {}",
                k.name()
            );
        }
        assert_eq!(seen.len(), EventKind::ALL.len());
    }

    #[test]
    fn instants_are_faults() {
        for k in EventKind::ALL {
            if k.is_instant() {
                assert_eq!(k.category(), "fault");
            }
            if k.is_comm() {
                assert_eq!(k.category(), "proto");
            }
        }
    }
}
