//! LambdaML AllReduce: master-aggregated synchronization (§2, Table 1).
//!
//! Per batch round every worker pushes its gradient to shared storage; a
//! designated master (worker 0) fetches all of them, aggregates, and pushes
//! the result; everyone fetches the aggregate and updates locally. Simple,
//! but the master serializes `W` gradient transfers per round — the
//! scalability bottleneck the paper measures in Fig. 2 (21.88 s at 16
//! workers on ResNet-50).

use crate::cloud::FrameworkKind;
use crate::metrics::Stage;
use crate::tensor::Slab;
use crate::Result;

use super::env::{ClusterEnv, Device};
use super::{EpochStats, Strategy};

#[derive(Debug, Default)]
pub struct AllReduce {
    pub master: usize,
}

impl AllReduce {
    pub fn new() -> AllReduce {
        AllReduce { master: 0 }
    }

    /// One synchronization round after gradients are computed: workers put,
    /// master aggregates, workers fetch + update. Factored out so Fig. 2 can
    /// measure a single round's communication time.
    pub fn sync_round(
        &self,
        env: &mut ClusterEnv,
        round_tag: &str,
        grads: Vec<Slab>,
    ) -> Result<()> {
        let w_count = env.num_workers();

        // Every worker uploads its gradient.
        for w in 0..w_count {
            let key = format!("{round_tag}/g{w}");
            let t0 = env.workers[w].clock;
            let done = env.store.put(t0, &key, grads[w].clone(), &mut env.ledger, &mut env.comm);
            let dt = done - t0;
            env.workers[w].clock = done;
            env.stages.add(Stage::Synchronize, dt);
        }

        // Master bulk-fetches all gradients (pipelined over one connection,
        // still serialized on its clock — the Fig. 2 bottleneck), averages.
        let m = self.master;
        let keys: Vec<String> = (0..w_count).map(|w| format!("{round_tag}/g{w}")).collect();
        let t0 = env.workers[m].clock;
        let (done, fetched) = env.store.get_many(t0, &keys, &mut env.ledger, &mut env.comm)?;
        env.stages.add(Stage::Synchronize, done - t0);
        env.workers[m].clock = done;
        let agg_secs = env.local_agg_secs(w_count);
        env.workers[m].clock += agg_secs;
        env.stages.add(Stage::Synchronize, agg_secs);
        let mean = Slab::mean(&fetched)?;
        let t0 = env.workers[m].clock;
        let done = env.store.put(t0, &format!("{round_tag}/agg"), mean, &mut env.ledger, &mut env.comm);
        env.stages.add(Stage::Synchronize, done - t0);
        env.workers[m].clock = done;

        // Everyone fetches the aggregate and applies it.
        for w in 0..w_count {
            let t0 = env.workers[w].clock;
            let (done, agg) = env.store.get(t0, &format!("{round_tag}/agg"), &mut env.ledger, &mut env.comm)?;
            env.stages.add(Stage::Synchronize, done - t0);
            env.workers[w].clock = done;
            // Gradients were already averaged by the master: inv_k = 1.
            env.apply_update(w, &agg, 1.0)?;
        }
        Ok(())
    }
}

impl Strategy for AllReduce {
    fn kind(&self) -> FrameworkKind {
        FrameworkKind::AllReduce
    }

    fn run_epoch(&mut self, env: &mut ClusterEnv) -> Result<EpochStats> {
        env.begin_epoch();
        let w_count = env.num_workers();
        let start = env.max_clock();
        let alloc_mb = env.allocated_mb();
        let mut loss_sum = 0.0;
        let mut loss_n = 0usize;

        for round in 0..env.batches_per_epoch {
            let tag = format!("e{}/r{}", env.epoch, round);

            // Each batch is one stateless invocation per worker.
            let mut invs = Vec::with_capacity(w_count);
            let mut grads = Vec::with_capacity(w_count);
            for w in 0..w_count {
                let inv = env.lambda.begin_invocation(env.workers[w].clock, w);
                env.workers[w].clock = inv.body_start;
                invs.push(inv);
                env.state_load(w);
                let g = env.compute_grad(w, Device::LambdaCpu)?;
                if let Some(l) = g.loss {
                    loss_sum += l;
                    loss_n += 1;
                }
                grads.push(g.grad);
            }

            self.sync_round(env, &tag, grads)?;

            // Residual orchestration overhead (calibration), then billing.
            let overhead = self.kind().batch_overhead();
            for w in 0..w_count {
                env.charge_sync(w, overhead);
                let end = env.workers[w].clock;
                env.lambda.finish_invocation(invs[w], end, alloc_mb, &mut env.ledger);
            }
        }

        let epoch_secs = env.max_clock() - start;
        Ok(EpochStats {
            mean_loss: (loss_n > 0).then(|| loss_sum / loss_n as f64),
            batches: env.batches_per_epoch * w_count,
            epoch_secs,
            mean_fn_secs: env.lambda.mean_duration(),
        })
    }

    fn stage_table(&self) -> Vec<(Stage, &'static str)> {
        vec![
            (Stage::FetchDataset, "Each worker fetches a minibatch."),
            (
                Stage::ComputeGradients,
                "Gradients are computed for the minibatch and stored in a shared database.",
            ),
            (
                Stage::Synchronize,
                "A designated master worker retrieves all gradients, aggregates, stores the \
                 result; other workers fetch the aggregated gradient.",
            ),
            (Stage::ModelUpdate, "Workers apply the aggregated gradient to update the model."),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::FrameworkKind;
    use crate::coordinator::env::EnvConfig;

    fn env(workers: usize) -> ClusterEnv {
        ClusterEnv::new(
            EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", workers).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn epoch_runs_and_bills_all_invocations() {
        let mut e = env(4);
        let stats = AllReduce::new().run_epoch(&mut e).unwrap();
        assert_eq!(stats.batches, 4 * 24);
        assert_eq!(e.lambda.invocations, 4 * 24);
        assert!(stats.epoch_secs > 0.0);
        assert!(e.ledger.total_paper() > 0.0);
        // per-batch duration should land in the paper's ballpark (14.38 s)
        assert!(
            (stats.mean_fn_secs - 14.382).abs() / 14.382 < 0.15,
            "mean fn duration {:.2}s vs paper 14.382s",
            stats.mean_fn_secs
        );
    }

    #[test]
    fn master_is_slowest_clock() {
        let mut e = env(4);
        AllReduce::new().run_epoch(&mut e).unwrap();
        // Master (w0) fetched W grads per round; its clock must lead or tie.
        let m = e.workers[0].clock;
        assert!(e.workers.iter().all(|w| w.clock <= m));
    }

    #[test]
    fn comm_scales_with_workers() {
        let mut small = env(4);
        AllReduce::new().run_epoch(&mut small).unwrap();
        let mut big = env(8);
        AllReduce::new().run_epoch(&mut big).unwrap();
        assert!(big.comm.wire_bytes() > small.comm.wire_bytes() * 3 / 2);
    }
}
