//! Integration tests for the invariant auditor (`slsgpu::analysis`).
//!
//! Three layers:
//! - fixture goldens: the mini-repo under `rust/tests/fixtures/audit/` is
//!   audited and the rendered report compared byte-for-byte against
//!   goldens produced by `python/tools/gen_audit_goldens.py` — so the
//!   byte-identity of the Rust and Python auditors is a test, not just a
//!   CI property;
//! - in-memory workspaces: each rule's firing, suppression and scope
//!   behaviour pinned with minimal assembled inputs;
//! - the repo itself: `cargo run -- audit` must be clean, which is also
//!   asserted here so `cargo test` alone catches a new violation.

use std::path::Path;

use slsgpu::analysis::{audit_repo, audit_workspace, RuleId, Workspace};

const FIXTURE_DIR: &str = "rust/tests/fixtures/audit";

fn fixture_audit() -> slsgpu::analysis::Audit {
    let ws = Workspace::from_disk(Path::new(FIXTURE_DIR)).expect("fixture dir readable");
    audit_workspace(&ws)
}

// ---------------------------------------------------------------------------
// Fixture goldens (cross-checked against the Python auditor)

#[test]
fn fixture_text_matches_python_golden() {
    let report = fixture_audit().report();
    assert_eq!(report.to_text(), include_str!("golden/audit_fixture.txt"));
}

#[test]
fn fixture_json_matches_python_golden() {
    let report = fixture_audit().report();
    assert_eq!(
        format!("{}\n", report.to_json()),
        include_str!("golden/audit_fixture.json")
    );
}

#[test]
fn fixture_counts_are_pinned() {
    let audit = fixture_audit();
    assert_eq!(audit.open_count(), 14);
    assert_eq!(audit.allows.len(), 3);
    assert!(!audit.clean());
    // Every rule fires at least once across open + suppressed findings.
    for rule in [
        RuleId::UnorderedIteration,
        RuleId::VtimePurity,
        RuleId::FloatReduction,
        RuleId::TargetRegistration,
        RuleId::TraceEmit,
        RuleId::GeneratedDocs,
        RuleId::StaleAllow,
    ] {
        assert!(
            audit.findings.iter().any(|f| f.rule == rule),
            "rule {:?} never fired in the fixture",
            rule
        );
    }
}

#[test]
fn fixture_audit_is_deterministic() {
    let a = fixture_audit().report().to_text();
    let b = fixture_audit().report().to_text();
    assert_eq!(a, b);
}

// ---------------------------------------------------------------------------
// In-memory workspaces: per-rule behaviour

fn ws_with(path: &str, src: &str) -> Workspace {
    let mut ws = Workspace::new();
    ws.add(path, src);
    ws
}

#[test]
fn unordered_iteration_fires_in_sim_paths_only() {
    let src = "use std::collections::HashMap;\n";
    let audit = audit_workspace(&ws_with("rust/src/sim/vtime.rs", src));
    assert_eq!(audit.open_count(), 1);
    assert_eq!(audit.findings[0].rule, RuleId::UnorderedIteration);
    assert_eq!(audit.findings[0].line, 1);

    // runtime/ is out of scope by design (host-side memoization only).
    let audit = audit_workspace(&ws_with("rust/src/runtime/engine.rs", src));
    assert!(audit.findings.iter().all(|f| f.rule != RuleId::UnorderedIteration));
}

#[test]
fn tokens_in_comments_and_strings_do_not_fire() {
    let src = "// HashMap in a comment\nlet s = \"Instant::now\";\n";
    let audit = audit_workspace(&ws_with("rust/src/sim/vtime.rs", src));
    assert!(audit.clean(), "{:?}", audit.findings);
}

#[test]
fn vtime_purity_exempts_util_cli() {
    let src = "let args = std::env::args();\n";
    let audit = audit_workspace(&ws_with("rust/src/util/cli.rs", src));
    assert!(audit.clean());
    let audit = audit_workspace(&ws_with("rust/src/util/json.rs", src));
    assert_eq!(audit.open_count(), 1);
    assert_eq!(audit.findings[0].rule, RuleId::VtimePurity);
}

#[test]
fn float_reduction_exempts_tensor() {
    let src = "pub fn s(xs: &[f32]) -> f32 { xs.iter().sum::<f32>() }\n";
    let audit = audit_workspace(&ws_with("rust/src/tensor/kernels.rs", src));
    assert!(audit.clean());
    let audit = audit_workspace(&ws_with("rust/src/exp/table1.rs", src));
    assert_eq!(audit.open_count(), 1);
    assert_eq!(audit.findings[0].rule, RuleId::FloatReduction);
}

#[test]
fn trace_emit_exempts_sanctioned_files() {
    let src = "let e = EventKind::Poll;\n";
    for exempt in [
        "rust/src/coordinator/protocol.rs",
        "rust/src/coordinator/env.rs",
        "rust/src/trace/mod.rs",
    ] {
        let audit = audit_workspace(&ws_with(exempt, src));
        assert!(audit.clean(), "{exempt} should be exempt");
    }
    let audit = audit_workspace(&ws_with("rust/src/coordinator/spirt.rs", src));
    assert_eq!(audit.open_count(), 1);
    assert_eq!(audit.findings[0].rule, RuleId::TraceEmit);
}

#[test]
fn trailing_allow_suppresses_and_is_listed() {
    let src = "use std::collections::HashMap; // audit:allow(unordered-iteration, lookup only)\n";
    let audit = audit_workspace(&ws_with("rust/src/cloud/redis.rs", src));
    assert!(audit.clean());
    assert_eq!(audit.findings.len(), 1);
    assert_eq!(audit.findings[0].suppressed.as_deref(), Some("lookup only"));
    assert_eq!(audit.allows.len(), 1);
    assert_eq!(audit.allows[0].rule, RuleId::UnorderedIteration);
}

#[test]
fn comment_line_allow_covers_the_following_statement() {
    let src = "// audit:allow(trace-emit, spans the whole call)\n\
               let idx = trace.span(\n    a,\n    EventKind::Poll,\n);\n";
    let audit = audit_workspace(&ws_with("rust/src/coordinator/spirt.rs", src));
    assert!(audit.clean(), "{:?}", audit.findings);
    assert_eq!(audit.allows.len(), 1);
}

#[test]
fn allow_does_not_reach_past_the_statement_end() {
    // The allow covers the first statement (ends with `;`); the second
    // HashMap line is outside its span and stays open.
    let src = "// audit:allow(unordered-iteration, first statement only)\n\
               let a: HashMap<u32, u32> = HashMap::new();\n\
               let b: HashMap<u32, u32> = HashMap::new();\n";
    let audit = audit_workspace(&ws_with("rust/src/sim/vtime.rs", src));
    assert_eq!(audit.open_count(), 1);
    assert_eq!(audit.open().next().unwrap().line, 3);
}

#[test]
fn stale_unknown_and_reasonless_allows_are_findings() {
    let src = "// audit:allow(unordered-iteration, nothing below)\n\
               fn a() {}\n\
               // audit:allow(bogus-rule, whatever)\n\
               // audit:allow(vtime-purity)\n";
    let audit = audit_workspace(&ws_with("rust/src/sim/vtime.rs", src));
    let details: Vec<&str> = audit.open().map(|f| f.detail.as_str()).collect();
    assert_eq!(audit.open_count(), 3);
    assert!(audit.open().all(|f| f.rule == RuleId::StaleAllow));
    assert!(details.iter().any(|d| d.contains("suppresses nothing")));
    assert!(details.iter().any(|d| d.contains("unknown rule `bogus-rule`")));
    assert!(details.iter().any(|d| d.contains("has no justification")));
}

#[test]
fn registration_catches_ghosts_and_unregistered_targets() {
    let mut ws = Workspace::new();
    ws.add(
        "Cargo.toml",
        "[package]\nname = \"x\"\n\n\
         [[test]]\nname = \"present\"\npath = \"rust/tests/present.rs\"\n\n\
         [[test]]\nname = \"ghost\"\npath = \"rust/tests/ghost.rs\"\n",
    );
    ws.add("rust/tests/present.rs", "#[test]\nfn t() {}\n");
    ws.add("rust/tests/orphan.rs", "#[test]\nfn t() {}\n");
    let audit = audit_workspace(&ws);
    assert_eq!(audit.open_count(), 2);
    let mut opens = audit.open();
    let ghost = opens.next().unwrap();
    assert_eq!(ghost.file, "Cargo.toml");
    assert!(ghost.detail.contains("points at missing rust/tests/ghost.rs"));
    let orphan = opens.next().unwrap();
    assert_eq!(orphan.file, "rust/tests/orphan.rs");
    assert!(orphan.detail.contains("no [[test]] entry"));
}

#[test]
fn docs_markers_are_required() {
    let mut ws = Workspace::new();
    ws.add("docs/good.md", "# t\n\n> Generated by `slsgpu report` — do not edit by hand.\n");
    ws.add("docs/bad.md", "# hand-written\n");
    ws.add("docs/data/good.json", "{\"command\":\"slsgpu exp\"}\n");
    ws.add("docs/data/bad.json", "{}\n");
    let audit = audit_workspace(&ws);
    assert_eq!(audit.open_count(), 2);
    let files: Vec<&str> = audit.open().map(|f| f.file.as_str()).collect();
    assert_eq!(files, vec!["docs/bad.md", "docs/data/bad.json"]);
}

// ---------------------------------------------------------------------------
// The repo audits itself

#[test]
fn repo_audit_is_clean() {
    // CWD under `cargo test` is the package root. Skip quietly when the
    // sources are not present (e.g. a packaged test run).
    let audit = match audit_repo(Path::new(".")) {
        Ok(a) => a,
        Err(_) => return,
    };
    if audit.checked.get("stale-allow").copied().unwrap_or(0) == 0 {
        return; // no rust/src tree collected; not a checkout
    }
    let open: Vec<String> = audit
        .open()
        .map(|f| format!("{}:{} {} — {}", f.file, f.line, f.rule.name(), f.detail))
        .collect();
    assert!(open.is_empty(), "repo audit found open violations:\n{}", open.join("\n"));
    assert!(
        !audit.allows.is_empty(),
        "the repo carries known suppressions; none being found means the scanner broke"
    );
}
