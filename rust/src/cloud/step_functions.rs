//! AWS Step Functions substrate — SPIRT's orchestration layer.
//!
//! SPIRT drives its stage pipeline (fetch → compute → sync → update) with a
//! Step Functions state machine; every stage boundary is a billed state
//! transition with a small latency. The overhead is tiny per transition but
//! SPIRT pays it per batch per worker, which is part of why its per-batch
//! duration exceeds the LambdaML variants (Table 2 calibration).

use crate::metrics::{CostKind, Ledger};
use crate::sim::VTime;

use super::calibration::STEPFN_TRANSITION_LATENCY;
use super::pricing;

/// A state machine execution context.
#[derive(Debug, Default)]
pub struct StepFunctions {
    pub transitions: u64,
    latency: f64,
}

impl StepFunctions {
    pub fn new() -> StepFunctions {
        StepFunctions { transitions: 0, latency: STEPFN_TRANSITION_LATENCY }
    }

    /// Execute one state transition at `now`; returns when the next state
    /// may begin.
    pub fn transition(&mut self, now: VTime, ledger: &mut Ledger) -> VTime {
        self.transitions += 1;
        ledger.charge(CostKind::StepFnTransitions, pricing::stepfn_cost(1));
        now + self.latency
    }

    /// A named stage boundary (same cost; name aids tracing/tests).
    pub fn enter_stage(&mut self, now: VTime, _stage: &str, ledger: &mut Ledger) -> VTime {
        self.transition(now, ledger)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transitions_advance_time_and_bill() {
        let mut sfn = StepFunctions::new();
        let mut ledger = Ledger::new();
        let t1 = sfn.transition(VTime::ZERO, &mut ledger);
        let t2 = sfn.enter_stage(t1, "sync", &mut ledger);
        assert!((t2.secs() - 2.0 * STEPFN_TRANSITION_LATENCY).abs() < 1e-12);
        assert_eq!(sfn.transitions, 2);
        assert!((ledger.get(CostKind::StepFnTransitions) - pricing::stepfn_cost(2)).abs() < 1e-15);
    }
}
