//! Fixture: unordered-iteration positives and negatives.
//! A HashMap mentioned in a comment is never a finding.

use std::collections::{BTreeMap, HashMap, HashSet};

pub struct State {
    pub by_worker: HashMap<usize, u64>,
    pub warm: HashSet<usize>, // audit:allow(unordered-iteration, membership-only set - never iterated)
    pub ordered: BTreeMap<usize, u64>,
}

pub fn describe() -> &'static str {
    "uses HashMap internally" // token inside a string literal: not a finding
}

// audit:allow(unordered-iteration, stale - nothing below matches)
pub fn ordered_only(m: &BTreeMap<usize, u64>) -> u64 {
    m.values().sum()
}

// audit:allow(vtime-purity, unterminated
pub fn noop() {}
