//! Communication-pattern anatomy: what actually crosses the wire, per
//! framework, for one paper-scale epoch (MobileNet, 4 workers).
//!
//! ```sh
//! cargo run --release --example comm_patterns
//! ```

use slsgpu::cloud::FrameworkKind;
use slsgpu::coordinator::{strategy_for, ClusterEnv, EnvConfig};
use slsgpu::metrics::CommKind;
use slsgpu::util::fmt_bytes;
use slsgpu::util::table::{Align, Table};

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(&[
        "Framework",
        "Puts",
        "Gets",
        "Queue msgs",
        "Wire bytes",
        "In-DB bytes",
        "Sync time (s)",
    ])
    .align(&[
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ])
    .title("Communication per epoch — MobileNet, B=512, 4 workers x 24 batches");

    for fw in FrameworkKind::ALL {
        let mut env = ClusterEnv::new(EnvConfig::virtual_paper(fw, "mobilenet", 4)?)?;
        strategy_for(fw).run_epoch(&mut env)?;
        t.row(vec![
            fw.name().to_string(),
            env.comm.ops(CommKind::Put).to_string(),
            env.comm.ops(CommKind::Get).to_string(),
            (env.comm.ops(CommKind::Publish) + env.comm.ops(CommKind::Poll)).to_string(),
            fmt_bytes(env.comm.wire_bytes()),
            fmt_bytes(env.comm.bytes(CommKind::InDb)),
            format!("{:.1}", env.stages.get(slsgpu::metrics::Stage::Synchronize)),
        ]);
    }
    print!("{}", t.render());
    println!("\nNote how SPIRT's traffic is dominated by in-database bytes (the RedisAI ops)");
    println!("while the LambdaML variants move every gradient over the wire each batch.");
    Ok(())
}
