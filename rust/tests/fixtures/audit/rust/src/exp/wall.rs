//! Fixture: vtime-purity and float-reduction positives, plus the stale
//! allow variants (unknown rule, missing reason).

// audit:allow(vtime-purity, fixture - import sanctioned for host-side reporting)
use std::time::Instant;

pub fn wall_ms() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64() * 1e3
}

pub fn reduce(xs: &[f32]) -> f32 {
    xs.iter().sum::<f32>()
}

// audit:allow(no-such-rule, typo in the rule name)
pub fn unknown_rule() {}

// audit:allow(vtime-purity)
pub fn missing_reason() {}
