//! Robust aggregation rules — the defense side of the gradient-poisoning
//! scenarios (SPIRT §6 "Byzantine tolerance"; Barrak et al. 2309.14148).
//!
//! A poisoned worker submits a scaled or sign-flipped update; the naive
//! arithmetic mean lets a single such worker steer the global step
//! arbitrarily. Two standard robust estimators bound that influence:
//!
//! * **Clipped mean** — every contribution's L2 norm is clipped to a
//!   multiple of the *median* contribution norm before averaging, so one
//!   worker's influence is bounded by `ratio × median / k` regardless of
//!   how large its update is.
//! * **Coordinate-wise median** — each parameter takes the median across
//!   workers, ignoring up to `(k-1)/2` arbitrary outliers per coordinate.
//! * **Krum** (Blanchard et al., NeurIPS 2017) — selects the single
//!   contribution whose summed squared distance to its `n − f − 2` nearest
//!   neighbours is smallest. With at most `f` Byzantine workers and
//!   `n ≥ 2f + 3`, the selected vector is within a bounded distance of an
//!   honest one — and unlike the clipped mean, Krum defeats *norm-
//!   disguised* attacks (e.g. sign flips at honest magnitude) because it
//!   scores geometry, not length.
//! * **Trimmed mean** — per coordinate, drop the `k` lowest and `k`
//!   highest values and average the remaining `n − 2k`; tolerates up to
//!   `k` Byzantine workers per coordinate while averaging more honest
//!   signal than the median when `n` is large.
//!
//! All rules preserve the slab contract: virtual (size-only) inputs produce a
//! virtual output of the same length, so the cost-model experiments traverse
//! the identical code path the end-to-end runs use.

use anyhow::{bail, Result};

use super::slab::Slab;

/// How a set of worker updates is combined into one gradient.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AggregationRule {
    /// Plain arithmetic mean (the paper's baseline in every framework).
    Mean,
    /// Norm-clip each contribution to `ratio × median norm`, then average.
    ClippedMean { ratio: f64 },
    /// Coordinate-wise median across contributions.
    CoordMedian,
    /// Krum selection assuming at most `f` Byzantine contributions.
    Krum { f: usize },
    /// Coordinate-wise mean after trimming the `k` lowest and `k` highest.
    TrimmedMean { k: usize },
}

impl AggregationRule {
    /// Parse a CLI spec: `mean`, `clipped`, `clipped:<ratio>`, `median`,
    /// `krum`, `krum:<f>`, `trimmed:<k>`.
    pub fn parse(spec: &str) -> Result<AggregationRule> {
        let spec = spec.trim().to_ascii_lowercase();
        Ok(match spec.as_str() {
            "mean" => AggregationRule::Mean,
            "clipped" => AggregationRule::ClippedMean { ratio: 1.0 },
            "median" | "coord-median" => AggregationRule::CoordMedian,
            "krum" => AggregationRule::Krum { f: 1 },
            other => {
                if let Some(r) = other.strip_prefix("clipped:") {
                    AggregationRule::ClippedMean { ratio: r.parse()? }
                } else if let Some(f) = other.strip_prefix("krum:") {
                    AggregationRule::Krum { f: f.parse()? }
                } else if let Some(k) = other.strip_prefix("trimmed:") {
                    AggregationRule::TrimmedMean { k: k.parse()? }
                } else {
                    bail!(
                        "unknown aggregation rule {other:?} \
                         (mean|clipped[:r]|median|krum[:f]|trimmed:k)"
                    )
                }
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AggregationRule::Mean => "mean",
            AggregationRule::ClippedMean { .. } => "clipped-mean",
            AggregationRule::CoordMedian => "coord-median",
            AggregationRule::Krum { .. } => "krum",
            AggregationRule::TrimmedMean { .. } => "trimmed-mean",
        }
    }

    /// Relative in-function compute cost vs the plain mean (extra slab
    /// passes: norm computation + clip for the clipped mean, per-coordinate
    /// sorting for the median and trimmed mean, all-pairs distances for
    /// Krum). The env charges this on the virtual clock.
    pub fn cost_multiplier(&self) -> f64 {
        match self {
            AggregationRule::Mean => 1.0,
            AggregationRule::ClippedMean { .. } => 2.0,
            AggregationRule::CoordMedian => 4.0,
            AggregationRule::TrimmedMean { .. } => 5.0,
            AggregationRule::Krum { .. } => 6.0,
        }
    }

    /// Combine `slabs` under this rule.
    pub fn apply(&self, slabs: &[Slab]) -> Result<Slab> {
        match self {
            AggregationRule::Mean => Slab::mean(slabs),
            AggregationRule::ClippedMean { ratio } => clipped_mean(slabs, *ratio),
            AggregationRule::CoordMedian => coordinate_median(slabs),
            AggregationRule::Krum { f } => krum(slabs, *f),
            AggregationRule::TrimmedMean { k } => trimmed_mean(slabs, *k),
        }
    }
}

fn check(slabs: &[Slab]) -> Result<(usize, bool)> {
    if slabs.is_empty() {
        bail!("aggregation of zero slabs");
    }
    let len = slabs[0].len();
    if slabs.iter().any(|s| s.len() != len) {
        bail!("slab length mismatch in aggregation");
    }
    Ok((len, slabs.iter().all(|s| s.is_real())))
}

/// Median via selection (`select_nth_unstable`), reordering `values` in
/// place: O(k) instead of the full O(k log k) sort the old implementation
/// paid per call — and `coordinate_median` calls this once *per parameter*.
/// The median is a function of the value multiset only, so selection
/// returns exactly the values the sort-based version produced (mean of the
/// two middles for even k).
fn median_of(values: &mut [f64]) -> f64 {
    let k = values.len();
    let (lo, mid, _) = values.select_nth_unstable_by(k / 2, f64::total_cmp);
    let hi = *mid;
    if k % 2 == 1 {
        hi
    } else {
        // The k/2-1'th order statistic is the max of the left partition.
        let lo_max = lo.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        0.5 * (lo_max + hi)
    }
}

/// Mean of `slabs` with each contribution's L2 norm clipped to
/// `ratio × median(norms)`. Virtual if any input is.
pub fn clipped_mean(slabs: &[Slab], ratio: f64) -> Result<Slab> {
    let (len, real) = check(slabs)?;
    if !real {
        return Ok(Slab::virtual_of(len));
    }
    let norms: Vec<f64> = slabs.iter().map(|s| s.l2_norm_sq().sqrt()).collect();
    let mut sorted = norms.clone();
    let clip = ratio * median_of(&mut sorted);
    let inv_k = 1.0 / slabs.len() as f32;
    let weights: Vec<f32> = norms
        .iter()
        .map(|norm| {
            let w = if *norm > clip && *norm > 0.0 { (clip / norm) as f32 } else { 1.0 };
            w * inv_k
        })
        .collect();
    // Single blocked pass (same shape as `Slab::mean`): per output element
    // the weighted adds still run in slab order with the old `+= w * y`
    // expression, so the result is bit-identical to the k-sweep `axpy` form
    // it replaces while touching each gradient block once, cache-resident.
    let views: Vec<&[f32]> = slabs.iter().map(|s| s.as_slice()).collect::<Result<_>>()?;
    let mut out = vec![0.0f32; len];
    let mut start = 0;
    while start < len {
        let end = (start + super::KERNEL_CHUNK).min(len);
        let ob = &mut out[start..end];
        for (v, w) in views.iter().zip(weights.iter()) {
            for (x, y) in ob.iter_mut().zip(v[start..end].iter()) {
                *x += *w * *y;
            }
        }
        start = end;
    }
    Ok(Slab::from_vec(out))
}

/// Coordinate-wise median across `slabs`. Virtual if any input is.
pub fn coordinate_median(slabs: &[Slab]) -> Result<Slab> {
    let (len, real) = check(slabs)?;
    if !real {
        return Ok(Slab::virtual_of(len));
    }
    let views: Vec<&[f32]> = slabs.iter().map(|s| s.as_slice()).collect::<Result<_>>()?;
    let mut out = Vec::with_capacity(len);
    let mut column: Vec<f64> = Vec::with_capacity(views.len());
    for j in 0..len {
        column.clear();
        column.extend(views.iter().map(|v| v[j] as f64));
        out.push(median_of(&mut column) as f32);
    }
    Ok(Slab::from_vec(out))
}

/// Krum selection: return a copy of the contribution whose summed squared
/// L2 distance to its `n − f − 2` nearest neighbours is smallest (ties
/// break toward the lower index, so the result is independent of any
/// intermediate ordering). Requires `n ≥ f + 3` so every candidate has at
/// least one scored neighbour. Virtual if any input is.
pub fn krum(slabs: &[Slab], f: usize) -> Result<Slab> {
    let (len, real) = check(slabs)?;
    let n = slabs.len();
    if n < f + 3 {
        bail!("krum needs n >= f + 3 contributions (got n={n}, f={f})");
    }
    if !real {
        return Ok(Slab::virtual_of(len));
    }
    let views: Vec<&[f32]> = slabs.iter().map(|s| s.as_slice()).collect::<Result<_>>()?;
    // Pairwise squared distances, accumulated in f64 so the scores are
    // independent of summation blocking.
    let mut d2 = vec![0.0f64; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let mut acc = 0.0f64;
            for (a, b) in views[i].iter().zip(views[j].iter()) {
                let d = (*a as f64) - (*b as f64);
                acc += d * d;
            }
            d2[i * n + j] = acc;
            d2[j * n + i] = acc;
        }
    }
    let m = n - f - 2; // neighbours scored per candidate
    let mut best = 0usize;
    let mut best_score = f64::INFINITY;
    let mut row: Vec<f64> = Vec::with_capacity(n - 1);
    for i in 0..n {
        row.clear();
        row.extend((0..n).filter(|j| *j != i).map(|j| d2[i * n + j]));
        row.sort_unstable_by(f64::total_cmp);
        let score: f64 = row[..m].iter().sum();
        // Strict `<` keeps the lowest index on ties.
        if score < best_score {
            best_score = score;
            best = i;
        }
    }
    Ok(Slab::from_vec(views[best].to_vec()))
}

/// Coordinate-wise trimmed mean: per parameter, drop the `k` lowest and
/// `k` highest contributions and average the remaining `n − 2k` (in sorted
/// order, accumulated in f64). Requires `n > 2k`. Virtual if any input is.
pub fn trimmed_mean(slabs: &[Slab], k: usize) -> Result<Slab> {
    let (len, real) = check(slabs)?;
    let n = slabs.len();
    if n <= 2 * k {
        bail!("trimmed mean needs n > 2k contributions (got n={n}, k={k})");
    }
    if !real {
        return Ok(Slab::virtual_of(len));
    }
    let views: Vec<&[f32]> = slabs.iter().map(|s| s.as_slice()).collect::<Result<_>>()?;
    let kept = (n - 2 * k) as f64;
    let mut out = Vec::with_capacity(len);
    let mut column: Vec<f64> = Vec::with_capacity(n);
    for j in 0..len {
        column.clear();
        column.extend(views.iter().map(|v| v[j] as f64));
        column.sort_unstable_by(f64::total_cmp);
        let sum: f64 = column[k..n - k].iter().sum();
        out.push((sum / kept) as f32);
    }
    Ok(Slab::from_vec(out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slab(v: &[f32]) -> Slab {
        Slab::from_vec(v.to_vec())
    }

    #[test]
    fn clipped_mean_bounds_an_outlier() {
        // Three honest unit-ish updates, one 100× outlier: the outlier's
        // influence is clipped to the median norm, so the mean stays near
        // the honest direction instead of being dragged 25× away.
        let honest = [slab(&[1.0, 0.0]), slab(&[1.1, 0.0]), slab(&[0.9, 0.0])];
        let poison = slab(&[-100.0, 0.0]);
        let all = [honest[0].clone(), honest[1].clone(), honest[2].clone(), poison];
        let naive = Slab::mean(&all).unwrap();
        assert!(naive.as_slice().unwrap()[0] < -20.0, "naive mean is hijacked");
        let robust = clipped_mean(&all, 1.0).unwrap();
        let x = robust.as_slice().unwrap()[0];
        assert!(x > 0.3 && x < 1.0, "clipped mean stays honest, got {x}");
    }

    #[test]
    fn coord_median_ignores_minority_outliers() {
        let m = coordinate_median(&[
            slab(&[1.0, 5.0]),
            slab(&[2.0, 6.0]),
            slab(&[1000.0, -1000.0]),
        ])
        .unwrap();
        assert_eq!(m.as_slice().unwrap(), &[2.0, 5.0]);
    }

    #[test]
    fn median_even_count_averages_middles() {
        let m = coordinate_median(&[slab(&[1.0]), slab(&[3.0]), slab(&[5.0]), slab(&[100.0])])
            .unwrap();
        assert_eq!(m.as_slice().unwrap(), &[4.0]);
    }

    const ALL_RULES: [AggregationRule; 5] = [
        AggregationRule::Mean,
        AggregationRule::ClippedMean { ratio: 1.0 },
        AggregationRule::CoordMedian,
        AggregationRule::Krum { f: 1 },
        AggregationRule::TrimmedMean { k: 1 },
    ];

    #[test]
    fn rules_match_mean_on_clean_identical_inputs() {
        // Four inputs so Krum's n >= f + 3 floor is met.
        let xs: Vec<Slab> = (0..4).map(|_| slab(&[2.0, -4.0])).collect();
        for rule in ALL_RULES {
            let out = rule.apply(&xs).unwrap();
            assert_eq!(out.as_slice().unwrap(), &[2.0, -4.0], "{}", rule.name());
        }
    }

    #[test]
    fn virtual_slabs_pass_through() {
        for rule in ALL_RULES {
            let xs: Vec<Slab> = (0..4).map(|_| Slab::virtual_of(7)).collect();
            let out = rule.apply(&xs).unwrap();
            assert!(!out.is_real(), "{}", rule.name());
            assert_eq!(out.len(), 7);
        }
    }

    #[test]
    fn parse_specs() {
        assert_eq!(AggregationRule::parse("mean").unwrap(), AggregationRule::Mean);
        assert_eq!(
            AggregationRule::parse("clipped:1.5").unwrap(),
            AggregationRule::ClippedMean { ratio: 1.5 }
        );
        assert_eq!(AggregationRule::parse("median").unwrap(), AggregationRule::CoordMedian);
        assert_eq!(AggregationRule::parse("krum").unwrap(), AggregationRule::Krum { f: 1 });
        assert_eq!(AggregationRule::parse("krum:2").unwrap(), AggregationRule::Krum { f: 2 });
        assert_eq!(
            AggregationRule::parse("trimmed:2").unwrap(),
            AggregationRule::TrimmedMean { k: 2 }
        );
        assert!(AggregationRule::parse("bulyan").is_err());
        assert!(AggregationRule::parse("trimmed").is_err(), "trimmed requires an explicit k");
    }

    #[test]
    fn krum_selects_an_honest_input_under_coalition() {
        // Five honest vectors clustered at (1, 0), two colluders at
        // (-9, -9): each colluder's nearest n-f-2 = 3 neighbours include
        // honest vectors far away, so colluder scores blow up and Krum
        // returns one of the honest inputs verbatim.
        let xs = [
            slab(&[1.0, 0.0]),
            slab(&[1.1, 0.1]),
            slab(&[0.9, -0.1]),
            slab(&[1.05, 0.0]),
            slab(&[0.95, 0.05]),
            slab(&[-9.0, -9.0]),
            slab(&[-9.1, -9.1]),
        ];
        let out = krum(&xs, 2).unwrap();
        let v = out.as_slice().unwrap();
        assert!(v[0] > 0.8 && v[0] < 1.2, "krum picked a colluder: {v:?}");
        // The output is one of the inputs, byte for byte.
        assert!(xs.iter().any(|x| x.as_slice().unwrap() == v));
    }

    #[test]
    fn krum_breaks_ties_toward_the_lower_index() {
        // Two identical tight pairs, equidistant geometry: scores tie, and
        // the selection must be index 0 regardless of evaluation order.
        let xs = [
            slab(&[1.0, 0.0]),
            slab(&[1.0, 0.0]),
            slab(&[-1.0, 0.0]),
            slab(&[-1.0, 0.0]),
        ];
        let out = krum(&xs, 1).unwrap();
        assert_eq!(out.as_slice().unwrap(), &[1.0, 0.0]);
    }

    #[test]
    fn trimmed_mean_drops_extremes_per_coordinate() {
        let xs = [
            slab(&[1.0, 10.0]),
            slab(&[2.0, 20.0]),
            slab(&[3.0, 30.0]),
            slab(&[-1000.0, 40.0]),
            slab(&[4.0, 9999.0]),
        ];
        let out = trimmed_mean(&xs, 1).unwrap();
        // Coord 0 keeps {1, 2, 3}; coord 1 keeps {20, 30, 40}.
        assert_eq!(out.as_slice().unwrap(), &[2.0, 30.0]);
    }

    #[test]
    fn krum_beats_clipped_mean_on_norm_disguised_sign_flip() {
        // The counterexample that motivates geometry-aware rules: two
        // colluders submit the *negated* honest gradient at honest
        // magnitude. Norm clipping is blind to them (no norm exceeds the
        // median), so the clipped mean is dragged toward zero — while Krum
        // and the trimmed mean recover an honest-direction step.
        // The colluders are *near*-identical, not byte-identical: a pair of
        // exact duplicates would score 0 under Krum's nearest-neighbour sum
        // (the classic sybil gap), which the honest cluster must beat by
        // being tighter than the colluders are to each other.
        let honest = [1.0f32, 0.0];
        let xs = [
            slab(&honest),
            slab(&[1.02, 0.01]),
            slab(&[0.98, -0.01]),
            slab(&[-1.0, 0.0]),
            slab(&[-0.97, 0.02]),
        ];
        let clipped = clipped_mean(&xs, 1.0).unwrap();
        let c = clipped.as_slice().unwrap()[0];
        assert!(c < 0.25, "clipping should fail to filter the flip, got {c}");
        let k = krum(&xs, 2).unwrap();
        assert!(k.as_slice().unwrap()[0] > 0.9, "krum recovers the honest direction");
        let t = trimmed_mean(&xs, 2).unwrap();
        assert!(t.as_slice().unwrap()[0] > 0.9, "trimmed mean recovers too");
    }

    #[test]
    fn mismatched_lengths_error() {
        assert!(coordinate_median(&[slab(&[1.0]), slab(&[1.0, 2.0])]).is_err());
        assert!(clipped_mean(&[], 1.0).is_err());
        // Population floors: krum needs n >= f + 3, trimmed needs n > 2k.
        assert!(krum(&[slab(&[1.0]), slab(&[2.0]), slab(&[3.0])], 1).is_err());
        assert!(trimmed_mean(&[slab(&[1.0]), slab(&[2.0])], 1).is_err());
    }

    fn noise(seed: u64, len: usize) -> Vec<f32> {
        let mut state = seed;
        (0..len)
            .map(|_| {
                state = state
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                ((state >> 40) as f32) / (1u64 << 24) as f32 - 0.5
            })
            .collect()
    }

    #[test]
    fn selection_median_matches_sort_reference() {
        // Value-identity against the old sort-based median, odd and even k,
        // with duplicate values in the mix.
        for k in 1..=9usize {
            let mut vals: Vec<f64> =
                noise(77 + k as u64, k).into_iter().map(|x| (x * 8.0).round()).collect();
            let mut sorted = vals.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let reference = if k % 2 == 1 {
                sorted[k / 2]
            } else {
                0.5 * (sorted[k / 2 - 1] + sorted[k / 2])
            };
            assert_eq!(median_of(&mut vals).to_bits(), reference.to_bits(), "k={k}");
        }
    }

    #[test]
    fn blocked_clipped_mean_is_bit_identical_to_axpy_sweeps() {
        // Multi-chunk inputs with one outlier so the clip path is active.
        let len = 2 * super::super::KERNEL_CHUNK + 9;
        let mut slabs: Vec<Slab> = (0..4).map(|i| Slab::from_vec(noise(i, len))).collect();
        let mut big = noise(99, len);
        for x in &mut big {
            *x *= 50.0;
        }
        slabs.push(Slab::from_vec(big));

        // Reference: the pre-blocking implementation — per-slab axpy sweeps.
        let norms: Vec<f64> = slabs.iter().map(|s| s.l2_norm_sq().sqrt()).collect();
        let mut sorted = norms.clone();
        let clip = 1.0 * median_of(&mut sorted);
        let inv_k = 1.0 / slabs.len() as f32;
        let mut reference = Slab::zeros(len);
        for (s, norm) in slabs.iter().zip(norms.iter()) {
            let w = if *norm > clip && *norm > 0.0 { (clip / norm) as f32 } else { 1.0 };
            reference.axpy(s, w * inv_k).unwrap();
        }

        let got = clipped_mean(&slabs, 1.0).unwrap();
        let gb: Vec<u32> = got.as_slice().unwrap().iter().map(|x| x.to_bits()).collect();
        let rb: Vec<u32> =
            reference.as_slice().unwrap().iter().map(|x| x.to_bits()).collect();
        assert_eq!(gb, rb);
    }
}
