//! Protocol-level tracing: a structured, deterministic event log over the
//! simulated protocol, plus the analyses built on it.
//!
//! Every protocol op flowing through `coordinator::protocol::Timeline`
//! (put/get/redis ops/notify/poll/advance), every stage span and fault event
//! in `ClusterEnv`, and the cost each op charged to `metrics::Ledger` emits a
//! [`TraceEvent`] into the run's [`TraceCollector`] — ring-buffered, and
//! zero-cost when disabled via [`TraceConfig`] on `EnvConfig` (the default).
//! The collector is purely observational: tracing on vs off is bit-identical
//! in virtual time and cost (`rust/tests/determinism.rs`).
//!
//! Analyses:
//! - [`chrome`] — Chrome trace-event JSON for Perfetto / `chrome://tracing`,
//!   one track per worker, faults as instants, byte-deterministic.
//! - [`critical_path`] — walks happens-before edges (put→get visibility,
//!   notify→poll, barriers) plus same-worker program order to name the
//!   worker/op chain that bounds each epoch.
//! - [`histogram`] — per-op-kind latency/cost percentiles (p50/p95/p99) on
//!   `metrics::Histogram`; feeds `docs/trace.md` and the scale sweep's
//!   optional p99 column.

pub mod chrome;
pub mod collector;
pub mod critical_path;
pub mod event;
pub mod histogram;

pub use collector::{TraceCollector, TraceConfig, DEFAULT_CAPACITY};
pub use event::{EventKind, TraceEvent};
