//! slsgpu CLI — the testbed launcher.
//!
//! ```text
//! slsgpu exp table1                          # workflow-stage comparison
//! slsgpu exp table2 [--workers 4]            # time/RAM/cost per epoch
//! slsgpu exp fig2   [--workers 4,8,12,16]    # AllReduce vs ScatterReduce
//! slsgpu exp fig3   [--rates 1.0,0.5,...]    # MLLess filtering sweep (sim)
//! slsgpu exp fig3-real [--model mobilenet_s] # MLLess real-gradient contrast
//! slsgpu exp spirt-indb [--real]             # §4.2 in-DB vs naive
//! slsgpu exp table3 [--model mobilenet_s] [--epochs 20] [--csv out.csv]
//! slsgpu fault-tolerance [--arch mobilenet] [--workers 4] [--epochs 3]
//! slsgpu robustness-tournament [--attack coalition|partition|straggler-tail|preemption-storm|all]
//!                    [--arch spirt|mlless|...|all] [--model mobilenet]
//!                    [--workers 8] [--epochs 2] [--seed 42] [--threads 0]
//!                    # aggregation-rule × attack × architecture grid + Pareto verdicts
//! slsgpu scale-sweep [--workers 4,16,64,256] [--modes bsp,async:2]  # up to 4096 workers
//!                    [--arch mobilenet] [--batches 24] [--epochs 1]
//!                    [--threads 0] [--csv out.csv] [--trace]  # 5 archs × W × mode
//!                    [--shards 1] [--replication 1]  # store tier, fixed per sweep
//! slsgpu shard-sweep [--shards 1,2,4,8] [--replication 1,2] [--workers 4,16,64]
//!                    [--arch mobilenet] [--batches 24] [--epochs 1]
//!                    [--threads 0] [--csv out.csv]  # MLLess store-tier frontier
//! slsgpu trace [--arch spirt|all] [--model mobilenet] [--workers 4]
//!              [--batches 24] [--epochs 1] [--mode bsp]
//!              [--format summary|chrome|csv] [--out trace.json]
//! slsgpu report [--out docs] [--skip table2,...]    # regenerate docs/
//!               [--workers 4] [--sweep-workers 4,16,64,256]
//!               [--sweep-batches 24] [--threads 0] [--fault-epochs 3]
//! slsgpu train --framework spirt --model mobilenet_s --epochs 5
//! slsgpu artifacts                            # list compiled artifacts
//! slsgpu audit [--root .] [--format text|json] # invariant audit (exit 1 on findings)
//! ```
//!
//! Experiments that execute real gradients need `make artifacts` first and
//! accept `--artifacts <dir>` (default: ./artifacts).

use std::rc::Rc;

use anyhow::{bail, Context, Result};

use slsgpu::cloud::FrameworkKind;
use slsgpu::coordinator::{strategy_for, ClusterEnv, EnvConfig, SyncMode};
use slsgpu::exp;
use slsgpu::runtime::Engine;
use slsgpu::train::{run_session, SessionConfig};
use slsgpu::util::cli::Args;

fn main() {
    if let Err(err) = run() {
        eprintln!("error: {err:#}");
        std::process::exit(1);
    }
}

fn framework_by_name(name: &str) -> Result<FrameworkKind> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "spirt" => FrameworkKind::Spirt,
        "mlless" => FrameworkKind::MlLess,
        "allreduce" => FrameworkKind::AllReduce,
        "scatterreduce" | "scatter-reduce" => FrameworkKind::ScatterReduce,
        "gpu" | "gpu-baseline" => FrameworkKind::GpuBaseline,
        other => bail!("unknown framework {other:?} (spirt|mlless|allreduce|scatterreduce|gpu)"),
    })
}

fn engine_from(args: &Args) -> Result<Rc<Engine>> {
    let dir = args.get_or("artifacts", "artifacts");
    Ok(Rc::new(Engine::load(dir).context("loading artifacts (run `make artifacts`)")?))
}

fn parse_list(spec: &str) -> Result<Vec<usize>> {
    spec.split(',').map(|s| Ok(s.trim().parse()?)).collect()
}

fn parse_flist(spec: &str) -> Result<Vec<f64>> {
    spec.split(',').map(|s| Ok(s.trim().parse()?)).collect()
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("exp") => run_exp(&args),
        Some("fault-tolerance") => run_fault_tolerance(&args),
        Some("robustness-tournament") => run_tournament(&args),
        Some("scale-sweep") => run_scale_sweep(&args),
        Some("shard-sweep") => run_shard_sweep(&args),
        Some("trace") => run_trace(&args),
        Some("report") => run_report(&args),
        Some("audit") => run_audit(&args),
        Some("train") => run_train(&args),
        Some("artifacts") => {
            let engine = engine_from(&args)?;
            println!("artifacts in {}:", engine.manifest.dir.display());
            for (name, entry) in &engine.manifest.models {
                println!(
                    "  model {name}: arch={} n_params={} batch={} ({} artifacts)",
                    entry.arch,
                    entry.n_params,
                    entry.batch,
                    entry.artifacts.len()
                );
            }
            for (name, slab) in &engine.manifest.slabs {
                println!("  slab {name}: n={} ({} artifacts)", slab.n, slab.artifacts.len());
            }
            Ok(())
        }
        Some(other) => bail!(
            "unknown subcommand {other:?} \
             (exp|fault-tolerance|robustness-tournament|scale-sweep|shard-sweep|trace|report|\
             audit|train|artifacts)"
        ),
        None => {
            println!("slsgpu — serverless-vs-GPU training testbed (see README)");
            println!(
                "subcommands: exp <table1|table2|fig2|fig3|fig3-real|spirt-indb|table3>, \
                 fault-tolerance, robustness-tournament, scale-sweep, shard-sweep, trace, \
                 report, audit, train, artifacts"
            );
            Ok(())
        }
    }
}

/// The invariant audit: scan the repo's own sources against the rule
/// catalogue in `analysis::rules` (DESIGN.md §7) and print the
/// deterministic report. Exits 1 when any finding is not covered by an
/// `audit:allow` — CI runs this as a blocking gate and compares the output
/// byte-for-byte against `python/tools/audit.py`.
fn run_audit(args: &Args) -> Result<()> {
    let root = std::path::PathBuf::from(args.get_or("root", "."));
    let audit = slsgpu::analysis::audit_repo(&root)?;
    let report = audit.report();
    match args.get_or("format", "text") {
        "text" => print!("{}", report.to_text()),
        "json" => println!("{}", report.to_json()),
        other => bail!("unknown format {other:?} (text|json)"),
    }
    if !audit.clean() {
        eprintln!("audit: {} unsuppressed finding(s)", audit.open_count());
        std::process::exit(1);
    }
    Ok(())
}

/// Regenerate the `docs/` tree: run the full virtual-mode experiment suite
/// and render every report as a Markdown page + JSON data file, plus the
/// `REPORT.md` summary. Deterministic: rerunning produces identical bytes.
fn run_report(args: &Args) -> Result<()> {
    let mut cfg = slsgpu::report::suite::SuiteConfig::default();
    if let Some(skip) = args.get("skip") {
        cfg.skip = skip.split(',').map(|s| s.trim().to_string()).collect();
    }
    cfg.table2_workers = args.get_usize("workers", 4)?;
    if let Some(w) = args.get("sweep-workers") {
        cfg.sweep.worker_counts = parse_list(w)?;
    }
    if let Some(m) = args.get("sweep-modes") {
        cfg.sweep.modes = m.split(',').map(SyncMode::parse).collect::<Result<Vec<_>>>()?;
    }
    cfg.sweep.batches_per_epoch = args.get_usize("sweep-batches", 24)?;
    cfg.sweep.threads = args.get_usize("threads", 0)?;
    cfg.fault.epochs = args.get_usize("fault-epochs", 3)?;
    cfg.fault.seed = args.get_usize("seed", 42)? as u64;
    cfg.tournament.threads = cfg.sweep.threads;
    cfg.tournament.seed = cfg.fault.seed;

    let out = std::path::PathBuf::from(args.get_or("out", "docs"));
    let entries = slsgpu::report::suite::run(&cfg)?;
    let written = slsgpu::report::suite::write_docs(&entries, &out)?;
    println!("wrote {} files to {}", written.len(), out.display());
    Ok(())
}

/// The scalability table: 5 architectures × worker counts × sync modes,
/// sweep points simulated in parallel on std threads.
fn run_scale_sweep(args: &Args) -> Result<()> {
    let modes = args
        .get_or("modes", "bsp,async:2")
        .split(',')
        .map(SyncMode::parse)
        .collect::<Result<Vec<_>>>()?;
    let cfg = exp::scale_sweep::SweepConfig {
        arch: args.get_or("arch", "mobilenet").to_string(),
        worker_counts: parse_list(args.get_or("workers", "4,16,64,256"))?,
        modes,
        batches_per_epoch: args.get_usize("batches", 24)?,
        epochs: args.get_usize("epochs", 1)?,
        threads: args.get_usize("threads", 0)?,
        trace: args.has_flag("trace"),
        store: slsgpu::cloud::StoreTierConfig::sharded(
            args.get_usize("shards", 1)?,
            args.get_usize("replication", 1)?,
        ),
    };
    let points = exp::scale_sweep::run(&cfg)?;
    print!("{}", exp::scale_sweep::render(&points, &cfg));
    if let Some(path) = args.get("csv") {
        std::fs::write(path, exp::scale_sweep::render_csv(&points))?;
        println!("wrote sweep points to {path}");
    }
    Ok(())
}

/// The store-tier frontier: MLLess (the shared-store architecture) across
/// shards × replication × workers, with the per-W Pareto frontier of
/// epoch time vs paper cost + store hosting.
fn run_shard_sweep(args: &Args) -> Result<()> {
    let cfg = exp::shard_sweep::ShardSweepConfig {
        arch: args.get_or("arch", "mobilenet").to_string(),
        shard_counts: parse_list(args.get_or("shards", "1,2,4,8"))?,
        replications: parse_list(args.get_or("replication", "1,2"))?,
        worker_counts: parse_list(args.get_or("workers", "4,16,64"))?,
        batches_per_epoch: args.get_usize("batches", 24)?,
        epochs: args.get_usize("epochs", 1)?,
        threads: args.get_usize("threads", 0)?,
    };
    let points = exp::shard_sweep::run(&cfg)?;
    print!("{}", exp::shard_sweep::render(&points, &cfg));
    if let Some(path) = args.get("csv") {
        std::fs::write(path, exp::shard_sweep::render_csv(&points))?;
        println!("wrote sweep points to {path}");
    }
    Ok(())
}

/// Protocol tracing: run the selected architecture(s) with the trace
/// collector on and emit the critical-path/percentile summary, a Chrome
/// trace-event file (chrome://tracing, Perfetto) or per-op-kind CSV.
fn run_trace(args: &Args) -> Result<()> {
    let cfg = exp::trace::TraceRunConfig {
        arch: args.get_or("model", "mobilenet").to_string(),
        workers: args.get_usize("workers", 4)?,
        batches_per_epoch: args.get_usize("batches", 24)?,
        epochs: args.get_usize("epochs", 1)?,
        mode: SyncMode::parse(args.get_or("mode", "bsp"))?,
    };
    let arch = args.get_or("arch", "spirt");
    let traces = if arch.eq_ignore_ascii_case("all") {
        exp::trace::run(&cfg)?
    } else {
        exp::trace::run_for(&cfg, &[framework_by_name(arch)?])?
    };
    let rendered = match args.get_or("format", "summary") {
        "summary" => exp::trace::render(&traces, &cfg),
        "chrome" => exp::trace::chrome_export(&traces),
        "csv" => exp::trace::render_csv(&traces),
        other => bail!("unknown format {other:?} (summary|chrome|csv)"),
    };
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, rendered)?;
            println!("wrote trace to {path}");
        }
        None => print!("{rendered}"),
    }
    Ok(())
}

/// The resilience table: five architectures under deterministic injected
/// faults, plus the poisoning/robust-aggregation accuracy contrast.
fn run_fault_tolerance(args: &Args) -> Result<()> {
    let cfg = exp::table4_faults::FaultConfig {
        arch: args.get_or("arch", "mobilenet").to_string(),
        workers: args.get_usize("workers", 4)?,
        epochs: args.get_usize("epochs", 3)?,
        seed: args.get_usize("seed", 42)? as u64,
    };
    let t4 = exp::table4_faults::run(&cfg)?;
    print!("{}", exp::table4_faults::render(&t4, &cfg));
    Ok(())
}

/// The robustness tournament: aggregation rule × adversarial regime ×
/// architecture, with cost/accuracy Pareto verdicts per family. `--arch`
/// filters the *architecture* here (matching the other per-framework
/// subcommands' vocabulary); the calibrated model profile is `--model`.
fn run_tournament(args: &Args) -> Result<()> {
    let mut cfg = exp::tournament::TournamentConfig {
        model: args.get_or("model", "mobilenet").to_string(),
        workers: args.get_usize("workers", 8)?,
        epochs: args.get_usize("epochs", 2)?,
        seed: args.get_usize("seed", 42)? as u64,
        threads: args.get_usize("threads", 0)?,
        ..exp::tournament::TournamentConfig::default()
    };
    let arch = args.get_or("arch", "all");
    if !arch.eq_ignore_ascii_case("all") {
        cfg.frameworks = vec![framework_by_name(arch)?];
    }
    let attack = args.get_or("attack", "all");
    if !attack.eq_ignore_ascii_case("all") {
        cfg.attacks = vec![exp::tournament::Attack::parse(attack)?];
    }
    let t = exp::tournament::run(&cfg)?;
    print!("{}", exp::tournament::render(&t, &cfg));
    Ok(())
}

fn run_exp(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| {
            anyhow::anyhow!(
                "usage: slsgpu exp <table1|table2|fig2|fig3|fig3-real|spirt-indb|table3>"
            )
        })?;
    match which {
        "table1" => {
            print!("{}", exp::table1::render());
        }
        "table2" => {
            let workers = args.get_usize("workers", 4)?;
            let rows = exp::table2::run(workers)?;
            print!("{}", exp::table2::report(&rows, workers).to_text());
        }
        "fig2" => {
            let counts = parse_list(args.get_or("workers", "4,8,12,16"))?;
            let points = exp::fig2::run(&counts)?;
            print!("{}", exp::fig2::render(&points));
        }
        "fig3" => {
            let rates = parse_flist(args.get_or("rates", "1.0,0.5,0.2,0.1,0.05"))?;
            let points = exp::fig3::run_sim(&rates)?;
            // The paper-headline footer is a report note now.
            print!("{}", exp::fig3::render_sim(&points));
        }
        "fig3-real" => {
            let engine = engine_from(args)?;
            let model = args.get_or("model", "mobilenet_s");
            let epochs = args.get_usize("epochs", 3)?;
            let c = exp::fig3::run_real(engine, model, epochs)?;
            print!("{}", exp::fig3::report_real(&c, model, epochs).to_text());
        }
        "spirt-indb" => {
            let minibatches = args.get_usize("minibatches", 24)?;
            let outcome = if args.has_flag("real") {
                let engine = engine_from(args)?;
                let slab = args.get_or("slab", "resnet18_full").to_string();
                exp::spirt_indb::run(Some((engine, &slab)), minibatches)?
            } else {
                exp::spirt_indb::run(None, minibatches)?
            };
            print!("{}", exp::spirt_indb::render(&outcome));
        }
        "table3" => {
            let engine = engine_from(args)?;
            let cfg = exp::table3::Table3Config {
                model: args.get_or("model", "mobilenet_s").to_string(),
                workers: args.get_usize("workers", 4)?,
                train_samples: args.get_usize("samples", 6144)?,
                max_epochs: args.get_usize("epochs", 20)?,
                target_acc: args.get_f64("target", 0.80)?,
                seed: args.get_usize("seed", 42)? as u64,
            };
            let reports = exp::table3::run(engine, &cfg)?;
            print!("{}", exp::table3::render(&reports, &cfg));
            if let Some(path) = args.get("csv") {
                std::fs::write(path, exp::table3::render_csv(&reports))?;
                println!("wrote accuracy-vs-time series to {path}");
            }
        }
        other => bail!("unknown experiment {other:?}"),
    }
    Ok(())
}

fn run_train(args: &Args) -> Result<()> {
    let fw = framework_by_name(args.get_or("framework", "spirt"))?;
    let engine = engine_from(args)?;
    let model = args.get_or("model", "mobilenet_s");
    let workers = args.get_usize("workers", 4)?;
    let samples = args.get_usize("samples", 4096)?;
    let epochs = args.get_usize("epochs", 5)?;
    let seed = args.get_usize("seed", 42)? as u64;

    let mut env = ClusterEnv::new(EnvConfig::real(fw, engine, model, workers, samples, seed)?)?;
    let mut strategy = strategy_for(fw);
    let cfg = SessionConfig {
        max_epochs: epochs,
        target_acc: args.get_f64("target", 0.80)?,
        patience: 8,
        evaluate: true,
    };
    println!(
        "training {model} with {} ({} workers, {} samples, {} epochs max)",
        fw.name(),
        workers,
        samples,
        epochs
    );
    let report = run_session(&mut env, strategy.as_mut(), &cfg)?;
    for e in &report.reports {
        println!(
            "epoch {:>2}: vtime {:>8.1}s  loss {}  acc {}  cost ${:.4}",
            e.epoch,
            e.vtime_secs,
            e.mean_loss.map(|l| format!("{l:.4}")).unwrap_or_else(|| "-".into()),
            e.test_acc.map(|a| format!("{:.1}%", a * 100.0)).unwrap_or_else(|| "-".into()),
            e.cost_usd
        );
    }
    println!(
        "done: final acc {}  total cost ${:.4}  virtual time {:.1} min",
        report.final_acc.map(|a| format!("{:.1}%", a * 100.0)).unwrap_or_else(|| "-".into()),
        report.total_cost_usd,
        report.total_vtime_secs / 60.0
    );
    Ok(())
}
