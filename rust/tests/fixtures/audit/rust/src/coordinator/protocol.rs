//! Fixture: the sanctioned emit point — construction here is fine.

pub fn emit() {
    let _ = EventKind::Poll;
}
