//! Chrome trace-event JSON export (loadable in Perfetto / `chrome://tracing`).
//!
//! One process per traced run (so `--arch all` shows the five architectures
//! side by side), one thread track per worker plus a `supervisor` track for
//! MLLess. Spans render as complete events (`ph:"X"`, microsecond `ts`/`dur`)
//! carrying bytes/cost/epoch/round in `args`; zero-duration fault markers
//! render as thread-scoped instants (`ph:"i"`). Serialization goes through
//! `util::json` (BTreeMap objects, fixed number formatting), so equal traces
//! produce byte-identical files.

use std::collections::BTreeMap;

use crate::faults::SUPERVISOR;
use crate::util::json::Json;

use super::event::TraceEvent;

/// One traced run to export: a label (architecture name), the worker count
/// (fixes the supervisor's thread id) and the event snapshot.
#[derive(Debug, Clone)]
pub struct ChromeRun {
    pub label: String,
    pub workers: usize,
    pub events: Vec<TraceEvent>,
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn meta(pid: usize, tid: usize, what: &str, name: &str) -> Json {
    obj(vec![
        ("ph", Json::Str("M".into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("name", Json::Str(what.into())),
        ("args", obj(vec![("name", Json::Str(name.into()))])),
    ])
}

fn tid_of(worker: usize, workers: usize) -> usize {
    if worker == SUPERVISOR {
        workers
    } else {
        worker
    }
}

fn event_json(pid: usize, workers: usize, e: &TraceEvent) -> Json {
    let mut pairs = vec![
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid_of(e.worker, workers) as f64)),
        ("ts", Json::Num(e.t0.secs() * 1e6)),
        ("name", Json::Str(e.kind.name().into())),
        ("cat", Json::Str(e.kind.category().into())),
        (
            "args",
            obj(vec![
                ("bytes", Json::Num(e.bytes as f64)),
                ("cost_usd", Json::Num(e.cost)),
                ("epoch", Json::Num(e.epoch as f64)),
                ("round", Json::Num(e.round as f64)),
            ]),
        ),
    ];
    if e.kind.is_instant() {
        pairs.push(("ph", Json::Str("i".into())));
        pairs.push(("s", Json::Str("t".into())));
    } else {
        pairs.push(("ph", Json::Str("X".into())));
        pairs.push(("dur", Json::Num((e.t1 - e.t0) * 1e6)));
    }
    obj(pairs)
}

/// Build the trace document for one or more runs.
pub fn json(runs: &[ChromeRun]) -> Json {
    let mut events = Vec::new();
    for (pid, run) in runs.iter().enumerate() {
        events.push(meta(pid, 0, "process_name", &run.label));
        let mut tids: BTreeMap<usize, String> = BTreeMap::new();
        for e in &run.events {
            let tid = tid_of(e.worker, run.workers);
            let name = if e.worker == SUPERVISOR {
                "supervisor".to_string()
            } else {
                format!("worker {}", e.worker)
            };
            tids.entry(tid).or_insert(name);
        }
        for (tid, name) in &tids {
            events.push(meta(pid, *tid, "thread_name", name));
        }
        for e in &run.events {
            events.push(event_json(pid, run.workers, e));
        }
    }
    obj(vec![
        ("displayTimeUnit", Json::Str("ms".into())),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// Serialize to the final newline-terminated file contents.
pub fn render(runs: &[ChromeRun]) -> String {
    format!("{}\n", json(runs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::VTime;
    use crate::trace::{EventKind, TraceCollector, TraceConfig};

    fn sample_run() -> ChromeRun {
        let mut c = TraceCollector::new(&TraceConfig::on());
        c.begin_epoch(1);
        c.span(0, VTime::from_secs(0.5), VTime::from_secs(1.25), EventKind::Put, 64, 0.001, None);
        c.instant(1, VTime::from_secs(2.0), EventKind::Poison);
        c.span(SUPERVISOR, VTime::from_secs(0.0), VTime::from_secs(0.25), EventKind::Poll, 0, 0.0, None);
        ChromeRun { label: "mlless".into(), workers: 2, events: c.snapshot() }
    }

    #[test]
    fn emits_valid_deterministic_json() {
        let runs = vec![sample_run()];
        let a = render(&runs);
        let b = render(&runs);
        assert_eq!(a, b);
        let doc = Json::parse(a.trim_end()).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 process_name + 3 thread_name + 3 events.
        assert_eq!(events.len(), 7);
        let span = events
            .iter()
            .find(|e| e.get("ph").map(|p| p.as_str().unwrap()).unwrap_or("") == "X")
            .unwrap();
        assert_eq!(span.get("ts").unwrap().as_f64().unwrap(), 0.5e6);
        assert_eq!(span.get("dur").unwrap().as_f64().unwrap(), 0.75e6);
        assert_eq!(span.get("args").unwrap().get("bytes").unwrap().as_f64().unwrap(), 64.0);
        let instant = events
            .iter()
            .find(|e| e.get("ph").map(|p| p.as_str().unwrap()).unwrap_or("") == "i")
            .unwrap();
        assert_eq!(instant.get("s").unwrap().as_str().unwrap(), "t");
        assert_eq!(instant.get("name").unwrap().as_str().unwrap(), "poison");
    }

    #[test]
    fn supervisor_maps_to_extra_track() {
        let run = sample_run();
        let doc = json(&[run]);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let sup = events
            .iter()
            .find(|e| {
                e.get("name").map(|n| n.as_str().unwrap()).unwrap_or("") == "thread_name"
                    && e.get("args").unwrap().get("name").unwrap().as_str().unwrap() == "supervisor"
            })
            .unwrap();
        assert_eq!(sup.get("tid").unwrap().as_f64().unwrap(), 2.0);
        let poll = events
            .iter()
            .find(|e| e.get("name").map(|n| n.as_str().unwrap()).unwrap_or("") == "poll")
            .unwrap();
        assert_eq!(poll.get("tid").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn multi_run_export_separates_pids() {
        let mut r0 = sample_run();
        r0.label = "a".into();
        let mut r1 = sample_run();
        r1.label = "b".into();
        let doc = json(&[r0, r1]);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let pids: std::collections::BTreeSet<i64> = events
            .iter()
            .map(|e| e.get("pid").unwrap().as_f64().unwrap() as i64)
            .collect();
        assert_eq!(pids.into_iter().collect::<Vec<_>>(), vec![0, 1]);
    }
}
