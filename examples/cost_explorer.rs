//! Cost explorer: where is the serverless-vs-GPU cost crossover?
//!
//! The paper's headline finding is a *crossover*: serverless wins on cost
//! for lightweight models (MobileNet), the GPU baseline wins for heavier
//! ones (ResNet-18). This example sweeps model size between and beyond the
//! paper's two anchors and reports the per-epoch cost of the cheapest
//! serverless variant vs the GPU fleet, locating the crossover.
//!
//! ```sh
//! cargo run --release --example cost_explorer
//! ```

use slsgpu::cloud::calibration::{scaled_profile, ModelProfile, FrameworkKind, MOBILENET, RESNET18};
use slsgpu::coordinator::{strategy_for, ClusterEnv, EnvConfig, GradMode};
use slsgpu::util::table::{Align, Table};

/// Interpolate a profile at an arbitrary parameter count between the
/// MobileNet and ResNet-18 calibration anchors (extrapolating beyond).
fn profile_at(params: u64) -> ModelProfile {
    let (a, b) = (MOBILENET, RESNET18);
    let t = (params as f64 - a.params as f64) / (b.params as f64 - a.params as f64);
    let lerp = |x: f64, y: f64| x + t * (y - x);
    ModelProfile {
        name: "interp",
        params,
        lambda_secs_per_sample: lerp(a.lambda_secs_per_sample, b.lambda_secs_per_sample),
        gpu_secs_per_sample: lerp(a.gpu_secs_per_sample, b.gpu_secs_per_sample),
        activation_mb: lerp(a.activation_mb, b.activation_mb),
    }
}

fn epoch_cost(fw: FrameworkKind, profile: ModelProfile) -> anyhow::Result<f64> {
    let cfg = EnvConfig {
        framework: fw,
        workers: 4,
        batches_per_epoch: 24,
        batch_size: 512,
        lr: 0.05,
        profile,
        grad_mode: GradMode::Virtual,
        seed: 7,
        fault_plan: slsgpu::faults::FaultPlan::none(),
        agg: slsgpu::tensor::AggregationRule::Mean,
        sync: slsgpu::coordinator::SyncMode::Bsp,
        trace: slsgpu::trace::TraceConfig::disabled(),
        store: slsgpu::cloud::StoreTierConfig::single(),
    };
    let mut env = ClusterEnv::new(cfg)?;
    strategy_for(fw).run_epoch(&mut env)?;
    Ok(env.ledger.total_paper())
}

fn main() -> anyhow::Result<()> {
    let sizes: Vec<u64> = vec![
        1_000_000, 2_000_000, 3_000_000, 4_200_000, 6_000_000, 8_000_000, 10_000_000,
        11_700_000, 16_000_000, 25_600_000,
    ];
    let mut t = Table::new(&["Params", "Serverless best ($)", "Best variant", "GPU ($)", "Winner"])
        .align(&[Align::Right, Align::Right, Align::Left, Align::Right, Align::Left])
        .title("Per-epoch cost vs model size (B=512, 4 workers x 24 batches)");

    let mut crossover: Option<u64> = None;
    let mut prev_serverless_won = true;
    for params in sizes {
        let profile = if params > RESNET18.params {
            scaled_profile(RESNET18, params)
        } else {
            profile_at(params)
        };
        let mut best = f64::INFINITY;
        let mut best_name = "";
        for fw in [FrameworkKind::AllReduce, FrameworkKind::ScatterReduce, FrameworkKind::Spirt] {
            let c = epoch_cost(fw, profile)?;
            if c < best {
                best = c;
                best_name = fw.name();
            }
        }
        let gpu = epoch_cost(FrameworkKind::GpuBaseline, profile)?;
        let serverless_wins = best < gpu;
        if prev_serverless_won && !serverless_wins && crossover.is_none() {
            crossover = Some(params);
        }
        prev_serverless_won = serverless_wins;
        t.row(vec![
            format!("{:.1}M", params as f64 / 1e6),
            format!("{best:.4}"),
            best_name.to_string(),
            format!("{gpu:.4}"),
            if serverless_wins { "serverless".into() } else { "GPU".to_string() },
        ]);
    }
    print!("{}", t.render());
    match crossover {
        Some(p) => println!(
            "crossover: GPU becomes cheaper at ~{:.1}M params \
             (paper: between 4.2M MobileNet and 11.7M ResNet-18)",
            p as f64 / 1e6
        ),
        None => println!("no crossover found in the swept range"),
    }
    Ok(())
}
