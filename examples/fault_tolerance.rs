//! Fault tolerance in two acts, no artifacts required (virtual gradients):
//!
//! 1. The same mid-training worker crash hits SPIRT and AllReduce — SPIRT's
//!    parallel minibatch fan-out absorbs the retry while AllReduce's master
//!    barrier stalls the whole round behind it.
//! 2. One worker poisons its gradients on a real (pure-Rust) learning task —
//!    the naive mean collapses, robust aggregation recovers.
//!
//! ```sh
//! cargo run --release --example fault_tolerance
//! ```

use slsgpu::cloud::FrameworkKind;
use slsgpu::coordinator::{strategy_for, ClusterEnv, EnvConfig};
use slsgpu::faults::{FaultPlan, poison_demo, PoisonMode};
use slsgpu::train::{run_session, SessionConfig};

fn epoch_secs(fw: FrameworkKind, plan: FaultPlan) -> anyhow::Result<(f64, f64)> {
    let cfg = EnvConfig::virtual_paper(fw, "mobilenet", 4)?.with_faults(plan);
    let mut env = ClusterEnv::new(cfg)?;
    let mut strategy = strategy_for(fw);
    let session = SessionConfig { max_epochs: 3, target_acc: 2.0, patience: 4, evaluate: false };
    let report = run_session(&mut env, strategy.as_mut(), &session)?;
    Ok((report.total_vtime_secs, env.recovery.downtime_secs))
}

fn main() -> anyhow::Result<()> {
    println!("== Act 1: the same crash, two topologies ==\n");
    for fw in [FrameworkKind::Spirt, FrameworkKind::AllReduce] {
        let (clean, _) = epoch_secs(fw, FaultPlan::none())?;
        // Worker 1 crashes mid-training: epoch 2, round 12.
        let (faulty, down) = epoch_secs(fw, FaultPlan::none().crash(1, 2, 12))?;
        println!(
            "{:<18} fault-free {:7.1}s   crashed {:7.1}s   degradation {:+5.1}% (downtime {:.1}s)",
            fw.name(),
            clean,
            faulty,
            (faulty - clean) / clean * 100.0,
            down
        );
    }

    println!("\n== Act 2: gradient poisoning vs robust aggregation ==\n");
    let report = poison_demo::run(42, poison_demo::DEMO_WORKERS, PoisonMode::Scale(-8.0))?;
    println!(
        "fault-free baseline (naive mean, no adversary): {:.1}% accuracy",
        report.fault_free_acc * 100.0
    );
    for row in &report.rows {
        println!(
            "  poisoned, {:<13} {:.1}% ({:+.1} pts)",
            row.rule.name(),
            row.final_acc * 100.0,
            (row.final_acc - report.fault_free_acc) * 100.0
        );
    }
    println!(
        "\nOne of {} workers submitted updates scaled by -8; clipping and the \
         coordinate median bound its influence, the mean does not.",
        report.workers
    );
    Ok(())
}
