"""Elementwise aggregation kernels vs oracles (hypothesis sweeps sizes)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import accumulate, fused_avg_update, sgd_update, l2_norm_sq
from compile.kernels import ref
from compile.kernels.significance import is_significant

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")

sizes = st.one_of(
    st.integers(1, 300),  # tiny slabs (below one block)
    st.integers(65530, 65545),  # straddling the 64K block edge
    st.integers(130_000, 140_000),  # multi-block
)
scalars = st.floats(-2.0, 2.0, allow_nan=False, width=32)


def _vec(rng, n):
    return jnp.asarray(rng.normal(size=n), jnp.float32)


@given(n=sizes, w=scalars, seed=st.integers(0, 2**31 - 1))
def test_accumulate_matches_ref(n, w, seed):
    rng = np.random.default_rng(seed)
    a, g = _vec(rng, n), _vec(rng, n)
    np.testing.assert_allclose(
        np.asarray(accumulate(a, g, w)), np.asarray(ref.accumulate(a, g, w)),
        atol=1e-5, rtol=1e-5,
    )


@given(n=sizes, inv_k=st.floats(0.0078125, 1.0, width=32), lr=st.floats(0.0, 1.0, width=32),
       seed=st.integers(0, 2**31 - 1))
def test_fused_avg_update_matches_ref(n, inv_k, lr, seed):
    rng = np.random.default_rng(seed)
    t, gs = _vec(rng, n), _vec(rng, n)
    np.testing.assert_allclose(
        np.asarray(fused_avg_update(t, gs, inv_k, lr)),
        np.asarray(ref.fused_avg_update(t, gs, inv_k, lr)),
        atol=1e-5, rtol=1e-5,
    )


@given(n=sizes, lr=st.floats(0.0, 1.0, width=32), seed=st.integers(0, 2**31 - 1))
def test_sgd_matches_ref(n, lr, seed):
    rng = np.random.default_rng(seed)
    t, g = _vec(rng, n), _vec(rng, n)
    np.testing.assert_allclose(
        np.asarray(sgd_update(t, g, lr)), np.asarray(ref.sgd_update(t, g, lr)),
        atol=1e-5, rtol=1e-5,
    )


@given(n=sizes, seed=st.integers(0, 2**31 - 1))
def test_l2_norm_sq_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    g = _vec(rng, n)
    np.testing.assert_allclose(
        float(l2_norm_sq(g)), float(ref.l2_norm_sq(g)), rtol=2e-4
    )


def test_fused_equivalence_with_two_step():
    """fused_avg_update == accumulate-then-sgd (the naive two-pass path)."""
    rng = np.random.default_rng(0)
    t, gs = _vec(rng, 70_000), _vec(rng, 70_000)
    k, lr = 4.0, 0.1
    fused = fused_avg_update(t, gs, 1.0 / k, lr)
    mean = accumulate(jnp.zeros_like(gs), gs, 1.0 / k)
    twostep = sgd_update(t, mean, lr)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(twostep), atol=1e-6)


def test_accumulate_is_linear():
    rng = np.random.default_rng(1)
    a, g1, g2 = _vec(rng, 5000), _vec(rng, 5000), _vec(rng, 5000)
    left = accumulate(accumulate(a, g1, 0.5), g2, 0.5)
    right = a + 0.5 * (g1 + g2)
    np.testing.assert_allclose(np.asarray(left), np.asarray(right), atol=1e-5)


@pytest.mark.parametrize("thresh,expect", [(0.0, 1.0), (1e9, 0.0)])
def test_significance_extremes(thresh, expect):
    rng = np.random.default_rng(2)
    g, t = _vec(rng, 1000), _vec(rng, 1000)
    assert float(is_significant(g, t, thresh)) == expect


def test_significance_zero_theta_always_significant():
    rng = np.random.default_rng(3)
    g = _vec(rng, 100)
    t = jnp.zeros((100,), jnp.float32)
    assert float(is_significant(g, t, 0.5)) == 1.0
