//! Training session driver: epochs × strategy × convergence tracking.
//!
//! Wires a [`Strategy`] to a [`ClusterEnv`] and runs epochs until the
//! [`EarlyStopper`] fires or the epoch budget is exhausted, recording an
//! [`EpochReport`] per epoch — the raw material for Table 3 / Fig. 4 and
//! the end-to-end examples.

use crate::coordinator::{ClusterEnv, EarlyStopper, EpochStats, Strategy};
use crate::Result;

/// One epoch's observable state.
#[derive(Debug, Clone)]
pub struct EpochReport {
    pub epoch: usize,
    /// Virtual time at epoch end (cumulative, seconds).
    pub vtime_secs: f64,
    pub epoch_secs: f64,
    pub mean_loss: Option<f64>,
    pub test_acc: Option<f64>,
    /// Cumulative cost under the paper's model (USD).
    pub cost_usd: f64,
    pub mean_fn_secs: f64,
}

/// Outcome of a full session.
#[derive(Debug, Clone)]
pub struct SessionReport {
    pub framework: &'static str,
    pub reports: Vec<EpochReport>,
    /// Virtual minutes at which the target accuracy was first reached.
    pub time_to_target_min: Option<f64>,
    pub final_acc: Option<f64>,
    pub total_cost_usd: f64,
    pub total_vtime_secs: f64,
}

/// Session parameters.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    pub max_epochs: usize,
    pub target_acc: f64,
    pub patience: usize,
    /// Evaluate accuracy every epoch (real mode); disable for cost-only runs.
    pub evaluate: bool,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig { max_epochs: 30, target_acc: 0.80, patience: 8, evaluate: true }
    }
}

/// Run a full training session.
pub fn run_session(
    env: &mut ClusterEnv,
    strategy: &mut dyn Strategy,
    cfg: &SessionConfig,
) -> Result<SessionReport> {
    let mut stopper = EarlyStopper::new(cfg.target_acc, cfg.patience);
    let mut reports = Vec::new();
    let mut time_to_target = None;

    for epoch in 1..=cfg.max_epochs {
        let stats: EpochStats = strategy.run_epoch(env)?;
        let acc = if cfg.evaluate { env.eval_accuracy()? } else { None };
        let vtime = env.max_clock().secs();
        reports.push(EpochReport {
            epoch,
            vtime_secs: vtime,
            epoch_secs: stats.epoch_secs,
            mean_loss: stats.mean_loss,
            test_acc: acc,
            cost_usd: env.ledger.total_paper(),
            mean_fn_secs: stats.mean_fn_secs,
        });

        if let Some(acc) = acc {
            if acc >= cfg.target_acc && time_to_target.is_none() {
                time_to_target = Some(vtime / 60.0);
            }
            if stopper.observe(epoch, acc) {
                break;
            }
        }
    }

    let final_acc = reports.iter().rev().find_map(|r| r.test_acc);
    Ok(SessionReport {
        framework: env.framework.name(),
        time_to_target_min: time_to_target,
        final_acc,
        total_cost_usd: env.ledger.total_paper(),
        total_vtime_secs: env.max_clock().secs(),
        reports,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::FrameworkKind;
    use crate::coordinator::{strategy_for, EnvConfig};

    #[test]
    fn virtual_session_runs_epochs_without_eval() {
        let mut env = ClusterEnv::new(
            EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 4).unwrap(),
        )
        .unwrap();
        let mut strat = strategy_for(FrameworkKind::AllReduce);
        let cfg = SessionConfig { max_epochs: 2, evaluate: false, ..Default::default() };
        let report = run_session(&mut env, strat.as_mut(), &cfg).unwrap();
        assert_eq!(report.reports.len(), 2);
        assert!(report.total_cost_usd > 0.0);
        assert!(report.time_to_target_min.is_none());
        assert!(report.reports[1].vtime_secs > report.reports[0].vtime_secs);
        assert_eq!(report.framework, "AllReduce");
    }

    #[test]
    fn cost_accumulates_monotonically() {
        let mut env = ClusterEnv::new(
            EnvConfig::virtual_paper(FrameworkKind::ScatterReduce, "resnet18", 4).unwrap(),
        )
        .unwrap();
        let mut strat = strategy_for(FrameworkKind::ScatterReduce);
        let cfg = SessionConfig { max_epochs: 3, evaluate: false, ..Default::default() };
        let report = run_session(&mut env, strat.as_mut(), &cfg).unwrap();
        let costs: Vec<f64> = report.reports.iter().map(|r| r.cost_usd).collect();
        assert!(costs.windows(2).all(|w| w[1] > w[0]), "{costs:?}");
    }
}
