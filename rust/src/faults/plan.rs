//! Fault plans and the deterministic runtime schedule.
//!
//! A [`FaultPlan`] is *data*: a list of [`FaultEvent`]s that say which
//! worker misbehaves, how, and when — either at protocol coordinates
//! (epoch/round, the natural unit every strategy shares) or at a planned
//! virtual time on the worker's clock. A [`FaultSchedule`] is the plan
//! armed for one run: it tracks the per-worker round counters and which
//! one-shot events already fired. All queries are pure scans over the event
//! list, so a given (plan, seed, config) produces bit-identical virtual
//! timelines on every run — the property the determinism integration test
//! locks in.

use anyhow::{bail, Result};

use crate::sim::VTime;
use crate::tensor::Slab;

/// Sentinel worker id for events that target the MLLess supervisor rather
/// than a training worker.
pub const SUPERVISOR: usize = usize::MAX;

/// How a poisoned worker corrupts its gradient before submitting it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PoisonMode {
    /// Multiply the update by a factor (|f| > 1 amplifies, f < 0 reverses).
    Scale(f32),
    /// Flip the sign of every coordinate (Scale(-1) with intent spelled out).
    SignFlip,
}

impl PoisonMode {
    /// Corrupt `grad` in place. Virtual slabs pass through numerically
    /// (size-only experiments track the poisoning in RecoveryStats instead).
    pub fn apply(&self, grad: &mut Slab) {
        match self {
            PoisonMode::Scale(f) => grad.scale(*f),
            PoisonMode::SignFlip => grad.scale(-1.0),
        }
    }
}

/// What goes wrong.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The worker's in-flight invocation dies mid-compute. The platform
    /// retries it: cold start + state re-load + recompute, billed again.
    CrashCompute,
    /// The worker dies entering the synchronization stage and restarts
    /// after a cold start + snapshot restore. Peer behaviour is the
    /// architectural difference: SPIRT reroutes around the dead peer,
    /// barriered frameworks stall until it is back.
    CrashSync,
    /// The MLLess supervisor process dies; the round stalls until it
    /// restarts and re-polls the worker reports. No-op elsewhere.
    CrashSupervisor,
    /// Compute runs `factor`× slower while active (degraded vCPU,
    /// co-tenancy, thermal throttling).
    Straggler { factor: f64 },
    /// The worker's produced update is lost before synchronization while
    /// active (message/object drop).
    DropUpdate,
    /// A shard of the shared store tier crashes at the top of an epoch,
    /// losing its in-memory contents and serving nothing until it restarts.
    /// The event's `worker` field holds the *shard id*, not a worker id.
    /// Reads fail over to replicas (replication permitting); no-op for
    /// strategies that never touch the shared store.
    ShardCrash,
    /// The worker submits corrupted gradients while active.
    Poison(PoisonMode),
}

/// When a fault triggers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Trigger {
    /// Protocol coordinates: 1-based epoch, 0-based round/minibatch within
    /// it. Sync-phase crashes ignore the round (they fire at that epoch's
    /// synchronization stage).
    Round { epoch: usize, round: usize },
    /// First hook consultation at or after this virtual time on the
    /// affected worker's clock.
    VTime(f64),
}

/// One planned fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Target worker (or [`SUPERVISOR`]).
    pub worker: usize,
    pub kind: FaultKind,
    pub at: Trigger,
    /// For persistent kinds (straggler/drop/poison) triggered by round:
    /// how many consecutive rounds of that epoch stay affected; `None`
    /// means from the trigger to the end of the run (all later epochs).
    /// Ignored for crashes and for `VTime` triggers (always to end of run).
    pub rounds: Option<usize>,
}

/// A declarative set of fault events (builder-style construction).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan: a fault-free run.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn with(mut self, event: FaultEvent) -> FaultPlan {
        self.events.push(event);
        self
    }

    /// Compute-phase crash of `worker` at (epoch, round).
    pub fn crash(self, worker: usize, epoch: usize, round: usize) -> FaultPlan {
        self.with(FaultEvent {
            worker,
            kind: FaultKind::CrashCompute,
            at: Trigger::Round { epoch, round },
            rounds: None,
        })
    }

    /// Compute-phase crash of `worker` at the first invocation at or after
    /// virtual time `secs`.
    pub fn crash_at_vtime(self, worker: usize, secs: f64) -> FaultPlan {
        self.with(FaultEvent {
            worker,
            kind: FaultKind::CrashCompute,
            at: Trigger::VTime(secs),
            rounds: None,
        })
    }

    /// Sync-phase crash of `worker` in `epoch`.
    pub fn sync_crash(self, worker: usize, epoch: usize) -> FaultPlan {
        self.with(FaultEvent {
            worker,
            kind: FaultKind::CrashSync,
            at: Trigger::Round { epoch, round: 0 },
            rounds: None,
        })
    }

    /// MLLess supervisor crash at (epoch, round).
    pub fn supervisor_crash(self, epoch: usize, round: usize) -> FaultPlan {
        self.with(FaultEvent {
            worker: SUPERVISOR,
            kind: FaultKind::CrashSupervisor,
            at: Trigger::Round { epoch, round },
            rounds: None,
        })
    }

    /// Crash store-tier shard `shard` at the top of `epoch`.
    pub fn shard_crash(self, shard: usize, epoch: usize) -> FaultPlan {
        self.with(FaultEvent {
            worker: shard,
            kind: FaultKind::ShardCrash,
            at: Trigger::Round { epoch, round: 0 },
            rounds: None,
        })
    }

    /// `worker` computes `factor`× slower for `rounds` rounds from
    /// (epoch, round); `None` = for the rest of the run.
    pub fn straggler(
        self,
        worker: usize,
        epoch: usize,
        round: usize,
        factor: f64,
        rounds: Option<usize>,
    ) -> FaultPlan {
        self.with(FaultEvent {
            worker,
            kind: FaultKind::Straggler { factor },
            at: Trigger::Round { epoch, round },
            rounds,
        })
    }

    /// `worker`'s updates are dropped for `rounds` rounds from (epoch, round).
    pub fn drop_updates(
        self,
        worker: usize,
        epoch: usize,
        round: usize,
        rounds: Option<usize>,
    ) -> FaultPlan {
        self.with(FaultEvent {
            worker,
            kind: FaultKind::DropUpdate,
            at: Trigger::Round { epoch, round },
            rounds,
        })
    }

    /// `worker` submits poisoned gradients from `epoch` onwards.
    pub fn poison(self, worker: usize, epoch: usize, mode: PoisonMode) -> FaultPlan {
        self.with(FaultEvent {
            worker,
            kind: FaultKind::Poison(mode),
            at: Trigger::Round { epoch, round: 0 },
            rounds: None,
        })
    }
}

/// A [`FaultPlan`] armed for one run.
#[derive(Debug, Clone, Default)]
pub struct FaultSchedule {
    events: Vec<FaultEvent>,
    /// One-shot consumption flags (crashes fire exactly once).
    fired: Vec<bool>,
    /// Per-worker compute-round counter, reset each epoch.
    round_of: Vec<usize>,
    epoch: usize,
}

impl FaultSchedule {
    pub fn new(plan: FaultPlan, workers: usize) -> Result<FaultSchedule> {
        for ev in &plan.events {
            let is_supervisor = matches!(ev.kind, FaultKind::CrashSupervisor);
            if is_supervisor {
                if ev.worker != SUPERVISOR {
                    bail!("supervisor crash events must target SUPERVISOR");
                }
            } else if matches!(ev.kind, FaultKind::ShardCrash) {
                // The worker field is a shard id; the store tier validates
                // it against its shard count when the env is built.
            } else if ev.worker >= workers {
                bail!("fault event targets worker {} of {workers}", ev.worker);
            }
            if let FaultKind::Straggler { factor } = ev.kind {
                if !(factor >= 1.0 && factor.is_finite()) {
                    bail!("straggler factor must be >= 1, got {factor}");
                }
            }
        }
        let fired = vec![false; plan.events.len()];
        Ok(FaultSchedule {
            events: plan.events,
            fired,
            round_of: vec![0; workers],
            epoch: 0,
        })
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// New epoch: reset the per-worker round counters.
    pub fn begin_epoch(&mut self, epoch: usize) {
        self.epoch = epoch;
        for r in &mut self.round_of {
            *r = 0;
        }
    }

    /// A worker starts computing its next gradient; returns the 0-based
    /// round index within the current epoch.
    pub fn note_compute(&mut self, worker: usize) -> usize {
        let r = self.round_of[worker];
        self.round_of[worker] += 1;
        r
    }

    /// A retry re-runs the same round: undo one `note_compute` so the
    /// recomputation does not shift later round coordinates.
    pub fn redo_round(&mut self, worker: usize) {
        self.round_of[worker] = self.round_of[worker].saturating_sub(1);
    }

    /// The round the worker most recently computed (0 before any compute).
    pub fn current_round(&self, worker: usize) -> usize {
        self.round_of[worker].saturating_sub(1)
    }

    /// Is a persistent event active at (this epoch, `round`, `now`)?
    fn active(&self, ev: &FaultEvent, round: usize, now: VTime) -> bool {
        match ev.at {
            Trigger::VTime(t) => now.secs() >= t,
            Trigger::Round { epoch, round: r0 } => {
                if self.epoch < epoch {
                    return false;
                }
                if self.epoch > epoch {
                    // Later epochs: only open-ended windows persist.
                    return ev.rounds.is_none();
                }
                match ev.rounds {
                    None => round >= r0,
                    Some(n) => round >= r0 && round < r0 + n,
                }
            }
        }
    }

    /// Compute slowdown multiplier for `worker` at `round` (product of all
    /// active straggler events; 1.0 when none).
    pub fn compute_factor(&self, worker: usize, round: usize, now: VTime) -> f64 {
        self.events
            .iter()
            .filter(|ev| ev.worker == worker)
            .filter_map(|ev| match ev.kind {
                FaultKind::Straggler { factor } if self.active(ev, round, now) => Some(factor),
                _ => None,
            })
            .product()
    }

    /// Active poison mode for `worker` at `round` (first match wins).
    pub fn poison(&self, worker: usize, round: usize, now: VTime) -> Option<PoisonMode> {
        self.events
            .iter()
            .filter(|ev| ev.worker == worker)
            .find_map(|ev| match ev.kind {
                FaultKind::Poison(mode) if self.active(ev, round, now) => Some(mode),
                _ => None,
            })
    }

    /// Is `worker`'s update at `round` dropped?
    pub fn drop_update(&self, worker: usize, round: usize, now: VTime) -> bool {
        self.events.iter().any(|ev| {
            ev.worker == worker
                && matches!(ev.kind, FaultKind::DropUpdate)
                && self.active(ev, round, now)
        })
    }

    /// One-shot matcher: fire (and consume) the first unfired event of
    /// `kind` for `worker` whose trigger matches.
    fn fire(
        &mut self,
        worker: usize,
        kind: FaultKind,
        round: Option<usize>,
        now: VTime,
    ) -> bool {
        for (i, ev) in self.events.iter().enumerate() {
            if self.fired[i] || ev.worker != worker || ev.kind != kind {
                continue;
            }
            let hit = match ev.at {
                Trigger::VTime(t) => now.secs() >= t,
                Trigger::Round { epoch, round: r0 } => {
                    self.epoch == epoch && round.map(|r| r == r0).unwrap_or(true)
                }
            };
            if hit {
                self.fired[i] = true;
                return true;
            }
        }
        false
    }

    /// Does `worker`'s invocation crash at `round`? Consumes the event.
    pub fn crash_compute(&mut self, worker: usize, round: usize, now: VTime) -> bool {
        self.fire(worker, FaultKind::CrashCompute, Some(round), now)
    }

    /// Does `worker` crash entering this epoch's sync stage? Consumes.
    pub fn crash_sync(&mut self, worker: usize, now: VTime) -> bool {
        self.fire(worker, FaultKind::CrashSync, None, now)
    }

    /// Does the supervisor crash at `round`? Consumes.
    pub fn crash_supervisor(&mut self, round: usize, now: VTime) -> bool {
        self.fire(SUPERVISOR, FaultKind::CrashSupervisor, Some(round), now)
    }

    /// Next store-tier shard crashing at the top of the current epoch, if
    /// any. Consumes one event per call — loop until `None` to drain an
    /// epoch's shard crashes. Returns the shard id (the event's `worker`
    /// field).
    pub fn crash_shard(&mut self, now: VTime) -> Option<usize> {
        for (i, ev) in self.events.iter().enumerate() {
            if self.fired[i] || !matches!(ev.kind, FaultKind::ShardCrash) {
                continue;
            }
            let hit = match ev.at {
                Trigger::VTime(t) => now.secs() >= t,
                Trigger::Round { epoch, .. } => self.epoch == epoch,
            };
            if hit {
                self.fired[i] = true;
                return Some(ev.worker);
            }
        }
        None
    }

    /// Largest shard id any [`FaultKind::ShardCrash`] event targets (for
    /// validation against the store tier's shard count).
    pub fn max_crashed_shard(&self) -> Option<usize> {
        self.events
            .iter()
            .filter(|ev| matches!(ev.kind, FaultKind::ShardCrash))
            .map(|ev| ev.worker)
            .max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(secs: f64) -> VTime {
        VTime::from_secs(secs)
    }

    #[test]
    fn round_counters_track_per_worker_per_epoch() {
        let mut s = FaultSchedule::new(FaultPlan::none(), 2).unwrap();
        s.begin_epoch(1);
        assert_eq!(s.note_compute(0), 0);
        assert_eq!(s.note_compute(0), 1);
        assert_eq!(s.note_compute(1), 0);
        assert_eq!(s.current_round(0), 1);
        s.redo_round(0);
        assert_eq!(s.note_compute(0), 1, "retry re-runs the same round");
        s.begin_epoch(2);
        assert_eq!(s.note_compute(0), 0);
    }

    #[test]
    fn compute_crash_fires_once_at_its_round() {
        let plan = FaultPlan::none().crash(1, 2, 3);
        let mut s = FaultSchedule::new(plan, 4).unwrap();
        s.begin_epoch(1);
        assert!(!s.crash_compute(1, 3, t(0.0)), "wrong epoch");
        s.begin_epoch(2);
        assert!(!s.crash_compute(1, 2, t(0.0)), "wrong round");
        assert!(!s.crash_compute(0, 3, t(0.0)), "wrong worker");
        assert!(s.crash_compute(1, 3, t(0.0)));
        assert!(!s.crash_compute(1, 3, t(0.0)), "one-shot");
    }

    #[test]
    fn vtime_crash_fires_at_first_consultation_after_t() {
        let plan = FaultPlan::none().crash_at_vtime(0, 100.0);
        let mut s = FaultSchedule::new(plan, 1).unwrap();
        s.begin_epoch(1);
        assert!(!s.crash_compute(0, 0, t(99.9)));
        assert!(s.crash_compute(0, 5, t(100.5)));
        assert!(!s.crash_compute(0, 6, t(200.0)));
    }

    #[test]
    fn straggler_window_is_bounded_in_rounds() {
        let plan = FaultPlan::none().straggler(0, 1, 2, 4.0, Some(3));
        let mut s = FaultSchedule::new(plan, 1).unwrap();
        s.begin_epoch(1);
        assert_eq!(s.compute_factor(0, 1, t(0.0)), 1.0);
        assert_eq!(s.compute_factor(0, 2, t(0.0)), 4.0);
        assert_eq!(s.compute_factor(0, 4, t(0.0)), 4.0);
        assert_eq!(s.compute_factor(0, 5, t(0.0)), 1.0);
        s.begin_epoch(2);
        assert_eq!(s.compute_factor(0, 2, t(0.0)), 1.0, "window was epoch-local");
    }

    #[test]
    fn open_ended_poison_persists_across_epochs() {
        let plan = FaultPlan::none().poison(2, 2, PoisonMode::SignFlip);
        let mut s = FaultSchedule::new(plan, 3).unwrap();
        s.begin_epoch(1);
        assert!(s.poison(2, 0, t(0.0)).is_none());
        s.begin_epoch(2);
        assert_eq!(s.poison(2, 0, t(0.0)), Some(PoisonMode::SignFlip));
        s.begin_epoch(7);
        assert_eq!(s.poison(2, 23, t(0.0)), Some(PoisonMode::SignFlip));
        assert!(s.poison(1, 0, t(0.0)).is_none());
    }

    #[test]
    fn drop_and_sync_and_supervisor_events() {
        let plan = FaultPlan::none()
            .drop_updates(1, 1, 0, Some(2))
            .sync_crash(0, 3)
            .supervisor_crash(2, 5);
        let mut s = FaultSchedule::new(plan, 2).unwrap();
        s.begin_epoch(1);
        assert!(s.drop_update(1, 0, t(0.0)));
        assert!(s.drop_update(1, 1, t(0.0)));
        assert!(!s.drop_update(1, 2, t(0.0)));
        assert!(!s.crash_sync(0, t(0.0)));
        s.begin_epoch(2);
        assert!(!s.crash_supervisor(4, t(0.0)));
        assert!(s.crash_supervisor(5, t(0.0)));
        assert!(!s.crash_supervisor(5, t(0.0)), "one-shot");
        s.begin_epoch(3);
        assert!(s.crash_sync(0, t(0.0)));
        assert!(!s.crash_sync(0, t(0.0)), "one-shot");
    }

    #[test]
    fn shard_crash_fires_once_at_its_epoch() {
        // Shard ids are not worker ids: shard 3 on a 2-worker plan is fine.
        let plan = FaultPlan::none().shard_crash(3, 2).shard_crash(0, 2);
        let mut s = FaultSchedule::new(plan, 2).unwrap();
        assert_eq!(s.max_crashed_shard(), Some(3));
        s.begin_epoch(1);
        assert_eq!(s.crash_shard(t(0.0)), None, "wrong epoch");
        s.begin_epoch(2);
        assert_eq!(s.crash_shard(t(0.0)), Some(3));
        assert_eq!(s.crash_shard(t(0.0)), Some(0), "drains in plan order");
        assert_eq!(s.crash_shard(t(0.0)), None, "one-shot");
        s.begin_epoch(3);
        assert_eq!(s.crash_shard(t(0.0)), None);
    }

    #[test]
    fn poison_modes_corrupt_real_slabs_only() {
        let mut g = Slab::from_vec(vec![1.0, -2.0]);
        PoisonMode::SignFlip.apply(&mut g);
        assert_eq!(g.as_slice().unwrap(), &[-1.0, 2.0]);
        PoisonMode::Scale(-4.0).apply(&mut g);
        assert_eq!(g.as_slice().unwrap(), &[4.0, -8.0]);
        let mut v = Slab::virtual_of(3);
        PoisonMode::Scale(-4.0).apply(&mut v);
        assert_eq!(v.len(), 3);
        assert!(!v.is_real());
    }

    #[test]
    fn invalid_plans_are_rejected() {
        assert!(FaultSchedule::new(FaultPlan::none().crash(5, 1, 0), 4).is_err());
        assert!(
            FaultSchedule::new(FaultPlan::none().straggler(0, 1, 0, 0.5, None), 4).is_err(),
            "speedup straggler makes no sense"
        );
        let bad = FaultPlan::none().with(FaultEvent {
            worker: 0,
            kind: FaultKind::CrashSupervisor,
            at: Trigger::Round { epoch: 1, round: 0 },
            rounds: None,
        });
        assert!(FaultSchedule::new(bad, 4).is_err());
    }
}
