//! Render an [`Audit`] as a typed [`crate::report::Report`].
//!
//! The audit gate consumes the text view (`cargo run -- audit`) and CI
//! compares it byte-for-byte against `python/tools/audit.py`, so the
//! construction here must stay deterministic: rules in catalogue order,
//! findings sorted by (file, line, rule), suppressions by (file, line) —
//! the engine already guarantees the sort, this module only lays out
//! tables.

use crate::report::{Align, Cell, Report, Section, Table};

use super::engine::Audit;
use super::rules::ALL;

/// The command line shown in report provenance.
pub const AUDIT_COMMAND: &str = "cargo run -- audit (fallback: python3 python/tools/audit.py)";

/// Build the deterministic audit report.
pub fn render(audit: &Audit) -> Report {
    let mut summary = Table::new(
        "audit_rules",
        &[
            ("rule", Align::Left),
            ("scope", Align::Left),
            ("files", Align::Right),
            ("open", Align::Right),
            ("allowed", Align::Right),
        ],
    )
    .title("Audited invariants");
    for rule in ALL {
        let open = audit
            .findings
            .iter()
            .filter(|f| f.rule == rule && f.suppressed.is_none())
            .count();
        let allowed = audit
            .findings
            .iter()
            .filter(|f| f.rule == rule && f.suppressed.is_some())
            .count();
        let files = audit.checked.get(rule.name()).copied().unwrap_or(0);
        summary.push_row(vec![
            Cell::text(rule.name()),
            Cell::text(rule.scope()),
            Cell::count(files as u64),
            Cell::count(open as u64),
            Cell::count(allowed as u64),
        ]);
    }
    let note = if audit.clean() {
        format!(
            "audit: clean — 0 open findings, {} suppression(s) in force.",
            audit.allows.len()
        )
    } else {
        format!(
            "audit: {} open finding(s), {} suppression(s) in force.",
            audit.open_count(),
            audit.allows.len()
        )
    };

    let mut report = Report::new(
        "audit",
        "Invariant audit — determinism, accounting and registration contracts",
        AUDIT_COMMAND,
    )
    .with_intro(
        "Static token-level audit of the invariants every result in this repo rests on: \
         no unordered-container iteration in simulation paths, no wall clock or ambient \
         state in virtual time, f32 reductions only under the tensor:: chunked-kernel \
         contract, every test/bench/example registered in Cargo.toml, trace events built \
         only at the sanctioned emit points, and generated-docs markers on every \
         suite-owned page. Violations are either fixed or carry an explicit audit:allow \
         with a justification; stale allows are findings themselves. Rule catalogue: \
         DESIGN.md §7.",
    )
    .with_section(Section::new().table(summary).note(note));

    if audit.open_count() > 0 {
        let mut t = Table::new(
            "audit_findings",
            &[
                ("rule", Align::Left),
                ("file", Align::Left),
                ("line", Align::Right),
                ("detail", Align::Left),
            ],
        )
        .title("Open findings");
        for f in audit.open() {
            t.push_row(vec![
                Cell::text(f.rule.name()),
                Cell::text(&f.file),
                Cell::count(f.line as u64),
                Cell::text(&f.detail),
            ]);
        }
        report = report.with_section(Section::new().heading("Findings").table(t));
    }

    if !audit.allows.is_empty() {
        let mut t = Table::new(
            "audit_allows",
            &[
                ("rule", Align::Left),
                ("file", Align::Left),
                ("line", Align::Right),
                ("reason", Align::Left),
            ],
        )
        .title("Suppressions in force");
        for a in &audit.allows {
            t.push_row(vec![
                Cell::text(a.rule.name()),
                Cell::text(&a.file),
                Cell::count(a.line as u64),
                Cell::text(&a.reason),
            ]);
        }
        report = report.with_section(Section::new().heading("Suppressions").table(t));
    }

    report
}
