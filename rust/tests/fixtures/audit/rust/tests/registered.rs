#[test]
fn ok() {}
