//! Deterministic fault injection — the robustness half of the comparison.
//!
//! The paper's argument for SPIRT is not only cost/performance but *fault
//! tolerance*: P2P serverless training survives worker crashes and tolerates
//! gradient poisoning, while master-aggregated (AllReduce), chunk-owned
//! (ScatterReduce) and supervisor-coordinated (MLLess) topologies each have
//! a stall point, and an always-on GPU fleet pays reboot time at on-demand
//! rates (SPIRT: Barrak et al., arXiv:2309.14148; P2P fault tolerance:
//! arXiv:2302.13995). This module makes those claims measurable:
//!
//! * [`plan`] — [`FaultPlan`] / [`FaultSchedule`]: seeded, virtual-time-
//!   deterministic injection of worker crashes (with cold-start restarts),
//!   straggler slowdowns, update drops, and gradient poisoning, planned at
//!   protocol coordinates (epoch/round) or virtual times. Four adversarial
//!   regimes compose on top of the single-fault kinds: colluding Byzantine
//!   *coalitions* ([`FaultPlan::coalition`]), network *partitions* that
//!   heal at a planned virtual time ([`FaultPlan::partition`], enforced at
//!   every `coordinator::protocol` op), *heavy-tailed stragglers* with
//!   deterministic Pareto draws ([`FaultPlan::pareto_stragglers`]) and
//!   correlated spot-*preemption storms*
//!   ([`FaultPlan::preemption_storm`]).
//! * [`poison_demo`] — a dependency-free distributed training task that
//!   shows robust aggregation (`tensor::robust`) recovering accuracy under
//!   poisoned workers while the naive mean degrades.
//!
//! The hooks live in `coordinator::env::ClusterEnv` (fetch/compute/sync/
//! update boundaries) and in each `Strategy`; recovery *costs* are billed
//! through `cloud::recovery` into the ledger and tallied in
//! `metrics::RecoveryStats`. `exp::table4_faults` renders the resulting
//! per-architecture resilience table.

pub mod plan;
pub mod poison_demo;

pub use plan::{
    FaultEvent, FaultKind, FaultPlan, FaultSchedule, PartitionHit, PoisonMode, SUPERVISOR, Trigger,
};
