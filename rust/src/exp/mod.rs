//! Experiment drivers: one per table/figure in the paper's evaluation.
//!
//! Each driver runs the relevant protocol(s) through the full substrate
//! stack and *returns* a typed [`crate::report::Report`] placing the
//! paper's rows next to our measured values, so the reproduction status is
//! visible at a glance — in the CLI (text renderer), in the generated
//! `docs/` pages (Markdown renderer, `slsgpu report`), and as JSON data.
//! See DESIGN.md §2 for the experiment index and EXPERIMENTS.md for the
//! run commands; the rendered results live under `docs/`.
//!
//! The `rel_err`/`vs_paper` helpers are re-exported from
//! [`crate::report::model`], where the anchored-cell verdict logic
//! generalizes them.

pub mod fig2;
pub mod fig3;
pub mod scale_sweep;
pub mod shard_sweep;
pub mod spirt_indb;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4_faults;
pub mod tournament;
pub mod trace;

pub use crate::report::{rel_err, vs_paper};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_err_basics() {
        assert!((rel_err(110.0, 100.0) - 0.1).abs() < 1e-12);
        assert_eq!(rel_err(5.0, 0.0), 0.0);
    }

    #[test]
    fn vs_paper_formats() {
        let s = vs_paper(14.0, 14.343, 2);
        assert!(s.starts_with("14.00 (paper 14.34"), "{s}");
    }

    #[test]
    fn vs_paper_zero_paper_value_has_no_inf_or_nan() {
        let s = vs_paper(5.0, 0.0, 1);
        assert_eq!(s, "5.0 (paper 0.0)");
        assert!(!s.contains("inf") && !s.contains("NaN"), "{s}");
    }
}
