//! Cluster environment: workers + substrates + measurement plane.
//!
//! One `ClusterEnv` is one experiment: it owns the worker states (virtual
//! clock + model replica + data shard), every cloud substrate instance, the
//! gradient source (real PJRT artifacts or size-only), and the cost/comm/
//! stage accumulators. Strategies mutate it; the experiment drivers read the
//! results out of it.

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::cloud::calibration::{self, FrameworkKind, ModelProfile};
use crate::cloud::{GpuFleet, LambdaRuntime, MessageQueue, ObjectStore, Redis, StepFunctions};
use crate::data::{Dataset, SyntheticCifar, IMG_ELEMS};
use crate::metrics::{CommStats, Ledger, Stage, StageTimer};
use crate::runtime::{Engine, PjrtMath};
use crate::sim::VTime;
use crate::tensor::Slab;
use crate::util::rng::Rng;

/// Local (in-function) aggregation memory bandwidth, bytes/sec — the speed
/// of summing gradient slabs inside a worker (NumPy-level memory-bound op).
pub const LOCAL_AGG_BW: f64 = 2.0e9;

/// Whether gradients come from the PJRT runtime or are size-only.
pub enum GradMode {
    /// Size-only gradients; losses are not tracked. Used by the paper-scale
    /// cost/communication experiments (Table 2, Fig. 2, Fig. 3-sim).
    Virtual,
    /// Real gradients through the AOT grad artifact; the full e2e path.
    Real {
        engine: Rc<Engine>,
        /// Executed model config name (e.g. "mobilenet_s").
        model: String,
        train: Dataset,
        test: Dataset,
    },
}

/// One worker replica.
#[derive(Debug)]
pub struct WorkerState {
    pub id: usize,
    pub clock: VTime,
    pub theta: Slab,
    /// Sample indices this worker owns (reshuffled every epoch).
    pub shard: Vec<usize>,
    cursor: usize,
}

/// Experiment parameters for building a [`ClusterEnv`].
pub struct EnvConfig {
    pub framework: FrameworkKind,
    pub workers: usize,
    /// Gradient batches per worker per epoch (paper: 24).
    pub batches_per_epoch: usize,
    /// Samples per gradient batch (paper: 512; executed configs: 32/64).
    pub batch_size: usize,
    pub lr: f32,
    /// Full-architecture profile for the virtual-time compute model.
    pub profile: ModelProfile,
    pub grad_mode: GradMode,
    pub seed: u64,
}

impl EnvConfig {
    /// Paper-scale, size-only config (cost/communication experiments).
    pub fn virtual_paper(framework: FrameworkKind, arch: &str, workers: usize) -> Result<EnvConfig> {
        let profile = calibration::profile(arch)
            .ok_or_else(|| anyhow::anyhow!("unknown architecture {arch}"))?;
        Ok(EnvConfig {
            framework,
            workers,
            batches_per_epoch: 24,
            batch_size: 512,
            lr: 0.05,
            profile,
            grad_mode: GradMode::Virtual,
            seed: 0x5157,
        })
    }

    /// End-to-end config over an executed model (real gradients). The
    /// virtual-time compute model is the full architecture's, scaled to the
    /// reduced parameter count.
    pub fn real(
        framework: FrameworkKind,
        engine: Rc<Engine>,
        model: &str,
        workers: usize,
        train_samples: usize,
        seed: u64,
    ) -> Result<EnvConfig> {
        let entry = engine.manifest.model(model)?.clone();
        let base = calibration::profile(&entry.arch)
            .ok_or_else(|| anyhow::anyhow!("no profile for arch {}", entry.arch))?;
        let profile = calibration::scaled_profile(base, entry.n_params as u64);
        let gen = SyntheticCifar::with_defaults(seed);
        let train = gen.generate(train_samples, 0);
        let test = gen.generate(entry.eval_batch * 4, 1);
        let batch = entry.batch;
        let batches_per_epoch = (train_samples / workers / batch).max(1);
        Ok(EnvConfig {
            framework,
            workers,
            batches_per_epoch,
            batch_size: batch,
            lr: 0.1,
            profile,
            grad_mode: GradMode::Real { engine, model: model.to_string(), train, test },
            seed,
        })
    }
}

/// Result of one gradient computation.
#[derive(Debug)]
pub struct GradResult {
    pub grad: Slab,
    pub loss: Option<f64>,
    pub correct: u32,
    /// Virtual seconds the computation took on the configured device.
    pub secs: f64,
}

/// Which device executes gradient compute (drives the duration model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Device {
    LambdaCpu,
    GpuT4,
}

/// The experiment world.
pub struct ClusterEnv {
    pub framework: FrameworkKind,
    pub workers: Vec<WorkerState>,
    pub profile: ModelProfile,
    pub batch_size: usize,
    pub batches_per_epoch: usize,
    pub lr: f32,
    pub n_params: usize,
    pub epoch: usize,

    // Substrates.
    pub lambda: LambdaRuntime,
    /// Shared object store (LambdaML gradient bucket, Lambda data loads).
    pub store: ObjectStore,
    /// GPU-side object store (EC2 bandwidth profile).
    pub gpu_store: ObjectStore,
    pub queues: MessageQueue,
    pub stepfn: StepFunctions,
    /// Per-worker Redis instances (SPIRT's P2P databases).
    pub worker_redis: Vec<Redis>,
    /// Shared Redis (MLLess update store, LambdaML model store).
    pub shared_redis: Redis,
    pub fleet: GpuFleet,

    // Measurement plane.
    pub ledger: Ledger,
    pub comm: CommStats,
    pub stages: StageTimer,

    grad_mode: GradMode,
    pub rng: Rng,
}

impl ClusterEnv {
    pub fn new(cfg: EnvConfig) -> Result<ClusterEnv> {
        if cfg.workers == 0 {
            bail!("need at least one worker");
        }
        let n_params = match &cfg.grad_mode {
            GradMode::Virtual => cfg.profile.params as usize,
            GradMode::Real { engine, model, .. } => engine.manifest.model(model)?.n_params,
        };

        let rng = Rng::new(cfg.seed);
        let mut workers = Vec::with_capacity(cfg.workers);
        let theta0 = match &cfg.grad_mode {
            GradMode::Virtual => Slab::virtual_of(n_params),
            GradMode::Real { engine, model, .. } => engine.init(model, cfg.seed as u32)?,
        };
        let shards = match &cfg.grad_mode {
            GradMode::Virtual => vec![Vec::new(); cfg.workers],
            GradMode::Real { train, .. } => train.shard_indices(cfg.workers),
        };
        for (id, shard) in shards.into_iter().enumerate() {
            workers.push(WorkerState {
                id,
                clock: VTime::ZERO,
                theta: theta0.clone(),
                shard,
                cursor: 0,
            });
        }

        // SPIRT's per-worker Redis instances get the PJRT in-database math
        // engine in real mode (the RedisAI analog).
        let worker_redis: Vec<Redis> = (0..cfg.workers)
            .map(|i| match &cfg.grad_mode {
                GradMode::Real { engine, model, .. } => Redis::with_math(
                    format!("spirt-w{i}"),
                    std::sync::Arc::new(PjrtMath::new(engine.clone(), model.clone())),
                ),
                GradMode::Virtual => Redis::new(format!("spirt-w{i}")),
            })
            .collect();

        Ok(ClusterEnv {
            framework: cfg.framework,
            workers,
            profile: cfg.profile,
            batch_size: cfg.batch_size,
            batches_per_epoch: cfg.batches_per_epoch,
            lr: cfg.lr,
            n_params,
            epoch: 0,
            lambda: LambdaRuntime::new(),
            store: ObjectStore::new(),
            gpu_store: ObjectStore::with_profile(
                calibration::GPU_S3_LATENCY,
                calibration::GPU_S3_BW,
                64,
            ),
            queues: MessageQueue::new(),
            stepfn: StepFunctions::new(),
            worker_redis,
            shared_redis: Redis::new("shared"),
            fleet: GpuFleet::new(cfg.workers),
            ledger: Ledger::new(),
            comm: CommStats::new(),
            stages: StageTimer::new(),
            grad_mode: cfg.grad_mode,
            rng: Rng::fork(&rng, 1),
        })
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn is_real(&self) -> bool {
        matches!(self.grad_mode, GradMode::Real { .. })
    }

    /// Gradient payload bytes (f32 × params).
    pub fn grad_bytes(&self) -> u64 {
        self.n_params as u64 * 4
    }

    /// Begin a new epoch: reshuffle shards, bump counter.
    pub fn begin_epoch(&mut self) {
        self.epoch += 1;
        let mut rng = self.rng.fork(0xE70C ^ self.epoch as u64);
        for w in &mut self.workers {
            rng.shuffle(&mut w.shard);
            w.cursor = 0;
        }
    }

    /// Serverless statelessness: re-load model + batch data on invocation.
    /// Advances the worker clock; charges FetchDataset stage time.
    pub fn state_load(&mut self, w: usize) {
        let model_load = self.grad_bytes() as f64 / calibration::REDIS_BW
            + calibration::REDIS_LATENCY;
        let data_bytes = (self.batch_size * IMG_ELEMS * 4) as u64;
        let data_load = data_bytes as f64 / calibration::S3_BW + calibration::S3_LATENCY;
        let secs = model_load + data_load;
        self.workers[w].clock += secs;
        self.stages.add(Stage::FetchDataset, secs);
    }

    /// Compute one gradient batch for worker `w` on `device`. Advances the
    /// worker clock by the modeled duration; returns the (real or virtual)
    /// gradient.
    pub fn compute_grad(&mut self, w: usize, device: Device) -> Result<GradResult> {
        let per_sample = match device {
            Device::LambdaCpu => self.profile.lambda_secs_per_sample,
            Device::GpuT4 => self.profile.gpu_secs_per_sample,
        };
        let secs = per_sample * self.batch_size as f64;

        let out = match &self.grad_mode {
            GradMode::Virtual => GradResult {
                grad: Slab::virtual_of(self.n_params),
                loss: None,
                correct: 0,
                secs,
            },
            GradMode::Real { engine, model, train, .. } => {
                let worker = &mut self.workers[w];
                let b = self.batch_size;
                if worker.shard.len() < b {
                    bail!("worker {w} shard smaller than one batch");
                }
                // Wrap the cursor (epoch boundaries are driven by the
                // strategy's batches_per_epoch, not shard exhaustion).
                if worker.cursor + b > worker.shard.len() {
                    worker.cursor = 0;
                }
                let idx = &worker.shard[worker.cursor..worker.cursor + b];
                worker.cursor += b;
                let (x, y) = train.batch(idx);
                let g = engine.grad(model, &worker.theta, &x, &y)?;
                GradResult {
                    grad: g.grads,
                    loss: Some(g.loss as f64),
                    correct: g.correct,
                    secs,
                }
            }
        };
        self.workers[w].clock += secs;
        self.stages.add(Stage::ComputeGradients, secs);
        Ok(out)
    }

    /// Apply `theta -= lr * inv_k * gsum` on worker `w`'s replica. In real
    /// mode this runs the fused Pallas `avg_update` artifact; virtual mode
    /// charges the modeled duration only.
    pub fn apply_update(&mut self, w: usize, gsum: &Slab, inv_k: f32) -> Result<()> {
        let secs = 3.0 * gsum.nbytes() as f64 / LOCAL_AGG_BW;
        match &self.grad_mode {
            GradMode::Virtual => {}
            GradMode::Real { engine, model, .. } => {
                let theta = &self.workers[w].theta;
                self.workers[w].theta =
                    engine.avg_update(model, theta, gsum, inv_k, self.lr)?;
            }
        }
        self.workers[w].clock += secs;
        self.stages.add(Stage::ModelUpdate, secs);
        Ok(())
    }

    /// Local in-function aggregation duration for summing `k` slabs.
    pub fn local_agg_secs(&self, k: usize) -> f64 {
        k as f64 * self.grad_bytes() as f64 / LOCAL_AGG_BW
    }

    /// Charge `secs` of synchronization wait to worker `w`.
    pub fn charge_sync(&mut self, w: usize, secs: f64) {
        self.workers[w].clock += secs;
        self.stages.add(Stage::Synchronize, secs);
    }

    /// Virtual barrier across all workers (clocks jump to the max).
    pub fn barrier(&mut self) -> VTime {
        let t = self
            .workers
            .iter()
            .map(|w| w.clock)
            .fold(VTime::ZERO, VTime::max);
        for w in &mut self.workers {
            w.clock = t;
        }
        t
    }

    /// Max worker clock (epoch end time).
    pub fn max_clock(&self) -> VTime {
        self.workers.iter().map(|w| w.clock).fold(VTime::ZERO, VTime::max)
    }

    /// Evaluate test accuracy of worker 0's replica (real mode only).
    pub fn eval_accuracy(&self) -> Result<Option<f64>> {
        let GradMode::Real { engine, model, test, .. } = &self.grad_mode else {
            return Ok(None);
        };
        let entry = engine.manifest.model(model)?;
        let b = entry.eval_batch;
        let theta = &self.workers[0].theta;
        let mut correct = 0u64;
        let mut total = 0u64;
        let batches = test.len() / b;
        for i in 0..batches {
            let idx: Vec<usize> = (i * b..(i + 1) * b).collect();
            let (x, y) = test.batch(&idx);
            let (_, c) = engine.eval(model, theta, &x, &y)?;
            correct += c as u64;
            total += b as u64;
        }
        Ok(Some(correct as f64 / total.max(1) as f64))
    }

    /// Allocated Lambda memory for this framework/model (billing input).
    pub fn allocated_mb(&self) -> f64 {
        calibration::peak_ram_mb(self.framework, &self.profile, self.batch_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn virt_env(workers: usize) -> ClusterEnv {
        ClusterEnv::new(
            EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", workers).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn virtual_env_has_paper_shapes() {
        let env = virt_env(4);
        assert_eq!(env.num_workers(), 4);
        assert_eq!(env.n_params, 4_200_000);
        assert_eq!(env.grad_bytes(), 16_800_000);
        assert_eq!(env.batches_per_epoch, 24);
        assert!(!env.is_real());
    }

    #[test]
    fn compute_grad_charges_device_time() {
        let mut env = virt_env(2);
        let r = env.compute_grad(0, Device::LambdaCpu).unwrap();
        assert!((r.secs - 512.0 * env.profile.lambda_secs_per_sample).abs() < 1e-9);
        assert_eq!(env.workers[0].clock.secs(), r.secs);
        assert_eq!(env.workers[1].clock.secs(), 0.0);
        let g = env.compute_grad(1, Device::GpuT4).unwrap();
        assert!(g.secs < r.secs, "T4 must be faster than Lambda CPU");
        assert_eq!(r.grad.len(), env.n_params);
    }

    #[test]
    fn barrier_aligns_clocks() {
        let mut env = virt_env(3);
        env.charge_sync(1, 5.0);
        let t = env.barrier();
        assert_eq!(t.secs(), 5.0);
        assert!(env.workers.iter().all(|w| w.clock == t));
    }

    #[test]
    fn state_load_charges_fetch_stage() {
        let mut env = virt_env(1);
        env.state_load(0);
        assert!(env.stages.get(Stage::FetchDataset) > 0.05);
        assert!(env.workers[0].clock.secs() > 0.0);
    }

    #[test]
    fn apply_update_virtual_charges_update_stage() {
        let mut env = virt_env(1);
        let g = Slab::virtual_of(env.n_params);
        env.apply_update(0, &g, 0.25).unwrap();
        assert!(env.stages.get(Stage::ModelUpdate) > 0.0);
    }

    #[test]
    fn begin_epoch_reshuffles_deterministically() {
        let mut a = virt_env(2);
        let mut b = virt_env(2);
        a.begin_epoch();
        b.begin_epoch();
        assert_eq!(a.epoch, 1);
        assert_eq!(a.workers[0].shard, b.workers[0].shard);
    }

    #[test]
    fn allocated_memory_uses_framework_model() {
        let env = virt_env(4);
        let mb = env.allocated_mb();
        assert!((mb - 2070.7).abs() < 50.0, "AllReduce/MobileNet ≈ 2048–2090, got {mb}");
    }
}
