//! Table 1: stage-by-stage workflow comparison (qualitative).
//!
//! The stage contents live on the `Strategy` implementations themselves;
//! this driver renders them side by side, proving the code structure *is*
//! the paper's Table 1.

use crate::cloud::FrameworkKind;
use crate::coordinator::strategy_for;
use crate::metrics::Stage;
use crate::util::table::{Align, Table};

pub fn render() -> String {
    let mut t = Table::new(&["Framework", "Stage", "Content"])
        .title("Table 1 — Key computational stages per framework")
        .align(&[Align::Left, Align::Left, Align::Left]);
    for (i, kind) in FrameworkKind::ALL.iter().enumerate() {
        if i > 0 {
            t.rule();
        }
        let strat = strategy_for(*kind);
        for (stage, content) in strat.stage_table() {
            t.row(vec![kind.name().to_string(), stage.to_string(), wrap(content, 78)]);
        }
    }
    t.render()
}

fn wrap(text: &str, _width: usize) -> String {
    // Single-line cell (terminal tables stay readable unwrapped).
    text.split_whitespace().collect::<Vec<_>>().join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_frameworks_and_stages() {
        let s = render();
        for kind in FrameworkKind::ALL {
            assert!(s.contains(kind.name()), "missing {}", kind.name());
        }
        for stage in Stage::ALL {
            assert!(s.contains(&stage.to_string()), "missing {stage}");
        }
        // Signature details from the paper's Table 1.
        assert!(s.contains("averaged within the database")); // SPIRT
        assert!(s.contains("significant")); // MLLess
        assert!(s.contains("master")); // AllReduce
        assert!(s.contains("chunks")); // ScatterReduce
        assert!(s.contains("S3 bucket")); // GPU
    }
}
