#!/usr/bin/env python3
"""Generate ``rust/tests/golden/report_fixture.{txt,md,json}``.

Builds the exact fixture `rust/tests/report.rs::fixture()` builds and
renders it through the byte-exact replica in ``report_replica.py``. Run
from the repo root:

    python3 python/tools/gen_report_goldens.py

Regenerate only when the renderer format deliberately changes; the golden
tests exist to catch *accidental* byte drift.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import report_replica as rr  # noqa: E402


def fixture():
    t = rr.table(
        "timing",
        [("Framework", rr.LEFT), ("Per-batch (s)", rr.RIGHT), ("Verdict basis", rr.LEFT)],
        title="Fixture — paper-anchored timings",
    )
    rr.push_row(
        t,
        [
            rr.cell("SPIRT"),
            rr.vs_paper_cell(14.0, 14.343, 2, 0.15),
            rr.cell("within 15%"),
        ],
    )
    rr.rule(t)
    rr.push_row(
        t,
        [
            rr.cell("MLLess"),
            rr.vs_paper_cell(99.0, 69.425, 2, 0.15),
            rr.cell("out of 15%"),
        ],
    )
    plain = rr.table("counts", [("kind", rr.LEFT), ("n", rr.RIGHT)])
    rr.push_row(plain, [rr.cell("ops"), rr.count_cell(42)])
    return rr.report(
        "fixture",
        "Fixture report",
        "slsgpu fixture",
        intro=["Fixed input for the golden-file tests: byte-stable across runs and platforms."],
        sections=[
            rr.section(
                heading="Timings",
                paragraphs=["One PASS row and one WARN row."],
                tables=[t],
                notes=["note: trailing footer line"],
            ),
            rr.section(tables=[plain]),
        ],
    )


def main():
    root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    golden = os.path.join(root, "rust", "tests", "golden")
    os.makedirs(golden, exist_ok=True)
    r = fixture()
    outputs = {
        "report_fixture.txt": rr.report_text(r),
        "report_fixture.md": rr.report_md(r),
        "report_fixture.json": rr.report_json(r),
    }
    for name, contents in outputs.items():
        path = os.path.join(golden, name)
        with open(path, "w", encoding="utf-8") as fh:
            fh.write(contents)
        print(f"wrote {path} ({len(contents)} bytes)")


if __name__ == "__main__":
    main()
