//! Bench: regenerate Table 3 / Fig. 4 (convergence time & final accuracy,
//! all five frameworks, end-to-end real gradients).
//!
//! The full run (to 80%) takes tens of minutes of CPU; the default here
//! uses a reduced budget controlled by SLSGPU_T3_EPOCHS / SLSGPU_T3_SAMPLES
//! so `cargo bench` stays tractable. The full-budget record lives in
//! EXPERIMENTS.md (produced by `slsgpu exp table3`).
use std::rc::Rc;
use std::time::Instant;

use slsgpu::exp::table3::{render, render_csv, run, Table3Config};
use slsgpu::runtime::Engine;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let engine = match Engine::load("artifacts") {
        Ok(e) => Rc::new(e),
        Err(err) => {
            println!("table3 bench skipped: {err:#} (run `make artifacts`)");
            return;
        }
    };
    let cfg = Table3Config {
        model: "mobilenet_s".into(),
        workers: 4,
        train_samples: env_usize("SLSGPU_T3_SAMPLES", 512),
        max_epochs: env_usize("SLSGPU_T3_EPOCHS", 3),
        target_acc: 0.80,
        seed: 42,
    };
    let t0 = Instant::now();
    let rows = run(engine, &cfg).expect("table3");
    print!("{}", render(&rows, &cfg));
    let csv = render_csv(&rows);
    std::fs::write("fig4_curve.csv", &csv).ok();
    println!("accuracy-vs-time series -> fig4_curve.csv ({} rows)", csv.lines().count() - 1);
    println!("regenerated in {:.1} s (budget: {} epochs x {} samples)",
        t0.elapsed().as_secs_f64(), cfg.max_epochs, cfg.train_samples);
}
