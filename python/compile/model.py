"""Layer-2 artifact functions: everything the Rust coordinator executes.

For each *executed* model config this module builds the pure functions that
aot.py lowers to HLO text:

  init(seed)            -> theta                       (He-normal flat init)
  grad(theta, x, y)     -> (loss, grads, correct)      (fwd+bwd, flat ABI)
  eval(theta, x, y)     -> (loss, correct)             (fwd only)

and, per flat-slab size n (executed configs + the paper's full model sizes):

  acc(acc, g, w)            -> acc + w*g               (Pallas)
  sgd(theta, g, lr)         -> theta - lr*g            (Pallas)
  avg_update(theta, gsum,
             inv_k, lr)     -> theta - lr*inv_k*gsum   (Pallas, fused in-DB op)

The executed configs are width-reduced so a full convergence run fits the CPU
testbed; the paper-size elementwise slabs (4.2M / 11.7M params) make the
SPIRT in-database benchmark move paper-scale bytes through real compiled code.
"""

import jax
import jax.numpy as jnp

from . import params as P
from .kernels import accumulate, fused_avg_update, sgd_update
from .models import ARCHS

NUM_CLASSES = 10
IMAGE_SHAPE = (32, 32, 3)

# Configs that are lowered to executable grad/eval/init artifacts.
MODEL_CONFIGS = {
    "mobilenet_s": {"arch": "mobilenet", "width": 0.25, "batch": 64, "eval_batch": 256},
    "resnet18_s": {"arch": "resnet18", "width": 0.25, "batch": 32, "eval_batch": 256},
}

# Paper-reported full-model parameter counts (gradient payload sizes for the
# communication/cost experiments; no grad artifact is built at these sizes).
PAPER_SIZES = {
    "mobilenet": 4_200_000,
    "resnet18": 11_700_000,
    "resnet50": 25_600_000,
}


def build_model(name):
    """Instantiate (init, apply, spec) for a named executed config."""
    cfg = MODEL_CONFIGS[name]
    init, apply = ARCHS[cfg["arch"]](width=cfg["width"], num_classes=NUM_CLASSES)
    params = jax.eval_shape(init, jax.random.PRNGKey(0))
    spec = P.flatten_spec(params)
    return init, apply, spec


def make_init_fn(name):
    init, _, _ = build_model(name)

    def init_flat(seed):
        key = jax.random.PRNGKey(seed)
        return (P.tree_to_vec(init(key)),)

    return init_flat


def make_grad_fn(name):
    from .models import layers as L

    _, apply, spec = build_model(name)

    def loss_fn(theta, x, y):
        params = P.vec_to_tree(theta, spec)
        logits = apply(params, x)
        return L.softmax_cross_entropy(logits, y), logits

    def grad_flat(theta, x, y):
        (loss, logits), grads_tree = jax.value_and_grad(loss_fn, has_aux=True)(
            theta, x, y
        )
        # theta is already flat, so grads_tree is the flat cotangent.
        return loss, grads_tree, L.correct_count(logits, y)

    return grad_flat


def make_eval_fn(name):
    from .models import layers as L

    _, apply, spec = build_model(name)

    def eval_flat(theta, x, y):
        params = P.vec_to_tree(theta, spec)
        logits = apply(params, x)
        return L.softmax_cross_entropy(logits, y), L.correct_count(logits, y)

    return eval_flat


# ---------------------------------------------------------------------------
# Elementwise slab artifacts (size-parameterized, Pallas-backed)


def make_acc_fn():
    def acc(a, g, w):
        return (accumulate(a, g, w),)

    return acc


def make_sgd_fn():
    def sgd(theta, g, lr):
        return (sgd_update(theta, g, lr),)

    return sgd


def make_avg_update_fn():
    def avg_update(theta, gsum, inv_k, lr):
        return (fused_avg_update(theta, gsum, inv_k, lr),)

    return avg_update


def slab_sizes():
    """All flat-slab sizes that need elementwise artifacts."""
    sizes = {}
    for name in MODEL_CONFIGS:
        _, _, spec = build_model(name)
        sizes[name] = spec["total"]
    for arch, n in PAPER_SIZES.items():
        sizes[f"{arch}_full"] = n
    return sizes
