//! ASCII table renderer for experiment reports (paper-style rows).

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table builder; renders with box-drawing borders.
#[derive(Debug, Default)]
pub struct Table {
    title: Option<String>,
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
    separators: Vec<usize>, // row indices after which to draw a rule
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            title: None,
            header: header.iter().map(|s| s.to_string()).collect(),
            aligns: header.iter().map(|_| Align::Right).collect(),
            rows: Vec::new(),
            separators: Vec::new(),
        }
    }

    pub fn title(mut self, t: impl Into<String>) -> Self {
        self.title = Some(t.into());
        self
    }

    pub fn align(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.header.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Draw a horizontal rule after the last added row (section break).
    pub fn rule(&mut self) {
        self.separators.push(self.rows.len());
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let rule: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncols {
                let pad = widths[i] - cells[i].chars().count();
                match self.aligns[i] {
                    Align::Left => s.push_str(&format!(" {}{} |", cells[i], " ".repeat(pad))),
                    Align::Right => s.push_str(&format!(" {}{} |", " ".repeat(pad), cells[i])),
                }
            }
            s
        };

        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&rule);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&rule);
        out.push('\n');
        for (i, row) in self.rows.iter().enumerate() {
            out.push_str(&fmt_row(row));
            out.push('\n');
            if self.separators.contains(&(i + 1)) && i + 1 != self.rows.len() {
                out.push_str(&rule);
                out.push('\n');
            }
        }
        out.push_str(&rule);
        out.push('\n');
        out
    }
}

/// Convenience: `cells![a, b, c]` -> `Vec<String>` via Display.
#[macro_export]
macro_rules! cells {
    ($($x:expr),* $(,)?) => {
        vec![$(format!("{}", $x)),*]
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]).align(&[Align::Left, Align::Right]);
        t.row(vec!["a".into(), "1.5".into()]);
        t.row(vec!["long-name".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("| name      | value |"), "{s}");
        assert!(s.contains("| a         |   1.5 |"), "{s}");
    }

    #[test]
    fn title_and_rule() {
        let mut t = Table::new(&["x"]).title("T");
        t.row(vec!["1".into()]);
        t.rule();
        t.row(vec!["2".into()]);
        let s = t.render();
        assert!(s.starts_with("T\n"));
        // two data rows + header -> at least 4 rules
        assert!(s.matches("+---+").count() >= 4, "{s}");
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
