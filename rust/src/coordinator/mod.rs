//! The five training architectures under comparison.
//!
//! Each framework implements [`Strategy`]: given a [`ClusterEnv`] (workers,
//! substrates, measurement plane), `run_epoch` executes one full pass of the
//! paper's Fig.-1 workflow — fetch → compute → synchronize → update — with
//! the framework's own aggregation topology and synchronization mechanism:
//!
//! | framework       | aggregation                        | sync            |
//! |-----------------|------------------------------------|-----------------|
//! | SPIRT           | in-database (RedisAI), P2P         | sync queue      |
//! | MLLess          | significance-filtered, supervisor  | queues + superv.|
//! | AllReduce       | designated master                  | storage polling |
//! | ScatterReduce   | chunk-per-worker                   | storage polling |
//! | GPU baseline    | local average (all-gather via S3)  | storage polling |
//!
//! Gradients are real slabs in end-to-end mode and size-only in cost-model
//! mode; both traverse identical protocol code (see `tensor::Slab`).
//!
//! The clock/stage/ledger/fault bookkeeping around every substrate call is
//! shared: strategies drive per-worker [`protocol::Timeline`] handles
//! rather than hand-rolling it, and consult [`protocol::SyncMode`] at each
//! synchronization point — [`SyncMode::Bsp`] reproduces the paper's
//! bulk-synchronous rounds, [`SyncMode::Async`] relaxes them to a
//! bounded-staleness quorum.

pub mod allreduce;
pub mod convergence;
pub mod env;
pub mod gpu;
pub mod mlless;
pub mod protocol;
pub mod scatter_reduce;
pub mod spirt;

use crate::cloud::FrameworkKind;
use crate::metrics::Stage;
use crate::Result;

pub use convergence::EarlyStopper;
pub use env::{ClusterEnv, EnvConfig, GradMode, WorkerState};
pub use protocol::{Op, OpOut, RedisSel, StoreSel, SyncMode, Timeline};

/// Per-epoch outcome of a strategy run.
#[derive(Debug, Clone, Default)]
pub struct EpochStats {
    /// Mean training loss over all gradient batches (None in virtual mode).
    pub mean_loss: Option<f64>,
    /// Total gradient batches processed across workers.
    pub batches: usize,
    /// Epoch wall time on the virtual timeline (max worker clock advance).
    pub epoch_secs: f64,
    /// Mean Lambda function duration this epoch (0 for the GPU baseline).
    pub mean_fn_secs: f64,
}

/// A distributed training architecture.
pub trait Strategy {
    fn kind(&self) -> FrameworkKind;

    /// Execute one epoch (every worker consumes its batch schedule once).
    fn run_epoch(&mut self, env: &mut ClusterEnv) -> Result<EpochStats>;

    /// Table-1 stage contents: what this framework does in each stage.
    fn stage_table(&self) -> Vec<(Stage, &'static str)>;
}

/// Instantiate a strategy by kind (default knobs).
pub fn strategy_for(kind: FrameworkKind) -> Box<dyn Strategy> {
    match kind {
        FrameworkKind::Spirt => Box::new(spirt::Spirt::new()),
        FrameworkKind::MlLess => Box::new(mlless::MlLess::new(mlless::DEFAULT_THRESHOLD)),
        FrameworkKind::AllReduce => Box::new(allreduce::AllReduce::new()),
        FrameworkKind::ScatterReduce => Box::new(scatter_reduce::ScatterReduce::new()),
        FrameworkKind::GpuBaseline => Box::new(gpu::GpuBaseline::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_covers_all_frameworks() {
        for kind in FrameworkKind::ALL {
            let s = strategy_for(kind);
            assert_eq!(s.kind(), kind);
            let stages = s.stage_table();
            assert_eq!(stages.len(), 4, "{kind:?} must describe all 4 stages");
            for (i, want) in Stage::ALL.iter().enumerate() {
                assert_eq!(stages[i].0, *want);
                assert!(!stages[i].1.is_empty());
            }
        }
    }
}
