//! Redis/RedisAI substrate: KV tensor store + in-database computation.
//!
//! SPIRT hosts one RedisAI instance per worker and pushes the gradient math
//! *into* the database (AI.TENSORSET + scripted averaging/SGD), so slabs
//! never cross the network during aggregation — the paper measures this as
//! 67.32→37.41 s averaging and 27.5→4.8 s updates vs a naive
//! fetch-update-store loop (§4.2). This substrate reproduces both paths:
//!
//! * network ops (`set`/`get`) charge latency + bytes/bandwidth and move
//!   real slabs in and out;
//! * in-DB ops (`acc_in_db`, `avg_update_in_db`) run a [`SlabMath`] engine
//!   *inside* the store — on the end-to-end path that engine is the PJRT
//!   executable of the fused Pallas kernel (`runtime::PjrtMath`), the
//!   faithful RedisAI analog — and charge only the in-instance throughput.
//!
//! Redis command processing is single-threaded: one queueing server, so
//! concurrent clients serialize exactly like a real instance.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::metrics::{CommKind, CommStats, Ledger};
use crate::sim::{Resource, VTime};
use crate::tensor::{RustMath, Slab, SlabMath};

use super::calibration::{
    CLIENT_TENSOR_BW, INDB_UPDATE_BW, REDIS_BW, REDIS_INDB_BW, REDIS_LATENCY, TORCH_REBUILD_BW,
};

/// One Redis/RedisAI instance.
pub struct Redis {
    name: String,
    /// Key -> (slab, visibility time). Ordered map: only keyed lookups
    /// touch it, and keeping sim-path containers ordered is the
    /// `unordered-iteration` audit invariant.
    store: BTreeMap<String, (Slab, VTime)>,
    cmd: Resource, // single-threaded command loop (network transfers)
    /// RedisAI executes scripted tensor ops on a background worker thread
    /// (AI.SCRIPTEXEC threadpool) — the command loop stays responsive while
    /// accumulation chains run, matching RedisAI's actual architecture.
    script_engine: Resource,
    math: Arc<dyn SlabMath>,
    latency: f64,
    net_bw: f64,
    indb_bw: f64,
    /// Seconds requests spent queued behind other clients of this instance
    /// (command loop + script engine). Pure bookkeeping — never fed back
    /// into any timeline — surfaced per shard by `cloud::cluster`.
    queue_wait: f64,
}

impl std::fmt::Debug for Redis {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Redis")
            .field("name", &self.name)
            .field("keys", &self.store.len())
            .finish()
    }
}

impl Redis {
    pub fn new(name: impl Into<String>) -> Redis {
        Redis::with_math(name, Arc::new(RustMath))
    }

    /// Install the in-database math engine (PJRT-backed on the e2e path).
    pub fn with_math(name: impl Into<String>, math: Arc<dyn SlabMath>) -> Redis {
        Redis {
            name: name.into(),
            store: BTreeMap::new(),
            cmd: Resource::new("redis-cmd", 1),
            script_engine: Resource::new("redisai-scripts", 1),
            math,
            latency: REDIS_LATENCY,
            net_bw: REDIS_BW,
            indb_bw: REDIS_INDB_BW,
            queue_wait: 0.0,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// SET: transfer the slab over the network into the store. Per-op
    /// latency is client-side RTT; only the transfer occupies the command
    /// loop.
    pub fn set(&mut self, now: VTime, key: &str, slab: Slab, comm: &mut CommStats) -> VTime {
        let bytes = slab.nbytes();
        let arrival = now + self.latency;
        let served = self.cmd.serve(arrival, bytes as f64 / self.net_bw);
        self.queue_wait += served.queueing_delay(arrival);
        let done = served.end;
        self.store.insert(key.to_string(), (slab, done));
        comm.record(CommKind::Put, bytes);
        comm.comm_time += done - now;
        done
    }

    /// GET: transfer the slab out (waits for visibility). The wait for the
    /// producer's write to land is a stall on the *writer*, not transfer
    /// overhead: it accrues to `CommStats::visibility_wait`, and only the
    /// remaining span (latency + wire time + queueing) to `comm_time`.
    pub fn get(&mut self, now: VTime, key: &str, comm: &mut CommStats) -> Result<(VTime, Slab)> {
        let (slab, visible) = self
            .store
            .get(key)
            .ok_or_else(|| anyhow!("redis[{}]: missing key {key}", self.name))?
            .clone();
        let start = now.max(visible) + self.latency;
        let served = self.cmd.serve(start, slab.nbytes() as f64 / self.net_bw);
        self.queue_wait += served.queueing_delay(start);
        let done = served.end;
        comm.record(CommKind::Get, slab.nbytes());
        let wait = (visible - now).max(0.0);
        comm.visibility_wait += wait;
        comm.comm_time += (done - now) - wait;
        Ok((done, slab))
    }

    /// Client-side tensor GET (tensorget → numpy conversion in a Python
    /// function — the naive fetch-update-store path of §4.2).
    pub fn get_tensor_client(
        &mut self,
        now: VTime,
        key: &str,
        comm: &mut CommStats,
    ) -> Result<(VTime, Slab)> {
        let (slab, visible) = self.peek(key)?;
        let start = now.max(visible) + self.latency;
        let served = self.cmd.serve(start, slab.nbytes() as f64 / CLIENT_TENSOR_BW);
        self.queue_wait += served.queueing_delay(start);
        let done = served.end;
        comm.record(CommKind::Get, slab.nbytes());
        let wait = (visible - now).max(0.0);
        comm.visibility_wait += wait;
        comm.comm_time += (done - now) - wait;
        Ok((done, slab))
    }

    /// Client-side tensor SET (numpy → tensorset from a Python function).
    pub fn set_tensor_client(
        &mut self,
        now: VTime,
        key: &str,
        slab: Slab,
        comm: &mut CommStats,
    ) -> VTime {
        let bytes = slab.nbytes();
        let arrival = now + self.latency;
        let served = self.cmd.serve(arrival, bytes as f64 / CLIENT_TENSOR_BW);
        self.queue_wait += served.queueing_delay(arrival);
        let done = served.end;
        self.store.insert(key.to_string(), (slab, done));
        comm.record(CommKind::Put, bytes);
        comm.comm_time += done - now;
        done
    }

    /// Replica write: the primary pushes the payload to this instance after
    /// its own ack at `after`. The client is *not* blocked on replication
    /// (asynchronous, Redis-style), so no `comm_time` accrues — only this
    /// instance's command loop is occupied and the Put bytes are counted.
    /// Returns when the replica copy becomes visible.
    pub fn replicate_set(
        &mut self,
        after: VTime,
        key: &str,
        slab: Slab,
        comm: &mut CommStats,
    ) -> VTime {
        let bytes = slab.nbytes();
        let arrival = after + self.latency;
        let served = self.cmd.serve(arrival, bytes as f64 / self.net_bw);
        self.queue_wait += served.queueing_delay(arrival);
        let done = served.end;
        self.store.insert(key.to_string(), (slab, done));
        comm.record(CommKind::Put, bytes);
        done
    }

    /// Client-side model rebuild: torch.load + state_dict copy after a
    /// fetch. Pure client time (no Redis server involvement).
    pub fn rebuild_secs(bytes: u64) -> f64 {
        bytes as f64 / TORCH_REBUILD_BW
    }

    /// Earliest time `key` is visible.
    pub fn visible_at(&self, key: &str) -> Option<VTime> {
        self.store.get(key).map(|(_, t)| *t)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.store.contains_key(key)
    }

    /// In-DB `dst = src_acc + w * src_g` (AI script). Bytes never leave the
    /// instance; duration uses in-instance throughput over 3 slab passes.
    pub fn acc_in_db(
        &mut self,
        now: VTime,
        dst: &str,
        src_acc: &str,
        src_g: &str,
        w: f32,
        comm: &mut CommStats,
    ) -> Result<VTime> {
        let (acc, v1) = self.peek(src_acc)?;
        let (g, v2) = self.peek(src_g)?;
        let out = self.math.acc(&acc, &g, w)?;
        let bytes = 3 * out.nbytes();
        let start = now.max(v1).max(v2) + self.latency;
        let done = self.serve_script(start, bytes as f64 / self.indb_bw);
        self.store.insert(dst.to_string(), (out, done));
        comm.record(CommKind::InDb, bytes);
        Ok(done)
    }

    /// In-DB `dst = w * src` (scripted scaling — SPIRT's in-database
    /// gradient averaging: `avg = gsum / k` without leaving the instance).
    pub fn scale_in_db(
        &mut self,
        now: VTime,
        dst: &str,
        src: &str,
        w: f32,
        comm: &mut CommStats,
    ) -> Result<VTime> {
        let (src_slab, visible) = self.peek(src)?;
        let out = self.math.scale(&src_slab, w)?;
        let bytes = 2 * out.nbytes();
        let start = now.max(visible) + self.latency;
        let done = self.serve_script(start, bytes as f64 / self.indb_bw);
        self.store.insert(dst.to_string(), (out, done));
        comm.record(CommKind::InDb, bytes);
        Ok(done)
    }

    /// In-DB fused `theta = theta - lr * inv_k * gsum` (SPIRT model update).
    pub fn avg_update_in_db(
        &mut self,
        now: VTime,
        theta_key: &str,
        gsum_key: &str,
        inv_k: f32,
        lr: f32,
        comm: &mut CommStats,
    ) -> Result<VTime> {
        let (theta, v1) = self.peek(theta_key)?;
        let (gsum, v2) = self.peek(gsum_key)?;
        let out = self.math.avg_update(&theta, &gsum, inv_k, lr)?;
        let bytes = 3 * out.nbytes();
        let start = now.max(v1).max(v2);
        // TorchScript SGD is slower than a scripted buffer add (§4.2: 4.8 s
        // for a 46.8 MB model).
        let done = self.serve_script(start + self.latency, bytes as f64 / INDB_UPDATE_BW);
        self.store.insert(theta_key.to_string(), (out, done));
        comm.record(CommKind::InDb, bytes);
        Ok(done)
    }

    /// Run a scripted op on the background engine, tracking queueing delay.
    fn serve_script(&mut self, arrival: VTime, service: f64) -> VTime {
        let served = self.script_engine.serve(arrival, service);
        self.queue_wait += served.queueing_delay(arrival);
        served.end
    }

    /// Value + visibility without timeline effects (internal).
    fn peek(&self, key: &str) -> Result<(Slab, VTime)> {
        self.store
            .get(key)
            .cloned()
            .ok_or_else(|| anyhow!("redis[{}]: missing key {key}", self.name))
    }

    /// Read a stored slab without modeling a transfer (test/assert helper).
    pub fn peek_slab(&self, key: &str) -> Result<Slab> {
        Ok(self.peek(key)?.0)
    }

    pub fn delete(&mut self, key: &str) {
        self.store.remove(key);
    }

    pub fn clear(&mut self) {
        self.store.clear();
        self.cmd.reset();
        self.script_engine.reset();
        self.queue_wait = 0.0;
    }

    /// Drop command-loop and script-engine busy history that ended at or
    /// before `before` (see `sim::Resource::release` for why this cannot
    /// move any future placement). Called by `ClusterEnv` at epoch
    /// boundaries so a long sweep's interval history stays bounded;
    /// `queue_wait`/`busy_time`/request stats are untouched.
    pub fn prune_history(&mut self, before: VTime) {
        self.cmd.release(before);
        self.script_engine.release(before);
    }

    /// Seconds requests spent queued behind other clients of this instance.
    pub fn queue_wait(&self) -> f64 {
        self.queue_wait
    }

    /// Requests handled by the command loop + script engine.
    pub fn requests(&self) -> u64 {
        self.cmd.requests() + self.script_engine.requests()
    }

    /// Total service time across the command loop + script engine
    /// (utilization numerator over an experiment's duration).
    pub fn busy_time(&self) -> f64 {
        self.cmd.busy_time() + self.script_engine.busy_time()
    }

    /// Bill the hosting EC2 fleet for the experiment duration (the paper
    /// excludes this; we track it under `CostKind::Ec2Redis`). `instances`
    /// is how many instances actually ran — SPIRT hosts one per worker and
    /// the sharded store tier one per shard, not the single instance this
    /// method used to hard-code.
    pub fn bill_hosting(&self, duration: f64, instances: usize, ledger: &mut Ledger) {
        ledger.charge(
            crate::metrics::CostKind::Ec2Redis,
            super::pricing::redis_host_cost(duration, instances),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut r = Redis::new("w0");
        let mut c = CommStats::new();
        let t1 = r.set(VTime::ZERO, "g", Slab::from_vec(vec![1.0, 2.0]), &mut c);
        let (t2, s) = r.get(t1, "g", &mut c).unwrap();
        assert!(t2 > t1);
        assert_eq!(s.as_slice().unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn indb_acc_computes_real_math() {
        let mut r = Redis::new("w0");
        let mut c = CommStats::new();
        r.set(VTime::ZERO, "acc", Slab::from_vec(vec![1.0, 1.0]), &mut c);
        r.set(VTime::ZERO, "g", Slab::from_vec(vec![2.0, 4.0]), &mut c);
        r.acc_in_db(VTime::from_secs(1.0), "acc", "acc", "g", 0.5, &mut c).unwrap();
        let out = r.peek_slab("acc").unwrap();
        assert_eq!(out.as_slice().unwrap(), &[2.0, 3.0]);
        assert!(c.bytes(CommKind::InDb) > 0);
    }

    #[test]
    fn indb_avg_update_applies_fused_step() {
        let mut r = Redis::new("w0");
        let mut c = CommStats::new();
        r.set(VTime::ZERO, "theta", Slab::from_vec(vec![1.0]), &mut c);
        r.set(VTime::ZERO, "gsum", Slab::from_vec(vec![4.0]), &mut c);
        r.avg_update_in_db(VTime::from_secs(1.0), "theta", "gsum", 0.25, 0.1, &mut c)
            .unwrap();
        let theta = r.peek_slab("theta").unwrap();
        assert!((theta.as_slice().unwrap()[0] - 0.9).abs() < 1e-6);
    }

    #[test]
    fn indb_is_faster_than_fetch_update_store() {
        // The §4.2 contrast: the naive path round-trips tensors through a
        // Python client (tensorget → numpy → tensorset); the in-DB path
        // runs one scripted op on identically sized slabs.
        let n = 2_000_000; // 8 MB
        let mut c = CommStats::new();

        let mut naive = Redis::new("naive");
        naive.set(VTime::ZERO, "acc", Slab::virtual_of(n), &mut c);
        naive.set(VTime::ZERO, "g", Slab::virtual_of(n), &mut c);
        let t0 = VTime::from_secs(1.0);
        let (t1, _) = naive.get_tensor_client(t0, "acc", &mut c).unwrap();
        let (t2, _) = naive.get_tensor_client(t1, "g", &mut c).unwrap();
        let t_naive = naive.set_tensor_client(t2, "acc", Slab::virtual_of(n), &mut c) - t0;

        // In-DB: one scripted op.
        let mut indb = Redis::new("indb");
        indb.set(VTime::ZERO, "acc", Slab::virtual_of(n), &mut c);
        indb.set(VTime::ZERO, "g", Slab::virtual_of(n), &mut c);
        let t_indb =
            indb.acc_in_db(t0, "acc", "acc", "g", 1.0, &mut c).unwrap() - t0;

        assert!(
            t_indb < t_naive * 0.75,
            "in-DB {t_indb:.3}s should beat naive {t_naive:.3}s"
        );
    }

    #[test]
    fn paper_4_2_averaging_times_reproduce() {
        // ResNet-18 (46.8 MB), 24 minibatch accumulations per epoch.
        let n = 11_700_000;
        let mut c = CommStats::new();

        // Naive: each stateless function fetches acc + grad, stores acc.
        let mut naive = Redis::new("naive");
        naive.set(VTime::ZERO, "acc", Slab::virtual_of(n), &mut c);
        naive.set(VTime::ZERO, "g", Slab::virtual_of(n), &mut c);
        let mut t = VTime::from_secs(0.0);
        let start = t;
        for _ in 0..24 {
            let (t1, _) = naive.get_tensor_client(t, "acc", &mut c).unwrap();
            let (t2, _) = naive.get_tensor_client(t1, "g", &mut c).unwrap();
            t = naive.set_tensor_client(t2, "acc", Slab::virtual_of(n), &mut c);
        }
        let naive_secs = t - start;
        assert!((naive_secs - 67.32).abs() / 67.32 < 0.05, "naive {naive_secs:.1}s vs 67.32");

        // In-DB: 24 scripted accumulations.
        let mut indb = Redis::new("indb");
        indb.set(VTime::ZERO, "gsum", Slab::virtual_of(n), &mut c);
        indb.set(VTime::ZERO, "g", Slab::virtual_of(n), &mut c);
        let mut t = VTime::from_secs(0.0);
        let start = t;
        for _ in 0..24 {
            t = indb.acc_in_db(t, "gsum", "gsum", "g", 1.0, &mut c).unwrap();
        }
        let indb_secs = t - start;
        assert!((indb_secs - 37.41).abs() / 37.41 < 0.05, "in-DB {indb_secs:.1}s vs 37.41");
    }

    #[test]
    fn paper_4_2_update_times_reproduce() {
        // ResNet-18 model update: naive (fetch theta+gsum, rebuild
        // state_dict, store) vs in-DB fused TorchScript SGD.
        let n = 11_700_000;
        let bytes = 4 * n as u64;
        let mut c = CommStats::new();

        let mut r = Redis::new("upd");
        r.set(VTime::ZERO, "theta", Slab::virtual_of(n), &mut c);
        r.set(VTime::ZERO, "gsum", Slab::virtual_of(n), &mut c);

        let t0 = VTime::from_secs(0.0);
        let (t1, _) = r.get_tensor_client(t0, "theta", &mut c).unwrap();
        let (t2, _) = r.get_tensor_client(t1, "gsum", &mut c).unwrap();
        let t3 = t2 + Redis::rebuild_secs(bytes);
        let t_naive = r.set_tensor_client(t3, "theta", Slab::virtual_of(n), &mut c) - t0;
        assert!((t_naive - 27.5).abs() / 27.5 < 0.10, "naive update {t_naive:.1}s vs 27.5");

        let t_indb = r
            .avg_update_in_db(VTime::from_secs(100.0), "theta", "gsum", 1.0, 0.1, &mut c)
            .unwrap()
            - VTime::from_secs(100.0);
        assert!((t_indb - 4.8).abs() / 4.8 < 0.10, "in-DB update {t_indb:.2}s vs 4.8");
    }

    #[test]
    fn single_threaded_commands_serialize() {
        let mut r = Redis::new("w0");
        let mut c = CommStats::new();
        let big = Slab::virtual_of(30_000_000); // 120 MB -> 0.4 s at 300 MB/s
        let t_a = r.set(VTime::ZERO, "a", big.clone(), &mut c);
        let t_b = r.set(VTime::ZERO, "b", big, &mut c);
        assert!(t_b.secs() > t_a.secs() + 0.3, "second client must queue");
    }

    #[test]
    fn missing_keys_error() {
        let mut r = Redis::new("w0");
        let mut c = CommStats::new();
        assert!(r.get(VTime::ZERO, "x", &mut c).is_err());
        assert!(r.acc_in_db(VTime::ZERO, "d", "a", "b", 1.0, &mut c).is_err());
    }

    #[test]
    fn visibility_wait_is_not_comm_time() {
        // A reader arriving long before the producer's write lands used to
        // book the whole stall as comm_time; the stall now accrues to
        // visibility_wait and comm_time keeps only the transfer span.
        let mut r = Redis::new("w0");
        let mut c = CommStats::new();
        let visible = r.set(VTime::ZERO, "g", Slab::virtual_of(30_000_000), &mut c);
        assert!(visible.secs() > 0.3, "120 MB at 300 MB/s");
        let put_time = c.comm_time;

        let (done, _) = r.get(VTime::ZERO, "g", &mut c).unwrap();
        let get_span = done.secs(); // reader blocked from t=0 to done
        let wait = c.visibility_wait;
        assert!((wait - visible.secs()).abs() < 1e-9, "stall == producer visibility");
        let get_comm = c.comm_time - put_time;
        assert!((get_comm + wait - get_span).abs() < 1e-9, "split tiles the span");
        assert!(get_comm < get_span, "transfer share strictly under the stall-y span");

        // A reader arriving after visibility pays no visibility wait.
        let (_, _) = r.get(VTime::from_secs(100.0), "g", &mut c).unwrap();
        assert_eq!(c.visibility_wait, wait, "late reader adds no stall");
    }

    #[test]
    fn visibility_split_leaves_timeline_untouched() {
        // The accounting split is bookkeeping only: completion times must be
        // what they always were (now.max(visible) + latency + wire time).
        let mut r = Redis::new("w0");
        let mut c = CommStats::new();
        let visible = r.set(VTime::ZERO, "g", Slab::virtual_of(1_000_000), &mut c);
        let (done, _) = r.get(VTime::ZERO, "g", &mut c).unwrap();
        let expected = visible.secs() + REDIS_LATENCY + 4_000_000.0 / REDIS_BW;
        assert!((done.secs() - expected).abs() < 1e-9, "{done:?} vs {expected}");
    }

    #[test]
    fn replicate_set_occupies_replica_without_blocking_client() {
        let mut r = Redis::new("replica");
        let mut c = CommStats::new();
        let before = c.comm_time;
        let vis = r.replicate_set(VTime::from_secs(1.0), "k", Slab::virtual_of(1_000_000), &mut c);
        assert!(vis.secs() > 1.0, "replica copy lands after the primary ack");
        assert_eq!(c.comm_time, before, "async replication never blocks the client");
        assert_eq!(c.ops(CommKind::Put), 1, "replica write is a counted Put");
        assert_eq!(r.visible_at("k"), Some(vis));
    }

    #[test]
    fn queue_wait_tracks_contention() {
        let mut r = Redis::new("w0");
        let mut c = CommStats::new();
        let big = Slab::virtual_of(30_000_000); // 0.4 s of service each
        r.set(VTime::ZERO, "a", big.clone(), &mut c);
        assert_eq!(r.queue_wait(), 0.0, "uncontended request never queues");
        r.set(VTime::ZERO, "b", big, &mut c);
        assert!(r.queue_wait() > 0.3, "second concurrent client queues");
        assert_eq!(r.requests(), 2);
        assert!(r.busy_time() > 0.7);
    }

    #[test]
    fn hosting_bill_scales_with_instances() {
        let mut one = Ledger::new();
        let mut four = Ledger::new();
        let r = Redis::new("w0");
        r.bill_hosting(3600.0, 1, &mut one);
        r.bill_hosting(3600.0, 4, &mut four);
        let kind = crate::metrics::CostKind::Ec2Redis;
        assert!((four.get(kind) - 4.0 * one.get(kind)).abs() < 1e-12);
        assert_eq!(one.total_paper(), 0.0, "hosting stays outside the paper total");
    }
}
