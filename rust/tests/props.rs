//! Property-based tests (hand-rolled harness; proptest is unavailable in
//! the offline vendor set). Each property runs against a seeded sweep of
//! randomized cases — failures print the offending seed for replay.

use slsgpu::cloud::pricing;
use slsgpu::metrics::CommStats;
use slsgpu::sim::{Resource, VTime};
use slsgpu::tensor::robust::{clipped_mean, krum, trimmed_mean};
use slsgpu::tensor::{ChunkPlan, SignificanceFilter, Slab};
use slsgpu::util::json::Json;
use slsgpu::util::rng::Rng;

const CASES: u64 = 200;

#[test]
fn prop_chunk_split_concat_roundtrip() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let k = 1 + rng.below(16) as usize;
        let n = k + rng.below(10_000) as usize;
        let data: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let plan = ChunkPlan::new(n, k).unwrap();
        let chunks = plan.split(&Slab::from_vec(data.clone())).unwrap();
        // chunks partition exactly
        let total: usize = chunks.iter().map(|c| c.len()).sum();
        assert_eq!(total, n, "seed {seed}");
        // lengths differ by at most 1
        let lens: Vec<usize> = chunks.iter().map(|c| c.len()).collect();
        let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
        assert!(mx - mn <= 1, "seed {seed}: {lens:?}");
        // roundtrip is exact
        let back = plan.concat(&chunks).unwrap();
        assert_eq!(back.as_slice().unwrap(), data.as_slice(), "seed {seed}");
    }
}

#[test]
fn prop_resource_no_overlap_and_causality() {
    for seed in 0..CASES {
        let mut rng = Rng::new(1000 + seed);
        let servers = 1 + rng.below(4) as usize;
        let mut r = Resource::new("p", servers);
        let mut served = Vec::new();
        for _ in 0..50 {
            let arrival = VTime::from_secs(rng.range_f64(0.0, 100.0));
            let service = rng.range_f64(0.01, 5.0);
            let s = r.serve(arrival, service);
            // causality: service starts no earlier than arrival
            assert!(s.start >= arrival, "seed {seed}");
            assert!((s.end - s.start - service).abs() < 1e-9, "seed {seed}");
            served.push(s);
        }
        // capacity: at no point are more than `servers` requests in service
        let mut events: Vec<(f64, i32)> = Vec::new();
        for s in &served {
            events.push((s.start.secs(), 1));
            events.push((s.end.secs(), -1));
        }
        events.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut active = 0;
        for (_, delta) in events {
            active += delta;
            assert!(active <= servers as i32, "seed {seed}: capacity exceeded");
        }
    }
}

#[test]
fn prop_resource_backfill_is_issue_order_independent() {
    // The module doc's promise: results must not depend on the (arbitrary)
    // order in which simulation code issues requests for concurrent
    // workers. With gap-aware backfill that holds whenever the competing
    // requests are exchangeable — equal service times, arrivals on a
    // common grid (the shape concurrent same-payload protocol rounds
    // produce): the multiset of served (start, end) intervals is invariant
    // under any permutation of the issue order.
    for seed in 0..CASES {
        let mut rng = Rng::new(7000 + seed);
        let servers = 1 + rng.below(4) as usize;
        let n = 5 + rng.below(36) as usize;
        let requests: Vec<f64> = (0..n).map(|_| rng.below(20) as f64).collect();

        let schedule = |order: &[usize]| -> Vec<(u64, u64)> {
            let mut r = Resource::new("p", servers);
            let mut served: Vec<(u64, u64)> = order
                .iter()
                .map(|&i| {
                    let s = r.serve(VTime::from_secs(requests[i]), 1.0);
                    (s.start.secs().to_bits(), s.end.secs().to_bits())
                })
                .collect();
            served.sort_unstable();
            served
        };

        let base_order: Vec<usize> = (0..n).collect();
        let mut permuted = base_order.clone();
        rng.shuffle(&mut permuted);
        assert_eq!(
            schedule(&base_order),
            schedule(&permuted),
            "seed {seed}: schedule depends on issue order (servers {servers}, n {n})"
        );
    }
}

#[test]
fn prop_resource_backfill_heterogeneous_durations_keep_invariants() {
    // The heterogeneous-duration regime: mixed service times break the
    // exchangeability argument above, so the interval *multiset* is allowed
    // to move under permutation (see the pinned counterexample below). What
    // must survive any issue order is everything the cost model consumes:
    // causality, exact per-request service length, server capacity, and
    // total busy mass.
    for seed in 0..CASES {
        let mut rng = Rng::new(8000 + seed);
        let servers = 1 + rng.below(4) as usize;
        let n = 5 + rng.below(36) as usize;
        let requests: Vec<(f64, f64)> = (0..n)
            .map(|_| (rng.below(20) as f64, 0.25 + rng.below(16) as f64 * 0.5))
            .collect();

        let mut order: Vec<usize> = (0..n).collect();
        for pass in 0..2 {
            if pass == 1 {
                rng.shuffle(&mut order);
            }
            let mut r = Resource::new("p", servers);
            let mut busy_mass = 0.0;
            let mut events: Vec<(f64, i32)> = Vec::new();
            for &i in &order {
                let (arrival, service) = requests[i];
                let s = r.serve(VTime::from_secs(arrival), service);
                assert!(s.start.secs() >= arrival, "seed {seed}: time travel");
                assert!(
                    (s.end - s.start - service).abs() < 1e-9,
                    "seed {seed}: service stretched"
                );
                busy_mass += s.end - s.start;
                events.push((s.start.secs(), 1));
                events.push((s.end.secs(), -1));
            }
            let expected_mass: f64 = requests.iter().map(|(_, d)| d).sum();
            assert!((busy_mass - expected_mass).abs() < 1e-6, "seed {seed}: mass drift");
            events.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut active = 0;
            for (_, delta) in events {
                active += delta;
                assert!(active <= servers as i32, "seed {seed}: capacity exceeded");
            }
        }
    }
}

#[test]
fn resource_backfill_heterogeneous_counterexample_is_order_dependent() {
    // Regression pin for the docs' "order independence holds for
    // exchangeable requests only" caveat: with mixed durations, greedy
    // gap-aware backfill IS issue-order dependent. One server; a long job
    // issued first occupies [0,10) and pushes the short ones behind it,
    // while issuing the short ones first leaves the long job starting at 2.
    // If this test ever fails, the scheduler's placement rule changed and
    // both the module doc and `prop_resource_backfill_is_issue_order_independent`
    // need re-deriving.
    let schedule = |reqs: &[(f64, f64)]| -> Vec<(u64, u64)> {
        let mut r = Resource::new("p", 1);
        let mut served: Vec<(u64, u64)> = reqs
            .iter()
            .map(|&(arrival, service)| {
                let s = r.serve(VTime::from_secs(arrival), service);
                (s.start.secs().to_bits(), s.end.secs().to_bits())
            })
            .collect();
        served.sort_unstable();
        served
    };
    let long_first = schedule(&[(0.0, 10.0), (0.0, 1.0), (1.0, 1.0)]);
    let short_first = schedule(&[(0.0, 1.0), (1.0, 1.0), (0.0, 10.0)]);
    assert_ne!(
        long_first, short_first,
        "greedy backfill became order-independent for heterogeneous durations?"
    );
    // The exact placements, pinned: long-first serializes everything behind
    // the long job; short-first backfills the long job after the shorts.
    let b = |x: f64| x.to_bits();
    assert_eq!(long_first, vec![(b(0.0), b(10.0)), (b(10.0), b(11.0)), (b(11.0), b(12.0))]);
    assert_eq!(short_first, vec![(b(0.0), b(1.0)), (b(1.0), b(2.0)), (b(2.0), b(12.0))]);
}

#[test]
fn prop_slab_mean_bounded_by_extremes() {
    for seed in 0..CASES {
        let mut rng = Rng::new(2000 + seed);
        let n = 1 + rng.below(500) as usize;
        let k = 1 + rng.below(6) as usize;
        let slabs: Vec<Slab> = (0..k)
            .map(|_| Slab::from_vec((0..n).map(|_| rng.normal_f32(0.0, 3.0)).collect()))
            .collect();
        let mean = Slab::mean(&slabs).unwrap();
        let m = mean.as_slice().unwrap();
        for i in 0..n {
            let vals: Vec<f32> = slabs.iter().map(|s| s.as_slice().unwrap()[i]).collect();
            let lo = vals.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert!(
                m[i] >= lo - 1e-4 && m[i] <= hi + 1e-4,
                "seed {seed}: mean outside hull at {i}"
            );
        }
    }
}

/// Random slab population for the robust-aggregation properties: `honest`
/// vectors clustered around a common direction plus `byzantine` arbitrary
/// outliers, in a deterministic interleaved order.
fn robust_population(rng: &mut Rng, n_honest: usize, n_byz: usize, dim: usize) -> Vec<Slab> {
    let center: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut slabs = Vec::with_capacity(n_honest + n_byz);
    for _ in 0..n_honest {
        slabs.push(Slab::from_vec(
            center.iter().map(|c| c + rng.normal_f32(0.0, 0.05)).collect(),
        ));
    }
    for _ in 0..n_byz {
        slabs.push(Slab::from_vec(
            (0..dim).map(|_| rng.normal_f32(0.0, 50.0)).collect(),
        ));
    }
    // Interleave deterministically so Byzantine inputs are not always last.
    rng.shuffle(&mut slabs);
    slabs
}

#[test]
fn prop_krum_and_trimmed_mean_are_permutation_invariant() {
    // Both rules are functions of the input *multiset*: permuting the slab
    // order must not change a single output bit. (Krum's index tie-break
    // only matters for exactly-tied scores, which continuous random data
    // does not produce.)
    for seed in 0..CASES {
        let mut rng = Rng::new(9000 + seed);
        let dim = 1 + rng.below(40) as usize;
        let f = 1 + rng.below(2) as usize; // 1..=2
        let n = (f + 3) + rng.below(6) as usize;
        let slabs = robust_population(&mut rng, n - f, f, dim);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let permuted: Vec<Slab> = order.iter().map(|&i| slabs[i].clone()).collect();

        let k1 = krum(&slabs, f).unwrap();
        let k2 = krum(&permuted, f).unwrap();
        assert_eq!(k1.as_slice().unwrap(), k2.as_slice().unwrap(), "seed {seed}: krum");

        let kk = f.min((n - 1) / 2);
        let t1 = trimmed_mean(&slabs, kk).unwrap();
        let t2 = trimmed_mean(&permuted, kk).unwrap();
        let b1: Vec<u32> = t1.as_slice().unwrap().iter().map(|x| x.to_bits()).collect();
        let b2: Vec<u32> = t2.as_slice().unwrap().iter().map(|x| x.to_bits()).collect();
        assert_eq!(b1, b2, "seed {seed}: trimmed mean");
    }
}

#[test]
fn prop_krum_matches_brute_force_reference_on_small_n() {
    // Reference implementation: score every candidate by the sum of its
    // n-f-2 smallest squared distances (full sort, f64), pick the argmin
    // with lowest-index tie-break. The kernel must select the same input.
    for seed in 0..CASES {
        let mut rng = Rng::new(10_000 + seed);
        let dim = 1 + rng.below(12) as usize;
        let f = 1 + rng.below(2) as usize;
        let n = (f + 3) + rng.below(4) as usize;
        let slabs = robust_population(&mut rng, n - f, f, dim);
        let views: Vec<&[f32]> = slabs.iter().map(|s| s.as_slice().unwrap()).collect();

        let mut best = 0usize;
        let mut best_score = f64::INFINITY;
        for i in 0..n {
            let mut dists: Vec<f64> = (0..n)
                .filter(|&j| j != i)
                .map(|j| {
                    views[i]
                        .iter()
                        .zip(views[j])
                        .map(|(a, b)| {
                            let d = (*a as f64) - (*b as f64);
                            d * d
                        })
                        .sum::<f64>()
                })
                .collect();
            dists.sort_by(f64::total_cmp);
            let score: f64 = dists[..n - f - 2].iter().sum();
            if score < best_score {
                best_score = score;
                best = i;
            }
        }
        let got = krum(&slabs, f).unwrap();
        assert_eq!(got.as_slice().unwrap(), views[best], "seed {seed}");
    }
}

#[test]
fn prop_trimmed_mean_matches_brute_force_reference_on_small_n() {
    // Reference: per coordinate, full sort, drop k from each end, f64 mean
    // over the middle in sorted order — the exact computation the kernel
    // performs, so agreement is bit-exact.
    for seed in 0..CASES {
        let mut rng = Rng::new(11_000 + seed);
        let dim = 1 + rng.below(12) as usize;
        let k = 1 + rng.below(2) as usize;
        let n = (2 * k + 1) + rng.below(5) as usize;
        let slabs = robust_population(&mut rng, n - k, k, dim);
        let views: Vec<&[f32]> = slabs.iter().map(|s| s.as_slice().unwrap()).collect();
        let m = slabs.len();
        let mut reference = Vec::with_capacity(dim);
        for j in 0..dim {
            let mut col: Vec<f64> = views.iter().map(|v| v[j] as f64).collect();
            col.sort_by(f64::total_cmp);
            let sum: f64 = col[k..m - k].iter().sum();
            reference.push((sum / (m - 2 * k) as f64) as f32);
        }
        let got = trimmed_mean(&slabs, k).unwrap();
        let gb: Vec<u32> = got.as_slice().unwrap().iter().map(|x| x.to_bits()).collect();
        let rb: Vec<u32> = reference.iter().map(|x| x.to_bits()).collect();
        assert_eq!(gb, rb, "seed {seed}");
    }
}

#[test]
fn prop_robust_rules_tolerate_f_byzantine_below_breakdown() {
    // With at most f Byzantine inputs and enough honest ones, Krum must
    // return an honest input verbatim, and every trimmed-mean coordinate
    // must stay inside the honest value hull (the Byzantine values are
    // either trimmed or bracketed by honest extremes).
    for seed in 0..CASES {
        let mut rng = Rng::new(12_000 + seed);
        let dim = 1 + rng.below(24) as usize;
        let f = 1 + rng.below(3) as usize; // 1..=3
        let n_honest = (2 * f + 3) + rng.below(4) as usize; // n >= 2f + 3
        let center: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let honest: Vec<Vec<f32>> = (0..n_honest)
            .map(|_| center.iter().map(|c| c + rng.normal_f32(0.0, 0.02)).collect())
            .collect();
        let byz: Vec<Vec<f32>> = (0..f)
            .map(|_| (0..dim).map(|_| rng.normal_f32(0.0, 100.0)).collect())
            .collect();
        let mut slabs: Vec<Slab> = honest
            .iter()
            .chain(byz.iter())
            .map(|v| Slab::from_vec(v.clone()))
            .collect();
        rng.shuffle(&mut slabs);

        let selected = krum(&slabs, f).unwrap();
        let sv = selected.as_slice().unwrap();
        assert!(
            honest.iter().any(|h| h.as_slice() == sv),
            "seed {seed}: krum returned a non-honest vector"
        );

        let trimmed = trimmed_mean(&slabs, f).unwrap();
        let tv = trimmed.as_slice().unwrap();
        for j in 0..dim {
            let lo = honest.iter().map(|h| h[j]).fold(f32::INFINITY, f32::min);
            let hi = honest.iter().map(|h| h[j]).fold(f32::NEG_INFINITY, f32::max);
            assert!(
                tv[j] >= lo - 1e-4 && tv[j] <= hi + 1e-4,
                "seed {seed}: trimmed mean left the honest hull at {j}"
            );
        }
    }
}

#[test]
fn clipped_mean_norm_blindness_counterexample_pinned() {
    // The breakdown contrast that motivates Krum/trimmed-mean: two
    // colluders submit the *negated* honest direction at honest magnitude.
    // Norm clipping cannot see them (no norm exceeds the median), so the
    // clipped mean collapses toward zero; Krum and the trimmed mean both
    // recover the honest direction. If this pin ever breaks, the
    // aggregator's breakdown-point table in DESIGN.md §8 needs re-deriving.
    let xs = [
        Slab::from_vec(vec![1.0, 0.0]),
        Slab::from_vec(vec![1.02, 0.01]),
        Slab::from_vec(vec![0.98, -0.01]),
        Slab::from_vec(vec![-1.0, 0.0]),
        Slab::from_vec(vec![-0.97, 0.02]),
    ];
    let c = clipped_mean(&xs, 1.0).unwrap();
    assert!(
        c.as_slice().unwrap()[0] < 0.25,
        "clipped mean should be fooled, got {}",
        c.as_slice().unwrap()[0]
    );
    let k = krum(&xs, 2).unwrap();
    assert!(k.as_slice().unwrap()[0] > 0.9, "krum recovers");
    let t = trimmed_mean(&xs, 2).unwrap();
    assert!(t.as_slice().unwrap()[0] > 0.9, "trimmed mean recovers");
}

#[test]
fn prop_significance_filter_conserves_gradient_mass() {
    // Everything offered is either published or still pending: no signal
    // is lost, only delayed (the MLLess invariant).
    for seed in 0..CASES {
        let mut rng = Rng::new(3000 + seed);
        let n = 1 + rng.below(64) as usize;
        let threshold = rng.range_f64(0.0, 2.0);
        let mut filter = SignificanceFilter::new(threshold);
        let theta = Slab::from_vec((0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect());
        let mut offered_sum = vec![0f64; n];
        let mut published_sum = vec![0f64; n];
        for _ in 0..20 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
            for (a, b) in offered_sum.iter_mut().zip(&g) {
                *a += *b as f64;
            }
            if let Some(update) = filter.offer(Slab::from_vec(g), &theta) {
                for (a, b) in published_sum.iter_mut().zip(update.as_slice().unwrap()) {
                    *a += *b as f64;
                }
            }
        }
        if let Some(pending) = filter.drain_pending() {
            for (a, b) in published_sum.iter_mut().zip(pending.as_slice().unwrap()) {
                *a += *b as f64;
            }
        }
        for i in 0..n {
            assert!(
                (offered_sum[i] - published_sum[i]).abs() < 1e-3,
                "seed {seed}: gradient mass lost at {i}"
            );
        }
    }
}

#[test]
fn prop_lambda_billing_monotone_in_time_and_memory() {
    for seed in 0..CASES {
        let mut rng = Rng::new(4000 + seed);
        let t = rng.range_f64(0.1, 100.0);
        let mb = rng.range_f64(128.0, 10_240.0);
        let dt = rng.range_f64(0.01, 10.0);
        let dmb = rng.range_f64(1.0, 1024.0);
        let base = pricing::lambda_cost(t, mb);
        assert!(pricing::lambda_cost(t + dt, mb) > base, "seed {seed}");
        assert!(pricing::lambda_cost(t, mb + dmb) > base, "seed {seed}");
        assert!(base > 0.0);
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    for seed in 0..CASES {
        let mut rng = Rng::new(5000 + seed);
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e} in {text}"));
        assert_eq!(v, back, "seed {seed}");
    }
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.bernoulli(0.5)),
        2 => Json::Num((rng.below(2_000_000) as f64 - 1_000_000.0) / 16.0),
        3 => {
            let len = rng.below(12) as usize;
            Json::Str((0..len).map(|_| (b'a' + rng.below(26) as u8) as char).collect())
        }
        4 => {
            let len = rng.below(4) as usize;
            Json::Arr((0..len).map(|_| random_json(rng, depth - 1)).collect())
        }
        _ => {
            let len = rng.below(4) as usize;
            Json::Obj(
                (0..len)
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            )
        }
    }
}

#[test]
fn prop_redis_visibility_ordering() {
    // A get issued at any time always returns data at/after the set's
    // completion time (no time-travel reads).
    for seed in 0..CASES {
        let mut rng = Rng::new(6000 + seed);
        let mut redis = slsgpu::cloud::Redis::new("p");
        let mut comm = CommStats::new();
        let set_at = VTime::from_secs(rng.range_f64(0.0, 10.0));
        let n = 1 + rng.below(100_000) as usize;
        let visible = redis.set(set_at, "k", Slab::virtual_of(n), &mut comm);
        let get_at = VTime::from_secs(rng.range_f64(0.0, 20.0));
        let (done, slab) = redis.get(get_at, "k", &mut comm).unwrap();
        assert!(done >= visible, "seed {seed}");
        assert!(done >= get_at, "seed {seed}");
        assert_eq!(slab.len(), n);
    }
}
