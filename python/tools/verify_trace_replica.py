#!/usr/bin/env python3
"""Independent Python replica of the ``trace::`` math, run against the same
hand-computed cases the Rust unit tests assert.

This container carries no Rust toolchain, so the trace subsystem's three
pieces of non-trivial math are re-derived here from the module docs and
checked against the expected values that ``rust/src/trace/*.rs`` unit tests
hard-code. A PASS from this script means the *specification* (predecessor
rule, nearest-rank percentiles, Chrome JSON shape and number formatting)
is internally consistent and matches the hand computations; the Rust tests
re-prove the same numbers on the real implementation at first toolchain
contact.

* ``predecessor`` / ``epoch_path``  ↔ ``trace::critical_path``
* ``percentile``                    ↔ ``metrics::Histogram::percentile``
* ``chrome_doc`` / ``jnum``         ↔ ``trace::chrome`` + ``util::json``

Run from anywhere: ``python3 python/tools/verify_trace_replica.py``
"""

import math

SUPERVISOR = (1 << 64) - 1  # faults::SUPERVISOR = usize::MAX


# -- events ------------------------------------------------------------------

def ev(worker, t0, t1, kind, bytes_=0, cost=0.0, epoch=1, round_=0,
       dep=None, prev=None, instant=False):
    return {
        "worker": worker, "t0": t0, "t1": t1, "kind": kind, "bytes": bytes_,
        "cost": cost, "epoch": epoch, "round": round_, "dep": dep,
        "prev": prev, "instant": instant,
    }


class Collector:
    """Mirror of ``TraceCollector``'s edge bookkeeping: per-worker prev
    chain and last-writer-per-key dep resolution."""

    def __init__(self):
        self.events = []
        self.writers = {}
        self.last_by_worker = {}
        self.epoch = 0

    def begin_epoch(self, epoch):
        self.epoch = epoch

    def span(self, worker, t0, t1, kind, bytes_=0, cost=0.0, dep=None):
        idx = len(self.events)
        prev = self.last_by_worker.get(worker)
        self.last_by_worker[worker] = idx
        self.events.append(
            ev(worker, t0, t1, kind, bytes_, cost, self.epoch, dep=dep, prev=prev))
        return idx

    def instant(self, worker, t, kind):
        idx = len(self.events)
        prev = self.last_by_worker.get(worker)
        self.last_by_worker[worker] = idx
        self.events.append(
            ev(worker, t, t, kind, epoch=self.epoch, prev=prev, instant=True))
        return idx

    def note_write(self, key, idx):
        self.writers[key] = idx

    def writer_of(self, key):
        return self.writers.get(key)


# -- critical path (trace::critical_path) ------------------------------------

def predecessor(events, e):
    """Edge rule: dep iff it actually gated (dep.t1 > e.t0), else walk the
    prev chain back past events that finished after e started."""
    if e["dep"] is not None and events[e["dep"]]["t1"] > e["t0"]:
        return e["dep"]
    p = e["prev"]
    while p is not None:
        pe = events[p]
        if pe["t1"] <= e["t0"]:
            return p
        p = pe["prev"]
    return None


def epoch_path(events, epoch):
    in_epoch = [(i, e) for i, e in enumerate(events) if e["epoch"] == epoch]
    if not in_epoch:
        return None
    terminal = max(in_epoch, key=lambda ie: (ie[1]["t1"], ie[0]))[0]
    steps, per_kind = [], {}
    cur = terminal
    while True:
        e = events[cur]
        pred = predecessor(events, e)
        if pred is not None:
            self_secs = max(e["t1"] - max(events[pred]["t1"], e["t0"]), 0.0)
        else:
            self_secs = e["t1"] - e["t0"]
        steps.append({"idx": cur, "worker": e["worker"], "kind": e["kind"],
                      "t0": e["t0"], "t1": e["t1"], "self_secs": self_secs})
        per_kind[e["kind"]] = per_kind.get(e["kind"], 0.0) + self_secs
        if pred is None:
            break
        cur = pred
    kind_secs = sorted(per_kind.items(), key=lambda kv: (-kv[1], kv[0]))
    return {"epoch": epoch, "bound_worker": steps[0]["worker"],
            "start": steps[-1]["t0"], "end": steps[0]["t1"],
            "steps": steps, "kind_secs": kind_secs}


def describe(path, max_steps):
    def label(w):
        return "sup" if w == SUPERVISOR else f"w{w}"
    parts = [f"{label(s['worker'])}:{s['kind']}" for s in path["steps"][:max_steps]]
    if len(path["steps"]) > max_steps:
        parts.append(f"… {len(path['steps']) - max_steps} more")
    return " <- ".join(parts)


def dominant(path, k):
    return " · ".join(f"{kind} {secs:.2f}s" for kind, secs in path["kind_secs"][:k])


# -- nearest-rank percentiles (metrics::Histogram) ----------------------------

def percentile(samples, p):
    if not samples:
        return 0.0
    s = sorted(samples)
    rank = math.ceil(p / 100.0 * len(s))
    return s[min(max(rank, 1), len(s)) - 1]


# -- Chrome export (trace::chrome via util::json) -----------------------------

def jnum(n):
    """Rust Json::Num formatting: integer form when fract()==0 and |n|<1e15,
    else f64 Display (shortest round-trip == Python repr at these scales)."""
    if float(n) == int(n) and abs(n) < 1e15:
        return str(int(n))
    return repr(float(n))


def jstr(s):
    out = ['"']
    for ch in s:
        if ch == '"':
            out.append('\\"')
        elif ch == "\\":
            out.append("\\\\")
        elif ord(ch) < 0x20:
            out.append(f"\\u{ord(ch):04x}")
        else:
            out.append(ch)
    out.append('"')
    return "".join(out)


def jwrite(v):
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, (int, float)):
        return jnum(v)
    if isinstance(v, str):
        return jstr(v)
    if isinstance(v, list):
        return "[" + ",".join(jwrite(x) for x in v) + "]"
    if isinstance(v, dict):  # BTreeMap ⇒ keys sorted
        return "{" + ",".join(f"{jstr(k)}:{jwrite(x)}" for k, x in sorted(v.items())) + "}"
    raise TypeError(v)


def tid_of(worker, workers):
    return workers if worker == SUPERVISOR else worker


def chrome_doc(runs):
    events = []
    for pid, run in enumerate(runs):
        events.append({"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
                       "args": {"name": run["label"]}})
        tids = {}
        for e in run["events"]:
            tid = tid_of(e["worker"], run["workers"])
            name = "supervisor" if e["worker"] == SUPERVISOR else f"worker {e['worker']}"
            tids.setdefault(tid, name)
        for tid in sorted(tids):
            events.append({"ph": "M", "pid": pid, "tid": tid, "name": "thread_name",
                           "args": {"name": tids[tid]}})
        for e in run["events"]:
            out = {"pid": pid, "tid": tid_of(e["worker"], run["workers"]),
                   "ts": e["t0"] * 1e6, "name": e["kind"], "cat": "trace",
                   "args": {"bytes": e["bytes"], "cost_usd": e["cost"],
                            "epoch": e["epoch"], "round": e["round"]}}
            if e["instant"]:
                out["ph"], out["s"] = "i", "t"
            else:
                out["ph"], out["dur"] = "X", (e["t1"] - e["t0"]) * 1e6
            events.append(out)
    return {"displayTimeUnit": "ms", "traceEvents": events}


# -- checks -------------------------------------------------------------------

def check(name, cond, detail=""):
    status = "ok" if cond else "FAIL"
    print(f"  [{status}] {name}{(' — ' + detail) if detail and not cond else ''}")
    return cond


def main():
    ok = True

    print("critical path — hand DAG (mirrors walks_the_gating_chain_not_program_order):")
    c = Collector()
    c.begin_epoch(1)
    p0 = c.span(0, 0.0, 2.0, "put", 8)
    c.note_write("s3/g0", p0)
    c.span(1, 0.0, 5.0, "compute")
    p1 = c.span(1, 5.0, 6.0, "put", 8)
    c.note_write("s3/g1", p1)
    c.span(0, 2.0, 6.5, "get", 8, dep=c.writer_of("s3/g1"))
    p = epoch_path(c.events, 1)
    chain = [(s["idx"], s["kind"]) for s in p["steps"]]
    ok &= check("gating chain, not program order",
                chain == [(3, "get"), (2, "put"), (1, "compute")], str(chain))
    ok &= check("bound worker 0", p["bound_worker"] == 0)
    selfs = [s["self_secs"] for s in p["steps"]]
    ok &= check("self-times 0.5/1.0/5.0",
                all(abs(a - b) < 1e-12 for a, b in zip(selfs, [0.5, 1.0, 5.0])), str(selfs))
    ok &= check("self-times tile the span",
                abs(sum(selfs) - (p["end"] - p["start"])) < 1e-12)
    ok &= check("dominant kind is compute", p["kind_secs"][0] == ("compute", 5.0))
    ok &= check("describe format", describe(p, 8) == "w0:get <- w1:put <- w1:compute",
                describe(p, 8))
    ok &= check("dominant format", dominant(p, 2) == "compute 5.00s · put 1.00s",
                dominant(p, 2))

    print("predecessor rule (mirrors skips_satisfied_deps_and_overlapping_predecessors):")
    c = Collector()
    c.begin_epoch(1)
    w = c.span(1, 0.0, 1.0, "put", 8)
    c.note_write("s3/k", w)
    c.span(0, 0.0, 4.0, "compute")  # parallel branch
    c.span(0, 0.0, 2.0, "compute")  # feeds the get
    c.span(0, 2.0, 3.0, "get", 8, dep=c.writer_of("s3/k"))
    ok &= check("satisfied dep ignored, overlapping prev skipped",
                predecessor(c.events, c.events[3]) == 2,
                str(predecessor(c.events, c.events[3])))

    print("nearest-rank percentiles (mirrors nearest_rank_percentiles_per_kind):")
    lat = [float(i) for i in range(1, 101)]  # 1..100 ms
    ok &= check("p50 = 50", percentile(lat, 50.0) == 50.0)
    ok &= check("p95 = 95", percentile(lat, 95.0) == 95.0)
    ok &= check("p99 = 99", percentile(lat, 99.0) == 99.0)
    ok &= check("singleton p99", percentile([7.5], 99.0) == 7.5)
    ok &= check("empty -> 0", percentile([], 50.0) == 0.0)
    # rank clamp: p so small the rank floors to 0 must still read sample 1.
    ok &= check("rank clamps to [1, n]", percentile(lat, 0.0) == 1.0)

    print("Chrome export (mirrors emits_valid_deterministic_json):")
    c = Collector()
    c.begin_epoch(1)
    c.span(0, 0.5, 1.25, "put", 64, 0.001)
    c.instant(1, 2.0, "poison")
    c.span(SUPERVISOR, 0.0, 0.25, "poll")
    run = {"label": "mlless", "workers": 2, "events": c.events}
    doc = chrome_doc([run])
    rendered = jwrite(doc) + "\n"
    ok &= check("byte-stable", rendered == jwrite(chrome_doc([run])) + "\n")
    evs = doc["traceEvents"]
    ok &= check("1 process + 3 threads + 3 events", len(evs) == 7, str(len(evs)))
    span = next(e for e in evs if e.get("ph") == "X" and e["name"] == "put")
    ok &= check("ts in µs", span["ts"] == 0.5e6)
    ok &= check("dur in µs", span["dur"] == 0.75e6)
    inst = next(e for e in evs if e.get("ph") == "i")
    ok &= check("instant scope t", inst["s"] == "t" and inst["name"] == "poison")
    sup = next(e for e in evs if e.get("ph") == "M"
               and e["name"] == "thread_name" and e["args"]["name"] == "supervisor")
    ok &= check("supervisor on tid = workers", sup["tid"] == 2)
    ok &= check("integer number fast path", jnum(500000.0) == "500000")
    ok &= check("fractional numbers via shortest repr", jnum(0.001) == "0.001")
    ok &= check("keys sorted (BTreeMap order)",
                rendered.index('"displayTimeUnit"') < rendered.index('"traceEvents"'))
    two = chrome_doc([dict(run, label="a"), dict(run, label="b")])
    pids = sorted({e["pid"] for e in two["traceEvents"]})
    ok &= check("multi-run pids 0,1", pids == [0, 1])

    print("PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
