//! Repo-native invariant auditor: static analysis over this repository's
//! own sources, enforcing the contracts every bit-identical result rests
//! on.
//!
//! The auditor is self-contained (no external deps, matching the crate's
//! zero-dependency default build) and deliberately simple: a
//! comment/string-aware line [`scanner`], a catalogue of token-level
//! [`rules`], a whitelist-driven [`workspace`] model of the repo (sources,
//! Cargo.toml targets, docs tree), an [`engine`] that applies rules and
//! `audit:allow` suppressions, and a [`report`] layer that renders the
//! result through the typed `report::` model — so the audit output is as
//! deterministic as the experiment tables, and CI can compare the cargo
//! run byte-for-byte against the toolchain-less fallback
//! `python/tools/audit.py`.
//!
//! Entry points: [`audit_repo`] (from a checkout) and [`audit_workspace`]
//! (from an in-memory fixture, used by the rule tests).

pub mod engine;
pub mod report;
pub mod rules;
pub mod scanner;
pub mod workspace;

use std::path::Path;

use anyhow::Result;

pub use engine::{Allow, Audit, Finding};
pub use rules::RuleId;
pub use workspace::Workspace;

/// Audit a repo checkout rooted at `root`.
pub fn audit_repo(root: &Path) -> Result<Audit> {
    let ws = Workspace::from_disk(root)?;
    Ok(engine::run(&ws))
}

/// Audit an in-memory workspace (fixtures, tests).
pub fn audit_workspace(ws: &Workspace) -> Audit {
    engine::run(ws)
}

impl Audit {
    /// The deterministic audit report (text/JSON via `report::` renderers).
    pub fn report(&self) -> crate::report::Report {
        report::render(self)
    }
}
