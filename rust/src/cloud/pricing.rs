//! AWS pricing tables (us-east-1 on-demand, as used by the paper §3.3).
//!
//! The paper computes serverless cost as `time(s) × RAM(GB) × $0.0000166667`
//! (Lambda x86 GB-second) and GPU cost from g4dn.xlarge hourly pricing; we
//! additionally carry the request-level fees for S3/SQS/Step Functions so
//! the orchestration-cost discussion (§5) is quantified rather than assumed.

/// AWS Lambda x86: USD per GB-second.
pub const LAMBDA_USD_PER_GB_SECOND: f64 = 0.000_016_666_7;
/// AWS Lambda: USD per request.
pub const LAMBDA_USD_PER_REQUEST: f64 = 0.000_000_2;
/// EC2 g4dn.xlarge (1x NVIDIA T4, 16 GB): USD per hour, on-demand.
pub const G4DN_XLARGE_USD_PER_HOUR: f64 = 0.526;
/// EC2 r5.large hosting Redis/RedisAI (excluded by the paper's cost model).
pub const REDIS_EC2_USD_PER_HOUR: f64 = 0.126;
/// S3: USD per 1000 PUT/COPY/POST requests.
pub const S3_USD_PER_1K_PUT: f64 = 0.005;
/// S3: USD per 1000 GET requests.
pub const S3_USD_PER_1K_GET: f64 = 0.0004;
/// SQS/RabbitMQ-equivalent: USD per million messages.
pub const QUEUE_USD_PER_MILLION_MSG: f64 = 0.40;
/// Step Functions: USD per 1000 state transitions.
pub const STEPFN_USD_PER_1K_TRANSITIONS: f64 = 0.025;

/// Lambda execution cost: duration × allocated memory × GB-second rate,
/// plus the per-request fee. This is exactly the paper's §4.1 formula —
/// including its decimal MB→GB conversion (2685 MB = 2.685 GB), which we
/// match so Table 2 cost columns reproduce digit-for-digit.
pub fn lambda_cost(duration_secs: f64, allocated_mb: f64) -> f64 {
    duration_secs * (allocated_mb / 1000.0) * LAMBDA_USD_PER_GB_SECOND
        + LAMBDA_USD_PER_REQUEST
}

/// GPU instance cost for a duration.
pub fn gpu_cost(duration_secs: f64, instances: usize) -> f64 {
    duration_secs / 3600.0 * G4DN_XLARGE_USD_PER_HOUR * instances as f64
}

pub fn s3_put_cost(requests: u64) -> f64 {
    requests as f64 / 1000.0 * S3_USD_PER_1K_PUT
}

pub fn s3_get_cost(requests: u64) -> f64 {
    requests as f64 / 1000.0 * S3_USD_PER_1K_GET
}

pub fn queue_cost(messages: u64) -> f64 {
    messages as f64 / 1_000_000.0 * QUEUE_USD_PER_MILLION_MSG
}

pub fn stepfn_cost(transitions: u64) -> f64 {
    transitions as f64 / 1000.0 * STEPFN_USD_PER_1K_TRANSITIONS
}

pub fn redis_host_cost(duration_secs: f64, instances: usize) -> f64 {
    duration_secs / 3600.0 * REDIS_EC2_USD_PER_HOUR * instances as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_spirt_mobilenet() {
        // §4.1: 15.44 s at 2685 MB -> ~0.000689 USD per function.
        let c = lambda_cost(15.44, 2685.0) - LAMBDA_USD_PER_REQUEST;
        assert!((c - 0.000_689).abs() < 0.000_005, "got {c}");
    }

    #[test]
    fn paper_example_gpu_mobilenet() {
        // §4.1: 4 instances × 92 s -> ~0.0538 USD total.
        let c = gpu_cost(92.0, 4);
        assert!((c - 0.0538).abs() < 0.0005, "got {c}");
    }

    #[test]
    fn request_fees_scale_linearly() {
        assert!((s3_put_cost(2000) - 0.01).abs() < 1e-12);
        assert!((s3_get_cost(1000) - 0.0004).abs() < 1e-12);
        assert!((queue_cost(1_000_000) - 0.40).abs() < 1e-12);
        assert!((stepfn_cost(4000) - 0.1).abs() < 1e-12);
    }
}
