//! Fig. 2: AllReduce vs ScatterReduce communication time as the worker
//! count scales, for MobileNet and ResNet-50 payloads.
//!
//! Measures one synchronization round (gradients already computed) — the
//! paper's communication-time metric. The crossover the paper reports must
//! emerge: ScatterReduce wins on the large model (master bandwidth bound),
//! AllReduce wins on the small model at high worker counts (request-count
//! bound). The paper only anchors 4–16 workers; sweeps beyond that (the
//! scale-sweep regime) render an em-dash in the paper column.

use crate::cloud::FrameworkKind;
use crate::coordinator::allreduce::AllReduce;
use crate::coordinator::scatter_reduce::ScatterReduce;
use crate::coordinator::{ClusterEnv, EnvConfig};
use crate::tensor::Slab;
use crate::util::table::{Align, Table};
use crate::Result;

#[derive(Debug, Clone)]
pub struct Point {
    pub arch: String,
    pub workers: usize,
    pub allreduce_secs: f64,
    pub scatter_secs: f64,
}

/// Paper's Fig. 2 anchor values (communication seconds). Worker counts the
/// paper never measured (anything beyond 4–16) have no anchor.
pub fn paper_anchor(arch: &str, workers: usize) -> Option<(f64, f64)> {
    // (allreduce, scatter) — §4.2 text gives the 16-worker extremes.
    match (arch, workers) {
        ("resnet50", 16) => Some((21.88, 8.36)),
        ("mobilenet", 16) => Some((4.77, 6.47)),
        _ => None,
    }
}

fn comm_round(fw: FrameworkKind, arch: &str, workers: usize) -> Result<f64> {
    let mut env = ClusterEnv::new(EnvConfig::virtual_paper(fw, arch, workers)?)?;
    let grads: Vec<Slab> = (0..workers).map(|_| Slab::virtual_of(env.n_params)).collect();
    match fw {
        FrameworkKind::AllReduce => {
            AllReduce::new().sync_round(&mut env, 0, "fig2", grads)?;
        }
        FrameworkKind::ScatterReduce => {
            ScatterReduce::new().sync_round(&mut env, 0, "fig2", grads)?;
        }
        _ => anyhow::bail!("fig2 compares the LambdaML strategies"),
    }
    // Round completion: the slowest worker's clock.
    Ok(env.max_clock().secs())
}

/// Sweep worker counts for both models.
pub fn run(worker_counts: &[usize]) -> Result<Vec<Point>> {
    let mut out = Vec::new();
    for arch in ["mobilenet", "resnet50"] {
        for &w in worker_counts {
            out.push(Point {
                arch: arch.to_string(),
                workers: w,
                allreduce_secs: comm_round(FrameworkKind::AllReduce, arch, w)?,
                scatter_secs: comm_round(FrameworkKind::ScatterReduce, arch, w)?,
            });
        }
    }
    Ok(out)
}

pub fn render(points: &[Point]) -> String {
    let mut t = Table::new(&[
        "Model",
        "Workers",
        "AllReduce (s)",
        "ScatterReduce (s)",
        "Winner",
        "Paper (AR/SR)",
    ])
    .title("Fig. 2 — Communication time per synchronization round")
    .align(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Left, Align::Right]);
    let mut last_arch = String::new();
    for p in points {
        if p.arch != last_arch {
            if !last_arch.is_empty() {
                t.rule();
            }
            last_arch = p.arch.clone();
        }
        let winner = if p.allreduce_secs < p.scatter_secs { "AllReduce" } else { "ScatterReduce" };
        let paper = paper_anchor(&p.arch, p.workers)
            .map(|(a, s)| format!("{a:.2}/{s:.2}"))
            .unwrap_or_else(|| "—".into());
        t.row(vec![
            p.arch.clone(),
            p.workers.to_string(),
            format!("{:.2}", p.allreduce_secs),
            format!("{:.2}", p.scatter_secs),
            winner.to_string(),
            paper,
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossover_shapes_match_paper() {
        let points = run(&[4, 16]).unwrap();
        let find = |arch: &str, w: usize| {
            points.iter().find(|p| p.arch == arch && p.workers == w).unwrap()
        };
        // Large model at 16 workers: ScatterReduce must win decisively.
        let big = find("resnet50", 16);
        assert!(
            big.scatter_secs * 1.5 < big.allreduce_secs,
            "resnet50@16: SR {:.2}s vs AR {:.2}s",
            big.scatter_secs,
            big.allreduce_secs
        );
        // Small model at 16 workers: AllReduce must win.
        let small = find("mobilenet", 16);
        assert!(
            small.allreduce_secs < small.scatter_secs,
            "mobilenet@16: AR {:.2}s vs SR {:.2}s",
            small.allreduce_secs,
            small.scatter_secs
        );
    }

    #[test]
    fn comm_time_grows_with_workers() {
        let points = run(&[4, 8, 16]).unwrap();
        let series: Vec<f64> = points
            .iter()
            .filter(|p| p.arch == "resnet50")
            .map(|p| p.allreduce_secs)
            .collect();
        assert!(series.windows(2).all(|w| w[1] > w[0]), "{series:?}");
    }

    #[test]
    fn anchorless_worker_counts_render_an_em_dash_row() {
        // Scale-sweep worker counts have no paper anchors; the figure must
        // still run and render instead of relying on the 4–16 table.
        let points = run(&[64]).unwrap();
        assert_eq!(points.len(), 2);
        for p in &points {
            assert!(paper_anchor(&p.arch, p.workers).is_none());
            assert!(p.allreduce_secs > 0.0 && p.scatter_secs > 0.0);
        }
        let table = render(&points);
        assert!(table.contains('—'), "missing-anchor rows must render an em dash:\n{table}");
    }

    #[test]
    fn sixteen_worker_extremes_near_paper() {
        let points = run(&[16]).unwrap();
        for p in &points {
            let (ar, sr) = paper_anchor(&p.arch, 16).unwrap();
            // The shapes must hold within a loose factor (our substrate is a
            // model, not their testbed): 2x band on absolute values.
            assert!(
                p.allreduce_secs > ar / 2.0 && p.allreduce_secs < ar * 2.0,
                "{}: AR {:.2} vs paper {ar}",
                p.arch,
                p.allreduce_secs
            );
            assert!(
                p.scatter_secs > sr / 2.0 && p.scatter_secs < sr * 2.0,
                "{}: SR {:.2} vs paper {sr}",
                p.arch,
                p.scatter_secs
            );
        }
    }
}

