//! Table 4 (extension): per-architecture resilience under injected faults.
//!
//! The paper compares the five architectures on time/cost/accuracy; SPIRT's
//! companion papers (arXiv:2309.14148, arXiv:2302.13995) argue the real
//! differentiator is what happens when things break. This driver makes that
//! a table: every architecture runs the same paper-scale workload under the
//! same deterministic fault scenarios, and the per-scenario deltas against
//! the fault-free run expose the topology differences —
//!
//! * SPIRT's parallel minibatch fan-out absorbs a worker crash and its P2P
//!   sync reroutes around a dead peer (time-to-target within ~20% of
//!   fault-free);
//! * AllReduce's master waits on every gradient, so one crash stalls the
//!   whole round by more than the restart itself;
//! * ScatterReduce stalls on a late chunk owner;
//! * MLLess stalls on its supervisor (single point of coordination);
//! * the GPU fleet pays instance reboot time at always-on rates.
//!
//! The poisoning half of the table runs on real gradients via
//! [`crate::faults::poison_demo`] (accuracy is meaningless on size-only
//! slabs): naive mean vs the robust rules in [`crate::tensor::robust`].

use crate::cloud::{FrameworkKind, StoreTierConfig};
use crate::coordinator::{strategy_for, ClusterEnv, EnvConfig};
use crate::faults::{FaultPlan, poison_demo, PoisonMode};
use crate::metrics::RecoveryStats;
use crate::report::{Align, Cell as RCell, Report, Table};
use crate::train::{run_session, SessionConfig};
use crate::Result;

/// The injected fault scenarios (one column family of the table).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// No faults — the baseline every delta is computed against.
    FaultFree,
    /// Worker 1's invocation crashes mid-training (epoch 2, round 12) and
    /// is retried after a cold start.
    WorkerCrash,
    /// Worker 1 dies entering epoch 2's synchronization and restarts from
    /// a snapshot.
    SyncCrash,
    /// Worker 1 computes 4× slower for all of epoch 2.
    Straggler,
    /// Worker 1's updates are dropped for the first 6 rounds of epoch 2.
    UpdateDrop,
    /// The MLLess supervisor crashes at epoch 2, round 12 (no-op for the
    /// other architectures — they have no supervisor to lose).
    SupervisorCrash,
    /// Shard 0 of the shared store tier crashes at the top of epoch 2,
    /// losing its contents; the tier runs 2 shards at replication 2 for
    /// this scenario so reads fail over to the surviving replica. No-op for
    /// architectures that never touch the shared store.
    ShardCrash,
}

impl Scenario {
    pub const ALL: [Scenario; 7] = [
        Scenario::FaultFree,
        Scenario::WorkerCrash,
        Scenario::SyncCrash,
        Scenario::Straggler,
        Scenario::UpdateDrop,
        Scenario::SupervisorCrash,
        Scenario::ShardCrash,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Scenario::FaultFree => "fault-free",
            Scenario::WorkerCrash => "worker crash",
            Scenario::SyncCrash => "sync crash",
            Scenario::Straggler => "straggler 4x",
            Scenario::UpdateDrop => "update drop",
            Scenario::SupervisorCrash => "supervisor crash",
            Scenario::ShardCrash => "store-shard crash",
        }
    }
}

/// Experiment knobs.
#[derive(Debug, Clone)]
pub struct FaultConfig {
    pub arch: String,
    pub workers: usize,
    pub epochs: usize,
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig { arch: "mobilenet".into(), workers: 4, epochs: 3, seed: 42 }
    }
}

/// Build the deterministic fault plan for a scenario. The faulty epoch is
/// the middle of the run ("mid-training"); the faulty worker is 1 (never
/// the AllReduce master, so the master-stall effect is the topology's, not
/// the trivial "the master itself died" case).
pub fn plan_for(scenario: Scenario, cfg: &FaultConfig) -> FaultPlan {
    let epoch = (cfg.epochs / 2 + 1).min(cfg.epochs);
    let worker = 1usize.min(cfg.workers - 1);
    match scenario {
        Scenario::FaultFree => FaultPlan::none(),
        Scenario::WorkerCrash => FaultPlan::none().crash(worker, epoch, 12),
        Scenario::SyncCrash => FaultPlan::none().sync_crash(worker, epoch),
        Scenario::Straggler => FaultPlan::none().straggler(worker, epoch, 0, 4.0, Some(24)),
        Scenario::UpdateDrop => FaultPlan::none().drop_updates(worker, epoch, 0, Some(6)),
        Scenario::SupervisorCrash => FaultPlan::none().supervisor_crash(epoch, 12),
        Scenario::ShardCrash => FaultPlan::none().shard_crash(0, epoch),
    }
}

/// Store tier for a scenario: the shard-crash scenario runs a 2-shard,
/// fully replicated tier so failover (not unrecoverable data loss) is what
/// gets measured; every other scenario keeps the paper's single instance.
pub fn store_for(scenario: Scenario) -> StoreTierConfig {
    match scenario {
        Scenario::ShardCrash => StoreTierConfig::sharded(2, 2),
        _ => StoreTierConfig::single(),
    }
}

/// One (framework, scenario) measurement.
#[derive(Debug, Clone)]
pub struct Cell {
    pub framework: FrameworkKind,
    pub scenario: Scenario,
    pub vtime_secs: f64,
    pub cost_usd: f64,
    pub recovery: RecoveryStats,
}

/// The full resilience run: 5 architectures × scenarios, plus the
/// poisoning/robust-aggregation accuracy contrast.
#[derive(Debug, Clone)]
pub struct Table4 {
    pub cells: Vec<Cell>,
    pub poison: poison_demo::PoisonReport,
}

fn run_one(fw: FrameworkKind, scenario: Scenario, cfg: &FaultConfig) -> Result<Cell> {
    let mut env_cfg = EnvConfig::virtual_paper(fw, &cfg.arch, cfg.workers)?
        .with_faults(plan_for(scenario, cfg))
        .with_store(store_for(scenario));
    env_cfg.seed = cfg.seed;
    let mut env = ClusterEnv::new(env_cfg)?;
    let mut strategy = strategy_for(fw);
    let session = SessionConfig {
        max_epochs: cfg.epochs,
        target_acc: 2.0, // unreachable: run the full epoch budget
        patience: cfg.epochs + 1,
        evaluate: false,
    };
    let report = run_session(&mut env, strategy.as_mut(), &session)?;
    Ok(Cell {
        framework: fw,
        scenario,
        vtime_secs: report.total_vtime_secs,
        cost_usd: report.total_cost_usd,
        recovery: env.recovery.clone(),
    })
}

/// Run the full table.
pub fn run(cfg: &FaultConfig) -> Result<Table4> {
    let mut cells = Vec::new();
    for fw in FrameworkKind::ALL {
        for scenario in Scenario::ALL {
            cells.push(run_one(fw, scenario, cfg)?);
        }
    }
    let poison = poison_demo::run(cfg.seed, poison_demo::DEMO_WORKERS, PoisonMode::Scale(-8.0))?;
    Ok(Table4 { cells, poison })
}

/// Fault-free baseline cell for a framework.
fn baseline(cells: &[Cell], fw: FrameworkKind) -> &Cell {
    cells
        .iter()
        .find(|c| c.framework == fw && c.scenario == Scenario::FaultFree)
        .expect("fault-free baseline present")
}

/// Build the resilience report: the injected-fault table plus the
/// poisoning/robust-aggregation contrast as a second table in the same
/// section (no paper anchors — this table is the extension beyond the
/// paper; its hard bounds live in the tests below).
pub fn report(t4: &Table4, cfg: &FaultConfig) -> Report {
    let mut t = Table::new(
        "resilience",
        &[
            ("Framework", Align::Left),
            ("Scenario", Align::Left),
            ("Time (s)", Align::Right),
            ("dTime", Align::Right),
            ("Cost ($)", Align::Right),
            ("dCost", Align::Right),
            ("Recovery", Align::Left),
        ],
    )
    .title(format!(
        "Table 4 — Resilience under injected faults ({}, {} workers, {} epochs, seed {}; \
         deltas vs each framework's fault-free run)",
        cfg.arch, cfg.workers, cfg.epochs, cfg.seed
    ));

    for fw in FrameworkKind::ALL {
        let base = baseline(&t4.cells, fw).clone();
        for cell in t4.cells.iter().filter(|c| c.framework == fw) {
            let dt = cell.vtime_secs - base.vtime_secs;
            let dc = cell.cost_usd - base.cost_usd;
            t.push_row(vec![
                RCell::text(fw.name()),
                RCell::text(cell.scenario.name()),
                RCell::num(cell.vtime_secs, 1),
                if cell.scenario == Scenario::FaultFree {
                    RCell::text("-")
                } else {
                    RCell::text(format!("{:+.1}% ({dt:+.1}s)", dt / base.vtime_secs * 100.0))
                        .with_value(dt)
                },
                RCell::num(cell.cost_usd, 4),
                if cell.scenario == Scenario::FaultFree {
                    RCell::text("-")
                } else {
                    RCell::text(format!("{:+.1}%", dc / base.cost_usd.max(1e-12) * 100.0))
                        .with_value(dc)
                },
                RCell::text(cell.recovery.summary()),
            ]);
        }
        t.rule();
    }

    let mut p = Table::new(
        "poison",
        &[
            ("Aggregation", Align::Left),
            ("Final acc (%)", Align::Right),
            ("d vs fault-free (pts)", Align::Right),
        ],
    )
    .title(format!(
        "Poisoned-gradient recovery — 1 of {} workers submits {:?}-scaled updates \
         (real gradients, logistic task, seed {})",
        t4.poison.workers, t4.poison.mode, cfg.seed
    ));
    p.push_row(vec![
        RCell::text("fault-free (mean)"),
        RCell::num(t4.poison.fault_free_acc * 100.0, 1),
        RCell::text("-"),
    ]);
    for row in &t4.poison.rows {
        p.push_row(vec![
            RCell::text(row.rule.name()),
            RCell::num(row.final_acc * 100.0, 1),
            RCell::text(format!("{:+.1}", (row.final_acc - t4.poison.fault_free_acc) * 100.0))
                .with_value((row.final_acc - t4.poison.fault_free_acc) * 100.0),
        ]);
    }

    Report::new(
        "table4_faults",
        "Table 4 — Resilience under injected faults",
        format!(
            "slsgpu fault-tolerance --arch {} --workers {} --epochs {} --seed {}",
            cfg.arch, cfg.workers, cfg.epochs, cfg.seed
        ),
    )
    .with_intro(
        "Extension beyond the paper: every architecture runs the same paper-scale \
         workload under the same deterministic fault scenarios, and the per-scenario \
         deltas against its own fault-free run expose the topology differences — \
         SPIRT absorbs a worker crash and reroutes around a dead peer, AllReduce's \
         master barrier amplifies it, ScatterReduce stalls on the late chunk owner, \
         MLLess only stalls when its supervisor dies, and the GPU fleet pays instance \
         reboots at on-demand rates. The store-shard crash row runs the shared tier \
         as a 2-shard replicated cluster and downs one shard mid-run: only MLLess \
         (the shared-store user) sees failover reads; everyone else is bit-identical \
         to fault-free. The second table shows the poisoning contrast on \
         real gradients: naive mean collapses, clipped mean and coordinate median \
         recover.",
    )
    .with_table(t)
    .with_table(p)
}

/// Legacy CLI view of [`report`]: resilience table, blank line, poisoning
/// table.
pub fn render(t4: &Table4, cfg: &FaultConfig) -> String {
    report(t4, cfg).to_text()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::calibration::LAMBDA_COLD_START;

    fn small() -> FaultConfig {
        FaultConfig { epochs: 3, ..Default::default() }
    }

    /// The acceptance headline: a mid-training worker crash leaves SPIRT's
    /// time within 20% of fault-free while AllReduce degrades by more than
    /// the restart stall — and a seeded run is bit-for-bit reproducible.
    #[test]
    fn crash_asymmetry_and_reproducibility() {
        let cfg = small();
        let a = run(&cfg).unwrap();
        let b = run(&cfg).unwrap();
        for (ca, cb) in a.cells.iter().zip(&b.cells) {
            assert_eq!(
                ca.vtime_secs.to_bits(),
                cb.vtime_secs.to_bits(),
                "{:?}/{:?} must be bit-identical",
                ca.framework,
                ca.scenario
            );
            assert_eq!(ca.cost_usd.to_bits(), cb.cost_usd.to_bits());
        }

        let cell = |fw, s| {
            a.cells
                .iter()
                .find(|c| c.framework == fw && c.scenario == s)
                .unwrap()
        };
        let spirt_base = cell(FrameworkKind::Spirt, Scenario::FaultFree);
        let spirt_crash = cell(FrameworkKind::Spirt, Scenario::WorkerCrash);
        assert!(
            spirt_crash.vtime_secs < spirt_base.vtime_secs * 1.20,
            "SPIRT crash {:.1}s vs base {:.1}s",
            spirt_crash.vtime_secs,
            spirt_base.vtime_secs
        );

        let ar_base = cell(FrameworkKind::AllReduce, Scenario::FaultFree);
        let ar_crash = cell(FrameworkKind::AllReduce, Scenario::WorkerCrash);
        assert!(
            ar_crash.vtime_secs - ar_base.vtime_secs > LAMBDA_COLD_START,
            "AllReduce must stall by more than the restart: +{:.1}s",
            ar_crash.vtime_secs - ar_base.vtime_secs
        );
    }

    #[test]
    fn supervisor_crash_only_hurts_mlless() {
        let cfg = small();
        let t4 = run(&cfg).unwrap();
        for fw in FrameworkKind::ALL {
            let base = baseline(&t4.cells, fw);
            let sup = t4
                .cells
                .iter()
                .find(|c| c.framework == fw && c.scenario == Scenario::SupervisorCrash)
                .unwrap();
            if fw == FrameworkKind::MlLess {
                assert!(sup.vtime_secs > base.vtime_secs + 1.0, "MLLess stalls");
                assert_eq!(sup.recovery.supervisor_restarts, 1);
            } else {
                assert_eq!(
                    sup.vtime_secs.to_bits(),
                    base.vtime_secs.to_bits(),
                    "{fw:?} has no supervisor to lose"
                );
            }
        }
    }

    #[test]
    fn shard_crash_only_touches_shared_store_users() {
        // Note the shard-crash cell runs on a 2-shard replicated tier while
        // the baseline runs the single instance — for the architectures
        // that never touch the shared store that provisioning difference
        // (like the crash itself) must not move a single bit.
        let cfg = small();
        let t4 = run(&cfg).unwrap();
        for fw in FrameworkKind::ALL {
            let base = baseline(&t4.cells, fw);
            let sc = t4
                .cells
                .iter()
                .find(|c| c.framework == fw && c.scenario == Scenario::ShardCrash)
                .unwrap();
            if fw == FrameworkKind::MlLess {
                assert_eq!(sc.recovery.shard_restarts, 1, "the crash fired");
                assert!(
                    sc.recovery.shard_failovers > 0,
                    "replica reads must cover the downed shard"
                );
            } else {
                assert_eq!(
                    sc.vtime_secs.to_bits(),
                    base.vtime_secs.to_bits(),
                    "{fw:?} never touches the shared store"
                );
                assert_eq!(sc.cost_usd.to_bits(), base.cost_usd.to_bits());
                assert_eq!(sc.recovery.shard_failovers, 0);
            }
        }
    }

    #[test]
    fn faults_always_cost_money_never_save_it() {
        let cfg = small();
        let t4 = run(&cfg).unwrap();
        for fw in FrameworkKind::ALL {
            let base = baseline(&t4.cells, fw);
            for s in [Scenario::WorkerCrash, Scenario::SyncCrash, Scenario::Straggler] {
                let c = t4
                    .cells
                    .iter()
                    .find(|c| c.framework == fw && c.scenario == s)
                    .unwrap();
                assert!(
                    c.cost_usd >= base.cost_usd - 1e-12,
                    "{fw:?}/{s:?}: {:.6} vs base {:.6}",
                    c.cost_usd,
                    base.cost_usd
                );
            }
        }
    }

    #[test]
    fn render_includes_both_tables() {
        let cfg = FaultConfig { epochs: 1, ..Default::default() };
        let t4 = run(&cfg).unwrap();
        let s = render(&t4, &cfg);
        assert!(s.contains("Table 4"));
        assert!(s.contains("Poisoned-gradient recovery"));
        assert!(s.contains("SPIRT"));
        assert!(s.contains("coord-median"));
    }
}
