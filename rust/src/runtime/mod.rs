//! PJRT runtime: loads AOT artifacts and executes them on the hot path.
//!
//! `make artifacts` (build time, Python) lowers the JAX/Pallas functions to
//! HLO *text*; this module (run time, Rust) parses that text into
//! `HloModuleProto`s, compiles them once on the PJRT CPU client and executes
//! them with zero Python involvement. Text is the interchange format because
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example).

#[cfg(feature = "pjrt")]
pub mod engine;
/// Stub engine for builds without the vendored `xla` crate: `Engine::load`
/// errors with guidance, the cost-model experiments never notice.
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;
pub mod manifest;

pub use engine::{Engine, GradOutput, PjrtMath};
pub use manifest::{Manifest, ModelEntry, SlabEntry};
