//! Trace-layer acceptance tests.
//!
//! * Chrome export determinism: serializing the same traced runs twice must
//!   produce bit-identical JSON, and the traced scale sweep must be
//!   invariant across `--threads` values.
//! * Hand-checked critical path: a tiny 2-worker, 1-round AllReduce epoch
//!   has a fully predictable gating chain — the analyzer must walk exactly
//!   that chain, not program order.
//! * Opt-in guard: tracing stays disabled by default on every config type
//!   an exp driver consumes.

use slsgpu::cloud::FrameworkKind;
use slsgpu::coordinator::EnvConfig;
use slsgpu::exp::{scale_sweep, trace as exp_trace};
use slsgpu::report::suite::SuiteConfig;
use slsgpu::trace::{EventKind, TraceConfig};

fn small_cfg() -> exp_trace::TraceRunConfig {
    exp_trace::TraceRunConfig {
        batches_per_epoch: 4,
        epochs: 2,
        ..exp_trace::TraceRunConfig::default()
    }
}

#[test]
fn chrome_export_is_bit_identical_across_runs() {
    let a = exp_trace::run(&small_cfg()).unwrap();
    let b = exp_trace::run(&small_cfg()).unwrap();
    let ja = exp_trace::chrome_export(&a);
    let jb = exp_trace::chrome_export(&b);
    assert_eq!(ja, jb, "chrome JSON must be byte-stable across runs");
    assert!(ja.contains("\"traceEvents\""));
    assert!(ja.ends_with('\n'));
    // Every architecture contributes a named process and a worker track.
    for fw in FrameworkKind::ALL {
        assert!(ja.contains(fw.name()), "missing process for {}", fw.name());
    }
    assert!(ja.contains("worker 0") && ja.contains("supervisor"), "{}", &ja[..400]);
    // The summary and CSV renderings are deterministic too.
    assert_eq!(
        exp_trace::render(&a, &small_cfg()),
        exp_trace::render(&b, &small_cfg())
    );
    assert_eq!(exp_trace::render_csv(&a), exp_trace::render_csv(&b));
}

#[test]
fn traced_sweep_is_invariant_across_thread_counts() {
    let cfg = |threads| scale_sweep::SweepConfig {
        worker_counts: vec![4],
        batches_per_epoch: 4,
        threads,
        trace: true,
        ..scale_sweep::SweepConfig::default()
    };
    let serial = scale_sweep::run(&cfg(1)).unwrap();
    let parallel = scale_sweep::run(&cfg(4)).unwrap();
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.framework, b.framework);
        let (pa, pb) = (a.p99_op_ms.unwrap(), b.p99_op_ms.unwrap());
        assert_eq!(
            pa.to_bits(),
            pb.to_bits(),
            "{} W={}: p99 must not depend on thread count",
            a.framework.name(),
            a.workers
        );
        assert!(pa > 0.0);
    }
}

/// 2 workers, 1 batch, 1 epoch of AllReduce: the epoch is bound by the
/// round's fixed op sequence — a final sync-overhead charge, behind the
/// model update, behind the aggregate fetch, behind the master's
/// aggregate-put / local-aggregation / bulk-fetch, behind a gradient
/// upload fed by its compute and state load. Asserted step by step.
#[test]
fn two_worker_allreduce_critical_path_by_hand() {
    let cfg = exp_trace::TraceRunConfig {
        workers: 2,
        batches_per_epoch: 1,
        epochs: 1,
        ..exp_trace::TraceRunConfig::default()
    };
    let traces = exp_trace::run_for(&cfg, &[FrameworkKind::AllReduce]).unwrap();
    let t = &traces[0];
    assert_eq!(t.paths.len(), 1);
    let p = &t.paths[0];
    assert_eq!(p.epoch, 1);

    let kinds: Vec<EventKind> = p.steps.iter().map(|s| s.kind).collect();
    assert_eq!(
        kinds,
        vec![
            EventKind::SyncWait,
            EventKind::ApplyUpdate,
            EventKind::Get,
            EventKind::Put,
            EventKind::Advance,
            EventKind::GetMany,
            EventKind::Put,
            EventKind::Compute,
            EventKind::StateLoad,
        ],
        "chain: {}",
        slsgpu::trace::critical_path::describe(p, 16)
    );
    // Steps 3..6 are the master's serialized aggregation (the Fig. 2
    // bottleneck): aggregate put, local aggregation, bulk fetch — all on
    // worker 0. The upload that gated the bulk fetch and its compute chain
    // sit on a single worker too.
    assert!(p.steps[3..6].iter().all(|s| s.worker == 0), "master ops on w0: {p:?}");
    let uploader = p.steps[6].worker;
    assert!(p.steps[6..].iter().all(|s| s.worker == uploader), "upload chain: {p:?}");
    // Self-times tile the bound span exactly: every hop on the chain is
    // contiguous with (or overlapped by) its predecessor.
    let sum: f64 = p.steps.iter().map(|s| s.self_secs).sum();
    assert!((sum - p.span_secs()).abs() < 1e-6, "sum {sum} vs span {}", p.span_secs());
    // Compute dominates a 2-worker round.
    assert_eq!(p.kind_secs[0].0, EventKind::Compute);

    // The event-queue scheduler core resolves this run's waits through a
    // heap instead of per-op scans; the analyzer's walk must not notice:
    // a second run reproduces the same chain, rendered byte for byte.
    let again = exp_trace::run_for(&cfg, &[FrameworkKind::AllReduce]).unwrap();
    assert_eq!(
        slsgpu::trace::critical_path::describe(p, 16),
        slsgpu::trace::critical_path::describe(&again[0].paths[0], 16),
        "critical path must be byte-stable across runs on the event core"
    );
}

#[test]
fn tracing_defaults_off_everywhere() {
    assert_eq!(TraceConfig::default(), TraceConfig::disabled());
    let ec = EnvConfig::virtual_paper(FrameworkKind::Spirt, "mobilenet", 4).unwrap();
    assert!(!ec.trace.enabled, "EnvConfig::virtual_paper must not trace by default");
    assert!(
        !scale_sweep::SweepConfig::default().trace,
        "sweep tracing must be opt-in (--trace)"
    );
    assert!(
        !SuiteConfig::default().sweep.trace,
        "the docs suite's sweep must not trace (it would change docs/ output)"
    );
}
