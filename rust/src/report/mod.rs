//! Typed experiment reports — the crate's documentation pipeline.
//!
//! The paper's contribution is a comparative table set; this module makes
//! the reproduction's tables *values* instead of side effects. Every
//! experiment driver in [`crate::exp`] builds a [`Report`] (sections →
//! tables → rows → cells, with optional paper [`Anchor`]s and PASS/WARN
//! [`Verdict`]s), and four pure renderers turn that one value into:
//!
//! * the legacy CLI text view ([`Report::to_text`] — byte-compatible with
//!   the pre-report `render()` output),
//! * a `docs/` Markdown page ([`Report::to_markdown`]),
//! * CSV ([`Table::to_csv`]),
//! * machine-readable JSON ([`Report::to_json`], written under
//!   `docs/data/` as a bench/accuracy trajectory).
//!
//! [`suite`] runs the whole virtual-mode experiment suite and regenerates
//! the `docs/` tree deterministically; CI diffs that tree against the
//! checked-in state so the rendered documentation can never drift from
//! what the simulator measures.

pub mod model;
pub mod render;
pub mod suite;

pub use crate::util::table::Align;
pub use model::{rel_err, vs_paper, Anchor, Cell, Column, Report, Row, Section, Table, Verdict};
