"""AOT lowering: JAX functions -> HLO text artifacts + manifest.json.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the Rust `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly. Lowered with return_tuple=True — every artifact
output is a tuple, unwrapped with to_tupleN on the Rust side.

Run as:  cd python && python -m compile.aot --out ../artifacts
The Makefile `artifacts` target is a no-op when inputs are unchanged.
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, arg_specs, path):
    t0 = time.time()
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1e6:.2f} MB, {time.time() - t0:.1f}s)", flush=True)


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def u32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


def build_all(out_dir):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "version": 1,
        "image_shape": list(M.IMAGE_SHAPE),
        "num_classes": M.NUM_CLASSES,
        "models": {},
        "slabs": {},
        "paper_sizes": M.PAPER_SIZES,
    }

    # Per-config executable artifacts: init / grad / eval.
    for name, cfg in M.MODEL_CONFIGS.items():
        _, _, spec = M.build_model(name)
        n = spec["total"]
        batch, eval_batch = cfg["batch"], cfg["eval_batch"]
        print(f"[{name}] n_params={n} batch={batch}", flush=True)

        x = f32(batch, *M.IMAGE_SHAPE)
        y = i32(batch)
        xe = f32(eval_batch, *M.IMAGE_SHAPE)
        ye = i32(eval_batch)

        files = {
            "init": f"init_{name}.hlo.txt",
            "grad": f"grad_{name}.hlo.txt",
            "eval": f"eval_{name}.hlo.txt",
        }
        lower_to_file(M.make_init_fn(name), [u32()], os.path.join(out_dir, files["init"]))
        lower_to_file(
            M.make_grad_fn(name), [f32(n), x, y], os.path.join(out_dir, files["grad"])
        )
        lower_to_file(
            M.make_eval_fn(name), [f32(n), xe, ye], os.path.join(out_dir, files["eval"])
        )

        manifest["models"][name] = {
            "arch": cfg["arch"],
            "width": cfg["width"],
            "n_params": n,
            "batch": batch,
            "eval_batch": eval_batch,
            "artifacts": files,
        }

    # Size-parameterized elementwise slab artifacts (Pallas-backed).
    for slab_name, n in M.slab_sizes().items():
        print(f"[slab {slab_name}] n={n}", flush=True)
        files = {
            "acc": f"acc_{slab_name}.hlo.txt",
            "sgd": f"sgd_{slab_name}.hlo.txt",
            "avg_update": f"avg_update_{slab_name}.hlo.txt",
        }
        lower_to_file(
            M.make_acc_fn(), [f32(n), f32(n), f32()], os.path.join(out_dir, files["acc"])
        )
        lower_to_file(
            M.make_sgd_fn(), [f32(n), f32(n), f32()], os.path.join(out_dir, files["sgd"])
        )
        lower_to_file(
            M.make_avg_update_fn(),
            [f32(n), f32(n), f32(), f32()],
            os.path.join(out_dir, files["avg_update"]),
        )
        manifest["slabs"][slab_name] = {"n": n, "artifacts": files}

    manifest_path = os.path.join(out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {manifest_path}", flush=True)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    args = ap.parse_args()
    build_all(args.out)


if __name__ == "__main__":
    main()
