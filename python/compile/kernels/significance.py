"""Blockwise squared-L2-norm Pallas kernel — MLLess significance filtering.

MLLess publishes a gradient only when it is "significant" (its relative
magnitude exceeds a threshold); everything else stays local, which is where
its 13x communication reduction comes from (Fig. 3). The decision needs
||g||^2, computed here as a 1-D grid of per-block partial sums followed by a
scalar reduction — the canonical two-stage TPU reduction (VMEM-resident block
reduce on the VPU, then a trivial final sum).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .aggregate import BLOCK, _ceil_to


def _sumsq_kernel(g_ref, o_ref):
    blk = g_ref[...]
    o_ref[0] = jnp.sum(blk * blk)


@jax.jit
def l2_norm_sq(g):
    """sum(g**2) via per-block partial sums (zero padding is inert)."""
    n = g.shape[0]
    block = min(BLOCK, _ceil_to(n, 8))
    np_ = _ceil_to(n, block)
    gp = jnp.pad(g, (0, np_ - n))
    nblocks = np_ // block

    partials = pl.pallas_call(
        _sumsq_kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nblocks,), jnp.float32),
        interpret=True,
    )(gp)
    return jnp.sum(partials)


@jax.jit
def is_significant(g, theta, threshold):
    """MLLess predicate: ||g|| / ||theta|| > threshold (as f32 0/1)."""
    gn = l2_norm_sq(g)
    tn = l2_norm_sq(theta)
    # Guard ||theta|| = 0 (first step): everything is significant then.
    return jnp.where(gn > (threshold * threshold) * jnp.maximum(tn, 1e-12), 1.0, 0.0)
