//! The full virtual-mode experiment suite + the `docs/` tree writer.
//!
//! `slsgpu report --out docs/` calls [`run`] (every virtual-mode experiment
//! driver, fixed order, fixed seeds) and [`write_docs`] (one Markdown page
//! and one JSON data file per experiment, plus the `docs/REPORT.md`
//! summary). Because every driver is deterministic and the renderers are
//! pure, regenerating the tree from the same source is bit-identical —
//! which is what lets CI diff `docs/` against the checked-in state and
//! fail when the documentation has drifted from the simulator.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::exp;
use crate::Result;

use super::model::{Report, Verdict};

/// Suite knobs. Defaults reproduce the canonical `docs/` tree: paper-scale
/// parameters everywhere, the full 4→256 scale sweep.
#[derive(Debug, Clone)]
pub struct SuiteConfig {
    /// Experiment ids to skip (accepts `-` or `_` separators).
    pub skip: Vec<String>,
    /// Table 2 worker count (paper: 4).
    pub table2_workers: usize,
    /// Fig. 2 worker-count sweep (paper: 4–16).
    pub fig2_workers: Vec<usize>,
    /// Fig. 3 publish-rate sweep.
    pub fig3_rates: Vec<f64>,
    /// §4.2 in-DB benchmark minibatch count (paper: 24).
    pub indb_minibatches: usize,
    /// Table 4 fault-injection knobs.
    pub fault: exp::table4_faults::FaultConfig,
    /// Robustness-tournament grid (rule × attack × architecture).
    pub tournament: exp::tournament::TournamentConfig,
    /// Scale-sweep grid.
    pub sweep: exp::scale_sweep::SweepConfig,
    /// Shard-sweep grid (store-tier provisioning frontier).
    pub shard_sweep: exp::shard_sweep::ShardSweepConfig,
    /// Protocol-trace run parameters.
    pub trace: exp::trace::TraceRunConfig,
}

impl Default for SuiteConfig {
    fn default() -> Self {
        SuiteConfig {
            skip: Vec::new(),
            table2_workers: 4,
            fig2_workers: vec![4, 8, 12, 16],
            fig3_rates: vec![1.0, 0.5, 0.2, 0.1, 0.05],
            indb_minibatches: 24,
            fault: exp::table4_faults::FaultConfig::default(),
            tournament: exp::tournament::TournamentConfig::default(),
            sweep: exp::scale_sweep::SweepConfig::default(),
            shard_sweep: exp::shard_sweep::ShardSweepConfig::default(),
            trace: exp::trace::TraceRunConfig::default(),
        }
    }
}

/// Why a suite entry has no report.
#[derive(Debug, Clone)]
pub enum Outcome {
    Ran(Report),
    Skipped(String),
}

/// One experiment's slot in the suite, ran or skipped.
#[derive(Debug, Clone)]
pub struct Entry {
    /// Page / data-file stem (`table2`, `scale_sweep`, ...).
    pub id: String,
    pub title: String,
    pub outcome: Outcome,
}

impl Entry {
    fn ran(report: Report) -> Entry {
        Entry { id: report.id.clone(), title: report.title.clone(), outcome: Outcome::Ran(report) }
    }

    fn skipped(id: &str, title: &str, reason: impl Into<String>) -> Entry {
        Entry {
            id: id.to_string(),
            title: title.to_string(),
            outcome: Outcome::Skipped(reason.into()),
        }
    }
}

fn norm(id: &str) -> String {
    id.trim().to_ascii_lowercase().replace('-', "_")
}

impl SuiteConfig {
    fn skips(&self, id: &str) -> bool {
        self.skip.iter().any(|s| norm(s) == norm(id))
    }
}

/// The suite's experiment ids, in execution order.
pub const EXPERIMENT_IDS: [&str; 11] = [
    "table1",
    "table2",
    "fig2",
    "fig3",
    "spirt_indb",
    "table3",
    "table4_faults",
    "tournament",
    "scale_sweep",
    "shard_sweep",
    "trace",
];

/// Run the full virtual-mode suite. Table 3 needs compiled PJRT artifacts
/// and is always a skipped stub here; everything else runs unless listed in
/// `cfg.skip`. Progress goes to stderr so stdout stays machine-clean.
pub fn run(cfg: &SuiteConfig) -> Result<Vec<Entry>> {
    let mut entries = Vec::new();
    for id in EXPERIMENT_IDS {
        if cfg.skips(id) {
            entries.push(Entry::skipped(id, &canonical_title(id), "skipped via --skip"));
            continue;
        }
        if id == "table3" {
            entries.push(Entry::skipped(
                id,
                &canonical_title(id),
                "needs compiled PJRT artifacts: run `make artifacts`, then \
                 `cargo run --release --features pjrt -- exp table3`",
            ));
            continue;
        }
        eprintln!("report: running {id} ...");
        let report = run_one(id, cfg).with_context(|| format!("running experiment {id}"))?;
        entries.push(Entry::ran(report));
    }
    Ok(entries)
}

/// Canonical title per experiment id — the single source both the skip
/// path and the drivers' `Report::new` calls must agree on (asserted in
/// `rust/tests/report.rs`, so a retitled driver cannot silently desync the
/// summary row rendered when that experiment is skipped).
pub fn canonical_title(id: &str) -> String {
    match id {
        "table1" => "Table 1 — Key computational stages per framework".to_string(),
        "table2" => "Table 2 — Training time, peak RAM and cost per epoch".to_string(),
        "fig2" => "Fig. 2 — Communication time per synchronization round".to_string(),
        "fig3" => "Fig. 3 — MLLess significance filtering".to_string(),
        "spirt_indb" => "SPIRT in-database ops vs naive fetch-update-store".to_string(),
        "table3" => "Table 3 / Fig. 4 — convergence on the executed model".to_string(),
        "table4_faults" => "Table 4 — Resilience under injected faults".to_string(),
        "tournament" => {
            "Robustness tournament — aggregation rule × attack × architecture".to_string()
        }
        "scale_sweep" => "Scale sweep — 4 → 256 workers × sync modes".to_string(),
        "shard_sweep" => "Shard sweep — store-tier provisioning frontier (MLLess)".to_string(),
        "trace" => "Protocol trace — critical path and op latency percentiles".to_string(),
        other => other.to_string(),
    }
}

fn run_one(id: &str, cfg: &SuiteConfig) -> Result<Report> {
    Ok(match id {
        "table1" => exp::table1::report(),
        "table2" => {
            let rows = exp::table2::run(cfg.table2_workers)?;
            exp::table2::report(&rows, cfg.table2_workers)
        }
        "fig2" => {
            let points = exp::fig2::run(&cfg.fig2_workers)?;
            exp::fig2::report(&points)
        }
        "fig3" => {
            let points = exp::fig3::run_sim(&cfg.fig3_rates)?;
            exp::fig3::report_sim(&points)
        }
        "spirt_indb" => {
            let outcome = exp::spirt_indb::run(None, cfg.indb_minibatches)?;
            exp::spirt_indb::report(&outcome)
        }
        "table4_faults" => {
            let t4 = exp::table4_faults::run(&cfg.fault)?;
            exp::table4_faults::report(&t4, &cfg.fault)
        }
        "tournament" => {
            let t = exp::tournament::run(&cfg.tournament)?;
            exp::tournament::report(&t, &cfg.tournament)
        }
        "scale_sweep" => {
            let points = exp::scale_sweep::run(&cfg.sweep)?;
            exp::scale_sweep::report(&points, &cfg.sweep)
        }
        "shard_sweep" => {
            let points = exp::shard_sweep::run(&cfg.shard_sweep)?;
            exp::shard_sweep::report(&points, &cfg.shard_sweep)
        }
        "trace" => {
            let traces = exp::trace::run(&cfg.trace)?;
            exp::trace::report(&traces, &cfg.trace)
        }
        other => anyhow::bail!("unknown experiment id {other:?}"),
    })
}

// ---------------------------------------------------------------------------
// docs/ tree

/// Marker every generated page carries; `write_docs` only ever deletes
/// files containing it, so pointing `--out` at a directory with
/// hand-written Markdown cannot destroy anything.
const PAGE_MARKER: &str = "Generated by `slsgpu report`";
/// Counterpart marker for `data/*.json`: every generated report JSON has a
/// `command` field starting with `slsgpu`.
const DATA_MARKER: &str = "\"command\":\"slsgpu";

/// Write the `docs/` tree: `REPORT.md`, one page per entry (stub pages for
/// skipped experiments so summary links always resolve), and
/// `data/<id>.json` for every ran experiment. The writer owns the tree: any
/// previously *generated* `*.md` under `out` / `*.json` under `out/data`
/// that it does not regenerate (recognized by the generated-file markers)
/// is deleted first, so a regeneration is a clean replacement and
/// `git diff` sees exactly the drift; files without a marker are left
/// untouched.
pub fn write_docs(entries: &[Entry], out: &Path) -> Result<Vec<PathBuf>> {
    let data_dir = out.join("data");
    fs::create_dir_all(&data_dir).with_context(|| format!("creating {}", data_dir.display()))?;
    clear_generated(out, "md", PAGE_MARKER)?;
    clear_generated(&data_dir, "json", DATA_MARKER)?;

    let mut written = Vec::new();
    let mut write = |path: PathBuf, contents: String| -> Result<()> {
        fs::write(&path, contents).with_context(|| format!("writing {}", path.display()))?;
        written.push(path);
        Ok(())
    };

    for entry in entries {
        match &entry.outcome {
            Outcome::Ran(report) => {
                write(out.join(format!("{}.md", entry.id)), report.to_markdown())?;
                write(
                    data_dir.join(format!("{}.json", entry.id)),
                    format!("{}\n", report.to_json()),
                )?;
            }
            Outcome::Skipped(reason) => {
                write(out.join(format!("{}.md", entry.id)), stub_page(entry, reason))?;
            }
        }
    }
    write(out.join("REPORT.md"), summary_markdown(entries))?;
    Ok(written)
}

fn clear_generated(dir: &Path, ext: &str, marker: &str) -> Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    for dirent in fs::read_dir(dir)? {
        let path = dirent?.path();
        if path.is_file()
            && path.extension().and_then(|e| e.to_str()) == Some(ext)
            && fs::read_to_string(&path).map(|s| s.contains(marker)).unwrap_or(false)
        {
            fs::remove_file(&path).with_context(|| format!("removing {}", path.display()))?;
        }
    }
    Ok(())
}

fn stub_page(entry: &Entry, reason: &str) -> String {
    format!(
        "# {}\n\n> Generated by `slsgpu report` — do not edit by hand.\n\n\
         **Not run in this suite:** {}\n",
        entry.title, reason
    )
}

/// The `docs/REPORT.md` summary: one status row per experiment, linking the
/// page and data file, with PASS/WARN aggregated over paper-anchored cells.
pub fn summary_markdown(entries: &[Entry]) -> String {
    let mut out = String::from(
        "# Reproduction report — CPU-serverless vs GPU training architectures\n\n\
         > Generated by `slsgpu report` — do not edit by hand.\n\
         > Regenerate: `cargo run --release -- report --out docs/`\n\n\
         Each page below is rendered from the same typed `report::Report` value its\n\
         experiment driver returns — the CLI table, the Markdown page and the JSON\n\
         data file are three views of one measurement, so documented status cannot\n\
         drift from the simulator. **PASS** = every paper-anchored cell within its\n\
         tolerance; **WARN** = at least one anchored cell out of tolerance (the hard\n\
         bounds are enforced separately by the test suite); **—** = no paper anchors\n\
         (qualitative table, or an extension beyond the paper's measured range).\n\n\
         | Experiment | Status | Anchors (PASS/WARN) | Page | Data |\n\
         | :--- | :--- | ---: | :--- | :--- |\n",
    );
    for entry in entries {
        let (status, anchors, data) = match &entry.outcome {
            Outcome::Ran(report) => {
                let (pass, warn) = report.verdicts();
                let status = match report.status() {
                    Some(Verdict::Pass) => "PASS".to_string(),
                    Some(Verdict::Warn) => "WARN".to_string(),
                    None => "—".to_string(),
                };
                let anchors =
                    if pass + warn > 0 { format!("{pass}/{warn}") } else { "—".to_string() };
                (status, anchors, format!("[json](data/{}.json)", entry.id))
            }
            Outcome::Skipped(_) => ("skipped".to_string(), "—".to_string(), "—".to_string()),
        };
        out.push_str(&format!(
            "| {} | {} | {} | [{}.md]({}.md) | {} |\n",
            entry.title.replace('|', "\\|"),
            status,
            anchors,
            entry.id,
            entry.id,
            data,
        ));
    }
    out.push_str(
        "\nAll simulations are seeded and virtual-time deterministic: regenerating\n\
         this tree from the same source produces bit-identical files (asserted in\n\
         `rust/tests/report.rs`), and CI fails if `docs/` is stale.\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skip_matching_normalizes_separators() {
        let cfg = SuiteConfig {
            skip: vec!["scale-sweep".into(), "TABLE4_FAULTS".into()],
            ..SuiteConfig::default()
        };
        assert!(cfg.skips("scale_sweep"));
        assert!(cfg.skips("table4_faults"));
        assert!(!cfg.skips("table2"));
    }

    #[test]
    fn summary_lists_every_entry_with_links() {
        let entries = vec![
            Entry::skipped("table3", &canonical_title("table3"), "needs artifacts"),
            Entry::ran(Report::new("table1", "Table 1 — demo", "slsgpu exp table1")),
        ];
        let md = summary_markdown(&entries);
        assert!(md.contains("[table3.md](table3.md)"), "{md}");
        assert!(md.contains("[table1.md](table1.md)"), "{md}");
        assert!(md.contains("| skipped |"), "{md}");
    }
}
