//! MLLess: significance-filtered parameter exchange with a supervisor.
//!
//! §2's workflow: each worker computes a minibatch gradient and publishes it
//! *only if significant* (relative L2 norm above a threshold); insignificant
//! gradients accumulate locally and ride along with the next significant
//! update, so signal is delayed rather than lost. A central supervisor
//! coordinates rounds: workers report (update key or "none") through
//! queues, the supervisor tells everyone when to fetch, workers pull the
//! published updates from shared Redis, aggregate and update.
//!
//! The filter is where MLLess's 13× communication reduction comes from
//! (Fig. 3); the supervisor round-trips are where its high per-batch
//! latency comes from (69.4 s vs ~14.4 s for LambdaML — Table 2).

use crate::cloud::FrameworkKind;
use crate::metrics::Stage;
use crate::sim::VTime;
use crate::tensor::{SignificanceFilter, Slab};
use crate::trace::EventKind;
use crate::Result;

use super::env::{ClusterEnv, Device};
use super::protocol::{quorum_subset, RedisSel, SyncMode};
use super::{EpochStats, Strategy};

/// Default relative-norm threshold (calibrated so early epochs publish
/// nearly everything and filtering ramps up as gradients shrink — the
/// behaviour MLLess reports).
pub const DEFAULT_THRESHOLD: f64 = 0.05;

pub struct MlLess {
    filters: Vec<SignificanceFilter>,
    threshold: f64,
    /// The supervisor's own virtual clock.
    supervisor_clock: VTime,
    /// Publish probability for size-only gradients (virtual mode cannot
    /// evaluate the norm predicate; 1.0 = worst-case full traffic, which is
    /// what Table 2 measures; Fig. 3's sim sweep varies it).
    virtual_publish_rate: f64,
    /// Fig. 3 counters.
    pub updates_proposed: u64,
    pub updates_published: u64,
}

impl MlLess {
    pub fn new(threshold: f64) -> MlLess {
        MlLess {
            filters: Vec::new(),
            threshold,
            supervisor_clock: VTime::ZERO,
            virtual_publish_rate: 1.0,
            updates_proposed: 0,
            updates_published: 0,
        }
    }

    /// Set the virtual-mode publish rate (builder style).
    pub fn with_virtual_publish_rate(mut self, rate: f64) -> MlLess {
        assert!((0.0..=1.0).contains(&rate));
        self.virtual_publish_rate = rate;
        self
    }

    pub fn publish_rate(&self) -> f64 {
        if self.updates_proposed == 0 {
            1.0
        } else {
            self.updates_published as f64 / self.updates_proposed as f64
        }
    }

    fn ensure_filters(&mut self, workers: usize) {
        while self.filters.len() < workers {
            self.filters.push(SignificanceFilter::new(self.threshold));
        }
    }
}

impl Strategy for MlLess {
    fn kind(&self) -> FrameworkKind {
        FrameworkKind::MlLess
    }

    fn run_epoch(&mut self, env: &mut ClusterEnv) -> Result<EpochStats> {
        env.begin_epoch();
        let w_count = env.num_workers();
        self.ensure_filters(w_count);
        let start = env.max_clock();
        let alloc_mb = env.allocated_mb();
        let epoch = env.epoch;
        let mut loss_sum = 0.0;
        let mut loss_n = 0usize;

        for round in 0..env.batches_per_epoch {
            env.trace.set_round(round);
            let sup_topic = format!("mlless/sup/e{epoch}/r{round}");
            let proceed_topic = format!("mlless/proceed/e{epoch}/r{round}");

            // -- compute + filter + report --------------------------------
            let mut invs = Vec::with_capacity(w_count);
            let mut published: Vec<Option<(String, Slab)>> = Vec::with_capacity(w_count);
            let mut report_done: Vec<VTime> = Vec::with_capacity(w_count);
            for w in 0..w_count {
                let inv = env.lambda.begin_invocation(env.workers[w].clock, w);
                env.workers[w].clock = inv.body_start;
                invs.push(inv);
                env.state_load(w);
                let mut g = env.compute_grad(w, Device::LambdaCpu)?;
                if env.crash_in_compute(w) {
                    g = env.recover_invocation(w, Device::LambdaCpu)?;
                }
                if let Some(l) = g.loss {
                    loss_sum += l;
                    loss_n += 1;
                }

                self.updates_proposed += 1;
                let offer = if g.grad.is_real() {
                    self.filters[w].offer(g.grad, &env.workers[w].theta)
                } else {
                    // Size-only gradients: model the filter's pass rate.
                    env.rng.bernoulli(self.virtual_publish_rate).then_some(g.grad)
                };
                // An injected message drop loses the update *after* the
                // filter drained it — the signal is gone, not delayed.
                let dropped = offer.is_some() && env.update_dropped(w);
                let offer = if dropped { None } else { offer };
                let report = if let Some(update) = offer {
                    self.updates_published += 1;
                    let key = format!("u/e{epoch}/r{round}/w{w}");
                    env.timeline(w).redis_set(
                        RedisSel::Shared,
                        Stage::Synchronize,
                        &key,
                        update.share(),
                    );
                    published.push(Some((key.clone(), update)));
                    key
                } else {
                    published.push(None);
                    "none".to_string()
                };
                report_done.push(env.timeline(w).notify(&sup_topic, report));
            }

            // -- supervisor: wait for reports, authorize fetch -------------
            // The supervisor is MLLess's single point of coordination: when
            // it crashes, *every* worker idles until it restarts and
            // re-polls the round's reports — there is no peer to reroute
            // through (contrast with SPIRT's P2P sync above). In async mode
            // it authorizes the fetch once a bounded-staleness quorum of
            // reports is in; late updates are skipped for the round.
            let wait_count = env.sync.quorum(w_count);
            let t0 = self.supervisor_clock;
            let traced = env.trace.enabled();
            let cost0 = if traced { env.ledger.total_full() } else { 0.0 };
            let mut t = env
                .queues
                .wait_for(t0, &sup_topic, wait_count, &mut env.ledger, &mut env.comm)?;
            if traced {
                // The supervisor's wait is gated on the quorum-th report;
                // its (queue-request) cost is sampled around the wait only,
                // so a supervisor crash keeps billing to its own span.
                use crate::faults::SUPERVISOR;
                let cost = env.ledger.total_full() - cost0;
                let dep = env.trace.notify_dep(&sup_topic, wait_count);
                // audit:allow(trace-emit, MLLess supervisor-track emit point - DESIGN.md §6)
                env.trace.span(SUPERVISOR, t0, t, EventKind::Poll, 0, cost, dep);
            }
            if let Some(restart) = env.supervisor_crash(round, t) {
                t = t + restart;
            }
            self.supervisor_clock = t + 0.010; // decision processing
            let cost0 = if traced { env.ledger.total_full() } else { 0.0 };
            let vis = env.queues.publish(
                self.supervisor_clock,
                &proceed_topic,
                "proceed",
                &mut env.ledger,
                &mut env.comm,
            );
            if traced {
                use crate::faults::SUPERVISOR;
                let cost = env.ledger.total_full() - cost0;
                // audit:allow(trace-emit, MLLess supervisor notify emit point - DESIGN.md §6)
                let idx = env.trace.span(
                    SUPERVISOR,
                    self.supervisor_clock,
                    vis,
                    EventKind::Notify,
                    "proceed".len() as u64,
                    cost,
                    None,
                );
                env.trace.note_notify(&proceed_topic, idx);
            }

            // Workers whose reports made the quorum (all of them in BSP),
            // then the published keys among them (the supervisor's fetch
            // list). Quorum-excluded published updates are lost for the
            // round, exactly like a late report in the real system.
            let included: Vec<usize> = match env.sync {
                SyncMode::Bsp => (0..w_count).collect(),
                SyncMode::Async { .. } => {
                    let mut sel = quorum_subset(&report_done, wait_count, round);
                    sel.sort_unstable();
                    sel
                }
            };
            if env.sync.is_async() {
                for w in 0..w_count {
                    if !included.contains(&w) && published[w].is_some() {
                        env.comm.stale_skips += 1;
                    }
                }
            }
            let keys: Vec<String> = included
                .iter()
                .filter_map(|&i| published[i].as_ref().map(|(k, _)| k.clone()))
                .collect();

            // -- workers: wait for authorization, fetch + aggregate --------
            for w in 0..w_count {
                // A sync-phase crash restarts this worker before it polls;
                // the others proceed without waiting for it (they only wait
                // on the supervisor's proceed message).
                env.sync_crash(w);
                env.timeline(w).poll(&proceed_topic, 1)?;

                let mut updates: Vec<Slab> = Vec::new();
                for key in &keys {
                    // Own update is already local — no fetch needed.
                    if let Some((own_key, own)) = &published[w] {
                        if own_key == key {
                            updates.push(own.share());
                            continue;
                        }
                    }
                    let u =
                        env.timeline(w).redis_get(RedisSel::Shared, Stage::Synchronize, key)?;
                    updates.push(u);
                }

                if !updates.is_empty() {
                    let agg_secs = env.local_agg_secs(updates.len());
                    env.charge_sync(w, agg_secs);
                    let mean = env.aggregate(w, &updates)?;
                    env.apply_update(w, &mean, 1.0)?;
                }

                // Supervisor scheduling latency: a fixed coordination floor
                // plus per-published-update scheduling round-trips (Table 2
                // residual; collapses under filtering — Fig. 3).
                use crate::cloud::calibration::{MLLESS_PER_UPDATE, MLLESS_ROUND_BASE};
                let overhead = MLLESS_ROUND_BASE + keys.len() as f64 * MLLESS_PER_UPDATE;
                env.charge_sync(w, overhead);
                let end = env.workers[w].clock;
                env.lambda.finish_invocation(invs[w], end, alloc_mb, &mut env.ledger);
            }

            // Published updates are consumed (or quorum-skipped); drop them
            // from the store. The round's topics are likewise dead — every
            // worker polled `proceed` and the supervisor drained the report
            // quorum — and topic names are unique per round, so dropping
            // them keeps queue memory flat across a W=4096 sweep instead of
            // growing by W+1 messages per round.
            for (key, _) in published.iter().flatten() {
                env.shared_redis.delete(key);
            }
            env.queues.drop_topic(&sup_topic);
            env.queues.drop_topic(&proceed_topic);
        }

        let epoch_secs = env.max_clock() - start;
        Ok(EpochStats {
            mean_loss: (loss_n > 0).then(|| loss_sum / loss_n as f64),
            batches: env.batches_per_epoch * w_count,
            epoch_secs,
            mean_fn_secs: env.lambda.mean_duration(),
        })
    }

    fn stage_table(&self) -> Vec<(Stage, &'static str)> {
        vec![
            (Stage::FetchDataset, "Each worker fetches a single minibatch for processing."),
            (
                Stage::ComputeGradients,
                "Gradients are computed and, if the change is significant, stored in a shared \
                 database with keys sent to peers via queues.",
            ),
            (
                Stage::Synchronize,
                "Workers listen to their queues, collect update keys, wait for synchronization \
                 instructions from the supervisor, then fetch and aggregate the gradients.",
            ),
            (Stage::ModelUpdate, "The aggregated gradients are used to update the model."),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::env::EnvConfig;

    fn env(threshold_irrelevant: bool) -> ClusterEnv {
        let _ = threshold_irrelevant;
        ClusterEnv::new(EnvConfig::virtual_paper(FrameworkKind::MlLess, "mobilenet", 4).unwrap())
            .unwrap()
    }

    #[test]
    fn per_function_duration_matches_paper() {
        let mut e = env(true);
        // Virtual slabs have zero norm -> nothing significant; use
        // threshold 0 so every update is published (worst-case traffic,
        // which is what the Table 2 MLLess row measures pre-convergence).
        let mut s = MlLess::new(0.0);
        let stats = s.run_epoch(&mut e).unwrap();
        assert!(
            (stats.mean_fn_secs - 69.425).abs() / 69.425 < 0.15,
            "mean fn {:.2}s vs paper 69.425s",
            stats.mean_fn_secs
        );
        assert_eq!(s.publish_rate(), 1.0);
    }

    #[test]
    fn filtering_reduces_traffic_and_time() {
        let mut open = env(true);
        let open_stats = MlLess::new(0.0).run_epoch(&mut open).unwrap();
        let mut filtered = env(true);
        let filtered_stats = MlLess::new(0.0)
            .with_virtual_publish_rate(0.1)
            .run_epoch(&mut filtered)
            .unwrap();
        assert!(filtered.comm.wire_bytes() < open.comm.wire_bytes() / 2);
        assert!(filtered_stats.epoch_secs < open_stats.epoch_secs / 2.0);
    }

    #[test]
    fn supervisor_round_trips_counted() {
        let mut e = env(true);
        MlLess::new(0.0).run_epoch(&mut e).unwrap();
        // per round: W reports + 1 proceed -> at least 24 * 5 messages.
        assert!(e.queues.total_published() >= 24 * 5);
    }

    #[test]
    fn async_quorum_trims_the_supervisor_round() {
        use crate::coordinator::protocol::SyncMode;
        let mut bsp = env(true);
        let b = MlLess::new(0.0).run_epoch(&mut bsp).unwrap();

        let cfg = EnvConfig::virtual_paper(FrameworkKind::MlLess, "mobilenet", 4)
            .unwrap()
            .with_sync(SyncMode::Async { staleness: 1 });
        let mut asy = ClusterEnv::new(cfg).unwrap();
        let a = MlLess::new(0.0).run_epoch(&mut asy).unwrap();

        // One published update per round misses the 3-of-4 quorum.
        assert_eq!(asy.comm.stale_skips, 24);
        use crate::metrics::CommKind;
        assert!(asy.comm.ops(CommKind::Get) < bsp.comm.ops(CommKind::Get));
        // Fewer scheduled updates -> lower per-round supervisor overhead.
        assert!(a.epoch_secs < b.epoch_secs, "async {} vs bsp {}", a.epoch_secs, b.epoch_secs);
    }
}
