//! LambdaML ScatterReduce: chunked distributed aggregation (§2, Table 1).
//!
//! Each worker splits its gradient into `W` chunks, keeps chunk `w` and
//! uploads the rest; worker `i` aggregates everyone's chunk `i`, re-uploads
//! the partial aggregate; everyone downloads the `W` partials and
//! reassembles the full mean gradient. Aggregation work is balanced, but
//! the request count grows as `O(W)` per worker per round — which is why
//! AllReduce overtakes it for small models at high worker counts while
//! ScatterReduce wins on large models (Fig. 2).

use crate::cloud::FrameworkKind;
use crate::metrics::Stage;
use crate::tensor::{ChunkPlan, Slab};
use crate::Result;

use super::env::{ClusterEnv, Device};
use super::{EpochStats, Strategy};

#[derive(Debug, Default)]
pub struct ScatterReduce;

impl ScatterReduce {
    pub fn new() -> ScatterReduce {
        ScatterReduce
    }

    /// One chunked synchronization round (factored out for Fig. 2).
    ///
    /// Fault semantics: a sync-phase crash makes the crashed worker a late
    /// *chunk owner* — every peer needs its partial aggregate, so all of
    /// them stall behind its restart. A dropped update removes that
    /// worker's gradient (its outgoing chunks and its own kept chunk) from
    /// the round's aggregate.
    pub fn sync_round(
        &self,
        env: &mut ClusterEnv,
        round_tag: &str,
        grads: Vec<Slab>,
    ) -> Result<()> {
        let w_count = env.num_workers();
        let plan = ChunkPlan::new(env.n_params, w_count)?;

        // Scatter: worker w uploads chunk j (j != w) for peer j; keeps own.
        let mut own_chunks: Vec<Option<Slab>> = vec![None; w_count];
        let mut dropped = vec![false; w_count];
        for w in 0..w_count {
            env.sync_crash(w);
            if env.update_dropped(w) {
                dropped[w] = true;
                continue;
            }
            let chunks = plan.split(&grads[w])?;
            for (j, chunk) in chunks.into_iter().enumerate() {
                if j == w {
                    own_chunks[w] = Some(chunk);
                } else {
                    let key = format!("{round_tag}/c{w}to{j}");
                    let t0 = env.workers[w].clock;
                    let done = env.store.put(t0, &key, chunk, &mut env.ledger, &mut env.comm);
                    env.stages.add(Stage::Synchronize, done - t0);
                    env.workers[w].clock = done;
                }
            }
        }

        // Reduce: worker w aggregates everyone's chunk w, uploads partial.
        for w in 0..w_count {
            let mut parts: Vec<Slab> = own_chunks[w].take().into_iter().collect();
            for j in 0..w_count {
                if j == w || dropped[j] {
                    continue;
                }
                let key = format!("{round_tag}/c{j}to{w}");
                let t0 = env.workers[w].clock;
                let (done, c) = env.store.get(t0, &key, &mut env.ledger, &mut env.comm)?;
                env.stages.add(Stage::Synchronize, done - t0);
                env.workers[w].clock = done;
                parts.push(c);
            }
            let agg_secs =
                w_count as f64 * (plan.chunk_len(w) as f64 * 4.0) / super::env::LOCAL_AGG_BW;
            env.workers[w].clock += agg_secs;
            env.stages.add(Stage::Synchronize, agg_secs);
            let partial = if parts.is_empty() {
                // Every contribution to this chunk was dropped: zero update.
                if env.is_real() {
                    Slab::zeros(plan.chunk_len(w))
                } else {
                    Slab::virtual_of(plan.chunk_len(w))
                }
            } else {
                env.aggregate(w, &parts)?
            };
            let t0 = env.workers[w].clock;
            let done = env.store.put(
                t0,
                &format!("{round_tag}/agg{w}"),
                partial,
                &mut env.ledger,
                &mut env.comm,
            );
            env.stages.add(Stage::Synchronize, done - t0);
            env.workers[w].clock = done;
        }

        // All-gather: everyone downloads the other partials, reassembles,
        // and applies the full mean gradient.
        for w in 0..w_count {
            let mut parts: Vec<Option<Slab>> = vec![None; w_count];
            for j in 0..w_count {
                let key = format!("{round_tag}/agg{j}");
                let t0 = env.workers[w].clock;
                let (done, c) = env.store.get(t0, &key, &mut env.ledger, &mut env.comm)?;
                env.stages.add(Stage::Synchronize, done - t0);
                env.workers[w].clock = done;
                parts[j] = Some(c);
            }
            let full = plan.concat(&parts.into_iter().map(|c| c.unwrap()).collect::<Vec<_>>())?;
            env.apply_update(w, &full, 1.0)?;
        }
        Ok(())
    }
}

impl Strategy for ScatterReduce {
    fn kind(&self) -> FrameworkKind {
        FrameworkKind::ScatterReduce
    }

    fn run_epoch(&mut self, env: &mut ClusterEnv) -> Result<EpochStats> {
        env.begin_epoch();
        let w_count = env.num_workers();
        let start = env.max_clock();
        let alloc_mb = env.allocated_mb();
        let mut loss_sum = 0.0;
        let mut loss_n = 0usize;

        for round in 0..env.batches_per_epoch {
            let tag = format!("e{}/r{}", env.epoch, round);
            let mut invs = Vec::with_capacity(w_count);
            let mut grads = Vec::with_capacity(w_count);
            for w in 0..w_count {
                let inv = env.lambda.begin_invocation(env.workers[w].clock, w);
                env.workers[w].clock = inv.body_start;
                invs.push(inv);
                env.state_load(w);
                let mut g = env.compute_grad(w, Device::LambdaCpu)?;
                if env.crash_in_compute(w) {
                    g = env.recover_invocation(w, Device::LambdaCpu)?;
                }
                if let Some(l) = g.loss {
                    loss_sum += l;
                    loss_n += 1;
                }
                grads.push(g.grad);
            }

            self.sync_round(env, &tag, grads)?;

            let overhead = self.kind().batch_overhead();
            for w in 0..w_count {
                env.charge_sync(w, overhead);
                let end = env.workers[w].clock;
                env.lambda.finish_invocation(invs[w], end, alloc_mb, &mut env.ledger);
            }
        }

        let epoch_secs = env.max_clock() - start;
        Ok(EpochStats {
            mean_loss: (loss_n > 0).then(|| loss_sum / loss_n as f64),
            batches: env.batches_per_epoch * w_count,
            epoch_secs,
            mean_fn_secs: env.lambda.mean_duration(),
        })
    }

    fn stage_table(&self) -> Vec<(Stage, &'static str)> {
        vec![
            (Stage::FetchDataset, "Each worker fetches a minibatch to process."),
            (
                Stage::ComputeGradients,
                "Gradients are computed and divided into chunks, one per peer; workers retain \
                 one chunk and send the rest to the database.",
            ),
            (
                Stage::Synchronize,
                "Workers fetch chunks assigned to them, aggregate, send the result back, then \
                 retrieve and concatenate all aggregated chunks to form the full gradient.",
            ),
            (Stage::ModelUpdate, "The full aggregated gradient is used to update the model."),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::env::EnvConfig;

    fn env(workers: usize, arch: &str) -> ClusterEnv {
        ClusterEnv::new(
            EnvConfig::virtual_paper(FrameworkKind::ScatterReduce, arch, workers).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn epoch_matches_paper_batch_duration() {
        let mut e = env(4, "mobilenet");
        let stats = ScatterReduce::new().run_epoch(&mut e).unwrap();
        assert!(
            (stats.mean_fn_secs - 14.343).abs() / 14.343 < 0.15,
            "mean fn {:.2}s vs paper 14.343s",
            stats.mean_fn_secs
        );
    }

    #[test]
    fn chunk_traffic_is_balanced() {
        // Unlike AllReduce there is no single hot worker: clocks end close.
        let mut e = env(4, "resnet18");
        ScatterReduce::new().run_epoch(&mut e).unwrap();
        let clocks: Vec<f64> = e.workers.iter().map(|w| w.clock.secs()).collect();
        let max = clocks.iter().cloned().fold(0.0, f64::max);
        let min = clocks.iter().cloned().fold(f64::MAX, f64::min);
        assert!((max - min) / max < 0.05, "imbalance: {clocks:?}");
    }

    #[test]
    fn request_count_grows_with_workers() {
        let mut a = env(4, "mobilenet");
        ScatterReduce::new().run_epoch(&mut a).unwrap();
        let mut b = env(8, "mobilenet");
        ScatterReduce::new().run_epoch(&mut b).unwrap();
        // ops per worker per round ~ 3(W-1)+1: grows superlinearly in total
        assert!(b.comm.total_ops() > 2 * a.comm.total_ops());
    }
}
