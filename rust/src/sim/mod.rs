//! Virtual-time simulation core.
//!
//! Experiments report time on the *paper's* axis (seconds of AWS wall time),
//! not the testbed's CPU wall time. Every substrate operation charges a
//! modeled duration to the calling worker's [`VTime`] clock; synchronization
//! points take the max across clocks; shared services are queueing
//! [`Resource`]s whose servers have `next_free` times. Because every duration
//! is a pure function of the operation (no host clock reads), a seeded run is
//! bit-for-bit reproducible.

pub mod resource;
pub mod sched;
pub mod vtime;

pub use resource::{Resource, Served};
pub use sched::{EventQueue, OrderLog};
pub use vtime::VTime;

/// Advance all clocks to the max (a synchronization barrier). Returns the
/// barrier time.
pub fn barrier(clocks: &mut [VTime]) -> VTime {
    let t = clocks.iter().copied().fold(VTime::ZERO, VTime::max);
    for c in clocks.iter_mut() {
        *c = t;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barrier_takes_max_and_aligns() {
        let mut clocks = [VTime::from_secs(1.0), VTime::from_secs(5.0), VTime::from_secs(2.0)];
        let t = barrier(&mut clocks);
        assert_eq!(t, VTime::from_secs(5.0));
        assert!(clocks.iter().all(|c| *c == t));
    }

    #[test]
    fn barrier_is_idempotent() {
        let mut clocks = [VTime::from_secs(3.0), VTime::from_secs(3.0)];
        let t1 = barrier(&mut clocks);
        let t2 = barrier(&mut clocks);
        assert_eq!(t1, t2);
    }
}
