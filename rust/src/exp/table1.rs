//! Table 1: stage-by-stage workflow comparison (qualitative).
//!
//! The stage contents live on the `Strategy` implementations themselves;
//! this driver renders them side by side, proving the code structure *is*
//! the paper's Table 1.

use crate::cloud::FrameworkKind;
use crate::coordinator::strategy_for;
use crate::metrics::Stage;
use crate::report::{Align, Cell, Report, Table};

/// Build the Table 1 report from the strategies' own stage descriptions.
pub fn report() -> Report {
    let mut t = Table::new(
        "stages",
        &[("Framework", Align::Left), ("Stage", Align::Left), ("Content", Align::Left)],
    )
    .title("Table 1 — Key computational stages per framework");
    for (i, kind) in FrameworkKind::ALL.iter().enumerate() {
        if i > 0 {
            t.rule();
        }
        let strat = strategy_for(*kind);
        for (stage, content) in strat.stage_table() {
            t.push_row(vec![
                Cell::text(kind.name()),
                Cell::text(stage.to_string()),
                Cell::text(wrap(content, 78)),
            ]);
        }
    }
    Report::new(
        "table1",
        "Table 1 — Key computational stages per framework",
        "slsgpu exp table1",
    )
    .with_intro(
        "Qualitative workflow comparison: what each framework does in the paper's four \
         Fig.-1 stages (fetch → compute → synchronize → update). The stage contents are \
         read off the `Strategy` implementations at run time, so this table documents \
         the code structure itself — it cannot drift from what the simulator executes.",
    )
    .with_table(t)
}

/// Legacy CLI view of [`report`].
pub fn render() -> String {
    report().to_text()
}

fn wrap(text: &str, _width: usize) -> String {
    // Single-line cell (terminal tables stay readable unwrapped).
    text.split_whitespace().collect::<Vec<_>>().join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_frameworks_and_stages() {
        let s = render();
        for kind in FrameworkKind::ALL {
            assert!(s.contains(kind.name()), "missing {}", kind.name());
        }
        for stage in Stage::ALL {
            assert!(s.contains(&stage.to_string()), "missing {stage}");
        }
        // Signature details from the paper's Table 1.
        assert!(s.contains("averaged within the database")); // SPIRT
        assert!(s.contains("significant")); // MLLess
        assert!(s.contains("master")); // AllReduce
        assert!(s.contains("chunks")); // ScatterReduce
        assert!(s.contains("S3 bucket")); // GPU

        // Qualitative table: no paper anchors, so no overall status.
        assert_eq!(report().status(), None);
    }
}
