//! Protocol-trace experiment: run every architecture with tracing on and
//! analyze the event log three ways — the per-epoch critical path (which
//! worker/op chain bounds the epoch), per-op-kind latency percentiles, and
//! the cost attribution of the trace (how much of the ledger the protocol
//! spans explain).
//!
//! The Chrome export ([`chrome_export`]) serializes the same runs as a
//! Perfetto-loadable trace-event file: one process per architecture, one
//! track per worker (plus a supervisor track), faults as instant markers.

use crate::cloud::FrameworkKind;
use crate::coordinator::{strategy_for, ClusterEnv, EnvConfig, SyncMode};
use crate::faults::SUPERVISOR;
use crate::report::{Align, Cell, Report, Section, Table};
use crate::trace::chrome::{self, ChromeRun};
use crate::trace::critical_path::{self, EpochPath};
use crate::trace::histogram::{self, KindStats};
use crate::trace::{TraceConfig, TraceEvent};
use crate::Result;

/// Trace-run parameters (one deterministic simulation per architecture).
#[derive(Debug, Clone)]
pub struct TraceRunConfig {
    /// Calibrated architecture profile (`mobilenet`, `resnet18`, ...).
    pub arch: String,
    /// Worker count (paper: 4).
    pub workers: usize,
    /// Gradient batches per worker per epoch (paper: 24).
    pub batches_per_epoch: usize,
    /// Epochs simulated (each gets its own critical path).
    pub epochs: usize,
    /// Synchronization policy.
    pub mode: SyncMode,
}

impl Default for TraceRunConfig {
    fn default() -> Self {
        TraceRunConfig {
            arch: "mobilenet".to_string(),
            workers: 4,
            batches_per_epoch: 24,
            epochs: 2,
            mode: SyncMode::Bsp,
        }
    }
}

/// One architecture's traced run and its derived analyses.
#[derive(Debug, Clone)]
pub struct ArchTrace {
    pub framework: FrameworkKind,
    pub workers: usize,
    /// The raw event log (ring-buffer snapshot, oldest first).
    pub events: Vec<TraceEvent>,
    /// One critical path per simulated epoch.
    pub paths: Vec<EpochPath>,
    /// Latency/cost summary per op kind.
    pub kinds: Vec<KindStats>,
    /// Full ledger total at the end of the run (USD).
    pub total_cost: f64,
    /// Cost attributed to traced spans (USD); the residual is billing that
    /// lands outside protocol ops (invocation billing, fleet hours, Step
    /// Functions transitions).
    pub attributed_cost: f64,
    /// Mean epoch wall time on the virtual timeline (seconds).
    pub epoch_secs: f64,
}

/// Trace one architecture under `cfg`.
pub fn run_one(cfg: &TraceRunConfig, fw: FrameworkKind) -> Result<ArchTrace> {
    let mut ec = EnvConfig::virtual_paper(fw, &cfg.arch, cfg.workers)?
        .with_sync(cfg.mode)
        .with_trace(TraceConfig::on());
    ec.batches_per_epoch = cfg.batches_per_epoch;
    let mut env = ClusterEnv::new(ec)?;
    let mut strategy = strategy_for(fw);
    let epochs = cfg.epochs.max(1);
    let mut epoch_secs = 0.0;
    for _ in 0..epochs {
        epoch_secs += strategy.run_epoch(&mut env)?.epoch_secs;
    }
    let paths = critical_path::analyze(&env.trace);
    let kinds = histogram::kind_stats(env.trace.events());
    let attributed_cost = env.trace.events().map(|e| e.cost).sum();
    Ok(ArchTrace {
        framework: fw,
        workers: cfg.workers,
        events: env.trace.snapshot(),
        paths,
        kinds,
        total_cost: env.ledger.total_full(),
        attributed_cost,
        epoch_secs: epoch_secs / epochs as f64,
    })
}

/// Trace all five architectures (canonical order).
pub fn run(cfg: &TraceRunConfig) -> Result<Vec<ArchTrace>> {
    FrameworkKind::ALL.iter().map(|&fw| run_one(cfg, fw)).collect()
}

/// Trace a subset (the CLI's `--arch <name>` path).
pub fn run_for(cfg: &TraceRunConfig, frameworks: &[FrameworkKind]) -> Result<Vec<ArchTrace>> {
    frameworks.iter().map(|&fw| run_one(cfg, fw)).collect()
}

fn worker_label(w: usize) -> String {
    if w == SUPERVISOR {
        "sup".to_string()
    } else {
        format!("w{w}")
    }
}

/// Build the trace report: critical paths, op-kind percentiles, and the
/// span-attributed share of the ledger. No paper anchors — the paper never
/// instruments its runs at this granularity.
pub fn report(traces: &[ArchTrace], cfg: &TraceRunConfig) -> Report {
    let mut cp = Table::new(
        "trace_critical_path",
        &[
            ("Framework", Align::Left),
            ("Epoch", Align::Right),
            ("Bound by", Align::Left),
            ("Span", Align::Right),
            ("Critical chain (terminal first)", Align::Left),
            ("Dominant self-time", Align::Left),
        ],
    )
    .title(format!(
        "Per-epoch critical path — {} profile, {} workers, {} batches/epoch, {}",
        cfg.arch,
        cfg.workers,
        cfg.batches_per_epoch,
        cfg.mode.label()
    ));
    let mut first = true;
    for t in traces {
        if !first {
            cp.rule();
        }
        first = false;
        for p in &t.paths {
            cp.push_row(vec![
                Cell::text(t.framework.name()),
                Cell::count(p.epoch as u64),
                Cell::text(worker_label(p.bound_worker)),
                Cell::text(format!("{:.1}s", p.span_secs())).with_value(p.span_secs()),
                Cell::text(critical_path::describe(p, 4)),
                Cell::text(critical_path::dominant(p, 2)),
            ]);
        }
    }

    let mut lat = Table::new(
        "trace_latency",
        &[
            ("Framework", Align::Left),
            ("Op", Align::Left),
            ("Count", Align::Right),
            ("p50 (ms)", Align::Right),
            ("p95 (ms)", Align::Right),
            ("p99 (ms)", Align::Right),
            ("max (ms)", Align::Right),
            ("Total (s)", Align::Right),
            ("Cost ($)", Align::Right),
        ],
    )
    .title("Per-op-kind latency percentiles (nearest-rank) and attributed cost");
    let mut first = true;
    for t in traces {
        if !first {
            lat.rule();
        }
        first = false;
        for k in &t.kinds {
            lat.push_row(vec![
                Cell::text(t.framework.name()),
                Cell::text(k.kind.name()),
                Cell::count(k.count),
                Cell::num(k.p50_ms, 2),
                Cell::num(k.p95_ms, 2),
                Cell::num(k.p99_ms, 2),
                Cell::num(k.max_ms, 2),
                Cell::num(k.total_secs, 2),
                Cell::num(k.total_cost, 4),
            ]);
        }
    }

    let mut cost = Table::new(
        "trace_cost",
        &[
            ("Framework", Align::Left),
            ("Events", Align::Right),
            ("Epoch", Align::Right),
            ("Attributed ($)", Align::Right),
            ("Ledger ($)", Align::Right),
            ("Residual ($)", Align::Right),
        ],
    )
    .title("Cost attribution: ledger share explained by traced protocol spans");
    for t in traces {
        cost.push_row(vec![
            Cell::text(t.framework.name()),
            Cell::count(t.events.len() as u64),
            Cell::text(crate::util::fmt_duration(t.epoch_secs)).with_value(t.epoch_secs),
            Cell::num(t.attributed_cost, 4),
            Cell::num(t.total_cost, 4),
            Cell::num(t.total_cost - t.attributed_cost, 4),
        ]);
    }

    Report::new(
        "trace",
        "Protocol trace — critical path and op latency percentiles",
        format!(
            "slsgpu trace --arch all --model {} --workers {} --batches {} --epochs {}",
            cfg.arch, cfg.workers, cfg.batches_per_epoch, cfg.epochs
        ),
    )
    .with_intro(
        "Every protocol op, stage span and fault event of a traced run lands in a \
         deterministic structured event log (see DESIGN.md, trace layer). Three views \
         of that log: the per-epoch critical path (the happens-before chain of events \
         that bounds the epoch — which worker, which ops), per-op-kind latency \
         percentiles, and the share of the billing ledger attributable to individual \
         protocol spans. The residual is billing that has no single op to attach to \
         (Lambda invocation billing, GPU fleet hours, Step Functions transitions). \
         Tracing is opt-in and purely observational: timelines and costs are \
         bit-identical with it on or off (asserted in `rust/tests/determinism.rs`).",
    )
    .with_section(
        Section::new()
            .heading("Critical paths")
            .paragraph(
                "The chain is read right to left: each step waited on the one after it \
                 (a put the get was gated on, the slowest worker at a barrier, the \
                 previous in-DB accumulation). `Bound by` names the worker whose event \
                 ends the epoch; `sup` is the MLLess supervisor.",
            )
            .table(cp),
    )
    .with_section(Section::new().heading("Op latency").table(lat))
    .with_section(Section::new().heading("Cost attribution").table(cost))
}

/// Legacy CLI view of [`report`].
pub fn render(traces: &[ArchTrace], cfg: &TraceRunConfig) -> String {
    report(traces, cfg).to_text()
}

/// Chrome trace-event JSON over the runs (`chrome://tracing` / Perfetto).
pub fn chrome_export(traces: &[ArchTrace]) -> String {
    let runs: Vec<ChromeRun> = traces
        .iter()
        .map(|t| ChromeRun {
            label: t.framework.name().to_string(),
            workers: t.workers,
            events: t.events.clone(),
        })
        .collect();
    chrome::render(&runs)
}

/// CSV export: one row per (framework, op kind).
pub fn render_csv(traces: &[ArchTrace]) -> String {
    let mut out = String::from(
        "framework,kind,count,p50_ms,p95_ms,p99_ms,max_ms,total_secs,total_cost\n",
    );
    for t in traces {
        for k in &t.kinds {
            out.push_str(&format!(
                "{},{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6}\n",
                t.framework.name(),
                k.kind.name(),
                k.count,
                k.p50_ms,
                k.p95_ms,
                k.p99_ms,
                k.max_ms,
                k.total_secs,
                k.total_cost
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> TraceRunConfig {
        TraceRunConfig {
            arch: "mobilenet".to_string(),
            workers: 4,
            batches_per_epoch: 4,
            epochs: 2,
            mode: SyncMode::Bsp,
        }
    }

    #[test]
    fn every_architecture_yields_paths_and_percentiles() {
        let traces = run(&small_cfg()).unwrap();
        assert_eq!(traces.len(), FrameworkKind::ALL.len());
        for t in &traces {
            assert!(!t.events.is_empty(), "{:?}", t.framework);
            assert_eq!(t.paths.len(), 2, "{:?}: one path per epoch", t.framework);
            for p in &t.paths {
                assert!(!p.steps.is_empty());
                assert!(p.span_secs() > 0.0);
                assert!(critical_path::describe(p, 4).contains(':'));
            }
            assert!(!t.kinds.is_empty());
            assert!(t.attributed_cost >= 0.0);
            assert!(t.attributed_cost <= t.total_cost + 1e-9, "{:?}", t.framework);
        }
        let text = render(&traces, &small_cfg());
        assert!(text.contains("Critical chain"), "{text}");
    }

    #[test]
    fn report_title_matches_suite_canonical_title() {
        let traces = run_for(&small_cfg(), &[FrameworkKind::Spirt]).unwrap();
        let r = report(&traces, &small_cfg());
        assert_eq!(r.title, crate::report::suite::canonical_title("trace"));
    }

    #[test]
    fn chrome_and_csv_exports_are_non_trivial() {
        let traces = run_for(&small_cfg(), &[FrameworkKind::AllReduce]).unwrap();
        let chrome = chrome_export(&traces);
        assert!(chrome.contains("\"traceEvents\""));
        assert!(chrome.contains("AllReduce"));
        let csv = render_csv(&traces);
        assert!(csv.lines().count() > 3, "{csv}");
    }
}
