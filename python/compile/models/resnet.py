"""ResNet-18/50 adapted to CIFAR-scale 32x32 inputs (He et al. 2016).

CIFAR stem (3x3 stride-1, no max-pool); four stages with strides 1/2/2/2 so
32x32 ends at 4x4 before global pooling. ResNet-18 uses BasicBlocks,
ResNet-50 Bottlenecks. Projection shortcuts and all bottleneck 1x1 convs are
Pallas-matmul GEMMs; GroupNorm replaces BatchNorm (see models/__init__.py).

ResNet-50 exists in the zoo primarily as the large-gradient workload of the
paper's Fig. 2 communication study (25.6M params); it is lowered/executed only
at reduced width.
"""

import jax

from . import layers as L


def _basic_block(keys, cin, cout, stride):
    p = {
        "conv1": L.init_conv(keys[0], 3, 3, cin, cout),
        "gn1": L.init_groupnorm(cout),
        "conv2": L.init_conv(keys[1], 3, 3, cout, cout),
        "gn2": L.init_groupnorm(cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = L.init_pointwise(keys[2], cin, cout)
        p["proj_gn"] = L.init_groupnorm(cout)
    return p


def _apply_basic(p, x, stride):
    out = L.relu(L.groupnorm(p["gn1"], L.conv(p["conv1"], x, stride)))
    out = L.groupnorm(p["gn2"], L.conv(p["conv2"], out))
    if "proj" in p:
        # Strided projection: subsample spatially, then 1x1 GEMM.
        sc = x[:, ::stride, ::stride, :] if stride != 1 else x
        sc = L.groupnorm(p["proj_gn"], L.pointwise(p["proj"], sc))
    else:
        sc = x
    return L.relu(out + sc)


def _bottleneck_block(keys, cin, cmid, cout, stride):
    p = {
        "pw1": L.init_pointwise(keys[0], cin, cmid),
        "gn1": L.init_groupnorm(cmid),
        "conv2": L.init_conv(keys[1], 3, 3, cmid, cmid),
        "gn2": L.init_groupnorm(cmid),
        "pw3": L.init_pointwise(keys[2], cmid, cout),
        "gn3": L.init_groupnorm(cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = L.init_pointwise(keys[3], cin, cout)
        p["proj_gn"] = L.init_groupnorm(cout)
    return p


def _apply_bottleneck(p, x, stride):
    out = L.relu(L.groupnorm(p["gn1"], L.pointwise(p["pw1"], x)))
    out = L.relu(L.groupnorm(p["gn2"], L.conv(p["conv2"], out, stride)))
    out = L.groupnorm(p["gn3"], L.pointwise(p["pw3"], out))
    if "proj" in p:
        sc = x[:, ::stride, ::stride, :] if stride != 1 else x
        sc = L.groupnorm(p["proj_gn"], L.pointwise(p["proj"], sc))
    else:
        sc = x
    return L.relu(out + sc)


def _resnet(stage_blocks, bottleneck, width, num_classes):
    base = [64, 128, 256, 512]
    chans = [max(8, int(c * width)) for c in base]
    expansion = 4 if bottleneck else 1

    def init(key):
        nkeys = 2 + sum(stage_blocks) * 4
        keys = jax.random.split(key, nkeys)
        ki = 0

        def take(n):
            nonlocal ki
            out = keys[ki : ki + n]
            ki += n
            return out

        stem_ch = chans[0]
        params = {
            "stem": {
                "conv": L.init_conv(take(1)[0], 3, 3, 3, stem_ch),
                "gn": L.init_groupnorm(stem_ch),
            },
            "stages": [],
        }
        cin = stem_ch
        for si, nblocks in enumerate(stage_blocks):
            stage = []
            for bi in range(nblocks):
                stride = 2 if (si > 0 and bi == 0) else 1
                if bottleneck:
                    cmid = chans[si]
                    cout = chans[si] * expansion
                    stage.append(_bottleneck_block(take(4), cin, cmid, cout, stride))
                else:
                    cout = chans[si]
                    stage.append(_basic_block(take(3), cin, cout, stride))
                cin = cout
            params["stages"].append(stage)
        params["head"] = L.init_dense(take(1)[0], cin, num_classes)
        return params

    def apply(params, x):
        x = L.relu(L.groupnorm(params["stem"]["gn"], L.conv(params["stem"]["conv"], x)))
        for si, stage in enumerate(params["stages"]):
            for bi, blk in enumerate(stage):
                stride = 2 if (si > 0 and bi == 0) else 1
                if bottleneck:
                    x = _apply_bottleneck(blk, x, stride)
                else:
                    x = _apply_basic(blk, x, stride)
        x = L.global_avg_pool(x)
        return L.dense(params["head"], x)

    return init, apply


def resnet18(width=1.0, num_classes=10):
    """BasicBlock ResNet-18: stages [2,2,2,2] (11.2M params at width=1)."""
    return _resnet([2, 2, 2, 2], bottleneck=False, width=width, num_classes=num_classes)


def resnet50(width=1.0, num_classes=10):
    """Bottleneck ResNet-50: stages [3,4,6,3] (the Fig. 2 large model)."""
    return _resnet([3, 4, 6, 3], bottleneck=True, width=width, num_classes=num_classes)
