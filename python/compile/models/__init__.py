"""Layer-2 model zoo: MobileNet-v1 and ResNet-18/50 adapted to 32x32 inputs.

Models are plain functional JAX: ``init(key) -> params`` pytrees and
``apply(params, x) -> logits``. BatchNorm is replaced by GroupNorm (stateless,
identical train/eval behaviour) so the flat-parameter ABI carries no running
statistics — the standard substitution for parameter-server-style training
where optimizer state must be an opaque slab.

Pointwise (1x1) convolutions, projection shortcuts and the classifier head
run through the Pallas matmul kernel (kernels.matmul); spatial 3x3 and
depthwise convolutions use lax.conv_general_dilated, which XLA already lowers
optimally on every backend.
"""

from .mobilenet import mobilenet
from .resnet import resnet18, resnet50

ARCHS = {
    "mobilenet": mobilenet,
    "resnet18": resnet18,
    "resnet50": resnet50,
}

__all__ = ["mobilenet", "resnet18", "resnet50", "ARCHS"]
