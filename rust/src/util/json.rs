//! Minimal JSON parser + writer (no external deps).
//!
//! Parses the artifact `manifest.json` emitted by `python/compile/aot.py`
//! and serializes experiment reports. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (not needed for our inputs).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value. Objects use `BTreeMap` for deterministic iteration.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors -----------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("expected object while looking up {key:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected {:?} at byte {}, got {:?}", b as char, self.pos - 1, got as char);
        }
        Ok(())
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump()?;
                            let digit = (d as char)
                                .to_digit(16)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            code = code * 16 + digit;
                        }
                        out.push(char::from_u32(code).ok_or_else(|| anyhow!("bad codepoint"))?);
                    }
                    c => bail!("bad escape \\{}", c as char),
                },
                c if c < 0x20 => bail!("control character in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences byte by byte.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        bail!("truncated UTF-8 sequence");
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// Writer

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{item}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_through_display() {
        let src = r#"{"models":{"m":{"n_params":215642,"batch":64}},"ok":true,"x":[1.5,-2]}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(v, Json::Str("café é".into()));
    }

    #[test]
    fn usize_accessor_guards() {
        assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
        assert!(Json::parse("4.2").unwrap().as_usize().is_err());
        assert!(Json::parse("-1").unwrap().as_usize().is_err());
    }
}
