"""Elementwise gradient-aggregation Pallas kernels (the "RedisAI ops").

SPIRT's headline optimization is *in-database* gradient math: the Redis
instance that stores worker gradients also averages them and applies the SGD
update, so gradients are never shuttled out to the function runtime
(§4.2: averaging 67.32s -> 37.41s, update 27.5s -> 4.8s vs the naive
fetch-update-store baseline). In this reproduction the Redis substrate
(rust/src/cloud/redis.rs) embeds PJRT executables of exactly these kernels —
the "in-database computation" runs real compiled code on real bytes.

All three kernels stream flat f32 slabs through VMEM in BLOCK-element tiles
(1-D grid). Slab length is padded to a tile multiple by the wrappers; padding
lanes are mathematically inert (they are sliced away on return).

  accumulate(acc, g, w)            -> acc + w * g        (k-way sum, axpy)
  fused_avg_update(theta, gsum,
                   inv_k, lr)      -> theta - lr*(inv_k*gsum)
  sgd_update(theta, g, lr)         -> theta - lr * g
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 64K f32 = 256 KiB per resident block; three operands keep the working set
# under 1 MiB, far below the ~16 MiB VMEM of a TPU core — the schedule is
# bandwidth-bound by construction (see EXPERIMENTS.md §Perf).
BLOCK = 65536


def _ceil_to(value: int, mult: int) -> int:
    return ((value + mult - 1) // mult) * mult


def _axpy_kernel(acc_ref, g_ref, w_ref, o_ref):
    o_ref[...] = acc_ref[...] + w_ref[0] * g_ref[...]


def _fused_avg_update_kernel(theta_ref, gsum_ref, inv_k_ref, lr_ref, o_ref):
    # One fused pass: scale the gradient sum to a mean and apply SGD, so the
    # slab crosses HBM<->VMEM once instead of twice.
    o_ref[...] = theta_ref[...] - lr_ref[0] * (inv_k_ref[0] * gsum_ref[...])


def _sgd_kernel(theta_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = theta_ref[...] - lr_ref[0] * g_ref[...]


def _elementwise_call(kernel, vecs, scalars):
    """Run `kernel` over equal-length flat vectors + broadcast scalars."""
    n = vecs[0].shape[0]
    block = min(BLOCK, _ceil_to(n, 8))
    np_ = _ceil_to(n, block)
    padded = [jnp.pad(v, (0, np_ - n)) for v in vecs]
    scal = [jnp.reshape(s, (1,)).astype(jnp.float32) for s in scalars]

    vec_specs = [pl.BlockSpec((block,), lambda i: (i,)) for _ in padded]
    # Scalars are replicated to every grid step (block index 0 of a len-1 arr).
    scal_specs = [pl.BlockSpec((1,), lambda i: (0,)) for _ in scal]

    out = pl.pallas_call(
        kernel,
        grid=(np_ // block,),
        in_specs=vec_specs + scal_specs,
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=True,
    )(*padded, *scal)
    return out[:n]


@jax.jit
def accumulate(acc, g, w):
    """acc + w*g — the incremental k-way aggregation step (axpy)."""
    return _elementwise_call(_axpy_kernel, [acc, g], [w])


@jax.jit
def fused_avg_update(theta, gsum, inv_k, lr):
    """theta - lr * (inv_k * gsum) — SPIRT's fused in-database op."""
    return _elementwise_call(_fused_avg_update_kernel, [theta, gsum], [inv_k, lr])


@jax.jit
def sgd_update(theta, g, lr):
    """Plain SGD step on a flat parameter slab."""
    return _elementwise_call(_sgd_kernel, [theta, g], [lr])
