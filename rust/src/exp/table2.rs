//! Table 2: training time, peak RAM and cost per epoch — all five
//! frameworks × {MobileNet, ResNet-18} at the paper's scale (B=512, 4
//! workers × 24 batches, AWS pricing).

use crate::cloud::calibration::{peak_ram_mb, profile, FrameworkKind};
use crate::coordinator::{strategy_for, ClusterEnv, EnvConfig};
use crate::metrics::CostKind;
use crate::report::{Align, Cell, Report, Table};
use crate::Result;

/// Tolerances for the paper-anchored columns — the same bands the unit
/// tests below assert, so a WARN in `docs/` and a failing test share a
/// boundary. The cost band is 30% because the paper's AllReduce /
/// ScatterReduce cost cells are internally inconsistent with its own
/// GB-second formula (see `costs_within_30pct_of_paper`).
pub const PER_BATCH_TOL: f64 = 0.15;
pub const COST_TOL: f64 = 0.30;
/// Peak-RAM band (EXPERIMENTS.md: within 7% of the paper's figures).
pub const RAM_TOL: f64 = 0.07;

/// One Table 2 row.
#[derive(Debug, Clone)]
pub struct Row {
    pub framework: FrameworkKind,
    pub arch: String,
    /// Mean per-function duration (s); epoch wall time for the GPU row.
    pub per_batch_secs: f64,
    /// Per-worker serial sum over 24 batches (the paper's "Total Time").
    pub total_time_secs: f64,
    pub peak_ram_mb: Option<f64>,
    pub cost_per_worker_usd: f64,
    pub total_cost_usd: f64,
}

/// Paper's Table 2 values for the comparison columns:
/// (framework, arch) -> (per-batch s, peak RAM MB, total cost USD).
pub fn paper_row(fw: FrameworkKind, arch: &str) -> (f64, f64, f64) {
    match (fw, arch) {
        (FrameworkKind::Spirt, "mobilenet") => (15.44, 2685.0, 0.0660),
        (FrameworkKind::ScatterReduce, "mobilenet") => (14.343, 2048.0, 0.0422),
        (FrameworkKind::AllReduce, "mobilenet") => (14.382, 2048.0, 0.0427),
        (FrameworkKind::MlLess, "mobilenet") => (69.425, 3024.0, 0.3356),
        (FrameworkKind::GpuBaseline, "mobilenet") => (92.0, 0.0, 0.0538),
        (FrameworkKind::Spirt, "resnet18") => (28.55, 3200.0, 0.1460),
        (FrameworkKind::ScatterReduce, "resnet18") => (27.17, 2880.0, 0.1249),
        (FrameworkKind::AllReduce, "resnet18") => (26.79, 2986.0, 0.1328),
        (FrameworkKind::MlLess, "resnet18") => (78.39, 3630.0, 0.4548),
        (FrameworkKind::GpuBaseline, "resnet18") => (139.0, 0.0, 0.0812),
        _ => (0.0, 0.0, 0.0),
    }
}

/// Run one (framework, arch) cell of Table 2 for a single epoch.
pub fn run_cell(fw: FrameworkKind, arch: &str, workers: usize) -> Result<Row> {
    let mut env = ClusterEnv::new(EnvConfig::virtual_paper(fw, arch, workers)?)?;
    let mut strategy = strategy_for(fw);
    let stats = strategy.run_epoch(&mut env)?;

    let (per_batch, total_time) = if fw == FrameworkKind::GpuBaseline {
        (stats.epoch_secs, stats.epoch_secs)
    } else {
        (stats.mean_fn_secs, stats.mean_fn_secs * env.batches_per_epoch as f64)
    };
    let total_cost = env.ledger.total_paper();
    let cost_per_worker = if fw == FrameworkKind::GpuBaseline {
        env.ledger.get(CostKind::Ec2Gpu) / workers as f64
    } else {
        total_cost / workers as f64
    };
    let prof = profile(arch).unwrap();
    Ok(Row {
        framework: fw,
        arch: arch.to_string(),
        per_batch_secs: per_batch,
        total_time_secs: total_time,
        peak_ram_mb: (fw != FrameworkKind::GpuBaseline).then(|| peak_ram_mb(fw, &prof, 512)),
        cost_per_worker_usd: cost_per_worker,
        total_cost_usd: total_cost,
    })
}

/// Run the full table.
pub fn run(workers: usize) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for arch in ["mobilenet", "resnet18"] {
        for fw in FrameworkKind::ALL {
            rows.push(run_cell(fw, arch, workers)?);
        }
    }
    Ok(rows)
}

/// Build the paper-vs-measured report (anchored on per-batch duration,
/// peak RAM and total cost). `workers` is the count the rows were run
/// with, so the rendered title and reproduce command match the data.
pub fn report(rows: &[Row], workers: usize) -> Report {
    let mut t = Table::new(
        "table2",
        &[
            ("Framework", Align::Left),
            ("Per-batch (s)", Align::Right),
            ("Total time (s)", Align::Right),
            ("Peak RAM (MB)", Align::Right),
            ("Cost/worker ($)", Align::Right),
            ("Total cost ($)", Align::Right),
            ("Paper total ($)", Align::Right),
        ],
    )
    .title(format!(
        "Table 2 — Training time, peak RAM and cost per epoch (B=512, {workers} workers x \
         24 batches)"
    ));

    let mut last_arch = String::new();
    for row in rows {
        if row.arch != last_arch {
            if !last_arch.is_empty() {
                t.rule();
            }
            last_arch = row.arch.clone();
        }
        let (paper_batch, paper_ram, paper_cost) = paper_row(row.framework, &row.arch);
        let ram_cell = match row.peak_ram_mb {
            Some(m) if paper_ram > 0.0 => Cell::anchored(format!("{m:.0}"), m, paper_ram, RAM_TOL),
            Some(m) => Cell::num(m, 0),
            None => Cell::text("N/A"),
        };
        t.push_row(vec![
            Cell::text(format!("{} [{}]", row.framework.name(), row.arch)),
            Cell::anchored(
                format!("{:.2} (paper {:.2})", row.per_batch_secs, paper_batch),
                row.per_batch_secs,
                paper_batch,
                PER_BATCH_TOL,
            ),
            Cell::num(row.total_time_secs, 1),
            ram_cell,
            Cell::num(row.cost_per_worker_usd, 4),
            Cell::anchored(
                format!("{:.4}", row.total_cost_usd),
                row.total_cost_usd,
                paper_cost,
                COST_TOL,
            ),
            Cell::num(paper_cost, 4),
        ]);
    }
    Report::new(
        "table2",
        "Table 2 — Training time, peak RAM and cost per epoch",
        format!("slsgpu exp table2 --workers {workers}"),
    )
    .with_intro(format!(
        "All five frameworks × {{MobileNet, ResNet-18}} at the paper's scale (B=512, \
         {workers} workers × 24 batches, AWS pricing). Per-batch durations and total \
         costs are anchored to the paper's Table 2; Peak RAM uses the calibrated \
         per-framework memory model. Time is virtual (the paper's AWS axis); costs \
         follow the paper's own GB-second + request-fee formulas."
    ))
    .with_table(t)
}

/// Legacy CLI view of [`report`] at the paper's 4-worker scale (the shape
/// the benches and tests reference).
pub fn render(rows: &[Row]) -> String {
    report(rows, 4).to_text()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_shapes_hold() {
        let rows = run(4).unwrap();
        let cost = |fw: FrameworkKind, arch: &str| {
            rows.iter()
                .find(|r| r.framework == fw && r.arch == arch)
                .unwrap()
                .total_cost_usd
        };
        // Finding 1: serverless (LambdaML) beats GPU on cost for MobileNet…
        let gpu_mobilenet = cost(FrameworkKind::GpuBaseline, "mobilenet");
        assert!(cost(FrameworkKind::ScatterReduce, "mobilenet") < gpu_mobilenet);
        assert!(cost(FrameworkKind::AllReduce, "mobilenet") < gpu_mobilenet);
        // …but GPU wins for ResNet-18 (crossover).
        for fw in [
            FrameworkKind::Spirt,
            FrameworkKind::MlLess,
            FrameworkKind::AllReduce,
            FrameworkKind::ScatterReduce,
        ] {
            assert!(
                cost(fw, "resnet18") > cost(FrameworkKind::GpuBaseline, "resnet18"),
                "{fw:?} should cost more than GPU on resnet18"
            );
        }
        // Finding 2: MLLess is the most expensive serverless variant.
        for arch in ["mobilenet", "resnet18"] {
            for fw in
                [FrameworkKind::Spirt, FrameworkKind::AllReduce, FrameworkKind::ScatterReduce]
            {
                assert!(cost(FrameworkKind::MlLess, arch) > cost(fw, arch));
            }
        }
    }

    #[test]
    fn per_batch_durations_within_15pct_of_paper() {
        let rows = run(4).unwrap();
        for row in &rows {
            let (paper_batch, _, _) = paper_row(row.framework, &row.arch);
            let err = super::super::rel_err(row.per_batch_secs, paper_batch);
            assert!(
                err < 0.15,
                "{:?}/{}: {:.2}s vs paper {:.2}s ({:.0}%)",
                row.framework,
                row.arch,
                row.per_batch_secs,
                paper_batch,
                err * 100.0
            );
        }
    }

    #[test]
    fn costs_within_30pct_of_paper() {
        // Note: the paper's AllReduce/ScatterReduce cost cells are
        // internally inconsistent with its own formula (14.343 s × 2.048 GB
        // × $0.0000166667 = $0.00049/function, not the printed $0.000442),
        // so a 30% band is the tightest defensible tolerance there; the
        // self-consistent rows (SPIRT, MLLess, GPU) land within ~10%.
        let rows = run(4).unwrap();
        for row in &rows {
            let (_, _, paper_cost) = paper_row(row.framework, &row.arch);
            let err = super::super::rel_err(row.total_cost_usd, paper_cost);
            assert!(
                err < 0.30,
                "{:?}/{}: ${:.4} vs paper ${:.4} ({:.0}%)",
                row.framework,
                row.arch,
                row.total_cost_usd,
                paper_cost,
                err * 100.0
            );
        }
    }

    #[test]
    fn render_contains_all_rows() {
        let rows = run(4).unwrap();
        let s = render(&rows);
        assert!(s.contains("SPIRT [mobilenet]"));
        assert!(s.contains("GPU (g4dn.xlarge) [resnet18]"));
    }

    #[test]
    fn report_anchors_duration_and_cost_on_every_row() {
        let rows = run(4).unwrap();
        let r = report(&rows, 4);
        let (pass, warn) = r.verdicts();
        // Per-batch + total cost anchored on all 10 rows, RAM on the 8
        // serverless rows.
        assert_eq!(pass + warn, 2 * rows.len() + 8, "pass={pass} warn={warn}");
        // The tolerance-tested columns (duration ≤15%, cost ≤30%) pass by
        // the assertions above, so the report can at worst WARN on RAM.
        assert!(r.status().is_some());
    }
}
