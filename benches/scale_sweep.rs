//! Host-side simulator throughput on the scale-sweep path (plain harness;
//! criterion is unavailable offline). Reports protocol rounds simulated per
//! wall-second — the number that bounds how far the sweep axes (workers ×
//! modes × architectures) can be pushed. Feeds EXPERIMENTS.md §Scale sweep.

use std::time::Instant;

use slsgpu::cloud::FrameworkKind;
use slsgpu::coordinator::{strategy_for, ClusterEnv, EnvConfig, SyncMode};
use slsgpu::exp::scale_sweep::{run, SweepConfig};

/// Simulate `epochs` epochs of one (framework, W, mode) point and report
/// rounds/second of host wall time.
fn bench_point(fw: FrameworkKind, workers: usize, mode: SyncMode, batches: usize) {
    let mut cfg = EnvConfig::virtual_paper(fw, "mobilenet", workers).unwrap().with_sync(mode);
    cfg.batches_per_epoch = batches;
    let mut env = ClusterEnv::new(cfg).unwrap();
    let mut strategy = strategy_for(fw);
    let t0 = Instant::now();
    strategy.run_epoch(&mut env).unwrap();
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "{:<14} W={:<4} {:<8} {:>6} rounds  {:>10.1} rounds/s  {:>8} ops",
        fw.name(),
        workers,
        mode.label(),
        batches,
        batches as f64 / secs,
        env.comm.total_ops()
    );
}

fn main() {
    println!("-- single points (one epoch each) --");
    for fw in [FrameworkKind::AllReduce, FrameworkKind::ScatterReduce, FrameworkKind::Spirt] {
        for workers in [16, 64, 256] {
            for mode in [SyncMode::Bsp, SyncMode::Async { staleness: 2 }] {
                bench_point(fw, workers, mode, 24);
            }
        }
    }

    println!("-- threaded sweep (5 architectures x W x 2 modes) --");
    for workers in [vec![4, 16], vec![4, 16, 64]] {
        let cfg = SweepConfig {
            worker_counts: workers.clone(),
            batches_per_epoch: 24,
            threads: 0,
            ..SweepConfig::default()
        };
        let points = cfg.worker_counts.len() * cfg.modes.len() * 5;
        let rounds = points * cfg.batches_per_epoch;
        let t0 = Instant::now();
        run(&cfg).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "sweep W={workers:?}: {points:>3} points  {:>8.1} rounds/s  {secs:.2}s total",
            rounds as f64 / secs
        );
    }
}
