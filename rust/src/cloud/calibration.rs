//! Calibrated latency / bandwidth / compute / memory constants.
//!
//! We do not have the authors' AWS testbed; every duration below is either a
//! public service characteristic (S3/Redis/SQS latency & bandwidth ranges)
//! or is *calibrated from the paper's own measurements* (per-batch compute
//! seconds, peak-RAM decomposition). The experiment drivers then let the
//! protocol simulations produce epoch times, costs and communication
//! patterns from these components — the paper's *shape* (who wins, where
//! crossovers fall) emerges from the models rather than being transcribed.
//!
//! Calibration sources (all from the paper):
//! * Table 2 per-batch durations @B=512: SPIRT 15.44/28.55 s,
//!   Scatter 14.343/27.17 s, AllReduce 14.382/26.79 s, MLLess 69.425/78.39 s
//!   (MobileNet / ResNet-18).
//! * Table 2 peak RAM: 2685/2048/2048/3024 MB (MobileNet),
//!   3200/2880/2986/3630 MB (ResNet-18).
//! * GPU epochs: 92 s (MobileNet), 139 s (ResNet-18) on g4dn.xlarge.
//! * §4.2: SPIRT in-DB averaging 67.32→37.41 s, update 27.5→4.8 s.

/// Model architecture profile used by the duration/memory models.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelProfile {
    pub name: &'static str,
    /// Parameter count (gradient payload = 4×params bytes).
    pub params: u64,
    /// Seconds of Lambda-CPU compute per *sample* (fwd+bwd), calibrated from
    /// the LambdaML per-batch durations after subtracting state-load/sync.
    pub lambda_secs_per_sample: f64,
    /// Seconds of T4-GPU compute per sample (fwd+bwd), calibrated from the
    /// GPU epoch times after subtracting per-batch S3 synchronization.
    pub gpu_secs_per_sample: f64,
    /// Activation memory at B=512 in MB (NHWC f32 working set).
    pub activation_mb: f64,
}

/// MobileNet-v1 (paper size 4.2M params).
pub const MOBILENET: ModelProfile = ModelProfile {
    name: "mobilenet",
    params: 4_200_000,
    // (14.36 batch - 0.2 init - 0.23 loads - ~1.1 sync) / 512 ≈ 0.0249
    lambda_secs_per_sample: 0.0249,
    // (92/24 batch - ~0.54 S3 sync at GPU_S3_BW) / 512 ≈ 0.0064
    gpu_secs_per_sample: 0.00644,
    activation_mb: 680.0,
};

/// ResNet-18 (paper size 11.7M params).
pub const RESNET18: ModelProfile = ModelProfile {
    name: "resnet18",
    params: 11_700_000,
    // (27.0 batch - 0.2 init - 0.53 loads - ~1.3 sync) / 512 ≈ 0.0487
    lambda_secs_per_sample: 0.0487,
    // (139/24 batch - ~1.14 S3 sync at GPU_S3_BW) / 512 ≈ 0.0091
    gpu_secs_per_sample: 0.0091,
    activation_mb: 1430.0,
};

/// ResNet-50 (Fig. 2 payload-scaling model; 25.6M params). Per-sample times
/// extrapolated from ResNet-18 by FLOP ratio (~2.2×).
pub const RESNET50: ModelProfile = ModelProfile {
    name: "resnet50",
    params: 25_600_000,
    lambda_secs_per_sample: 0.107,
    gpu_secs_per_sample: 0.0200,
    activation_mb: 2900.0,
};

pub fn profile(name: &str) -> Option<ModelProfile> {
    match name {
        "mobilenet" => Some(MOBILENET),
        "resnet18" => Some(RESNET18),
        "resnet50" => Some(RESNET50),
        _ => None,
    }
}

/// Scale a full-size profile down to a reduced testbed config (width-reduced
/// executed models): compute and memory scale with the parameter ratio.
pub fn scaled_profile(base: ModelProfile, params: u64) -> ModelProfile {
    let r = params as f64 / base.params as f64;
    ModelProfile {
        name: base.name,
        params,
        lambda_secs_per_sample: base.lambda_secs_per_sample * r,
        gpu_secs_per_sample: base.gpu_secs_per_sample * r,
        activation_mb: base.activation_mb * r.sqrt(), // activations ~ width
    }
}

// ---------------------------------------------------------------------------
// Network / service characteristics (public AWS figures)

/// S3 per-request latency (first byte + auth + TLS from Lambda), seconds.
/// 150 ms is the level at which ScatterReduce's O(W) request count costs it
/// the small-model regime, matching Fig. 2's measured crossover.
pub const S3_LATENCY: f64 = 0.15;
/// S3 effective single-stream bandwidth, bytes/sec (Lambda-side).
pub const S3_BW: f64 = 100.0e6;
/// S3 bandwidth from EC2 GPU instances (10 GbE, multipart), bytes/sec.
pub const GPU_S3_BW: f64 = 200.0e6;
/// S3 latency from EC2 (same-region, no TLS tunnel re-setup), seconds.
pub const GPU_S3_LATENCY: f64 = 0.05;
/// Redis (EC2-hosted, same AZ) per-op latency, seconds.
pub const REDIS_LATENCY: f64 = 0.0015;
/// Redis raw transfer bandwidth, bytes/sec (AI.TENSORSET/GET of raw
/// buffers over 10 GbE, no Python-side conversion).
pub const REDIS_BW: f64 = 300.0e6;
/// RedisAI in-database tensor-script throughput, bytes/sec (touched bytes
/// per second of a scripted elementwise op). Calibrated from §4.2: 24
/// ResNet-18 accumulations × 3×46.8 MB / 90 MB/s ≈ 37.4 s — the paper's
/// in-database averaging figure (37.41 s).
pub const REDIS_INDB_BW: f64 = 90.0e6;
/// Scripted fused SGD update throughput (TorchScript inside RedisAI is
/// slower than a plain buffer add). Calibrated from §4.2's in-DB update:
/// 3×46.8 MB / 29 MB/s ≈ 4.8 s.
pub const INDB_UPDATE_BW: f64 = 29.0e6;
/// Client-side tensor round-trip bandwidth, bytes/sec: tensorget →
/// numpy/pickle → tensorset through a Python Lambda (the *naive
/// fetch-update-store* path of §4.2). Calibrated: 24 × 3×46.8 MB / 50 MB/s
/// ≈ 67.4 s — the paper's naive averaging figure (67.32 s).
pub const CLIENT_TENSOR_BW: f64 = 50.0e6;
/// Rebuilding a framework state_dict from fetched bytes (torch.load +
/// parameter copy), bytes/sec — dominates the naive model-update path
/// (27.5 s for ResNet-18 per §4.2).
pub const TORCH_REBUILD_BW: f64 = 2.0e6;
/// Queue (RabbitMQ/SQS) publish or poll latency, seconds.
pub const QUEUE_LATENCY: f64 = 0.005;
/// Step Functions per-transition latency, seconds.
pub const STEPFN_TRANSITION_LATENCY: f64 = 0.025;

// ---------------------------------------------------------------------------
// Lambda runtime characteristics

/// Cold-start (sandbox + PyTorch import), seconds.
pub const LAMBDA_COLD_START: f64 = 2.8;
/// Warm-start init overhead per invocation, seconds.
pub const LAMBDA_WARM_INIT: f64 = 0.20;

/// Per-framework fixed orchestration overhead per batch invocation, seconds
/// — the residual between the paper's measured per-batch durations and the
/// compute + load + protocol components (Table 2 calibration).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FrameworkKind {
    Spirt,
    MlLess,
    AllReduce,
    ScatterReduce,
    GpuBaseline,
}

impl FrameworkKind {
    pub const ALL: [FrameworkKind; 5] = [
        FrameworkKind::Spirt,
        FrameworkKind::MlLess,
        FrameworkKind::AllReduce,
        FrameworkKind::ScatterReduce,
        FrameworkKind::GpuBaseline,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FrameworkKind::Spirt => "SPIRT",
            FrameworkKind::MlLess => "MLLess",
            FrameworkKind::AllReduce => "AllReduce",
            FrameworkKind::ScatterReduce => "ScatterReduce",
            FrameworkKind::GpuBaseline => "GPU (g4dn.xlarge)",
        }
    }

    /// Residual per-batch orchestration overhead (seconds).
    pub fn batch_overhead(&self) -> f64 {
        match self {
            // Step Functions stage transitions + RabbitMQ notify/poll +
            // per-minibatch fault-tolerance checkpointing (SPIRT is the
            // "fault-tolerant and reliable" design — it journals every
            // minibatch), beyond raw transfers.
            FrameworkKind::Spirt => 1.5,
            // Supervisor round-trips: workers idle while the supervisor
            // decides when updates may be fetched (the paper's §2 bottleneck;
            // dominates MLLess's 69 s batches). The strategy decomposes this
            // into MLLESS_ROUND_BASE + published × MLLESS_PER_UPDATE, which
            // sums to 53 s at 4 workers with every update published.
            FrameworkKind::MlLess => 53.0,
            FrameworkKind::AllReduce => 0.10,
            FrameworkKind::ScatterReduce => 0.10,
            FrameworkKind::GpuBaseline => 0.05,
        }
    }
}

/// MLLess supervisor overhead decomposition (per round, seconds): a fixed
/// coordination floor plus a per-published-update scheduling cost. With 4
/// workers all publishing: 2.0 + 4 × 12.75 = 53 s (the Table 2 residual);
/// with the filter suppressing most updates the round cost collapses —
/// which is exactly the mechanism behind Fig. 3's 13× convergence gain.
pub const MLLESS_ROUND_BASE: f64 = 2.0;
pub const MLLESS_PER_UPDATE: f64 = 12.75;

// ---------------------------------------------------------------------------
// Peak-RAM model (Table 2 calibration)

/// Lambda deployment base footprint (PyTorch + NumPy + clients), MB.
pub fn framework_base_mb(fw: FrameworkKind) -> f64 {
    match fw {
        // + RedisAI client, sshtunnel, Step Functions SDK, minibatch queues.
        FrameworkKind::Spirt => 2_110.0,
        // + update cache and supervisor bookkeeping.
        FrameworkKind::MlLess => 2_200.0,
        FrameworkKind::AllReduce => 1_340.0,
        FrameworkKind::ScatterReduce => 1_340.0,
        FrameworkKind::GpuBaseline => 0.0, // not Lambda-billed
    }
}

/// Number of gradient-sized buffers the function holds simultaneously.
pub fn gradient_copies(fw: FrameworkKind) -> f64 {
    match fw {
        // Parallel per-minibatch gradient buffers before in-DB averaging.
        FrameworkKind::Spirt => 3.0,
        // Model + significant-update buffer.
        FrameworkKind::MlLess => 2.0,
        // Model + own gradient + aggregation buffer (master path).
        FrameworkKind::AllReduce => 2.0,
        FrameworkKind::ScatterReduce => 1.0,
        FrameworkKind::GpuBaseline => 2.0,
    }
}

/// Fraction of peak activation memory resident in the function. SPIRT
/// offloads per-minibatch gradient math to RedisAI, so fewer activation
/// buffers are live at once.
pub fn activation_residency(fw: FrameworkKind) -> f64 {
    match fw {
        FrameworkKind::Spirt => 0.75,
        _ => 1.0,
    }
}

/// Peak RAM of one worker function, MB (Table 2 "Peak RAM" model).
pub fn peak_ram_mb(fw: FrameworkKind, model: &ModelProfile, batch: usize) -> f64 {
    let params_mb = model.params as f64 * 4.0 / 1.0e6;
    let act_mb = model.activation_mb * batch as f64 / 512.0 * activation_residency(fw);
    framework_base_mb(fw) + act_mb + params_mb * (1.0 + gradient_copies(fw))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Peak-RAM model must land within 7% of every Table 2 measurement.
    #[test]
    fn peak_ram_matches_table2() {
        let cases = [
            (FrameworkKind::Spirt, MOBILENET, 2685.0),
            (FrameworkKind::ScatterReduce, MOBILENET, 2048.0),
            (FrameworkKind::AllReduce, MOBILENET, 2048.0),
            (FrameworkKind::MlLess, MOBILENET, 3024.0),
            (FrameworkKind::Spirt, RESNET18, 3200.0),
            (FrameworkKind::ScatterReduce, RESNET18, 2880.0),
            (FrameworkKind::AllReduce, RESNET18, 2986.0),
            (FrameworkKind::MlLess, RESNET18, 3630.0),
        ];
        for (fw, model, paper) in cases {
            let got = peak_ram_mb(fw, &model, 512);
            let err = (got - paper).abs() / paper;
            assert!(
                err < 0.07,
                "{:?}/{}: model {got:.0} vs paper {paper} ({:.1}%)",
                fw,
                model.name,
                err * 100.0
            );
        }
    }

    #[test]
    fn per_sample_times_reconstruct_batch_durations() {
        // compute(B=512) + init + loads + overhead ≈ paper per-batch numbers
        // for the LambdaML variants (±10%).
        for (model, paper) in [(MOBILENET, 14.343), (RESNET18, 27.17)] {
            let loads = (model.params as f64 * 4.0) / REDIS_BW
                + (512.0 * 32.0 * 32.0 * 3.0 * 4.0) / S3_BW;
            let got = 512.0 * model.lambda_secs_per_sample
                + LAMBDA_WARM_INIT
                + loads
                + 1.2; // typical LambdaML sync component
            let err = (got - paper).abs() / paper;
            assert!(err < 0.10, "{}: {got:.2} vs {paper} ({:.1}%)", model.name, err * 100.0);
        }
    }

    #[test]
    fn gpu_per_sample_times_reconstruct_epochs() {
        // Per batch each GPU puts its gradient and gets the 3 peers' (at EC2
        // S3 bandwidth), then updates locally.
        for (model, paper_epoch) in [(MOBILENET, 92.0), (RESNET18, 139.0)] {
            let grad_bytes = model.params as f64 * 4.0;
            let sync = 4.0 * grad_bytes / GPU_S3_BW + 4.0 * GPU_S3_LATENCY;
            let got = 24.0 * (512.0 * model.gpu_secs_per_sample + sync);
            let err = (got - paper_epoch).abs() / paper_epoch;
            assert!(
                err < 0.15,
                "{}: {got:.1} vs {paper_epoch} ({:.1}%)",
                model.name,
                err * 100.0
            );
        }
    }

    #[test]
    fn scaled_profile_shrinks_everything() {
        let s = scaled_profile(MOBILENET, 215_642);
        assert_eq!(s.params, 215_642);
        assert!(s.lambda_secs_per_sample < MOBILENET.lambda_secs_per_sample / 10.0);
        assert!(s.activation_mb < MOBILENET.activation_mb);
    }

    #[test]
    fn profiles_by_name() {
        assert_eq!(profile("mobilenet").unwrap().params, 4_200_000);
        assert_eq!(profile("resnet50").unwrap().params, 25_600_000);
        assert!(profile("vgg").is_none());
    }
}
