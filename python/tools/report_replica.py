"""Byte-exact Python replica of ``rust/src/report``'s renderers.

This container carries no Rust toolchain, so artifacts that must match the
Rust renderers byte-for-byte (the golden files under ``rust/tests/golden/``
and the bootstrap ``docs/`` pages) are produced by this replica instead.
Every function mirrors its Rust counterpart line by line:

* ``ascii_table``   ↔ ``util::table::Table::render``
* ``report_text``   ↔ ``report::Report::to_text``
* ``report_md``     ↔ ``report::Report::to_markdown``
* ``report_json``   ↔ ``report::Report::to_json`` + ``util::json`` writer

Float formatting notes: Rust's ``{:.N}`` and Python's ``{:.Nf}`` both
correctly round the same IEEE-754 double, and Rust's ``f64`` ``Display``
(shortest round-trip, no exponent below 1e15) matches ``repr(float)`` for
the magnitudes used here; the integer fast path (`fract() == 0`) is
replicated explicitly.
"""

LEFT, RIGHT = "left", "right"


# -- model -------------------------------------------------------------------

def cell(text, value=None, paper=None, tol=None):
    """A report cell: rendered text + optional value and paper anchor."""
    return {"text": text, "value": value, "paper": paper, "tol": tol}


def num_cell(value, digits):
    return cell(f"{value:.{digits}f}", value=value)


def count_cell(value):
    return cell(str(value), value=float(value))


def vs_paper(measured, paper, digits):
    if paper == 0.0:
        return f"{measured:.{digits}f} (paper {paper:.{digits}f})"
    pct = (measured - paper) / paper * 100.0
    return f"{measured:.{digits}f} (paper {paper:.{digits}f}, {pct:+.1f}%)"


def vs_paper_cell(measured, paper, digits, tol):
    return cell(vs_paper(measured, paper, digits), value=measured, paper=paper, tol=tol)


def rel_err(measured, paper):
    if paper == 0.0:
        return 0.0
    return abs(measured - paper) / abs(paper)


def verdict(c):
    if c["value"] is None or c["paper"] is None:
        return None
    return "PASS" if rel_err(c["value"], c["paper"]) <= c["tol"] else "WARN"


def table(tid, columns, title=None):
    """columns: list of (name, LEFT|RIGHT)."""
    return {"id": tid, "title": title, "columns": columns, "rows": [], "rules": []}


def push_row(t, cells):
    assert len(cells) == len(t["columns"]), f"row arity mismatch in {t['id']}"
    t["rows"].append(cells)


def rule(t):
    t["rules"].append(len(t["rows"]))


def section(heading=None, paragraphs=(), tables=(), notes=()):
    return {
        "heading": heading,
        "paragraphs": list(paragraphs),
        "tables": list(tables),
        "notes": list(notes),
    }


def report(rid, title, command, intro=(), sections=()):
    return {
        "id": rid,
        "title": title,
        "command": command,
        "intro": list(intro),
        "sections": list(sections),
    }


# -- text renderer (util::table + report::render) ----------------------------

def ascii_table(t):
    names = [c[0] for c in t["columns"]]
    aligns = [c[1] for c in t["columns"]]
    widths = [len(n) for n in names]
    for row in t["rows"]:
        for i, c in enumerate(row):
            widths[i] = max(widths[i], len(c["text"]))
    hrule = "+" + "+".join("-" * (w + 2) for w in widths) + "+"

    def fmt_row(cells):
        s = "|"
        for i, text in enumerate(cells):
            pad = " " * (widths[i] - len(text))
            if aligns[i] == LEFT:
                s += f" {text}{pad} |"
            else:
                s += f" {pad}{text} |"
        return s

    out = []
    if t["title"] is not None:
        out.append(t["title"])
    out.append(hrule)
    out.append(fmt_row(names))
    out.append(hrule)
    for i, row in enumerate(t["rows"]):
        out.append(fmt_row([c["text"] for c in row]))
        if (i + 1) in t["rules"] and (i + 1) != len(t["rows"]):
            out.append(hrule)
    out.append(hrule)
    return "\n".join(out) + "\n"


def section_text(s):
    out = ""
    if s["heading"] is not None:
        out += s["heading"] + "\n\n"
    for p in s["paragraphs"]:
        out += p + "\n\n"
    for i, t in enumerate(s["tables"]):
        if i > 0:
            out += "\n"
        out += ascii_table(t)
    for n in s["notes"]:
        out += n + "\n"
    return out


def report_text(r):
    out = ""
    for i, s in enumerate(r["sections"]):
        if i > 0:
            out += "\n"
        out += section_text(s)
    return out


# -- markdown renderer -------------------------------------------------------

def md_escape(text):
    return text.replace("|", "\\|")


def md_cell(c):
    if verdict(c) == "WARN":
        return f"{md_escape(c['text'])} **WARN**"
    return md_escape(c["text"])


def table_md(t):
    out = ""
    if t["title"] is not None:
        out += f"**{md_escape(t['title'])}**\n\n"
    out += "| " + " | ".join(md_escape(c[0]) for c in t["columns"]) + " |\n"
    out += "| " + " | ".join(":---" if c[1] == LEFT else "---:" for c in t["columns"]) + " |\n"
    for row in t["rows"]:
        out += "| " + " | ".join(md_cell(c) for c in row) + " |\n"
    passes = sum(1 for row in t["rows"] for c in row if verdict(c) == "PASS")
    warns = sum(1 for row in t["rows"] for c in row if verdict(c) == "WARN")
    if passes + warns > 0:
        out += f"\n*Paper anchors: {passes} PASS, {warns} WARN.*\n"
    return out


def section_md(s):
    out = ""
    if s["heading"] is not None:
        out += f"## {s['heading']}\n\n"
    for p in s["paragraphs"]:
        out += p + "\n\n"
    for t in s["tables"]:
        out += table_md(t) + "\n"
    for n in s["notes"]:
        out += n + "\n\n"
    return out


def report_md(r):
    out = f"# {r['title']}\n\n"
    out += (
        "> Generated by `slsgpu report` — do not edit by hand.\n"
        f"> Reproduce: `{r['command']}`\n\n"
    )
    for p in r["intro"]:
        out += p + "\n\n"
    for s in r["sections"]:
        out += section_md(s)
    return out.rstrip() + "\n"


# -- JSON writer (util::json semantics) --------------------------------------

def json_escape(s):
    out = '"'
    for ch in s:
        if ch == '"':
            out += '\\"'
        elif ch == "\\":
            out += "\\\\"
        elif ch == "\n":
            out += "\\n"
        elif ch == "\r":
            out += "\\r"
        elif ch == "\t":
            out += "\\t"
        elif ord(ch) < 0x20:
            out += f"\\u{ord(ch):04x}"
        else:
            out += ch
    return out + '"'


def json_num(v):
    if float(v) == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def json_value(v):
    if isinstance(v, str):
        return json_escape(v)
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, (int, float)):
        return json_num(v)
    if isinstance(v, list):
        return "[" + ",".join(json_value(x) for x in v) + "]"
    if isinstance(v, dict):  # keys sorted, as BTreeMap iterates
        return "{" + ",".join(
            f"{json_escape(k)}:{json_value(v[k])}" for k in sorted(v)
        ) + "}"
    raise TypeError(v)


def cell_json(c):
    obj = {"text": c["text"]}
    if c["value"] is not None:
        obj["value"] = c["value"]
    if c["paper"] is not None:
        anchor = {"paper": c["paper"], "tol": c["tol"]}
        v = verdict(c)
        if v is not None:
            anchor["verdict"] = v
        obj["anchor"] = anchor
    return obj


def table_json(t):
    obj = {
        "id": t["id"],
        "columns": [{"name": n, "align": a} for n, a in t["columns"]],
        "rows": [[cell_json(c) for c in row] for row in t["rows"]],
    }
    if t["title"] is not None:
        obj["title"] = t["title"]
    if t["rules"]:
        obj["rules"] = t["rules"]
    return obj


def report_json(r):
    passes = warns = 0
    for s in r["sections"]:
        for t in s["tables"]:
            for row in t["rows"]:
                for c in row:
                    v = verdict(c)
                    passes += v == "PASS"
                    warns += v == "WARN"
    obj = {
        "id": r["id"],
        "title": r["title"],
        "command": r["command"],
        "anchors": {"pass": passes, "warn": warns},
        "sections": [],
    }
    if r["intro"]:
        obj["intro"] = r["intro"]
    if passes + warns > 0:
        obj["status"] = "WARN" if warns else "PASS"
    for s in r["sections"]:
        sec = {"tables": [table_json(t) for t in s["tables"]]}
        if s["heading"] is not None:
            sec["heading"] = s["heading"]
        if s["paragraphs"]:
            sec["paragraphs"] = s["paragraphs"]
        if s["notes"]:
            sec["notes"] = s["notes"]
        obj["sections"].append(sec)
    return json_value(obj) + "\n"
