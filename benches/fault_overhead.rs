//! Fault-engine overhead benchmark (plain harness; criterion is
//! unavailable offline): the hooks are consulted on every compute/sync
//! boundary even in fault-free runs, so their cost must stay negligible
//! against the protocol simulation itself. Reports host-time per simulated
//! epoch for (a) a fault-free plan, (b) an armed multi-fault plan, and (c)
//! robust aggregation rules, for the AllReduce protocol at paper scale.

use std::time::Instant;

use slsgpu::cloud::FrameworkKind;
use slsgpu::coordinator::{strategy_for, ClusterEnv, EnvConfig};
use slsgpu::faults::{FaultPlan, PoisonMode};
use slsgpu::tensor::AggregationRule;

fn epoch_host_secs(plan: &FaultPlan, agg: AggregationRule, iters: usize) -> f64 {
    // Warmup.
    run_once(plan, agg);
    let t0 = Instant::now();
    for _ in 0..iters {
        run_once(plan, agg);
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn run_once(plan: &FaultPlan, agg: AggregationRule) {
    let cfg = EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 4)
        .unwrap()
        .with_faults(plan.clone())
        .with_aggregation(agg);
    let mut env = ClusterEnv::new(cfg).unwrap();
    let mut strategy = strategy_for(FrameworkKind::AllReduce);
    strategy.run_epoch(&mut env).unwrap();
}

fn main() {
    let iters = 30;
    let none = FaultPlan::none();
    let busy = FaultPlan::none()
        .crash(1, 1, 5)
        .sync_crash(2, 1)
        .straggler(3, 1, 0, 3.0, Some(8))
        .drop_updates(0, 1, 0, Some(4))
        .poison(3, 1, PoisonMode::SignFlip);

    let base = epoch_host_secs(&none, AggregationRule::Mean, iters);
    println!("allreduce epoch, no faults, mean agg      {:>10.2} us", base * 1e6);

    let armed = epoch_host_secs(&busy, AggregationRule::Mean, iters);
    println!(
        "allreduce epoch, 5-event plan, mean agg   {:>10.2} us  ({:+.1}% vs fault-free)",
        armed * 1e6,
        (armed - base) / base * 100.0
    );

    for agg in [AggregationRule::ClippedMean { ratio: 1.0 }, AggregationRule::CoordMedian] {
        let t = epoch_host_secs(&none, agg, iters);
        println!(
            "allreduce epoch, no faults, {:<12}  {:>10.2} us  ({:+.1}% vs mean)",
            agg.name(),
            t * 1e6,
            (t - base) / base * 100.0
        );
    }
}
