//! The `Slab` type: a flat f32 vector, real or size-only.
//!
//! Real slabs are `Arc`-backed: `clone`/[`Slab::share`] hand out a second
//! reference to the same buffer in O(1), and mutating ops copy-on-write
//! (`Arc::make_mut`). This is what lets the protocol layer move gradients
//! through stores, queues and peer databases without deep-copying 16–100 MB
//! payloads on every hop — the scale-sweep hot path at 256 workers.

use std::sync::Arc;

use anyhow::{bail, Result};

/// A flat f32 tensor slab.
#[derive(Debug, Clone, PartialEq)]
pub enum Slab {
    /// Backed by shared memory; elementwise math is real and mutation is
    /// copy-on-write.
    Real(Arc<Vec<f32>>),
    /// Size-only stand-in for paper-scale payloads; math is a no-op that
    /// preserves length (time/cost models only need bytes).
    Virtual { len: usize },
}

impl Slab {
    pub fn zeros(len: usize) -> Slab {
        Slab::Real(Arc::new(vec![0.0; len]))
    }

    pub fn virtual_of(len: usize) -> Slab {
        Slab::Virtual { len }
    }

    pub fn from_vec(v: Vec<f32>) -> Slab {
        Slab::Real(Arc::new(v))
    }

    /// A cheap second handle to the same payload (O(1): bumps the refcount
    /// for real slabs, copies a length for virtual ones). Use this instead
    /// of `clone` on protocol hot paths to make the non-copying intent
    /// grep-visible.
    pub fn share(&self) -> Slab {
        self.clone()
    }

    pub fn len(&self) -> usize {
        match self {
            Slab::Real(v) => v.len(),
            Slab::Virtual { len } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_real(&self) -> bool {
        matches!(self, Slab::Real(_))
    }

    /// Payload size on the wire (f32).
    pub fn nbytes(&self) -> u64 {
        self.len() as u64 * 4
    }

    pub fn as_slice(&self) -> Result<&[f32]> {
        match self {
            Slab::Real(v) => Ok(v.as_slice()),
            Slab::Virtual { .. } => bail!("virtual slab has no data"),
        }
    }

    pub fn zeros_like(&self) -> Slab {
        match self {
            Slab::Real(v) => Slab::zeros(v.len()),
            Slab::Virtual { len } => Slab::Virtual { len: *len },
        }
    }

    fn check_len(&self, other: &Slab) -> Result<()> {
        if self.len() != other.len() {
            bail!("slab length mismatch: {} vs {}", self.len(), other.len());
        }
        Ok(())
    }

    /// `self += w * g` — the aggregation primitive (pure-Rust path, used by
    /// the "naive" baselines; the in-database path runs the PJRT kernel).
    pub fn axpy(&mut self, g: &Slab, w: f32) -> Result<()> {
        self.check_len(g)?;
        if let (Slab::Real(a), Slab::Real(b)) = (&mut *self, g) {
            let a = Arc::make_mut(a);
            for (x, y) in a.iter_mut().zip(b.iter()) {
                *x += w * *y;
            }
        }
        Ok(())
    }

    /// `self *= s`.
    pub fn scale(&mut self, s: f32) {
        if let Slab::Real(v) = self {
            for x in Arc::make_mut(v).iter_mut() {
                *x *= s;
            }
        }
    }

    /// `self -= lr * g` — SGD apply (pure-Rust path).
    pub fn sgd(&mut self, g: &Slab, lr: f32) -> Result<()> {
        self.axpy(g, -lr)
    }

    pub fn l2_norm_sq(&self) -> f64 {
        match self {
            Slab::Real(v) => v.iter().map(|x| (*x as f64) * (*x as f64)).sum(),
            Slab::Virtual { .. } => 0.0,
        }
    }

    /// Mean of `k` slabs (all must be same length). Virtual if any input is.
    pub fn mean(slabs: &[Slab]) -> Result<Slab> {
        if slabs.is_empty() {
            bail!("mean of zero slabs");
        }
        let len = slabs[0].len();
        if slabs.iter().any(|s| s.len() != len) {
            bail!("slab length mismatch in mean");
        }
        if slabs.iter().any(|s| !s.is_real()) {
            return Ok(Slab::Virtual { len });
        }
        let mut acc = Slab::zeros(len);
        let w = 1.0 / slabs.len() as f32;
        for s in slabs {
            acc.axpy(s, w)?;
        }
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_real() {
        let mut a = Slab::from_vec(vec![1.0, 2.0]);
        a.axpy(&Slab::from_vec(vec![10.0, 20.0]), 0.5).unwrap();
        assert_eq!(a.as_slice().unwrap(), &[6.0, 12.0]);
    }

    #[test]
    fn axpy_virtual_is_noop_but_typed() {
        let mut a = Slab::virtual_of(5);
        a.axpy(&Slab::virtual_of(5), 1.0).unwrap();
        assert_eq!(a.len(), 5);
        assert!(!a.is_real());
        assert!(a.axpy(&Slab::virtual_of(4), 1.0).is_err());
    }

    #[test]
    fn sgd_matches_manual() {
        let mut theta = Slab::from_vec(vec![1.0, 1.0, 1.0]);
        theta.sgd(&Slab::from_vec(vec![1.0, 2.0, 3.0]), 0.1).unwrap();
        let got = theta.as_slice().unwrap();
        for (g, w) in got.iter().zip([0.9, 0.8, 0.7]) {
            assert!((g - w).abs() < 1e-6);
        }
    }

    #[test]
    fn mean_of_slabs() {
        let m = Slab::mean(&[
            Slab::from_vec(vec![1.0, 3.0]),
            Slab::from_vec(vec![3.0, 5.0]),
        ])
        .unwrap();
        assert_eq!(m.as_slice().unwrap(), &[2.0, 4.0]);
    }

    #[test]
    fn mean_propagates_virtual() {
        let m = Slab::mean(&[Slab::zeros(3), Slab::virtual_of(3)]).unwrap();
        assert!(!m.is_real());
        assert_eq!(m.len(), 3);
    }

    #[test]
    fn nbytes_is_4x() {
        assert_eq!(Slab::virtual_of(1000).nbytes(), 4000);
    }

    #[test]
    fn norm() {
        assert_eq!(Slab::from_vec(vec![3.0, 4.0]).l2_norm_sq(), 25.0);
    }

    #[test]
    fn mean_empty_errors() {
        assert!(Slab::mean(&[]).is_err());
    }

    #[test]
    fn share_is_aliasing_until_mutation() {
        // share() hands out the same buffer; a mutating op copies-on-write
        // so the sibling handle never observes the change.
        let a = Slab::from_vec(vec![1.0, 2.0]);
        let b = a.share();
        if let (Slab::Real(va), Slab::Real(vb)) = (&a, &b) {
            assert!(Arc::ptr_eq(va, vb), "share must not deep-copy");
        } else {
            panic!("expected real slabs");
        }
        let mut c = b.share();
        c.axpy(&a, 1.0).unwrap();
        assert_eq!(a.as_slice().unwrap(), &[1.0, 2.0], "COW must protect siblings");
        assert_eq!(c.as_slice().unwrap(), &[2.0, 4.0]);
    }

    #[test]
    fn axpy_self_aliased_reads_pre_update_values() {
        let a = Slab::from_vec(vec![1.0, -2.0]);
        let mut b = a.share();
        b.axpy(&a, 1.0).unwrap();
        assert_eq!(b.as_slice().unwrap(), &[2.0, -4.0]);
        assert_eq!(a.as_slice().unwrap(), &[1.0, -2.0]);
    }
}
