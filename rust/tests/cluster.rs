//! Store-cluster integration tests: full MLLess sessions (the one
//! architecture whose critical path runs through the shared store) on
//! sharded/replicated/budgeted store tiers, including a mid-training
//! `ShardCrash`. The unit tests in `cloud::cluster` pin the tier's local
//! semantics; these pin what the whole protocol stack does with them.

use slsgpu::cloud::{FrameworkKind, StoreTierConfig};
use slsgpu::coordinator::{strategy_for, ClusterEnv, EnvConfig};
use slsgpu::faults::FaultPlan;
use slsgpu::train::{run_session, SessionConfig, SessionReport};

const EPOCHS: usize = 3;

fn mlless_session(store: StoreTierConfig, plan: FaultPlan) -> (SessionReport, ClusterEnv) {
    let cfg = EnvConfig::virtual_paper(FrameworkKind::MlLess, "mobilenet", 4)
        .unwrap()
        .with_store(store)
        .with_faults(plan);
    let mut env = ClusterEnv::new(cfg).unwrap();
    let mut strategy = strategy_for(FrameworkKind::MlLess);
    let session_cfg = SessionConfig {
        max_epochs: EPOCHS,
        target_acc: 2.0,
        patience: EPOCHS + 1,
        evaluate: false,
    };
    let report = run_session(&mut env, strategy.as_mut(), &session_cfg).unwrap();
    (report, env)
}

fn assert_bit_identical(a: &SessionReport, b: &SessionReport, label: &str) {
    assert_eq!(a.reports.len(), b.reports.len(), "{label}");
    for (ra, rb) in a.reports.iter().zip(&b.reports) {
        assert_eq!(ra.vtime_secs.to_bits(), rb.vtime_secs.to_bits(), "{label}: e{}", ra.epoch);
        assert_eq!(ra.cost_usd.to_bits(), rb.cost_usd.to_bits(), "{label}: e{} cost", ra.epoch);
    }
    assert_eq!(a.total_vtime_secs.to_bits(), b.total_vtime_secs.to_bits(), "{label}");
}

#[test]
fn replicated_tier_survives_a_shard_crash_via_failover() {
    // Shard 0 crashes at the top of epoch 2 and loses its contents. With
    // R=2 every key has a live replica, so training rides through on
    // failover reads/writes — and the whole thing stays deterministic.
    let plan = FaultPlan::none().shard_crash(0, 2);
    let (a, env_a) = mlless_session(StoreTierConfig::sharded(2, 2), plan.clone());
    let (b, env_b) = mlless_session(StoreTierConfig::sharded(2, 2), plan);
    assert_eq!(a.reports.len(), EPOCHS, "training must complete through the crash");
    assert_eq!(env_a.recovery.shard_restarts, 1);
    assert!(
        env_a.recovery.shard_failovers > 0,
        "epoch-2 traffic for the crashed shard must fail over"
    );
    assert_eq!(env_a.recovery.shard_failovers, env_b.recovery.shard_failovers);
    assert_bit_identical(&a, &b, "mlless s2r2 + shard crash");
    // The cluster's own counters agree with the protocol attribution.
    assert_eq!(env_a.shared_redis.total_failovers(), env_a.recovery.shard_failovers);
}

#[test]
fn unreplicated_tier_stalls_through_the_crash_instead() {
    // Same crash, R=1: there is no replica to fail over to, so writes and
    // reads keyed to shard 0 wait out the 30 s restart. Slower than the
    // replicated run's failover path, but never an error — and the stall
    // is billed to visibility_wait, not transfer time.
    let plan = FaultPlan::none().shard_crash(0, 2);
    let (clean, _) = mlless_session(StoreTierConfig::sharded(2, 1), FaultPlan::none());
    let (crashed, env) = mlless_session(StoreTierConfig::sharded(2, 1), plan);
    assert_eq!(crashed.reports.len(), EPOCHS);
    assert_eq!(env.recovery.shard_restarts, 1);
    assert_eq!(env.recovery.shard_failovers, 0, "R=1 has nowhere to fail over");
    assert!(
        crashed.total_vtime_secs > clean.total_vtime_secs,
        "waiting out the restart must cost virtual time: {} vs {}",
        crashed.total_vtime_secs,
        clean.total_vtime_secs
    );
    assert!(env.comm.visibility_wait > 0.0, "the stall lands in visibility_wait");
}

#[test]
fn shard_reports_account_for_the_session_traffic() {
    let (_, env) = mlless_session(StoreTierConfig::sharded(4, 1), FaultPlan::none());
    let reports = env.shared_redis.shard_reports();
    assert_eq!(reports.len(), 4);
    let puts: u64 = reports.iter().map(|r| r.stats.puts).sum();
    let gets: u64 = reports.iter().map(|r| r.stats.gets).sum();
    // 4 workers × 1 update each × rounds: every publish is read by the
    // 3 peers, so store reads outnumber writes.
    assert!(puts > 0);
    assert!(gets > puts, "{gets} gets vs {puts} puts");
    // MLLess deletes consumed keys, so nothing stays resident...
    assert_eq!(reports.iter().map(|r| r.keys).sum::<usize>(), 0);
    // ...but the hottest-key high-water mark survives the deletions.
    assert!(reports.iter().any(|r| r.stats.hottest_gets > 0));
    // With no byte budget configured, nothing is ever evicted.
    assert_eq!(reports.iter().map(|r| r.stats.evictions).sum::<u64>(), 0);
}

#[test]
fn slack_byte_budget_is_timeline_invisible() {
    // A budget that never binds must not move a single bit: eviction
    // bookkeeping (touch counters, LRU maps) lives outside the clocks.
    let slack = StoreTierConfig {
        capacity_bytes: Some(1 << 40),
        ..StoreTierConfig::sharded(2, 2)
    };
    let (budgeted, env) = mlless_session(slack, FaultPlan::none());
    let (unbudgeted, _) = mlless_session(StoreTierConfig::sharded(2, 2), FaultPlan::none());
    let evictions: u64 =
        env.shared_redis.shard_reports().iter().map(|r| r.stats.evictions).sum();
    assert_eq!(evictions, 0);
    assert_bit_identical(&budgeted, &unbudgeted, "slack budget");
}
