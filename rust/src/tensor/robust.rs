//! Robust aggregation rules — the defense side of the gradient-poisoning
//! scenarios (SPIRT §6 "Byzantine tolerance"; Barrak et al. 2309.14148).
//!
//! A poisoned worker submits a scaled or sign-flipped update; the naive
//! arithmetic mean lets a single such worker steer the global step
//! arbitrarily. Two standard robust estimators bound that influence:
//!
//! * **Clipped mean** — every contribution's L2 norm is clipped to a
//!   multiple of the *median* contribution norm before averaging, so one
//!   worker's influence is bounded by `ratio × median / k` regardless of
//!   how large its update is.
//! * **Coordinate-wise median** — each parameter takes the median across
//!   workers, ignoring up to `(k-1)/2` arbitrary outliers per coordinate.
//!
//! Both preserve the slab contract: virtual (size-only) inputs produce a
//! virtual output of the same length, so the cost-model experiments traverse
//! the identical code path the end-to-end runs use.

use anyhow::{bail, Result};

use super::slab::Slab;

/// How a set of worker updates is combined into one gradient.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AggregationRule {
    /// Plain arithmetic mean (the paper's baseline in every framework).
    Mean,
    /// Norm-clip each contribution to `ratio × median norm`, then average.
    ClippedMean { ratio: f64 },
    /// Coordinate-wise median across contributions.
    CoordMedian,
}

impl AggregationRule {
    /// Parse a CLI spec: `mean`, `clipped`, `clipped:<ratio>`, `median`.
    pub fn parse(spec: &str) -> Result<AggregationRule> {
        let spec = spec.trim().to_ascii_lowercase();
        Ok(match spec.as_str() {
            "mean" => AggregationRule::Mean,
            "clipped" => AggregationRule::ClippedMean { ratio: 1.0 },
            "median" | "coord-median" => AggregationRule::CoordMedian,
            other => match other.strip_prefix("clipped:") {
                Some(r) => AggregationRule::ClippedMean { ratio: r.parse()? },
                None => bail!("unknown aggregation rule {other:?} (mean|clipped[:r]|median)"),
            },
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AggregationRule::Mean => "mean",
            AggregationRule::ClippedMean { .. } => "clipped-mean",
            AggregationRule::CoordMedian => "coord-median",
        }
    }

    /// Relative in-function compute cost vs the plain mean (extra slab
    /// passes: norm computation + clip for the clipped mean, per-coordinate
    /// sorting for the median). The env charges this on the virtual clock.
    pub fn cost_multiplier(&self) -> f64 {
        match self {
            AggregationRule::Mean => 1.0,
            AggregationRule::ClippedMean { .. } => 2.0,
            AggregationRule::CoordMedian => 4.0,
        }
    }

    /// Combine `slabs` under this rule.
    pub fn apply(&self, slabs: &[Slab]) -> Result<Slab> {
        match self {
            AggregationRule::Mean => Slab::mean(slabs),
            AggregationRule::ClippedMean { ratio } => clipped_mean(slabs, *ratio),
            AggregationRule::CoordMedian => coordinate_median(slabs),
        }
    }
}

fn check(slabs: &[Slab]) -> Result<(usize, bool)> {
    if slabs.is_empty() {
        bail!("aggregation of zero slabs");
    }
    let len = slabs[0].len();
    if slabs.iter().any(|s| s.len() != len) {
        bail!("slab length mismatch in aggregation");
    }
    Ok((len, slabs.iter().all(|s| s.is_real())))
}

/// Median via selection (`select_nth_unstable`), reordering `values` in
/// place: O(k) instead of the full O(k log k) sort the old implementation
/// paid per call — and `coordinate_median` calls this once *per parameter*.
/// The median is a function of the value multiset only, so selection
/// returns exactly the values the sort-based version produced (mean of the
/// two middles for even k).
fn median_of(values: &mut [f64]) -> f64 {
    let k = values.len();
    let (lo, mid, _) = values.select_nth_unstable_by(k / 2, f64::total_cmp);
    let hi = *mid;
    if k % 2 == 1 {
        hi
    } else {
        // The k/2-1'th order statistic is the max of the left partition.
        let lo_max = lo.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        0.5 * (lo_max + hi)
    }
}

/// Mean of `slabs` with each contribution's L2 norm clipped to
/// `ratio × median(norms)`. Virtual if any input is.
pub fn clipped_mean(slabs: &[Slab], ratio: f64) -> Result<Slab> {
    let (len, real) = check(slabs)?;
    if !real {
        return Ok(Slab::virtual_of(len));
    }
    let norms: Vec<f64> = slabs.iter().map(|s| s.l2_norm_sq().sqrt()).collect();
    let mut sorted = norms.clone();
    let clip = ratio * median_of(&mut sorted);
    let inv_k = 1.0 / slabs.len() as f32;
    let weights: Vec<f32> = norms
        .iter()
        .map(|norm| {
            let w = if *norm > clip && *norm > 0.0 { (clip / norm) as f32 } else { 1.0 };
            w * inv_k
        })
        .collect();
    // Single blocked pass (same shape as `Slab::mean`): per output element
    // the weighted adds still run in slab order with the old `+= w * y`
    // expression, so the result is bit-identical to the k-sweep `axpy` form
    // it replaces while touching each gradient block once, cache-resident.
    let views: Vec<&[f32]> = slabs.iter().map(|s| s.as_slice()).collect::<Result<_>>()?;
    let mut out = vec![0.0f32; len];
    let mut start = 0;
    while start < len {
        let end = (start + super::KERNEL_CHUNK).min(len);
        let ob = &mut out[start..end];
        for (v, w) in views.iter().zip(weights.iter()) {
            for (x, y) in ob.iter_mut().zip(v[start..end].iter()) {
                *x += *w * *y;
            }
        }
        start = end;
    }
    Ok(Slab::from_vec(out))
}

/// Coordinate-wise median across `slabs`. Virtual if any input is.
pub fn coordinate_median(slabs: &[Slab]) -> Result<Slab> {
    let (len, real) = check(slabs)?;
    if !real {
        return Ok(Slab::virtual_of(len));
    }
    let views: Vec<&[f32]> = slabs.iter().map(|s| s.as_slice()).collect::<Result<_>>()?;
    let mut out = Vec::with_capacity(len);
    let mut column: Vec<f64> = Vec::with_capacity(views.len());
    for j in 0..len {
        column.clear();
        column.extend(views.iter().map(|v| v[j] as f64));
        out.push(median_of(&mut column) as f32);
    }
    Ok(Slab::from_vec(out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slab(v: &[f32]) -> Slab {
        Slab::from_vec(v.to_vec())
    }

    #[test]
    fn clipped_mean_bounds_an_outlier() {
        // Three honest unit-ish updates, one 100× outlier: the outlier's
        // influence is clipped to the median norm, so the mean stays near
        // the honest direction instead of being dragged 25× away.
        let honest = [slab(&[1.0, 0.0]), slab(&[1.1, 0.0]), slab(&[0.9, 0.0])];
        let poison = slab(&[-100.0, 0.0]);
        let all = [honest[0].clone(), honest[1].clone(), honest[2].clone(), poison];
        let naive = Slab::mean(&all).unwrap();
        assert!(naive.as_slice().unwrap()[0] < -20.0, "naive mean is hijacked");
        let robust = clipped_mean(&all, 1.0).unwrap();
        let x = robust.as_slice().unwrap()[0];
        assert!(x > 0.3 && x < 1.0, "clipped mean stays honest, got {x}");
    }

    #[test]
    fn coord_median_ignores_minority_outliers() {
        let m = coordinate_median(&[
            slab(&[1.0, 5.0]),
            slab(&[2.0, 6.0]),
            slab(&[1000.0, -1000.0]),
        ])
        .unwrap();
        assert_eq!(m.as_slice().unwrap(), &[2.0, 5.0]);
    }

    #[test]
    fn median_even_count_averages_middles() {
        let m = coordinate_median(&[slab(&[1.0]), slab(&[3.0]), slab(&[5.0]), slab(&[100.0])])
            .unwrap();
        assert_eq!(m.as_slice().unwrap(), &[4.0]);
    }

    #[test]
    fn rules_match_mean_on_clean_identical_inputs() {
        let xs = [slab(&[2.0, -4.0]), slab(&[2.0, -4.0]), slab(&[2.0, -4.0])];
        for rule in [
            AggregationRule::Mean,
            AggregationRule::ClippedMean { ratio: 1.0 },
            AggregationRule::CoordMedian,
        ] {
            let out = rule.apply(&xs).unwrap();
            assert_eq!(out.as_slice().unwrap(), &[2.0, -4.0], "{}", rule.name());
        }
    }

    #[test]
    fn virtual_slabs_pass_through() {
        for rule in [
            AggregationRule::Mean,
            AggregationRule::ClippedMean { ratio: 1.0 },
            AggregationRule::CoordMedian,
        ] {
            let out = rule.apply(&[Slab::virtual_of(7), Slab::virtual_of(7)]).unwrap();
            assert!(!out.is_real());
            assert_eq!(out.len(), 7);
        }
    }

    #[test]
    fn parse_specs() {
        assert_eq!(AggregationRule::parse("mean").unwrap(), AggregationRule::Mean);
        assert_eq!(
            AggregationRule::parse("clipped:1.5").unwrap(),
            AggregationRule::ClippedMean { ratio: 1.5 }
        );
        assert_eq!(AggregationRule::parse("median").unwrap(), AggregationRule::CoordMedian);
        assert!(AggregationRule::parse("krum").is_err());
    }

    #[test]
    fn mismatched_lengths_error() {
        assert!(coordinate_median(&[slab(&[1.0]), slab(&[1.0, 2.0])]).is_err());
        assert!(clipped_mean(&[], 1.0).is_err());
    }

    fn noise(seed: u64, len: usize) -> Vec<f32> {
        let mut state = seed;
        (0..len)
            .map(|_| {
                state = state
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                ((state >> 40) as f32) / (1u64 << 24) as f32 - 0.5
            })
            .collect()
    }

    #[test]
    fn selection_median_matches_sort_reference() {
        // Value-identity against the old sort-based median, odd and even k,
        // with duplicate values in the mix.
        for k in 1..=9usize {
            let mut vals: Vec<f64> =
                noise(77 + k as u64, k).into_iter().map(|x| (x * 8.0).round()).collect();
            let mut sorted = vals.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let reference = if k % 2 == 1 {
                sorted[k / 2]
            } else {
                0.5 * (sorted[k / 2 - 1] + sorted[k / 2])
            };
            assert_eq!(median_of(&mut vals).to_bits(), reference.to_bits(), "k={k}");
        }
    }

    #[test]
    fn blocked_clipped_mean_is_bit_identical_to_axpy_sweeps() {
        // Multi-chunk inputs with one outlier so the clip path is active.
        let len = 2 * super::super::KERNEL_CHUNK + 9;
        let mut slabs: Vec<Slab> = (0..4).map(|i| Slab::from_vec(noise(i, len))).collect();
        let mut big = noise(99, len);
        for x in &mut big {
            *x *= 50.0;
        }
        slabs.push(Slab::from_vec(big));

        // Reference: the pre-blocking implementation — per-slab axpy sweeps.
        let norms: Vec<f64> = slabs.iter().map(|s| s.l2_norm_sq().sqrt()).collect();
        let mut sorted = norms.clone();
        let clip = 1.0 * median_of(&mut sorted);
        let inv_k = 1.0 / slabs.len() as f32;
        let mut reference = Slab::zeros(len);
        for (s, norm) in slabs.iter().zip(norms.iter()) {
            let w = if *norm > clip && *norm > 0.0 { (clip / norm) as f32 } else { 1.0 };
            reference.axpy(s, w * inv_k).unwrap();
        }

        let got = clipped_mean(&slabs, 1.0).unwrap();
        let gb: Vec<u32> = got.as_slice().unwrap().iter().map(|x| x.to_bits()).collect();
        let rb: Vec<u32> =
            reference.as_slice().unwrap().iter().map(|x| x.to_bits()).collect();
        assert_eq!(gb, rb);
    }
}
