//! Sharded parameter-store tier: a consistent-hash cluster of Redis shards.
//!
//! Every architecture's shared store traffic used to funnel through one
//! `cloud::redis` instance — fine for reproducing the paper's single-host
//! measurements, but silent about the question the scale sweep asks: what
//! happens to the store tier at 256+ workers? This module models the store
//! as a real distributed system, in the style of the RedisAI-cluster /
//! MLLess storage designs:
//!
//! * a [`HashRing`] (virtual nodes, FNV-1a) routes each key to a primary
//!   shard deterministically — same key, same shard, every run;
//! * replication factor R writes each key to the first R distinct shards
//!   clockwise of its hash (asynchronously — the client is acked by the
//!   primary; replicas' command loops absorb the copies);
//! * reads prefer the primary and fail over down the preference list when
//!   a shard is crashed (`faults::FaultKind::ShardCrash`), which also
//!   models the crash as losing the shard's in-memory contents;
//! * an optional per-shard byte budget evicts least-recently-used keys,
//!   deterministically (recency = a monotone touch counter, no clocks);
//! * each shard is its own single-threaded [`Redis`] instance, so hot keys
//!   contend for one command loop while the ring spreads cold traffic.
//!
//! The load-bearing compatibility contract: a cluster configured with
//! `shards = 1, replication = 1` and no byte budget degenerates to exactly
//! the old single-instance code path — bit-identical virtual time and cost
//! for all five architectures (locked in by `rust/tests/determinism.rs`).

pub mod ring;

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::metrics::{CommStats, Ledger};
use crate::sim::VTime;
use crate::tensor::Slab;

use super::redis::Redis;
pub use ring::HashRing;

/// Seconds a crashed shard takes to come back (instance replacement +
/// process start; an empty restart, not a snapshot restore — the crash
/// loses the shard's in-memory contents, which is what replication is for).
pub const SHARD_RESTART_SECS: f64 = 30.0;

/// Virtual nodes per shard (load-split smoothness vs ring size).
pub const DEFAULT_VNODES: usize = 64;

/// How the shared store tier is provisioned.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreTierConfig {
    /// Number of Redis shards (>= 1).
    pub shards: usize,
    /// Copies of each key (1 = no replication; clamped to `shards`).
    pub replication: usize,
    /// Virtual nodes per shard on the hash ring.
    pub vnodes: usize,
    /// Per-shard byte budget; exceeding it evicts LRU keys. `None` = no
    /// eviction (the single-instance behaviour).
    pub capacity_bytes: Option<u64>,
}

impl StoreTierConfig {
    /// The pre-cluster store: one shard, no replication, no eviction.
    pub fn single() -> StoreTierConfig {
        StoreTierConfig {
            shards: 1,
            replication: 1,
            vnodes: DEFAULT_VNODES,
            capacity_bytes: None,
        }
    }

    /// `shards` shards at replication `r`, default vnodes, no budget.
    pub fn sharded(shards: usize, replication: usize) -> StoreTierConfig {
        StoreTierConfig { shards, replication, ..StoreTierConfig::single() }
    }

    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            bail!("store tier needs at least one shard");
        }
        if self.replication == 0 {
            bail!("replication factor must be >= 1");
        }
        if self.replication > self.shards {
            bail!(
                "replication {} exceeds shard count {}",
                self.replication,
                self.shards
            );
        }
        if self.vnodes == 0 {
            bail!("need at least one virtual node per shard");
        }
        Ok(())
    }

    /// Short label for tables/CSV (`s4r2`).
    pub fn label(&self) -> String {
        format!("s{}r{}", self.shards, self.replication)
    }
}

impl Default for StoreTierConfig {
    fn default() -> StoreTierConfig {
        StoreTierConfig::single()
    }
}

/// Per-shard traffic counters (cluster bookkeeping; never timeline-visible).
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// Primary writes routed here.
    pub puts: u64,
    /// Reads served here (primary or failover).
    pub gets: u64,
    /// Replica copies absorbed by this shard's command loop.
    pub replica_writes: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Keys dropped by the LRU byte budget.
    pub evictions: u64,
    /// Reads this shard served *for* a down primary.
    pub failovers: u64,
    /// Most-read key seen on this shard and its read count (high-water
    /// mark over the run — entries for deleted keys stop counting but the
    /// mark survives, so memory stays bounded by the live key set).
    pub hottest_key: String,
    pub hottest_gets: u64,
}

/// One shard: a [`Redis`] instance plus routing/eviction state.
#[derive(Debug)]
struct Shard {
    redis: Redis,
    /// Down (crashed, restarting) until this time, if ever crashed.
    down_until: Option<VTime>,
    /// Monotone touch counter driving LRU order (no wall clocks: ties are
    /// impossible and order is identical on every run).
    seq: u64,
    /// Touch-order index: seq -> key (the LRU end is the smallest seq).
    lru: BTreeMap<u64, String>,
    /// key -> (current seq, resident bytes). Ordered maps here and below:
    /// only keyed lookups touch them (unordered-iteration audit invariant).
    resident: BTreeMap<String, (u64, u64)>,
    resident_bytes: u64,
    /// Live per-key read counts backing the hottest-key high-water mark.
    reads: BTreeMap<String, u64>,
    stats: ShardStats,
}

impl Shard {
    fn new(name: String) -> Shard {
        Shard {
            redis: Redis::new(name),
            down_until: None,
            seq: 0,
            lru: BTreeMap::new(),
            resident: BTreeMap::new(),
            resident_bytes: 0,
            reads: BTreeMap::new(),
            stats: ShardStats::default(),
        }
    }

    fn is_down(&self, t: VTime) -> bool {
        self.down_until.map(|until| t < until).unwrap_or(false)
    }

    /// Mark `key` most-recently-used (insert or refresh).
    fn touch(&mut self, key: &str, bytes: u64) {
        self.seq += 1;
        if let Some((old_seq, old_bytes)) = self.resident.get(key).copied() {
            self.lru.remove(&old_seq);
            self.resident_bytes -= old_bytes;
        }
        self.lru.insert(self.seq, key.to_string());
        self.resident.insert(key.to_string(), (self.seq, bytes));
        self.resident_bytes += bytes;
    }

    /// Forget `key` (deletion or eviction).
    fn forget(&mut self, key: &str) {
        if let Some((seq, bytes)) = self.resident.remove(key) {
            self.lru.remove(&seq);
            self.resident_bytes -= bytes;
        }
        self.reads.remove(key);
    }

    /// Evict LRU keys until the budget holds, never evicting `just_wrote`.
    fn enforce_budget(&mut self, budget: Option<u64>, just_wrote: &str) {
        let Some(cap) = budget else { return };
        while self.resident_bytes > cap {
            let Some((&seq, _)) = self.lru.iter().find(|(_, k)| k.as_str() != just_wrote)
            else {
                break; // only the fresh key is resident; nothing to evict
            };
            let key = self.lru.remove(&seq).expect("lru entry vanished");
            let (_, bytes) = self.resident.remove(&key).expect("resident entry vanished");
            self.resident_bytes -= bytes;
            self.reads.remove(&key);
            self.redis.delete(&key);
            self.stats.evictions += 1;
        }
    }

    fn note_read(&mut self, key: &str, bytes: u64) {
        self.stats.gets += 1;
        self.stats.bytes_out += bytes;
        let n = self.reads.entry(key.to_string()).or_insert(0);
        *n += 1;
        if *n > self.stats.hottest_gets {
            self.stats.hottest_gets = *n;
            if self.stats.hottest_key != key {
                self.stats.hottest_key = key.to_string();
            }
        }
    }
}

/// A point-in-time view of one shard for reports/traces.
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub shard: usize,
    pub keys: usize,
    pub resident_bytes: u64,
    /// Seconds requests spent queued at this shard's command loop/script
    /// engine (contention signal of the shard-sweep frontier).
    pub queue_wait: f64,
    pub requests: u64,
    pub busy_secs: f64,
    pub stats: ShardStats,
}

/// The sharded store tier.
#[derive(Debug)]
pub struct RedisCluster {
    ring: HashRing,
    shards: Vec<Shard>,
    replication: usize,
    capacity_bytes: Option<u64>,
    /// Total failover reads (the protocol layer samples deltas of this to
    /// attribute failovers to `RecoveryStats`).
    failovers: u64,
}

impl RedisCluster {
    pub fn new(name: impl Into<String>, cfg: &StoreTierConfig) -> Result<RedisCluster> {
        cfg.validate()?;
        let name = name.into();
        let shards = (0..cfg.shards)
            .map(|i| {
                // Shard 0 of a 1-shard tier keeps the bare name so error
                // messages and traces match the pre-cluster store.
                let shard_name =
                    if cfg.shards == 1 { name.clone() } else { format!("{name}-s{i}") };
                Shard::new(shard_name)
            })
            .collect();
        Ok(RedisCluster {
            ring: HashRing::new(cfg.shards, cfg.vnodes),
            shards,
            replication: cfg.replication,
            capacity_bytes: cfg.capacity_bytes,
            failovers: 0,
        })
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn replication(&self) -> usize {
        self.replication
    }

    /// The shard owning `key` (trace coordinate; routing-only, no clocks).
    pub fn primary_of(&self, key: &str) -> usize {
        self.ring.primary(key)
    }

    /// Write `key`: primary ack on the caller's clock, async replica
    /// copies behind it. Returns the primary ack time.
    pub fn set(&mut self, now: VTime, key: &str, slab: Slab, comm: &mut CommStats) -> VTime {
        let prefs = self.ring.shards_for(key, self.replication);
        // Primary write: first live shard in preference order. If every
        // replica is down the write waits out the primary's restart.
        let primary = prefs
            .iter()
            .copied()
            .find(|&s| !self.shards[s].is_down(now))
            .unwrap_or(prefs[0]);
        let start = match self.shards[primary].down_until {
            Some(until) if now < until => until,
            _ => now,
        };
        let bytes = slab.nbytes();
        let done = self.shards[primary].redis.set(start, key, slab.share(), comm);
        if start > now {
            // The stall for the restart is producer-side wait, not wire time.
            comm.comm_time -= start - now;
            comm.visibility_wait += start - now;
        }
        let sh = &mut self.shards[primary];
        sh.stats.puts += 1;
        sh.stats.bytes_in += bytes;
        if primary != prefs[0] {
            sh.stats.failovers += 1;
            self.failovers += 1;
        }
        sh.touch(key, bytes);
        sh.enforce_budget(self.capacity_bytes, key);

        // Asynchronous replication fan-out after the primary ack.
        for &r in prefs.iter().filter(|&&r| r != primary) {
            if self.shards[r].is_down(done) {
                continue; // a down replica just misses this copy
            }
            self.shards[r].redis.replicate_set(done, key, slab.share(), comm);
            let sh = &mut self.shards[r];
            sh.stats.replica_writes += 1;
            sh.stats.bytes_in += bytes;
            sh.touch(key, bytes);
            sh.enforce_budget(self.capacity_bytes, key);
        }
        done
    }

    /// Read `key`: served by the primary, or by the first live replica
    /// holding a copy when the primary is down (a counted failover). If no
    /// live shard holds the key, the read waits out the owner's restart —
    /// and errors if the copy did not survive anywhere.
    pub fn get(&mut self, now: VTime, key: &str, comm: &mut CommStats) -> Result<(VTime, Slab)> {
        let prefs = self.ring.shards_for(key, self.replication);
        let serving = prefs
            .iter()
            .copied()
            .find(|&s| !self.shards[s].is_down(now) && self.shards[s].redis.contains(key));
        match serving {
            Some(s) => {
                let (done, slab) = self.shards[s].redis.get(now, key, comm)?;
                let bytes = slab.nbytes();
                self.shards[s].note_read(key, bytes);
                self.shards[s].touch(key, bytes);
                if s != prefs[0] {
                    self.shards[s].stats.failovers += 1;
                    self.failovers += 1;
                }
                Ok((done, slab))
            }
            None => {
                // Every holder is down (or the key never existed). Wait for
                // the first preference shard that still holds a copy.
                let holder = prefs
                    .iter()
                    .copied()
                    .find(|&s| self.shards[s].redis.contains(key))
                    .ok_or_else(|| {
                        anyhow!("redis-cluster: missing key {key} on shards {prefs:?}")
                    })?;
                let until = self.shards[holder].down_until.unwrap_or(now);
                let start = until.max(now);
                let (done, slab) = self.shards[holder].redis.get(start, key, comm)?;
                if start > now {
                    comm.comm_time -= start - now;
                    comm.visibility_wait += start - now;
                }
                let bytes = slab.nbytes();
                self.shards[holder].note_read(key, bytes);
                self.shards[holder].touch(key, bytes);
                Ok((done, slab))
            }
        }
    }

    /// Earliest time `key` is visible anywhere (preference order).
    pub fn visible_at(&self, key: &str) -> Option<VTime> {
        self.ring
            .shards_for(key, self.replication)
            .into_iter()
            .find_map(|s| self.shards[s].redis.visible_at(key))
    }

    pub fn contains(&self, key: &str) -> bool {
        self.ring
            .shards_for(key, self.replication)
            .into_iter()
            .any(|s| self.shards[s].redis.contains(key))
    }

    /// Drop `key` from every replica (no timeline effects, like
    /// [`Redis::delete`] — consumed-round cleanup).
    pub fn delete(&mut self, key: &str) {
        for s in self.ring.shards_for(key, self.replication) {
            self.shards[s].redis.delete(key);
            self.shards[s].forget(key);
        }
    }

    pub fn clear(&mut self) {
        for sh in &mut self.shards {
            sh.redis.clear();
            sh.lru.clear();
            sh.resident.clear();
            sh.resident_bytes = 0;
            sh.reads.clear();
        }
    }

    /// Prune every shard's command-loop/script-engine busy history that
    /// ended at or before `before` (see `Redis::prune_history`). Routing,
    /// residency, LRU order and all stats are untouched.
    pub fn prune_history(&mut self, before: VTime) {
        for sh in &mut self.shards {
            sh.redis.prune_history(before);
        }
    }

    /// Crash `shard` at `now`: it loses its in-memory contents and serves
    /// nothing until `now + SHARD_RESTART_SECS`. Reads fail over to
    /// replicas in the meantime.
    pub fn crash_shard(&mut self, shard: usize, now: VTime) -> Result<()> {
        if shard >= self.shards.len() {
            bail!("shard {shard} out of range ({} shards)", self.shards.len());
        }
        let sh = &mut self.shards[shard];
        sh.down_until = Some(now + SHARD_RESTART_SECS);
        let lost: Vec<String> = sh.lru.values().cloned().collect();
        for key in lost {
            sh.redis.delete(&key);
            sh.forget(&key);
        }
        Ok(())
    }

    /// Total failover reads served so far (delta-sampled by the protocol
    /// layer into `RecoveryStats::shard_failovers`).
    pub fn total_failovers(&self) -> u64 {
        self.failovers
    }

    /// Bill the EC2 fleet hosting the tier: one instance per shard for the
    /// experiment duration (tracked under `Ec2Redis`, outside the paper's
    /// cost model — exactly the accounting `Redis::bill_hosting` used to
    /// collapse to a single instance).
    pub fn bill_hosting(&self, duration: f64, ledger: &mut Ledger) {
        self.shards[0].redis.bill_hosting(duration, self.shards.len(), ledger);
    }

    /// Per-shard traffic/contention snapshot (reports, trace summaries).
    pub fn shard_reports(&self) -> Vec<ShardReport> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, sh)| ShardReport {
                shard: i,
                keys: sh.resident.len(),
                resident_bytes: sh.resident_bytes,
                queue_wait: sh.redis.queue_wait(),
                requests: sh.redis.requests(),
                busy_secs: sh.redis.busy_time(),
                stats: sh.stats.clone(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slab(n: usize) -> Slab {
        Slab::virtual_of(n)
    }

    fn cluster(shards: usize, replication: usize) -> RedisCluster {
        RedisCluster::new("shared", &StoreTierConfig::sharded(shards, replication)).unwrap()
    }

    #[test]
    fn config_validation() {
        assert!(StoreTierConfig::single().validate().is_ok());
        assert!(StoreTierConfig::sharded(4, 2).validate().is_ok());
        assert!(StoreTierConfig::sharded(0, 1).validate().is_err());
        assert!(StoreTierConfig::sharded(2, 3).validate().is_err(), "R > N");
        assert!(StoreTierConfig::sharded(2, 0).validate().is_err());
        assert_eq!(StoreTierConfig::sharded(4, 2).label(), "s4r2");
    }

    #[test]
    fn single_shard_roundtrip_matches_plain_redis() {
        // shards=1/replication=1 must be the old store, bit for bit.
        let mut plain = Redis::new("shared");
        let mut cl = RedisCluster::new("shared", &StoreTierConfig::single()).unwrap();
        let mut ca = CommStats::new();
        let mut cb = CommStats::new();
        for i in 0..8 {
            let key = format!("u/e1/r{i}/w0");
            let tp = plain.set(VTime::from_secs(i as f64), &key, slab(1_000_000), &mut ca);
            let tc = cl.set(VTime::from_secs(i as f64), &key, slab(1_000_000), &mut cb);
            assert_eq!(tp.secs().to_bits(), tc.secs().to_bits(), "{key} put");
            let (gp, _) = plain.get(VTime::ZERO, &key, &mut ca).unwrap();
            let (gc, _) = cl.get(VTime::ZERO, &key, &mut cb).unwrap();
            assert_eq!(gp.secs().to_bits(), gc.secs().to_bits(), "{key} get");
        }
        assert_eq!(ca.comm_time.to_bits(), cb.comm_time.to_bits());
        assert_eq!(ca.visibility_wait.to_bits(), cb.visibility_wait.to_bits());
        assert_eq!(ca.wire_bytes(), cb.wire_bytes());
    }

    #[test]
    fn replication_writes_land_on_distinct_shards() {
        let mut cl = cluster(4, 2);
        let mut c = CommStats::new();
        cl.set(VTime::ZERO, "k", slab(1000), &mut c);
        let holders: Vec<usize> = (0..4).filter(|&s| cl.shards[s].redis.contains("k")).collect();
        assert_eq!(holders.len(), 2, "one primary + one replica");
        assert_eq!(c.ops(crate::metrics::CommKind::Put), 2);
        // Replica visibility trails the primary ack.
        let primary = cl.primary_of("k");
        let replica = *holders.iter().find(|&&s| s != primary).unwrap();
        assert!(
            cl.shards[replica].redis.visible_at("k").unwrap()
                > cl.shards[primary].redis.visible_at("k").unwrap()
        );
    }

    #[test]
    fn failover_read_after_shard_crash() {
        let mut cl = cluster(3, 2);
        let mut c = CommStats::new();
        let vis = cl.set(VTime::ZERO, "k", slab(1000), &mut c);
        let primary = cl.primary_of("k");
        cl.crash_shard(primary, vis).unwrap();
        assert!(cl.shards[primary].is_down(vis));
        assert!(!cl.shards[primary].redis.contains("k"), "crash loses contents");

        let t = vis + 1.0;
        let (done, got) = cl.get(t, "k", &mut c).unwrap();
        assert_eq!(got.len(), 1000);
        assert!(done > t);
        assert_eq!(cl.total_failovers(), 1);
        let reports = cl.shard_reports();
        let served: Vec<usize> =
            reports.iter().filter(|r| r.stats.failovers > 0).map(|r| r.shard).collect();
        assert_eq!(served.len(), 1);
        assert_ne!(served[0], primary);

        // After the restart window the primary is live again (but empty —
        // new writes repopulate it).
        let later = vis + SHARD_RESTART_SECS + 1.0;
        assert!(!cl.shards[primary].is_down(later));
        cl.set(later, "k2", slab(10), &mut c);
    }

    #[test]
    fn unreplicated_crash_waits_out_restart_or_errors() {
        let mut cl = cluster(2, 1);
        let mut c = CommStats::new();
        let vis = cl.set(VTime::ZERO, "k", slab(1000), &mut c);
        let primary = cl.primary_of("k");
        cl.crash_shard(primary, vis).unwrap();
        // R=1: the only copy died with the shard.
        assert!(cl.get(vis + 1.0, "k", &mut c).is_err());
        // A fresh write during downtime fails over to the live shard and
        // stays readable.
        let t = cl.set(vis + 1.0, "k", slab(1000), &mut c);
        assert!(cl.get(t, "k", &mut c).is_ok());
    }

    #[test]
    fn lru_eviction_is_deterministic_and_budgeted() {
        let cfg = StoreTierConfig {
            shards: 1,
            replication: 1,
            vnodes: 4,
            capacity_bytes: Some(10_000), // 2500 f32s
        };
        let run = || {
            let mut cl = RedisCluster::new("shared", &cfg).unwrap();
            let mut c = CommStats::new();
            for i in 0..6 {
                cl.set(VTime::from_secs(i as f64), &format!("k{i}"), slab(300), &mut c);
            }
            // Touch k4 so k5's write evicts the older k3 first.
            cl.get(VTime::from_secs(10.0), "k4", &mut c).unwrap();
            cl.set(VTime::from_secs(11.0), "big", slab(2000), &mut c);
            let survivors: Vec<String> =
                (0..6).map(|i| format!("k{i}")).filter(|k| cl.contains(k)).collect();
            let r = cl.shard_reports().remove(0);
            (survivors, r.stats.evictions, r.resident_bytes)
        };
        let (a_s, a_e, a_b) = run();
        let (b_s, b_e, b_b) = run();
        assert_eq!(a_s, b_s, "eviction order must be run-invariant");
        assert_eq!(a_e, b_e);
        assert_eq!(a_b, b_b);
        assert!(a_e > 0, "budget must have evicted something");
        assert!(a_b <= 10_000, "budget holds after every write");
        assert!(a_s.contains(&"k4".to_string()), "recently-read key survives");
        assert!(!a_s.contains(&"k0".to_string()), "coldest key goes first");
    }

    #[test]
    fn hot_key_tracking_survives_deletion() {
        let mut cl = cluster(1, 1);
        let mut c = CommStats::new();
        let vis = cl.set(VTime::ZERO, "hot", slab(100), &mut c);
        for _ in 0..5 {
            cl.get(vis, "hot", &mut c).unwrap();
        }
        cl.set(VTime::ZERO, "cold", slab(100), &mut c);
        cl.get(vis + 100.0, "cold", &mut c).unwrap();
        cl.delete("hot");
        let r = cl.shard_reports().remove(0);
        assert_eq!(r.stats.hottest_key, "hot");
        assert_eq!(r.stats.hottest_gets, 5);
        assert_eq!(r.keys, 1, "deleted key is gone from the store");
    }

    #[test]
    fn hosting_bill_covers_every_shard() {
        let cl = cluster(4, 2);
        let mut ledger = Ledger::new();
        cl.bill_hosting(3600.0, &mut ledger);
        let got = ledger.get(crate::metrics::CostKind::Ec2Redis);
        let want = crate::cloud::pricing::redis_host_cost(3600.0, 4);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn sharding_relieves_the_single_command_loop() {
        // The point of the tier: concurrent writers to distinct keys stop
        // serializing behind one command loop once there are enough shards.
        let run = |shards: usize| {
            let mut cl = cluster(shards, 1);
            let mut c = CommStats::new();
            (0..8)
                .map(|i| cl.set(VTime::ZERO, &format!("w{i}/grad"), slab(2_000_000), &mut c))
                .fold(VTime::ZERO, VTime::max)
                .secs()
        };
        let one = run(1);
        let eight = run(8);
        assert!(eight < one * 0.7, "8 shards {eight:.3}s vs 1 shard {one:.3}s");
    }
}
