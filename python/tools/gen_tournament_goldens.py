#!/usr/bin/env python3
"""Generate ``rust/tests/golden/tournament_fixture.{txt,md,json}``.

Builds the exact tournament-shaped fixture that
``rust/tests/tournament.rs::fixture()`` builds — same table ids, column
set, Pareto marks, and section layout as ``exp::tournament::report`` —
and renders it through the byte-exact replica in ``report_replica.py``.
Run from the repo root:

    python3 python/tools/gen_tournament_goldens.py

Regenerate only when the renderer format or the tournament grid dialect
deliberately changes; the golden tests exist to catch *accidental* drift.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import report_replica as rr  # noqa: E402

COLUMNS = [
    ("Framework", rr.LEFT),
    ("Rule", rr.LEFT),
    ("Time (s)", rr.RIGHT),
    ("Cost ($)", rr.RIGHT),
    ("Acc (%)", rr.RIGHT),
    ("dAcc (pts)", rr.RIGHT),
    ("Pareto", rr.LEFT),
    ("Recovery", rr.LEFT),
]


def grid_row(fw, rule, time, cost, acc, dacc, pareto, recovery):
    return [
        rr.cell(fw),
        rr.cell(rule),
        rr.num_cell(time, 1),
        rr.num_cell(cost, 4),
        rr.num_cell(acc, 1),
        rr.cell(f"{dacc:+.1f}", value=dacc),
        rr.cell("*" if pareto else "-"),
        rr.cell(recovery),
    ]


def fixture():
    coalition = rr.table("tournament_coalition", COLUMNS, title="Attack: coalition")
    rr.push_row(coalition, grid_row("spirt", "mean", 412.5, 0.0315, 52.1, -34.6, False, "16 poisoned"))
    rr.push_row(coalition, grid_row("spirt", "krum", 437.2, 0.0341, 86.3, -0.4, True, "16 poisoned"))
    rr.rule(coalition)
    rr.push_row(coalition, grid_row("allreduce", "mean", 398.1, 0.0287, 52.1, -34.6, True, "16 poisoned"))
    rr.push_row(
        coalition, grid_row("allreduce", "trimmed-mean", 421.9, 0.0312, 86.1, -0.6, True, "16 poisoned")
    )

    storm = rr.table("tournament_preemption_storm", COLUMNS, title="Attack: preemption-storm")
    rr.push_row(storm, grid_row("spirt", "mean", 498.7, 0.0389, 86.7, 0.0, True, "3 preempted"))
    rr.push_row(storm, grid_row("spirt", "coord-median", 530.4, 0.0452, 86.7, 0.0, False, "3 preempted"))
    rr.rule(storm)
    rr.push_row(storm, grid_row("allreduce", "mean", 471.3, 0.0344, 86.7, 0.0, True, "3 preempted"))
    rr.push_row(
        storm, grid_row("allreduce", "coord-median", 503.8, 0.0401, 86.7, 0.0, False, "3 preempted")
    )

    return rr.report(
        "tournament",
        "Robustness tournament — aggregation rule × attack × architecture",
        "slsgpu robustness-tournament --model mobilenet --workers 8 --epochs 2 --seed 42",
        intro=[
            "Fixed input for the tournament golden-file tests: the (framework x rule) grid "
            "dialect with Pareto verdicts, byte-stable across runs and platforms."
        ],
        sections=[
            rr.section(
                heading="Attack: coalition",
                paragraphs=["Workers 1 and 2 collude on the same rounds; the mean diverges."],
                tables=[coalition],
            ),
            # Report::with_note appends to the last section, so the
            # report-level note lands here.
            rr.section(
                heading="Attack: preemption-storm",
                paragraphs=["Correlated spot preemptions; accuracy is unharmed, time is not."],
                tables=[storm],
                notes=["note: every cell is an independent seeded simulation."],
            ),
        ],
    )


def main():
    root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    golden = os.path.join(root, "rust", "tests", "golden")
    os.makedirs(golden, exist_ok=True)
    r = fixture()
    outputs = {
        "tournament_fixture.txt": rr.report_text(r),
        "tournament_fixture.md": rr.report_md(r),
        "tournament_fixture.json": rr.report_json(r),
    }
    for name, contents in outputs.items():
        path = os.path.join(golden, name)
        with open(path, "w", encoding="utf-8") as fh:
            fh.write(contents)
        print(f"wrote {path} ({len(contents)} bytes)")


if __name__ == "__main__":
    main()
