//! Discrete-event scheduling primitives: the event-queue core.
//!
//! The simulator is analytic — every substrate op computes its completion
//! time in closed form — but the *coordination* layer still has to resolve
//! waits: "which k of these n contributions land first" (quorum gathers),
//! "when is the k-th message visible" (queue polls), "process completions
//! in arrival order" (SPIRT's minibatch fan-in). Before this module those
//! resolutions re-sorted full vectors per call, which is what made
//! 1024–4096-worker rounds cost O(W² log W) host work. The two structures
//! here make them O(log W) per event without moving a single bit of
//! virtual time:
//!
//! * [`EventQueue`] — a deterministic min-heap of `(VTime, seq, payload)`
//!   events. Ties at equal `VTime` pop in **insertion order** (the `seq`
//!   counter), so a caller that pushes events in its tie-break order gets
//!   exactly the order a stable sort of `(VTime, push index)` would
//!   produce. `coordinator::protocol::quorum_subset` pushes candidates in
//!   rotated-index order and pops the quorum; `coordinator::spirt` pushes
//!   minibatch completions and pops them in completion order.
//! * [`OrderLog`] — an incrementally maintained sorted multiset of
//!   `VTime`s with O(log n) rank queries. `cloud::queue` keeps one per
//!   topic so `kth_visible` (the MLLess supervisor wait and every queue
//!   poll) stops re-sorting the topic's full visibility vector per call.
//!
//! Both are *order-isomorphic* to the sort-based code they replace — the
//! unit tests pin the pop/rank sequences bit-for-bit against sort
//! references over adversarial tie patterns — which is what lets the
//! determinism suite demand bit-identical vtime/cost/trace output on the
//! new core.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::VTime;

/// One pending event: fires at `at`; `seq` breaks ties FIFO.
#[derive(Debug, Clone)]
struct Entry<T> {
    at: VTime,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the earliest event
        // (and, at equal times, the earliest-pushed) on top.
        other.at.cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic discrete-event priority queue.
///
/// `pop` yields events in `(VTime, insertion order)` — identical to
/// stable-sorting the pushed `(at, payload)` pairs by `at`. The insertion
/// counter is queue-local, so draining and reusing a queue never leaks
/// ordering state between rounds.
#[derive(Debug, Clone, Default)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    next_seq: u64,
}

impl<T> EventQueue<T> {
    pub fn new() -> EventQueue<T> {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0 }
    }

    pub fn with_capacity(n: usize) -> EventQueue<T> {
        EventQueue { heap: BinaryHeap::with_capacity(n), next_seq: 0 }
    }

    /// Schedule `payload` at `at`. Events pushed at the same `VTime` pop
    /// in push order.
    pub fn push(&mut self, at: VTime, payload: T) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry { at, seq, payload });
    }

    /// Remove and return the earliest event.
    pub fn pop(&mut self) -> Option<(VTime, T)> {
        self.heap.pop().map(|e| (e.at, e.payload))
    }

    /// Time of the earliest pending event.
    pub fn peek_time(&self) -> Option<VTime> {
        self.heap.peek().map(|e| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drain every pending event in firing order.
    pub fn drain_ordered(&mut self) -> Vec<(VTime, T)> {
        let mut out = Vec::with_capacity(self.heap.len());
        while let Some(e) = self.pop() {
            out.push(e);
        }
        out
    }
}

/// Incrementally sorted multiset of `VTime`s with O(log n) rank queries.
///
/// `insert` places the value *after* any equal elements (binary search on
/// `partition_point`), so the stored order is exactly what a stable sort
/// of the insertion sequence would produce — and `kth(k)` is exactly the
/// value `sorted[k-1]` the old sort-per-call code computed.
#[derive(Debug, Clone, Default)]
pub struct OrderLog {
    sorted: Vec<VTime>,
}

impl OrderLog {
    pub fn new() -> OrderLog {
        OrderLog { sorted: Vec::new() }
    }

    pub fn insert(&mut self, t: VTime) {
        let idx = self.sorted.partition_point(|&x| x <= t);
        self.sorted.insert(idx, t);
    }

    /// 1-based order statistic: the k-th smallest recorded value.
    pub fn kth(&self, k: usize) -> Option<VTime> {
        if k == 0 {
            return None;
        }
        self.sorted.get(k - 1).copied()
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    pub fn clear(&mut self) {
        self.sorted.clear();
    }

    /// Rebuild from an unsorted iterator (used after a queue drain removes
    /// an arbitrary subset of messages).
    pub fn rebuild(&mut self, times: impl Iterator<Item = VTime>) {
        self.sorted.clear();
        self.sorted.extend(times);
        self.sorted.sort();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random times on a coarse grid so ties are
    /// frequent (the interesting case for tie-break rules).
    fn grid_times(seed: u64, n: usize) -> Vec<VTime> {
        let mut state = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                VTime::from_secs((state >> 59) as f64) // 0..=31, heavy ties
            })
            .collect()
    }

    #[test]
    fn pop_order_matches_stable_sort_bit_for_bit() {
        for seed in 1..=20u64 {
            let times = grid_times(seed, 97);
            let mut q = EventQueue::new();
            for (i, &t) in times.iter().enumerate() {
                q.push(t, i);
            }
            // Reference: the sort-based resolution this queue replaces.
            let mut reference: Vec<(VTime, usize)> =
                times.iter().copied().zip(0..times.len()).collect();
            reference.sort_by(|a, b| a.0.cmp(&b.0)); // stable: ties keep push order
            let drained = q.drain_ordered();
            assert_eq!(drained.len(), reference.len());
            for ((ta, pa), (tb, pb)) in drained.iter().zip(&reference) {
                assert_eq!(ta.to_bits(), tb.to_bits(), "seed {seed}: time bits");
                assert_eq!(pa, pb, "seed {seed}: tie-break must be FIFO");
            }
        }
    }

    #[test]
    fn interleaved_push_pop_is_still_earliest_first() {
        let mut q = EventQueue::new();
        q.push(VTime::from_secs(5.0), "late");
        q.push(VTime::from_secs(1.0), "early");
        assert_eq!(q.peek_time(), Some(VTime::from_secs(1.0)));
        assert_eq!(q.pop().unwrap().1, "early");
        q.push(VTime::from_secs(0.5), "earlier still");
        assert_eq!(q.pop().unwrap().1, "earlier still");
        assert_eq!(q.pop().unwrap().1, "late");
        assert!(q.pop().is_none() && q.is_empty());
    }

    #[test]
    fn fifo_ties_survive_reuse_across_rounds() {
        // Draining must reset nothing that would perturb the next round's
        // tie-break: two identical rounds pop identically.
        let mut q = EventQueue::new();
        let round = |q: &mut EventQueue<usize>| {
            for i in 0..8 {
                q.push(VTime::from_secs(2.0), i);
            }
            q.drain_ordered().into_iter().map(|(_, i)| i).collect::<Vec<_>>()
        };
        assert_eq!(round(&mut q), round(&mut q));
        assert_eq!(round(&mut q), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn order_log_kth_matches_sort_reference() {
        for seed in 1..=20u64 {
            let times = grid_times(seed.wrapping_add(100), 61);
            let mut log = OrderLog::new();
            let mut reference: Vec<VTime> = Vec::new();
            for &t in &times {
                log.insert(t);
                reference.push(t);
                let mut sorted = reference.clone();
                sorted.sort();
                for k in 1..=reference.len() {
                    assert_eq!(
                        log.kth(k).unwrap().to_bits(),
                        sorted[k - 1].to_bits(),
                        "seed {seed}: k={k} of {}",
                        reference.len()
                    );
                }
            }
        }
        assert_eq!(OrderLog::new().kth(0), None);
        assert_eq!(OrderLog::new().kth(1), None);
    }

    #[test]
    fn order_log_rebuild_matches_fresh_inserts() {
        let times = grid_times(7, 33);
        let mut incremental = OrderLog::new();
        for &t in &times {
            incremental.insert(t);
        }
        let mut rebuilt = OrderLog::new();
        rebuilt.rebuild(times.iter().copied());
        assert_eq!(incremental.len(), rebuilt.len());
        for k in 1..=times.len() {
            assert_eq!(incremental.kth(k).unwrap().to_bits(), rebuilt.kth(k).unwrap().to_bits());
        }
    }
}
