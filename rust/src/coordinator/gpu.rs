//! Distributed GPU baseline: data-parallel T4 instances + S3 all-gather.
//!
//! The paper's baseline (§2): each g4dn.xlarge processes its batch, uploads
//! gradients to a shared S3 bucket, downloads the peers' gradients and
//! averages locally before updating. No Lambda billing — the instances are
//! on for the whole epoch (hourly billing), which is exactly the
//! always-on-vs-pay-per-use contrast the paper studies.
//!
//! Under [`SyncMode::Async`] each instance averages the earliest-visible
//! quorum of peer gradients (its own local copy always included) instead of
//! waiting for the full all-gather — the asynchronous-SGD variant of the
//! baseline.

use crate::cloud::FrameworkKind;
use crate::metrics::Stage;
use crate::tensor::Slab;
use crate::Result;

use super::env::{ClusterEnv, Device};
use super::protocol::{store_quorum, StoreSel, SyncMode};
use super::{EpochStats, Strategy};

#[derive(Debug, Default)]
pub struct GpuBaseline;

impl GpuBaseline {
    pub fn new() -> GpuBaseline {
        GpuBaseline
    }
}

impl Strategy for GpuBaseline {
    fn kind(&self) -> FrameworkKind {
        FrameworkKind::GpuBaseline
    }

    fn run_epoch(&mut self, env: &mut ClusterEnv) -> Result<EpochStats> {
        env.begin_epoch();
        let w_count = env.num_workers();
        let start = env.max_clock();
        let mode = env.sync;
        let mut loss_sum = 0.0;
        let mut loss_n = 0usize;

        for round in 0..env.batches_per_epoch {
            env.trace.set_round(round);
            let tag = format!("gpu/e{}/r{}", env.epoch, round);

            // Compute on the T4s (data already resident on instance disk).
            // A crashed step costs an instance *reboot* — and the instance
            // keeps billing by the hour while it boots, which is the
            // always-on half of the paper's cost argument.
            let mut grads = Vec::with_capacity(w_count);
            for w in 0..w_count {
                let mut g = env.compute_grad(w, Device::GpuT4)?;
                if env.crash_in_compute(w) {
                    g = env.recover_invocation(w, Device::GpuT4)?;
                }
                if let Some(l) = g.loss {
                    loss_sum += l;
                    loss_n += 1;
                }
                grads.push(g.grad);
            }

            // All-gather through the shared bucket (EC2-side bandwidth).
            // Every peer needs every gradient, so a rebooting instance
            // stalls the whole fleet; dropped uploads fall out of the mean.
            // One key string per worker per round; the fetch loops below
            // index into this instead of re-formatting W keys per fetcher
            // (O(W^2) string builds per round at sweep scale).
            let keys: Vec<String> = (0..w_count).map(|j| format!("{tag}/g{j}")).collect();
            let mut dropped = vec![false; w_count];
            for w in 0..w_count {
                let mut tl = env.timeline(w);
                if tl.enter_sync() {
                    dropped[w] = true;
                    continue;
                }
                tl.put(StoreSel::Gpu, Stage::Synchronize, &keys[w], grads[w].share());
            }

            // Async mode: one earliest-visible quorum of uploads per round;
            // every instance fetches that subset (plus its own local copy).
            // BSP drives its fetches off `dropped` directly, so `picked`
            // stays empty there.
            let uploaded: Vec<usize> = (0..w_count).filter(|&j| !dropped[j]).collect();
            let up_keys: Vec<String> = uploaded.iter().map(|&j| keys[j].clone()).collect();
            let picked: Vec<usize> = match mode {
                SyncMode::Bsp => Vec::new(),
                SyncMode::Async { .. } => {
                    let sub = store_quorum(env, StoreSel::Gpu, &up_keys, mode, round, 0);
                    env.comm.stale_skips += (uploaded.len() - sub.len()) as u64;
                    sub.into_iter().map(|i| uploaded[i]).collect()
                }
            };

            for w in 0..w_count {
                let mut fetched = Vec::with_capacity(w_count);
                match mode {
                    SyncMode::Bsp => {
                        let mut tl = env.timeline(w);
                        for j in 0..w_count {
                            if j == w {
                                // The local copy survives even if the
                                // upload dropped.
                                fetched.push(grads[w].share());
                                continue;
                            }
                            if dropped[j] {
                                continue;
                            }
                            fetched.push(tl.get(StoreSel::Gpu, Stage::Synchronize, &keys[j])?);
                        }
                    }
                    SyncMode::Async { .. } => {
                        fetched.push(grads[w].share());
                        let mut tl = env.timeline(w);
                        for &j in &picked {
                            if j == w {
                                continue;
                            }
                            fetched.push(tl.get(StoreSel::Gpu, Stage::Synchronize, &keys[j])?);
                        }
                    }
                }
                let mean = env.aggregate(w, &fetched)?;
                env.apply_update(w, &mean, 1.0)?;
                env.charge_sync(w, self.kind().batch_overhead());
            }

            // The round's uploads are consumed; free them (timeline-neutral).
            for key in &up_keys {
                env.gpu_store.delete(key);
            }
        }

        // Instances bill for the epoch's wall time.
        let epoch_secs = env.max_clock() - start;
        env.fleet.bill(epoch_secs, &mut env.ledger);

        Ok(EpochStats {
            mean_loss: (loss_n > 0).then(|| loss_sum / loss_n as f64),
            batches: env.batches_per_epoch * w_count,
            epoch_secs,
            mean_fn_secs: 0.0,
        })
    }

    fn stage_table(&self) -> Vec<(Stage, &'static str)> {
        vec![
            (
                Stage::FetchDataset,
                "Each GPU loads its assigned batch of data and a local copy of the model.",
            ),
            (Stage::ComputeGradients, "Gradients are computed locally by each GPU."),
            (
                Stage::Synchronize,
                "Each GPU uploads its gradients to a shared S3 bucket, retrieves others' \
                 gradients, and performs local averaging.",
            ),
            (Stage::ModelUpdate, "The locally averaged gradients are used to update the model."),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::env::EnvConfig;
    use crate::metrics::CostKind;

    fn env(arch: &str) -> ClusterEnv {
        ClusterEnv::new(
            EnvConfig::virtual_paper(FrameworkKind::GpuBaseline, arch, 4).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn epoch_time_matches_paper() {
        for (arch, paper) in [("mobilenet", 92.0), ("resnet18", 139.0)] {
            let mut e = env(arch);
            let stats = GpuBaseline::new().run_epoch(&mut e).unwrap();
            let err = (stats.epoch_secs - paper).abs() / paper;
            assert!(err < 0.15, "{arch}: epoch {:.1}s vs paper {paper}s", stats.epoch_secs);
        }
    }

    #[test]
    fn bills_ec2_not_lambda() {
        let mut e = env("mobilenet");
        GpuBaseline::new().run_epoch(&mut e).unwrap();
        assert!(e.ledger.get(CostKind::Ec2Gpu) > 0.0);
        assert_eq!(e.ledger.get(CostKind::LambdaCompute), 0.0);
        // Paper: ~0.0538 USD for the MobileNet epoch.
        let cost = e.ledger.get(CostKind::Ec2Gpu);
        assert!((cost - 0.0538).abs() / 0.0538 < 0.2, "cost {cost}");
    }

    #[test]
    fn gpu_epoch_is_much_faster_than_serverless() {
        let mut g = env("mobilenet");
        let gstats = GpuBaseline::new().run_epoch(&mut g).unwrap();
        let mut a = ClusterEnv::new(
            EnvConfig::virtual_paper(FrameworkKind::AllReduce, "mobilenet", 4).unwrap(),
        )
        .unwrap();
        let astats = super::super::allreduce::AllReduce::new().run_epoch(&mut a).unwrap();
        assert!(gstats.epoch_secs * 2.0 < astats.epoch_secs);
    }

    #[test]
    fn async_all_gather_fetches_fewer_gradients() {
        let mut bsp = ClusterEnv::new(
            EnvConfig::virtual_paper(FrameworkKind::GpuBaseline, "mobilenet", 8).unwrap(),
        )
        .unwrap();
        let b = GpuBaseline::new().run_epoch(&mut bsp).unwrap();
        let mut asy = ClusterEnv::new(
            EnvConfig::virtual_paper(FrameworkKind::GpuBaseline, "mobilenet", 8)
                .unwrap()
                .with_sync(SyncMode::Async { staleness: 3 }),
        )
        .unwrap();
        let a = GpuBaseline::new().run_epoch(&mut asy).unwrap();
        use crate::metrics::CommKind;
        assert!(asy.comm.ops(CommKind::Get) < bsp.comm.ops(CommKind::Get));
        assert_eq!(asy.comm.stale_skips, 3 * 24);
        assert!(a.epoch_secs <= b.epoch_secs, "async {} vs {}", a.epoch_secs, b.epoch_secs);
    }
}
