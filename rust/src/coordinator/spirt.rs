//! SPIRT: fault-tolerant P2P serverless training with in-database math.
//!
//! The paper's §2 workflow, reproduced stage by stage:
//!
//! 1. **Fetch/Compute** — each worker runs its minibatch gradient functions
//!    *in parallel* (one Lambda invocation per minibatch); every gradient is
//!    written into the worker's own RedisAI instance and accumulated there
//!    (`acc_in_db` — the gradient never returns to the function).
//! 2. **In-DB averaging** — the accumulated sum is scaled to a mean inside
//!    the database (`scale_in_db`).
//! 3. **Synchronize** — the worker notifies a sync queue, polls until all
//!    peers report, then fetches every peer's *averaged* gradient directly
//!    from the peers' Redis instances (P2P, no central store).
//! 4. **Update** — second-level aggregation is stored locally and the model
//!    update runs *in the database* via the fused Pallas `avg_update`
//!    kernel (`avg_update_in_db`).
//!
//! Gradient accumulation means SPIRT synchronizes **once per epoch** rather
//! than once per batch — the key reason it converges in wall-clock time
//! close to the GPU baseline (Table 3) while LambdaML variants take 20×
//! longer. A Step Functions state machine drives the stage pipeline.

use crate::cloud::FrameworkKind;
use crate::metrics::Stage;
use crate::sim::{EventQueue, VTime};
use crate::tensor::Slab;
use crate::trace::EventKind;
use crate::Result;

use super::env::{ClusterEnv, Device};
use super::protocol::{trace_redis_key, RedisSel};
use super::{EpochStats, Strategy};

#[derive(Debug, Default)]
pub struct Spirt;

impl Spirt {
    pub fn new() -> Spirt {
        Spirt
    }

    /// Upload the model replica into each worker's Redis (epoch 1 setup).
    fn ensure_theta_in_db(&self, env: &mut ClusterEnv) {
        for w in 0..env.num_workers() {
            if !env.worker_redis[w].contains("theta") {
                let theta = env.workers[w].theta.share();
                env.timeline(w).redis_set(RedisSel::Own, Stage::FetchDataset, "theta", theta);
            }
        }
    }
}

impl Strategy for Spirt {
    fn kind(&self) -> FrameworkKind {
        FrameworkKind::Spirt
    }

    fn run_epoch(&mut self, env: &mut ClusterEnv) -> Result<EpochStats> {
        env.begin_epoch();
        let w_count = env.num_workers();
        let start = env.max_clock();
        let alloc_mb = env.allocated_mb();
        let epoch = env.epoch;
        let inv_k_minibatch = 1.0 / env.batches_per_epoch as f32;
        let traced = env.trace.enabled();
        let mut loss_sum = 0.0;
        let mut loss_n = 0usize;

        self.ensure_theta_in_db(env);

        // ---- Stage 1+2: parallel minibatch gradient functions ------------
        for w in 0..w_count {
            let base = env.workers[w].clock;
            let base = env.stepfn.enter_stage(base, "compute", &mut env.ledger);
            let mut gsum_ready = VTime::ZERO;

            // Phase A — fan out: every minibatch invocation starts at `base`
            // and computes independently (Lambda scales horizontally). A
            // crashed minibatch function is retried by the platform — the
            // retry lands late but the *other* minibatches keep going, so
            // the epoch absorbs the restart instead of stalling on it (the
            // fault-tolerance property the SPIRT paper claims). A dropped
            // minibatch gradient never reaches the database: its signal is
            // lost but the function still ran and bills.
            let mut arrivals = EventQueue::with_capacity(env.batches_per_epoch);
            let mut dropped_done = VTime::ZERO;
            for m in 0..env.batches_per_epoch {
                env.trace.set_round(m);
                env.workers[w].clock = base;
                let inv = env.lambda.begin_invocation(base, w);
                env.workers[w].clock = inv.body_start;
                env.state_load(w);
                let mut g = env.compute_grad(w, Device::LambdaCpu)?;
                if env.crash_in_compute(w) {
                    g = env.recover_invocation(w, Device::LambdaCpu)?;
                }
                if let Some(l) = g.loss {
                    loss_sum += l;
                    loss_n += 1;
                }
                if env.update_dropped(w) {
                    let end = env.workers[w].clock + self.kind().batch_overhead();
                    env.stages.add(Stage::Synchronize, self.kind().batch_overhead());
                    env.lambda.finish_invocation(inv, end, alloc_mb, &mut env.ledger);
                    dropped_done = dropped_done.max(end);
                    continue;
                }
                arrivals.push(env.workers[w].clock, (m, inv, g.grad));
            }

            // Phase B — the worker's single-threaded RedisAI serves the
            // gradient writes + in-DB accumulations in *arrival* order (the
            // cold-started invocation arrives last and must not delay the
            // warm ones through FIFO scheduling). The accumulation script is
            // fired asynchronously: the function returns after its TENSORSET
            // acks; the database chews through the accumulation chain in the
            // background and the *epoch* waits for it, not the functions.
            // Popping the event queue yields arrivals earliest-first with
            // FIFO ties (minibatch order) — the same order the stable sort
            // on arrival time produced, bit for bit.
            if arrivals.is_empty() {
                // Every minibatch gradient was dropped: seed an empty sum so
                // the averaging/update stages still run (a zero update).
                let zero = if env.is_real() {
                    Slab::zeros(env.n_params)
                } else {
                    Slab::virtual_of(env.n_params)
                };
                let t0 = base.max(dropped_done);
                gsum_ready = env.worker_redis[w].set(t0, "gsum", zero, &mut env.comm);
            }
            let mut fn_done = dropped_done;
            // The in-DB accumulation chain: each acc depends on the previous
            // one (the database serializes the scripts), which the trace
            // records as explicit edges so the critical path can follow the
            // chain even though worker clocks reset per minibatch.
            let mut prev_acc: Option<u64> = None;
            let mut i = 0usize;
            while let Some((arrive, (m, inv, grad))) = arrivals.pop() {
                env.trace.set_round(m);
                let gbytes = if traced { grad.nbytes() } else { 0 };
                let gkey = format!("g/e{epoch}/m{m}");
                let t = env.worker_redis[w].set(arrive, &gkey, grad, &mut env.comm);
                env.stages.add(Stage::ComputeGradients, t - arrive);
                if traced {
                    // audit:allow(trace-emit, SPIRT private-op emit point - DESIGN.md §6)
                    env.trace.span(w, arrive, t, EventKind::RedisSet, gbytes, 0.0, None);
                }

                // Async in-DB accumulate (first arrival seeds the sum).
                let acc_done = if i == 0 {
                    env.worker_redis[w].scale_in_db(t, "gsum", &gkey, 1.0, &mut env.comm)?
                } else {
                    env.worker_redis[w].acc_in_db(t, "gsum", "gsum", &gkey, 1.0, &mut env.comm)?
                };
                if traced {
                    // audit:allow(trace-emit, SPIRT in-DB accumulation chain - private-op emit point, DESIGN.md §6)
                    let idx =
                        env.trace.span(w, t, acc_done, EventKind::InDb, gbytes, 0.0, prev_acc);
                    prev_acc = idx;
                }
                gsum_ready = gsum_ready.max(acc_done);
                env.worker_redis[w].delete(&gkey);

                // Residual orchestration overhead + billing (function ends
                // without waiting for the accumulation script).
                let end = t + self.kind().batch_overhead();
                env.stages.add(Stage::Synchronize, self.kind().batch_overhead());
                env.lambda.finish_invocation(inv, end, alloc_mb, &mut env.ledger);
                fn_done = fn_done.max(end);
                i += 1;
            }
            // Worker resumes when all minibatch functions *and* the in-DB
            // accumulation chain are done.
            env.workers[w].clock = fn_done.max(gsum_ready);

            // In-DB averaging of the accumulated sum.
            let avg_key = format!("avg/e{epoch}");
            let t0 = env.stepfn.enter_stage(env.workers[w].clock, "average", &mut env.ledger);
            let t = env.worker_redis[w].scale_in_db(
                t0,
                &avg_key,
                "gsum",
                inv_k_minibatch,
                &mut env.comm,
            )?;
            if traced {
                // audit:allow(trace-emit, SPIRT in-DB averaging - private-op emit point, DESIGN.md §6)
                let idx = env.trace.span(w, t0, t, EventKind::InDb, 0, 0.0, prev_acc);
                // Peers fetch the average P2P: register this as its writer
                // so their `redis_get(Peer(w), ..)` deps resolve.
                env.trace.note_write(
                    trace_redis_key(RedisSel::Own, w, &env.shared_redis, &avg_key),
                    idx,
                );
            }
            env.stages.add(Stage::ComputeGradients, t - env.workers[w].clock);
            env.workers[w].clock = t;
        }
        env.trace.set_round(0);

        // ---- Stage 3: sync queue + P2P fetch of averaged gradients -------
        // Fault semantics: a worker that crashes entering sync restarts
        // (its clock absorbs the downtime and its model is restored from
        // its own Redis snapshot), but its *peers do not wait* — they count
        // only live workers on the sync queue and reroute the P2P exchange
        // around the dead peer's average. That is SPIRT's P2P advantage
        // over the master/supervisor topologies, made measurable. Async
        // mode thins the queue wait further to a bounded-staleness quorum
        // and skips peer averages that are not yet visible.
        let mut down = vec![false; w_count];
        for (w, d) in down.iter_mut().enumerate() {
            *d = env.sync_crash(w).is_some();
        }
        let live = down.iter().filter(|d| !**d).count().max(1);
        let wait_count = env.sync.quorum(live);

        let topic = format!("spirt/sync/e{epoch}");
        for w in 0..w_count {
            env.workers[w].clock =
                env.stepfn.enter_stage(env.workers[w].clock, "sync", &mut env.ledger);
            env.timeline(w).notify(&topic, format!("w{w}"));
        }
        for w in 0..w_count {
            env.timeline(w).poll(&topic, wait_count)?;
        }
        // Every worker has observed the quorum; the per-epoch topic's
        // messages are dead weight from here on (topic names are unique per
        // epoch, so without this the queue grows by W messages every epoch).
        env.queues.drop_topic(&topic);

        let avg_key = format!("avg/e{epoch}");
        for w in 0..w_count {
            let mut avgs: Vec<Slab> = Vec::with_capacity(w_count);
            // Own average: read locally (in-instance, negligible transfer).
            avgs.push(env.worker_redis[w].peek_slab(&avg_key)?);
            for j in 0..w_count {
                if j == w {
                    continue;
                }
                if down[j] {
                    // Reroute: skip the dead peer's average this epoch.
                    env.recovery.rerouted_fetches += 1;
                    continue;
                }
                if env.sync.is_async() {
                    // Bounded staleness: take only averages already visible
                    // at this worker's clock; the quorum wait above
                    // guarantees enough of them.
                    let vis = env.worker_redis[j].visible_at(&avg_key).expect("peer avg stored");
                    if vis > env.workers[w].clock {
                        env.comm.stale_skips += 1;
                        continue;
                    }
                }
                let g = env.timeline(w).redis_get(RedisSel::Peer(j), Stage::Synchronize, &avg_key)?;
                avgs.push(g);
            }

            // Second-level aggregation, stored locally.
            let agg_secs = env.local_agg_secs(avgs.len());
            env.charge_sync(w, agg_secs);
            let final_grad = env.aggregate(w, &avgs)?;
            env.timeline(w).redis_set(
                RedisSel::Own,
                Stage::Synchronize,
                &format!("final/e{epoch}"),
                final_grad,
            );

            // ---- Stage 4: in-database model update (fused kernel) --------
            // Gradient accumulation applies ONE averaged update per epoch;
            // linear LR scaling (capped for stability) compensates for the
            // reduced update frequency — the standard large-batch rule, and
            // why SPIRT's convergence-per-epoch stays close to the per-batch
            // frameworks' (Table 3).
            let lr = env.lr * (env.batches_per_epoch.min(8) as f32);
            let t0 = env.stepfn.enter_stage(env.workers[w].clock, "update", &mut env.ledger);
            let t = env.worker_redis[w].avg_update_in_db(
                t0,
                "theta",
                &format!("final/e{epoch}"),
                1.0, // already a global mean
                lr,
                &mut env.comm,
            )?;
            if traced {
                // Fused in-DB update; same-worker program order links it to
                // the final-gradient write just above.
                // audit:allow(trace-emit, SPIRT fused in-DB update - private-op emit point, DESIGN.md §6)
                env.trace.span(w, t0, t, EventKind::InDb, 0, 0.0, None);
            }
            env.stages.add(Stage::ModelUpdate, t - env.workers[w].clock);
            env.workers[w].clock = t;
            // Mirror the in-DB replica into the worker state (real mode).
            if env.is_real() {
                env.workers[w].theta = env.worker_redis[w].peek_slab("theta")?;
            }
            env.worker_redis[w].delete(&format!("final/e{epoch}"));
        }

        let epoch_secs = env.max_clock() - start;
        Ok(EpochStats {
            mean_loss: (loss_n > 0).then(|| loss_sum / loss_n as f64),
            batches: env.batches_per_epoch * w_count,
            epoch_secs,
            mean_fn_secs: env.lambda.mean_duration(),
        })
    }

    fn stage_table(&self) -> Vec<(Stage, &'static str)> {
        vec![
            (Stage::FetchDataset, "Each worker fetches its assigned minibatches."),
            (
                Stage::ComputeGradients,
                "Gradients are computed in parallel for each minibatch, sent to the local \
                 Redis database, and averaged within the database.",
            ),
            (
                Stage::Synchronize,
                "The worker notifies a synchronization queue, polls until all peers complete, \
                 retrieves averaged gradients from other workers, aggregates them, and stores \
                 the result locally.",
            ),
            (Stage::ModelUpdate, "The final aggregated gradient updates the model in-database."),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::env::EnvConfig;

    fn env(arch: &str) -> ClusterEnv {
        ClusterEnv::new(EnvConfig::virtual_paper(FrameworkKind::Spirt, arch, 4).unwrap()).unwrap()
    }

    #[test]
    fn per_function_duration_matches_paper() {
        let mut e = env("mobilenet");
        let stats = Spirt::new().run_epoch(&mut e).unwrap();
        assert_eq!(e.lambda.invocations, 4 * 24);
        assert!(
            (stats.mean_fn_secs - 15.44).abs() / 15.44 < 0.15,
            "mean fn {:.2}s vs paper 15.44s",
            stats.mean_fn_secs
        );
    }

    #[test]
    fn epoch_wall_time_is_parallel_not_serial() {
        // 24 parallel minibatch functions: epoch wall time must be far below
        // the serial sum (24 × 15.44 ≈ 370 s).
        let mut e = env("mobilenet");
        let stats = Spirt::new().run_epoch(&mut e).unwrap();
        assert!(stats.epoch_secs < 120.0, "epoch {:.1}s", stats.epoch_secs);
        assert!(stats.epoch_secs > 15.0);
    }

    #[test]
    fn syncs_once_per_epoch_not_per_batch() {
        let mut e = env("mobilenet");
        Spirt::new().run_epoch(&mut e).unwrap();
        // One sync-queue notification per worker per epoch.
        assert_eq!(e.queues.total_published(), 4);
    }

    #[test]
    fn indb_traffic_dominates_gradient_movement() {
        let mut e = env("resnet18");
        Spirt::new().run_epoch(&mut e).unwrap();
        use crate::metrics::CommKind;
        // Aggregation happened in the database, not over the wire: in-DB
        // bytes exceed Get bytes (P2P avg fetches).
        assert!(e.comm.bytes(CommKind::InDb) > e.comm.bytes(CommKind::Get));
    }

    #[test]
    fn minibatch_crash_is_absorbed_by_the_fanout() {
        use crate::faults::FaultPlan;
        let mut clean = env("mobilenet");
        let c = Spirt::new().run_epoch(&mut clean).unwrap();

        let cfg = EnvConfig::virtual_paper(FrameworkKind::Spirt, "mobilenet", 4)
            .unwrap()
            .with_faults(FaultPlan::none().crash(1, 1, 12));
        let mut faulty = ClusterEnv::new(cfg).unwrap();
        let f = Spirt::new().run_epoch(&mut faulty).unwrap();

        assert_eq!(faulty.recovery.invocation_retries, 1);
        // The other 23 minibatch functions ran in parallel: the epoch
        // stays within 20% of fault-free (the resilience headline).
        assert!(
            f.epoch_secs < c.epoch_secs * 1.20,
            "faulty {:.1}s vs clean {:.1}s",
            f.epoch_secs,
            c.epoch_secs
        );
    }

    #[test]
    fn sync_crash_reroutes_around_the_dead_peer() {
        use crate::faults::FaultPlan;
        let mut clean = env("mobilenet");
        let c = Spirt::new().run_epoch(&mut clean).unwrap();

        let cfg = EnvConfig::virtual_paper(FrameworkKind::Spirt, "mobilenet", 4)
            .unwrap()
            .with_faults(FaultPlan::none().sync_crash(2, 1));
        let mut faulty = ClusterEnv::new(cfg).unwrap();
        let f = Spirt::new().run_epoch(&mut faulty).unwrap();

        // Three live peers each skipped the dead peer's average.
        assert_eq!(faulty.recovery.rerouted_fetches, 3);
        assert_eq!(faulty.recovery.snapshot_restores, 1);
        // Live peers did not stall on the restart: epoch within 20%.
        assert!(
            f.epoch_secs < c.epoch_secs * 1.20,
            "faulty {:.1}s vs clean {:.1}s",
            f.epoch_secs,
            c.epoch_secs
        );
    }

    #[test]
    fn async_quorum_decouples_fast_workers_from_a_straggler() {
        use crate::coordinator::protocol::SyncMode;
        use crate::faults::FaultPlan;
        let plan = FaultPlan::none().straggler(3, 1, 0, 4.0, None);

        let cfg = EnvConfig::virtual_paper(FrameworkKind::Spirt, "mobilenet", 4)
            .unwrap()
            .with_faults(plan.clone());
        let mut bsp = ClusterEnv::new(cfg).unwrap();
        Spirt::new().run_epoch(&mut bsp).unwrap();

        let cfg = EnvConfig::virtual_paper(FrameworkKind::Spirt, "mobilenet", 4)
            .unwrap()
            .with_faults(plan)
            .with_sync(SyncMode::Async { staleness: 2 });
        let mut asy = ClusterEnv::new(cfg).unwrap();
        Spirt::new().run_epoch(&mut asy).unwrap();

        // Healthy workers wait for a 2-report quorum instead of all 4, and
        // skip the straggler's not-yet-visible average.
        assert!(asy.comm.stale_skips > 0, "late averages must be skipped");
        assert!(
            asy.workers[0].clock < bsp.workers[0].clock,
            "healthy worker decoupled: {} vs {}",
            asy.workers[0].clock,
            bsp.workers[0].clock
        );
    }

    #[test]
    fn stepfn_transitions_billed() {
        let mut e = env("mobilenet");
        Spirt::new().run_epoch(&mut e).unwrap();
        assert!(e.stepfn.transitions >= 4 * 3);
        use crate::metrics::CostKind;
        assert!(e.ledger.get(CostKind::StepFnTransitions) > 0.0);
    }
}

