//! Determinism integration test: the same seed and config must produce
//! bit-identical `SessionReport` virtual-time and cost traces across two
//! runs, for all five strategies, with and without an active `FaultPlan`,
//! in both synchronization modes (BSP and bounded-staleness async).
//!
//! This is the property the whole testbed stands on — every experiment
//! table is reproducible, and neither the fault engine (which injects
//! events at epoch/round coordinates and virtual times) nor the async
//! quorum selection may introduce any run-to-run variation of its own.

use slsgpu::cloud::{FrameworkKind, StoreTierConfig};
use slsgpu::coordinator::{strategy_for, ClusterEnv, EnvConfig, SyncMode};
use slsgpu::faults::{FaultPlan, PoisonMode};
use slsgpu::tensor::AggregationRule;
use slsgpu::trace::TraceConfig;
use slsgpu::train::{run_session, SessionConfig, SessionReport};

const EPOCHS: usize = 3;

fn session_traced(
    fw: FrameworkKind,
    plan: &FaultPlan,
    agg: AggregationRule,
    sync: SyncMode,
    trace: TraceConfig,
) -> SessionReport {
    let cfg = EnvConfig::virtual_paper(fw, "mobilenet", 4)
        .unwrap()
        .with_faults(plan.clone())
        .with_aggregation(agg)
        .with_sync(sync)
        .with_trace(trace);
    let mut env = ClusterEnv::new(cfg).unwrap();
    let mut strategy = strategy_for(fw);
    let session_cfg = SessionConfig {
        max_epochs: EPOCHS,
        target_acc: 2.0,
        patience: EPOCHS + 1,
        evaluate: false,
    };
    run_session(&mut env, strategy.as_mut(), &session_cfg).unwrap()
}

fn session_with(
    fw: FrameworkKind,
    plan: &FaultPlan,
    agg: AggregationRule,
    sync: SyncMode,
) -> SessionReport {
    session_traced(fw, plan, agg, sync, TraceConfig::disabled())
}

fn session(fw: FrameworkKind, plan: &FaultPlan, agg: AggregationRule) -> SessionReport {
    session_with(fw, plan, agg, SyncMode::Bsp)
}

fn assert_bit_identical(a: &SessionReport, b: &SessionReport, label: &str) {
    assert_eq!(a.reports.len(), b.reports.len(), "{label}: epoch count");
    for (ra, rb) in a.reports.iter().zip(&b.reports) {
        assert_eq!(
            ra.vtime_secs.to_bits(),
            rb.vtime_secs.to_bits(),
            "{label}: epoch {} vtime {} vs {}",
            ra.epoch,
            ra.vtime_secs,
            rb.vtime_secs
        );
        assert_eq!(
            ra.cost_usd.to_bits(),
            rb.cost_usd.to_bits(),
            "{label}: epoch {} cost",
            ra.epoch
        );
        assert_eq!(ra.epoch_secs.to_bits(), rb.epoch_secs.to_bits(), "{label}: epoch secs");
    }
    assert_eq!(a.total_vtime_secs.to_bits(), b.total_vtime_secs.to_bits(), "{label}: total");
    assert_eq!(a.total_cost_usd.to_bits(), b.total_cost_usd.to_bits(), "{label}: total cost");
}

/// A busy plan touching every fault kind (worker 1 crashes in compute,
/// worker 2 crashes at sync, worker 3 straggles and poisons, drops on 0).
fn busy_plan() -> FaultPlan {
    FaultPlan::none()
        .crash(1, 2, 5)
        .sync_crash(2, 2)
        .straggler(3, 1, 0, 3.0, Some(8))
        .drop_updates(0, 2, 0, Some(4))
        .poison(3, 1, PoisonMode::Scale(-4.0))
        .supervisor_crash(2, 10)
}

fn session_stored(fw: FrameworkKind, store: StoreTierConfig) -> SessionReport {
    let cfg = EnvConfig::virtual_paper(fw, "mobilenet", 4).unwrap().with_store(store);
    let mut env = ClusterEnv::new(cfg).unwrap();
    let mut strategy = strategy_for(fw);
    let session_cfg = SessionConfig {
        max_epochs: EPOCHS,
        target_acc: 2.0,
        patience: EPOCHS + 1,
        evaluate: false,
    };
    run_session(&mut env, strategy.as_mut(), &session_cfg).unwrap()
}

#[test]
fn single_shard_store_is_bit_identical_to_the_default() {
    // The store-cluster compatibility contract: shards=1, replication=1
    // degenerates to the pre-cluster single shared instance (pinned
    // against plain `Redis` bit-for-bit in `cloud::cluster`'s unit
    // tests). At the session level, any single-shard provisioning —
    // vnode count is irrelevant when there is one shard to route to —
    // must leave every architecture's timeline and ledger untouched.
    let odd_vnodes = StoreTierConfig { vnodes: 7, ..StoreTierConfig::single() };
    for fw in FrameworkKind::ALL {
        let default = session(fw, &FaultPlan::none(), AggregationRule::Mean);
        let explicit = session_stored(fw, StoreTierConfig::single());
        let reringed = session_stored(fw, odd_vnodes.clone());
        assert_bit_identical(&default, &explicit, &format!("{} s1r1", fw.name()));
        assert_bit_identical(&default, &reringed, &format!("{} s1r1 vnodes=7", fw.name()));
    }
}

#[test]
fn sharding_the_store_moves_only_the_shared_store_architecture() {
    // MLLess is the one strategy routing traffic through the shared
    // store; for the other four a sharded/replicated tier must be
    // bit-invisible. For MLLess itself the timeline legitimately moves
    // (four command loops instead of one), so the assertion there is
    // determinism of the sharded run.
    let tier = StoreTierConfig::sharded(4, 2);
    for fw in FrameworkKind::ALL {
        let sharded = session_stored(fw, tier.clone());
        if fw == FrameworkKind::MlLess {
            let again = session_stored(fw, tier.clone());
            assert_bit_identical(&sharded, &again, "mlless s4r2 rerun");
        } else {
            let default = session(fw, &FaultPlan::none(), AggregationRule::Mean);
            assert_bit_identical(&default, &sharded, &format!("{} ignores s4r2", fw.name()));
        }
    }
}

#[test]
fn fault_free_sessions_are_bit_identical() {
    for fw in FrameworkKind::ALL {
        let a = session(fw, &FaultPlan::none(), AggregationRule::Mean);
        let b = session(fw, &FaultPlan::none(), AggregationRule::Mean);
        assert_bit_identical(&a, &b, fw.name());
    }
}

#[test]
fn faulty_sessions_are_bit_identical() {
    let plan = busy_plan();
    for fw in FrameworkKind::ALL {
        let a = session(fw, &plan, AggregationRule::ClippedMean { ratio: 1.0 });
        let b = session(fw, &plan, AggregationRule::ClippedMean { ratio: 1.0 });
        assert_bit_identical(&a, &b, fw.name());
    }
}

#[test]
fn async_sessions_are_bit_identical() {
    let mode = SyncMode::Async { staleness: 2 };
    for fw in FrameworkKind::ALL {
        let a = session_with(fw, &FaultPlan::none(), AggregationRule::Mean, mode);
        let b = session_with(fw, &FaultPlan::none(), AggregationRule::Mean, mode);
        assert_bit_identical(&a, &b, &format!("{} async", fw.name()));
    }
}

#[test]
fn async_faulty_sessions_are_bit_identical() {
    let mode = SyncMode::Async { staleness: 2 };
    let plan = busy_plan();
    for fw in FrameworkKind::ALL {
        let a = session_with(fw, &plan, AggregationRule::ClippedMean { ratio: 1.0 }, mode);
        let b = session_with(fw, &plan, AggregationRule::ClippedMean { ratio: 1.0 }, mode);
        assert_bit_identical(&a, &b, &format!("{} async+faults", fw.name()));
    }
}

#[test]
fn tracing_on_is_bit_identical_to_tracing_off() {
    // The trace layer is purely observational: enabling it must not move a
    // single clock or ledger bit, even under the busy fault plan and the
    // async quorum (where any perturbation of RNG draws or event order
    // would cascade into different timelines).
    let plan = busy_plan();
    for mode in [SyncMode::Bsp, SyncMode::Async { staleness: 2 }] {
        for fw in FrameworkKind::ALL {
            let agg = AggregationRule::ClippedMean { ratio: 1.0 };
            let off = session_traced(fw, &plan, agg, mode, TraceConfig::disabled());
            let on = session_traced(fw, &plan, agg, mode, TraceConfig::on());
            assert_bit_identical(&off, &on, &format!("{} traced {}", fw.name(), mode.label()));
        }
    }
}

#[test]
fn event_queue_core_matches_stepped_semantics_across_the_matrix() {
    // The scheduler core resolves waits through `sim::{EventQueue,
    // OrderLog}` (heap pops and rank lookups) where the seed implementation
    // stepped/re-sorted per op. Equivalence to the stepped core is pinned
    // piecewise at the unit level (sort-reference tests in `sim::sched`,
    // `coordinator::protocol::quorum_subset`, `cloud::queue`); this test
    // closes the loop end to end: every cell of the full matrix — all five
    // architectures × {BSP, bounded-staleness async} × the busy fault plan
    // × tracing {off, on} — must (a) reproduce vtime/cost bit-for-bit on a
    // rerun and (b) be unmoved by tracing, i.e. the event core resolves
    // existing waits without creating or reordering any.
    let plan = busy_plan();
    let agg = AggregationRule::ClippedMean { ratio: 1.0 };
    for mode in [SyncMode::Bsp, SyncMode::Async { staleness: 2 }] {
        for fw in FrameworkKind::ALL {
            let off_a = session_traced(fw, &plan, agg, mode, TraceConfig::disabled());
            let off_b = session_traced(fw, &plan, agg, mode, TraceConfig::disabled());
            let on = session_traced(fw, &plan, agg, mode, TraceConfig::on());
            let label = format!("{} {} event-core", fw.name(), mode.label());
            assert_bit_identical(&off_a, &off_b, &format!("{label} rerun"));
            assert_bit_identical(&off_a, &on, &format!("{label} traced"));
        }
    }
}

/// The four adversarial regimes behind `slsgpu robustness-tournament`,
/// each as a standalone plan at coordinates inside the 3-epoch session.
fn adversarial_plans() -> [(&'static str, FaultPlan); 4] {
    [
        (
            "coalition",
            FaultPlan::none().coalition(&[1, 2], 2, 0, Some(8), PoisonMode::Scale(-8.0)),
        ),
        ("partition-heal", FaultPlan::none().partition(&[1], 0.0, 45.0)),
        (
            "straggler-tail",
            FaultPlan::none().pareto_stragglers(&[1, 2, 3], 1, 0, 1.5, 1.0, 42, None),
        ),
        ("preemption-storm", FaultPlan::none().preemption_storm(&[1, 2, 3], 2, 5)),
    ]
}

#[test]
fn adversarial_matrix_is_bit_identical_across_runs_and_tracing() {
    // The tournament's contract, cell by cell: every adversarial regime ×
    // all five architectures × {BSP, bounded-staleness async} must (a)
    // reproduce vtime/cost bit-for-bit on a rerun and (b) be unmoved by
    // enabling the trace layer — including the new Partition/PartitionHeal
    // and Preemption supervisor events, whose one-shot fired flags must be
    // consumed identically whether or not a sink is attached.
    //
    // ClippedMean (not Krum/trimmed) on purpose: the async quorum at 4
    // workers aggregates 2 slabs, below the n >= f+3 / n > 2k floors of
    // the selection rules. Their determinism is covered at full width by
    // `exp::tournament`'s thread-count test (BSP, 8 workers).
    let agg = AggregationRule::ClippedMean { ratio: 1.0 };
    for (name, plan) in adversarial_plans() {
        for mode in [SyncMode::Bsp, SyncMode::Async { staleness: 2 }] {
            for fw in FrameworkKind::ALL {
                let off_a = session_traced(fw, &plan, agg, mode, TraceConfig::disabled());
                let off_b = session_traced(fw, &plan, agg, mode, TraceConfig::disabled());
                let on = session_traced(fw, &plan, agg, mode, TraceConfig::on());
                let label = format!("{} {} {}", fw.name(), mode.label(), name);
                assert_bit_identical(&off_a, &off_b, &format!("{label} rerun"));
                assert_bit_identical(&off_a, &on, &format!("{label} traced"));
            }
        }
    }
}

#[test]
fn adversarial_regimes_move_the_clock_as_designed() {
    // A coalition poisons gradient *values* only — the timeline must not
    // move relative to a clean run. Partitions, Pareto stragglers, and
    // preemption storms all cost virtual time on every architecture (the
    // partition victim's first comm op defers to the heal; stragglers
    // stretch compute; preemption restarts bill cold-start downtime).
    let agg = AggregationRule::ClippedMean { ratio: 1.0 };
    for fw in FrameworkKind::ALL {
        let clean = session(fw, &FaultPlan::none(), agg);
        for (name, plan) in adversarial_plans() {
            let hit = session(fw, &plan, agg);
            if name == "coalition" {
                assert_bit_identical(&clean, &hit, &format!("{} coalition clock", fw.name()));
            } else {
                assert!(
                    hit.total_vtime_secs > clean.total_vtime_secs,
                    "{} {}: expected added vtime ({} vs {})",
                    fw.name(),
                    name,
                    hit.total_vtime_secs,
                    clean.total_vtime_secs
                );
            }
        }
    }
}

#[test]
fn faults_change_the_trace_but_only_the_faults() {
    // Sanity check that the fault plan is actually exercised: the faulty
    // trace must differ from the fault-free one for every serverless
    // framework (the GPU baseline ignores the supervisor/queue events but
    // still pays crash/straggler time).
    let plan = busy_plan();
    for fw in FrameworkKind::ALL {
        let clean = session(fw, &FaultPlan::none(), AggregationRule::Mean);
        let faulty = session(fw, &plan, AggregationRule::Mean);
        assert!(
            faulty.total_vtime_secs > clean.total_vtime_secs,
            "{}: faults must add virtual time ({} vs {})",
            fw.name(),
            faulty.total_vtime_secs,
            clean.total_vtime_secs
        );
    }
}
