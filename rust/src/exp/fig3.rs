//! Fig. 3: MLLess communication-overhead reduction via significance
//! filtering.
//!
//! The paper reports a 13× convergence-time improvement (113,379 s →
//! 8,667 s) from propagating only significant updates. Two reproductions:
//!
//! * **sim sweep** (`run_sim`) — paper-scale MobileNet, publish-rate sweep:
//!   epoch time and wire traffic as a function of the fraction of updates
//!   that pass the filter (the quantity the threshold controls).
//! * **real contrast** (`run_real`, integration tests / examples) — the
//!   executed model with the real filter at threshold 0 vs default, where
//!   the publish rate *emerges* from actual gradient norms.

use std::rc::Rc;

use crate::cloud::FrameworkKind;
use crate::coordinator::mlless::MlLess;
use crate::coordinator::{ClusterEnv, EnvConfig, Strategy};
use crate::report::{Align, Cell, Report, Table};
use crate::runtime::Engine;
use crate::train::{run_session, SessionConfig};
use crate::Result;

#[derive(Debug, Clone)]
pub struct SimPoint {
    pub publish_rate: f64,
    pub epoch_secs: f64,
    pub wire_bytes: u64,
    pub messages: u64,
}

/// Paper's headline contrast (seconds to convergence).
pub const PAPER_UNFILTERED_SECS: f64 = 113_379.0;
pub const PAPER_FILTERED_SECS: f64 = 8_667.0;

/// Sweep the fraction of updates that pass the significance filter.
pub fn run_sim(rates: &[f64]) -> Result<Vec<SimPoint>> {
    let mut out = Vec::new();
    for &rate in rates {
        let mut env =
            ClusterEnv::new(EnvConfig::virtual_paper(FrameworkKind::MlLess, "mobilenet", 4)?)?;
        let mut strat = MlLess::new(0.0).with_virtual_publish_rate(rate);
        let stats = strat.run_epoch(&mut env)?;
        out.push(SimPoint {
            publish_rate: rate,
            epoch_secs: stats.epoch_secs,
            wire_bytes: env.comm.wire_bytes(),
            messages: env.queues.total_published(),
        });
    }
    Ok(out)
}

#[derive(Debug, Clone)]
pub struct RealContrast {
    pub unfiltered_secs: f64,
    pub filtered_secs: f64,
    pub unfiltered_bytes: u64,
    pub filtered_bytes: u64,
    pub filtered_publish_rate: f64,
    pub speedup: f64,
}

/// Real-gradient contrast on the executed model config.
pub fn run_real(engine: Rc<Engine>, model: &str, epochs: usize) -> Result<RealContrast> {
    let session = |threshold: f64| -> Result<(f64, u64, f64)> {
        let cfg = EnvConfig::real(
            FrameworkKind::MlLess,
            engine.clone(),
            model,
            4,
            4 * 6 * engine.manifest.model(model)?.batch,
            7,
        )?;
        let mut env = ClusterEnv::new(cfg)?;
        let mut strat = MlLess::new(threshold);
        let scfg = SessionConfig {
            max_epochs: epochs,
            target_acc: 2.0, // never early-stop: fixed epoch budget
            patience: usize::MAX,
            evaluate: false,
        };
        let report = run_session(&mut env, &mut strat, &scfg)?;
        Ok((report.total_vtime_secs, env.comm.wire_bytes(), strat.publish_rate()))
    };
    let (unfiltered_secs, unfiltered_bytes, _) = session(0.0)?;
    let (filtered_secs, filtered_bytes, rate) =
        session(crate::coordinator::mlless::DEFAULT_THRESHOLD)?;
    Ok(RealContrast {
        unfiltered_secs,
        filtered_secs,
        unfiltered_bytes,
        filtered_bytes,
        filtered_publish_rate: rate,
        speedup: unfiltered_secs / filtered_secs.max(1e-9),
    })
}

/// Build the sim-sweep report, with the paper's headline contrast as a
/// trailing note (the legacy CLI footer line).
pub fn report_sim(points: &[SimPoint]) -> Report {
    let mut t = Table::new(
        "fig3_sim",
        &[
            ("Publish rate", Align::Right),
            ("Epoch time (s)", Align::Right),
            ("Wire traffic", Align::Right),
            ("Queue msgs", Align::Right),
        ],
    )
    .title("Fig. 3 — MLLess epoch time & traffic vs significant-update rate (sim, MobileNet)");
    for p in points {
        t.push_row(vec![
            Cell::text(format!("{:.0}%", p.publish_rate * 100.0)).with_value(p.publish_rate),
            Cell::num(p.epoch_secs, 1),
            Cell::text(crate::util::fmt_bytes(p.wire_bytes)).with_value(p.wire_bytes as f64),
            Cell::count(p.messages),
        ]);
    }
    let rates: Vec<String> = points.iter().map(|p| format!("{}", p.publish_rate)).collect();
    Report::new(
        "fig3",
        "Fig. 3 — MLLess significance filtering",
        format!("slsgpu exp fig3 --rates {}", rates.join(",")),
    )
        .with_intro(
            "Publish-rate sweep at paper scale (MobileNet, 4 workers): epoch time and \
             wire traffic as a function of the fraction of updates that pass MLLess's \
             significance filter — the quantity its threshold controls. The paper's 13× \
             convergence-time headline has no per-point anchors; the sweep brackets the \
             mechanism (supervisor scheduling cost collapses with the publish rate). For \
             the real-gradient contrast where the publish rate *emerges* from gradient \
             norms, run `slsgpu exp fig3-real` with compiled artifacts.",
        )
        .with_table(t)
        .with_note(format!(
            "paper headline: {} s -> {} s (13x) with filtering",
            PAPER_UNFILTERED_SECS, PAPER_FILTERED_SECS
        ))
}

/// Legacy CLI view of [`report_sim`] (table + paper-headline footer).
pub fn render_sim(points: &[SimPoint]) -> String {
    report_sim(points).to_text()
}

/// Build the real-gradient contrast report (needs compiled artifacts).
pub fn report_real(c: &RealContrast, model: &str, epochs: usize) -> Report {
    let mut t = Table::new(
        "fig3_real",
        &[
            ("Variant", Align::Left),
            ("Time (s)", Align::Right),
            ("Wire traffic", Align::Right),
            ("Publish rate", Align::Right),
        ],
    )
    .title(format!("Fig. 3 — MLLess real-gradient contrast ({model}, {epochs} epochs)"));
    t.push_row(vec![
        Cell::text("unfiltered"),
        Cell::num(c.unfiltered_secs, 1),
        Cell::text(crate::util::fmt_bytes(c.unfiltered_bytes))
            .with_value(c.unfiltered_bytes as f64),
        Cell::text("100%").with_value(1.0),
    ]);
    t.push_row(vec![
        Cell::text("filtered"),
        Cell::num(c.filtered_secs, 1),
        Cell::text(crate::util::fmt_bytes(c.filtered_bytes)).with_value(c.filtered_bytes as f64),
        Cell::text(format!("{:.0}%", c.filtered_publish_rate * 100.0))
            .with_value(c.filtered_publish_rate),
    ]);
    Report::new(
        "fig3_real",
        "Fig. 3 — MLLess real-gradient contrast",
        format!("slsgpu exp fig3-real --model {model} --epochs {epochs}"),
    )
    .with_table(t)
    .with_note(format!(
        "speedup: {:.1}x (paper: {:.1}x)",
        c.speedup,
        PAPER_UNFILTERED_SECS / PAPER_FILTERED_SECS
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filtering_cuts_time_and_traffic_monotonically() {
        let points = run_sim(&[1.0, 0.5, 0.1, 0.02]).unwrap();
        for w in points.windows(2) {
            assert!(
                w[1].epoch_secs < w[0].epoch_secs,
                "epoch time must drop: {:?}",
                points.iter().map(|p| p.epoch_secs).collect::<Vec<_>>()
            );
            assert!(w[1].wire_bytes <= w[0].wire_bytes);
        }
        // Strong reduction end to end (the Fig. 3 shape).
        let first = &points[0];
        let last = &points[points.len() - 1];
        assert!(
            first.epoch_secs / last.epoch_secs > 3.0,
            "{} -> {}",
            first.epoch_secs,
            last.epoch_secs
        );
        assert!(first.wire_bytes / last.wire_bytes.max(1) >= 10);
    }
}
