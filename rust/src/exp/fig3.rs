//! Fig. 3: MLLess communication-overhead reduction via significance
//! filtering.
//!
//! The paper reports a 13× convergence-time improvement (113,379 s →
//! 8,667 s) from propagating only significant updates. Two reproductions:
//!
//! * **sim sweep** (`run_sim`) — paper-scale MobileNet, publish-rate sweep:
//!   epoch time and wire traffic as a function of the fraction of updates
//!   that pass the filter (the quantity the threshold controls).
//! * **real contrast** (`run_real`, integration tests / examples) — the
//!   executed model with the real filter at threshold 0 vs default, where
//!   the publish rate *emerges* from actual gradient norms.

use std::rc::Rc;

use crate::cloud::FrameworkKind;
use crate::coordinator::mlless::MlLess;
use crate::coordinator::{ClusterEnv, EnvConfig, Strategy};
use crate::runtime::Engine;
use crate::train::{run_session, SessionConfig};
use crate::util::table::{Align, Table};
use crate::Result;

#[derive(Debug, Clone)]
pub struct SimPoint {
    pub publish_rate: f64,
    pub epoch_secs: f64,
    pub wire_bytes: u64,
    pub messages: u64,
}

/// Paper's headline contrast (seconds to convergence).
pub const PAPER_UNFILTERED_SECS: f64 = 113_379.0;
pub const PAPER_FILTERED_SECS: f64 = 8_667.0;

/// Sweep the fraction of updates that pass the significance filter.
pub fn run_sim(rates: &[f64]) -> Result<Vec<SimPoint>> {
    let mut out = Vec::new();
    for &rate in rates {
        let mut env =
            ClusterEnv::new(EnvConfig::virtual_paper(FrameworkKind::MlLess, "mobilenet", 4)?)?;
        let mut strat = MlLess::new(0.0).with_virtual_publish_rate(rate);
        let stats = strat.run_epoch(&mut env)?;
        out.push(SimPoint {
            publish_rate: rate,
            epoch_secs: stats.epoch_secs,
            wire_bytes: env.comm.wire_bytes(),
            messages: env.queues.total_published(),
        });
    }
    Ok(out)
}

#[derive(Debug, Clone)]
pub struct RealContrast {
    pub unfiltered_secs: f64,
    pub filtered_secs: f64,
    pub unfiltered_bytes: u64,
    pub filtered_bytes: u64,
    pub filtered_publish_rate: f64,
    pub speedup: f64,
}

/// Real-gradient contrast on the executed model config.
pub fn run_real(engine: Rc<Engine>, model: &str, epochs: usize) -> Result<RealContrast> {
    let session = |threshold: f64| -> Result<(f64, u64, f64)> {
        let cfg = EnvConfig::real(
            FrameworkKind::MlLess,
            engine.clone(),
            model,
            4,
            4 * 6 * engine.manifest.model(model)?.batch,
            7,
        )?;
        let mut env = ClusterEnv::new(cfg)?;
        let mut strat = MlLess::new(threshold);
        let scfg = SessionConfig {
            max_epochs: epochs,
            target_acc: 2.0, // never early-stop: fixed epoch budget
            patience: usize::MAX,
            evaluate: false,
        };
        let report = run_session(&mut env, &mut strat, &scfg)?;
        Ok((report.total_vtime_secs, env.comm.wire_bytes(), strat.publish_rate()))
    };
    let (unfiltered_secs, unfiltered_bytes, _) = session(0.0)?;
    let (filtered_secs, filtered_bytes, rate) =
        session(crate::coordinator::mlless::DEFAULT_THRESHOLD)?;
    Ok(RealContrast {
        unfiltered_secs,
        filtered_secs,
        unfiltered_bytes,
        filtered_bytes,
        filtered_publish_rate: rate,
        speedup: unfiltered_secs / filtered_secs.max(1e-9),
    })
}

pub fn render_sim(points: &[SimPoint]) -> String {
    let mut t = Table::new(&["Publish rate", "Epoch time (s)", "Wire traffic", "Queue msgs"])
        .title("Fig. 3 — MLLess epoch time & traffic vs significant-update rate (sim, MobileNet)")
        .align(&[Align::Right, Align::Right, Align::Right, Align::Right]);
    for p in points {
        t.row(vec![
            format!("{:.0}%", p.publish_rate * 100.0),
            format!("{:.1}", p.epoch_secs),
            crate::util::fmt_bytes(p.wire_bytes),
            p.messages.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filtering_cuts_time_and_traffic_monotonically() {
        let points = run_sim(&[1.0, 0.5, 0.1, 0.02]).unwrap();
        for w in points.windows(2) {
            assert!(
                w[1].epoch_secs < w[0].epoch_secs,
                "epoch time must drop: {:?}",
                points.iter().map(|p| p.epoch_secs).collect::<Vec<_>>()
            );
            assert!(w[1].wire_bytes <= w[0].wire_bytes);
        }
        // Strong reduction end to end (the Fig. 3 shape).
        let first = &points[0];
        let last = &points[points.len() - 1];
        assert!(
            first.epoch_secs / last.epoch_secs > 3.0,
            "{} -> {}",
            first.epoch_secs,
            last.epoch_secs
        );
        assert!(first.wire_bytes / last.wire_bytes.max(1) >= 10);
    }
}
