"""Flat-parameter ABI: pytree <-> f32[n] slab conversion.

The Rust coordinator treats model state as an opaque f32 slab (the same way
the real frameworks shuttle pickled/serialized gradients through Redis/S3).
jax.tree_util flattening order is deterministic for a fixed pytree structure,
so a (treedef, shapes) spec pinned at trace time round-trips exactly.
"""

import jax
import jax.numpy as jnp


def flatten_spec(params):
    """Capture the (treedef, shapes, sizes, total) spec of a params pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    shapes = [leaf.shape for leaf in leaves]
    sizes = [int(leaf.size) for leaf in leaves]
    return {
        "treedef": treedef,
        "shapes": shapes,
        "sizes": sizes,
        "total": int(sum(sizes)),
    }


def tree_to_vec(params):
    """Concatenate all leaves (flatten order) into one f32 vector."""
    leaves = jax.tree_util.tree_leaves(params)
    return jnp.concatenate([leaf.reshape(-1).astype(jnp.float32) for leaf in leaves])


def vec_to_tree(vec, spec):
    """Inverse of tree_to_vec under the captured spec."""
    leaves = []
    off = 0
    for shape, size in zip(spec["shapes"], spec["sizes"]):
        leaves.append(vec[off : off + size].reshape(shape))
        off += size
    return jax.tree_util.tree_unflatten(spec["treedef"], leaves)


def param_count(init, key=None):
    """Total parameter count of a model's init function."""
    key = jax.random.PRNGKey(0) if key is None else key
    params = jax.eval_shape(init, key)
    return int(sum(leaf.size for leaf in jax.tree_util.tree_leaves(params)))
