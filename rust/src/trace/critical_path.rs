//! Critical-path analysis over the trace-event DAG.
//!
//! Edges, in precedence order at each step of the backward walk:
//!
//! 1. **Explicit dependency** (`TraceEvent::dep`): a cross-worker
//!    happens-before edge — the put a get observed, the notify a poll was
//!    gated on, the slowest worker a barrier waited for. Followed only when
//!    the dependency actually gated the op (`dep.t1 > op.t0`); an edge to a
//!    write that was already visible cost nothing.
//! 2. **Program order** (`TraceEvent::prev`): the same-worker chain, walked
//!    back past any events that *finished after* this op started. That skip
//!    matters for SPIRT, whose per-minibatch clock resets make a worker's
//!    track non-monotonic — the immediate recorded predecessor may be a
//!    parallel minibatch, not the op that fed this one.
//!
//! The walk starts at the epoch's last-finishing event and always moves to a
//! strictly smaller event index, so it terminates without cycle detection.

use std::collections::{BTreeMap, BTreeSet};

use crate::faults::SUPERVISOR;

use super::collector::TraceCollector;
use super::event::{EventKind, TraceEvent};

/// One hop on the critical path (terminal-first order in [`EpochPath`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PathStep {
    pub idx: u64,
    pub worker: usize,
    pub kind: EventKind,
    pub t0_secs: f64,
    pub t1_secs: f64,
    /// Seconds this step contributed beyond its predecessor's finish — the
    /// segment lengths sum to (roughly) the epoch's bound span.
    pub self_secs: f64,
}

/// The chain of ops bounding one epoch's finish time.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochPath {
    pub epoch: u32,
    /// Worker whose event ends the epoch (the terminal step's track).
    pub bound_worker: usize,
    pub start_secs: f64,
    pub end_secs: f64,
    /// Terminal-first chain of steps.
    pub steps: Vec<PathStep>,
    /// Self-time per kind along the path, descending.
    pub kind_secs: Vec<(EventKind, f64)>,
}

impl EpochPath {
    /// Wall span covered by the path.
    pub fn span_secs(&self) -> f64 {
        self.end_secs - self.start_secs
    }
}

/// Walk the critical path of every epoch present in the collector.
pub fn analyze(col: &TraceCollector) -> Vec<EpochPath> {
    let epochs: BTreeSet<u32> = col.events().map(|e| e.epoch).collect();
    epochs.into_iter().filter_map(|ep| epoch_path(col, ep)).collect()
}

fn epoch_path(col: &TraceCollector, epoch: u32) -> Option<EpochPath> {
    let (terminal, _) = col
        .iter_indexed()
        .filter(|(_, e)| e.epoch == epoch)
        .max_by_key(|(i, e)| (e.t1, *i))?;
    let mut steps = Vec::new();
    let mut per_kind: BTreeMap<EventKind, f64> = BTreeMap::new();
    let mut cur = terminal;
    // Indices strictly decrease along the walk; the cap is a belt-and-braces
    // guard, not a correctness requirement.
    for _ in 0..1_000_000 {
        let e = *col.get(cur)?;
        let pred = predecessor(col, &e);
        let pred_t1 = pred.and_then(|p| col.get(p)).map(|p| p.t1.secs());
        let self_secs = match pred_t1 {
            Some(pt) => (e.t1.secs() - pt.max(e.t0.secs())).max(0.0),
            None => e.secs(),
        };
        steps.push(PathStep {
            idx: cur,
            worker: e.worker,
            kind: e.kind,
            t0_secs: e.t0.secs(),
            t1_secs: e.t1.secs(),
            self_secs,
        });
        *per_kind.entry(e.kind).or_insert(0.0) += self_secs;
        match pred {
            Some(p) => cur = p,
            None => break,
        }
    }
    let mut kind_secs: Vec<(EventKind, f64)> = per_kind.into_iter().collect();
    kind_secs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    Some(EpochPath {
        epoch,
        bound_worker: steps[0].worker,
        start_secs: steps.last().map(|s| s.t0_secs).unwrap_or(0.0),
        end_secs: steps[0].t1_secs,
        steps,
        kind_secs,
    })
}

/// The event that gated `e`, per the edge rules in the module docs.
fn predecessor(col: &TraceCollector, e: &TraceEvent) -> Option<u64> {
    if let Some(d) = e.dep {
        if let Some(de) = col.get(d) {
            if de.t1 > e.t0 {
                return Some(d);
            }
        }
    }
    let mut p = e.prev;
    while let Some(pi) = p {
        let pe = col.get(pi)?;
        if pe.t1 <= e.t0 {
            return Some(pi);
        }
        p = pe.prev;
    }
    None
}

fn worker_label(w: usize) -> String {
    if w == SUPERVISOR {
        "sup".to_string()
    } else {
        format!("w{w}")
    }
}

/// Render the chain tail as `w0:apply-update <- w0:get <- w1:put <- …`.
pub fn describe(path: &EpochPath, max_steps: usize) -> String {
    let mut parts: Vec<String> = path
        .steps
        .iter()
        .take(max_steps)
        .map(|s| format!("{}:{}", worker_label(s.worker), s.kind.name()))
        .collect();
    if path.steps.len() > max_steps {
        parts.push(format!("… {} more", path.steps.len() - max_steps));
    }
    parts.join(" <- ")
}

/// Render the top-`k` kinds by path self-time as `compute 14.40s · poll 3.21s`.
pub fn dominant(path: &EpochPath, k: usize) -> String {
    path.kind_secs
        .iter()
        .take(k)
        .map(|(kind, secs)| format!("{} {:.2}s", kind.name(), secs))
        .collect::<Vec<_>>()
        .join(" · ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::VTime;
    use crate::trace::TraceConfig;

    fn t(s: f64) -> VTime {
        VTime::from_secs(s)
    }

    /// Hand-built DAG: w0 puts [0,2]; w1 computes [0,5] then puts [5,6];
    /// w0 gets [2,6.5] gated on w1's put. Expected chain: get <- put <-
    /// compute (w0's own put is NOT on the path — it finished long before
    /// the get was actually gated).
    #[test]
    fn walks_the_gating_chain_not_program_order() {
        let mut c = TraceCollector::new(&TraceConfig::on());
        c.begin_epoch(1);
        let p0 = c.span(0, t(0.0), t(2.0), EventKind::Put, 8, 0.0, None);
        c.note_write("s3/g0".into(), p0);
        c.span(1, t(0.0), t(5.0), EventKind::Compute, 0, 0.0, None);
        let p1 = c.span(1, t(5.0), t(6.0), EventKind::Put, 8, 0.0, None);
        c.note_write("s3/g1".into(), p1);
        c.span(0, t(2.0), t(6.5), EventKind::Get, 8, 0.0, c.writer_of("s3/g1"));

        let paths = analyze(&c);
        assert_eq!(paths.len(), 1);
        let p = &paths[0];
        assert_eq!(p.epoch, 1);
        assert_eq!(p.bound_worker, 0);
        let chain: Vec<(u64, EventKind)> = p.steps.iter().map(|s| (s.idx, s.kind)).collect();
        assert_eq!(
            chain,
            vec![(3, EventKind::Get), (2, EventKind::Put), (1, EventKind::Compute)]
        );
        // Self-times: get contributes 6.5-6.0, put 1.0, compute 5.0 — and
        // they sum to the full span.
        assert!((p.steps[0].self_secs - 0.5).abs() < 1e-12);
        assert!((p.steps[1].self_secs - 1.0).abs() < 1e-12);
        assert!((p.steps[2].self_secs - 5.0).abs() < 1e-12);
        assert!((p.span_secs() - 6.5).abs() < 1e-12);
        assert_eq!(p.kind_secs[0], (EventKind::Compute, 5.0));
        assert_eq!(describe(p, 8), "w0:get <- w1:put <- w1:compute");
        assert_eq!(dominant(p, 2), "compute 5.00s · put 1.00s");
    }

    /// A dependency that was already visible (`dep.t1 <= t0`) must not be
    /// followed; program order wins, skipping same-worker events that
    /// finished after this op started (SPIRT's reset-clock fan-out).
    #[test]
    fn skips_satisfied_deps_and_overlapping_predecessors() {
        let mut c = TraceCollector::new(&TraceConfig::on());
        c.begin_epoch(1);
        let w = c.span(1, t(0.0), t(1.0), EventKind::Put, 8, 0.0, None);
        c.note_write("s3/k".into(), w);
        c.span(0, t(0.0), t(4.0), EventKind::Compute, 0, 0.0, None); // parallel branch
        c.span(0, t(0.0), t(2.0), EventKind::Compute, 0, 0.0, None); // feeds the get
        c.span(0, t(2.0), t(3.0), EventKind::Get, 8, 0.0, c.writer_of("s3/k"));
        // Terminal is the long parallel compute (t1 = 4.0), alone on its path
        // branch; check the get's predecessor logic directly instead.
        let e = *c.get(3).unwrap();
        assert_eq!(
            predecessor(&c, &e),
            Some(2),
            "satisfied dep ignored, overlapping prev (idx 1) skipped"
        );
    }

    #[test]
    fn one_path_per_epoch() {
        let mut c = TraceCollector::new(&TraceConfig::on());
        c.begin_epoch(1);
        c.span(0, t(0.0), t(1.0), EventKind::Compute, 0, 0.0, None);
        c.begin_epoch(2);
        c.span(0, t(1.0), t(3.0), EventKind::Compute, 0, 0.0, None);
        let paths = analyze(&c);
        assert_eq!(paths.len(), 2);
        assert_eq!(paths[0].epoch, 1);
        assert_eq!(paths[1].epoch, 2);
        // Epoch 2's path chains back into epoch 1's work via program order.
        assert_eq!(paths[1].steps.len(), 2);
        assert_eq!(paths[1].start_secs, 0.0);
    }
}
