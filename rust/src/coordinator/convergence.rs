//! Early stopping / convergence detection (paper §4.3: "Early stopping was
//! applied to detect convergence in all setups").

/// Tracks test accuracy across epochs; signals stop at a target accuracy or
/// when improvement stalls for `patience` epochs.
#[derive(Debug, Clone)]
pub struct EarlyStopper {
    pub target_acc: f64,
    pub patience: usize,
    pub min_delta: f64,
    best: f64,
    stale: usize,
    /// Epoch (1-based) at which `target_acc` was first reached.
    pub reached_target_at: Option<usize>,
}

impl EarlyStopper {
    pub fn new(target_acc: f64, patience: usize) -> EarlyStopper {
        EarlyStopper {
            target_acc,
            patience,
            min_delta: 1e-4,
            best: f64::NEG_INFINITY,
            stale: 0,
            reached_target_at: None,
        }
    }

    /// Record an epoch's accuracy; returns `true` when training should stop.
    pub fn observe(&mut self, epoch: usize, acc: f64) -> bool {
        if acc >= self.target_acc && self.reached_target_at.is_none() {
            self.reached_target_at = Some(epoch);
        }
        if acc > self.best + self.min_delta {
            self.best = acc;
            self.stale = 0;
        } else {
            self.stale += 1;
        }
        acc >= self.target_acc || self.stale >= self.patience
    }

    pub fn best(&self) -> f64 {
        self.best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stops_at_target() {
        let mut s = EarlyStopper::new(0.8, 5);
        assert!(!s.observe(1, 0.5));
        assert!(!s.observe(2, 0.7));
        assert!(s.observe(3, 0.81));
        assert_eq!(s.reached_target_at, Some(3));
    }

    #[test]
    fn stops_on_plateau() {
        let mut s = EarlyStopper::new(0.99, 3);
        assert!(!s.observe(1, 0.60));
        assert!(!s.observe(2, 0.60));
        assert!(!s.observe(3, 0.60));
        assert!(s.observe(4, 0.60));
        assert_eq!(s.reached_target_at, None);
        assert!((s.best() - 0.60).abs() < 1e-9);
    }

    #[test]
    fn improvement_resets_patience() {
        let mut s = EarlyStopper::new(0.99, 2);
        assert!(!s.observe(1, 0.5));
        assert!(!s.observe(2, 0.5));
        assert!(!s.observe(3, 0.6)); // improvement resets
        assert!(!s.observe(4, 0.6));
        assert!(s.observe(5, 0.6));
    }
}
