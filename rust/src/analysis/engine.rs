//! Rule evaluation, `audit:allow` suppression, and the audit result model.
//!
//! Allow syntax: a line comment containing the `audit:allow` marker,
//! immediately followed by the rule name and a reason in parentheses,
//! separated by a comma. Allows are parsed from the scanner's *comment*
//! view only, so the marker never fires from a string literal — and note
//! that writing a literal example of the full syntax in a `rust/src`
//! comment registers as a real (and then stale) allow, which is why this
//! paragraph spells it out instead of showing one.
//!
//! Placement: trailing on the offending line, or on a comment-only line
//! directly above, in which case it covers the statement that starts on
//! the next code line — every following code line up to and including the
//! first whose trimmed code ends with `;`, `{` or `}`, capped at
//! [`MAX_ALLOW_SPAN`] lines — so multi-line calls (a trace span split
//! across arguments) need a single annotation. An allow that suppresses
//! nothing, names an unknown rule, or carries no reason is itself a
//! finding (`stale-allow`), and stale-allow findings cannot be allowed.

use std::collections::BTreeMap;

use super::rules::{RuleId, ALL, DATA_MARKER, LINE_RULES, PAGE_MARKER};
use super::scanner::{scan, ScanLine};
use super::workspace::Workspace;

/// Longest statement (in lines) a comment-line allow can cover.
pub const MAX_ALLOW_SPAN: usize = 12;

const ALLOW_MARKER: &str = "audit:allow(";

/// One rule hit, suppressed or not.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: RuleId,
    pub file: String,
    /// 1-based line; registration/docs findings anchor to line 1.
    pub line: usize,
    pub detail: String,
    /// `Some(reason)` when an `audit:allow` covers this finding.
    pub suppressed: Option<String>,
}

/// One well-formed `audit:allow` annotation.
#[derive(Debug, Clone)]
pub struct Allow {
    pub rule: RuleId,
    pub file: String,
    pub line: usize,
    pub reason: String,
    pub used: bool,
}

/// Full audit result: every finding (suppressed and open), every valid
/// allow, and per-rule scope sizes for the summary table.
#[derive(Debug, Clone, Default)]
pub struct Audit {
    pub findings: Vec<Finding>,
    pub allows: Vec<Allow>,
    /// Rule name -> number of files in that rule's scope.
    pub checked: BTreeMap<&'static str, usize>,
}

impl Audit {
    /// Findings not covered by an allow.
    pub fn open(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.suppressed.is_none())
    }

    /// Number of unsuppressed findings.
    pub fn open_count(&self) -> usize {
        self.open().count()
    }

    /// True when the audit gate passes.
    pub fn clean(&self) -> bool {
        self.open_count() == 0
    }
}

/// Lines a well-formed allow at `line` (1-based) covers.
fn coverage(scanned: &[ScanLine], line: usize) -> Vec<usize> {
    let idx = line - 1;
    if idx >= scanned.len() {
        return Vec::new();
    }
    if !scanned[idx].code.trim().is_empty() {
        return vec![line];
    }
    let mut out = Vec::new();
    let end = (idx + 1 + MAX_ALLOW_SPAN).min(scanned.len());
    for (k, scan_line) in scanned.iter().enumerate().take(end).skip(idx + 1) {
        let code = scan_line.code.trim();
        if code.is_empty() {
            continue;
        }
        out.push(k + 1);
        if matches!(code.chars().last(), Some(';') | Some('{') | Some('}')) {
            break;
        }
    }
    out
}

struct ParsedAllow {
    line: usize,
    rule: RuleId,
    reason: String,
    covers: Vec<usize>,
    used: bool,
}

/// Parse every allow in a file; malformed ones become findings directly.
fn parse_allows(path: &str, scanned: &[ScanLine], findings: &mut Vec<Finding>) -> Vec<ParsedAllow> {
    let mut allows = Vec::new();
    for (idx, line) in scanned.iter().enumerate() {
        let ln = idx + 1;
        let mut rest = line.comment.as_str();
        while let Some(at) = rest.find(ALLOW_MARKER) {
            let after = &rest[at + ALLOW_MARKER.len()..];
            let Some(close) = after.find(')') else {
                findings.push(Finding {
                    rule: RuleId::StaleAllow,
                    file: path.to_string(),
                    line: ln,
                    detail: "malformed audit:allow (missing closing parenthesis)".to_string(),
                    suppressed: None,
                });
                break;
            };
            let inner = &after[..close];
            let (name, reason) = match inner.find(',') {
                Some(comma) => (inner[..comma].trim(), inner[comma + 1..].trim()),
                None => (inner.trim(), ""),
            };
            match RuleId::from_name(name) {
                None => findings.push(Finding {
                    rule: RuleId::StaleAllow,
                    file: path.to_string(),
                    line: ln,
                    detail: format!("audit:allow names unknown rule `{name}`"),
                    suppressed: None,
                }),
                Some(_) if reason.is_empty() => findings.push(Finding {
                    rule: RuleId::StaleAllow,
                    file: path.to_string(),
                    line: ln,
                    detail: format!("audit:allow({name}) has no justification"),
                    suppressed: None,
                }),
                Some(rule) => allows.push(ParsedAllow {
                    line: ln,
                    rule,
                    reason: reason.to_string(),
                    covers: coverage(scanned, ln),
                    used: false,
                }),
            }
            rest = &after[close + 1..];
        }
    }
    allows
}

/// Cargo.toml target registration (rule 4).
fn check_registration(ws: &Workspace, findings: &mut Vec<Finding>) -> usize {
    let mut registered: BTreeMap<&'static str, Vec<String>> = BTreeMap::new();
    if let Some(cargo) = ws.get("Cargo.toml") {
        let mut kind: Option<&'static str> = None;
        let mut name = String::new();
        for (idx, raw) in cargo.lines().enumerate() {
            let line = raw.trim();
            if line.starts_with('[') {
                kind = match line {
                    "[[test]]" => Some("test"),
                    "[[bench]]" => Some("bench"),
                    "[[example]]" => Some("example"),
                    _ => None,
                };
                name.clear();
                continue;
            }
            let Some(k) = kind else { continue };
            if let Some(v) = toml_str(line, "name") {
                name = v.to_string();
            }
            if let Some(v) = toml_str(line, "path") {
                registered.entry(k).or_default().push(v.to_string());
                if ws.get(v).is_none() {
                    findings.push(Finding {
                        rule: RuleId::TargetRegistration,
                        file: "Cargo.toml".to_string(),
                        line: idx + 1,
                        detail: format!("[[{k}]] {name} points at missing {v}"),
                        suppressed: None,
                    });
                }
            }
        }
    }
    let empty = Vec::new();
    let mut candidates = 0usize;
    for (kind, dir) in [("test", "rust/tests"), ("bench", "benches"), ("example", "examples")] {
        let paths = registered.get(kind).unwrap_or(&empty);
        for file in ws.direct_rs(dir) {
            candidates += 1;
            if !paths.iter().any(|p| p == file) {
                findings.push(Finding {
                    rule: RuleId::TargetRegistration,
                    file: file.to_string(),
                    line: 1,
                    detail: format!(
                        "no [[{kind}]] entry in Cargo.toml (auto-discovery is off: this target never builds)"
                    ),
                    suppressed: None,
                });
            }
        }
    }
    candidates
}

/// Parse `key = "value"` from one trimmed Cargo.toml line.
fn toml_str<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let rest = line.strip_prefix(key)?.trim_start();
    let rest = rest.strip_prefix('=')?.trim_start();
    let rest = rest.strip_prefix('"')?;
    rest.split('"').next()
}

/// Generated-docs markers (rule 6).
fn check_docs(ws: &Workspace, findings: &mut Vec<Finding>) -> usize {
    let mut count = 0usize;
    for path in ws.docs("md") {
        count += 1;
        if !ws.get(path).is_some_and(|c| c.contains(PAGE_MARKER)) {
            findings.push(Finding {
                rule: RuleId::GeneratedDocs,
                file: path.to_string(),
                line: 1,
                detail: "suite-owned page lacks the generated-file marker".to_string(),
                suppressed: None,
            });
        }
    }
    for path in ws.docs("json") {
        count += 1;
        if !ws.get(path).is_some_and(|c| c.contains(DATA_MARKER)) {
            findings.push(Finding {
                rule: RuleId::GeneratedDocs,
                file: path.to_string(),
                line: 1,
                detail: "suite-owned data file lacks the generated-data marker".to_string(),
                suppressed: None,
            });
        }
    }
    count
}

/// Run every rule over the workspace.
pub fn run(ws: &Workspace) -> Audit {
    let mut findings: Vec<Finding> = Vec::new();
    let mut allows: Vec<Allow> = Vec::new();
    let mut src_files = 0usize;
    let mut in_scope: BTreeMap<&'static str, usize> = BTreeMap::new();

    for (path, contents) in ws.rust_src() {
        src_files += 1;
        let scanned = scan(contents);
        let mut file_allows = parse_allows(path, &scanned, &mut findings);
        for rule in LINE_RULES {
            if !rule.in_scope(path) {
                continue;
            }
            *in_scope.entry(rule.name()).or_insert(0) += 1;
            for (idx, line) in scanned.iter().enumerate() {
                let ln = idx + 1;
                let Some(detail) = rule.match_line(&line.code) else { continue };
                let suppressed = file_allows
                    .iter_mut()
                    .find(|a| a.rule == rule && a.covers.contains(&ln))
                    .map(|a| {
                        a.used = true;
                        a.reason.clone()
                    });
                findings.push(Finding {
                    rule,
                    file: path.to_string(),
                    line: ln,
                    detail,
                    suppressed,
                });
            }
        }
        for a in file_allows {
            if !a.used {
                findings.push(Finding {
                    rule: RuleId::StaleAllow,
                    file: path.to_string(),
                    line: a.line,
                    detail: format!("audit:allow({}) suppresses nothing (stale)", a.rule.name()),
                    suppressed: None,
                });
            } else {
                allows.push(Allow {
                    rule: a.rule,
                    file: path.to_string(),
                    line: a.line,
                    reason: a.reason,
                    used: true,
                });
            }
        }
    }

    let reg_candidates = check_registration(ws, &mut findings);
    let docs_count = check_docs(ws, &mut findings);

    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    allows.sort_by(|a, b| (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line)));

    let mut checked = BTreeMap::new();
    for rule in ALL {
        let n = match rule {
            RuleId::TargetRegistration => reg_candidates,
            RuleId::GeneratedDocs => docs_count,
            RuleId::StaleAllow => src_files,
            _ => in_scope.get(rule.name()).copied().unwrap_or(0),
        };
        checked.insert(rule.name(), n);
    }
    Audit { findings, allows, checked }
}
