//! Experiment configuration: a small INI/TOML-subset parser + typed configs.
//!
//! The CLI accepts `--config <file>` with sections and `key = value` lines:
//!
//! ```text
//! [experiment]
//! name = "table2"
//! workers = 4
//!
//! [training]
//! lr = 0.08
//! target_acc = 0.8
//! ```
//!
//! Values: strings (quoted), numbers, booleans. Flat dotted lookup
//! (`section.key`). No external dependencies.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// Flat `section.key -> value` configuration.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            values.insert(
                full_key,
                parse_value(val.trim())
                    .with_context(|| format!("line {}: bad value {val:?}", lineno + 1))?,
            );
        }
        Ok(Config { values })
    }

    pub fn load(path: &str) -> Result<Config> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> Result<String> {
        match self.get(key) {
            None => Ok(default.to_string()),
            Some(v) => Ok(v.as_str()?.to_string()),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_f64(),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_usize(),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_bool(),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<Value> {
    if let Some(stripped) = text.strip_prefix('"') {
        let inner = stripped.strip_suffix('"').context("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(n) = text.parse::<f64>() {
        return Ok(Value::Num(n));
    }
    // Bare strings are accepted for convenience (framework names etc.).
    if text.chars().all(|c| c.is_alphanumeric() || "_-.".contains(c)) {
        return Ok(Value::Str(text.to_string()));
    }
    bail!("cannot parse value {text:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment setup
[experiment]
name = "table2"     # quoted string
workers = 4
arch = mobilenet    # bare string

[training]
lr = 0.08
evaluate = true
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("experiment.name", "x").unwrap(), "table2");
        assert_eq!(c.usize_or("experiment.workers", 0).unwrap(), 4);
        assert_eq!(c.str_or("experiment.arch", "x").unwrap(), "mobilenet");
        assert!((c.f64_or("training.lr", 0.0).unwrap() - 0.08).abs() < 1e-12);
        assert!(c.bool_or("training.evaluate", false).unwrap());
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("x.y", 7).unwrap(), 7);
        assert_eq!(c.str_or("a.b", "z").unwrap(), "z");
    }

    #[test]
    fn type_errors_are_loud() {
        let c = Config::parse("[a]\nk = \"str\"").unwrap();
        assert!(c.f64_or("a.k", 0.0).is_err());
        assert!(c.usize_or("a.k", 0).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("no equals sign here").is_err());
    }

    #[test]
    fn comments_respect_quotes() {
        let c = Config::parse("k = \"a#b\" # trailing").unwrap();
        assert_eq!(c.str_or("k", "").unwrap(), "a#b");
    }

    #[test]
    fn fractional_usize_rejected() {
        let c = Config::parse("k = 4.5").unwrap();
        assert!(c.usize_or("k", 0).is_err());
    }
}
