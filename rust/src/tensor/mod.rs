//! Flat tensor slabs — the wire format of every framework.
//!
//! All five architectures shuttle gradients/parameters as opaque `f32` slabs
//! (the real systems move pickled tensors through Redis/S3; we move
//! [`Slab`]s). A slab is either *real* (backed by memory, used by the
//! end-to-end training runs) or *virtual* (size-only, used by the
//! paper-scale cost/communication experiments where a 25.6M-param gradient
//! would be 100 MB of irrelevant bytes). Every operation preserves length
//! and "virtualness" so the two modes traverse identical protocol code.

pub mod chunk;
pub mod robust;
pub mod significance;
pub mod slab;

pub use chunk::ChunkPlan;
pub use robust::AggregationRule;
pub use significance::SignificanceFilter;
pub use slab::{Slab, KERNEL_CHUNK};

use anyhow::Result;

/// Elementwise slab math engine — the compute behind RedisAI's in-database
/// ops. Two implementations exist: [`RustMath`] (portable loops, used by the
/// naive baselines and virtual-slab simulations) and
/// `runtime::PjrtMath` (executes the AOT-compiled Pallas kernels — the
/// faithful RedisAI analog used on the end-to-end path).
pub trait SlabMath: Send + Sync {
    /// `acc + w * g`.
    fn acc(&self, acc: &Slab, g: &Slab, w: f32) -> Result<Slab>;
    /// `theta - lr * (inv_k * gsum)` — the fused average+SGD op.
    fn avg_update(&self, theta: &Slab, gsum: &Slab, inv_k: f32, lr: f32) -> Result<Slab>;
    /// `theta - lr * g`.
    fn sgd(&self, theta: &Slab, g: &Slab, lr: f32) -> Result<Slab>;
    /// `w * src` — a single-source, two-pass op (read src, write out).
    fn scale(&self, src: &Slab, w: f32) -> Result<Slab>;
}

/// Pure-Rust [`SlabMath`] (virtual slabs pass through size-only).
#[derive(Debug, Default, Clone, Copy)]
pub struct RustMath;

// All four ops lower onto the one-pass chunked constructors in `slab` —
// the old `clone` + in-place form copied the source buffer and then swept
// it again read-modify-write; `axpy_new`/`scale_new` write each output
// element once and are bit-identical to the old results (pinned by the
// slab kernel tests and `fused_ops_match_clone_then_mutate` below).
impl SlabMath for RustMath {
    fn acc(&self, acc: &Slab, g: &Slab, w: f32) -> Result<Slab> {
        Slab::axpy_new(acc, g, w)
    }

    fn avg_update(&self, theta: &Slab, gsum: &Slab, inv_k: f32, lr: f32) -> Result<Slab> {
        Slab::axpy_new(theta, gsum, -lr * inv_k)
    }

    fn sgd(&self, theta: &Slab, g: &Slab, lr: f32) -> Result<Slab> {
        Slab::axpy_new(theta, g, -lr)
    }

    fn scale(&self, src: &Slab, w: f32) -> Result<Slab> {
        Ok(Slab::scale_new(src, w))
    }
}

#[cfg(test)]
mod math_tests {
    use super::*;

    #[test]
    fn rust_math_matches_manual() {
        let m = RustMath;
        let acc = m.acc(&Slab::from_vec(vec![1.0]), &Slab::from_vec(vec![2.0]), 0.5).unwrap();
        assert_eq!(acc.as_slice().unwrap(), &[2.0]);
        let upd = m
            .avg_update(&Slab::from_vec(vec![1.0]), &Slab::from_vec(vec![4.0]), 0.25, 0.1)
            .unwrap();
        assert!((upd.as_slice().unwrap()[0] - 0.9).abs() < 1e-6);
        let sgd = m.sgd(&Slab::from_vec(vec![1.0]), &Slab::from_vec(vec![1.0]), 0.3).unwrap();
        assert!((sgd.as_slice().unwrap()[0] - 0.7).abs() < 1e-6);
        let scaled = m.scale(&Slab::from_vec(vec![2.0, -4.0]), 0.5).unwrap();
        assert_eq!(scaled.as_slice().unwrap(), &[1.0, -2.0]);
    }

    #[test]
    fn scale_equals_acc_into_zeros() {
        // The old scale_in_db detour: acc(zeros, src, w) == w * src.
        let m = RustMath;
        let src = Slab::from_vec(vec![1.5, -3.0, 0.25]);
        let via_acc = m.acc(&src.zeros_like(), &src, 0.5).unwrap();
        let direct = m.scale(&src, 0.5).unwrap();
        assert_eq!(via_acc.as_slice().unwrap(), direct.as_slice().unwrap());
    }

    #[test]
    fn rust_math_passes_virtual_through() {
        let m = RustMath;
        let out = m.acc(&Slab::virtual_of(8), &Slab::virtual_of(8), 1.0).unwrap();
        assert_eq!(out.len(), 8);
        assert!(!out.is_real());
    }

    #[test]
    fn fused_ops_match_clone_then_mutate() {
        // The pre-fusion reference: clone + in-place op, bit for bit.
        let m = RustMath;
        let theta = Slab::from_vec((0..9000).map(|i| (i as f32).sin()).collect());
        let g = Slab::from_vec((0..9000).map(|i| (i as f32).cos()).collect());
        let cases: Vec<(Slab, Slab)> = vec![
            (m.acc(&theta, &g, 0.7).unwrap(), {
                let mut r = theta.share();
                r.axpy(&g, 0.7).unwrap();
                r
            }),
            (m.avg_update(&theta, &g, 0.25, 0.1).unwrap(), {
                let mut r = theta.share();
                r.axpy(&g, -0.1 * 0.25).unwrap();
                r
            }),
            (m.sgd(&theta, &g, 0.3).unwrap(), {
                let mut r = theta.share();
                r.axpy(&g, -0.3).unwrap();
                r
            }),
            (m.scale(&g, -2.5).unwrap(), {
                let mut r = g.share();
                r.scale(-2.5);
                r
            }),
        ];
        for (got, want) in &cases {
            let got: Vec<u32> = got.as_slice().unwrap().iter().map(|x| x.to_bits()).collect();
            let want: Vec<u32> =
                want.as_slice().unwrap().iter().map(|x| x.to_bits()).collect();
            assert_eq!(got, want);
        }
    }
}
