//! Renderers: one typed [`Report`], four faithful views.
//!
//! * [`Report::to_text`] — the legacy CLI view: aligned ASCII tables with
//!   box-drawing rules, byte-compatible with the pre-report `render()`
//!   output every experiment test references.
//! * [`Report::to_markdown`] — the `docs/` page: title, provenance line,
//!   methodology paragraphs, GitHub tables with WARN markers on anchored
//!   cells that exceed their tolerance.
//! * [`Table::to_csv`] — per-table CSV preferring raw values over the
//!   formatted text.
//! * [`Report::to_json`] — the machine-readable export under `docs/data/`,
//!   built on [`crate::util::json::Json`] (BTreeMap-backed, so key order —
//!   and therefore the byte stream — is deterministic).

use crate::util::json::Json;
use crate::util::table::{Align, Table as AsciiTable};

use super::model::{Cell, Report, Section, Table, Verdict};

// ---------------------------------------------------------------------------
// Plain text (legacy CLI shape)

impl Table {
    /// Render as the legacy aligned ASCII table (title line + box borders).
    ///
    /// ```
    /// use slsgpu::report::{Align, Cell, Table};
    /// let mut t = Table::new("demo", &[("name", Align::Left), ("value", Align::Right)]);
    /// t.push_row(vec![Cell::text("a"), Cell::num(1.5, 1)]);
    /// assert!(t.to_text().contains("| a    |   1.5 |"));
    /// ```
    pub fn to_text(&self) -> String {
        let names: Vec<&str> = self.columns.iter().map(|c| c.name.as_str()).collect();
        let aligns: Vec<Align> = self.columns.iter().map(|c| c.align).collect();
        let mut t = AsciiTable::new(&names).align(&aligns);
        if let Some(title) = &self.title {
            t = t.title(title.clone());
        }
        for (i, row) in self.rows.iter().enumerate() {
            t.row(row.cells.iter().map(|c| c.text.clone()).collect());
            if self.rules.contains(&(i + 1)) {
                t.rule();
            }
        }
        t.render()
    }
}

impl Section {
    fn to_text(&self) -> String {
        let mut out = String::new();
        if let Some(h) = &self.heading {
            out.push_str(h);
            out.push_str("\n\n");
        }
        for p in &self.paragraphs {
            out.push_str(p);
            out.push_str("\n\n");
        }
        for (i, t) in self.tables.iter().enumerate() {
            if i > 0 {
                out.push('\n');
            }
            out.push_str(&t.to_text());
        }
        for n in &self.notes {
            out.push_str(n);
            out.push('\n');
        }
        out
    }
}

impl Report {
    /// Render the CLI view: sections only — the report title and intro are
    /// page front-matter and stay out of the terminal output, preserving
    /// the pre-report stdout shape.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for (i, s) in self.sections.iter().enumerate() {
            if i > 0 {
                out.push('\n');
            }
            out.push_str(&s.to_text());
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Markdown (docs/ pages)

fn md_escape(text: &str) -> String {
    text.replace('|', "\\|")
}

fn md_cell(cell: &Cell) -> String {
    match cell.verdict() {
        Some(Verdict::Warn) => format!("{} **WARN**", md_escape(&cell.text)),
        _ => md_escape(&cell.text),
    }
}

impl Table {
    /// Render as a GitHub-flavored Markdown table with alignment hints and
    /// `**WARN**` markers on out-of-tolerance anchored cells.
    ///
    /// ```
    /// use slsgpu::report::{Align, Cell, Table};
    /// let mut t = Table::new("demo", &[("name", Align::Left), ("value", Align::Right)]);
    /// t.push_row(vec![Cell::text("a"), Cell::num(1.5, 1)]);
    /// let md = t.to_markdown();
    /// assert!(md.contains("| :--- | ---: |"));
    /// assert!(md.contains("| a | 1.5 |"));
    /// ```
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if let Some(title) = &self.title {
            out.push_str(&format!("**{}**\n\n", md_escape(title)));
        }
        let header: Vec<String> = self.columns.iter().map(|c| md_escape(&c.name)).collect();
        out.push_str(&format!("| {} |\n", header.join(" | ")));
        let hints: Vec<&str> = self
            .columns
            .iter()
            .map(|c| match c.align {
                Align::Left => ":---",
                Align::Right => "---:",
            })
            .collect();
        out.push_str(&format!("| {} |\n", hints.join(" | ")));
        for row in &self.rows {
            let cells: Vec<String> = row.cells.iter().map(md_cell).collect();
            out.push_str(&format!("| {} |\n", cells.join(" | ")));
        }
        let (pass, warn) = self.verdicts();
        if pass + warn > 0 {
            out.push_str(&format!("\n*Paper anchors: {pass} PASS, {warn} WARN.*\n"));
        }
        out
    }
}

impl Section {
    fn to_markdown(&self) -> String {
        let mut out = String::new();
        if let Some(h) = &self.heading {
            out.push_str(&format!("## {h}\n\n"));
        }
        for p in &self.paragraphs {
            out.push_str(p);
            out.push_str("\n\n");
        }
        for t in &self.tables {
            out.push_str(&t.to_markdown());
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(n);
            out.push_str("\n\n");
        }
        out
    }
}

impl Report {
    /// Render the `docs/` page: title, provenance line, intro paragraphs,
    /// then every section.
    pub fn to_markdown(&self) -> String {
        let mut out = format!("# {}\n\n", self.title);
        out.push_str(&format!(
            "> Generated by `slsgpu report` — do not edit by hand.\n> Reproduce: `{}`\n\n",
            self.command
        ));
        for p in &self.intro {
            out.push_str(p);
            out.push_str("\n\n");
        }
        for s in &self.sections {
            out.push_str(&s.to_markdown());
        }
        format!("{}\n", out.trim_end())
    }
}

// ---------------------------------------------------------------------------
// CSV

fn csv_escape(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

impl Table {
    /// Render as CSV. Cells export their raw value when one is attached
    /// (full float precision), falling back to the rendered text.
    ///
    /// ```
    /// use slsgpu::report::{Align, Cell, Table};
    /// let mut t = Table::new("demo", &[("name", Align::Left), ("value", Align::Right)]);
    /// t.push_row(vec![Cell::text("a,b"), Cell::num(1.5, 1)]);
    /// assert_eq!(t.to_csv(), "name,value\n\"a,b\",1.5\n");
    /// ```
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let header: Vec<String> = self.columns.iter().map(|c| csv_escape(&c.name)).collect();
        out.push_str(&header.join(","));
        out.push('\n');
        for row in &self.rows {
            let fields: Vec<String> = row
                .cells
                .iter()
                .map(|c| match c.value {
                    Some(v) => format!("{v}"),
                    None => csv_escape(&c.text),
                })
                .collect();
            out.push_str(&fields.join(","));
            out.push('\n');
        }
        out
    }
}

// ---------------------------------------------------------------------------
// JSON (docs/data/*.json)

fn json_str(s: &str) -> Json {
    Json::Str(s.to_string())
}

fn json_str_arr(items: &[String]) -> Json {
    Json::Arr(items.iter().map(|s| json_str(s)).collect())
}

fn cell_json(cell: &Cell) -> Json {
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("text".to_string(), json_str(&cell.text));
    if let Some(v) = cell.value {
        obj.insert("value".to_string(), Json::Num(v));
    }
    if let Some(a) = cell.anchor {
        let mut anchor = std::collections::BTreeMap::new();
        anchor.insert("paper".to_string(), Json::Num(a.paper));
        anchor.insert("tol".to_string(), Json::Num(a.tol));
        if let Some(verdict) = cell.verdict() {
            anchor.insert("verdict".to_string(), json_str(verdict.name()));
        }
        obj.insert("anchor".to_string(), Json::Obj(anchor));
    }
    Json::Obj(obj)
}

impl Table {
    /// Render as a JSON object (columns, rows of typed cells, rules).
    pub fn to_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("id".to_string(), json_str(&self.id));
        if let Some(title) = &self.title {
            obj.insert("title".to_string(), json_str(title));
        }
        obj.insert(
            "columns".to_string(),
            Json::Arr(
                self.columns
                    .iter()
                    .map(|c| {
                        let mut col = std::collections::BTreeMap::new();
                        col.insert("name".to_string(), json_str(&c.name));
                        let align = match c.align {
                            Align::Left => "left",
                            Align::Right => "right",
                        };
                        col.insert("align".to_string(), json_str(align));
                        Json::Obj(col)
                    })
                    .collect(),
            ),
        );
        obj.insert(
            "rows".to_string(),
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.cells.iter().map(cell_json).collect()))
                    .collect(),
            ),
        );
        if !self.rules.is_empty() {
            obj.insert(
                "rules".to_string(),
                Json::Arr(self.rules.iter().map(|r| Json::Num(*r as f64)).collect()),
            );
        }
        Json::Obj(obj)
    }
}

impl Report {
    /// Render the machine-readable export. Deterministic: object keys are
    /// sorted (BTreeMap) and floats print in Rust's shortest round-trip
    /// form, so the same measurements always produce the same bytes.
    pub fn to_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("id".to_string(), json_str(&self.id));
        obj.insert("title".to_string(), json_str(&self.title));
        obj.insert("command".to_string(), json_str(&self.command));
        if !self.intro.is_empty() {
            obj.insert("intro".to_string(), json_str_arr(&self.intro));
        }
        let (pass, warn) = self.verdicts();
        let mut anchors = std::collections::BTreeMap::new();
        anchors.insert("pass".to_string(), Json::Num(pass as f64));
        anchors.insert("warn".to_string(), Json::Num(warn as f64));
        obj.insert("anchors".to_string(), Json::Obj(anchors));
        if let Some(status) = self.status() {
            obj.insert("status".to_string(), json_str(status.name()));
        }
        obj.insert(
            "sections".to_string(),
            Json::Arr(
                self.sections
                    .iter()
                    .map(|s| {
                        let mut sec = std::collections::BTreeMap::new();
                        if let Some(h) = &s.heading {
                            sec.insert("heading".to_string(), json_str(h));
                        }
                        if !s.paragraphs.is_empty() {
                            sec.insert("paragraphs".to_string(), json_str_arr(&s.paragraphs));
                        }
                        sec.insert(
                            "tables".to_string(),
                            Json::Arr(s.tables.iter().map(|t| t.to_json()).collect()),
                        );
                        if !s.notes.is_empty() {
                            sec.insert("notes".to_string(), json_str_arr(&s.notes));
                        }
                        Json::Obj(sec)
                    })
                    .collect(),
            ),
        );
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::super::model::{Cell, Report, Section, Table};
    use crate::util::table::Align;

    fn demo_table() -> Table {
        let mut t = Table::new("demo", &[("name", Align::Left), ("value", Align::Right)])
            .title("Demo table");
        t.push_row(vec![Cell::text("pass-row"), Cell::vs_paper(1.0, 1.0, 1, 0.1)]);
        t.push_row(vec![Cell::text("warn-row"), Cell::vs_paper(2.0, 1.0, 1, 0.1)]);
        t
    }

    #[test]
    fn text_matches_legacy_ascii_renderer() {
        let s = demo_table().to_text();
        assert!(s.starts_with("Demo table\n"), "{s}");
        assert!(s.contains("| name     |"), "{s}");
        assert!(s.contains("+-"), "{s}");
    }

    #[test]
    fn markdown_flags_warn_cells_only() {
        let md = demo_table().to_markdown();
        assert!(md.contains("| :--- | ---: |"), "{md}");
        assert!(md.contains("2.0 (paper 1.0, +100.0%) **WARN**"), "{md}");
        assert!(!md.contains("1.0 (paper 1.0, +0.0%) **WARN**"), "{md}");
        assert!(md.contains("*Paper anchors: 1 PASS, 1 WARN.*"), "{md}");
    }

    #[test]
    fn markdown_escapes_pipes() {
        let mut t = Table::new("t", &[("a", Align::Left)]);
        t.push_row(vec![Cell::text("x | y")]);
        assert!(t.to_markdown().contains("x \\| y"));
    }

    #[test]
    fn csv_prefers_raw_values() {
        let csv = demo_table().to_csv();
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some("name,value"));
        assert_eq!(lines.next(), Some("pass-row,1"));
        assert_eq!(lines.next(), Some("warn-row,2"));
    }

    #[test]
    fn report_text_omits_front_matter_and_keeps_notes_order() {
        let r = Report::new("demo", "Demo report", "slsgpu demo")
            .with_intro("intro paragraph")
            .with_section(Section::new().table(demo_table()).note("trailing note"));
        let text = r.to_text();
        assert!(!text.contains("Demo report"), "{text}");
        assert!(!text.contains("intro paragraph"), "{text}");
        assert!(text.ends_with("trailing note\n"), "{text}");
        let md = r.to_markdown();
        assert!(md.starts_with("# Demo report\n"), "{md}");
        assert!(md.contains("intro paragraph"), "{md}");
        assert!(md.contains("Reproduce: `slsgpu demo`"), "{md}");
    }

    #[test]
    fn json_is_valid_and_roundtrips() {
        let r = Report::new("demo", "Demo report", "slsgpu demo").with_table(demo_table());
        let s = r.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&s).unwrap();
        assert_eq!(parsed.get("id").unwrap().as_str().unwrap(), "demo");
        assert_eq!(parsed.get("status").unwrap().as_str().unwrap(), "WARN");
        assert_eq!(
            parsed.get("anchors").unwrap().get("pass").unwrap().as_usize().unwrap(),
            1
        );
    }
}
