//! Dependency-free utilities: PRNG, JSON, CLI args, ASCII tables.
//!
//! The build environment is fully offline (only the `xla` crate and its
//! transitive deps are vendored), so the conveniences that would normally
//! come from `rand`, `serde_json`, `clap` and `comfy-table` are implemented
//! here as small, tested modules.

pub mod cli;
pub mod json;
pub mod rng;
pub mod table;

/// Format a byte count as a human-readable string.
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut value = bytes as f64;
    let mut unit = 0;
    while value >= 1024.0 && unit < UNITS.len() - 1 {
        value /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{bytes} B")
    } else {
        format!("{value:.2} {}", UNITS[unit])
    }
}

/// Format seconds as `h:mm:ss.s` / `m:ss.s` / `s.s` depending on magnitude.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 3600.0 {
        format!(
            "{}h{:02}m{:04.1}s",
            (secs / 3600.0) as u64,
            ((secs % 3600.0) / 60.0) as u64,
            secs % 60.0
        )
    } else if secs >= 60.0 {
        format!("{}m{:04.1}s", (secs / 60.0) as u64, secs % 60.0)
    } else {
        format!("{secs:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.00 MiB");
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(5.0), "5.00s");
        assert_eq!(fmt_duration(65.0), "1m05.0s");
        assert!(fmt_duration(3725.0).starts_with("1h02m"));
    }
}
