//! Virtual time: an f64-seconds newtype with total ordering.

use std::cmp::Ordering;
use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point on the virtual timeline, in seconds since experiment start.
///
/// Total ordering is safe because durations are always finite (asserted on
/// construction), so `VTime` can be used in sorts and max-reductions.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct VTime(f64);

impl VTime {
    pub const ZERO: VTime = VTime(0.0);

    pub fn from_secs(s: f64) -> VTime {
        assert!(s.is_finite() && s >= 0.0, "invalid virtual time {s}");
        VTime(s)
    }

    pub fn secs(self) -> f64 {
        self.0
    }

    /// Raw IEEE-754 bits of the underlying seconds value. Bit-identity
    /// assertions (determinism suite, event-queue reference tests) compare
    /// these instead of going through `secs().to_bits()` at every call site.
    pub fn to_bits(self) -> u64 {
        self.0.to_bits()
    }

    pub fn minutes(self) -> f64 {
        self.0 / 60.0
    }

    pub fn max(self, other: VTime) -> VTime {
        if self.0 >= other.0 {
            self
        } else {
            other
        }
    }

    pub fn min(self, other: VTime) -> VTime {
        if self.0 <= other.0 {
            self
        } else {
            other
        }
    }
}

impl Add<f64> for VTime {
    type Output = VTime;
    fn add(self, dur: f64) -> VTime {
        assert!(dur.is_finite() && dur >= 0.0, "invalid duration {dur}");
        VTime(self.0 + dur)
    }
}

impl AddAssign<f64> for VTime {
    fn add_assign(&mut self, dur: f64) {
        *self = *self + dur;
    }
}

impl Sub for VTime {
    type Output = f64;
    fn sub(self, other: VTime) -> f64 {
        self.0 - other.0
    }
}

impl PartialOrd for VTime {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Eq for VTime {}

#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for VTime {
    fn cmp(&self, other: &Self) -> Ordering {
        // Finite by construction.
        self.0.partial_cmp(&other.0).unwrap()
    }
}

impl fmt::Display for VTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", crate::util::fmt_duration(self.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let t = VTime::from_secs(1.5) + 2.5;
        assert_eq!(t.secs(), 4.0);
        assert_eq!(t - VTime::from_secs(1.0), 3.0);
        assert_eq!(t.minutes(), 4.0 / 60.0);
    }

    #[test]
    fn ordering_and_max() {
        let a = VTime::from_secs(1.0);
        let b = VTime::from_secs(2.0);
        assert!(a < b);
        assert_eq!(a.max(b), b);
        assert_eq!(a.min(b), a);
        let mut v = vec![b, a];
        v.sort();
        assert_eq!(v, vec![a, b]);
    }

    #[test]
    #[should_panic(expected = "invalid duration")]
    fn rejects_negative_duration() {
        let _ = VTime::ZERO + (-1.0);
    }

    #[test]
    #[should_panic(expected = "invalid virtual time")]
    fn rejects_nan() {
        let _ = VTime::from_secs(f64::NAN);
    }
}
