//! Gradient-poisoning recovery demo: robust aggregation on a real
//! (dependency-free) distributed learning task.
//!
//! The accuracy effect of poisoning cannot be shown on size-only gradients,
//! and the PJRT artifacts are not always available — so this module trains
//! an actual model with pure-Rust math: logistic regression on a seeded
//! synthetic binary task, data-parallel across `workers` shards, gradients
//! aggregated per round exactly like the frameworks aggregate slabs. One
//! worker is Byzantine ([`PoisonMode`] applied to its submitted gradient);
//! the aggregation rule is the variable under test.
//!
//! Expected (and asserted) outcome: with the naive mean a single scaled
//! sign-flipped worker drives the global step in the wrong direction and
//! accuracy collapses; clipped mean bounds its influence and recovers to
//! within 2 accuracy points of the fault-free run, and the coordinate
//! median recovers almost as closely (it carries a small estimator bias —
//! median-of-shards vs mean-of-shards) — the SPIRT robustness claim,
//! reproduced in miniature.

use anyhow::Result;

use crate::faults::PoisonMode;
use crate::tensor::{AggregationRule, Slab};
use crate::util::rng::Rng;

/// Demo dimensions: small enough to run in milliseconds, large enough that
/// accuracies are stable across seeds.
const DIM: usize = 24;
const TRAIN: usize = 1024;
const TEST: usize = 512;
const ROUNDS: usize = 100;
const LR: f32 = 0.5;

/// Default worker count for the demo: one Byzantine worker out of eight.
/// At 4 workers (25% Byzantine) even robust estimators carry a visible
/// equilibrium bias; 1-of-8 is the regime the 2-point recovery claim is
/// calibrated for.
pub const DEMO_WORKERS: usize = 8;

/// One aggregation rule's outcome under a poisoned worker.
#[derive(Debug, Clone)]
pub struct PoisonRow {
    pub rule: AggregationRule,
    pub final_acc: f64,
}

/// Full demo outcome.
#[derive(Debug, Clone)]
pub struct PoisonReport {
    pub workers: usize,
    pub mode: PoisonMode,
    /// Accuracy of the fault-free run (naive mean, no adversary).
    pub fault_free_acc: f64,
    pub rows: Vec<PoisonRow>,
}

/// Seeded synthetic binary task: labels follow a fixed ground-truth linear
/// separator with margin noise.
struct Task {
    x: Vec<f32>, // n × DIM
    y: Vec<f32>, // ±1
}

impl Task {
    /// Draw `n` samples labeled by the shared ground-truth separator
    /// `w_true` (train and test must come from the same separator).
    fn generate(rng: &mut Rng, n: usize, w_true: &[f32]) -> Task {
        let mut x = Vec::with_capacity(n * DIM);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let xi: Vec<f32> = (0..DIM).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            // audit:allow(float-reduction, 16-wide dot product in fixed order - demo data gen, not a kernel or vtime path)
            let margin: f32 = xi.iter().zip(w_true).map(|(a, b)| a * b).sum::<f32>()
                + rng.normal_f32(0.0, 0.5);
            y.push(if margin >= 0.0 { 1.0 } else { -1.0 });
            x.extend_from_slice(&xi);
        }
        Task { x, y }
    }

    fn len(&self) -> usize {
        self.y.len()
    }

    /// Mean logistic-loss gradient of `theta` over samples [lo, hi).
    fn grad(&self, theta: &[f32], lo: usize, hi: usize) -> Slab {
        let mut g = vec![0.0f32; DIM];
        for i in lo..hi {
            let xi = &self.x[i * DIM..(i + 1) * DIM];
            let yi = self.y[i];
            // audit:allow(float-reduction, 16-wide dot product in fixed order - demo gradient, checked by its accuracy tests)
            let m: f32 = xi.iter().zip(theta).map(|(a, b)| a * b).sum();
            // d/dw ln(1+exp(-y w·x)) = -y x σ(-y w·x)
            let s = 1.0 / (1.0 + (yi * m).exp());
            let c = -yi * s / (hi - lo) as f32;
            for (gj, xj) in g.iter_mut().zip(xi) {
                *gj += c * xj;
            }
        }
        Slab::from_vec(g)
    }

    fn accuracy(&self, theta: &[f32]) -> f64 {
        let correct = (0..self.len())
            .filter(|&i| {
                let xi = &self.x[i * DIM..(i + 1) * DIM];
                // audit:allow(float-reduction, 16-wide dot product in fixed order - demo accuracy metric)
                let m: f32 = xi.iter().zip(theta).map(|(a, b)| a * b).sum();
                (m >= 0.0) == (self.y[i] >= 0.0)
            })
            .count();
        correct as f64 / self.len() as f64
    }
}

/// Train with `workers` data-parallel shards; every worker listed in
/// `poisoned` (a coalition — possibly empty, possibly a single Byzantine
/// worker) corrupts its gradient with `mode` before submission; `rule`
/// combines the submissions. Returns test accuracy.
fn train(
    train: &Task,
    test: &Task,
    workers: usize,
    poisoned: &[usize],
    mode: PoisonMode,
    rule: AggregationRule,
) -> Result<f64> {
    let mut theta = vec![0.0f32; DIM];
    let shard = train.len() / workers;
    for _ in 0..ROUNDS {
        let mut grads = Vec::with_capacity(workers);
        for w in 0..workers {
            let mut g = train.grad(&theta, w * shard, (w + 1) * shard);
            if poisoned.contains(&w) {
                mode.apply(&mut g);
            }
            grads.push(g);
        }
        let step = rule.apply(&grads)?;
        for (t, s) in theta.iter_mut().zip(step.as_slice()?) {
            *t -= LR * s;
        }
    }
    Ok(test.accuracy(&theta))
}

/// Final test accuracy of one training run with the workers in `poisoned`
/// colluding under `mode` and `rule` aggregating. An empty coalition is the
/// fault-free baseline. This is the accuracy axis of the robustness
/// tournament (`exp::tournament`): same task, same seed derivation as
/// [`run`], so tournament columns are comparable to the demo table.
pub fn coalition_accuracy(
    seed: u64,
    workers: usize,
    poisoned: &[usize],
    mode: PoisonMode,
    rule: AggregationRule,
) -> Result<f64> {
    assert!(workers >= 3, "need a Byzantine minority");
    let mut rng = Rng::new(seed ^ 0xB12A_57);
    let w_true: Vec<f32> = (0..DIM).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let train_set = Task::generate(&mut rng, TRAIN, &w_true);
    let test_set = Task::generate(&mut rng, TEST, &w_true);
    train(&train_set, &test_set, workers, poisoned, mode, rule)
}

/// Run the full demo: fault-free baseline, then each rule against one
/// poisoned worker out of `workers`.
pub fn run(seed: u64, workers: usize, mode: PoisonMode) -> Result<PoisonReport> {
    assert!(workers >= 3, "need a Byzantine minority");
    let mut rng = Rng::new(seed ^ 0xB12A_57);
    let w_true: Vec<f32> = (0..DIM).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let train_set = Task::generate(&mut rng, TRAIN, &w_true);
    let test_set = Task::generate(&mut rng, TEST, &w_true);

    let fault_free_acc =
        train(&train_set, &test_set, workers, &[], PoisonMode::SignFlip, AggregationRule::Mean)?;
    let mut rows = Vec::new();
    for rule in [
        AggregationRule::Mean,
        AggregationRule::ClippedMean { ratio: 1.0 },
        AggregationRule::CoordMedian,
    ] {
        let final_acc = train(&train_set, &test_set, workers, &[1], mode, rule)?;
        rows.push(PoisonRow { rule, final_acc });
    }
    Ok(PoisonReport { workers, mode, fault_free_acc, rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The headline robustness claim, asserted: robust aggregation
    /// (clipped mean) recovers final accuracy to within 2 points of the
    /// fault-free run while the naive mean measurably degrades. The
    /// coordinate median also recovers but carries a small estimator bias
    /// (median-of-shards vs mean-of-shards), so its bound is looser.
    #[test]
    fn robust_rules_recover_naive_mean_degrades() {
        let report = run(42, DEMO_WORKERS, PoisonMode::Scale(-8.0)).unwrap();
        assert!(
            report.fault_free_acc > 0.85,
            "fault-free baseline should learn the task, got {:.3}",
            report.fault_free_acc
        );
        for row in &report.rows {
            match row.rule {
                AggregationRule::Mean => assert!(
                    row.final_acc < report.fault_free_acc - 0.05,
                    "naive mean should degrade measurably: {:.3} vs {:.3}",
                    row.final_acc,
                    report.fault_free_acc
                ),
                AggregationRule::ClippedMean { .. } => assert!(
                    row.final_acc >= report.fault_free_acc - 0.02,
                    "clipped mean should recover within 2 points: {:.3} vs {:.3}",
                    row.final_acc,
                    report.fault_free_acc
                ),
                AggregationRule::CoordMedian => assert!(
                    row.final_acc >= report.fault_free_acc - 0.04,
                    "coord median should recover within 4 points: {:.3} vs {:.3}",
                    row.final_acc,
                    report.fault_free_acc
                ),
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = run(7, DEMO_WORKERS, PoisonMode::SignFlip).unwrap();
        let b = run(7, DEMO_WORKERS, PoisonMode::SignFlip).unwrap();
        assert_eq!(a.fault_free_acc.to_bits(), b.fault_free_acc.to_bits());
        for (ra, rb) in a.rows.iter().zip(&b.rows) {
            assert_eq!(ra.final_acc.to_bits(), rb.final_acc.to_bits());
        }
    }

    #[test]
    fn empty_coalition_matches_fault_free_baseline() {
        let report = run(42, DEMO_WORKERS, PoisonMode::Scale(-8.0)).unwrap();
        let clean = coalition_accuracy(
            42,
            DEMO_WORKERS,
            &[],
            PoisonMode::Scale(-8.0),
            AggregationRule::Mean,
        )
        .unwrap();
        assert_eq!(clean.to_bits(), report.fault_free_acc.to_bits());
    }

    #[test]
    fn sign_flip_alone_is_tolerated_by_median() {
        let report = run(3, DEMO_WORKERS, PoisonMode::SignFlip).unwrap();
        let median = report
            .rows
            .iter()
            .find(|r| r.rule == AggregationRule::CoordMedian)
            .unwrap();
        assert!(median.final_acc >= report.fault_free_acc - 0.02);
    }
}
