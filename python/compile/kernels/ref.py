"""Pure-jnp oracles for every Pallas kernel — the build-time correctness bar.

pytest asserts allclose(kernel, ref) across a hypothesis sweep of shapes and
value ranges before aot.py is allowed to emit artifacts (see
python/tests/test_*_kernel.py).
"""

import jax.numpy as jnp


def matmul(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def accumulate(acc, g, w):
    return acc + w * g


def fused_avg_update(theta, gsum, inv_k, lr):
    return theta - lr * (inv_k * gsum)


def sgd_update(theta, g, lr):
    return theta - lr * g


def l2_norm_sq(g):
    return jnp.sum(g * g)


def is_significant(g, theta, threshold):
    gn = jnp.sum(g * g)
    tn = jnp.sum(theta * theta)
    return jnp.where(gn > (threshold * threshold) * jnp.maximum(tn, 1e-12), 1.0, 0.0)
