//! Fault plans and the deterministic runtime schedule.
//!
//! A [`FaultPlan`] is *data*: a list of [`FaultEvent`]s that say which
//! worker misbehaves, how, and when — either at protocol coordinates
//! (epoch/round, the natural unit every strategy shares) or at a planned
//! virtual time on the worker's clock. A [`FaultSchedule`] is the plan
//! armed for one run: it tracks the per-worker round counters and which
//! one-shot events already fired. All queries are pure scans over the event
//! list, so a given (plan, seed, config) produces bit-identical virtual
//! timelines on every run — the property the determinism integration test
//! locks in.

use anyhow::{bail, Result};

use crate::sim::VTime;
use crate::tensor::Slab;
use crate::util::rng::Rng;

/// Sentinel worker id for events that target the MLLess supervisor rather
/// than a training worker.
pub const SUPERVISOR: usize = usize::MAX;

/// How a poisoned worker corrupts its gradient before submitting it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PoisonMode {
    /// Multiply the update by a factor (|f| > 1 amplifies, f < 0 reverses).
    Scale(f32),
    /// Flip the sign of every coordinate (Scale(-1) with intent spelled out).
    SignFlip,
}

impl PoisonMode {
    /// Corrupt `grad` in place. Virtual slabs pass through numerically
    /// (size-only experiments track the poisoning in RecoveryStats instead).
    pub fn apply(&self, grad: &mut Slab) {
        match self {
            PoisonMode::Scale(f) => grad.scale(*f),
            PoisonMode::SignFlip => grad.scale(-1.0),
        }
    }
}

/// What goes wrong.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The worker's in-flight invocation dies mid-compute. The platform
    /// retries it: cold start + state re-load + recompute, billed again.
    CrashCompute,
    /// The worker dies entering the synchronization stage and restarts
    /// after a cold start + snapshot restore. Peer behaviour is the
    /// architectural difference: SPIRT reroutes around the dead peer,
    /// barriered frameworks stall until it is back.
    CrashSync,
    /// The MLLess supervisor process dies; the round stalls until it
    /// restarts and re-polls the worker reports. No-op elsewhere.
    CrashSupervisor,
    /// Compute runs `factor`× slower while active (degraded vCPU,
    /// co-tenancy, thermal throttling).
    Straggler { factor: f64 },
    /// The worker's produced update is lost before synchronization while
    /// active (message/object drop).
    DropUpdate,
    /// A shard of the shared store tier crashes at the top of an epoch,
    /// losing its in-memory contents and serving nothing until it restarts.
    /// The event's `worker` field holds the *shard id*, not a worker id.
    /// Reads fail over to replicas (replication permitting); no-op for
    /// strategies that never touch the shared store.
    ShardCrash,
    /// The worker submits corrupted gradients while active.
    Poison(PoisonMode),
    /// The worker is cut off from the network (stores, queues, peers) from
    /// the trigger until virtual time `heal` on its clock: every protocol
    /// op it issues in that window is deferred to the heal time. Partition
    /// events must use [`Trigger::VTime`] so the heal-after-start invariant
    /// is checkable up front.
    Partition { heal: f64 },
    /// Heavy-tailed straggler: each affected round draws a deterministic
    /// Pareto-like slowdown factor `scale · (1 − u)^(−1/alpha)` where `u`
    /// is a seeded uniform keyed by (worker, epoch, round). Small `alpha`
    /// (e.g. 1.5) gives the occasional catastrophic tail round the fixed
    /// [`FaultKind::Straggler`] cannot model.
    ParetoStraggler { alpha: f64, scale: f64, seed: u64 },
    /// Spot-instance preemption: the in-flight invocation is reclaimed by
    /// the platform mid-compute. Recovery mechanics match
    /// [`FaultKind::CrashCompute`] (cold start + state re-load + recompute,
    /// billed again), but the event is traced as a preemption so storms
    /// stay visible as such in the event log.
    Preempt,
}

/// When a fault triggers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Trigger {
    /// Protocol coordinates: 1-based epoch, 0-based round/minibatch within
    /// it. Sync-phase crashes ignore the round (they fire at that epoch's
    /// synchronization stage).
    Round { epoch: usize, round: usize },
    /// First hook consultation at or after this virtual time on the
    /// affected worker's clock.
    VTime(f64),
}

/// One planned fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Target worker (or [`SUPERVISOR`]).
    pub worker: usize,
    pub kind: FaultKind,
    pub at: Trigger,
    /// For persistent kinds (straggler/drop/poison) triggered by round:
    /// how many consecutive rounds of that epoch stay affected; `None`
    /// means from the trigger to the end of the run (all later epochs).
    /// Ignored for crashes and for `VTime` triggers (always to end of run).
    pub rounds: Option<usize>,
}

/// A declarative set of fault events (builder-style construction).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan: a fault-free run.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn with(mut self, event: FaultEvent) -> FaultPlan {
        self.events.push(event);
        self
    }

    /// Compute-phase crash of `worker` at (epoch, round).
    pub fn crash(self, worker: usize, epoch: usize, round: usize) -> FaultPlan {
        self.with(FaultEvent {
            worker,
            kind: FaultKind::CrashCompute,
            at: Trigger::Round { epoch, round },
            rounds: None,
        })
    }

    /// Compute-phase crash of `worker` at the first invocation at or after
    /// virtual time `secs`.
    pub fn crash_at_vtime(self, worker: usize, secs: f64) -> FaultPlan {
        self.with(FaultEvent {
            worker,
            kind: FaultKind::CrashCompute,
            at: Trigger::VTime(secs),
            rounds: None,
        })
    }

    /// Sync-phase crash of `worker` in `epoch`.
    pub fn sync_crash(self, worker: usize, epoch: usize) -> FaultPlan {
        self.with(FaultEvent {
            worker,
            kind: FaultKind::CrashSync,
            at: Trigger::Round { epoch, round: 0 },
            rounds: None,
        })
    }

    /// MLLess supervisor crash at (epoch, round).
    pub fn supervisor_crash(self, epoch: usize, round: usize) -> FaultPlan {
        self.with(FaultEvent {
            worker: SUPERVISOR,
            kind: FaultKind::CrashSupervisor,
            at: Trigger::Round { epoch, round },
            rounds: None,
        })
    }

    /// Crash store-tier shard `shard` at the top of `epoch`.
    pub fn shard_crash(self, shard: usize, epoch: usize) -> FaultPlan {
        self.with(FaultEvent {
            worker: shard,
            kind: FaultKind::ShardCrash,
            at: Trigger::Round { epoch, round: 0 },
            rounds: None,
        })
    }

    /// `worker` computes `factor`× slower for `rounds` rounds from
    /// (epoch, round); `None` = for the rest of the run.
    pub fn straggler(
        self,
        worker: usize,
        epoch: usize,
        round: usize,
        factor: f64,
        rounds: Option<usize>,
    ) -> FaultPlan {
        self.with(FaultEvent {
            worker,
            kind: FaultKind::Straggler { factor },
            at: Trigger::Round { epoch, round },
            rounds,
        })
    }

    /// `worker`'s updates are dropped for `rounds` rounds from (epoch, round).
    pub fn drop_updates(
        self,
        worker: usize,
        epoch: usize,
        round: usize,
        rounds: Option<usize>,
    ) -> FaultPlan {
        self.with(FaultEvent {
            worker,
            kind: FaultKind::DropUpdate,
            at: Trigger::Round { epoch, round },
            rounds,
        })
    }

    /// `worker` submits poisoned gradients from `epoch` onwards.
    pub fn poison(self, worker: usize, epoch: usize, mode: PoisonMode) -> FaultPlan {
        self.with(FaultEvent {
            worker,
            kind: FaultKind::Poison(mode),
            at: Trigger::Round { epoch, round: 0 },
            rounds: None,
        })
    }

    /// A colluding Byzantine coalition: every worker in `members` applies
    /// the same `mode` on the same rounds — `rounds` rounds from
    /// (epoch, round), `None` = to the end of the run. Coordinated
    /// poisoning is the regime robust aggregators quote their breakdown
    /// point `f` against; validation rejects plans that name a member
    /// twice with overlapping windows (the duplicate would silently
    /// shadow under first-match-wins resolution).
    pub fn coalition(
        mut self,
        members: &[usize],
        epoch: usize,
        round: usize,
        rounds: Option<usize>,
        mode: PoisonMode,
    ) -> FaultPlan {
        for &worker in members {
            self.events.push(FaultEvent {
                worker,
                kind: FaultKind::Poison(mode),
                at: Trigger::Round { epoch, round },
                rounds,
            });
        }
        self
    }

    /// Partition `members` off the network from virtual time `start` until
    /// they heal at `heal` (both on the affected workers' clocks). While
    /// partitioned, every protocol op a member issues is deferred to the
    /// heal time; peers see its writes only after. Validation rejects
    /// `heal <= start`.
    pub fn partition(mut self, members: &[usize], start: f64, heal: f64) -> FaultPlan {
        for &worker in members {
            self.events.push(FaultEvent {
                worker,
                kind: FaultKind::Partition { heal },
                at: Trigger::VTime(start),
                rounds: None,
            });
        }
        self
    }

    /// Heavy-tailed stragglers on `members`: each affected round draws a
    /// deterministic Pareto-like factor (shape `alpha`, minimum `scale`)
    /// from a stream keyed by `seed` and the (worker, epoch, round)
    /// coordinates, for `rounds` rounds from (epoch, round);
    /// `None` = rest of the run.
    #[allow(clippy::too_many_arguments)]
    pub fn pareto_stragglers(
        mut self,
        members: &[usize],
        epoch: usize,
        round: usize,
        alpha: f64,
        scale: f64,
        seed: u64,
        rounds: Option<usize>,
    ) -> FaultPlan {
        for &worker in members {
            self.events.push(FaultEvent {
                worker,
                kind: FaultKind::ParetoStraggler { alpha, scale, seed },
                at: Trigger::Round { epoch, round },
                rounds,
            });
        }
        self
    }

    /// A correlated spot-preemption storm: every worker in `victims` is
    /// preempted mid-compute at (epoch, round) — the burst pattern of a
    /// capacity reclaim sweeping a spot fleet. Each victim pays the full
    /// cold-start restart billing of a compute crash.
    pub fn preemption_storm(mut self, victims: &[usize], epoch: usize, round: usize) -> FaultPlan {
        for &worker in victims {
            self.events.push(FaultEvent {
                worker,
                kind: FaultKind::Preempt,
                at: Trigger::Round { epoch, round },
                rounds: None,
            });
        }
        self
    }
}

/// Can two poison windows on the same worker ever be active on the same
/// round? Conservative: any reachable overlap counts.
fn poison_windows_overlap(a: &FaultEvent, b: &FaultEvent) -> bool {
    let (ea, ra, na) = match a.at {
        // A VTime-triggered poison is active from t to the end of the run.
        Trigger::VTime(_) => return true,
        Trigger::Round { epoch, round } => (epoch, round, a.rounds),
    };
    let (eb, rb, nb) = match b.at {
        Trigger::VTime(_) => return true,
        Trigger::Round { epoch, round } => (epoch, round, b.rounds),
    };
    match (na, nb) {
        // Bounded windows are epoch-local: overlap needs the same epoch
        // and intersecting round intervals.
        (Some(na), Some(nb)) => ea == eb && ra < rb + nb && rb < ra + na,
        // An open window covers every round of every later epoch.
        (None, Some(nb)) => eb > ea || (eb == ea && rb + nb > ra),
        (Some(na), None) => ea > eb || (ea == eb && ra + na > rb),
        (None, None) => true,
    }
}

/// A deterministic Pareto-like slowdown factor for one (worker, epoch,
/// round) coordinate: `scale · (1 − u)^(−1/alpha)` with `u` drawn from a
/// stream forked off `seed` by the coordinates. Pure function — the same
/// coordinates always produce the same factor, independent of query order.
fn pareto_factor(
    seed: u64,
    worker: usize,
    epoch: usize,
    round: usize,
    alpha: f64,
    scale: f64,
) -> f64 {
    let u = Rng::new(seed)
        .fork(worker as u64)
        .fork(epoch as u64)
        .fork(round as u64)
        .next_f64();
    // u ∈ [0, 1); cap just below 1 so the tail stays finite.
    scale * (1.0 - u.min(1.0 - 1e-12)).powf(-1.0 / alpha)
}

/// Result of a [`FaultSchedule::partition_until`] query for a partitioned
/// worker: when it heals, and which planned windows were consulted for the
/// first time (so the env traces each partition span exactly once).
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionHit {
    /// Virtual time the worker becomes reachable again.
    pub until: f64,
    /// `(start, heal)` of each window first consulted by this query.
    pub newly: Vec<(f64, f64)>,
}

/// A [`FaultPlan`] armed for one run.
#[derive(Debug, Clone, Default)]
pub struct FaultSchedule {
    events: Vec<FaultEvent>,
    /// One-shot consumption flags (crashes fire exactly once; partitions
    /// reuse the flag to trace their window exactly once).
    fired: Vec<bool>,
    /// Per-worker compute-round counter, reset each epoch.
    round_of: Vec<usize>,
    epoch: usize,
    /// Cached "any partition events at all" — `partition_until` sits on
    /// every protocol op, so the common no-partition case must be one load.
    has_partition: bool,
}

impl FaultSchedule {
    pub fn new(plan: FaultPlan, workers: usize) -> Result<FaultSchedule> {
        for ev in &plan.events {
            let is_supervisor = matches!(ev.kind, FaultKind::CrashSupervisor);
            if is_supervisor {
                if ev.worker != SUPERVISOR {
                    bail!("supervisor crash events must target SUPERVISOR");
                }
            } else if matches!(ev.kind, FaultKind::ShardCrash) {
                // The worker field is a shard id; the store tier validates
                // it against its shard count when the env is built.
            } else if ev.worker >= workers {
                bail!("fault event targets worker {} of {workers}", ev.worker);
            }
            if let FaultKind::Straggler { factor } = ev.kind {
                if !(factor >= 1.0 && factor.is_finite()) {
                    bail!("straggler factor must be >= 1, got {factor}");
                }
            }
            if let FaultKind::ParetoStraggler { alpha, scale, .. } = ev.kind {
                if !(alpha > 0.0 && alpha.is_finite()) {
                    bail!("pareto straggler shape must be > 0, got {alpha}");
                }
                if !(scale >= 1.0 && scale.is_finite()) {
                    bail!("pareto straggler scale must be >= 1, got {scale}");
                }
            }
            if let FaultKind::Partition { heal } = ev.kind {
                let Trigger::VTime(start) = ev.at else {
                    bail!("partition events must use a VTime trigger");
                };
                if !(heal.is_finite() && start.is_finite() && heal > start) {
                    bail!("partition heal time {heal} must follow its start {start}");
                }
            }
        }
        // A worker named twice in overlapping poison windows would fire
        // silently under first-match-wins resolution: the duplicate event
        // never applies, and a coalition plan that meant two *different*
        // workers quietly loses a member. Reject up front.
        for (i, a) in plan.events.iter().enumerate() {
            if !matches!(a.kind, FaultKind::Poison(_)) {
                continue;
            }
            for b in plan.events.iter().skip(i + 1) {
                if !matches!(b.kind, FaultKind::Poison(_)) || a.worker != b.worker {
                    continue;
                }
                if poison_windows_overlap(a, b) {
                    bail!(
                        "poison events name worker {} twice with overlapping rounds",
                        a.worker
                    );
                }
            }
        }
        let fired = vec![false; plan.events.len()];
        let has_partition =
            plan.events.iter().any(|ev| matches!(ev.kind, FaultKind::Partition { .. }));
        Ok(FaultSchedule {
            events: plan.events,
            fired,
            round_of: vec![0; workers],
            epoch: 0,
            has_partition,
        })
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// New epoch: reset the per-worker round counters.
    pub fn begin_epoch(&mut self, epoch: usize) {
        self.epoch = epoch;
        for r in &mut self.round_of {
            *r = 0;
        }
    }

    /// A worker starts computing its next gradient; returns the 0-based
    /// round index within the current epoch.
    pub fn note_compute(&mut self, worker: usize) -> usize {
        let r = self.round_of[worker];
        self.round_of[worker] += 1;
        r
    }

    /// A retry re-runs the same round: undo one `note_compute` so the
    /// recomputation does not shift later round coordinates.
    pub fn redo_round(&mut self, worker: usize) {
        self.round_of[worker] = self.round_of[worker].saturating_sub(1);
    }

    /// The round the worker most recently computed (0 before any compute).
    pub fn current_round(&self, worker: usize) -> usize {
        self.round_of[worker].saturating_sub(1)
    }

    /// Is a persistent event active at (this epoch, `round`, `now`)?
    fn active(&self, ev: &FaultEvent, round: usize, now: VTime) -> bool {
        match ev.at {
            Trigger::VTime(t) => now.secs() >= t,
            Trigger::Round { epoch, round: r0 } => {
                if self.epoch < epoch {
                    return false;
                }
                if self.epoch > epoch {
                    // Later epochs: only open-ended windows persist.
                    return ev.rounds.is_none();
                }
                match ev.rounds {
                    None => round >= r0,
                    Some(n) => round >= r0 && round < r0 + n,
                }
            }
        }
    }

    /// Compute slowdown multiplier for `worker` at `round` (product of all
    /// active straggler events; 1.0 when none). Heavy-tailed events draw
    /// their factor from a pure function of (seed, worker, epoch, round),
    /// so the same coordinates always see the same tail.
    pub fn compute_factor(&self, worker: usize, round: usize, now: VTime) -> f64 {
        self.events
            .iter()
            .filter(|ev| ev.worker == worker)
            .filter_map(|ev| match ev.kind {
                FaultKind::Straggler { factor } if self.active(ev, round, now) => Some(factor),
                FaultKind::ParetoStraggler { alpha, scale, seed }
                    if self.active(ev, round, now) =>
                {
                    Some(pareto_factor(seed, worker, self.epoch, round, alpha, scale))
                }
                _ => None,
            })
            .product()
    }

    /// If `worker` is partitioned at `now`, the virtual time it heals
    /// (max over overlapping partition events), plus the `(start, heal)`
    /// windows consulted here for the first time (for one-shot trace
    /// emission). `None` when the worker is reachable.
    pub fn partition_until(&mut self, worker: usize, now: VTime) -> Option<PartitionHit> {
        if !self.has_partition {
            return None;
        }
        let mut until = f64::NEG_INFINITY;
        let mut newly = Vec::new();
        for (i, ev) in self.events.iter().enumerate() {
            let FaultKind::Partition { heal } = ev.kind else { continue };
            // Validated at construction: partitions are VTime-triggered.
            let Trigger::VTime(start) = ev.at else { continue };
            if ev.worker != worker || now.secs() < start || now.secs() >= heal {
                continue;
            }
            until = until.max(heal);
            if !self.fired[i] {
                self.fired[i] = true;
                newly.push((start, heal));
            }
        }
        (until > f64::NEG_INFINITY).then_some(PartitionHit { until, newly })
    }

    /// Does the platform preempt `worker`'s in-flight invocation at
    /// `round`? Consumes the event (a spot reclaim fires once).
    pub fn preempted(&mut self, worker: usize, round: usize, now: VTime) -> bool {
        self.fire(worker, FaultKind::Preempt, Some(round), now)
    }

    /// Active poison mode for `worker` at `round` (first match wins).
    pub fn poison(&self, worker: usize, round: usize, now: VTime) -> Option<PoisonMode> {
        self.events
            .iter()
            .filter(|ev| ev.worker == worker)
            .find_map(|ev| match ev.kind {
                FaultKind::Poison(mode) if self.active(ev, round, now) => Some(mode),
                _ => None,
            })
    }

    /// Is `worker`'s update at `round` dropped?
    pub fn drop_update(&self, worker: usize, round: usize, now: VTime) -> bool {
        self.events.iter().any(|ev| {
            ev.worker == worker
                && matches!(ev.kind, FaultKind::DropUpdate)
                && self.active(ev, round, now)
        })
    }

    /// One-shot matcher: fire (and consume) the first unfired event of
    /// `kind` for `worker` whose trigger matches.
    fn fire(
        &mut self,
        worker: usize,
        kind: FaultKind,
        round: Option<usize>,
        now: VTime,
    ) -> bool {
        for (i, ev) in self.events.iter().enumerate() {
            if self.fired[i] || ev.worker != worker || ev.kind != kind {
                continue;
            }
            let hit = match ev.at {
                Trigger::VTime(t) => now.secs() >= t,
                Trigger::Round { epoch, round: r0 } => {
                    self.epoch == epoch && round.map(|r| r == r0).unwrap_or(true)
                }
            };
            if hit {
                self.fired[i] = true;
                return true;
            }
        }
        false
    }

    /// Does `worker`'s invocation crash at `round`? Consumes the event.
    pub fn crash_compute(&mut self, worker: usize, round: usize, now: VTime) -> bool {
        self.fire(worker, FaultKind::CrashCompute, Some(round), now)
    }

    /// Does `worker` crash entering this epoch's sync stage? Consumes.
    pub fn crash_sync(&mut self, worker: usize, now: VTime) -> bool {
        self.fire(worker, FaultKind::CrashSync, None, now)
    }

    /// Does the supervisor crash at `round`? Consumes.
    pub fn crash_supervisor(&mut self, round: usize, now: VTime) -> bool {
        self.fire(SUPERVISOR, FaultKind::CrashSupervisor, Some(round), now)
    }

    /// Next store-tier shard crashing at the top of the current epoch, if
    /// any. Consumes one event per call — loop until `None` to drain an
    /// epoch's shard crashes. Returns the shard id (the event's `worker`
    /// field).
    pub fn crash_shard(&mut self, now: VTime) -> Option<usize> {
        for (i, ev) in self.events.iter().enumerate() {
            if self.fired[i] || !matches!(ev.kind, FaultKind::ShardCrash) {
                continue;
            }
            let hit = match ev.at {
                Trigger::VTime(t) => now.secs() >= t,
                Trigger::Round { epoch, .. } => self.epoch == epoch,
            };
            if hit {
                self.fired[i] = true;
                return Some(ev.worker);
            }
        }
        None
    }

    /// Largest shard id any [`FaultKind::ShardCrash`] event targets (for
    /// validation against the store tier's shard count).
    pub fn max_crashed_shard(&self) -> Option<usize> {
        self.events
            .iter()
            .filter(|ev| matches!(ev.kind, FaultKind::ShardCrash))
            .map(|ev| ev.worker)
            .max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(secs: f64) -> VTime {
        VTime::from_secs(secs)
    }

    #[test]
    fn round_counters_track_per_worker_per_epoch() {
        let mut s = FaultSchedule::new(FaultPlan::none(), 2).unwrap();
        s.begin_epoch(1);
        assert_eq!(s.note_compute(0), 0);
        assert_eq!(s.note_compute(0), 1);
        assert_eq!(s.note_compute(1), 0);
        assert_eq!(s.current_round(0), 1);
        s.redo_round(0);
        assert_eq!(s.note_compute(0), 1, "retry re-runs the same round");
        s.begin_epoch(2);
        assert_eq!(s.note_compute(0), 0);
    }

    #[test]
    fn compute_crash_fires_once_at_its_round() {
        let plan = FaultPlan::none().crash(1, 2, 3);
        let mut s = FaultSchedule::new(plan, 4).unwrap();
        s.begin_epoch(1);
        assert!(!s.crash_compute(1, 3, t(0.0)), "wrong epoch");
        s.begin_epoch(2);
        assert!(!s.crash_compute(1, 2, t(0.0)), "wrong round");
        assert!(!s.crash_compute(0, 3, t(0.0)), "wrong worker");
        assert!(s.crash_compute(1, 3, t(0.0)));
        assert!(!s.crash_compute(1, 3, t(0.0)), "one-shot");
    }

    #[test]
    fn vtime_crash_fires_at_first_consultation_after_t() {
        let plan = FaultPlan::none().crash_at_vtime(0, 100.0);
        let mut s = FaultSchedule::new(plan, 1).unwrap();
        s.begin_epoch(1);
        assert!(!s.crash_compute(0, 0, t(99.9)));
        assert!(s.crash_compute(0, 5, t(100.5)));
        assert!(!s.crash_compute(0, 6, t(200.0)));
    }

    #[test]
    fn straggler_window_is_bounded_in_rounds() {
        let plan = FaultPlan::none().straggler(0, 1, 2, 4.0, Some(3));
        let mut s = FaultSchedule::new(plan, 1).unwrap();
        s.begin_epoch(1);
        assert_eq!(s.compute_factor(0, 1, t(0.0)), 1.0);
        assert_eq!(s.compute_factor(0, 2, t(0.0)), 4.0);
        assert_eq!(s.compute_factor(0, 4, t(0.0)), 4.0);
        assert_eq!(s.compute_factor(0, 5, t(0.0)), 1.0);
        s.begin_epoch(2);
        assert_eq!(s.compute_factor(0, 2, t(0.0)), 1.0, "window was epoch-local");
    }

    #[test]
    fn open_ended_poison_persists_across_epochs() {
        let plan = FaultPlan::none().poison(2, 2, PoisonMode::SignFlip);
        let mut s = FaultSchedule::new(plan, 3).unwrap();
        s.begin_epoch(1);
        assert!(s.poison(2, 0, t(0.0)).is_none());
        s.begin_epoch(2);
        assert_eq!(s.poison(2, 0, t(0.0)), Some(PoisonMode::SignFlip));
        s.begin_epoch(7);
        assert_eq!(s.poison(2, 23, t(0.0)), Some(PoisonMode::SignFlip));
        assert!(s.poison(1, 0, t(0.0)).is_none());
    }

    #[test]
    fn drop_and_sync_and_supervisor_events() {
        let plan = FaultPlan::none()
            .drop_updates(1, 1, 0, Some(2))
            .sync_crash(0, 3)
            .supervisor_crash(2, 5);
        let mut s = FaultSchedule::new(plan, 2).unwrap();
        s.begin_epoch(1);
        assert!(s.drop_update(1, 0, t(0.0)));
        assert!(s.drop_update(1, 1, t(0.0)));
        assert!(!s.drop_update(1, 2, t(0.0)));
        assert!(!s.crash_sync(0, t(0.0)));
        s.begin_epoch(2);
        assert!(!s.crash_supervisor(4, t(0.0)));
        assert!(s.crash_supervisor(5, t(0.0)));
        assert!(!s.crash_supervisor(5, t(0.0)), "one-shot");
        s.begin_epoch(3);
        assert!(s.crash_sync(0, t(0.0)));
        assert!(!s.crash_sync(0, t(0.0)), "one-shot");
    }

    #[test]
    fn shard_crash_fires_once_at_its_epoch() {
        // Shard ids are not worker ids: shard 3 on a 2-worker plan is fine.
        let plan = FaultPlan::none().shard_crash(3, 2).shard_crash(0, 2);
        let mut s = FaultSchedule::new(plan, 2).unwrap();
        assert_eq!(s.max_crashed_shard(), Some(3));
        s.begin_epoch(1);
        assert_eq!(s.crash_shard(t(0.0)), None, "wrong epoch");
        s.begin_epoch(2);
        assert_eq!(s.crash_shard(t(0.0)), Some(3));
        assert_eq!(s.crash_shard(t(0.0)), Some(0), "drains in plan order");
        assert_eq!(s.crash_shard(t(0.0)), None, "one-shot");
        s.begin_epoch(3);
        assert_eq!(s.crash_shard(t(0.0)), None);
    }

    #[test]
    fn poison_modes_corrupt_real_slabs_only() {
        let mut g = Slab::from_vec(vec![1.0, -2.0]);
        PoisonMode::SignFlip.apply(&mut g);
        assert_eq!(g.as_slice().unwrap(), &[-1.0, 2.0]);
        PoisonMode::Scale(-4.0).apply(&mut g);
        assert_eq!(g.as_slice().unwrap(), &[4.0, -8.0]);
        let mut v = Slab::virtual_of(3);
        PoisonMode::Scale(-4.0).apply(&mut v);
        assert_eq!(v.len(), 3);
        assert!(!v.is_real());
    }

    #[test]
    fn coalition_expands_to_coordinated_poison_events() {
        let plan =
            FaultPlan::none().coalition(&[1, 3], 2, 1, Some(2), PoisonMode::Scale(-4.0));
        assert_eq!(plan.events.len(), 2);
        let mut s = FaultSchedule::new(plan, 4).unwrap();
        s.begin_epoch(2);
        for w in [1, 3] {
            assert!(s.poison(w, 0, t(0.0)).is_none(), "before the window");
            assert_eq!(s.poison(w, 1, t(0.0)), Some(PoisonMode::Scale(-4.0)));
            assert_eq!(s.poison(w, 2, t(0.0)), Some(PoisonMode::Scale(-4.0)));
            assert!(s.poison(w, 3, t(0.0)).is_none(), "after the window");
        }
        assert!(s.poison(0, 1, t(0.0)).is_none(), "non-members unaffected");
    }

    #[test]
    fn coalition_naming_a_worker_twice_on_one_round_is_rejected() {
        // The duplicate would silently shadow under first-match-wins.
        let dup = FaultPlan::none().coalition(&[1, 1], 1, 0, Some(2), PoisonMode::SignFlip);
        let err = FaultSchedule::new(dup, 4).unwrap_err().to_string();
        assert!(err.contains("twice"), "{err}");
        // Two open-ended poison events on one worker always overlap.
        let open = FaultPlan::none()
            .poison(2, 1, PoisonMode::SignFlip)
            .poison(2, 5, PoisonMode::Scale(-2.0));
        assert!(FaultSchedule::new(open, 4).is_err());
        // Disjoint bounded windows on the same worker are fine.
        let disjoint = FaultPlan::none()
            .coalition(&[0], 1, 0, Some(2), PoisonMode::SignFlip)
            .coalition(&[0], 1, 5, Some(2), PoisonMode::Scale(-2.0));
        assert!(FaultSchedule::new(disjoint, 4).is_ok());
        // Same rounds on *different* workers is the whole point.
        let coalition =
            FaultPlan::none().coalition(&[0, 1, 2], 1, 0, None, PoisonMode::SignFlip);
        assert!(FaultSchedule::new(coalition, 4).is_ok());
    }

    #[test]
    fn partition_heal_must_follow_start() {
        let backwards = FaultPlan::none().partition(&[0], 50.0, 10.0);
        let err = FaultSchedule::new(backwards, 2).unwrap_err().to_string();
        assert!(err.contains("heal"), "{err}");
        assert!(FaultSchedule::new(FaultPlan::none().partition(&[0], 50.0, 50.0), 2).is_err());
        // Round-triggered partitions have no checkable start: rejected.
        let round_trigger = FaultPlan::none().with(FaultEvent {
            worker: 0,
            kind: FaultKind::Partition { heal: 10.0 },
            at: Trigger::Round { epoch: 1, round: 0 },
            rounds: None,
        });
        assert!(FaultSchedule::new(round_trigger, 2).is_err());
        assert!(FaultSchedule::new(FaultPlan::none().partition(&[0], 10.0, 50.0), 2).is_ok());
    }

    #[test]
    fn partition_window_defers_until_heal() {
        let plan = FaultPlan::none().partition(&[1], 10.0, 50.0);
        let mut s = FaultSchedule::new(plan, 2).unwrap();
        assert!(s.partition_until(1, t(5.0)).is_none(), "before the window");
        assert!(s.partition_until(0, t(20.0)).is_none(), "other worker");
        let hit = s.partition_until(1, t(20.0)).unwrap();
        assert_eq!(hit.until, 50.0);
        assert_eq!(hit.newly, vec![(10.0, 50.0)], "first consultation reports the window");
        let hit = s.partition_until(1, t(30.0)).unwrap();
        assert!(hit.newly.is_empty(), "window reported once");
        assert!(s.partition_until(1, t(50.0)).is_none(), "healed at the boundary");
    }

    #[test]
    fn pareto_straggler_factors_are_deterministic_and_heavy_tailed() {
        let mk = || {
            FaultSchedule::new(
                FaultPlan::none().pareto_stragglers(&[0, 1], 1, 0, 1.5, 1.0, 7, None),
                2,
            )
            .unwrap()
        };
        let mut a = mk();
        let mut b = mk();
        a.begin_epoch(1);
        b.begin_epoch(1);
        let mut max_factor: f64 = 0.0;
        for round in 0..200 {
            let fa = a.compute_factor(0, round, t(0.0));
            assert_eq!(
                fa.to_bits(),
                b.compute_factor(0, round, t(0.0)).to_bits(),
                "same coordinates, same draw"
            );
            assert!(fa >= 1.0, "pareto factor is a slowdown, got {fa}");
            max_factor = max_factor.max(fa);
        }
        assert!(max_factor > 4.0, "200 draws at alpha=1.5 should show a tail, max {max_factor}");
        let other = a.compute_factor(1, 0, t(0.0));
        assert_ne!(other.to_bits(), a.compute_factor(0, 0, t(0.0)).to_bits());
        // Invalid shapes/scales are rejected.
        assert!(FaultSchedule::new(
            FaultPlan::none().pareto_stragglers(&[0], 1, 0, 0.0, 1.0, 7, None),
            1
        )
        .is_err());
        assert!(FaultSchedule::new(
            FaultPlan::none().pareto_stragglers(&[0], 1, 0, 1.5, 0.5, 7, None),
            1
        )
        .is_err());
    }

    #[test]
    fn preemption_storm_fires_each_victim_once() {
        let plan = FaultPlan::none().preemption_storm(&[0, 2], 1, 3);
        let mut s = FaultSchedule::new(plan, 3).unwrap();
        s.begin_epoch(1);
        assert!(!s.preempted(0, 2, t(0.0)), "wrong round");
        assert!(s.preempted(0, 3, t(0.0)));
        assert!(!s.preempted(0, 3, t(0.0)), "one-shot");
        assert!(!s.preempted(1, 3, t(0.0)), "not a victim");
        assert!(s.preempted(2, 3, t(0.0)));
    }

    #[test]
    fn invalid_plans_are_rejected() {
        assert!(FaultSchedule::new(FaultPlan::none().crash(5, 1, 0), 4).is_err());
        assert!(
            FaultSchedule::new(FaultPlan::none().straggler(0, 1, 0, 0.5, None), 4).is_err(),
            "speedup straggler makes no sense"
        );
        let bad = FaultPlan::none().with(FaultEvent {
            worker: 0,
            kind: FaultKind::CrashSupervisor,
            at: Trigger::Round { epoch: 1, round: 0 },
            rounds: None,
        });
        assert!(FaultSchedule::new(bad, 4).is_err());
    }
}
