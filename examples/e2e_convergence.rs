//! End-to-end convergence driver (the repository's headline validation).
//!
//! Trains the executed MobileNet config through the FULL stack — synthetic
//! CIFAR data, real gradients via the AOT-compiled JAX/Pallas artifacts on
//! PJRT, the chosen framework's complete protocol over the simulated AWS
//! substrates — until the target accuracy, logging the loss/accuracy curve.
//! Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example e2e_convergence -- [framework] [epochs] [samples]
//! # e.g.  cargo run --release --example e2e_convergence -- gpu 12 1024
//! ```

use std::rc::Rc;

use slsgpu::cloud::FrameworkKind;
use slsgpu::coordinator::{strategy_for, ClusterEnv, EnvConfig};
use slsgpu::runtime::Engine;
use slsgpu::train::{run_session, SessionConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fw = match args.first().map(|s| s.as_str()).unwrap_or("gpu") {
        "spirt" => FrameworkKind::Spirt,
        "mlless" => FrameworkKind::MlLess,
        "allreduce" => FrameworkKind::AllReduce,
        "scatterreduce" => FrameworkKind::ScatterReduce,
        _ => FrameworkKind::GpuBaseline,
    };
    let max_epochs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(15);
    let samples: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(1024);

    let engine = Rc::new(Engine::load("artifacts")?);
    let mut env =
        ClusterEnv::new(EnvConfig::real(fw, engine, "mobilenet_s", 4, samples, 42)?)?;
    let mut strategy = strategy_for(fw);
    let cfg = SessionConfig { max_epochs, target_acc: 0.80, patience: 8, evaluate: true };

    println!("# e2e convergence: {} on mobilenet_s, {samples} samples, 4 workers", fw.name());
    println!("# epoch, vtime_s, loss, accuracy, cost_usd");
    let wall = std::time::Instant::now();
    let report = run_session(&mut env, strategy.as_mut(), &cfg)?;
    for e in &report.reports {
        println!(
            "{}, {:.1}, {:.4}, {:.4}, {:.5}",
            e.epoch,
            e.vtime_secs,
            e.mean_loss.unwrap_or(f64::NAN),
            e.test_acc.unwrap_or(f64::NAN),
            e.cost_usd
        );
    }
    println!(
        "# final: acc {:.1}%, target reached at {} min (virtual), host wall {:.0}s",
        report.final_acc.unwrap_or(0.0) * 100.0,
        report
            .time_to_target_min
            .map(|m| format!("{m:.2}"))
            .unwrap_or_else(|| "n/a".into()),
        wall.elapsed().as_secs_f64()
    );
    println!(
        "# comm: {} on the wire, {} in-database",
        slsgpu::util::fmt_bytes(env.comm.wire_bytes()),
        slsgpu::util::fmt_bytes(env.comm.bytes(slsgpu::metrics::CommKind::InDb)),
    );
    Ok(())
}
