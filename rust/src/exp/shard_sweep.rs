//! Shard sweep: the store-tier cost/latency frontier for MLLess.
//!
//! The scale sweep (`exp::scale_sweep`) holds the store tier fixed and
//! varies workers; this driver does the opposite experiment for the one
//! architecture whose critical path runs through the shared store. MLLess
//! workers publish per-round updates to the shared Redis tier and read
//! every peer's update back, so at high worker counts the single command
//! loop becomes the bottleneck the paper never measures. Sweeping
//! shards × replication × workers answers the provisioning question: how
//! many shards buy how much epoch time, and what does the extra hosting
//! (plus replication's wire traffic) cost?
//!
//! Every point is an independent deterministic simulation; a Pareto
//! marker flags, within each worker count, the points where no other
//! store configuration is both faster and cheaper (epoch seconds vs
//! paper cost + store hosting).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::cloud::{FrameworkKind, StoreTierConfig};
use crate::coordinator::{strategy_for, ClusterEnv, EnvConfig};
use crate::metrics::CostKind;
use crate::report::{Align, Cell, Report, Table};
use crate::util::{fmt_bytes, fmt_duration};
use crate::Result;

/// Sweep parameters. Combinations with `replication > shards` are
/// invalid tiers and silently skipped rather than rejected, so dense
/// lists like `--shards 1,2,4 --replication 1,2` just work.
#[derive(Debug, Clone)]
pub struct ShardSweepConfig {
    /// Calibrated architecture profile (`mobilenet`, `resnet18`, ...).
    pub arch: String,
    /// Shard counts to sweep.
    pub shard_counts: Vec<usize>,
    /// Replication factors to sweep.
    pub replications: Vec<usize>,
    /// Worker counts to sweep.
    pub worker_counts: Vec<usize>,
    /// Gradient batches per worker per epoch (paper: 24).
    pub batches_per_epoch: usize,
    /// Epochs simulated per point (metrics are per-epoch averages).
    pub epochs: usize,
    /// Simulation threads (0 = one per available core).
    pub threads: usize,
}

impl Default for ShardSweepConfig {
    fn default() -> Self {
        ShardSweepConfig {
            arch: "mobilenet".to_string(),
            shard_counts: vec![1, 2, 4, 8],
            replications: vec![1, 2],
            worker_counts: vec![4, 16, 64],
            batches_per_epoch: 24,
            epochs: 1,
            threads: 0,
        }
    }
}

/// One (shards × replication × workers) measurement for MLLess. Every
/// quantity is a per-epoch mean, matching `scale_sweep::SweepPoint`.
#[derive(Debug, Clone)]
pub struct ShardPoint {
    pub shards: usize,
    pub replication: usize,
    pub workers: usize,
    /// Mean epoch wall time on the virtual timeline (seconds).
    pub epoch_secs: f64,
    /// Mean cost per epoch under the paper's model (USD).
    pub cost_usd: f64,
    /// Mean store hosting per epoch (`CostKind::Ec2Redis`; the paper's
    /// model excludes it, which is exactly why the frontier adds it back).
    pub hosting_usd: f64,
    /// Mean bytes per epoch on the wire (replication fan-out included).
    pub wire_bytes: u64,
    /// Mean store requests per epoch, summed over shards.
    pub store_requests: u64,
    /// Mean seconds per epoch requests spent queued, summed over shards.
    pub queue_wait_secs: f64,
    /// The busiest shard's share of that queueing (contention signal).
    pub max_shard_queue_secs: f64,
    /// Busiest shard's requests over the per-shard mean (1.0 = even).
    pub load_skew: f64,
    /// Failover reads (0 unless a fault plan crashes a shard).
    pub failovers: u64,
    /// On the per-worker-count Pareto frontier of (epoch time, total $).
    pub pareto: bool,
}

impl ShardPoint {
    /// What the frontier actually trades off: paper cost plus the store
    /// hosting the paper's model leaves out.
    pub fn total_usd(&self) -> f64 {
        self.cost_usd + self.hosting_usd
    }

    pub fn label(&self) -> String {
        StoreTierConfig::sharded(self.shards, self.replication).label()
    }
}

fn run_point(
    cfg: &ShardSweepConfig,
    shards: usize,
    replication: usize,
    workers: usize,
) -> Result<ShardPoint> {
    let mut ec = EnvConfig::virtual_paper(FrameworkKind::MlLess, &cfg.arch, workers)?
        .with_store(StoreTierConfig::sharded(shards, replication));
    ec.batches_per_epoch = cfg.batches_per_epoch;
    let mut env = ClusterEnv::new(ec)?;
    let mut strategy = strategy_for(FrameworkKind::MlLess);
    let epochs = cfg.epochs.max(1);
    let mut total_secs = 0.0;
    for _ in 0..epochs {
        total_secs += strategy.run_epoch(&mut env)?.epoch_secs;
    }
    // Hosting is billed for the whole tier over the run's duration; the
    // recovery path can also charge Ec2Redis, so take the delta.
    let hosting_before = env.ledger.get(CostKind::Ec2Redis);
    env.shared_redis.bill_hosting(total_secs, &mut env.ledger);
    let hosting = env.ledger.get(CostKind::Ec2Redis) - hosting_before;

    let reports = env.shared_redis.shard_reports();
    let requests: u64 = reports.iter().map(|r| r.requests).sum();
    let queue_wait: f64 = reports.iter().map(|r| r.queue_wait).sum();
    let max_queue = reports.iter().map(|r| r.queue_wait).fold(0.0, f64::max);
    let max_requests = reports.iter().map(|r| r.requests).max().unwrap_or(0);
    let mean_requests = requests as f64 / reports.len() as f64;
    let epochs_f = epochs as f64;
    Ok(ShardPoint {
        shards,
        replication,
        workers,
        epoch_secs: total_secs / epochs_f,
        cost_usd: env.ledger.total_paper() / epochs_f,
        hosting_usd: hosting / epochs_f,
        wire_bytes: env.comm.wire_bytes() / epochs as u64,
        store_requests: requests / epochs as u64,
        queue_wait_secs: queue_wait / epochs_f,
        max_shard_queue_secs: max_queue / epochs_f,
        load_skew: if requests == 0 { 1.0 } else { max_requests as f64 / mean_requests },
        failovers: env.shared_redis.total_failovers(),
        pareto: false, // filled in by `run` once the whole grid exists
    })
}

/// The grid, minus invalid tiers (replication > shards).
fn tasks_of(cfg: &ShardSweepConfig) -> Vec<(usize, usize, usize)> {
    let mut tasks = Vec::new();
    for &w in &cfg.worker_counts {
        for &s in &cfg.shard_counts {
            for &r in &cfg.replications {
                if r <= s {
                    tasks.push((s, r, w));
                }
            }
        }
    }
    tasks
}

/// Mark, within each worker count, the points no other point dominates
/// on (epoch seconds, total cost): lower-or-equal on both with at least
/// one strictly lower kills a point's frontier membership.
fn mark_frontier(points: &mut [ShardPoint]) {
    let grid: Vec<(usize, f64, f64)> =
        points.iter().map(|p| (p.workers, p.epoch_secs, p.total_usd())).collect();
    for (p, &(w, t, c)) in points.iter_mut().zip(&grid) {
        p.pareto = !grid
            .iter()
            .any(|&(qw, qt, qc)| qw == w && qt <= t && qc <= c && (qt < t || qc < c));
    }
}

/// Run the sweep. Points are scheduled over a work-stealing cursor onto
/// `cfg.threads` std threads; output order is deterministic (workers ×
/// shards × replication, as configured) regardless of thread count.
pub fn run(cfg: &ShardSweepConfig) -> Result<Vec<ShardPoint>> {
    let tasks = tasks_of(cfg);
    if tasks.is_empty() {
        return Ok(Vec::new());
    }
    let threads = if cfg.threads > 0 {
        cfg.threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
    .clamp(1, tasks.len());

    let cursor = AtomicUsize::new(0);
    let outputs: Vec<Vec<(usize, Result<ShardPoint>)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut out = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= tasks.len() {
                            break;
                        }
                        let (s, r, w) = tasks[i];
                        out.push((i, run_point(cfg, s, r, w)));
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("sweep thread panicked")).collect()
    });

    let mut indexed: Vec<(usize, ShardPoint)> = Vec::with_capacity(tasks.len());
    for (i, res) in outputs.into_iter().flatten() {
        indexed.push((i, res?));
    }
    indexed.sort_by_key(|(i, _)| *i);
    let mut points: Vec<ShardPoint> = indexed.into_iter().map(|(_, p)| p).collect();
    mark_frontier(&mut points);
    Ok(points)
}

/// Build the sweep report: the full grid plus the frontier marker.
pub fn report(points: &[ShardPoint], cfg: &ShardSweepConfig) -> Report {
    let mut t = Table::new(
        "shard_sweep",
        &[
            ("W", Align::Right),
            ("Tier", Align::Left),
            ("Epoch", Align::Right),
            ("Cost ($)", Align::Right),
            ("Host ($)", Align::Right),
            ("Wire", Align::Right),
            ("Queue (s)", Align::Right),
            ("Hot shard (s)", Align::Right),
            ("Skew", Align::Right),
            ("Frontier", Align::Left),
        ],
    )
    .title(format!(
        "Store-tier shard sweep — MLLess, {} profile, {} batches/epoch",
        cfg.arch, cfg.batches_per_epoch
    ));
    let mut last_w: Option<usize> = None;
    for p in points {
        if last_w.is_some() && last_w != Some(p.workers) {
            t.rule();
        }
        last_w = Some(p.workers);
        t.push_row(vec![
            Cell::count(p.workers as u64),
            Cell::text(p.label()),
            Cell::text(fmt_duration(p.epoch_secs)).with_value(p.epoch_secs),
            Cell::num(p.cost_usd, 4),
            Cell::num(p.hosting_usd, 4),
            Cell::text(fmt_bytes(p.wire_bytes)).with_value(p.wire_bytes as f64),
            Cell::num(p.queue_wait_secs, 1),
            Cell::num(p.max_shard_queue_secs, 1),
            Cell::num(p.load_skew, 2),
            Cell::text(if p.pareto { "*" } else { "" }),
        ]);
    }
    let fmt_list = |xs: &[usize]| {
        xs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
    };
    Report::new(
        "shard_sweep",
        "Shard sweep — store-tier provisioning frontier (MLLess)",
        format!(
            "slsgpu shard-sweep --arch {} --shards {} --replication {} --workers {} --batches {}",
            cfg.arch,
            fmt_list(&cfg.shard_counts),
            fmt_list(&cfg.replications),
            fmt_list(&cfg.worker_counts),
            cfg.batches_per_epoch
        ),
    )
    .with_intro(
        "MLLess is the architecture whose critical path runs through the shared \
         parameter store: every worker publishes its round update there and reads \
         every peer's back, so one Redis command loop serializes O(W²) transfers \
         per round. Each row provisions the store as a consistent-hash cluster \
         (`Tier` = shards × replication) and re-runs the same seeded epoch; \
         `Queue` is time requests spent waiting for a shard's command loop \
         (summed over shards, per epoch), `Hot shard` the busiest shard's share, \
         `Skew` the busiest shard's request count over the per-shard mean. \
         `Host ($)` is the tier's EC2 hosting — outside the paper's cost model, \
         but exactly the money more shards spend — and `*` marks the per-W Pareto \
         frontier of epoch time vs paper cost + hosting. Replication does not \
         change epoch time materially (replica copies are asynchronous) but shows \
         up in `Wire`; it buys crash survival, priced here, not speed.",
    )
    .with_table(t)
}

/// CLI view of [`report`].
pub fn render(points: &[ShardPoint], cfg: &ShardSweepConfig) -> String {
    report(points, cfg).to_text()
}

/// CSV export (one row per point).
pub fn render_csv(points: &[ShardPoint]) -> String {
    let mut out = String::from(
        "shards,replication,workers,epoch_secs,cost_usd,hosting_usd,wire_bytes,\
         store_requests,queue_wait_secs,max_shard_queue_secs,load_skew,failovers,pareto\n",
    );
    for p in points {
        out.push_str(&format!(
            "{},{},{},{:.6},{:.6},{:.6},{},{},{:.6},{:.6},{:.4},{},{}\n",
            p.shards,
            p.replication,
            p.workers,
            p.epoch_secs,
            p.cost_usd,
            p.hosting_usd,
            p.wire_bytes,
            p.store_requests,
            p.queue_wait_secs,
            p.max_shard_queue_secs,
            p.load_skew,
            p.failovers,
            p.pareto
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> ShardSweepConfig {
        ShardSweepConfig {
            arch: "mobilenet".to_string(),
            shard_counts: vec![1, 2],
            replications: vec![1, 2],
            worker_counts: vec![4],
            batches_per_epoch: 4,
            epochs: 1,
            threads: 2,
        }
    }

    #[test]
    fn sweep_skips_invalid_tiers_and_measures_the_rest() {
        let points = run(&small_cfg()).unwrap();
        // s1r2 is invalid (replication > shards) and silently dropped.
        let tiers: Vec<String> = points.iter().map(|p| p.label()).collect();
        assert_eq!(tiers, vec!["s1r1", "s2r1", "s2r2"]);
        for p in &points {
            assert!(p.epoch_secs > 0.0, "{p:?}");
            assert!(p.cost_usd > 0.0, "{p:?}");
            assert!(p.hosting_usd > 0.0, "{p:?}");
            assert!(p.store_requests > 0, "{p:?}");
            assert_eq!(p.failovers, 0, "no faults in the sweep: {p:?}");
        }
        // Hosting burn rate (USD per virtual second) scales with shards.
        let rate = |p: &ShardPoint| p.hosting_usd / p.epoch_secs;
        let s1 = points.iter().find(|p| p.label() == "s1r1").unwrap();
        let s2 = points.iter().find(|p| p.label() == "s2r1").unwrap();
        assert!(rate(s2) > 1.5 * rate(s1), "{} vs {}", rate(s2), rate(s1));
    }

    #[test]
    fn replication_pays_in_wire_bytes_not_epoch_time() {
        let points = run(&small_cfg()).unwrap();
        let get = |label: &str| points.iter().find(|p| p.label() == label).unwrap();
        let (r1, r2) = (get("s2r1"), get("s2r2"));
        // Replica copies are asynchronous: the client is acked by the
        // primary, so they cost wire bytes without stretching the epoch
        // (they can only delay later ops that queue behind them).
        assert!(r2.wire_bytes > r1.wire_bytes, "{} vs {}", r2.wire_bytes, r1.wire_bytes);
        assert!(r2.epoch_secs < r1.epoch_secs * 1.5, "{} vs {}", r2.epoch_secs, r1.epoch_secs);
    }

    #[test]
    fn sharding_relieves_store_contention_at_scale() {
        let cfg = ShardSweepConfig {
            shard_counts: vec![1, 8],
            replications: vec![1],
            worker_counts: vec![32],
            batches_per_epoch: 4,
            threads: 0,
            ..ShardSweepConfig::default()
        };
        let points = run(&cfg).unwrap();
        let get = |s: usize| points.iter().find(|p| p.shards == s).unwrap();
        let (one, eight) = (get(1), get(8));
        assert!(
            eight.queue_wait_secs < one.queue_wait_secs,
            "8 shards must queue less than 1 at W=32: {} vs {}",
            eight.queue_wait_secs,
            one.queue_wait_secs
        );
        assert!(
            eight.epoch_secs <= one.epoch_secs,
            "less queueing cannot slow the epoch: {} vs {}",
            eight.epoch_secs,
            one.epoch_secs
        );
    }

    #[test]
    fn frontier_marks_the_undominated_points_per_worker_count() {
        let points = run(&small_cfg()).unwrap();
        for &w in &[4usize] {
            let group: Vec<&ShardPoint> =
                points.iter().filter(|p| p.workers == w).collect();
            assert!(group.iter().any(|p| p.pareto), "W={w} has no frontier");
            // The fastest and the cheapest points are always undominated.
            let fastest = group
                .iter()
                .min_by(|a, b| a.epoch_secs.total_cmp(&b.epoch_secs))
                .unwrap();
            let cheapest = group
                .iter()
                .min_by(|a, b| a.total_usd().total_cmp(&b.total_usd()))
                .unwrap();
            assert!(fastest.pareto, "{fastest:?}");
            assert!(cheapest.pareto, "{cheapest:?}");
            // Every dominated point is truly dominated by some frontier point.
            for p in &group {
                if !p.pareto {
                    assert!(group.iter().any(|q| {
                        q.pareto
                            && q.epoch_secs <= p.epoch_secs
                            && q.total_usd() <= p.total_usd()
                    }));
                }
            }
        }
        let table = render(&points, &small_cfg());
        assert!(table.contains("s2r2") && table.contains("Frontier"), "{table}");
        let csv = render_csv(&points);
        assert_eq!(csv.lines().count(), 1 + points.len());
        assert_eq!(csv.lines().nth(1).unwrap().split(',').count(), 13);
    }

    #[test]
    #[ignore = "sweep-scale run (MLLess is O(W^2) store ops per round); run explicitly"]
    fn sweep_completes_at_1024_workers() {
        // The store-tier axis at the scale-sweep's extended worker range:
        // the event-queue core + history pruning must carry a W=1024 MLLess
        // epoch through both a single store and a sharded tier.
        let cfg = ShardSweepConfig {
            shard_counts: vec![1, 8],
            replications: vec![1],
            worker_counts: vec![1024],
            batches_per_epoch: 1,
            threads: 0,
            ..ShardSweepConfig::default()
        };
        let points = run(&cfg).unwrap();
        assert_eq!(points.len(), 2);
        assert!(points.iter().all(|p| p.epoch_secs > 0.0 && p.store_requests > 0));
        let get = |s: usize| points.iter().find(|p| p.shards == s).unwrap();
        assert!(get(8).queue_wait_secs < get(1).queue_wait_secs);
    }

    #[test]
    fn sweep_is_deterministic_across_thread_counts() {
        let mut serial = small_cfg();
        serial.threads = 1;
        let mut parallel = small_cfg();
        parallel.threads = 4;
        let a = run(&serial).unwrap();
        let b = run(&parallel).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.shards, x.replication, x.workers), (y.shards, y.replication, y.workers));
            assert_eq!(
                x.epoch_secs.to_bits(),
                y.epoch_secs.to_bits(),
                "{}: vtime must not depend on thread count",
                x.label()
            );
            assert_eq!(x.cost_usd.to_bits(), y.cost_usd.to_bits());
            assert_eq!(x.queue_wait_secs.to_bits(), y.queue_wait_secs.to_bits());
            assert_eq!(x.pareto, y.pareto);
        }
    }
}
